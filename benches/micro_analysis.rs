//! Micro-benchmarks of the analysis substrate hot paths (the §Perf
//! targets): HBL lattice closure + exponent LP, the exact simplex, the
//! blocking LPs, the GEMMINI tile search and the cycle simulator.
//!
//! Run: `cargo bench --bench micro_analysis`

use convbound::bench::bench;
use convbound::conv::{resnet50_layers, Precision};
use convbound::gemmini::{simulate_layer, GemminiConfig};
use convbound::hbl::{analyze_7nl, analyze_small_filter};
use convbound::lp::{solve, Constraint, Objective, Rat, Rel};
use convbound::tiling::{
    optimize_gemmini_tiling, parallel_blocking, sequential_blocking, OptOptions,
};

fn main() {
    println!("=== analysis-layer micro benchmarks ===\n");

    bench("hbl: analyze_7nl (lattice + exact LP)", 2.0, || {
        std::hint::black_box(analyze_7nl(2, 2).expect("7NL LP feasible"));
    });

    bench("hbl: small-filter lift analysis", 1.0, || {
        std::hint::black_box(analyze_small_filter().expect("LP feasible"));
    });

    bench("lp: exact rational simplex (8 vars)", 1.0, || {
        let ge = |coeffs: Vec<i128>, rhs: i128| Constraint {
            coeffs: coeffs.into_iter().map(Rat::int).collect(),
            rel: Rel::Ge,
            rhs: Rat::int(rhs),
        };
        let cons: Vec<_> = (0..8)
            .map(|i| {
                let mut c = vec![1i128; 8];
                c[i] = 3;
                ge(c, 5)
            })
            .collect();
        let obj = vec![Rat::ONE; 8];
        std::hint::black_box(solve(Objective::Minimize, &obj, &cons));
    });

    let layers = resnet50_layers(1000);
    let p = Precision::paper_mixed();
    let cfg = GemminiConfig::default();

    let conv2 = layers[1].shape;
    bench("tiling: sequential blocking LP (conv2_x)", 1.0, || {
        std::hint::black_box(sequential_blocking(&conv2, p, 65536.0));
    });

    bench("tiling: parallel blocking (conv2_x, P=256)", 1.0, || {
        std::hint::black_box(parallel_blocking(&conv2, p, 256, 1e6));
    });

    let conv4 = layers[3].shape;
    bench("tiling: gemmini optimizer (conv4_x)", 1.0, || {
        std::hint::black_box(optimize_gemmini_tiling(&conv4, &cfg, OptOptions::default()));
    });

    let tile = optimize_gemmini_tiling(&conv4, &cfg, OptOptions::default());
    bench("gemmini: simulate conv4_x @ batch 1000", 3.0, || {
        std::hint::black_box(simulate_layer(&conv4, &cfg, &tile));
    });

    let conv1 = layers[0].shape;
    let tile1 = optimize_gemmini_tiling(&conv1, &cfg, OptOptions::default());
    bench("gemmini: simulate conv1 @ batch 1000", 3.0, || {
        std::hint::black_box(simulate_layer(&conv1, &cfg, &tile1));
    });
}
