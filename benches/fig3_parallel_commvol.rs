//! Figure 3 harness: regenerates the parallel communication-volume series
//! (per-processor words relative to the Theorem 2.2/2.3 bound vs processor
//! count) for ResNet-50 conv1 and conv2_x at batch 1000, p_I = p_F = 1,
//! p_O = 2, and times the generation.
//!
//! Run: `cargo bench --bench fig3_parallel_commvol`

use convbound::bench::{bench, write_csv};
use convbound::conv::{resnet50_layers, Precision};
use convbound::report::{default_proc_sweep, fig3_series, ratio_table};

fn main() {
    let p = Precision::paper_mixed();
    let layers = resnet50_layers(1000);
    let sweep = default_proc_sweep();
    let mem = 1e6;

    for l in &layers[..2] {
        println!("\n=== Figure 3 — {} (batch 1000, M = {mem:.0e} words/proc) ===", l.name);
        let rows = fig3_series(&l.shape, p, &sweep, mem);
        print!("{}", ratio_table("P", &rows).render());

        // paper-shape checks
        let mid = &rows[rows.len() / 2].1;
        println!(
            "at P = {}: blocking {:.1}x, im2col {:.1}x, winograd {:.1}x, fft {:.1}x of bound",
            rows[rows.len() / 2].0, mid[2].1, mid[1].1, mid[3].1, mid[4].1
        );
        let blocking_beats = rows.iter().filter(|(_, r)| r[2].1 <= r[1].1).count();
        println!(
            "blocking <= im2col at {}/{} processor counts (paper: 'blocking outperforms im2col considerably')",
            blocking_beats, rows.len()
        );

        let csv: Vec<Vec<f64>> = rows
            .iter()
            .map(|(pp, r)| {
                let mut row = vec![*pp as f64];
                row.extend(r.iter().map(|(_, v)| *v));
                row
            })
            .collect();
        let path = format!("target/figures/fig3_{}.csv", l.name);
        write_csv(&path, &["P", "naive", "im2col", "blocking", "winograd", "fft"], &csv).unwrap();
        println!("series written to {path}");
    }

    println!("\n=== harness timing ===");
    let shape = layers[1].shape;
    bench("fig3 full sweep (conv2_x, 14 points)", 1.0, || {
        std::hint::black_box(fig3_series(&shape, p, &sweep, mem));
    });
}
