//! Figure 2 harness: regenerates the sequential communication-volume series
//! (ratio to the Theorem 2.1 bound vs memory size) for ResNet-50 conv1 and
//! conv2_x at batch 1000, mixed precision p_I = p_F = 1, p_O = 2 — exactly
//! the paper's setting — and times the generation.
//!
//! Run: `cargo bench --bench fig2_sequential_commvol`

use convbound::bench::{bench, write_csv};
use convbound::conv::{resnet50_layers, Precision};
use convbound::report::{default_mem_sweep, fig2_series, ratio_table};

fn main() {
    let p = Precision::paper_mixed();
    let layers = resnet50_layers(1000);
    let sweep = default_mem_sweep();

    for l in &layers[..2] {
        println!("\n=== Figure 2 — {} (batch 1000, pI=pF=1, pO=2) ===", l.name);
        let rows = fig2_series(&l.shape, p, &sweep);
        print!("{}", ratio_table("M (words)", &rows).render());

        // paper-shape checks, printed for EXPERIMENTS.md
        let first = &rows.first().unwrap().1;
        let last = &rows.last().unwrap().1;
        println!("blocking ratio: {:.2}x at M=2^10 -> {:.2}x at M=2^24", first[2].1, last[2].1);
        println!("im2col   ratio: {:.2}x at M=2^10 -> {:.2}x at M=2^24", first[1].1, last[1].1);
        if l.name == "conv2_x" {
            let cross = rows.iter().find(|(_, r)| r[2].1 < r[1].1);
            match cross {
                Some((m, _)) => println!(
                    "blocking beats im2col from M = {m} words (paper: crossover for large M, σ=1)"
                ),
                None => println!("no blocking/im2col crossover observed in sweep"),
            }
        }

        let csv: Vec<Vec<f64>> = rows
            .iter()
            .map(|(m, r)| {
                let mut row = vec![*m];
                row.extend(r.iter().map(|(_, v)| *v));
                row
            })
            .collect();
        let path = format!("target/figures/fig2_{}.csv", l.name);
        write_csv(&path, &["M", "naive", "im2col", "blocking", "winograd", "fft"], &csv).unwrap();
        println!("series written to {path}");
    }

    println!("\n=== harness timing ===");
    let shape = layers[1].shape;
    bench("fig2 full sweep (conv2_x, 15 points)", 1.0, || {
        std::hint::black_box(fig2_series(&shape, p, &sweep));
    });
}
