//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. tile-optimizer objective: paper's max-updates vs our min-comm;
//! 2. double buffering on/off (the §5 halved-buffer tradeoff);
//! 3. the memory-coalescing burst model on/off (the §5 conv5 story);
//! 4. small-filter split on/off in the sequential blocking LP;
//! 5. multi-level (hierarchical) blocking vs flat blocking at L1 size.
//!
//! Run: `cargo bench --bench ablations`

use convbound::bounds::hierarchy::Hierarchy;
use convbound::commvol::seq::blocking_volume;
use convbound::conv::{resnet50_layers, Precision};
use convbound::gemmini::{simulate_layer, GemminiConfig};
use convbound::report::{fmt_f, fmt_x, Table};
use convbound::tiling::{
    hierarchical_blocking, optimize_gemmini_tiling, OptObjective, OptOptions,
};
use convbound::util::stats::geomean;

fn main() {
    let layers = resnet50_layers(1000);
    let cfg = GemminiConfig::default();
    let p = Precision::paper_mixed();

    // ---- 1. optimizer objective --------------------------------------
    println!("=== ablation 1: tile-optimizer objective (vs vendor, batch 1000) ===\n");
    let mut t = Table::new(&["layer", "max-updates comm", "min-comm comm",
                             "max-updates cycles", "min-comm cycles"]);
    let mut ratios = (Vec::new(), Vec::new());
    for l in &layers {
        let vend = convbound::tiling::vendor_tiling(&l.shape, &cfg);
        let rv = simulate_layer(&l.shape, &cfg, &vend);
        let a = optimize_gemmini_tiling(&l.shape, &cfg, OptOptions::default());
        let b = optimize_gemmini_tiling(&l.shape, &cfg, OptOptions {
            objective: OptObjective::MinCommRows,
            ..Default::default()
        });
        let ra = simulate_layer(&l.shape, &cfg, &a);
        let rb = simulate_layer(&l.shape, &cfg, &b);
        ratios.0.push(ra.comm_rows as f64 / rv.comm_rows as f64);
        ratios.1.push(rb.comm_rows as f64 / rv.comm_rows as f64);
        t.row(vec![
            l.name.to_string(),
            fmt_x(ra.comm_rows as f64 / rv.comm_rows as f64),
            fmt_x(rb.comm_rows as f64 / rv.comm_rows as f64),
            fmt_x(ra.cycles as f64 / rv.cycles as f64),
            fmt_x(rb.cycles as f64 / rv.cycles as f64),
        ]);
    }
    print!("{}", t.render());
    println!(
        "geomean comm vs vendor: max-updates {:.0}%, min-comm {:.0}% (min-comm objective is our extension)\n",
        geomean(&ratios.0) * 100.0,
        geomean(&ratios.1) * 100.0
    );

    // ---- 2. double buffering ------------------------------------------
    println!("=== ablation 2: double buffering ===\n");
    let sb = GemminiConfig { double_buffered: false, ..cfg };
    let mut t = Table::new(&["layer", "db cycles", "single cycles", "speedup"]);
    for l in &layers {
        // tile chosen under the smaller (double-buffered) capacity is legal
        // for both configurations
        let tile = optimize_gemmini_tiling(&l.shape, &cfg, OptOptions::default());
        let fast = simulate_layer(&l.shape, &cfg, &tile);
        let slow = simulate_layer(&l.shape, &sb, &tile);
        t.row(vec![
            l.name.to_string(),
            fmt_f(fast.cycles as f64),
            fmt_f(slow.cycles as f64),
            fmt_x(slow.cycles as f64 / fast.cycles as f64),
        ]);
    }
    print!("{}", t.render());

    // ---- 3. burst/coalescing model ------------------------------------
    println!("\n=== ablation 3: memory-coalescing burst model ===\n");
    let nb = GemminiConfig { burst_overhead_cycles: 0, ..cfg };
    let mut t = Table::new(&["layer", "cycles (burst model)", "cycles (ideal DMA)", "overhead"]);
    for l in &layers {
        let tile = optimize_gemmini_tiling(&l.shape, &cfg, OptOptions::default());
        let with = simulate_layer(&l.shape, &cfg, &tile);
        let without = simulate_layer(&l.shape, &nb, &tile);
        t.row(vec![
            l.name.to_string(),
            fmt_f(with.cycles as f64),
            fmt_f(without.cycles as f64),
            fmt_x(with.cycles as f64 / without.cycles as f64),
        ]);
    }
    print!("{}", t.render());

    // ---- 4. small-filter split in the blocking LP ----------------------
    println!("\n=== ablation 4: small-filter split (conv1, strided 7x7) ===\n");
    let conv1 = layers[0].shape;
    for m in [16384.0, 65536.0, 1048576.0] {
        let vol = blocking_volume(&conv1, p, m);
        // without the split: treat (q, r) ranges as merged by forcing a
        // stride-1-style shape with the same sizes (the LP then cannot
        // exploit σ): approximate by σ=1 shape with identical array sizes
        let mut merged = conv1;
        merged.s_w = 1;
        merged.s_h = 1;
        merged.w_o = conv1.s_w * conv1.w_o;
        merged.h_o = conv1.s_h * conv1.h_o;
        let vol_nosplit = blocking_volume(&merged, p, m)
            / (conv1.s_w * conv1.s_h) as f64; // same G after range merge
        println!(
            "M = {:>8}: with split {:>12} words | merged-range proxy {:>12} words",
            m, fmt_f(vol), fmt_f(vol_nosplit)
        );
    }

    // ---- 5. hierarchical vs flat blocking ------------------------------
    println!("\n=== ablation 5: hierarchical vs flat blocking (conv2_x) ===\n");
    let h = Hierarchy::typical_cpu();
    let s = layers[1].shape;
    let hb = hierarchical_blocking(&s, p, &h);
    let l1 = h.levels[0].capacity_words;
    let flat_l1_traffic = blocking_volume(&s, p, l1);
    println!("flat blocking at L1 ({l1} words): every word from DRAM: {} words", fmt_f(flat_l1_traffic));
    for (i, (tr, lvl)) in hb.traffic.iter().zip(&h.levels).enumerate() {
        println!(
            "hierarchical: boundary above L{} ({} words): {} words",
            i + 1, lvl.capacity_words, fmt_f(*tr)
        );
    }
    println!(
        "DRAM traffic reduction from nesting: {}",
        fmt_x(flat_l1_traffic / hb.traffic.last().unwrap().max(1.0))
    );
}
