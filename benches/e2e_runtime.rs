//! End-to-end runtime benchmarks: the PJRT execute hot path (per-layer and
//! whole-network artifacts) and the batching server's request throughput.
//! Requires `make artifacts`; skips gracefully otherwise.
//!
//! Run: `cargo bench --bench e2e_runtime`

use std::time::Duration;

use convbound::bench::bench;
use convbound::conv::Tensor4;
use convbound::coordinator::ConvServer;
use convbound::runtime::Runtime;

fn artifact_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn main() {
    if !artifact_dir().join("manifest.json").exists() {
        println!("SKIP e2e_runtime: artifacts/ missing — run `make artifacts`");
        return;
    }
    let mut rt = Runtime::new(artifact_dir()).expect("runtime");
    println!("platform: {}\n", rt.platform());

    // per-layer artifacts
    for key in ["unit3x3/blocked", "unit3x3/im2col", "unit1x1/blocked"] {
        let spec = rt.manifest().find(key).expect(key).clone();
        let tensors: Vec<Tensor4> = spec
            .inputs
            .iter()
            .enumerate()
            .map(|(i, d)| Tensor4::randn([d[0], d[1], d[2], d[3]], i as u64))
            .collect();
        rt.load(key).expect("compile");
        let refs: Vec<&Tensor4> = tensors.iter().collect();
        let macs = spec.updates as f64;
        let r = bench(&format!("runtime: execute {key}"), 1.5, || {
            std::hint::black_box(rt.run(key, &refs).expect("run"));
        });
        println!(
            "    -> {:.1} inferences/s, {:.1} MMAC/s",
            spec.inputs[0][0] as f64 / r.summary.mean,
            macs / r.summary.mean / 1e6
        );
    }

    // whole network
    {
        let key = "tiny_resnet/network";
        let spec = rt.manifest().find(key).expect(key).clone();
        let tensors: Vec<Tensor4> = spec
            .inputs
            .iter()
            .enumerate()
            .map(|(i, d)| Tensor4::randn([d[0], d[1], d[2], d[3]], 10 + i as u64))
            .collect();
        rt.load(key).expect("compile");
        let refs: Vec<&Tensor4> = tensors.iter().collect();
        let r = bench("runtime: execute tiny_resnet network", 2.0, || {
            std::hint::black_box(rt.run(key, &refs).expect("run"));
        });
        println!(
            "    -> {:.1} inferences/s, {:.1} MMAC/s",
            spec.inputs[0][0] as f64 / r.summary.mean,
            spec.updates as f64 / r.summary.mean / 1e6
        );
    }

    // serving path
    {
        let key = "unit3x3/blocked";
        let spec = rt.manifest().find(key).expect(key).clone();
        let wd = spec.inputs[1].clone();
        let xd = spec.inputs[0].clone();
        let weights = Tensor4::randn([wd[0], wd[1], wd[2], wd[3]], 3);
        let server = ConvServer::start(artifact_dir(), key, weights, Duration::from_millis(1))
            .expect("server");
        let img = Tensor4::randn([1, xd[1], xd[2], xd[3]], 9);
        let r = bench("server: 64-request burst (batch 4)", 2.0, || {
            let pending: Vec<_> = (0..64)
                .map(|_| server.submit(img.clone()).expect("submit"))
                .collect();
            for rx in pending {
                std::hint::black_box(rx.recv().expect("resp"));
            }
        });
        println!("    -> {:.0} requests/s", 64.0 / r.summary.mean);
        let stats = server.shutdown().expect("stats");
        println!(
            "    batches {} padded {} ({:.1}% waste)",
            stats.batches,
            stats.padded_slots,
            stats.padded_slots as f64 / (stats.batches.max(1) as f64 * 4.0) * 100.0
        );
    }
}
