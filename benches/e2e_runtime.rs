//! End-to-end runtime benchmarks: the execute hot path per layer artifact
//! and the batching server's request throughput.
//!
//! Runs out of the box on the built-in native backend (no artifacts, no
//! PJRT); with an `artifacts/` directory present the same harness drives
//! the artifact-backed runtime instead (and, under the `pjrt` feature, the
//! compiled XLA path including the whole-network artifact).
//!
//! Run: `cargo bench --bench e2e_runtime`

use std::time::Duration;

use convbound::bench::bench;
use convbound::conv::Tensor4;
use convbound::coordinator::ConvServer;
use convbound::runtime::Runtime;

fn artifact_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn main() {
    let have_artifacts = artifact_dir().join("manifest.json").exists();
    let mut rt = if have_artifacts {
        Runtime::new(artifact_dir()).expect("runtime")
    } else {
        println!("artifacts/ missing — benchmarking the built-in native backend");
        Runtime::builtin()
    };
    println!("platform: {}\n", rt.platform());

    // per-layer artifacts
    let layer_keys: Vec<String> = rt
        .manifest()
        .artifacts
        .iter()
        .filter(|a| a.kind == "blocked" || a.kind == "im2col")
        .map(|a| a.key())
        .collect();
    for key in &layer_keys {
        let spec = rt.manifest().find(key).expect("manifest key").clone();
        let tensors: Vec<Tensor4> = spec
            .inputs
            .iter()
            .enumerate()
            .map(|(i, d)| Tensor4::randn([d[0], d[1], d[2], d[3]], i as u64))
            .collect();
        if let Err(e) = rt.load(key).map(|_| ()) {
            println!("SKIP {key}: {e}");
            continue;
        }
        let refs: Vec<&Tensor4> = tensors.iter().collect();
        let macs = spec.updates as f64;
        let r = bench(&format!("runtime: execute {key}"), 1.5, || {
            std::hint::black_box(rt.run(key, &refs).expect("run"));
        });
        println!(
            "    -> {:.1} inferences/s, {:.1} MMAC/s",
            spec.inputs[0][0] as f64 / r.summary.mean,
            macs / r.summary.mean / 1e6
        );
    }

    // whole network (needs the compiled artifact + a backend that runs it)
    if let Some(spec) = rt.manifest().find("tiny_resnet/network").cloned() {
        match rt.load("tiny_resnet/network").map(|_| ()) {
            Ok(()) => {
                let tensors: Vec<Tensor4> = spec
                    .inputs
                    .iter()
                    .enumerate()
                    .map(|(i, d)| Tensor4::randn([d[0], d[1], d[2], d[3]], 10 + i as u64))
                    .collect();
                let refs: Vec<&Tensor4> = tensors.iter().collect();
                let r = bench("runtime: execute tiny_resnet network", 2.0, || {
                    std::hint::black_box(
                        rt.run("tiny_resnet/network", &refs).expect("run"),
                    );
                });
                println!(
                    "    -> {:.1} inferences/s, {:.1} MMAC/s",
                    spec.inputs[0][0] as f64 / r.summary.mean,
                    spec.updates as f64 / r.summary.mean / 1e6
                );
            }
            Err(e) => println!("SKIP tiny_resnet/network: {e}"),
        }
    }

    // serving path
    {
        let key = "unit3x3/blocked";
        let spec = rt.manifest().find(key).expect(key).clone();
        let wd = spec.inputs[1].clone();
        let xd = spec.inputs[0].clone();
        let batch = xd[0];
        let weights = Tensor4::randn([wd[0], wd[1], wd[2], wd[3]], 3);
        let linger = Duration::from_millis(1);
        let server = if have_artifacts {
            ConvServer::start(artifact_dir(), key, weights, linger)
        } else {
            ConvServer::start_builtin(key, weights, linger)
        }
        .expect("server");
        let img = Tensor4::randn([1, xd[1], xd[2], xd[3]], 9);
        let r = bench(
            &format!("server: 64-request burst (batch {batch})"),
            2.0,
            || {
                let pending: Vec<_> = (0..64)
                    .map(|_| server.submit(img.clone()).expect("submit"))
                    .collect();
                for rx in pending {
                    std::hint::black_box(rx.recv().expect("resp"));
                }
            },
        );
        println!("    -> {:.0} requests/s", 64.0 / r.summary.mean);
        let stats = server.shutdown().expect("stats");
        println!(
            "    batches {} padded {} ({:.1}% waste)",
            stats.batches,
            stats.padded_slots,
            stats.padded_slots as f64 / (stats.batches.max(1) as f64 * batch as f64)
                * 100.0
        );
    }
}
