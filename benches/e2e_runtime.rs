//! End-to-end runtime benchmarks: the execute hot path per layer artifact,
//! the batching server's request throughput, a per-kernel catalog sweep
//! (naive vs im2col vs tiled) emitted as machine-readable
//! `BENCH_kernels.json`, and a whole-network sweep comparing layer-by-layer
//! vs fused-reference vs fused-packed execution (throughput + measured
//! per-stage traffic + sliding-window halo-cache savings) emitted as
//! `BENCH_network.json`. `BENCH_training.json` carries the per-layer
//! backward-pass sweep plus a `fused_step` section: the whole training
//! step as fused sweeps vs the materialized layer-by-layer plan.
//!
//! Runs out of the box on the built-in native backend (no artifacts, no
//! PJRT); with an `artifacts/` directory present the same harness drives
//! the artifact-backed runtime instead (and, under the `pjrt` feature, the
//! compiled XLA path including the whole-network artifact).
//!
//! Run: `cargo bench --bench e2e_runtime`
//! Smoke (CI): `cargo bench --bench e2e_runtime -- --smoke` — scaled-down
//! shapes and short measurement windows, still writing the JSON.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use convbound::bench::bench;
use convbound::bounds::parallel_bound;
use convbound::commvol::seq::{
    blocking_volume, im2col_volume, naive_volume, winograd_volume,
};
use convbound::conv::{
    conv7nl_naive, paper_operands, pass_operands, resnet50_layers, scaled,
    ConvPass, Precision, Tensor4,
};
use convbound::coordinator::ConvServer;
use convbound::kernels::{
    conv_im2col, conv_network_fused, conv_network_fused_counted,
    conv_network_staged, conv_network_step_counted, conv_pass_tiled,
    conv_pass_tiled_counted, conv_tiled, conv_tiled_counted,
    conv_tiled_parallel, conv_winograd_counted, conv_winograd_parallel,
    default_workers, exec_sharded, expected_pass_traffic,
    expected_winograd_traffic, naive_network_step, staged_reference,
    verify_exchange, winograd_tolerance, FuseGroup, FusePlan, FusedExec,
    NetPass, NetTrafficCounters, ShardPlan, ShardStrategy,
    ShardTrafficCounters, TilePlan, TilePlanCache, Traffic, TrafficCounters,
    WinoPlan, DEFAULT_TILE_MEM_WORDS,
};
use convbound::obs;
use convbound::runtime::{Manifest, NetworkSpec, NetworkStage, Runtime};
use convbound::util::json::Json;
use convbound::util::threadpool::ThreadPool;

fn artifact_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// One kernel variant's result on one layer.
struct KernelRow {
    kernel: &'static str,
    secs: f64,
    mmac_per_s: f64,
    /// measured word traffic (tiled variants only; 0 for model-only rows)
    measured_words: u64,
    /// commvol::seq model volume for this kernel at the bench M
    model_words: f64,
}

impl KernelRow {
    fn json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("kernel".to_string(), Json::Str(self.kernel.to_string()));
        o.insert("secs".to_string(), Json::Num(self.secs));
        o.insert("mmac_per_s".to_string(), Json::Num(self.mmac_per_s));
        o.insert(
            "measured_words".to_string(),
            Json::Num(self.measured_words as f64),
        );
        o.insert("model_words".to_string(), Json::Num(self.model_words));
        Json::Obj(o)
    }
}

/// The five measured variants. `tiled_serial` is the apples-to-apples
/// comparison against the single-threaded naive/im2col rows (the paper's
/// blocking claim); `tiled` and `winograd` are the production paths over
/// the worker pool (winograd races the paper's algorithm comparison for
/// the 3×3-dominated catalog, validated against the tolerance oracle and
/// the exact transform-domain traffic model on every bench run).
const VARIANTS: [&str; 5] =
    ["naive", "im2col", "tiled_serial", "tiled", "winograd"];

/// Per-kernel sweep over the ResNet catalog; returns the JSON document.
fn kernels_sweep(smoke: bool) -> Json {
    let batch = if smoke { 1 } else { 2 };
    let scale = if smoke { 4 } else { 1 };
    let m = DEFAULT_TILE_MEM_WORDS;
    let p = Precision::uniform();
    let workers = default_workers();
    let pool = ThreadPool::new(workers);

    println!(
        "\n== kernel sweep: ResNet catalog, batch {batch}, scale 1/{scale}, \
         M = {m} words, {workers} workers =="
    );
    let mut layers = Vec::new();
    for l in resnet50_layers(batch) {
        let s = scaled(l.shape, scale);
        let (x, w) = paper_operands(&s, 3);
        let (x, w) = (Arc::new(x), Arc::new(w));
        let plan = Arc::new(TilePlan::new(&s, p, m));
        let wplan = Arc::new(WinoPlan::new(&s, p, m));
        let macs = s.updates() as f64;

        // winograd gates, revalidated on every bench run: one counted
        // execution within the documented tolerance oracle of the naive
        // nest, with measured traffic exactly the analytic transform-
        // domain model
        let wino_measured = {
            let counters = TrafficCounters::new();
            let got = conv_winograd_counted(&x, &w, &wplan, &counters);
            let want = conv7nl_naive(&x, &w, &s);
            let tol = winograd_tolerance(&x, &w, &s);
            let diff = got.max_abs_diff(&want);
            assert!(
                diff <= tol,
                "{}: winograd diverged from naive beyond tolerance \
                 ({diff} > {tol})",
                l.name
            );
            let measured = counters.snapshot();
            assert_eq!(
                measured,
                expected_winograd_traffic(&wplan),
                "{}: measured winograd traffic != analytic model",
                l.name
            );
            measured.total()
        };

        let ktarget = if smoke { 0.05 } else { 0.6 };
        let mut rows: Vec<KernelRow> = Vec::new();
        // one counted run serves both tiled rows: serial and parallel
        // charge identical traffic (asserted by the property tests)
        let mut tiled_measured: Option<u64> = None;
        for kernel in VARIANTS {
            let counters = Arc::new(TrafficCounters::new());
            let r = bench(
                &format!("kernels: {} {kernel}", l.name),
                ktarget,
                || {
                    match kernel {
                        "naive" => std::hint::black_box(conv7nl_naive(&x, &w, &s)),
                        "im2col" => std::hint::black_box(conv_im2col(&x, &w, &s)),
                        "tiled_serial" => {
                            std::hint::black_box(conv_tiled(&x, &w, &plan))
                        }
                        "winograd" => std::hint::black_box(
                            conv_winograd_parallel(
                                &x, &w, &wplan, &pool, &counters,
                            ),
                        ),
                        _ => std::hint::black_box(conv_tiled_parallel(
                            &x, &w, &plan, &pool, &counters,
                        )),
                    };
                },
            );
            let secs = r.summary.p50.max(1e-9);
            // live counters from exactly one execution (the bench loop
            // accumulated warmup + timed iterations, so reset first) —
            // a counter regression shows up here, not just in unit tests
            let measured_words = if kernel == "winograd" {
                wino_measured
            } else if kernel.starts_with("tiled") {
                *tiled_measured.get_or_insert_with(|| {
                    counters.reset();
                    std::hint::black_box(conv_tiled_counted(
                        &x, &w, &plan, &counters,
                    ));
                    counters.snapshot().total()
                })
            } else {
                0
            };
            let model_words = match kernel {
                "naive" => naive_volume(&s, p),
                "im2col" => im2col_volume(&s, p, m),
                "winograd" => winograd_volume(&s, p, m),
                _ => blocking_volume(&s, p, m),
            };
            rows.push(KernelRow {
                kernel,
                secs,
                mmac_per_s: macs / secs / 1e6,
                measured_words,
                model_words,
            });
        }

        let find = |name: &str| rows.iter().find(|r| r.kernel == name).unwrap();
        let (im2col, tser, tiled, wino) = (
            find("im2col"),
            find("tiled_serial"),
            find("tiled"),
            find("winograd"),
        );
        println!(
            "  {:<8} {:>9.0} kMAC: naive {:>7.1} | im2col {:>7.1} | tiled-serial \
             {:>7.1} | tiled/{workers}w {:>7.1} | winograd/{workers}w {:>7.1} \
             MMAC/s (serial blocking speedup {:.2}x vs im2col, traffic {:.2}x \
             of model; winograd traffic {:.2}x of model)",
            l.name,
            macs / 1e3,
            find("naive").mmac_per_s,
            im2col.mmac_per_s,
            tser.mmac_per_s,
            tiled.mmac_per_s,
            wino.mmac_per_s,
            tser.mmac_per_s / im2col.mmac_per_s,
            tser.measured_words as f64 / tser.model_words.max(1.0),
            wino.measured_words as f64 / wino.model_words.max(1.0),
        );

        let mut lo = BTreeMap::new();
        lo.insert("name".to_string(), Json::Str(l.name.to_string()));
        lo.insert("shape".to_string(), Json::Str(s.to_string()));
        lo.insert("updates".to_string(), Json::Num(s.updates() as f64));
        lo.insert(
            "kernels".to_string(),
            Json::Arr(rows.iter().map(|r| r.json()).collect()),
        );
        layers.push(Json::Obj(lo));
    }
    // observability cost gate: the same tiled hot path with the JSONL
    // sink off and on
    let (overhead_x, overhead_ok) = trace_overhead(smoke);

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("kernels".to_string()));
    doc.insert("smoke".to_string(), Json::Bool(smoke));
    doc.insert("mem_words".to_string(), Json::Num(m));
    doc.insert("workers".to_string(), Json::Num(workers as f64));
    doc.insert("trace_overhead_x".to_string(), Json::Num(overhead_x));
    doc.insert("trace_overhead_ok".to_string(), Json::Bool(overhead_ok));
    doc.insert("layers".to_string(), Json::Arr(layers));
    Json::Obj(doc)
}

/// Traced-vs-untraced pair on the tiled hot path. The observability
/// contract is "one branch when disabled, one buffered JSONL line per
/// counted execution when enabled", so the traced run must stay within
/// noise of the untraced one; the ratio and the pass/fail flag land in
/// `BENCH_kernels.json` for the CI gate.
fn trace_overhead(smoke: bool) -> (f64, bool) {
    let batch = if smoke { 1 } else { 2 };
    let scale = if smoke { 4 } else { 1 };
    let m = DEFAULT_TILE_MEM_WORDS;
    let p = Precision::uniform();
    let target = if smoke { 0.05 } else { 0.6 };
    let l = resnet50_layers(batch)
        .into_iter()
        .find(|l| l.name == "conv4_x")
        .expect("catalog layer");
    let s = scaled(l.shape, scale);
    let (x, w) = paper_operands(&s, 7);
    let plan = TilePlan::new(&s, p, m);
    let counters = TrafficCounters::new();

    assert!(!obs::enabled(), "global trace must start disabled");
    let off = bench("trace overhead: tiled untraced", target, || {
        std::hint::black_box(conv_tiled_counted(&x, &w, &plan, &counters));
    });
    let path = std::env::temp_dir().join("convbound_bench_trace.jsonl");
    obs::install_file(path.to_str().unwrap()).expect("trace sink");
    let on = bench("trace overhead: tiled traced", target, || {
        std::hint::black_box(conv_tiled_counted(&x, &w, &plan, &counters));
    });
    obs::uninstall();
    std::fs::remove_file(&path).ok();

    let overhead = on.summary.p50 / off.summary.p50.max(1e-12);
    // p50 is the stable statistic; the slack absorbs timer noise (wider
    // in smoke mode, where windows are 50 ms on scaled-down shapes)
    let limit = if smoke { 1.10 } else { 1.03 };
    println!(
        "\n== trace overhead: traced/untraced p50 {overhead:.4}x \
         (limit {limit:.2}x) =="
    );
    (overhead, overhead <= limit)
}

fn write_json(file: &str, doc: &Json) {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(file);
    match std::fs::write(&path, format!("{doc}\n")) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => println!("\nWARN: could not write {}: {e}", path.display()),
    }
}

/// One execution mode's result on one network.
struct NetworkRow {
    mode: &'static str,
    secs: f64,
    mmac_per_s: f64,
    /// measured per-stage word traffic, summed
    measured_words: u64,
    /// words crossing fused boundaries (must be 0 in fused mode)
    boundary_words: u64,
}

impl NetworkRow {
    fn json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("mode".to_string(), Json::Str(self.mode.to_string()));
        o.insert("secs".to_string(), Json::Num(self.secs));
        o.insert("mmac_per_s".to_string(), Json::Num(self.mmac_per_s));
        o.insert(
            "measured_words".to_string(),
            Json::Num(self.measured_words as f64),
        );
        o.insert(
            "boundary_words".to_string(),
            Json::Num(self.boundary_words as f64),
        );
        Json::Obj(o)
    }
}

/// Layer-by-layer vs fused execution (naive-reference and packed
/// microkernel) over the builtin network pipelines, plus a forced h-tiled
/// fully fused sweep measuring the sliding-window halo cache; returns the
/// `BENCH_network.json` document.
fn network_sweep(smoke: bool) -> Json {
    let m = DEFAULT_TILE_MEM_WORDS;
    let workers = default_workers();
    let pool = ThreadPool::new(workers);
    let cache = TilePlanCache::new();
    let target = if smoke { 0.05 } else { 0.6 };

    println!(
        "\n== network sweep: layered vs fused-reference vs fused-packed, \
         M = {m} words, {workers} workers =="
    );
    let mut nets_json = Vec::new();
    for net in &Manifest::builtin(convbound::runtime::manifest::BUILTIN_BATCH).networks {
        let packed = Arc::new(FusePlan::new(&net.stages, m, &cache));
        let reference = Arc::new({
            let mut p = (*packed).clone();
            p.exec = FusedExec::Reference;
            p
        });
        let image = Arc::new(Tensor4::randn(net.input_dims(), 21));
        let filters: Vec<Arc<Tensor4>> = net
            .stages
            .iter()
            .enumerate()
            .map(|(i, st)| {
                Arc::new(Tensor4::randn(st.shape.filter_dims(), 22 + i as u64))
            })
            .collect();
        let frefs: Vec<&Tensor4> = filters.iter().map(|f| f.as_ref()).collect();
        let macs = net.updates() as f64;
        let counters = NetTrafficCounters::new(net.stages.len());

        // the accumulation-order contract, revalidated on every bench run:
        // packed and reference fused execution agree bitwise
        {
            let ca = NetTrafficCounters::new(net.stages.len());
            let cb = NetTrafficCounters::new(net.stages.len());
            let a = conv_network_fused_counted(&image, &frefs, &packed, &ca);
            let b = conv_network_fused_counted(&image, &frefs, &reference, &cb);
            assert_eq!(
                a.max_abs_diff(&b),
                0.0,
                "{}: packed fused diverged from the reference nest",
                net.name
            );
        }

        let mut rows = Vec::new();
        for mode in ["layered", "fused_reference", "fused_packed"] {
            let plan = if mode == "fused_reference" { &reference } else { &packed };
            let r = bench(&format!("network: {} {mode}", net.name), target, || {
                match mode {
                    "layered" => std::hint::black_box(conv_network_staged(
                        &image, &filters, plan, &pool, &counters,
                    )),
                    _ => std::hint::black_box(conv_network_fused(
                        &image, &filters, plan, &pool, &counters,
                    )),
                };
            });
            // traffic from exactly one execution (bench accumulated
            // warmup + timed iterations)
            counters.reset();
            match mode {
                "layered" => std::hint::black_box(conv_network_staged(
                    &image, &filters, plan, &pool, &counters,
                )),
                _ => std::hint::black_box(conv_network_fused(
                    &image, &filters, plan, &pool, &counters,
                )),
            };
            let per_stage = counters.snapshot();
            let secs = r.summary.p50.max(1e-9);
            rows.push(NetworkRow {
                mode,
                secs,
                mmac_per_s: macs / secs / 1e6,
                measured_words: Traffic::sum(&per_stage).total(),
                // zero in fused modes; the layered baseline shows what the
                // same boundary positions cost when materialized
                boundary_words: packed.boundary_words(&per_stage),
            });
        }
        let find = |name: &str| rows.iter().find(|r| r.mode == name).unwrap();
        let (layered, refr, packd) =
            (find("layered"), find("fused_reference"), find("fused_packed"));
        println!(
            "  {:<12} {} stages, {} fused boundaries: layered {:>7.1} | \
             fused-ref {:>7.1} | fused-packed {:>7.1} MMAC/s (packed \
             {:.2}x layered, {:.2}x reference); traffic {} -> {} words \
             ({:.2}x saved), fused boundary words {}",
            net.name,
            net.stages.len(),
            packed.fused_boundaries(),
            layered.mmac_per_s,
            refr.mmac_per_s,
            packd.mmac_per_s,
            packd.mmac_per_s / layered.mmac_per_s.max(1e-9),
            packd.mmac_per_s / refr.mmac_per_s.max(1e-9),
            layered.measured_words,
            packd.measured_words,
            layered.measured_words as f64 / packd.measured_words.max(1) as f64,
            packd.boundary_words,
        );

        // ---- sliding-window halo study: force a fully fused plan swept
        // in single-row h-tiles so adjacent tiles share halo rows, then
        // run with the cache on and off (bitwise-identical outputs) ----
        let last = net.stages.last().unwrap().shape;
        let mut halo_on = (*packed).clone();
        halo_on.exec = FusedExec::Packed;
        halo_on.halo_cache = true;
        halo_on.groups = vec![FuseGroup {
            start: 0,
            end: net.stages.len() - 1,
            b_n: last.n,
            b_wo: last.w_o,
            b_ho: 1,
        }];
        let mut halo_off = halo_on.clone();
        halo_off.halo_cache = false;
        let ctr_on = NetTrafficCounters::new(net.stages.len());
        let out_on = conv_network_fused_counted(&image, &frefs, &halo_on, &ctr_on);
        let ctr_off = NetTrafficCounters::new(net.stages.len());
        let out_off =
            conv_network_fused_counted(&image, &frefs, &halo_off, &ctr_off);
        assert_eq!(
            out_on.max_abs_diff(&out_off),
            0.0,
            "{}: halo cache changed the result",
            net.name
        );
        let saved = ctr_on.halo_snapshot();
        let saved_total: u64 = saved.iter().sum();
        let in_on = Traffic::sum(&ctr_on.snapshot()).input_words;
        let in_off = Traffic::sum(&ctr_off.snapshot()).input_words;
        println!(
            "  {:<12} halo study (fully fused, b_ho=1): {} words served \
             from the cache; head input {} -> {} words",
            net.name, saved_total, in_off, in_on,
        );

        let mut no = BTreeMap::new();
        no.insert("name".to_string(), Json::Str(net.name.clone()));
        no.insert("batch".to_string(), Json::Num(net.batch() as f64));
        no.insert("stages".to_string(), Json::Num(net.stages.len() as f64));
        no.insert(
            "fused_boundaries".to_string(),
            Json::Num(packed.fused_boundaries() as f64),
        );
        no.insert(
            "groups".to_string(),
            Json::Arr(
                packed
                    .groups
                    .iter()
                    .map(|g| {
                        let mut go = BTreeMap::new();
                        go.insert("start".to_string(), Json::Num(g.start as f64));
                        go.insert("end".to_string(), Json::Num(g.end as f64));
                        go.insert("fused".to_string(), Json::Bool(g.is_fused()));
                        Json::Obj(go)
                    })
                    .collect(),
            ),
        );
        no.insert(
            "modes".to_string(),
            Json::Arr(rows.iter().map(|r| r.json()).collect()),
        );
        no.insert(
            "speedup_fused_vs_layered".to_string(),
            Json::Num(packd.mmac_per_s / layered.mmac_per_s.max(1e-9)),
        );
        no.insert(
            "speedup_packed_vs_reference".to_string(),
            Json::Num(packd.mmac_per_s / refr.mmac_per_s.max(1e-9)),
        );
        // the CI gate: the packed microkernel must not regress below the
        // fused naive baseline (5% slack absorbs measurement noise)
        no.insert(
            "fused_packed_ge_reference".to_string(),
            Json::Bool(packd.mmac_per_s >= 0.95 * refr.mmac_per_s),
        );
        no.insert(
            "halo_saved_words_total".to_string(),
            Json::Num(saved_total as f64),
        );
        no.insert(
            "halo_saved_words".to_string(),
            Json::Arr(saved.iter().map(|&w| Json::Num(w as f64)).collect()),
        );
        no.insert("halo_input_words_on".to_string(), Json::Num(in_on as f64));
        no.insert("halo_input_words_off".to_string(), Json::Num(in_off as f64));
        nets_json.push(Json::Obj(no));
    }
    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("network".to_string()));
    doc.insert("smoke".to_string(), Json::Bool(smoke));
    doc.insert("mem_words".to_string(), Json::Num(m));
    doc.insert("workers".to_string(), Json::Num(workers as f64));
    doc.insert("networks".to_string(), Json::Arr(nets_json));
    Json::Obj(doc)
}

/// Naive vs tiled throughput for the two backward convolutions of a
/// training step, per catalog layer, with the tiled gradients revalidated
/// bitwise against the `conv/training.rs` oracles and their measured
/// traffic against the per-pass analytic model on every bench run; plus a
/// `fused_step` section comparing the whole training step as fused sweeps
/// (`NetPass::Step`) against the fully materialized layer-by-layer plan on
/// the builtin networks (throughput + measured traffic + fused-boundary
/// words, which must be zero). Returns the `BENCH_training.json` document.
fn training_sweep(smoke: bool) -> Json {
    let batch = if smoke { 1 } else { 2 };
    let scale = if smoke { 4 } else { 2 };
    let m = DEFAULT_TILE_MEM_WORDS;
    let p = Precision::uniform();
    let target = if smoke { 0.05 } else { 0.6 };

    println!(
        "\n== training sweep: naive vs tiled dFilter/dInput, ResNet catalog, \
         batch {batch}, scale 1/{scale}, M = {m} words =="
    );
    let mut layers = Vec::new();
    for l in resnet50_layers(batch) {
        let s = scaled(l.shape, scale);
        let macs = s.updates() as f64;
        let mut passes_json = Vec::new();
        let mut summary = Vec::new();
        for pass in [ConvPass::DFilter, ConvPass::DInput] {
            let (a, b) = pass_operands(pass, &s, 5);
            let plan = TilePlan::for_pass(pass, &s, p, m);
            let oracle = || pass.naive_oracle(&a, &b, &s);
            // the backward accumulation-order contract, revalidated on
            // every bench run: tiled gradients are bitwise the oracles,
            // counters exactly the analytic per-pass model
            let counters = TrafficCounters::new();
            let tiled_out = conv_pass_tiled_counted(pass, &a, &b, &plan, &counters);
            assert_eq!(
                tiled_out.max_abs_diff(&oracle()),
                0.0,
                "{} {}: tiled gradient diverged from the oracle",
                l.name,
                pass.name()
            );
            let measured = counters.snapshot();
            let model = expected_pass_traffic(&plan);
            assert_eq!(
                measured, model,
                "{} {}: measured traffic != analytic model",
                l.name,
                pass.name()
            );

            let mut rows = Vec::new();
            for kernel in ["naive", "tiled"] {
                let r = bench(
                    &format!("training: {} {} {kernel}", l.name, pass.name()),
                    target,
                    || {
                        match kernel {
                            "naive" => std::hint::black_box(oracle()),
                            _ => std::hint::black_box(conv_pass_tiled(
                                pass, &a, &b, &plan,
                            )),
                        };
                    },
                );
                let secs = r.summary.p50.max(1e-9);
                let mut o = BTreeMap::new();
                o.insert("kernel".to_string(), Json::Str(kernel.to_string()));
                o.insert("secs".to_string(), Json::Num(secs));
                o.insert("mmac_per_s".to_string(), Json::Num(macs / secs / 1e6));
                o.insert(
                    "measured_words".to_string(),
                    Json::Num(if kernel == "tiled" {
                        measured.total() as f64
                    } else {
                        0.0
                    }),
                );
                o.insert(
                    "model_words".to_string(),
                    Json::Num(model.total() as f64),
                );
                rows.push((kernel, secs, Json::Obj(o)));
            }
            summary.push(format!(
                "{} naive {:.1} | tiled {:.1} MMAC/s",
                pass.name(),
                macs / rows[0].1 / 1e6,
                macs / rows[1].1 / 1e6
            ));
            let mut po = BTreeMap::new();
            po.insert("pass".to_string(), Json::Str(pass.name().to_string()));
            po.insert(
                "traffic_words".to_string(),
                Json::Num(measured.total() as f64),
            );
            po.insert("bitwise_vs_oracle".to_string(), Json::Bool(true));
            po.insert(
                "kernels".to_string(),
                Json::Arr(rows.into_iter().map(|(_, _, j)| j).collect()),
            );
            passes_json.push(Json::Obj(po));
        }
        println!(
            "  {:<8} {:>9.0} kMAC: {}",
            l.name,
            macs / 1e3,
            summary.join(" || ")
        );
        let mut lo = BTreeMap::new();
        lo.insert("name".to_string(), Json::Str(l.name.to_string()));
        lo.insert("shape".to_string(), Json::Str(s.to_string()));
        lo.insert("updates".to_string(), Json::Num(macs));
        lo.insert("passes".to_string(), Json::Arr(passes_json));
        layers.push(Json::Obj(lo));
    }
    // ---- fused training step: the whole step as fused sweeps vs the
    // fully materialized layer-by-layer step plan, per builtin network ----
    println!(
        "\n== fused training step: fused sweeps vs layer-by-layer, \
         builtin networks, M = {m} words =="
    );
    let cache = TilePlanCache::new();
    let mut steps_json = Vec::new();
    for net in &Manifest::builtin(convbound::runtime::manifest::BUILTIN_BATCH).networks {
        let fused = FusePlan::for_pass(NetPass::Step, &net.stages, m, &cache);
        let layered =
            FusePlan::materialized_pass(NetPass::Step, &net.stages, m, &cache);
        let image = Tensor4::randn(net.input_dims(), 31);
        let filters: Vec<Tensor4> = net
            .stages
            .iter()
            .enumerate()
            .map(|(i, st)| Tensor4::randn(st.shape.filter_dims(), 32 + i as u64))
            .collect();
        let frefs: Vec<&Tensor4> = filters.iter().collect();
        let gout = {
            let s = &net.stages[net.stages.len() - 1].shape;
            Tensor4::randn(
                [s.n as usize, s.c_o as usize, s.w_o as usize, s.h_o as usize],
                33,
            )
        };
        // a step performs all three passes per layer: forward recompute,
        // dFilter, dInput
        let step_macs = 3.0 * net.updates() as f64;

        // the step contract, revalidated on every bench run: when every
        // non-last group is fused, the fused step's gradients are bitwise
        // the layer-by-layer SGD oracle's
        if fused.step_bitwise() {
            let c = NetTrafficCounters::new(net.stages.len());
            let (dw, din) =
                conv_network_step_counted(&image, &frefs, &gout, &fused, &c);
            let (dw_ref, din_ref) =
                naive_network_step(&image, &frefs, &gout, &net.stages);
            assert_eq!(
                din.max_abs_diff(&din_ref),
                0.0,
                "{}: fused step dImage diverged from the SGD oracle",
                net.name
            );
            for (k, (a, b)) in dw.iter().zip(&dw_ref).enumerate() {
                assert_eq!(
                    a.max_abs_diff(b),
                    0.0,
                    "{} stage {k}: fused step dFilter diverged",
                    net.name
                );
            }
        }

        let mut rows = Vec::new();
        for (mode, plan) in [("fused", &fused), ("layered", &layered)] {
            let counters = NetTrafficCounters::new(net.stages.len());
            let r = bench(
                &format!("training step: {} {mode}", net.name),
                target,
                || {
                    std::hint::black_box(conv_network_step_counted(
                        &image, &frefs, &gout, plan, &counters,
                    ));
                },
            );
            // traffic from exactly one execution (the bench loop
            // accumulated warmup + timed iterations)
            counters.reset();
            std::hint::black_box(conv_network_step_counted(
                &image, &frefs, &gout, plan, &counters,
            ));
            let per_stage = counters.snapshot();
            assert_eq!(
                per_stage,
                plan.expected_network_traffic(),
                "{} {mode}: measured step traffic != analytic model",
                net.name
            );
            let boundary = plan.boundary_words(&per_stage);
            assert_eq!(
                boundary, 0,
                "{} {mode}: fused boundaries moved words",
                net.name
            );
            let secs = r.summary.p50.max(1e-9);
            rows.push(NetworkRow {
                mode,
                secs,
                mmac_per_s: step_macs / secs / 1e6,
                measured_words: Traffic::sum(&per_stage).total(),
                boundary_words: boundary,
            });
        }
        let find = |name: &str| rows.iter().find(|r| r.mode == name).unwrap();
        let (f, l) = (find("fused"), find("layered"));
        println!(
            "  {:<12} {} stages, {} fused boundaries{}: layered {:>7.1} | \
             fused {:>7.1} MMAC/s ({:.2}x); traffic {} -> {} words ({:.2}x \
             saved), fused boundary words {}",
            net.name,
            net.stages.len(),
            fused.fused_boundaries(),
            if fused.step_bitwise() { " (bitwise)" } else { "" },
            l.mmac_per_s,
            f.mmac_per_s,
            f.mmac_per_s / l.mmac_per_s.max(1e-9),
            l.measured_words,
            f.measured_words,
            l.measured_words as f64 / f.measured_words.max(1) as f64,
            f.boundary_words,
        );

        let mut so = BTreeMap::new();
        so.insert("name".to_string(), Json::Str(net.name.clone()));
        so.insert("batch".to_string(), Json::Num(net.batch() as f64));
        so.insert("stages".to_string(), Json::Num(net.stages.len() as f64));
        so.insert(
            "fused_boundaries".to_string(),
            Json::Num(fused.fused_boundaries() as f64),
        );
        so.insert(
            "step_bitwise".to_string(),
            Json::Bool(fused.step_bitwise()),
        );
        so.insert(
            "modes".to_string(),
            Json::Arr(rows.iter().map(|r| r.json()).collect()),
        );
        so.insert(
            "speedup_fused_vs_layered".to_string(),
            Json::Num(f.mmac_per_s / l.mmac_per_s.max(1e-9)),
        );
        so.insert(
            "boundary_words_fused".to_string(),
            Json::Num(f.boundary_words as f64),
        );
        so.insert(
            "traffic_saved_x".to_string(),
            Json::Num(l.measured_words as f64 / f.measured_words.max(1) as f64),
        );
        steps_json.push(Json::Obj(so));
    }

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("training".to_string()));
    doc.insert("smoke".to_string(), Json::Bool(smoke));
    doc.insert("mem_words".to_string(), Json::Num(m));
    doc.insert("layers".to_string(), Json::Arr(layers));
    doc.insert("fused_step".to_string(), Json::Arr(steps_json));
    Json::Obj(doc)
}

/// One (strategy, shard-count) cell of the parallel scaling sweep.
struct ShardRow {
    strategy: &'static str,
    shards: u64,
    secs: f64,
    mmac_per_s: f64,
    /// inter-shard words counted by the exchange buffers in one execution
    measured_words: u64,
    /// the plan's analytic per-shard model, summed — must equal measured
    expected_words: u64,
    /// Theorem 2.3 parallel lower bound at this processor count
    parallel_bound: f64,
    /// throughput vs the same strategy at P = 1
    speedup: f64,
}

impl ShardRow {
    fn json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert(
            "bound_ratio".to_string(),
            Json::Num(self.measured_words as f64 / self.parallel_bound.max(1.0)),
        );
        o.insert(
            "expected_words".to_string(),
            Json::Num(self.expected_words as f64),
        );
        o.insert(
            "measured_vs_bound_ok".to_string(),
            Json::Bool(self.measured_words == self.expected_words),
        );
        o.insert(
            "measured_words".to_string(),
            Json::Num(self.measured_words as f64),
        );
        o.insert("mmac_per_s".to_string(), Json::Num(self.mmac_per_s));
        o.insert("parallel_bound".to_string(), Json::Num(self.parallel_bound));
        o.insert("secs".to_string(), Json::Num(self.secs));
        o.insert("shards".to_string(), Json::Num(self.shards as f64));
        o.insert("speedup".to_string(), Json::Num(self.speedup));
        o.insert(
            "strategy".to_string(),
            Json::Str(self.strategy.to_string()),
        );
        Json::Obj(o)
    }
}

/// Sharded scaling sweep (`BENCH_parallel.json`): every shard strategy ×
/// P ∈ {1, 2, 4, 8} over one catalog layer and the tiny_resnet chain. Each
/// cell revalidates the tentpole contracts inline — output bitwise equal to
/// the single-node staged tiled engine, measured exchange words exactly
/// equal to the plan's analytic per-shard model — then times the healthy
/// path and reports speedup vs the same strategy at P = 1 plus the measured
/// exchange against the paper's Theorem 2.3 parallel bound. Channel
/// sharding is the traveling-accumulator chain (sequential by the
/// accumulation-order contract), so only batch/spatial are expected to
/// scale.
fn parallel_sweep(smoke: bool) -> Json {
    let m = DEFAULT_TILE_MEM_WORDS;
    let p = Precision::uniform();
    let target = if smoke { 0.05 } else { 0.6 };
    let procs: [u64; 4] = [1, 2, 4, 8];
    let cores = std::thread::available_parallelism()
        .map(std::num::NonZeroUsize::get)
        .unwrap_or(1);

    println!(
        "\n== parallel sweep: sharded engine, strategies x P {procs:?}, \
         M = {m} words, {cores} cores =="
    );

    // batch 8 so batch sharding still has work per shard at P = 8
    let layer = resnet50_layers(8)
        .into_iter()
        .find(|l| l.name == "conv4_x")
        .expect("conv4_x in catalog");
    let lshape = scaled(layer.shape, if smoke { 4 } else { 2 });
    let layer_stages = vec![NetworkStage { shape: lshape, precision: p }];
    let net = NetworkSpec::tiny_resnet(if smoke { 2 } else { 4 });

    let mut entities = Vec::new();
    let mut layer_speedup_p4 = 0.0_f64;
    for (label, stages) in [
        ("conv4_x", layer_stages.as_slice()),
        ("tiny_resnet", net.stages.as_slice()),
    ] {
        let head = stages[0].shape;
        let image = Arc::new(Tensor4::randn(
            [
                head.n as usize,
                head.c_i as usize,
                head.in_w() as usize,
                head.in_h() as usize,
            ],
            41,
        ));
        let filters: Vec<Arc<Tensor4>> = stages
            .iter()
            .enumerate()
            .map(|(i, st)| {
                Arc::new(Tensor4::randn(st.shape.filter_dims(), 42 + i as u64))
            })
            .collect();
        let frefs: Vec<&Tensor4> = filters.iter().map(|f| f.as_ref()).collect();
        let macs: f64 = stages.iter().map(|st| st.shape.updates()).sum::<u64>() as f64;
        let cache = TilePlanCache::new();
        let bound_at = |procs: u64| -> f64 {
            stages
                .iter()
                .map(|st| parallel_bound(&st.shape, st.precision, procs as f64, m))
                .sum()
        };
        // the single-node staged tiled chain every sharded run must match
        // bitwise (NOT the fused path — different accumulation order)
        let want = {
            let p1 = ShardPlan::new(stages, ShardStrategy::Batch, 1, m, &cache);
            staged_reference(&image, &frefs, &p1)
        };

        let mut rows: Vec<ShardRow> = Vec::new();
        for strategy in ShardStrategy::ALL {
            let mut secs_p1 = None;
            for shards in procs {
                let plan = Arc::new(ShardPlan::new(stages, strategy, shards, m, &cache));
                let counters = Arc::new(ShardTrafficCounters::new(plan.workers()));
                // the tentpole gates, revalidated on every bench run:
                // bitwise output + exchange exactly equal to the model
                let out = exec_sharded(&image, &filters, &plan, &counters)
                    .expect("healthy sharded run");
                assert_eq!(
                    out.max_abs_diff(&want),
                    0.0,
                    "{label}: {} x{shards} diverged from the staged engine",
                    strategy.name()
                );
                verify_exchange(&plan, &counters).expect("exchange == model");
                let measured = counters.total().total();
                let expected = plan.expected_exchange().total();
                let r = bench(
                    &format!("parallel: {label} {} x{shards}", strategy.name()),
                    target,
                    || {
                        counters.reset();
                        std::hint::black_box(
                            exec_sharded(&image, &filters, &plan, &counters)
                                .expect("sharded run"),
                        );
                    },
                );
                let secs = r.summary.p50.max(1e-9);
                let base = *secs_p1.get_or_insert(secs);
                let speedup = base / secs;
                if label == "conv4_x"
                    && shards == 4
                    && !matches!(strategy, ShardStrategy::Channel)
                {
                    layer_speedup_p4 = layer_speedup_p4.max(speedup);
                }
                rows.push(ShardRow {
                    strategy: strategy.name(),
                    shards,
                    secs,
                    mmac_per_s: macs / secs / 1e6,
                    measured_words: measured,
                    expected_words: expected,
                    parallel_bound: bound_at(shards),
                    speedup,
                });
                println!(
                    "    -> {:>7.1} MMAC/s, {:.2}x vs P=1, exchange {} words \
                     (model {}, {})",
                    macs / secs / 1e6,
                    speedup,
                    measured,
                    expected,
                    if measured == expected { "exact" } else { "MISMATCH" },
                );
            }
        }

        let best_p4 = rows
            .iter()
            .filter(|r| r.shards == 4)
            .map(|r| r.speedup)
            .fold(0.0_f64, f64::max);
        let mut eo = BTreeMap::new();
        eo.insert("name".to_string(), Json::Str(label.to_string()));
        eo.insert("batch".to_string(), Json::Num(head.n as f64));
        eo.insert("stages".to_string(), Json::Num(stages.len() as f64));
        eo.insert(
            "rows".to_string(),
            Json::Arr(rows.iter().map(|r| r.json()).collect()),
        );
        eo.insert("speedup_at_p4".to_string(), Json::Num(best_p4));
        eo.insert(
            "speedup_gt1_at_p4".to_string(),
            Json::Bool(best_p4 > 1.0),
        );
        entities.push(Json::Obj(eo));
    }

    // acceptance: the catalog layer must scale at P = 4 — but only hold
    // the bench to it when the machine has the cores to show it
    if cores >= 4 {
        assert!(
            layer_speedup_p4 > 1.0,
            "conv4_x: no batch/spatial speedup at P=4 on {cores} cores \
             (best {layer_speedup_p4:.2}x)"
        );
    } else {
        println!(
            "    (skipping P=4 speedup assert: only {cores} cores available)"
        );
    }

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("parallel".to_string()));
    doc.insert("smoke".to_string(), Json::Bool(smoke));
    doc.insert("mem_words".to_string(), Json::Num(m));
    doc.insert("cores".to_string(), Json::Num(cores as f64));
    doc.insert("entities".to_string(), Json::Arr(entities));
    Json::Obj(doc)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    // measurement windows: long enough for stable numbers normally, a few
    // iterations only in smoke mode
    let target = if smoke { 0.05 } else { 1.5 };

    let have_artifacts = artifact_dir().join("manifest.json").exists();
    let mut rt = if have_artifacts {
        Runtime::new(artifact_dir()).expect("runtime")
    } else {
        println!("artifacts/ missing — benchmarking the built-in native backend");
        Runtime::builtin()
    };
    println!("platform: {}{}\n", rt.platform(), if smoke { " (smoke)" } else { "" });

    // per-layer artifacts across all three native kernel kinds
    let layer_keys: Vec<String> = rt
        .manifest()
        .artifacts
        .iter()
        .filter(|a| a.kind == "blocked" || a.kind == "im2col" || a.kind == "tiled")
        .map(|a| a.key())
        .collect();
    for key in &layer_keys {
        let spec = rt.manifest().find(key).expect("manifest key").clone();
        let tensors: Vec<Tensor4> = spec
            .inputs
            .iter()
            .enumerate()
            .map(|(i, d)| Tensor4::randn([d[0], d[1], d[2], d[3]], i as u64))
            .collect();
        if let Err(e) = rt.load(key).map(|_| ()) {
            println!("SKIP {key}: {e}");
            continue;
        }
        let refs: Vec<&Tensor4> = tensors.iter().collect();
        let macs = spec.updates as f64;
        let r = bench(&format!("runtime: execute {key}"), target, || {
            std::hint::black_box(rt.run(key, &refs).expect("run"));
        });
        println!(
            "    -> {:.1} inferences/s, {:.1} MMAC/s",
            spec.inputs[0][0] as f64 / r.summary.mean,
            macs / r.summary.mean / 1e6
        );
    }

    // whole networks, forward and training sweeps (fused pipelines on the
    // native backend; compiled artifacts under pjrt)
    let network_keys: Vec<String> = rt
        .manifest()
        .artifacts
        .iter()
        .filter(|a| a.kind == "network" || a.kind == "training")
        .map(|a| a.key())
        .collect();
    for key in &network_keys {
        let spec = rt.manifest().find(key).expect("manifest key").clone();
        match rt.load(key).map(|_| ()) {
            Ok(()) => {
                let tensors: Vec<Tensor4> = spec
                    .inputs
                    .iter()
                    .enumerate()
                    .map(|(i, d)| Tensor4::randn([d[0], d[1], d[2], d[3]], 10 + i as u64))
                    .collect();
                let refs: Vec<&Tensor4> = tensors.iter().collect();
                let r = bench(&format!("runtime: execute {key}"), target, || {
                    std::hint::black_box(rt.run(key, &refs).expect("run"));
                });
                println!(
                    "    -> {:.1} inferences/s, {:.1} MMAC/s",
                    spec.inputs[0][0] as f64 / r.summary.mean,
                    spec.updates as f64 / r.summary.mean / 1e6
                );
            }
            Err(e) => println!("SKIP {key}: {e}"),
        }
    }

    // serving path — once through the naive-blocked artifact, once tiled
    for key in ["unit3x3/blocked", "unit3x3/tiled"] {
        let spec = match rt.manifest().find(key) {
            Some(s) => s.clone(),
            None => continue,
        };
        let wd = spec.inputs[1].clone();
        let xd = spec.inputs[0].clone();
        let batch = xd[0];
        let weights = Tensor4::randn([wd[0], wd[1], wd[2], wd[3]], 3);
        let linger = Duration::from_millis(1);
        let server = if have_artifacts {
            ConvServer::start(artifact_dir(), key, weights, linger)
        } else {
            ConvServer::start_builtin(key, weights, linger)
        }
        .expect("server");
        // zero-copy submit: the shared image crosses into the executor as
        // an Arc clone, never as a tensor copy
        let img = Arc::new(Tensor4::randn([1, xd[1], xd[2], xd[3]], 9));
        let r = bench(
            &format!("server: 64-request burst, {key} (batch {batch})"),
            target,
            || {
                let pending: Vec<_> = (0..64)
                    .map(|_| server.submit(Arc::clone(&img)).expect("submit"))
                    .collect();
                for rx in pending {
                    std::hint::black_box(rx.recv().expect("resp"));
                }
            },
        );
        println!("    -> {:.0} requests/s", 64.0 / r.summary.mean);
        let stats = server.shutdown().expect("stats");
        println!(
            "    batches {} padded {} ({:.1}% waste)",
            stats.batches,
            stats.padded_slots,
            stats.padded_slots as f64 / (stats.batches.max(1) as f64 * batch as f64)
                * 100.0
        );
    }

    // catalog kernel sweep + machine-readable output
    let doc = kernels_sweep(smoke);
    write_json("BENCH_kernels.json", &doc);

    // whole-network sweep: layer-by-layer vs fused pipelines
    let doc = network_sweep(smoke);
    write_json("BENCH_network.json", &doc);

    // backward passes: naive vs tiled dFilter/dInput per catalog layer
    let doc = training_sweep(smoke);
    write_json("BENCH_training.json", &doc);

    // sharded scaling: strategies x P vs the parallel bounds
    let doc = parallel_sweep(smoke);
    write_json("BENCH_parallel.json", &doc);
}
