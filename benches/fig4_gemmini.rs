//! Figure 4 harness: the GEMMINI evaluation at batch 1000 — estimated
//! communication and simulated clock cycles for our optimization-generated
//! tiling vs the vendor tiling, over the five standard ResNet-50
//! convolution sizes, with and without the §5 conv5 extra constraint.
//!
//! Run: `cargo bench --bench fig4_gemmini`

use convbound::bench::{bench, write_csv};
use convbound::conv::resnet50_layers;
use convbound::gemmini::GemminiConfig;
use convbound::report::{fig4_rows, fig4_table};
use convbound::tiling::{optimize_gemmini_tiling, OptOptions};
use convbound::util::stats::geomean;

fn main() {
    let cfg = GemminiConfig::default();
    let batch = 1000;

    println!("=== Figure 4 — batch {batch}, paper objective (max updates/tile) ===\n");
    let rows = fig4_rows(batch, &cfg, false);
    print!("{}", fig4_table(&rows).render());

    println!("\n=== with the §5 small-image constraint ===\n");
    let fixed = fig4_rows(batch, &cfg, true);
    print!("{}", fig4_table(&fixed).render());

    let comm: Vec<f64> = rows.iter().map(|r| r.comm_ratio()).collect();
    println!("\npaper: communication 45%–85% of vendor; measured {:.0}%–{:.0}% (geomean {:.0}%)",
        comm.iter().cloned().fold(f64::INFINITY, f64::min) * 100.0,
        comm.iter().cloned().fold(0.0_f64, f64::max) * 100.0,
        geomean(&comm) * 100.0);
    println!("paper: small-image regression repaired by one constraint: conv5 {:.0}% -> {:.0}% of vendor cycles",
        rows[4].cycle_ratio() * 100.0, fixed[4].cycle_ratio() * 100.0);

    let csv: Vec<Vec<f64>> = rows
        .iter()
        .enumerate()
        .map(|(i, r)| {
            vec![
                i as f64 + 1.0,
                r.ours.cycles as f64,
                r.vendor.cycles as f64,
                r.ours.comm_rows as f64,
                r.vendor.comm_rows as f64,
                r.vendor.spad_utilization,
            ]
        })
        .collect();
    write_csv(
        "target/figures/fig4.csv",
        &["layer", "ours_cycles", "vendor_cycles", "ours_comm", "vendor_comm", "vendor_util"],
        &csv,
    )
    .unwrap();
    println!("series written to target/figures/fig4.csv");

    println!("\n=== harness timing ===");
    let shape = resnet50_layers(batch)[3].shape;
    bench("gemmini tile optimizer (conv4_x)", 1.0, || {
        std::hint::black_box(optimize_gemmini_tiling(&shape, &cfg, OptOptions::default()));
    });
    bench("full fig4 (5 layers, 2 tilings, sim)", 3.0, || {
        std::hint::black_box(fig4_rows(batch, &cfg, false));
    });
}
