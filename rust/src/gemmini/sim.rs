//! Cycle-approximate GEMMINI execution of a tiled convolution.
//!
//! The paper measures (a) estimated communication — memory-controller rows
//! per tile × number of tiles — and (b) counted clock cycles on FireSim.
//! This simulator reproduces both from first principles:
//!
//! * the tile loop nest is walked exactly (edge tiles clipped), so the
//!   MAC count conservation law `Σ tile MACs = G` holds by construction;
//! * per reduction step, DMA time (rows × 16 B at `dma_bytes_per_cycle`)
//!   and compute time (weight-stationary: one pixel per cycle per 16×16
//!   weight block, plus block-swap fill) overlap under double buffering —
//!   the step costs `max(dma, compute)`; single-buffered they serialize;
//! * per-tile fixed overhead models the config/fence instruction sequence.
//!
//! Absolute cycle counts are not RTL-exact; ratios between tilings are the
//! quantity the paper's Figure 4 reports and are preserved because both
//! tilings run through the identical model.

use crate::conv::ConvShape;
use crate::tiling::gemmini_opt::GemminiTile;
use crate::util::ceil_div;

use super::config::GemminiConfig;

/// Result of simulating one layer under one tiling.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimResult {
    /// total clock cycles
    pub cycles: u64,
    /// exact communication in memory-controller rows (clipped tiles)
    pub comm_rows: u64,
    /// same in bytes
    pub dram_bytes: u64,
    /// multiply-accumulates executed (must equal `shape.updates()`)
    pub macs: u64,
    /// number of (output-tile × reduction-step) iterations
    pub tile_steps: u64,
    /// fraction of peak MAC throughput achieved
    pub mxu_utilization: f64,
    /// scratchpad utilization of a full (non-clipped) tile
    pub spad_utilization: f64,
}

/// Rows occupied by a (possibly clipped) tile instance.
fn clipped_rows(
    s: &ConvShape,
    c: &GemminiConfig,
    bn: u64,
    bci: u64,
    bco: u64,
    bwo: u64,
    bho: u64,
) -> (u64, u64, u64) {
    let dim = c.dim as u64;
    let in_w = s.s_w * (bwo - 1) + s.w_f;
    let in_h = s.s_h * (bho - 1) + s.h_f;
    let input = bn * in_w * in_h * ceil_div(bci, dim);
    let filter = s.w_f * s.h_f * ceil_div(bci, dim) * ceil_div(bco, dim) * dim;
    let output = bn * bwo * bho * ceil_div(bco, dim);
    (input, filter, output)
}

/// Simulate a full layer under `tile`.
pub fn simulate_layer(s: &ConvShape, c: &GemminiConfig, tile: &GemminiTile) -> SimResult {
    assert!(tile.fits(s, c), "tile does not fit the buffers: {tile:?}");
    let dim = c.dim as u64;

    let mut cycles: u64 = 0;
    let mut comm_rows: u64 = 0;
    let mut macs: u64 = 0;
    let mut tile_steps: u64 = 0;
    let mut prev_step_dma: u64 = 0; // for double-buffer pipelining

    // walk output tiles, clipping at the edges
    let mut n0 = 0;
    while n0 < s.n {
        let bn = tile.b_n.min(s.n - n0);
        let mut w0 = 0;
        while w0 < s.w_o {
            let bwo = tile.b_wo.min(s.w_o - w0);
            let mut h0 = 0;
            while h0 < s.h_o {
                let bho = tile.b_ho.min(s.h_o - h0);
                let mut co0 = 0;
                while co0 < s.c_o {
                    let bco = tile.b_co.min(s.c_o - co0);
                    // reduction over ci: accumulator holds the output block
                    let mut ci0 = 0;
                    while ci0 < s.c_i {
                        let bci = tile.b_ci.min(s.c_i - ci0);
                        let (in_r, f_r, _) =
                            clipped_rows(s, c, bn, bci, bco, bwo, bho);
                        let dma_bytes = (in_r + f_r) * dim;
                        // memory coalescing: the image is NCWH row-major in
                        // h, so an input tile spanning only part of h reads
                        // one DRAM segment per (n, ci-block, w) line; a tile
                        // spanning full h coalesces whole (n, ci-block)
                        // planes. Filters are contiguous.
                        let segments = if bho < s.h_o {
                            bn * ceil_div(bci, dim) * (s.s_w * (bwo - 1) + s.w_f)
                        } else {
                            bn * ceil_div(bci, dim)
                        };
                        let dma_cycles = (dma_bytes as f64
                            / c.dma_bytes_per_cycle)
                            .ceil() as u64
                            + segments * c.burst_overhead_cycles;
                        let pixels = bn * bwo * bho;
                        let blocks = s.w_f * s.h_f
                            * ceil_div(bci, dim)
                            * ceil_div(bco, dim);
                        let compute_cycles =
                            blocks * (pixels + c.block_swap_cycles);
                        let step = if c.double_buffered {
                            // this step's compute overlaps this step's DMA
                            // having been prefetched during the previous
                            // step; cost = max(compute, prev DMA)
                            compute_cycles.max(prev_step_dma)
                        } else {
                            compute_cycles + dma_cycles
                        };
                        prev_step_dma = dma_cycles;
                        cycles += step + c.tile_overhead_cycles;
                        comm_rows += in_r + f_r;
                        macs += bn * bci * bco * bwo * bho * s.w_f * s.h_f;
                        tile_steps += 1;
                        ci0 += bci;
                    }
                    // output writeback, once per output tile
                    let (_, _, out_r) = clipped_rows(s, c, bn, 0.max(1), bco, bwo, bho);
                    let wb_bytes = out_r * dim;
                    cycles +=
                        (wb_bytes as f64 / c.dma_bytes_per_cycle).ceil() as u64;
                    comm_rows += out_r;
                    co0 += bco;
                }
                h0 += bho;
            }
            w0 += bwo;
        }
        n0 += bn;
    }
    // drain the last prefetched DMA
    if c.double_buffered {
        cycles += prev_step_dma;
    }

    let peak = (dim * dim) as f64;
    SimResult {
        cycles,
        comm_rows,
        dram_bytes: comm_rows * dim,
        macs,
        tile_steps,
        mxu_utilization: macs as f64 / (cycles as f64 * peak),
        spad_utilization: tile.spad_utilization(s, c),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::resnet50_layers;
    use crate::tiling::{optimize_gemmini_tiling, vendor_tiling, OptOptions};

    fn small_shape() -> ConvShape {
        ConvShape::new(4, 32, 32, 14, 14, 3, 3, 1, 1)
    }

    #[test]
    fn mac_conservation_exact() {
        let s = small_shape();
        let c = GemminiConfig::default();
        for tile in [
            GemminiTile { b_n: 1, b_ci: 16, b_co: 16, b_wo: 7, b_ho: 7 },
            GemminiTile { b_n: 4, b_ci: 32, b_co: 32, b_wo: 14, b_ho: 14 },
            GemminiTile { b_n: 3, b_ci: 5, b_co: 9, b_wo: 4, b_ho: 13 },
        ] {
            if !tile.fits(&s, &c) {
                continue;
            }
            let r = simulate_layer(&s, &c, &tile);
            assert_eq!(r.macs, s.updates(), "{tile:?}");
        }
    }

    #[test]
    fn comm_at_least_compulsory_output() {
        let s = small_shape();
        let c = GemminiConfig::default();
        let tile = optimize_gemmini_tiling(&s, &c, OptOptions::default());
        let r = simulate_layer(&s, &c, &tile);
        // output rows alone are a floor on communication
        let dim = c.dim as u64;
        let out_rows_min = s.n * s.w_o * s.h_o * ceil_div(s.c_o, dim);
        assert!(r.comm_rows >= out_rows_min);
    }

    #[test]
    fn exact_comm_matches_estimate_for_dividing_tiles() {
        // when tile sizes divide the ranges, the simulator's exact count
        // equals the optimizer's closed-form estimate
        let s = small_shape();
        let c = GemminiConfig::default();
        let tile = GemminiTile { b_n: 2, b_ci: 16, b_co: 16, b_wo: 7, b_ho: 7 };
        assert!(tile.fits(&s, &c));
        let r = simulate_layer(&s, &c, &tile);
        assert_eq!(r.comm_rows, tile.comm_rows(&s, &c));
    }

    #[test]
    fn double_buffering_helps() {
        let s = small_shape();
        let db = GemminiConfig::default();
        let sb = GemminiConfig { double_buffered: false, ..db };
        // use a tile that fits the *smaller* (double-buffered) capacity so
        // both configs run the same tiling
        let tile = optimize_gemmini_tiling(&s, &db, OptOptions::default());
        let fast = simulate_layer(&s, &db, &tile);
        let slow = simulate_layer(&s, &sb, &tile);
        assert!(fast.cycles < slow.cycles);
        assert_eq!(fast.comm_rows, slow.comm_rows);
    }

    #[test]
    fn utilization_bounded() {
        let c = GemminiConfig::default();
        for l in resnet50_layers(8) {
            let tile = optimize_gemmini_tiling(&l.shape, &c, OptOptions::default());
            let r = simulate_layer(&l.shape, &c, &tile);
            assert!(r.mxu_utilization > 0.0 && r.mxu_utilization <= 1.0,
                    "{}: {r:?}", l.name);
        }
    }

    #[test]
    fn min_comm_tiling_no_worse_than_vendor_in_sim() {
        use crate::tiling::OptObjective;
        let c = GemminiConfig::default();
        let opts = OptOptions {
            objective: OptObjective::MinCommRows,
            ..Default::default()
        };
        for l in resnet50_layers(32) {
            let ours = optimize_gemmini_tiling(&l.shape, &c, opts);
            let vend = vendor_tiling(&l.shape, &c);
            let ro = simulate_layer(&l.shape, &c, &ours);
            let rv = simulate_layer(&l.shape, &c, &vend);
            // the estimate assumes dividing tiles; allow modest clipping slack
            assert!(
                ro.comm_rows as f64 <= rv.comm_rows as f64 * 1.10,
                "{}: ours {} vendor {}", l.name, ro.comm_rows, rv.comm_rows
            );
        }
    }
}
