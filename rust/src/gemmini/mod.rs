//! GEMMINI accelerator substrate (paper §5).
//!
//! The paper benchmarks its tilings on GEMMINI RTL under FireSim; this
//! module is the simulation substitute (DESIGN.md §2): the same buffer
//! geometry, row-granular memory controller, double-buffered DMA overlap
//! and weight-stationary 16×16 systolic-array timing, driven by the exact
//! tile loop nest.

pub mod config;
pub mod sim;

pub use config::GemminiConfig;
pub use sim::{simulate_layer, SimResult};
