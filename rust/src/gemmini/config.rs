//! GEMMINI accelerator geometry (paper §5, default chip configuration).
//!
//! * 16×16 weight-stationary systolic array (`DIM = 16`).
//! * 256 KiB scratchpad holding 8-bit words → 16384 rows of 16 bytes;
//!   double-buffered, so **8192 rows (128K words)** are usable per tile.
//! * 64 KiB accumulator holding 32-bit words → 1024 rows of 16 entries;
//!   double-buffered, so **512 rows (8K words)** are usable per tile.
//!
//! Rows are the allocation granularity of the chip's memory controller —
//! the paper's "estimated communication" metric counts rows; a tile whose
//! channel count is below 16 wastes the remainder of each row (the
//! root cause of the vendor tiling's poor conv1–conv3 utilization).

/// Chip configuration; `Default` is the paper's setup.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GemminiConfig {
    /// systolic array dimension (PEs per side)
    pub dim: usize,
    /// total scratchpad size in bytes (8-bit input/filter words)
    pub scratchpad_bytes: usize,
    /// total accumulator size in bytes (32-bit output words)
    pub accumulator_bytes: usize,
    /// halves buffers for tiling when true (double-buffered DMA overlap)
    pub double_buffered: bool,
    /// DMA main-memory bandwidth, bytes per cycle (FireSim's shared DDR3
    /// model sustains far less than the on-chip 16 B/cycle port width)
    pub dma_bytes_per_cycle: f64,
    /// fixed per-tile overhead (config / fence instructions), cycles
    pub tile_overhead_cycles: u64,
    /// pipeline fill/drain per weight-block swap, cycles
    pub block_swap_cycles: u64,
    /// DRAM burst-setup cost per non-contiguous segment, cycles — the
    /// "memory coalescing" factor of §5 that the communication-driven
    /// optimizer deliberately does not model
    pub burst_overhead_cycles: u64,
}

impl Default for GemminiConfig {
    fn default() -> Self {
        GemminiConfig {
            dim: 16,
            scratchpad_bytes: 256 * 1024,
            accumulator_bytes: 64 * 1024,
            double_buffered: true,
            dma_bytes_per_cycle: 2.0,
            tile_overhead_cycles: 400,
            block_swap_cycles: 16,
            burst_overhead_cycles: 32,
        }
    }
}

impl GemminiConfig {
    /// Scratchpad rows usable for one tile (halved when double-buffered).
    pub fn spad_rows(&self) -> usize {
        let rows = self.scratchpad_bytes / self.dim;
        if self.double_buffered {
            rows / 2
        } else {
            rows
        }
    }

    /// Accumulator rows usable for one tile.
    pub fn acc_rows(&self) -> usize {
        let rows = self.accumulator_bytes / (self.dim * 4);
        if self.double_buffered {
            rows / 2
        } else {
            rows
        }
    }

    /// Scratchpad capacity in 8-bit words usable per tile.
    pub fn spad_words(&self) -> usize {
        self.spad_rows() * self.dim
    }

    /// Accumulator capacity in 32-bit words usable per tile.
    pub fn acc_words(&self) -> usize {
        self.acc_rows() * self.dim
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_capacities() {
        let c = GemminiConfig::default();
        // "the scratchpad can hold 128K words, while the accumulator can
        // hold 8K words" (§5, after double-buffer halving)
        assert_eq!(c.spad_words(), 128 * 1024);
        assert_eq!(c.acc_words(), 8 * 1024);
        assert_eq!(c.spad_rows(), 8192);
        assert_eq!(c.acc_rows(), 512);
    }

    #[test]
    fn single_buffered_doubles_capacity() {
        let c = GemminiConfig { double_buffered: false, ..Default::default() };
        assert_eq!(c.spad_words(), 256 * 1024);
        assert_eq!(c.acc_words(), 16 * 1024);
    }
}
