//! Offline replay of a trace log: `trace check` validates
//! well-formedness (every line parses, required fields present, span
//! begin/end balance, timestamps monotone, the header is present), and
//! `trace summarize` reconstructs what the run did — per-request latency
//! percentiles, batch-size histogram, per-stage traffic totals — from
//! the log alone, flagging every traffic event whose measured words
//! differ from the analytic expectation embedded next to them.

use std::collections::{BTreeMap, BTreeSet};

use crate::err;
use crate::util::error::{Context, Result};
use crate::util::json::Json;
use crate::util::stats::percentile;

use super::sink::kind;

/// What `trace check` found in a well-formed log.
#[derive(Debug, Clone, Default)]
pub struct CheckReport {
    /// Total events (lines).
    pub events: u64,
    /// Balanced span pairs (one `B` + one `E`).
    pub spans: u64,
    /// Events per kind.
    pub kinds: BTreeMap<String, u64>,
}

impl CheckReport {
    pub fn render(&self) -> String {
        let kinds: Vec<String> = self
            .kinds
            .iter()
            .map(|(k, n)| format!("{k}:{n}"))
            .collect();
        format!(
            "trace OK: {} events, {} spans balanced\nkinds: {}",
            self.events,
            self.spans,
            kinds.join(" ")
        )
    }
}

/// Validate one log. Errors name the first offending line.
pub fn check_text(text: &str) -> Result<CheckReport> {
    let mut open: BTreeMap<u64, String> = BTreeMap::new();
    let mut known: BTreeSet<u64> = BTreeSet::new();
    let mut report = CheckReport::default();
    let mut prev_ts = f64::NEG_INFINITY;
    for (i, line) in text.lines().enumerate() {
        let n = i + 1;
        let v: Json =
            Json::parse(line).with_context(|| format!("trace line {n}"))?;
        if v.as_obj().is_none() {
            return Err(err!("trace line {n}: not a JSON object"));
        }
        let ts = v
            .get("ts_us")
            .as_f64()
            .ok_or_else(|| err!("trace line {n}: missing ts_us"))?;
        if ts < prev_ts {
            return Err(err!("trace line {n}: timestamp regressed"));
        }
        prev_ts = ts;
        v.get("tid")
            .as_f64()
            .ok_or_else(|| err!("trace line {n}: missing tid"))?;
        let k = v
            .get("kind")
            .as_str()
            .ok_or_else(|| err!("trace line {n}: missing kind"))?
            .to_string();
        let ph = v
            .get("ph")
            .as_str()
            .ok_or_else(|| err!("trace line {n}: missing ph"))?;
        if n == 1 && k != kind::TRACE {
            return Err(err!(
                "trace line 1: log must start with the '{}' header",
                kind::TRACE
            ));
        }
        match ph {
            "I" => {}
            "B" => {
                let span = v.get("span").as_u64_strict().ok_or_else(|| {
                    err!("trace line {n}: 'B' event without a span id")
                })?;
                if span == 0 || !known.insert(span) {
                    return Err(err!("trace line {n}: span {span} reused"));
                }
                if let Some(p) = v.get("parent").as_u64() {
                    if !known.contains(&p) {
                        return Err(err!(
                            "trace line {n}: parent span {p} never opened"
                        ));
                    }
                }
                open.insert(span, k.clone());
            }
            "E" => {
                let span = v.get("span").as_u64_strict().ok_or_else(|| {
                    err!("trace line {n}: 'E' event without a span id")
                })?;
                match open.remove(&span) {
                    Some(bk) if bk == k => report.spans += 1,
                    Some(bk) => {
                        return Err(err!(
                            "trace line {n}: span {span} opened as '{bk}' but closed as '{k}'"
                        ))
                    }
                    None => {
                        return Err(err!(
                            "trace line {n}: 'E' for span {span} that is not open"
                        ))
                    }
                }
                // every request must end in exactly one terminal
                // disposition — the fault-model invariant DESIGN.md §12
                // documents and the CI fault gates rely on
                if k == kind::REQUEST {
                    match v.get("disposition").as_str() {
                        Some("ok" | "failed" | "shed" | "expired") => {}
                        Some(other) => {
                            return Err(err!(
                                "trace line {n}: request span {span} closed with unknown disposition '{other}'"
                            ))
                        }
                        None => {
                            return Err(err!(
                                "trace line {n}: request span {span} closed without a terminal disposition"
                            ))
                        }
                    }
                }
            }
            other => return Err(err!("trace line {n}: bad ph '{other}'")),
        }
        *report.kinds.entry(k).or_insert(0) += 1;
        report.events += 1;
    }
    if report.events == 0 {
        return Err(err!("empty trace"));
    }
    if let Some((span, k)) = open.iter().next() {
        return Err(err!(
            "{} span(s) never closed (first: '{k}' span {span})",
            open.len()
        ));
    }
    Ok(report)
}

/// Validate the log at `path`.
pub fn check_file(path: &str) -> Result<CheckReport> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {path}"))?;
    check_text(&text)
}

/// Everything `trace summarize` reconstructs from a log.
#[derive(Debug, Clone, Default)]
pub struct TraceSummary {
    pub events: u64,
    /// Completed requests (`request` `E` events with disposition `ok`,
    /// or — legacy logs — without `dropped:true`).
    pub requests: u64,
    /// Requests accepted but failed (`request` `E` events with
    /// disposition `failed`, or — legacy logs — carrying `dropped:true`).
    pub dropped_requests: u64,
    /// Requests rejected at submit by a full `Shed` queue (disposition
    /// `shed`).
    pub shed: u64,
    /// Requests shed at dequeue for missing their deadline (disposition
    /// `expired`).
    pub expired: u64,
    /// Caught worker panics (`worker_panic` instants).
    pub panicked: u64,
    /// Degradations to a simpler execution path (`degrade` instants).
    pub degraded: u64,
    pub latency_p50_ms: f64,
    pub latency_p95_ms: f64,
    pub latency_p99_ms: f64,
    pub batches: u64,
    pub padded_slots: u64,
    /// Batch size → number of batches dispatched at that size.
    pub batch_hist: BTreeMap<u64, u64>,
    pub linger_flushes: u64,
    /// Max queue depth observed at any enqueue.
    pub peak_queue_depth: u64,
    /// Sum of per-batch executor seconds.
    pub total_exec_secs: f64,
    pub artifact_loads: u64,
    pub tile_plans: u64,
    pub fuse_plans: u64,
    pub autotune_probes: u64,
    pub autotune_pruned: u64,
    /// Traffic events seen (`traffic` + `stage_traffic`).
    pub traffic_events: u64,
    pub measured_words: u64,
    pub expected_words: u64,
    pub halo_words: u64,
    pub expected_halo_words: u64,
    /// Traffic events where any measured component ≠ its analytic
    /// expectation — the number the CI gate greps for zero of.
    pub mismatches: u64,
    /// Per `pass/stage` totals: (measured words, expected words).
    pub stage_words: BTreeMap<String, (u64, u64)>,
    pub logs: u64,
}

fn words(v: &Json, prefix: &str) -> (u64, u64, u64) {
    (
        v.get(&format!("{prefix}_input")).as_u64().unwrap_or(0),
        v.get(&format!("{prefix}_filter")).as_u64().unwrap_or(0),
        v.get(&format!("{prefix}_output")).as_u64().unwrap_or(0),
    )
}

/// Reconstruct a run summary from one log. Every line must parse; span
/// balance is `check`'s business, not this one's.
pub fn summarize_text(text: &str) -> Result<TraceSummary> {
    let mut s = TraceSummary::default();
    let mut latencies: Vec<f64> = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let v: Json = Json::parse(line)
            .with_context(|| format!("trace line {}", i + 1))?;
        s.events += 1;
        let k = v.get("kind").as_str().unwrap_or("");
        let ph = v.get("ph").as_str().unwrap_or("I");
        match (k, ph) {
            (kind::REQUEST, "B") => {
                let d = v.get("queue_depth").as_u64().unwrap_or(0);
                s.peak_queue_depth = s.peak_queue_depth.max(d);
            }
            (kind::REQUEST, "E") => {
                // legacy logs predate dispositions: `dropped:true` meant
                // failed-at-shutdown, anything else completed
                let legacy = if v.get("dropped") == &Json::Bool(true) {
                    "failed"
                } else {
                    "ok"
                };
                match v.get("disposition").as_str().unwrap_or(legacy) {
                    "failed" => s.dropped_requests += 1,
                    "shed" => s.shed += 1,
                    "expired" => s.expired += 1,
                    _ => {
                        s.requests += 1;
                        if let Some(l) = v.get("latency_secs").as_f64() {
                            latencies.push(l);
                        }
                    }
                }
            }
            (kind::BATCH, "B") => {
                s.batches += 1;
                s.padded_slots += v.get("padded").as_u64().unwrap_or(0);
                let size = v.get("size").as_u64().unwrap_or(0);
                *s.batch_hist.entry(size).or_insert(0) += 1;
                if v.get("linger_flush") == &Json::Bool(true) {
                    s.linger_flushes += 1;
                }
            }
            (kind::BATCH, "E") => {
                s.total_exec_secs += v.get("exec_secs").as_f64().unwrap_or(0.0);
            }
            (kind::ARTIFACT_LOAD, _) => s.artifact_loads += 1,
            (kind::TILE_PLAN, _) => s.tile_plans += 1,
            (kind::FUSE_PLAN, _) => s.fuse_plans += 1,
            (kind::AUTOTUNE_PROBE, _) => {
                s.autotune_probes += 1;
                if v.get("pruned") == &Json::Bool(true) {
                    s.autotune_pruned += 1;
                }
            }
            (kind::LOG, _) => s.logs += 1,
            (kind::WORKER_PANIC, _) => s.panicked += 1,
            (kind::DEGRADE, _) => s.degraded += 1,
            (kind::TRAFFIC, _) | (kind::STAGE_TRAFFIC, _) => {
                s.traffic_events += 1;
                let (mi, mf, mo) = words(&v, "measured");
                let (ei, ef, eo) = words(&v, "expected");
                let halo = v.get("halo_words").as_u64().unwrap_or(0);
                let ehalo =
                    v.get("expected_halo_words").as_u64().unwrap_or(0);
                s.measured_words += mi + mf + mo;
                s.expected_words += ei + ef + eo;
                s.halo_words += halo;
                s.expected_halo_words += ehalo;
                if (mi, mf, mo) != (ei, ef, eo) || halo != ehalo {
                    s.mismatches += 1;
                }
                let pass = v.get("pass").as_str().unwrap_or("?");
                let label = match v.get("stage").as_u64() {
                    Some(st) => format!("{pass}/stage{st}"),
                    None => format!("{pass}/layer"),
                };
                let e = s.stage_words.entry(label).or_insert((0, 0));
                e.0 += mi + mf + mo;
                e.1 += ei + ef + eo;
            }
            _ => {}
        }
    }
    if s.events == 0 {
        return Err(err!("empty trace"));
    }
    latencies.sort_by(f64::total_cmp);
    if !latencies.is_empty() {
        s.latency_p50_ms = percentile(&latencies, 0.50) * 1e3;
        s.latency_p95_ms = percentile(&latencies, 0.95) * 1e3;
        s.latency_p99_ms = percentile(&latencies, 0.99) * 1e3;
    }
    Ok(s)
}

/// Summarize the log at `path`.
pub fn summarize_file(path: &str) -> Result<TraceSummary> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {path}"))?;
    summarize_text(&text)
}

impl TraceSummary {
    pub fn render(&self) -> String {
        let mut out = String::new();
        let mut push = |line: String| {
            out.push_str(&line);
            out.push('\n');
        };
        push(format!("events: {}", self.events));
        push(format!("requests: {}", self.requests));
        if self.dropped_requests > 0 {
            push(format!("failed_requests: {}", self.dropped_requests));
        }
        // always printed (even when all-zero) so CI gates can grep it
        push(format!(
            "faults: shed={} expired={} panicked={} degraded={}",
            self.shed, self.expired, self.panicked, self.degraded
        ));
        if self.requests > 0 {
            push(format!(
                "latency_ms: p50={:.3} p95={:.3} p99={:.3}",
                self.latency_p50_ms, self.latency_p95_ms, self.latency_p99_ms
            ));
            push(format!("peak_queue_depth: {}", self.peak_queue_depth));
        }
        if self.batches > 0 {
            let hist: Vec<String> = self
                .batch_hist
                .iter()
                .map(|(size, n)| format!("{n}x{size}"))
                .collect();
            push(format!(
                "batches: {} (sizes {}), padded_slots: {}, linger_flushes: {}",
                self.batches,
                hist.join(" "),
                self.padded_slots,
                self.linger_flushes
            ));
            push(format!("exec_secs: {}", self.total_exec_secs));
        }
        push(format!(
            "plans: {} tile, {} fuse; artifact_loads: {}; autotune_probes: {} ({} LP-pruned); log_lines: {}",
            self.tile_plans,
            self.fuse_plans,
            self.artifact_loads,
            self.autotune_probes,
            self.autotune_pruned,
            self.logs
        ));
        push(format!(
            "traffic_events: {} (measured {} words, expected {} words; halo {} vs {})",
            self.traffic_events,
            self.measured_words,
            self.expected_words,
            self.halo_words,
            self.expected_halo_words
        ));
        for (label, (m, e)) in &self.stage_words {
            push(format!("  {label}: measured={m} expected={e}"));
        }
        push(format!(
            "measured-vs-expected mismatches: {}",
            self.mismatches
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn line(s: &str) -> String {
        // tests write fields; the scaffold adds the required envelope
        format!("{s}\n")
    }

    fn hdr() -> String {
        line(r#"{"kind":"trace","ph":"I","tid":0,"ts_us":0,"version":1}"#)
    }

    #[test]
    fn check_accepts_balanced_nested_log() {
        let log = hdr()
            + &line(r#"{"kind":"batch","ph":"B","span":1,"tid":1,"ts_us":5,"size":4,"padded":2}"#)
            + &line(r#"{"kind":"dispatch","ph":"B","span":2,"parent":1,"tid":1,"ts_us":6}"#)
            + &line(r#"{"kind":"log","ph":"I","tid":1,"ts_us":7,"msg":"x"}"#)
            + &line(r#"{"kind":"dispatch","ph":"E","span":2,"tid":1,"ts_us":9}"#)
            + &line(r#"{"kind":"batch","ph":"E","span":1,"tid":1,"ts_us":9,"exec_secs":0.5}"#);
        let r = check_text(&log).unwrap();
        assert_eq!(r.events, 6);
        assert_eq!(r.spans, 2);
        assert_eq!(r.kinds["batch"], 2);
        assert!(r.render().contains("trace OK"));
    }

    #[test]
    fn check_rejects_malformed_logs() {
        // garbage line
        let garbage = hdr() + "not json\n";
        assert!(check_text(&garbage).unwrap_err().to_string().contains("line 2"));
        // missing header
        let no_hdr =
            line(r#"{"kind":"log","ph":"I","tid":0,"ts_us":0}"#);
        assert!(check_text(&no_hdr)
            .unwrap_err()
            .to_string()
            .contains("header"));
        // unclosed span
        let unclosed = hdr()
            + &line(r#"{"kind":"batch","ph":"B","span":1,"tid":0,"ts_us":1}"#);
        assert!(check_text(&unclosed)
            .unwrap_err()
            .to_string()
            .contains("never closed"));
        // E without B
        let stray = hdr()
            + &line(r#"{"kind":"batch","ph":"E","span":9,"tid":0,"ts_us":1}"#);
        assert!(check_text(&stray)
            .unwrap_err()
            .to_string()
            .contains("not open"));
        // close under a different kind
        let crossed = hdr()
            + &line(r#"{"kind":"batch","ph":"B","span":1,"tid":0,"ts_us":1}"#)
            + &line(r#"{"kind":"dispatch","ph":"E","span":1,"tid":0,"ts_us":2}"#);
        assert!(check_text(&crossed)
            .unwrap_err()
            .to_string()
            .contains("closed as"));
        // timestamp regression
        let regress = hdr()
            + &line(r#"{"kind":"log","ph":"I","tid":0,"ts_us":5}"#)
            + &line(r#"{"kind":"log","ph":"I","tid":0,"ts_us":4}"#);
        assert!(check_text(&regress)
            .unwrap_err()
            .to_string()
            .contains("regressed"));
        // missing required field
        let no_ts = hdr() + &line(r#"{"kind":"log","ph":"I","tid":0}"#);
        assert!(check_text(&no_ts)
            .unwrap_err()
            .to_string()
            .contains("ts_us"));
        assert!(check_text("").is_err());
    }

    #[test]
    fn summarize_reconstructs_counts_latency_and_traffic() {
        let log = hdr()
            + &line(r#"{"kind":"request","ph":"B","span":1,"tid":0,"ts_us":1,"req":0,"queue_depth":1}"#)
            + &line(r#"{"kind":"request","ph":"B","span":2,"tid":0,"ts_us":2,"req":1,"queue_depth":2}"#)
            + &line(r#"{"kind":"batch","ph":"B","span":3,"tid":1,"ts_us":3,"seq":0,"size":2,"padded":1,"linger_flush":true}"#)
            + &line(r#"{"kind":"stage_traffic","ph":"I","tid":1,"ts_us":4,"pass":"fwd","stage":0,"measured_input":10,"measured_filter":4,"measured_output":6,"expected_input":10,"expected_filter":4,"expected_output":6,"halo_words":3,"expected_halo_words":3}"#)
            + &line(r#"{"kind":"traffic","ph":"I","tid":1,"ts_us":5,"pass":"dfilter","measured_input":7,"measured_filter":2,"measured_output":1,"expected_input":7,"expected_filter":3,"expected_output":1}"#)
            + &line(r#"{"kind":"request","ph":"E","span":1,"tid":1,"ts_us":6,"req":0,"latency_secs":0.001}"#)
            + &line(r#"{"kind":"request","ph":"E","span":2,"tid":1,"ts_us":7,"req":1,"latency_secs":0.003}"#)
            + &line(r#"{"kind":"batch","ph":"E","span":3,"tid":1,"ts_us":8,"exec_secs":0.25}"#);
        let s = summarize_text(&log).unwrap();
        assert_eq!(s.requests, 2);
        assert_eq!(s.batches, 1);
        assert_eq!(s.padded_slots, 1);
        assert_eq!(s.linger_flushes, 1);
        assert_eq!(s.batch_hist[&2], 1);
        assert_eq!(s.peak_queue_depth, 2);
        assert_eq!(s.total_exec_secs, 0.25);
        assert_eq!(s.traffic_events, 2);
        assert_eq!(s.measured_words, 20 + 10);
        assert_eq!(s.expected_words, 20 + 11);
        assert_eq!(s.halo_words, 3);
        assert_eq!(s.expected_halo_words, 3);
        // the dfilter event's filter words disagree → exactly one flag
        assert_eq!(s.mismatches, 1);
        assert_eq!(s.stage_words["fwd/stage0"], (20, 20));
        assert_eq!(s.stage_words["dfilter/layer"], (10, 11));
        // percentiles via util::stats::percentile on the sorted samples
        let lat = [0.001, 0.003];
        assert_eq!(s.latency_p50_ms, percentile(&lat, 0.50) * 1e3);
        assert_eq!(s.latency_p99_ms, percentile(&lat, 0.99) * 1e3);
        let text = s.render();
        assert!(text.contains("measured-vs-expected mismatches: 1"));
        assert!(text.contains("fwd/stage0"));
        // legacy log: no fault activity
        assert!(text.contains("faults: shed=0 expired=0 panicked=0 degraded=0"));
    }

    #[test]
    fn summarize_counts_dispositions_and_fault_instants() {
        let log = hdr()
            + &line(r#"{"kind":"request","ph":"B","span":1,"tid":0,"ts_us":1,"req":0,"queue_depth":1}"#)
            + &line(r#"{"kind":"request","ph":"B","span":2,"tid":0,"ts_us":2,"req":1,"queue_depth":2}"#)
            + &line(r#"{"kind":"request","ph":"B","span":3,"tid":0,"ts_us":3,"req":2,"queue_depth":2}"#)
            + &line(r#"{"kind":"request","ph":"B","span":4,"tid":0,"ts_us":4,"req":3,"queue_depth":3}"#)
            + &line(r#"{"kind":"worker_panic","ph":"I","tid":1,"ts_us":5,"key":"k","path":"tiled","cause":"boom"}"#)
            + &line(r#"{"kind":"degrade","ph":"I","tid":1,"ts_us":6,"key":"k","from":"tiled","to":"naive","cause":"boom"}"#)
            + &line(r#"{"kind":"request","ph":"E","span":1,"tid":1,"ts_us":7,"req":0,"disposition":"ok","latency_secs":0.002}"#)
            + &line(r#"{"kind":"request","ph":"E","span":2,"tid":1,"ts_us":8,"req":1,"disposition":"failed","cause":"x"}"#)
            + &line(r#"{"kind":"request","ph":"E","span":3,"tid":0,"ts_us":9,"req":2,"disposition":"shed","cause":"queue full"}"#)
            + &line(r#"{"kind":"request","ph":"E","span":4,"tid":1,"ts_us":10,"req":3,"disposition":"expired","cause":"deadline"}"#);
        let s = summarize_text(&log).unwrap();
        assert_eq!(s.requests, 1);
        assert_eq!(s.dropped_requests, 1);
        assert_eq!(s.shed, 1);
        assert_eq!(s.expired, 1);
        assert_eq!(s.panicked, 1);
        assert_eq!(s.degraded, 1);
        let text = s.render();
        assert!(text.contains("faults: shed=1 expired=1 panicked=1 degraded=1"));
        // the same log is also well-formed under check
        let r = check_text(&log).unwrap();
        assert_eq!(r.spans, 4);
    }

    #[test]
    fn check_requires_a_terminal_disposition_on_request_spans() {
        let missing = hdr()
            + &line(r#"{"kind":"request","ph":"B","span":1,"tid":0,"ts_us":1,"req":0}"#)
            + &line(r#"{"kind":"request","ph":"E","span":1,"tid":0,"ts_us":2,"req":0}"#);
        assert!(check_text(&missing)
            .unwrap_err()
            .to_string()
            .contains("without a terminal disposition"));
        let unknown = hdr()
            + &line(r#"{"kind":"request","ph":"B","span":1,"tid":0,"ts_us":1,"req":0}"#)
            + &line(r#"{"kind":"request","ph":"E","span":1,"tid":0,"ts_us":2,"req":0,"disposition":"vanished"}"#);
        assert!(check_text(&unknown)
            .unwrap_err()
            .to_string()
            .contains("unknown disposition"));
        // non-request spans stay disposition-free
        let batch = hdr()
            + &line(r#"{"kind":"batch","ph":"B","span":1,"tid":0,"ts_us":1}"#)
            + &line(r#"{"kind":"batch","ph":"E","span":1,"tid":0,"ts_us":2}"#);
        assert!(check_text(&batch).is_ok());
    }
}
