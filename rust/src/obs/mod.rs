//! Observability: structured JSONL tracing for the whole stack.
//!
//! The paper's reproduction *measures* everything — exact per-pass
//! traffic, halo words, autotuner prune counts — and this module is how
//! those measurements leave the process: a thread-safe event sink
//! ([`TraceSink`]) that writes one JSON object per line, every traffic
//! event carrying the *analytic* expectation next to the *measured*
//! value so the trace itself is a correctness gate, and a replay half
//! ([`replay`]) that validates and summarizes a log offline
//! (`convbound trace check|summarize`).
//!
//! The sink is off by default and the disabled fast path is one atomic
//! load ([`enabled`]), so instrumented hot paths pay one branch. Enable
//! it with `--trace <path>` on `serve`/`exec` or the `CONVBOUND_TRACE`
//! env var. The event schema (kinds, fields, span nesting) is documented
//! in DESIGN.md §10.

pub mod replay;
pub mod sink;

pub use replay::{
    check_file, check_text, summarize_file, summarize_text, CheckReport,
    TraceSummary,
};
pub use sink::{
    enabled, event, flush, init_from_env, install, install_file, jb, jf, js,
    ju, kind, log, scope, set_verbosity, uninstall, verbosity, Level,
    ScopeGuard, SpanId, TraceSink, TRACE_VERSION,
};
