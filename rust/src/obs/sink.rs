//! The event sink: a process-wide, thread-safe JSONL trace writer.
//!
//! One event is one JSON object on one line, built with
//! [`crate::util::json::Json`] (no external serializers). Every event
//! carries:
//!
//! * `ts_us`  — monotonic microseconds since the sink was created,
//!   stamped under the writer lock so lines land in non-decreasing order;
//! * `tid`    — a small per-thread tag (threadpool workers get their own);
//! * `kind`   — the event kind (see [`kind`]);
//! * `ph`     — the phase: `"B"` opens a span, `"E"` closes it, `"I"` is
//!   an instant event (the Chrome-trace convention);
//! * `span` / `parent` — span ids for `"B"`/`"E"` events. Same-thread
//!   nesting (batch → dispatch → exec → stage) is inferred from a
//!   thread-local span stack; cross-thread spans (a request enqueued on
//!   the caller's thread and completed on the executor's) carry their id
//!   explicitly via [`TraceSink::span_id`]/[`TraceSink::span_open`].
//!
//! Sinks come in three flavors: [`TraceSink::disabled`] (every call is a
//! no-op), [`TraceSink::to_file`]/[`TraceSink::to_writer`] (an owned
//! writer — what tests and per-server tracing use), and
//! [`TraceSink::global`] (defers to the process-wide sink installed by
//! [`install`]/[`init_from_env`] — what `--trace` and `CONVBOUND_TRACE`
//! switch on). The disabled/uninstalled fast path is a single relaxed
//! atomic load.

use std::collections::BTreeMap;
use std::io::Write;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::err;
use crate::util::error::{Context, Result};
use crate::util::json::Json;

/// Schema version stamped into the `trace` header event (the first line
/// of every log).
pub const TRACE_VERSION: u64 = 1;

/// Event kind names — one vocabulary shared by the emitters, the replay
/// tools and the tests. See DESIGN.md §10 for each kind's fields.
pub mod kind {
    /// Header event: first line of every log (`version`).
    pub const TRACE: &str = "trace";
    /// Server request span: `B` at enqueue (`req`, `queue_depth`), `E` at
    /// reply (`req`, `latency_secs`).
    pub const REQUEST: &str = "request";
    /// Server batch span: `B` when the batch forms (`seq`, `size`,
    /// `padded`, `linger_flush`, `reqs`), `E` after replies (`exec_secs`).
    pub const BATCH: &str = "batch";
    /// Runtime dispatch span inside a batch (`key`; `E` adds `secs`).
    pub const DISPATCH: &str = "dispatch";
    /// Instant: an artifact entered the runtime cache (`key`, `artifact`).
    pub const ARTIFACT_LOAD: &str = "artifact_load";
    /// Runtime executable span around one artifact run (`key`).
    pub const EXEC: &str = "exec";
    /// Instant: one counted network sweep finished (`pass`, `stages`,
    /// `groups`, `fused_boundaries`, `secs`), followed by its per-stage
    /// [`STAGE_TRAFFIC`] events.
    pub const NET_EXEC: &str = "net_exec";
    /// Instant: an LP tile plan was solved (`pass`, `blocks`, `ranges`).
    pub const TILE_PLAN: &str = "tile_plan";
    /// Instant: a fusion plan was decided (`pass`, `groups`).
    pub const FUSE_PLAN: &str = "fuse_plan";
    /// Instant: single-layer measured-vs-analytic traffic pair.
    pub const TRAFFIC: &str = "traffic";
    /// Instant: per-stage measured-vs-analytic traffic pair of a network
    /// sweep (plus `halo_words` vs `expected_halo_words`).
    pub const STAGE_TRAFFIC: &str = "stage_traffic";
    /// Instant: the autotuner timed (or LP-pruned) one candidate.
    pub const AUTOTUNE_PROBE: &str = "autotune_probe";
    /// Instant: the autotuner committed a winner for a shape/network.
    pub const AUTOTUNE_SELECT: &str = "autotune_select";
    /// Instant: aggregate LP-prune report for one selection.
    pub const AUTOTUNE_PRUNE: &str = "autotune_prune";
    /// Winograd kernel span around one counted run (`shape`, `sub_convs`,
    /// `tile_block`), enclosing three [`WINOGRAD_STAGE`] events.
    pub const WINOGRAD: &str = "winograd";
    /// Instant: one Winograd transform stage finished (`stage` ∈
    /// filter_transform|input_transform|output_transform, `secs`, `words`).
    pub const WINOGRAD_STAGE: &str = "winograd_stage";
    /// Instant: a routed diagnostic line (`level`, `msg`).
    pub const LOG: &str = "log";
    /// Instant: final [`crate::coordinator::ServerStats`] at shutdown.
    pub const SERVER_STATS: &str = "server_stats";
    /// Instant: a worker panic was caught and converted to a typed error
    /// (`key`, `path`, `cause`) — by the native backend's fallback
    /// wrapper or the server's dispatch guard. The process stays alive.
    pub const WORKER_PANIC: &str = "worker_panic";
    /// Instant: an execution degraded to a simpler verified path (`key`,
    /// `from`, `to`, `cause`).
    pub const DEGRADE: &str = "degrade";
    /// Sharded execution span: `B` before the virtual workers start
    /// (`strategy`, `shards`, `active`, `stages`), `E` after the output
    /// is assembled (`secs`), enclosing per-shard [`SHARD_TRAFFIC`]
    /// events.
    pub const SHARD: &str = "shard";
    /// Instant: one virtual worker's measured-vs-analytic inter-shard
    /// exchange words (`shard`, `halo_words`/`gather_words`/
    /// `reduce_words` measured and `exp_*` expected, `exchange_ok`).
    pub const SHARD_TRAFFIC: &str = "shard_traffic";
}

/// Identifier of one span; `0` is reserved for "no span" (disabled sink).
pub type SpanId = u64;

struct Shared {
    start: Instant,
    next_span: AtomicU64,
    out: Mutex<Box<dyn Write + Send>>,
}

impl Drop for Shared {
    fn drop(&mut self) {
        if let Ok(mut out) = self.out.lock() {
            let _ = out.flush();
        }
    }
}

/// Small monotone per-thread tag; cheaper and more readable than OS
/// thread ids, and stable for the life of the thread.
fn thread_tag() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(0);
    thread_local! {
        static TAG: u64 = NEXT.fetch_add(1, Ordering::Relaxed);
    }
    TAG.with(|t| *t)
}

thread_local! {
    /// Open scope spans on this thread, innermost last — the implicit
    /// parent for the next same-thread scope. Entries are keyed by sink
    /// identity (the `Shared` address): two sinks can be live at once
    /// (a per-server sink plus the global one), and a span id from one
    /// file must never become a parent reference in the other.
    static SPAN_STACK: std::cell::RefCell<Vec<(usize, SpanId)>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

fn sink_tag(sh: &Arc<Shared>) -> usize {
    Arc::as_ptr(sh) as usize
}

fn write_event(
    sh: &Shared,
    kind: &str,
    ph: &str,
    span: Option<SpanId>,
    parent: Option<SpanId>,
    fields: &[(&str, Json)],
) {
    let mut obj = BTreeMap::new();
    obj.insert("tid".to_string(), Json::Num(thread_tag() as f64));
    obj.insert("kind".to_string(), Json::Str(kind.to_string()));
    obj.insert("ph".to_string(), Json::Str(ph.to_string()));
    if let Some(s) = span {
        obj.insert("span".to_string(), Json::Num(s as f64));
    }
    if let Some(p) = parent {
        obj.insert("parent".to_string(), Json::Num(p as f64));
    }
    for (k, v) in fields {
        obj.insert((*k).to_string(), v.clone());
    }
    let mut out = sh.out.lock().unwrap();
    // stamp the timestamp under the writer lock: lines land in the file
    // in non-decreasing ts order, which `trace check` asserts
    let ts = sh.start.elapsed().as_micros() as f64;
    obj.insert("ts_us".to_string(), Json::Num(ts));
    let line = format!("{}\n", Json::Obj(obj));
    let _ = out.write_all(line.as_bytes());
}

#[derive(Clone)]
enum Inner {
    Disabled,
    Global,
    Writer(Arc<Shared>),
}

/// A handle to one trace destination. Cheap to clone; all clones share
/// the writer. See the module docs for the three flavors.
#[derive(Clone)]
pub struct TraceSink {
    inner: Inner,
}

impl TraceSink {
    /// A sink where every call is a no-op.
    pub const fn disabled() -> TraceSink {
        TraceSink { inner: Inner::Disabled }
    }

    /// A sink that defers to the process-global trace at every call —
    /// emits only while a global sink is [`install`]ed. This is the
    /// default wiring for long-lived components ([`crate::coordinator::
    /// ConvServer`]), so `--trace` reaches them without plumbing.
    pub fn global() -> TraceSink {
        TraceSink { inner: Inner::Global }
    }

    /// A sink that owns `w`. Emits the header event immediately.
    pub fn to_writer(w: Box<dyn Write + Send>) -> TraceSink {
        let shared = Arc::new(Shared {
            start: Instant::now(),
            next_span: AtomicU64::new(1),
            out: Mutex::new(w),
        });
        let sink = TraceSink { inner: Inner::Writer(shared) };
        sink.event(kind::TRACE, &[("version", ju(TRACE_VERSION))]);
        sink
    }

    /// A sink writing to a fresh file at `path`.
    pub fn to_file(path: &str) -> Result<TraceSink> {
        let f = std::fs::File::create(path)
            .with_context(|| format!("creating trace file {path}"))?;
        Ok(TraceSink::to_writer(Box::new(f)))
    }

    fn resolve(&self) -> Option<Arc<Shared>> {
        match &self.inner {
            Inner::Disabled => None,
            Inner::Writer(sh) => Some(Arc::clone(sh)),
            Inner::Global => {
                if !GLOBAL_ON.load(Ordering::Relaxed) {
                    return None;
                }
                GLOBAL.lock().unwrap().clone()
            }
        }
    }

    /// Is anything listening? The one branch hot paths pay.
    pub fn enabled(&self) -> bool {
        match &self.inner {
            Inner::Disabled => false,
            Inner::Writer(_) => true,
            Inner::Global => GLOBAL_ON.load(Ordering::Relaxed),
        }
    }

    /// Emit an instant (`ph:"I"`) event.
    pub fn event(&self, kind: &str, fields: &[(&str, Json)]) {
        if let Some(sh) = self.resolve() {
            write_event(&sh, kind, "I", None, None, fields);
        }
    }

    /// Allocate a span id without emitting anything — for spans that
    /// open on one thread and close on another (server requests).
    /// Returns `0` when the sink is disabled; `span_open`/`span_close`
    /// ignore id `0`, so callers can thread the id unconditionally.
    pub fn span_id(&self) -> SpanId {
        match self.resolve() {
            Some(sh) => sh.next_span.fetch_add(1, Ordering::Relaxed),
            None => 0,
        }
    }

    /// Open a cross-thread span allocated with [`TraceSink::span_id`].
    pub fn span_open(
        &self,
        kind: &str,
        span: SpanId,
        parent: Option<SpanId>,
        fields: &[(&str, Json)],
    ) {
        if span == 0 {
            return;
        }
        if let Some(sh) = self.resolve() {
            write_event(&sh, kind, "B", Some(span), parent, fields);
        }
    }

    /// Close a cross-thread span.
    pub fn span_close(&self, kind: &str, span: SpanId, fields: &[(&str, Json)]) {
        if span == 0 {
            return;
        }
        if let Some(sh) = self.resolve() {
            write_event(&sh, kind, "E", Some(span), None, fields);
        }
    }

    /// Open a same-thread nested span: the parent is the innermost scope
    /// already open on this thread. The guard emits the matching `E`
    /// event when dropped (or via [`ScopeGuard::end`] with extra fields).
    pub fn scope(&self, kind: &'static str, fields: &[(&str, Json)]) -> ScopeGuard {
        match self.resolve() {
            None => ScopeGuard { shared: None, kind, span: 0 },
            Some(sh) => {
                let me = sink_tag(&sh);
                let span = sh.next_span.fetch_add(1, Ordering::Relaxed);
                let parent = SPAN_STACK.with(|s| {
                    s.borrow()
                        .iter()
                        .rev()
                        .find(|&&(tag, _)| tag == me)
                        .map(|&(_, sp)| sp)
                });
                write_event(&sh, kind, "B", Some(span), parent, fields);
                SPAN_STACK.with(|s| s.borrow_mut().push((me, span)));
                ScopeGuard { shared: Some(sh), kind, span }
            }
        }
    }

    /// Flush the underlying writer.
    pub fn flush(&self) {
        if let Some(sh) = self.resolve() {
            let _ = sh.out.lock().unwrap().flush();
        }
    }
}

/// Guard of one same-thread scope span; closes the span on drop.
pub struct ScopeGuard {
    shared: Option<Arc<Shared>>,
    kind: &'static str,
    span: SpanId,
}

impl ScopeGuard {
    /// This scope's span id (`0` when the sink was disabled) — pass as
    /// `parent` to instant events logically nested under it.
    pub fn id(&self) -> SpanId {
        self.span
    }

    /// Close the span now, attaching result fields to the `E` event.
    pub fn end(mut self, fields: &[(&str, Json)]) {
        self.finish(fields);
    }

    fn finish(&mut self, fields: &[(&str, Json)]) {
        if let Some(sh) = self.shared.take() {
            let me = sink_tag(&sh);
            SPAN_STACK.with(|s| {
                let mut st = s.borrow_mut();
                if let Some(pos) =
                    st.iter().rposition(|&e| e == (me, self.span))
                {
                    st.remove(pos);
                }
            });
            write_event(&sh, self.kind, "E", Some(self.span), None, fields);
        }
    }
}

impl Drop for ScopeGuard {
    fn drop(&mut self) {
        self.finish(&[]);
    }
}

// ---------------- the process-global sink ----------------

static GLOBAL_ON: AtomicBool = AtomicBool::new(false);
static GLOBAL: Mutex<Option<Arc<Shared>>> = Mutex::new(None);
/// Stderr verbosity for [`log`]: 0 silent, 1 info (default), 2 debug.
static VERBOSITY: AtomicU8 = AtomicU8::new(1);

/// Install a writer-backed sink as the process-global trace; everything
/// emitting through [`TraceSink::global`] (and the free [`event`]/
/// [`scope`]/[`log`] helpers) starts landing in it.
pub fn install(sink: &TraceSink) -> Result<()> {
    match &sink.inner {
        Inner::Writer(sh) => {
            *GLOBAL.lock().unwrap() = Some(Arc::clone(sh));
            GLOBAL_ON.store(true, Ordering::SeqCst);
            Ok(())
        }
        _ => Err(err!("only writer-backed sinks can be installed globally")),
    }
}

/// Create a file sink at `path` and [`install`] it.
pub fn install_file(path: &str) -> Result<()> {
    install(&TraceSink::to_file(path)?)
}

/// Disable the global trace and flush whatever was written.
pub fn uninstall() {
    GLOBAL_ON.store(false, Ordering::SeqCst);
    let sh = GLOBAL.lock().unwrap().take();
    if let Some(sh) = sh {
        let _ = sh.out.lock().unwrap().flush();
    }
}

/// Is a global sink installed? One relaxed atomic load — the branch
/// instrumented hot paths pay when tracing is off.
pub fn enabled() -> bool {
    GLOBAL_ON.load(Ordering::Relaxed)
}

/// Emit an instant event to the global sink (no-op when disabled).
pub fn event(kind: &str, fields: &[(&str, Json)]) {
    if enabled() {
        TraceSink::global().event(kind, fields);
    }
}

/// Open a nested scope span on the global sink (no-op guard when
/// disabled).
pub fn scope(kind: &'static str, fields: &[(&str, Json)]) -> ScopeGuard {
    TraceSink::global().scope(kind, fields)
}

/// Flush the global sink.
pub fn flush() {
    if let Some(sh) = GLOBAL.lock().unwrap().clone() {
        let _ = sh.out.lock().unwrap().flush();
    }
}

/// Wire tracing/verbosity from the environment: `CONVBOUND_TRACE=<path>`
/// installs a global file sink (unless one is already installed — the
/// `--trace` flag wins), `CONVBOUND_VERBOSE=<0|1|2>` sets the stderr
/// verbosity of [`log`].
pub fn init_from_env() {
    if let Ok(v) = std::env::var("CONVBOUND_VERBOSE") {
        if let Ok(n) = v.parse::<u8>() {
            VERBOSITY.store(n, Ordering::Relaxed);
        }
    }
    if enabled() {
        return;
    }
    if let Ok(path) = std::env::var("CONVBOUND_TRACE") {
        if !path.is_empty() {
            if let Err(e) = install_file(&path) {
                eprintln!("convbound: CONVBOUND_TRACE ignored: {e}");
            }
        }
    }
}

/// Diagnostic levels for [`log`]; `Info` prints by default, `Debug` only
/// under `CONVBOUND_VERBOSE=2`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Info = 1,
    Debug = 2,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

/// Set the stderr verbosity of [`log`] (0 silent, 1 info, 2 debug).
pub fn set_verbosity(n: u8) {
    VERBOSITY.store(n, Ordering::Relaxed);
}

/// Current stderr verbosity.
pub fn verbosity() -> u8 {
    VERBOSITY.load(Ordering::Relaxed)
}

/// Route one diagnostic line: recorded as a structured `log` event when
/// the global trace is on, printed to stderr when `level` clears the
/// verbosity threshold. This replaces the ad-hoc `eprintln!`/`println!`
/// diagnostics in the autotuner, the CLI and the bench harness, so
/// `--check` stdout stays machine-parseable and quiet by default.
pub fn log(level: Level, msg: &str) {
    if enabled() {
        event(kind::LOG, &[("level", js(level.name())), ("msg", js(msg))]);
    }
    if (level as u8) <= VERBOSITY.load(Ordering::Relaxed) {
        eprintln!("{msg}");
    }
}

// ---------------- tiny Json constructors ----------------
//
// Call-site sugar for event fields; traffic word counts stay well below
// 2^53, so the f64-backed `Json::Num` is exact for every value we emit.

/// `Json::Num` from a u64.
pub fn ju(x: u64) -> Json {
    Json::Num(x as f64)
}

/// `Json::Num` from an f64.
pub fn jf(x: f64) -> Json {
    Json::Num(x)
}

/// `Json::Str` from a &str.
pub fn js(s: &str) -> Json {
    Json::Str(s.to_string())
}

/// `Json::Bool`.
pub fn jb(b: bool) -> Json {
    Json::Bool(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::threadpool::ThreadPool;

    /// A clonable in-memory writer so tests can read back what a sink
    /// wrote.
    #[derive(Clone, Default)]
    struct Buf(Arc<Mutex<Vec<u8>>>);

    impl Write for Buf {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    impl Buf {
        fn text(&self) -> String {
            String::from_utf8(self.0.lock().unwrap().clone()).unwrap()
        }
    }

    fn buf_sink() -> (TraceSink, Buf) {
        let buf = Buf::default();
        let sink = TraceSink::to_writer(Box::new(buf.clone()));
        (sink, buf)
    }

    #[test]
    fn disabled_sink_is_inert() {
        let sink = TraceSink::disabled();
        assert!(!sink.enabled());
        sink.event(kind::LOG, &[("msg", js("dropped"))]);
        let g = sink.scope(kind::EXEC, &[]);
        assert_eq!(g.id(), 0);
        drop(g);
        assert_eq!(sink.span_id(), 0);
        sink.span_open(kind::REQUEST, 0, None, &[]);
        sink.span_close(kind::REQUEST, 0, &[]);
        // a disabled scope must not touch the thread-local span stack: a
        // live scope opened inside one still has no parent
        let (live, buf) = buf_sink();
        let _outer = sink.scope(kind::BATCH, &[]);
        drop(live.scope(kind::EXEC, &[]));
        let lines: Vec<Json> = buf
            .text()
            .lines()
            .map(|l| Json::parse(l).unwrap())
            .collect();
        assert_eq!(lines.len(), 3); // header + exec B + exec E
        assert_eq!(lines[1].get("parent"), &Json::Null);
    }

    #[test]
    fn header_is_first_line_and_versioned() {
        let (sink, buf) = buf_sink();
        sink.event(kind::LOG, &[("msg", js("x"))]);
        let text = buf.text();
        let first = Json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(first.get("kind").as_str(), Some(kind::TRACE));
        assert_eq!(first.get("version").as_u64(), Some(TRACE_VERSION));
        assert_eq!(first.get("ph").as_str(), Some("I"));
    }

    #[test]
    fn concurrent_emit_from_pool_workers_stays_line_valid() {
        let (sink, buf) = buf_sink();
        let pool = ThreadPool::new(4);
        let n = 200usize;
        let s2 = sink.clone();
        pool.map((0..n).collect::<Vec<_>>(), move |i| {
            s2.event(kind::LOG, &[("i", ju(i as u64)), ("msg", js("w"))]);
        });
        drop(pool);
        sink.flush();
        let text = buf.text();
        let mut seen = vec![false; n];
        let mut prev_ts = 0u64;
        let mut count = 0usize;
        for line in text.lines() {
            let v = Json::parse(line).expect("every interleaved line parses");
            let ts = v.get("ts_us").as_u64().expect("ts present");
            assert!(ts >= prev_ts, "timestamps non-decreasing in file order");
            prev_ts = ts;
            if let Some(i) = v.get("i").as_u64() {
                seen[i as usize] = true;
            }
            count += 1;
        }
        assert_eq!(count, n + 1); // header + one line per event
        assert!(seen.iter().all(|&s| s), "no event lost or torn");
    }

    #[test]
    fn scope_spans_nest_via_thread_local_stack() {
        let (sink, buf) = buf_sink();
        {
            let outer = sink.scope(kind::BATCH, &[("seq", ju(1))]);
            let inner = sink.scope(kind::DISPATCH, &[]);
            sink.event(kind::LOG, &[("msg", js("inside"))]);
            inner.end(&[("secs", jf(0.5))]);
            drop(outer);
        }
        let events: Vec<Json> = buf
            .text()
            .lines()
            .map(|l| Json::parse(l).unwrap())
            .collect();
        // header, batch B, dispatch B, log I, dispatch E, batch E
        assert_eq!(events.len(), 6);
        let batch_b = &events[1];
        let disp_b = &events[2];
        let disp_e = &events[4];
        let batch_e = &events[5];
        assert_eq!(batch_b.get("ph").as_str(), Some("B"));
        assert_eq!(batch_b.get("parent"), &Json::Null);
        let batch_span = batch_b.get("span").as_u64().unwrap();
        assert_eq!(disp_b.get("parent").as_u64(), Some(batch_span));
        let disp_span = disp_b.get("span").as_u64().unwrap();
        assert_ne!(disp_span, batch_span);
        assert_eq!(disp_e.get("span").as_u64(), Some(disp_span));
        assert_eq!(disp_e.get("secs").as_f64(), Some(0.5));
        assert_eq!(batch_e.get("span").as_u64(), Some(batch_span));
    }

    #[test]
    fn cross_thread_spans_balance() {
        let (sink, buf) = buf_sink();
        let span = sink.span_id();
        assert_ne!(span, 0);
        sink.span_open(kind::REQUEST, span, None, &[("req", ju(7))]);
        let s2 = sink.clone();
        std::thread::spawn(move || {
            s2.span_close(kind::REQUEST, span, &[("latency_secs", jf(0.001))]);
        })
        .join()
        .unwrap();
        let events: Vec<Json> = buf
            .text()
            .lines()
            .map(|l| Json::parse(l).unwrap())
            .collect();
        assert_eq!(events[1].get("ph").as_str(), Some("B"));
        assert_eq!(events[2].get("ph").as_str(), Some("E"));
        assert_eq!(events[1].get("span"), events[2].get("span"));
        assert_ne!(events[1].get("tid"), events[2].get("tid"));
    }

    #[test]
    fn global_install_routes_deferred_sinks_and_uninstall_stops_them() {
        let (sink, buf) = buf_sink();
        // note: other tests in this binary may emit global events while
        // ours is installed; assertions below tolerate extra lines
        install(&sink).unwrap();
        assert!(enabled());
        let deferred = TraceSink::global();
        assert!(deferred.enabled());
        deferred.event(kind::LOG, &[("msg", js("marker-on"))]);
        uninstall();
        assert!(!enabled());
        assert!(!deferred.enabled());
        deferred.event(kind::LOG, &[("msg", js("marker-off"))]);
        let text = buf.text();
        for line in text.lines() {
            Json::parse(line).expect("global log stays line-valid");
        }
        assert!(text.contains("marker-on"));
        assert!(!text.contains("marker-off"));
    }
}
