//! Exact rational dense linear algebra for the HBL machinery.
//!
//! Everything is tiny (d ≤ 9), so dense RREF over [`Rat`] is the right
//! tool: ranks and nullspaces are exact, which Proposition 2.5 requires
//! (the subgroup-lattice reduction works with Q-linear spans).

use crate::lp::Rat;

/// Dense rational matrix, row-major.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub a: Vec<Rat>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, a: vec![Rat::ZERO; rows * cols] }
    }

    /// Build from integer rows.
    pub fn from_int_rows(rows: &[Vec<i128>]) -> Mat {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut m = Mat::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c, "ragged rows");
            for (j, &v) in row.iter().enumerate() {
                m[(i, j)] = Rat::int(v);
            }
        }
        m
    }

    pub fn identity(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = Rat::ONE;
        }
        m
    }

    pub fn row(&self, i: usize) -> &[Rat] {
        &self.a[i * self.cols..(i + 1) * self.cols]
    }

    /// `self * other`.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let v = self[(i, k)];
                if v.is_zero() {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] = out[(i, j)] + v * other[(k, j)];
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// In-place reduced row echelon form; returns pivot column indices.
    pub fn rref(&mut self) -> Vec<usize> {
        let mut pivots = Vec::new();
        let mut r = 0;
        for c in 0..self.cols {
            if r == self.rows {
                break;
            }
            // find a pivot row
            let Some(p) = (r..self.rows).find(|&i| !self[(i, c)].is_zero()) else {
                continue;
            };
            self.swap_rows(r, p);
            let inv = self[(r, c)].recip();
            for j in c..self.cols {
                self[(r, j)] = self[(r, j)] * inv;
            }
            for i in 0..self.rows {
                if i != r && !self[(i, c)].is_zero() {
                    let f = self[(i, c)];
                    for j in c..self.cols {
                        let sub = f * self[(r, j)];
                        self[(i, j)] = self[(i, j)] - sub;
                    }
                }
            }
            pivots.push(c);
            r += 1;
        }
        pivots
    }

    fn swap_rows(&mut self, i: usize, j: usize) {
        if i == j {
            return;
        }
        for c in 0..self.cols {
            let t = self[(i, c)];
            self[(i, c)] = self[(j, c)];
            self[(j, c)] = t;
        }
    }

    pub fn rank(&self) -> usize {
        let mut m = self.clone();
        m.rref().len()
    }

    /// Basis of the right nullspace `{x : A x = 0}`, as rows of the result.
    pub fn nullspace(&self) -> Mat {
        let mut m = self.clone();
        let pivots = m.rref();
        let free: Vec<usize> =
            (0..self.cols).filter(|c| !pivots.contains(c)).collect();
        let mut basis = Mat::zeros(free.len(), self.cols);
        for (bi, &fc) in free.iter().enumerate() {
            basis[(bi, fc)] = Rat::ONE;
            for (pr, &pc) in pivots.iter().enumerate() {
                basis[(bi, pc)] = -m[(pr, fc)];
            }
        }
        basis
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = Rat;
    fn index(&self, (i, j): (usize, usize)) -> &Rat {
        &self.a[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut Rat {
        &mut self.a[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_of_identity_and_singular() {
        assert_eq!(Mat::identity(4).rank(), 4);
        let m = Mat::from_int_rows(&[vec![1, 2], vec![2, 4]]);
        assert_eq!(m.rank(), 1);
        assert_eq!(Mat::zeros(3, 3).rank(), 0);
    }

    #[test]
    fn rref_known() {
        let mut m = Mat::from_int_rows(&[vec![1, 2, 3], vec![4, 5, 6]]);
        let piv = m.rref();
        assert_eq!(piv, vec![0, 1]);
        // rref is [[1,0,-1],[0,1,2]]
        assert_eq!(m[(0, 2)], Rat::int(-1));
        assert_eq!(m[(1, 2)], Rat::int(2));
    }

    #[test]
    fn nullspace_annihilates() {
        let m = Mat::from_int_rows(&[vec![1, 2, 3, 0], vec![0, 1, 1, -1]]);
        let ns = m.nullspace();
        assert_eq!(ns.rows, 2);
        // every basis row x satisfies A x = 0
        let prod = m.matmul(&ns.transpose());
        assert!(prod.a.iter().all(|v| v.is_zero()));
        // rank-nullity
        assert_eq!(m.rank() + ns.rank(), m.cols);
    }

    #[test]
    fn nullspace_of_full_rank_is_empty() {
        let ns = Mat::identity(3).nullspace();
        assert_eq!(ns.rows, 0);
    }

    #[test]
    fn matmul_small() {
        let a = Mat::from_int_rows(&[vec![1, 2], vec![3, 4]]);
        let b = Mat::from_int_rows(&[vec![5, 6], vec![7, 8]]);
        let c = a.matmul(&b);
        assert_eq!(c[(0, 0)], Rat::int(19));
        assert_eq!(c[(1, 1)], Rat::int(50));
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_int_rows(&[vec![1, 2, 3], vec![4, 5, 6]]);
        assert_eq!(a.transpose().transpose(), a);
    }
}
