//! The 7NL CNN instantiation of the HBL machinery (paper §3.1).
//!
//! Index order in Z^7: (i1, i2, i3, i4, i5, i6, i7) =
//! (batch N, in-chan cI, out-chan cO, out-w wO, out-h hO, filt-w wF, filt-h hF).
//!
//! Array-access homomorphisms:
//! ```text
//! φ_I(i) = (i1, i2, σw·i4 + i6, σh·i5 + i7)
//! φ_F(i) = (i2, i3, i6, i7)
//! φ_O(i) = (i1, i3, i4, i5)
//! ```
//!
//! The module reproduces the paper's §3.1 constraint table and the optimal
//! exponent tuples: `s = (2/3, 2/3, 2/3)` (Σ = 2) for the main bound and
//! `s = (1/2, 1/2, 1/2)` (Σ = 3/2) for the small-filter lift.

use crate::lp::Rat;
use crate::util::error::Result;

use super::exponents::{solve_exponents, HblSolution};
use super::linalg::Mat;
use super::subspace::Subspace;

/// The three array-access homomorphisms of 7NL CNN (as d_out × 7 matrices).
pub fn homs_7nl(sw: i128, sh: i128) -> [Mat; 3] {
    assert!(sw >= 1 && sh >= 1);
    let phi_i = Mat::from_int_rows(&[
        vec![1, 0, 0, 0, 0, 0, 0],
        vec![0, 1, 0, 0, 0, 0, 0],
        vec![0, 0, 0, sw, 0, 1, 0],
        vec![0, 0, 0, 0, sh, 0, 1],
    ]);
    let phi_f = Mat::from_int_rows(&[
        vec![0, 1, 0, 0, 0, 0, 0],
        vec![0, 0, 1, 0, 0, 0, 0],
        vec![0, 0, 0, 0, 0, 1, 0],
        vec![0, 0, 0, 0, 0, 0, 1],
    ]);
    let phi_o = Mat::from_int_rows(&[
        vec![1, 0, 0, 0, 0, 0, 0],
        vec![0, 0, 1, 0, 0, 0, 0],
        vec![0, 0, 0, 1, 0, 0, 0],
        vec![0, 0, 0, 0, 1, 0, 0],
    ]);
    [phi_i, phi_f, phi_o]
}

/// The paper's explicit subgroup generators C_{j,k} (§3.1), in table order:
/// C11, C21, C31, C41, C42, C43, C44, C51, C52, C53, C54.
pub fn paper_subgroups(sw: i128, sh: i128) -> Vec<Subspace> {
    let e = |i: usize| -> Vec<i128> {
        let mut v = vec![0; 7];
        v[i] = 1;
        v
    };
    vec![
        Subspace::span_int(7, &[e(0)]),                      // C11: i1
        Subspace::span_int(7, &[e(1)]),                      // C21: i2
        Subspace::span_int(7, &[e(2)]),                      // C31: i3
        Subspace::span_int(7, &[e(3)]),                      // C41: i4
        Subspace::span_int(7, &[e(5)]),                      // C42: i6
        Subspace::span_int(7, &[{
            let mut v = vec![0; 7];
            v[3] = 1;
            v[5] = -sw;
            v
        }]),                                                 // C43: i4, -σw·i4
        Subspace::span_int(7, &[e(3), e(5)]),                // C44: (i4, i6)
        Subspace::span_int(7, &[e(4)]),                      // C51: i5
        Subspace::span_int(7, &[e(6)]),                      // C52: i7
        Subspace::span_int(7, &[{
            let mut v = vec![0; 7];
            v[4] = 1;
            v[6] = -sh;
            v
        }]),                                                 // C53: i5, -σh·i5
        Subspace::span_int(7, &[e(4), e(6)]),                // C54: (i5, i7)
    ]
}

/// The small-filter lifted homomorphisms (§3.1, Lemma 3.4 setup): domain
/// (i1, i2, i3, i4, i5, r6, r7) with the (q6, q7) coordinates fixed.
/// ```text
/// φ'_I = (i1, i2, i4, r6, i5, r7)
/// φ'_F = (i2, i3, r6, r7)
/// φ'_O = (i1, i3, i4, i5)
/// ```
pub fn homs_small_filter() -> [Mat; 3] {
    let phi_i = Mat::from_int_rows(&[
        vec![1, 0, 0, 0, 0, 0, 0],
        vec![0, 1, 0, 0, 0, 0, 0],
        vec![0, 0, 0, 1, 0, 0, 0],
        vec![0, 0, 0, 0, 0, 1, 0],
        vec![0, 0, 0, 0, 1, 0, 0],
        vec![0, 0, 0, 0, 0, 0, 1],
    ]);
    let phi_f = Mat::from_int_rows(&[
        vec![0, 1, 0, 0, 0, 0, 0],
        vec![0, 0, 1, 0, 0, 0, 0],
        vec![0, 0, 0, 0, 0, 1, 0],
        vec![0, 0, 0, 0, 0, 0, 1],
    ]);
    let phi_o = Mat::from_int_rows(&[
        vec![1, 0, 0, 0, 0, 0, 0],
        vec![0, 0, 1, 0, 0, 0, 0],
        vec![0, 0, 0, 1, 0, 0, 0],
        vec![0, 0, 0, 0, 1, 0, 0],
    ]);
    [phi_i, phi_f, phi_o]
}

/// Full HBL analysis for 7NL CNN: constraints from the lattice closure of
/// the kernels *plus* the paper's explicit C_{j,k} subgroups (so the
/// reported table matches §3.1 row for row).
pub fn analyze_7nl(sw: i128, sh: i128) -> Result<HblSolution> {
    let homs = homs_7nl(sw, sh);
    solve_exponents(&homs, &paper_subgroups(sw, sh))
}

/// HBL analysis for the small-filter lift.
pub fn analyze_small_filter() -> Result<HblSolution> {
    solve_exponents(&homs_small_filter(), &[])
}

/// The asymptotic exponent: X = Ω(G / M^{s−1}) with s = Σ sⱼ.
pub fn communication_exponent(sol: &HblSolution) -> Rat {
    sol.total - Rat::ONE
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernels_match_paper() {
        let [phi_i, phi_f, phi_o] = homs_7nl(2, 2);
        // ker φ_I = (0,0,i3,i4,i5,−σw·i4,−σh·i5): rank 3
        assert_eq!(phi_i.nullspace().rank(), 3);
        assert_eq!(phi_f.nullspace().rank(), 3);
        assert_eq!(phi_o.nullspace().rank(), 3);
        // spot-check membership: (0,0,0,1,0,-2,0) ∈ ker φ_I for σw=2
        let v = Subspace::span_int(7, &[vec![0, 0, 0, 1, 0, -2, 0]]);
        let ker_i = Subspace::from_rows(phi_i.nullspace(), 7);
        assert!(ker_i.contains(&v));
    }

    #[test]
    fn optimal_exponent_sum_is_two_and_symmetric_point_feasible() {
        // The LP optimum value is Σs = 2; the optimal vertex is not unique
        // (e.g. (1,0,1) also achieves it). The paper's symmetric choice
        // (2/3,2/3,2/3) — the one minimizing the bound's constant — must be
        // feasible, and the LP solution must satisfy every constraint.
        for (sw, sh) in [(1, 1), (2, 2), (1, 2), (3, 1)] {
            let sol = analyze_7nl(sw, sh).expect("7NL LP feasible");
            assert_eq!(sol.total, Rat::int(2), "σ=({sw},{sh})");
            assert!(super::super::exponents::is_feasible(
                &sol.constraints,
                &vec![Rat::new(2, 3); 3]
            ));
            assert!(super::super::exponents::is_feasible(
                &sol.constraints,
                &sol.s
            ));
        }
    }

    #[test]
    fn closure_alone_already_forces_sum_two() {
        // Even without the paper's explicit C_{j,k} seeds, the lattice
        // generated by the kernels forces Σ s ≥ 2 (via e.g.
        // span{e3..e6} = (kerF ∩ (kerI+kerO)) + (kerO ∩ (kerI+kerF))).
        let homs = homs_7nl(1, 1);
        let sol = solve_exponents(&homs, &[]).expect("closure LP feasible");
        assert_eq!(sol.total, Rat::int(2));
    }

    #[test]
    fn paper_table_constraints_present() {
        let sol = analyze_7nl(1, 1).expect("7NL LP feasible");
        let names = ["I", "F", "O"];
        let printed: Vec<String> =
            sol.constraints.iter().map(|c| c.pretty(&names)).collect();
        // the four distinct constraints of the §3.1 table
        for want in [
            "1 ≤ s_I + s_O",
            "1 ≤ s_I + s_F",
            "1 ≤ s_F + s_O",
            "2 ≤ s_I + s_F + s_O",
        ] {
            assert!(
                printed.iter().any(|p| p == want),
                "missing constraint: {want}\nhave: {printed:?}"
            );
        }
    }

    #[test]
    fn paper_subgroup_ranks_match_table() {
        // the §3.1 table: (rk H, rk φI(H), rk φF(H), rk φO(H)) per C_{j,k}
        let homs = homs_7nl(2, 3);
        let expect = [
            (1, 1, 0, 1), // C11
            (1, 1, 1, 0), // C21
            (1, 0, 1, 1), // C31
            (1, 1, 0, 1), // C41
            (1, 1, 1, 0), // C42
            (1, 0, 1, 1), // C43
            (2, 1, 1, 1), // C44
            (1, 1, 0, 1), // C51
            (1, 1, 1, 0), // C52
            (1, 0, 1, 1), // C53
            (2, 1, 1, 1), // C54
        ];
        for (sub, want) in paper_subgroups(2, 3).iter().zip(expect) {
            let got = (
                sub.rank(),
                sub.image(&homs[0]).rank(),
                sub.image(&homs[1]).rank(),
                sub.image(&homs[2]).rank(),
            );
            assert_eq!(got, want);
        }
    }

    #[test]
    fn small_filter_exponents_are_halves() {
        let sol = analyze_small_filter().expect("small-filter LP feasible");
        assert_eq!(sol.total, Rat::new(3, 2));
        assert_eq!(sol.s, vec![Rat::new(1, 2); 3]);
    }

    #[test]
    fn communication_exponent_values() {
        assert_eq!(
            communication_exponent(&analyze_7nl(1, 1).expect("feasible")),
            Rat::ONE
        );
        assert_eq!(
            communication_exponent(&analyze_small_filter().expect("feasible")),
            Rat::new(1, 2)
        );
    }
}
