//! Subgroup/subspace lattice generation (paper §2.3).
//!
//! `Lattice(ker φⱼ)` is the smallest family of subspaces containing the
//! kernels and closed under sum and intersection. Proposition 2.5 says HBL
//! constraints need only be checked on this lattice; we compute it by
//! fixpoint closure with canonical-form deduplication.

use std::collections::HashSet;

use super::subspace::Subspace;

/// Closure of `seeds` under pairwise sum and intersection (zero subspace
/// excluded from the result — it contributes the trivial constraint 0 ≤ 0).
///
/// Worklist algorithm: each round combines only *new* elements against the
/// full set, with hash-based dedup on the canonical RREF basis — the naive
/// all-pairs-every-round variant re-derived the same subspaces thousands of
/// times (573 ms → ~15 ms on the 7NL lattice; EXPERIMENTS.md §Perf).
pub fn lattice_closure(seeds: &[Subspace]) -> Vec<Subspace> {
    let mut items: Vec<Subspace> = Vec::new();
    let mut seen: HashSet<Subspace> = HashSet::new();
    let mut frontier: Vec<Subspace> = Vec::new();
    for s in seeds {
        if !s.is_zero() && seen.insert(s.clone()) {
            items.push(s.clone());
            frontier.push(s.clone());
        }
    }
    while !frontier.is_empty() {
        let mut next: Vec<Subspace> = Vec::new();
        for f in &frontier {
            // combine the frontier against everything discovered so far
            // (items includes the frontier itself)
            for it in &items {
                let (s, i) = f.sum_and_intersect(it);
                for cand in [s, i] {
                    if !cand.is_zero() && !seen.contains(&cand) {
                        seen.insert(cand.clone());
                        next.push(cand);
                    }
                }
            }
        }
        items.extend(next.iter().cloned());
        frontier = next;
    }
    items
}

/// Check that a family is lattice-closed (for tests / invariants).
pub fn is_closed(items: &[Subspace]) -> bool {
    for i in 0..items.len() {
        for j in (i + 1)..items.len() {
            let s = items[i].sum(&items[j]);
            if !s.is_zero() && !items.contains(&s) {
                return false;
            }
            let t = items[i].intersect(&items[j]);
            if !t.is_zero() && !items.contains(&t) {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    fn axis(d: usize, i: usize) -> Subspace {
        let mut v = vec![0i128; d];
        v[i] = 1;
        Subspace::span_int(d, &[v])
    }

    #[test]
    fn closure_of_two_axes() {
        // {x-axis, y-axis} closes to {x, y, x+y-plane}
        let lat = lattice_closure(&[axis(3, 0), axis(3, 1)]);
        assert_eq!(lat.len(), 3);
        assert!(is_closed(&lat));
        assert!(lat.iter().any(|s| s.rank() == 2));
    }

    #[test]
    fn closure_is_idempotent() {
        let lat = lattice_closure(&[axis(4, 0), axis(4, 1), axis(4, 2)]);
        let again = lattice_closure(&lat);
        assert_eq!(lat.len(), again.len());
        assert!(is_closed(&lat));
    }

    #[test]
    fn duplicate_seeds_deduped() {
        let lat = lattice_closure(&[axis(2, 0), axis(2, 0)]);
        assert_eq!(lat.len(), 1);
    }

    #[test]
    fn overlapping_planes_close_with_intersection() {
        let u = Subspace::span_int(3, &[vec![1, 0, 0], vec![0, 1, 0]]);
        let w = Subspace::span_int(3, &[vec![0, 1, 0], vec![0, 0, 1]]);
        let lat = lattice_closure(&[u, w]);
        // u, w, u+w (=Q^3), u∩w (= y-axis)
        assert_eq!(lat.len(), 4);
        assert!(lat.iter().any(|s| s.rank() == 1));
        assert!(lat.iter().any(|s| s.rank() == 3));
        assert!(is_closed(&lat));
    }

    #[test]
    fn zero_subspace_never_in_lattice() {
        let u = axis(3, 0);
        let w = axis(3, 1);
        let lat = lattice_closure(&[u, w]);
        assert!(lat.iter().all(|s| !s.is_zero()));
    }
}
