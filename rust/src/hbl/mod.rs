//! Discrete Hölder–Brascamp–Lieb machinery (paper §2.3).
//!
//! Pipeline: array-access homomorphisms → kernels → subgroup lattice
//! (Prop. 2.5) → rank constraints → exact LP over the HBL exponents →
//! the asymptotic communication exponent `X = Ω(G / M^{Σs−1})`.

pub mod cnn;
pub mod exponents;
pub mod lattice;
pub mod linalg;
pub mod subspace;

pub use cnn::{analyze_7nl, analyze_small_filter, homs_7nl, homs_small_filter};
pub use exponents::{solve_exponents, HblConstraint, HblSolution};
pub use lattice::lattice_closure;
pub use linalg::Mat;
pub use subspace::Subspace;
