//! Tile plans: the bridge from the §3.2 LP blocking to executable loop
//! bounds, plus a process-wide plan cache.
//!
//! A [`TilePlan`] takes the continuous-then-rounded [`SeqBlocking`] and
//! turns it into the nine concrete loop ranges and block sizes the tiled
//! engine iterates. Block sizes are *balanced* before use: for each dim the
//! tile count `t = ceil(range/block)` is kept but the block is shrunk to
//! `ceil(range/t)`, so ragged edge tiles stay within one element of the
//! interior tiles instead of degenerating (range 5, block 4 → blocks of
//! 3+2 rather than 4+1). Balancing never increases the tile footprint, so
//! a blocking that fit in `M` words still fits.
//!
//! Solving the blocking LP is not free (a 9-variable simplex per shape), so
//! [`TilePlanCache`] memoizes plans keyed on `(shape, precision, M)`; the
//! native backend and the autotuner share one cache per backend instance.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::conv::{ConvPass, ConvShape, Precision};
use crate::obs::{self, jf, js, ju};
use crate::tiling::{sequential_blocking, SeqBlocking};
use crate::util::ceil_div;
use crate::util::json::Json;

/// Default fast-memory budget for tile planning: 64 Ki words = 256 KiB of
/// f32 — a typical per-core L2 slice.
pub const DEFAULT_TILE_MEM_WORDS: f64 = 65536.0;

/// Executable loop bounds derived from one LP blocking.
///
/// Dim order everywhere in `kernels/`:
/// `[n, cI, cO, wO, hO, q6, q7, r6, r7]` — the filter loops are split as
/// `i6 = σw·q6 + r6` (and likewise `i7`), following the small-filter trick
/// the blocking LP assumes.
#[derive(Debug, Clone)]
pub struct TilePlan {
    /// which convolution pass these loop bounds execute; the dim roles of
    /// `ranges`/`blocks` are pass-specific (see [`TilePlan::for_pass`])
    pub pass: ConvPass,
    /// the *forward* layer shape all three passes are keyed off
    pub shape: ConvShape,
    pub precision: Precision,
    /// fast-memory budget the blocking was solved for, in words
    pub mem_words: f64,
    /// the raw LP blocking this plan executes
    pub blocking: SeqBlocking,
    /// loop ranges of the nine blocked dims
    pub ranges: [u64; 9],
    /// balanced block sizes, `1 ≤ blocks[i] ≤ ranges[i]`
    pub blocks: [u64; 9],
}

/// Indices of the output-owning dims (n, cO, wO, hO) in the nine-dim order.
pub(crate) const OUT_DIMS: [usize; 4] = [0, 2, 3, 4];
/// Indices of the reduction dims (cI, q6, q7, r6, r7).
pub(crate) const RED_DIMS: [usize; 5] = [1, 5, 6, 7, 8];

/// The split-filter loop ranges of one shape:
/// `(q6, q7, r6, r7) = (ceil(wF/σw), ceil(hF/σh), σw, σh)` — the
/// `i6 = σw·q6 + r6` change of variables the §3.2 LP, the tile plans and
/// the fused packed panels all share.
pub(crate) fn filter_split_ranges(s: &ConvShape) -> (u64, u64, u64, u64) {
    (
        ceil_div(s.w_f, s.s_w),
        ceil_div(s.h_f, s.s_h),
        s.s_w,
        s.s_h,
    )
}

impl TilePlan {
    /// Solve (or re-use) the §3.2 LP for `shape` at memory size `m` and
    /// derive balanced integral loop bounds (the forward pass).
    pub fn new(shape: &ConvShape, p: Precision, m: f64) -> TilePlan {
        let blocking = sequential_blocking(shape, p, m);
        let (qw, qh, rw, rh) = filter_split_ranges(shape);
        let ranges = [
            shape.n,
            shape.c_i,
            shape.c_o,
            shape.w_o,
            shape.h_o,
            qw,
            qh,
            rw,
            rh,
        ];
        let raw = [
            blocking.b_n,
            blocking.b_ci,
            blocking.b_co,
            blocking.b_wo,
            blocking.b_ho,
            blocking.b_wf_q,
            blocking.b_hf_q,
            blocking.b_wf_r,
            blocking.b_hf_r,
        ];
        let plan = TilePlan {
            pass: ConvPass::Forward,
            shape: *shape,
            precision: p,
            mem_words: m,
            blocking,
            ranges,
            blocks: balanced_blocks(&ranges, &raw),
        };
        plan.trace_plan();
        plan
    }

    /// Solve the pass's permuted §3.2 LP and derive the pass's loop
    /// bounds. Dim roles of the nine `ranges`/`blocks` slots per pass
    /// (same `[i1, i2, i3, i4, i5, i6, i7, r, r]` positions everywhere —
    /// slot 1 is the contracted reduction channel, slots 0/2/3/4 own the
    /// output):
    ///
    /// * `Forward` — `[N, cI, cO, wO, hO, q6, q7, r6, r7]` (the existing
    ///   plan, bit-for-bit: this constructor delegates to
    ///   [`TilePlan::new`]).
    /// * `DFilter` — `[cI, N, cO, wF, hF, wO, hO, 1, 1]`: the output is
    ///   the filter gradient, the batch is contracted, and the permuted
    ///   "filter" loops (wO, hO) are swept in full per reduction step —
    ///   the dilated index map `σ·wO + i6` admits no stride split, and the
    ///   full sweep is what keeps the per-element accumulation order equal
    ///   to `dfilter_naive`'s (bitwise, for any N blocking).
    /// * `DInput` — `[N, cO, cI, WI, HI, wF, hF, 1, 1]`: the output is the
    ///   input gradient (spatial extent `WI = σ·wO + wF`), cO is
    ///   contracted, and the filter taps are swept in full per reduction
    ///   step for the same ascending-order contract vs `dinput_naive`.
    ///   Spatial blocks scale the LP's output blocks by the stride (one
    ///   dIn block of `σ·b` rows is fed by `b` output rows).
    pub fn for_pass(pass: ConvPass, shape: &ConvShape, p: Precision, m: f64) -> TilePlan {
        if pass == ConvPass::Forward {
            return TilePlan::new(shape, p, m);
        }
        let blocking =
            sequential_blocking(&pass.lp_shape(shape), pass.lp_precision(p), m);
        let (ranges, raw) = match pass {
            ConvPass::DFilter => (
                [
                    shape.c_i, shape.n, shape.c_o, shape.w_f, shape.h_f,
                    shape.w_o, shape.h_o, 1, 1,
                ],
                [
                    blocking.b_n,
                    blocking.b_ci,
                    blocking.b_co,
                    blocking.b_wo,
                    blocking.b_ho,
                    shape.w_o,
                    shape.h_o,
                    1,
                    1,
                ],
            ),
            ConvPass::DInput => (
                [
                    shape.n,
                    shape.c_o,
                    shape.c_i,
                    shape.in_w(),
                    shape.in_h(),
                    shape.w_f,
                    shape.h_f,
                    1,
                    1,
                ],
                [
                    blocking.b_n,
                    blocking.b_ci,
                    blocking.b_co,
                    shape.s_w * blocking.b_wo,
                    shape.s_h * blocking.b_ho,
                    shape.w_f,
                    shape.h_f,
                    1,
                    1,
                ],
            ),
            ConvPass::Forward => unreachable!("handled above"),
        };
        let plan = TilePlan {
            pass,
            shape: *shape,
            precision: p,
            mem_words: m,
            blocking,
            ranges,
            blocks: balanced_blocks(&ranges, &raw),
        };
        plan.trace_plan();
        plan
    }

    /// Emit a `tile_plan` trace event carrying the LP-derived loop bounds
    /// (nine ranges + balanced blocks) and tile counts. One branch when
    /// tracing is off.
    fn trace_plan(&self) {
        if !obs::enabled() {
            return;
        }
        let dims = |v: &[u64; 9]| {
            Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
        };
        obs::event(
            obs::kind::TILE_PLAN,
            &[
                ("pass", js(self.pass.name())),
                ("shape", js(&self.shape.to_string())),
                ("mem_words", jf(self.mem_words)),
                ("ranges", dims(&self.ranges)),
                ("blocks", dims(&self.blocks)),
                ("output_tiles", ju(self.output_tiles())),
                ("reduction_tiles", ju(self.reduction_tiles())),
            ],
        );
    }

    /// Tiles along each of the nine dims.
    pub fn tile_counts(&self) -> [u64; 9] {
        let mut t = [1u64; 9];
        for i in 0..9 {
            t[i] = ceil_div(self.ranges[i].max(1), self.blocks[i]);
        }
        t
    }

    /// Number of output tiles (blocks of n × cO × wO × hO) — the unit of
    /// parallelism: distinct output tiles write disjoint output regions.
    pub fn output_tiles(&self) -> u64 {
        let t = self.tile_counts();
        OUT_DIMS.iter().map(|&i| t[i]).product()
    }

    /// Number of reduction tiles (blocks of cI × q6 × q7 × r6 × r7) each
    /// output tile accumulates over while staying resident.
    pub fn reduction_tiles(&self) -> u64 {
        let t = self.tile_counts();
        RED_DIMS.iter().map(|&i| t[i]).product()
    }

    /// Total tile executions.
    pub fn total_tiles(&self) -> u64 {
        self.output_tiles() * self.reduction_tiles()
    }
}

/// Clamp the raw LP blocks to their ranges and balance them: for each dim
/// the tile count `t = ceil(range/block)` is kept but the block shrinks to
/// `ceil(range/t)`, so ragged edge tiles stay within one element of the
/// interior tiles.
fn balanced_blocks(ranges: &[u64; 9], raw: &[u64; 9]) -> [u64; 9] {
    let mut blocks = [1u64; 9];
    for i in 0..9 {
        let r = ranges[i].max(1);
        let b = raw[i].clamp(1, r);
        blocks[i] = ceil_div(r, ceil_div(r, b));
    }
    blocks
}

/// Cache key: the pass and shape plus the bit patterns of the precision
/// triple and the memory size (both are configuration constants, not
/// computed floats, so bit equality is the right notion).
type PlanKey = (ConvPass, ConvShape, [u64; 4]);

/// Memoizes [`TilePlan`]s so repeated loads of the same shape (server
/// restarts, autotuner probes, per-request planning) never re-solve the LP.
pub struct TilePlanCache {
    inner: Mutex<HashMap<PlanKey, Arc<TilePlan>>>,
}

impl TilePlanCache {
    pub fn new() -> TilePlanCache {
        TilePlanCache { inner: Mutex::new(HashMap::new()) }
    }

    /// Fetch the forward plan for `(shape, p, m)`, solving and caching on
    /// miss.
    pub fn plan(&self, shape: &ConvShape, p: Precision, m: f64) -> Arc<TilePlan> {
        self.plan_pass(ConvPass::Forward, shape, p, m)
    }

    /// Fetch the plan for `(pass, shape, p, m)`, solving and caching on
    /// miss. The LP runs under the cache lock: concurrent loaders of the
    /// *same* shape would otherwise race to duplicate work.
    pub fn plan_pass(
        &self,
        pass: ConvPass,
        shape: &ConvShape,
        p: Precision,
        m: f64,
    ) -> Arc<TilePlan> {
        let key = (
            pass,
            *shape,
            [p.p_i.to_bits(), p.p_f.to_bits(), p.p_o.to_bits(), m.to_bits()],
        );
        let mut cache = self.inner.lock().expect("plan cache poisoned");
        if let Some(plan) = cache.get(&key) {
            return Arc::clone(plan);
        }
        let plan = Arc::new(TilePlan::for_pass(pass, shape, p, m));
        cache.insert(key, Arc::clone(&plan));
        plan
    }

    /// Number of distinct plans currently cached.
    pub fn len(&self) -> usize {
        self.inner.lock().expect("plan cache poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for TilePlanCache {
    fn default() -> Self {
        TilePlanCache::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::resnet50_layers;

    #[test]
    fn blocks_within_ranges_and_cover() {
        for l in resnet50_layers(8) {
            let plan = TilePlan::new(&l.shape, Precision::uniform(), 65536.0);
            for i in 0..9 {
                assert!(plan.blocks[i] >= 1, "{}: dim {i}", l.name);
                assert!(
                    plan.blocks[i] <= plan.ranges[i].max(1),
                    "{}: dim {i}: block {} > range {}",
                    l.name,
                    plan.blocks[i],
                    plan.ranges[i]
                );
            }
            assert!(plan.output_tiles() >= 1);
            assert!(plan.reduction_tiles() >= 1);
        }
    }

    #[test]
    fn balancing_preserves_tile_count() {
        // for every dim: ceil(range / balanced) == ceil(range / raw-clamped)
        let l = resnet50_layers(16)[2]; // conv3_x
        let plan = TilePlan::new(&l.shape, Precision::uniform(), 16384.0);
        let raw = [
            plan.blocking.b_n,
            plan.blocking.b_ci,
            plan.blocking.b_co,
            plan.blocking.b_wo,
            plan.blocking.b_ho,
            plan.blocking.b_wf_q,
            plan.blocking.b_hf_q,
            plan.blocking.b_wf_r,
            plan.blocking.b_hf_r,
        ];
        for i in 0..9 {
            let r = plan.ranges[i].max(1);
            let b = raw[i].clamp(1, r);
            assert_eq!(
                (r + plan.blocks[i] - 1) / plan.blocks[i],
                (r + b - 1) / b,
                "dim {i}"
            );
        }
    }

    #[test]
    fn filter_split_ranges_match_shape() {
        let s = resnet50_layers(4)[0].shape; // conv1: 7x7 stride 2
        let plan = TilePlan::new(&s, Precision::uniform(), 65536.0);
        assert_eq!(plan.ranges[5], 4); // ceil(7/2)
        assert_eq!(plan.ranges[7], 2); // σw
    }

    #[test]
    fn backward_plans_map_the_pass_dims() {
        let s = resnet50_layers(8)[0].shape; // conv1: 7x7 stride 2
        let df = TilePlan::for_pass(ConvPass::DFilter, &s, Precision::uniform(), 65536.0);
        assert_eq!(
            df.ranges,
            [s.c_i, s.n, s.c_o, s.w_f, s.h_f, s.w_o, s.h_o, 1, 1]
        );
        // the permuted "filter" loops are swept in full: one reduction
        // step covers all of (wO, hO), so reduction tiles block N only
        assert_eq!(df.blocks[5], s.w_o);
        assert_eq!(df.blocks[6], s.h_o);
        assert_eq!(df.reduction_tiles(), df.tile_counts()[1]);

        let di = TilePlan::for_pass(ConvPass::DInput, &s, Precision::uniform(), 65536.0);
        assert_eq!(
            di.ranges,
            [s.n, s.c_o, s.c_i, s.in_w(), s.in_h(), s.w_f, s.h_f, 1, 1]
        );
        assert_eq!(di.blocks[5], s.w_f);
        assert_eq!(di.blocks[6], s.h_f);
        assert_eq!(di.reduction_tiles(), di.tile_counts()[1]);

        for p in [&df, &di] {
            for i in 0..9 {
                assert!(p.blocks[i] >= 1 && p.blocks[i] <= p.ranges[i].max(1));
            }
            assert!(p.output_tiles() >= 1);
        }
        // Forward delegation is bit-identical to TilePlan::new
        let fwd = TilePlan::for_pass(ConvPass::Forward, &s, Precision::uniform(), 65536.0);
        let new = TilePlan::new(&s, Precision::uniform(), 65536.0);
        assert_eq!(fwd.pass, ConvPass::Forward);
        assert_eq!(fwd.ranges, new.ranges);
        assert_eq!(fwd.blocks, new.blocks);
    }

    #[test]
    fn cache_keys_plans_by_pass() {
        let cache = TilePlanCache::new();
        let s = resnet50_layers(2)[1].shape;
        let p = Precision::uniform();
        let fwd = cache.plan_pass(ConvPass::Forward, &s, p, 65536.0);
        let df = cache.plan_pass(ConvPass::DFilter, &s, p, 65536.0);
        assert!(!Arc::ptr_eq(&fwd, &df));
        assert_eq!(cache.len(), 2);
        // the pass-less entry point is the Forward instantiation
        assert!(Arc::ptr_eq(&fwd, &cache.plan(&s, p, 65536.0)));
    }

    #[test]
    fn cache_returns_shared_plan() {
        let cache = TilePlanCache::new();
        let s = resnet50_layers(2)[1].shape;
        let p = Precision::uniform();
        let a = cache.plan(&s, p, 65536.0);
        let b = cache.plan(&s, p, 65536.0);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.len(), 1);
        // different memory size is a different plan
        let c = cache.plan(&s, p, 4096.0);
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(cache.len(), 2);
    }
}
