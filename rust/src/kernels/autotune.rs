//! Kernel selection: a heuristic pre-filter plus a measure-once autotuner
//! choosing between the naive loop nest, im2col+GEMM, the LP-tiled
//! engine and the Winograd F(2,3) transform kernel per
//! `(`[`ConvPass`]`, `[`ConvShape`]`)` — the gradient passes probe naive
//! vs tiled (no im2col lowering or Winograd gradient path exists).
//!
//! Policy (see DESIGN.md §6 and §8):
//!
//! * **heuristic** — tiny problems stay on the naive nest (tile/pack setup
//!   cannot amortize); thin reductions (`cI·wF·hF` small) favor im2col
//!   (the patch matrix is cheap and the GEMM is wide); everything else
//!   goes tiled.
//! * **measured** — `select_pass` times each applicable kernel once on a
//!   batch-clamped probe of the shape and caches the winner. Probes above
//!   a MAC budget skip measurement and trust the heuristic, so selection
//!   never costs more than a couple of probe convolutions. Candidates
//!   whose *analytic* traffic exceeds the best candidate's by more than
//!   [`PRUNE_TRAFFIC_RATIO`]x are LP-pruned from timing entirely (the
//!   heuristic choice is exempt), for kernels and network modes alike.
//! * **persistence** — [`Autotuner::save`] writes the cached choices (and
//!   the tiled-engine word traffic of each shape, which the counters
//!   measure exactly equal to [`super::exec::expected_pass_traffic`]) to a
//!   versioned JSON sidecar; [`Autotuner::warm_start`] reloads them on the
//!   next process start so servers skip the probe convolutions entirely.
//!   A sidecar written under a different memory budget or precision is
//!   ignored — its choices answered a different planning question — and
//!   the schema is forward-compatible across binaries (see
//!   [`SIDECAR_VERSION`]).

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::conv::{
    conv7nl_naive, pass_operands, ConvPass, ConvShape, NetworkStage,
    Precision, Tensor4,
};
use crate::err;
use crate::obs::{self, jb, jf, js, ju};
use crate::util::error::{Context, Result};
use crate::util::json::Json;

use super::exec::{
    conv_network_bwd_counted, conv_network_fused_counted,
    conv_network_step_counted, conv_pass_tiled, conv_tiled,
    expected_pass_traffic, NetTrafficCounters,
};
use super::fuse::{FusePlan, FusedExec, NetPass};
use super::im2col::conv_im2col;
use super::plan::{TilePlan, TilePlanCache};
use super::shard::{exec_sharded, ShardPlan, ShardStrategy, ShardTrafficCounters};
use super::winograd::{conv_winograd, expected_winograd_traffic, WinoPlan};

/// Sidecar schema version this binary writes. Readers accept any version
/// up to this one (older sidecars default the fields that did not exist
/// yet — entries without a `pass` are forward choices) and ignore files
/// from the future wholesale; unknown keys and unknown enum values inside
/// entries are skipped, not errors. Gradient-pass records live under
/// their own `pass_entries` key — `entries` stays forward-only in the
/// exact v1 schema — so the file is safe in *both* directions: a pass
/// binary reads a PR 3/4 sidecar (no version, no pass fields), and a
/// PR 3/4 binary reading a pass sidecar sees only the forward entries it
/// understands instead of having its per-shape choices silently
/// overwritten by same-shape gradient records. Network-mode records
/// follow the same split: forward choices stay under `networks`,
/// backward/step choices under `pass_networks` (with a `pass` field).
pub const SIDECAR_VERSION: u64 = 2;

/// The four executable kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelKind {
    Naive,
    Im2col,
    Tiled,
    /// Tiled Winograd F(2,3) (forward only; tolerance-validated).
    Winograd,
}

impl KernelKind {
    pub const ALL: [KernelKind; 4] = [
        KernelKind::Naive,
        KernelKind::Im2col,
        KernelKind::Tiled,
        KernelKind::Winograd,
    ];

    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Naive => "naive",
            KernelKind::Im2col => "im2col",
            KernelKind::Tiled => "tiled",
            KernelKind::Winograd => "winograd",
        }
    }

    pub fn parse(s: &str) -> Option<KernelKind> {
        match s {
            "naive" => Some(KernelKind::Naive),
            "im2col" => Some(KernelKind::Im2col),
            "tiled" => Some(KernelKind::Tiled),
            "winograd" => Some(KernelKind::Winograd),
            _ => None,
        }
    }
}

/// The three ways to execute a whole network pipeline — the candidate
/// fusion groupings the tuner probes the way it probes kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NetKernelKind {
    /// fused groups through the packed LP microkernel (the default)
    FusedPacked,
    /// fused groups through the patch-local naive reference nest
    FusedReference,
    /// every stage materialized through the LP-tiled engine
    Materialized,
}

impl NetKernelKind {
    pub const ALL: [NetKernelKind; 3] = [
        NetKernelKind::FusedPacked,
        NetKernelKind::FusedReference,
        NetKernelKind::Materialized,
    ];

    pub fn name(self) -> &'static str {
        match self {
            NetKernelKind::FusedPacked => "fused_packed",
            NetKernelKind::FusedReference => "fused_reference",
            NetKernelKind::Materialized => "materialized",
        }
    }

    pub fn parse(s: &str) -> Option<NetKernelKind> {
        match s {
            "fused_packed" => Some(NetKernelKind::FusedPacked),
            "fused_reference" => Some(NetKernelKind::FusedReference),
            "materialized" => Some(NetKernelKind::Materialized),
            _ => None,
        }
    }
}

/// Probes above this many MACs trust the heuristic instead of measuring.
const MEASURE_BUDGET_MACS: u64 = 200_000_000;

/// LP-prune threshold: a candidate whose *analytic* word traffic exceeds
/// the best candidate's by more than this ratio is never timed — the
/// blocking model already answered the question (Zhang et al.: let the
/// I/O bound prune the tuning space). The heuristic choice is exempt so
/// a sane fallback is always measured, and the per-kernel models are
/// deliberately optimistic (naive is charged its compulsory floor), so
/// pruning only fires when a candidate is hopeless under *any* timing.
pub const PRUNE_TRAFFIC_RATIO: f64 = 4.0;

/// One cached selection: the winning kernel plus the word traffic the
/// tiled engine charges for the full shape (its counters match the
/// analytic model exactly, so this *is* the measured tiled traffic).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Tuned {
    kernel: KernelKind,
    traffic_words: u64,
}

/// Per-shape kernel chooser (and per-network mode chooser) with a shared
/// plan cache.
pub struct Autotuner {
    pub mem_words: f64,
    /// word model the tile plans are solved under (f32 uniform by default;
    /// probing and execution always use the same plan either way)
    pub precision: Precision,
    plans: TilePlanCache,
    /// per-(pass, shape) kernel choices — the forward entries are what the
    /// pass-less [`Autotuner::select`] reads and writes
    choices: Mutex<HashMap<(ConvPass, ConvShape), Tuned>>,
    /// per-(network, pass) execution-mode choices, keyed on (name, batch,
    /// stage fingerprint, pass) — the fingerprint guards against a
    /// renamed-in-place chain reusing a stale choice, the way `choices`
    /// keys on the full [`ConvShape`]; the sidecar persists them next to
    /// the kernel choices, under the same (M, precision) staleness rule
    net_choices: Mutex<HashMap<(String, u64, u64, NetPass), NetKernelKind>>,
    /// per-(network, shard count) sharding-strategy choices, keyed like
    /// `net_choices` with the worker count in place of the pass
    shard_choices: Mutex<HashMap<(String, u64, u64, u64), ShardStrategy>>,
    /// when set (the default), probe timing skips candidates whose
    /// analytic traffic is > [`PRUNE_TRAFFIC_RATIO`]× the best candidate's
    pub prune_probes: bool,
    /// total candidates skipped by LP-pruning over this tuner's lifetime
    pruned: AtomicU64,
}

/// Deterministic fingerprint of a stage chain (shapes and precision bit
/// patterns, FNV-folded — stable across processes and toolchains): the
/// staleness guard that keeps a cached or persisted network choice from
/// answering for a *different* chain that shares its name and batch.
fn stages_fingerprint(stages: &[NetworkStage]) -> u64 {
    let mut f: u64 = 0xcbf29ce484222325;
    let mut mix = |v: u64| {
        f ^= v;
        f = f.wrapping_mul(0x100000001b3);
    };
    mix(stages.len() as u64);
    for st in stages {
        let s = &st.shape;
        for d in [s.n, s.c_i, s.c_o, s.w_o, s.h_o, s.w_f, s.h_f, s.s_w, s.s_h] {
            mix(d);
        }
        mix(st.precision.p_i.to_bits());
        mix(st.precision.p_f.to_bits());
        mix(st.precision.p_o.to_bits());
    }
    drop(mix);
    f
}

impl Autotuner {
    pub fn new(mem_words: f64) -> Autotuner {
        Autotuner::with_precision(mem_words, Precision::uniform())
    }

    pub fn with_precision(mem_words: f64, precision: Precision) -> Autotuner {
        Autotuner {
            mem_words,
            precision,
            plans: TilePlanCache::new(),
            choices: Mutex::new(HashMap::new()),
            net_choices: Mutex::new(HashMap::new()),
            shard_choices: Mutex::new(HashMap::new()),
            prune_probes: true,
            pruned: AtomicU64::new(0),
        }
    }

    /// How many probe candidates LP-pruning has skipped so far.
    pub fn pruned_probes(&self) -> u64 {
        self.pruned.load(Ordering::Relaxed)
    }

    /// The (cached) forward tile plan this tuner would execute `s` with.
    pub fn plan(&self, s: &ConvShape) -> Arc<TilePlan> {
        self.plans.plan(s, self.precision, self.mem_words)
    }

    /// The (cached) tile plan this tuner would execute pass `pass` of `s`
    /// with.
    pub fn plan_pass(&self, pass: ConvPass, s: &ConvShape) -> Arc<TilePlan> {
        self.plans.plan_pass(pass, s, self.precision, self.mem_words)
    }

    /// The kernels that can execute `pass`: the forward pass has im2col
    /// and Winograd lowerings, the gradient passes run naive vs tiled.
    pub fn pass_kernels(pass: ConvPass) -> &'static [KernelKind] {
        match pass {
            ConvPass::Forward => &KernelKind::ALL,
            _ => &[KernelKind::Naive, KernelKind::Tiled],
        }
    }

    /// Zero-cost selection from shape structure alone (forward pass).
    pub fn heuristic(s: &ConvShape) -> KernelKind {
        if s.updates() < (1 << 16) {
            return KernelKind::Naive;
        }
        if s.c_i * s.w_f * s.h_f < 16 {
            return KernelKind::Im2col;
        }
        KernelKind::Tiled
    }

    /// Zero-cost per-pass selection: forward keeps the three-way
    /// heuristic; the gradient passes stay naive only when tiny (tile
    /// setup cannot amortize) and go tiled otherwise.
    pub fn heuristic_pass(pass: ConvPass, s: &ConvShape) -> KernelKind {
        match pass {
            ConvPass::Forward => Autotuner::heuristic(s),
            _ => {
                if s.updates() < (1 << 16) {
                    KernelKind::Naive
                } else {
                    KernelKind::Tiled
                }
            }
        }
    }

    /// Measure-once selection: time each applicable kernel on a
    /// batch-clamped probe of `s`, cache and return the fastest. Falls
    /// back to
    /// [`Autotuner::heuristic`] when even the probe would be too large.
    pub fn select(&self, s: &ConvShape) -> KernelKind {
        self.select_pass(ConvPass::Forward, s)
    }

    /// Measure-once per-pass selection: time each of
    /// [`Autotuner::pass_kernels`] on a batch-clamped probe, cache keyed
    /// `(pass, shape)` and return the fastest. Falls back to
    /// [`Autotuner::heuristic_pass`] when even the probe would be too
    /// large.
    pub fn select_pass(&self, pass: ConvPass, s: &ConvShape) -> KernelKind {
        if let Some(t) = self
            .choices
            .lock()
            .expect("choices poisoned")
            .get(&(pass, *s))
        {
            return t.kernel;
        }
        let probe = s.with_batch(s.n.min(2));
        let kernel = if probe.updates() > MEASURE_BUDGET_MACS {
            Autotuner::heuristic_pass(pass, s)
        } else {
            self.measure_pass(pass, &probe)
        };
        // engine traffic is only meaningful (and its plan only needed)
        // when a counted engine won — the heuristic early-out stays
        // LP-free; winograd records its own exact analytic model the
        // same way tiled records the blocked-engine model
        let traffic_words = match kernel {
            KernelKind::Tiled => {
                expected_pass_traffic(&self.plan_pass(pass, s)).total()
            }
            KernelKind::Winograd => expected_winograd_traffic(&WinoPlan::new(
                s,
                self.precision,
                self.mem_words,
            ))
            .total(),
            _ => 0,
        };
        self.choices
            .lock()
            .expect("choices poisoned")
            .insert((pass, *s), Tuned { kernel, traffic_words });
        kernel
    }

    /// The fusion plan this tuner would execute `stages` with under a
    /// given network mode (tile plans come from the shared cache). The
    /// halo flag feeds the *planner* too — fusion decisions and tile
    /// fitting must use the model the run will execute under, or the
    /// `fused ≤ unfused` rule silently evaluates the wrong traffic.
    /// Ignored by `Materialized` (nothing fuses, nothing carries).
    pub fn network_plan(
        &self,
        stages: &[NetworkStage],
        kind: NetKernelKind,
        halo_cache: bool,
    ) -> FusePlan {
        self.network_pass_plan(NetPass::Forward, stages, kind, halo_cache, false)
    }

    /// The pass-generic fusion plan for `stages` under a network mode:
    /// the same three-way switch as [`Autotuner::network_plan`], solved
    /// for the pass's per-stage LPs and fused under the pass's fit rule.
    /// `halo_w` additionally carries head overlap columns across a batch
    /// block's w-tile-columns (forward plans with the cache on only).
    pub fn network_pass_plan(
        &self,
        pass: NetPass,
        stages: &[NetworkStage],
        kind: NetKernelKind,
        halo_cache: bool,
        halo_w: bool,
    ) -> FusePlan {
        match kind {
            NetKernelKind::FusedPacked => FusePlan::for_pass_with_options(
                pass,
                stages,
                self.mem_words,
                &self.plans,
                FusedExec::Packed,
                halo_cache,
                halo_w,
            ),
            NetKernelKind::FusedReference => FusePlan::for_pass_with_options(
                pass,
                stages,
                self.mem_words,
                &self.plans,
                FusedExec::Reference,
                halo_cache,
                halo_w,
            ),
            NetKernelKind::Materialized => FusePlan::materialized_pass(
                pass,
                stages,
                self.mem_words,
                &self.plans,
            ),
        }
    }

    /// Zero-cost network selection from plan structure alone: fuse
    /// (packed) when the planner fuses any boundary at this tuner's
    /// budget, else materialize.
    pub fn heuristic_network(&self, stages: &[NetworkStage]) -> NetKernelKind {
        self.heuristic_network_pass(NetPass::Forward, stages)
    }

    /// Pass-generic structural selection: fuse when the pass's planner
    /// fuses any boundary at this tuner's budget, else materialize.
    pub fn heuristic_network_pass(
        &self,
        pass: NetPass,
        stages: &[NetworkStage],
    ) -> NetKernelKind {
        let plan = FusePlan::for_pass(pass, stages, self.mem_words, &self.plans);
        if plan.fused_boundaries() > 0 {
            NetKernelKind::FusedPacked
        } else {
            NetKernelKind::Materialized
        }
    }

    /// The network modes that can execute `pass`: the gradient sweeps run
    /// their per-element gather nests regardless of the packed/reference
    /// switch (the accumulation-order contract pins them to the oracle),
    /// so only fused-vs-materialized is a real candidate there.
    pub fn net_pass_modes(pass: NetPass) -> &'static [NetKernelKind] {
        match pass {
            NetPass::Forward => &NetKernelKind::ALL,
            _ => &[NetKernelKind::FusedPacked, NetKernelKind::Materialized],
        }
    }

    /// Measure-once network-mode selection: time the three execution modes
    /// (fused-packed, fused-naive, materialized) on a batch-clamped probe
    /// of the chain, cache and return the fastest, keyed on
    /// `(name, batch, stage fingerprint)`. Falls back to
    /// [`Autotuner::heuristic_network`] when even the probe would exceed
    /// the MAC budget.
    pub fn select_network(&self, name: &str, stages: &[NetworkStage]) -> NetKernelKind {
        self.select_network_pass(NetPass::Forward, name, stages)
    }

    /// Measure-once pass-generic network-mode selection: time the modes
    /// that can execute `pass` ([`Autotuner::net_pass_modes`]) on a
    /// batch-clamped probe of the chain, cache keyed
    /// `(name, batch, stage fingerprint, pass)` and return the fastest.
    /// Falls back to [`Autotuner::heuristic_network_pass`] when even the
    /// probe would exceed the MAC budget; candidates whose analytic
    /// traffic exceeds the best mode's by >[`PRUNE_TRAFFIC_RATIO`]× are
    /// LP-pruned from timing.
    pub fn select_network_pass(
        &self,
        pass: NetPass,
        name: &str,
        stages: &[NetworkStage],
    ) -> NetKernelKind {
        assert!(!stages.is_empty(), "empty network");
        let key = (
            name.to_string(),
            stages[0].shape.n,
            stages_fingerprint(stages),
            pass,
        );
        if let Some(k) = self
            .net_choices
            .lock()
            .expect("net choices poisoned")
            .get(&key)
        {
            return *k;
        }
        let probe: Vec<NetworkStage> = stages
            .iter()
            .map(|st| NetworkStage {
                shape: st.shape.with_batch(st.shape.n.min(2)),
                precision: st.precision,
            })
            .collect();
        let macs: u64 = probe.iter().map(|st| st.shape.updates()).sum();
        // a training-step probe does ~3x the forward MACs (activation
        // recompute + both gradient chains)
        let cost = match pass {
            NetPass::Step => 3 * macs,
            _ => macs,
        };
        let kind = if cost > MEASURE_BUDGET_MACS {
            self.heuristic_network_pass(pass, stages)
        } else {
            self.measure_network_pass(pass, &probe)
        };
        self.net_choices
            .lock()
            .expect("net choices poisoned")
            .insert(key, kind);
        kind
    }

    fn measure_network_pass(
        &self,
        pass: NetPass,
        stages: &[NetworkStage],
    ) -> NetKernelKind {
        let head = &stages[0].shape;
        let tail = &stages[stages.len() - 1].shape;
        let image = Tensor4::randn(
            [
                head.n as usize,
                head.c_i as usize,
                head.in_w() as usize,
                head.in_h() as usize,
            ],
            1,
        );
        let gout = Tensor4::randn(
            [
                tail.n as usize,
                tail.c_o as usize,
                tail.w_o as usize,
                tail.h_o as usize,
            ],
            99,
        );
        let filters: Vec<Tensor4> = stages
            .iter()
            .enumerate()
            .map(|(i, st)| Tensor4::randn(st.shape.filter_dims(), 2 + i as u64))
            .collect();
        let frefs: Vec<&Tensor4> = filters.iter().collect();
        let candidates = Autotuner::net_pass_modes(pass);
        let plans: Vec<FusePlan> = candidates
            .iter()
            .map(|&kind| self.network_pass_plan(pass, stages, kind, true, false))
            .collect();
        let analytic: Vec<f64> = plans
            .iter()
            .map(|p| {
                p.expected_network_traffic()
                    .iter()
                    .map(|t| t.total())
                    .sum::<u64>() as f64
            })
            .collect();
        let floor = analytic.iter().cloned().fold(f64::INFINITY, f64::min);
        let keep = self.heuristic_network_pass(pass, stages);
        let mut pruned = 0u64;
        let mut best = (keep, f64::INFINITY);
        for ((&kind, plan), &words) in
            candidates.iter().zip(&plans).zip(&analytic)
        {
            if self.prune_probes
                && kind != keep
                && words > PRUNE_TRAFFIC_RATIO * floor
            {
                pruned += 1;
                if obs::enabled() {
                    obs::event(
                        obs::kind::AUTOTUNE_PROBE,
                        &[
                            ("pass", js(pass.name())),
                            ("stages", ju(stages.len() as u64)),
                            ("candidate", js(kind.name())),
                            ("analytic_words", jf(words)),
                            ("pruned", jb(true)),
                        ],
                    );
                }
                continue;
            }
            let counters = NetTrafficCounters::new(stages.len());
            let t0 = Instant::now();
            match pass {
                NetPass::Forward => {
                    std::hint::black_box(conv_network_fused_counted(
                        &image, &frefs, plan, &counters,
                    ));
                }
                NetPass::Backward => {
                    std::hint::black_box(conv_network_bwd_counted(
                        &gout, &frefs, plan, &counters,
                    ));
                }
                NetPass::Step => {
                    std::hint::black_box(conv_network_step_counted(
                        &image, &frefs, &gout, plan, &counters,
                    ));
                }
            }
            let secs = t0.elapsed().as_secs_f64();
            if obs::enabled() {
                obs::event(
                    obs::kind::AUTOTUNE_PROBE,
                    &[
                        ("pass", js(pass.name())),
                        ("stages", ju(stages.len() as u64)),
                        ("candidate", js(kind.name())),
                        ("analytic_words", jf(words)),
                        ("secs", jf(secs)),
                        ("pruned", jb(false)),
                    ],
                );
            }
            if secs < best.1 {
                best = (kind, secs);
            }
        }
        self.note_pruned(pruned, candidates.len(), pass.name(), "network-mode");
        if obs::enabled() {
            obs::event(
                obs::kind::AUTOTUNE_SELECT,
                &[
                    ("pass", js(pass.name())),
                    ("stages", ju(stages.len() as u64)),
                    ("kernel", js(best.0.name())),
                    ("secs", jf(best.1)),
                ],
            );
        }
        best.0
    }

    /// Measure-once shard-strategy selection for `shards` virtual
    /// workers: every strategy's *exact* analytic exchange volume
    /// (`ShardPlan::expected_exchange`, the same numbers the executor's
    /// gate enforces) sets the LP-pruning floor — candidates whose volume
    /// exceeds it by >[`PRUNE_TRAFFIC_RATIO`]× are never timed — and the
    /// survivors race on a batch-clamped probe. Falls back to the analytic
    /// minimum (what `--shard-by auto` picks) when even the probe would
    /// exceed the MAC budget. Cached per `(name, batch, chain, shards)`.
    pub fn select_shard(
        &self,
        name: &str,
        stages: &[NetworkStage],
        shards: u64,
    ) -> ShardStrategy {
        assert!(!stages.is_empty(), "empty network");
        let key = (
            name.to_string(),
            stages[0].shape.n,
            stages_fingerprint(stages),
            shards,
        );
        if let Some(s) = self
            .shard_choices
            .lock()
            .expect("shard choices poisoned")
            .get(&key)
        {
            return *s;
        }
        let auto =
            ShardPlan::auto(stages, shards, self.mem_words, &self.plans).strategy;
        let probe: Vec<NetworkStage> = stages
            .iter()
            .map(|st| NetworkStage {
                shape: st.shape.with_batch(st.shape.n.min(2)),
                precision: st.precision,
            })
            .collect();
        let macs: u64 = probe.iter().map(|st| st.shape.updates()).sum();
        let strategy = if macs > MEASURE_BUDGET_MACS {
            auto
        } else {
            self.measure_shard(auto, &probe, stages, shards)
        };
        self.shard_choices
            .lock()
            .expect("shard choices poisoned")
            .insert(key, strategy);
        strategy
    }

    fn measure_shard(
        &self,
        keep: ShardStrategy,
        probe: &[NetworkStage],
        stages: &[NetworkStage],
        shards: u64,
    ) -> ShardStrategy {
        let head = &probe[0].shape;
        let image = Arc::new(Tensor4::randn(
            [
                head.n as usize,
                head.c_i as usize,
                head.in_w() as usize,
                head.in_h() as usize,
            ],
            1,
        ));
        let filters: Vec<Arc<Tensor4>> = probe
            .iter()
            .enumerate()
            .map(|(i, st)| {
                Arc::new(Tensor4::randn(st.shape.filter_dims(), 2 + i as u64))
            })
            .collect();
        // prune on the FULL chain's analytic volumes (what deployment
        // pays), time on the clamped probe
        let analytic: Vec<f64> = ShardStrategy::ALL
            .iter()
            .map(|&st| {
                ShardPlan::new(stages, st, shards, self.mem_words, &self.plans)
                    .expected_exchange()
                    .total() as f64
            })
            .collect();
        let floor = analytic.iter().cloned().fold(f64::INFINITY, f64::min);
        let mut pruned = 0u64;
        let mut best = (keep, f64::INFINITY);
        for (&strategy, &words) in ShardStrategy::ALL.iter().zip(&analytic) {
            if self.prune_probes
                && strategy != keep
                && words > PRUNE_TRAFFIC_RATIO * floor.max(1.0)
            {
                pruned += 1;
                if obs::enabled() {
                    obs::event(
                        obs::kind::AUTOTUNE_PROBE,
                        &[
                            ("pass", js("shard")),
                            ("shards", ju(shards)),
                            ("candidate", js(strategy.name())),
                            ("analytic_words", jf(words)),
                            ("pruned", jb(true)),
                        ],
                    );
                }
                continue;
            }
            let plan = Arc::new(ShardPlan::new(
                probe, strategy, shards, self.mem_words, &self.plans,
            ));
            let counters = Arc::new(ShardTrafficCounters::new(plan.workers()));
            let t0 = Instant::now();
            let ok = std::hint::black_box(exec_sharded(
                &image, &filters, &plan, &counters,
            ))
            .is_ok();
            let secs = t0.elapsed().as_secs_f64();
            if obs::enabled() {
                obs::event(
                    obs::kind::AUTOTUNE_PROBE,
                    &[
                        ("pass", js("shard")),
                        ("shards", ju(shards)),
                        ("candidate", js(strategy.name())),
                        ("analytic_words", jf(words)),
                        ("secs", jf(secs)),
                        ("pruned", jb(false)),
                    ],
                );
            }
            if ok && secs < best.1 {
                best = (strategy, secs);
            }
        }
        self.note_pruned(pruned, ShardStrategy::ALL.len(), "shard", "shard-strategy");
        if obs::enabled() {
            obs::event(
                obs::kind::AUTOTUNE_SELECT,
                &[
                    ("pass", js("shard")),
                    ("shards", ju(shards)),
                    ("kernel", js(best.0.name())),
                    ("secs", jf(best.1)),
                ],
            );
        }
        best.0
    }

    /// Execute a whole network (serially) under the autotuned mode.
    pub fn run_network(
        &self,
        image: &Tensor4,
        filters: &[&Tensor4],
        name: &str,
        stages: &[NetworkStage],
    ) -> Tensor4 {
        let kind = self.select_network(name, stages);
        let plan = self.network_plan(stages, kind, true);
        let counters = NetTrafficCounters::new(stages.len());
        conv_network_fused_counted(image, filters, &plan, &counters)
    }

    /// Every cached network choice with its full key, sorted for stable
    /// sidecar files.
    fn tuned_networks_raw(
        &self,
    ) -> Vec<((String, u64, u64, NetPass), NetKernelKind)> {
        let mut out: Vec<((String, u64, u64, NetPass), NetKernelKind)> = self
            .net_choices
            .lock()
            .expect("net choices poisoned")
            .iter()
            .map(|(key, k)| (key.clone(), *k))
            .collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// Every cached `(network, batch, pass, mode)` tuple, in a
    /// deterministic order (for reports and tests).
    pub fn tuned_networks(&self) -> Vec<(String, u64, NetPass, NetKernelKind)> {
        self.tuned_networks_raw()
            .into_iter()
            .map(|((n, b, _, p), k)| (n, b, p, k))
            .collect()
    }

    /// Every cached `(pass, shape, kernel, tiled traffic words)` record,
    /// in a deterministic order (for stable sidecar files and reports).
    pub fn tuned(&self) -> Vec<(ConvPass, ConvShape, KernelKind, u64)> {
        let mut out: Vec<(ConvPass, ConvShape, KernelKind, u64)> = self
            .choices
            .lock()
            .expect("choices poisoned")
            .iter()
            .map(|((pass, s), t)| (*pass, *s, t.kernel, t.traffic_words))
            .collect();
        out.sort_by_key(|(pass, s, _, _)| {
            (
                *pass as u8,
                [s.n, s.c_i, s.c_o, s.w_o, s.h_o, s.w_f, s.h_f, s.s_w, s.s_h],
            )
        });
        out
    }

    /// Persist the cached kernel choices (and their tiled traffic) to a
    /// JSON sidecar, together with the `(M, precision)` configuration they
    /// were selected under and the schema [`SIDECAR_VERSION`].
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut doc = std::collections::BTreeMap::new();
        doc.insert("version".to_string(), Json::Num(SIDECAR_VERSION as f64));
        doc.insert("mem_words".to_string(), Json::Num(self.mem_words));
        doc.insert(
            "precision".to_string(),
            Json::Arr(vec![
                Json::Num(self.precision.p_i),
                Json::Num(self.precision.p_f),
                Json::Num(self.precision.p_o),
            ]),
        );
        let entry_json = |pass: ConvPass, s: ConvShape, k: KernelKind, words: u64| {
            let mut e = std::collections::BTreeMap::new();
            e.insert("pass".to_string(), Json::Str(pass.name().to_string()));
            e.insert(
                "shape".to_string(),
                Json::Arr(
                    [s.n, s.c_i, s.c_o, s.w_o, s.h_o, s.w_f, s.h_f, s.s_w, s.s_h]
                        .iter()
                        .map(|&d| Json::Num(d as f64))
                        .collect(),
                ),
            );
            e.insert("kernel".to_string(), Json::Str(k.name().to_string()));
            e.insert("traffic_words".to_string(), Json::Num(words as f64));
            Json::Obj(e)
        };
        // forward choices keep the v1 `entries` key (pre-pass binaries
        // read it as-is); gradient-pass choices go under `pass_entries`,
        // which those binaries ignore — otherwise a same-shape dfilter or
        // dinput record would overwrite their forward choice
        let mut entries = Vec::new();
        let mut pass_entries = Vec::new();
        for (pass, s, k, words) in self.tuned() {
            if pass == ConvPass::Forward {
                entries.push(entry_json(pass, s, k, words));
            } else {
                pass_entries.push(entry_json(pass, s, k, words));
            }
        }
        doc.insert("entries".to_string(), Json::Arr(entries));
        doc.insert("pass_entries".to_string(), Json::Arr(pass_entries));
        // same split as `entries`/`pass_entries`: forward network choices
        // keep the pass-less `networks` schema older binaries read, while
        // backward/step records go under `pass_networks` (with a `pass`
        // field) where those binaries cannot mistake them for forward ones
        let mut networks = Vec::new();
        let mut pass_networks = Vec::new();
        for ((name, batch, fp, pass), k) in self.tuned_networks_raw() {
            let mut e = std::collections::BTreeMap::new();
            e.insert("name".to_string(), Json::Str(name));
            e.insert("batch".to_string(), Json::Num(batch as f64));
            e.insert("stages".to_string(), Json::Str(format!("{fp:016x}")));
            e.insert("kernel".to_string(), Json::Str(k.name().to_string()));
            if pass == NetPass::Forward {
                networks.push(Json::Obj(e));
            } else {
                e.insert("pass".to_string(), Json::Str(pass.name().to_string()));
                pass_networks.push(Json::Obj(e));
            }
        }
        doc.insert("networks".to_string(), Json::Arr(networks));
        doc.insert("pass_networks".to_string(), Json::Arr(pass_networks));
        let path = path.as_ref();
        std::fs::write(path, format!("{}\n", Json::Obj(doc)))
            .with_context(|| format!("writing autotune sidecar {}", path.display()))
    }

    /// Warm-start the choice cache from a sidecar written by a previous
    /// process. Returns the number of choices loaded: `0` when the file
    /// does not exist, was written under a different `(M, precision)`
    /// configuration, or carries a schema version newer than this binary
    /// (stale or future sidecars are ignored, not trusted). Structurally
    /// malformed files are an error; entries whose `pass` or `kernel`
    /// carries an *unknown value* (a record from a newer binary) are
    /// skipped individually — forward compatibility, not corruption.
    pub fn warm_start(&self, path: impl AsRef<Path>) -> Result<usize> {
        let path = path.as_ref();
        if !path.exists() {
            return Ok(0);
        }
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading autotune sidecar {}", path.display()))?;
        let v = Json::parse(&text)
            .map_err(|e| err!("autotune sidecar {}: {e}", path.display()))?;
        // pre-version sidecars (PR 3/4 binaries) carry no field: version 1
        let version = v.get("version").as_u64().unwrap_or(1);
        if version > SIDECAR_VERSION {
            return Ok(0);
        }
        if v.get("mem_words").as_f64() != Some(self.mem_words) {
            return Ok(0);
        }
        let p = v.get("precision").as_arr().unwrap_or(&[]);
        if p.len() != 3
            || p[0].as_f64() != Some(self.precision.p_i)
            || p[1].as_f64() != Some(self.precision.p_f)
            || p[2].as_f64() != Some(self.precision.p_o)
        {
            return Ok(0);
        }
        // parse everything before touching the live cache: a malformed
        // sidecar must be rejected whole, not half-applied. `entries` is
        // the forward-only v1 list; `pass_entries` holds the gradient
        // passes (same record schema, absent in v1 files)
        let mut entries = Vec::new();
        for e in v
            .get("entries")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .chain(v.get("pass_entries").as_arr().unwrap_or(&[]))
        {
            let dims = e
                .get("shape")
                .as_arr()
                .ok_or_else(|| err!("sidecar entry missing 'shape'"))?;
            if dims.len() != 9 {
                return Err(err!("sidecar shape wants 9 dims, got {}", dims.len()));
            }
            let d: Vec<u64> = dims
                .iter()
                .map(|x| {
                    x.as_u64_strict().ok_or_else(|| {
                        err!("sidecar shape dim '{x}' is not an integer")
                    })
                })
                .collect::<Result<_>>()?;
            let shape = ConvShape::new(
                d[0], d[1], d[2], d[3], d[4], d[5], d[6], d[7], d[8],
            );
            // a missing 'pass' is a pre-pass (v1) forward entry; an
            // unrecognized pass or kernel name is a record from a newer
            // binary — skip it, the rest of the file is still good
            let pass = match e.get("pass") {
                Json::Null => ConvPass::Forward,
                other => match other.as_str().and_then(ConvPass::parse) {
                    Some(pass) => pass,
                    None => continue,
                },
            };
            let kernel = match e.get("kernel").as_str().map(KernelKind::parse) {
                Some(Some(k)) => k,
                Some(None) => continue,
                None => return Err(err!("sidecar entry missing 'kernel'")),
            };
            let traffic_words =
                e.get("traffic_words").as_u64_strict().ok_or_else(|| {
                    err!("sidecar entry has a malformed 'traffic_words'")
                })?;
            entries.push(((pass, shape), Tuned { kernel, traffic_words }));
        }
        let mut networks = Vec::new();
        for e in v
            .get("networks")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .chain(v.get("pass_networks").as_arr().unwrap_or(&[]))
        {
            let name = e
                .get("name")
                .as_str()
                .ok_or_else(|| err!("sidecar network entry missing 'name'"))?
                .to_string();
            let batch = e.get("batch").as_u64_strict().ok_or_else(|| {
                err!("sidecar network entry has a malformed 'batch'")
            })?;
            let fp = e
                .get("stages")
                .as_str()
                .and_then(|s| u64::from_str_radix(s, 16).ok())
                .ok_or_else(|| {
                    err!(
                        "sidecar network entry has a malformed 'stages' \
                         fingerprint"
                    )
                })?;
            // a missing 'pass' is a forward record from the pass-less
            // `networks` list; an unrecognized pass is a record from a
            // newer binary — skip it, the rest of the file is still good
            let pass = match e.get("pass") {
                Json::Null => NetPass::Forward,
                other => match other.as_str().and_then(NetPass::parse) {
                    Some(pass) => pass,
                    None => continue,
                },
            };
            // same forward-compat rule as entries: an unknown network mode
            // came from a newer binary and is skipped, not fatal
            let kernel = match e.get("kernel").as_str().map(NetKernelKind::parse) {
                Some(Some(k)) => k,
                Some(None) => continue,
                None => {
                    return Err(err!("sidecar network entry missing 'kernel'"))
                }
            };
            networks.push(((name, batch, fp, pass), kernel));
        }
        let loaded = entries.len() + networks.len();
        {
            let mut choices = self.choices.lock().expect("choices poisoned");
            for (key, tuned) in entries {
                choices.insert(key, tuned);
            }
        }
        {
            let mut nets = self.net_choices.lock().expect("net choices poisoned");
            for (key, kind) in networks {
                nets.insert(key, kind);
            }
        }
        Ok(loaded)
    }

    /// Analytic word traffic of executing `pass` of `s` with kernel `k` —
    /// the LP-pruning metric. Naive is charged its compulsory floor (every
    /// operand word touched exactly once; deliberately optimistic so the
    /// reference nest is never pruned by an overstated cache model),
    /// im2col adds the written-then-read patch matrix on top of that
    /// floor, and tiled is the exact blocked-engine model
    /// [`expected_pass_traffic`] whose counters the engine matches
    /// word-for-word.
    pub fn analytic_kernel_traffic(
        &self,
        pass: ConvPass,
        k: KernelKind,
        s: &ConvShape,
    ) -> f64 {
        let input = (s.n * s.c_i * s.in_w() * s.in_h()) as f64;
        let output = (s.n * s.c_o * s.w_o * s.h_o) as f64;
        let compulsory = input + s.filter_size() as f64 + output;
        match k {
            KernelKind::Naive => compulsory,
            KernelKind::Im2col => {
                let patch =
                    (s.n * s.c_i * s.w_f * s.h_f * s.w_o * s.h_o) as f64;
                compulsory + 2.0 * patch
            }
            KernelKind::Tiled => {
                expected_pass_traffic(&self.plan_pass(pass, s)).total() as f64
            }
            // the §4.2 analytic Winograd volume — the same model Figure 2
            // charts, so the LP prune races exactly what the paper races
            KernelKind::Winograd => {
                crate::commvol::seq::winograd_volume(s, self.precision, self.mem_words)
            }
        }
    }

    fn note_pruned(&self, pruned: u64, total: usize, pass: &str, what: &str) {
        if pruned == 0 {
            return;
        }
        self.pruned.fetch_add(pruned, Ordering::Relaxed);
        if obs::enabled() {
            obs::event(
                obs::kind::AUTOTUNE_PRUNE,
                &[
                    ("pass", js(pass)),
                    ("what", js(what)),
                    ("pruned", ju(pruned)),
                    ("candidates", ju(total as u64)),
                    ("ratio", jf(PRUNE_TRAFFIC_RATIO)),
                ],
            );
        }
        obs::log(
            obs::Level::Debug,
            &format!(
                "autotune: LP-pruned {pruned}/{total} {what} probes for pass \
                 '{pass}' (analytic traffic > {PRUNE_TRAFFIC_RATIO}x best)"
            ),
        );
    }

    fn measure_pass(&self, pass: ConvPass, s: &ConvShape) -> KernelKind {
        let (a, b) = pass_operands(pass, s, 1);
        // solve (and cache) the blocking LP outside the timed region: the
        // probe compares steady-state kernels, and the plan is a one-time
        // per-shape cost every later tiled run reuses
        let _ = self.plan_pass(pass, s);
        let candidates = Autotuner::pass_kernels(pass);
        let analytic: Vec<f64> = candidates
            .iter()
            .map(|&k| self.analytic_kernel_traffic(pass, k, s))
            .collect();
        let floor = analytic.iter().cloned().fold(f64::INFINITY, f64::min);
        let keep = Autotuner::heuristic_pass(pass, s);
        let mut pruned = 0u64;
        let mut best = (keep, f64::INFINITY);
        for (&k, &words) in candidates.iter().zip(&analytic) {
            if self.prune_probes
                && k != keep
                && words > PRUNE_TRAFFIC_RATIO * floor
            {
                pruned += 1;
                if obs::enabled() {
                    obs::event(
                        obs::kind::AUTOTUNE_PROBE,
                        &[
                            ("pass", js(pass.name())),
                            ("shape", js(&s.to_string())),
                            ("candidate", js(k.name())),
                            ("analytic_words", jf(words)),
                            ("pruned", jb(true)),
                        ],
                    );
                }
                continue;
            }
            let t0 = Instant::now();
            std::hint::black_box(self.run_pass_kernel(pass, k, &a, &b, s));
            let secs = t0.elapsed().as_secs_f64();
            if obs::enabled() {
                obs::event(
                    obs::kind::AUTOTUNE_PROBE,
                    &[
                        ("pass", js(pass.name())),
                        ("shape", js(&s.to_string())),
                        ("candidate", js(k.name())),
                        ("analytic_words", jf(words)),
                        ("secs", jf(secs)),
                        ("pruned", jb(false)),
                    ],
                );
            }
            if secs < best.1 {
                best = (k, secs);
            }
        }
        self.note_pruned(pruned, candidates.len(), pass.name(), "kernel");
        if obs::enabled() {
            obs::event(
                obs::kind::AUTOTUNE_SELECT,
                &[
                    ("pass", js(pass.name())),
                    ("shape", js(&s.to_string())),
                    ("kernel", js(best.0.name())),
                    ("secs", jf(best.1)),
                ],
            );
        }
        best.0
    }

    /// Execute the forward pass of `s` with an explicit kernel.
    pub fn run_kernel(
        &self,
        k: KernelKind,
        x: &Tensor4,
        w: &Tensor4,
        s: &ConvShape,
    ) -> Tensor4 {
        match k {
            KernelKind::Naive => conv7nl_naive(x, w, s),
            KernelKind::Im2col => conv_im2col(x, w, s),
            KernelKind::Tiled => conv_tiled(x, w, &self.plan(s)),
            KernelKind::Winograd => conv_winograd(
                x,
                w,
                &WinoPlan::new(s, self.precision, self.mem_words),
            ),
        }
    }

    /// Execute one pass of `s` with an explicit kernel. No im2col or
    /// Winograd lowering exists for the gradient passes
    /// ([`Autotuner::pass_kernels`] never offers them there); asking for
    /// one anyway runs the naive oracle.
    pub fn run_pass_kernel(
        &self,
        pass: ConvPass,
        k: KernelKind,
        a: &Tensor4,
        b: &Tensor4,
        s: &ConvShape,
    ) -> Tensor4 {
        match (pass, k) {
            (ConvPass::Forward, _) => self.run_kernel(k, a, b, s),
            (_, KernelKind::Tiled) => {
                conv_pass_tiled(pass, a, b, &self.plan_pass(pass, s))
            }
            _ => pass.naive_oracle(a, b, s),
        }
    }

    /// Execute the forward pass of `s` with the autotuned kernel.
    pub fn run(&self, x: &Tensor4, w: &Tensor4, s: &ConvShape) -> Tensor4 {
        let k = self.select(s);
        self.run_kernel(k, x, w, s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heuristic_tiers() {
        // tiny -> naive
        let tiny = ConvShape::new(1, 2, 2, 4, 4, 3, 3, 1, 1);
        assert_eq!(Autotuner::heuristic(&tiny), KernelKind::Naive);
        // big but thin reduction (1x1 filter, few channels) -> im2col
        let thin = ConvShape::new(64, 4, 64, 32, 32, 1, 1, 1, 1);
        assert!(thin.updates() >= (1 << 16));
        assert_eq!(Autotuner::heuristic(&thin), KernelKind::Im2col);
        // big with fat reduction -> tiled
        let fat = ConvShape::new(4, 64, 64, 14, 14, 3, 3, 1, 1);
        assert_eq!(Autotuner::heuristic(&fat), KernelKind::Tiled);
    }

    #[test]
    fn select_caches_and_run_matches_naive() {
        let tuner = Autotuner::new(4096.0);
        let s = ConvShape::new(2, 3, 4, 6, 6, 3, 3, 1, 1);
        let k1 = tuner.select(&s);
        let k2 = tuner.select(&s);
        assert_eq!(k1, k2);
        let (x, w) = crate::conv::paper_operands(&s, 5);
        let got = tuner.run(&x, &w, &s);
        let want = conv7nl_naive(&x, &w, &s);
        assert!(got.rel_l2(&want) < 1e-4, "rel {}", got.rel_l2(&want));
    }

    #[test]
    fn sidecar_roundtrips_and_rejects_stale_configs() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!(
            "convbound_autotune_{}_{:?}.json",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_file(&path);

        let tuner = Autotuner::new(4096.0);
        let a = ConvShape::new(2, 3, 4, 6, 6, 3, 3, 1, 1);
        let b = ConvShape::new(1, 2, 3, 4, 4, 3, 3, 2, 2);
        let ka = tuner.select(&a);
        let kb = tuner.select(&b);
        assert_eq!(tuner.tuned().len(), 2);
        for (_, _, k, words) in tuner.tuned() {
            match k {
                KernelKind::Tiled | KernelKind::Winograd => {
                    assert!(words > 0, "engine choices record their traffic")
                }
                _ => assert_eq!(
                    words, 0,
                    "naive/im2col choices carry no engine traffic"
                ),
            }
        }
        tuner.save(&path).expect("save sidecar");

        // same config: choices come back without re-probing
        let warm = Autotuner::new(4096.0);
        assert_eq!(warm.warm_start(&path).expect("warm start"), 2);
        assert_eq!(warm.select(&a), ka);
        assert_eq!(warm.select(&b), kb);
        assert_eq!(warm.tuned(), tuner.tuned());

        // different memory budget: the sidecar answers a different
        // planning question and must be ignored
        let other = Autotuner::new(8192.0);
        assert_eq!(other.warm_start(&path).expect("stale ok"), 0);
        assert!(other.tuned().is_empty());

        // different precision: ignored too
        let mixed = Autotuner::with_precision(4096.0, Precision::paper_mixed());
        assert_eq!(mixed.warm_start(&path).expect("stale ok"), 0);

        // missing file is not an error; garbage is
        let _ = std::fs::remove_file(&path);
        assert_eq!(tuner.warm_start(&path).expect("missing ok"), 0);
        std::fs::write(&path, "not json").unwrap();
        assert!(tuner.warm_start(&path).is_err());
        // structurally valid JSON with a non-integer shape dim is rejected,
        // not coerced into a phantom shape
        std::fs::write(
            &path,
            r#"{"mem_words":4096,"precision":[1,1,1],"entries":
               [{"shape":[2.5,3,4,6,6,3,3,1,1],"kernel":"tiled","traffic_words":1}]}"#,
        )
        .unwrap();
        assert!(tuner.warm_start(&path).is_err());
        // a rejected sidecar must not have half-applied: cache unchanged
        assert_eq!(tuner.tuned().len(), 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn per_pass_selection_caches_independently_and_matches_oracles() {
        let tuner = Autotuner::new(4096.0);
        let s = ConvShape::new(2, 3, 4, 6, 6, 3, 3, 1, 1);
        let kf = tuner.select_pass(ConvPass::Forward, &s);
        let kd = tuner.select_pass(ConvPass::DFilter, &s);
        let ki = tuner.select_pass(ConvPass::DInput, &s);
        // cached per (pass, shape): three independent records
        assert_eq!(tuner.tuned().len(), 3);
        assert_eq!(tuner.select_pass(ConvPass::DFilter, &s), kd);
        assert_eq!(tuner.select(&s), kf);
        // gradient probes never pick im2col (no such lowering)
        assert_ne!(kd, KernelKind::Im2col);
        assert_ne!(ki, KernelKind::Im2col);
        // tuned execution agrees with the oracles (bitwise when tiled won)
        for pass in [ConvPass::DFilter, ConvPass::DInput] {
            let (a, b) = pass_operands(pass, &s, 3);
            let k = tuner.select_pass(pass, &s);
            let got = tuner.run_pass_kernel(pass, k, &a, &b, &s);
            let want = pass.naive_oracle(&a, &b, &s);
            assert_eq!(got.max_abs_diff(&want), 0.0, "{}", pass.name());
        }
    }

    #[test]
    fn sidecar_is_pass_keyed_and_forward_compatible() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!(
            "convbound_autotune_pass_{}_{:?}.json",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_file(&path);

        let tuner = Autotuner::new(4096.0);
        let s = ConvShape::new(2, 3, 4, 6, 6, 3, 3, 1, 1);
        let kf = tuner.select_pass(ConvPass::Forward, &s);
        let kd = tuner.select_pass(ConvPass::DFilter, &s);
        let ki = tuner.select_pass(ConvPass::DInput, &s);
        tuner.save(&path).expect("save sidecar");
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"version\":2"), "{text}");
        assert!(text.contains("\"pass\":\"dfilter\""), "{text}");
        // `entries` must stay forward-only (the exact v1 schema a PR 3/4
        // binary reads): gradient records live under `pass_entries`, so an
        // old binary can never have its per-shape forward choice silently
        // overwritten by a same-shape dfilter/dinput record
        let doc = Json::parse(&text).unwrap();
        let fwd_entries = doc.get("entries").as_arr().unwrap();
        assert_eq!(fwd_entries.len(), 1, "{text}");
        assert_eq!(fwd_entries[0].get("pass").as_str(), Some("fwd"));
        assert_eq!(doc.get("pass_entries").as_arr().unwrap().len(), 2);

        // pass-keyed roundtrip
        let warm = Autotuner::new(4096.0);
        assert_eq!(warm.warm_start(&path).expect("warm start"), 3);
        assert_eq!(warm.tuned(), tuner.tuned());
        assert_eq!(warm.select_pass(ConvPass::Forward, &s), kf);
        assert_eq!(warm.select_pass(ConvPass::DFilter, &s), kd);
        assert_eq!(warm.select_pass(ConvPass::DInput, &s), ki);

        // a v1 sidecar (PR 3/4 binary: no version, no pass) loads as
        // forward choices, and unknown keys anywhere are ignored
        std::fs::write(
            &path,
            r#"{"mem_words":4096,"precision":[1,1,1],"surprise":true,
               "entries":[{"shape":[2,3,4,6,6,3,3,1,1],"kernel":"tiled",
                           "traffic_words":7,"note":"from the past"}]}"#,
        )
        .unwrap();
        let v1 = Autotuner::new(4096.0);
        assert_eq!(v1.warm_start(&path).expect("v1 loads"), 1);
        assert_eq!(v1.select_pass(ConvPass::Forward, &s), KernelKind::Tiled);
        assert_eq!(v1.tuned()[0].0, ConvPass::Forward);

        // records from a NEWER binary: an unknown pass or kernel skips
        // that entry only; a whole-file version from the future is
        // ignored wholesale. Either way: no error, no half-trusted cache.
        std::fs::write(
            &path,
            r#"{"version":2,"mem_words":4096,"precision":[1,1,1],
               "entries":[
                 {"pass":"dweight","shape":[2,3,4,6,6,3,3,1,1],
                  "kernel":"tiled","traffic_words":1},
                 {"pass":"dfilter","shape":[2,3,4,6,6,3,3,1,1],
                  "kernel":"fft","traffic_words":1},
                 {"pass":"dfilter","shape":[2,3,4,6,6,3,3,1,1],
                  "kernel":"naive","traffic_words":0}]}"#,
        )
        .unwrap();
        let fresh = Autotuner::new(4096.0);
        assert_eq!(fresh.warm_start(&path).expect("skips unknowns"), 1);
        assert_eq!(fresh.select_pass(ConvPass::DFilter, &s), KernelKind::Naive);
        std::fs::write(
            &path,
            r#"{"version":99,"mem_words":4096,"precision":[1,1,1],
               "entries":[{"pass":"fwd","shape":[2,3,4,6,6,3,3,1,1],
                           "kernel":"tiled","traffic_words":1}]}"#,
        )
        .unwrap();
        let future = Autotuner::new(4096.0);
        assert_eq!(future.warm_start(&path).expect("future ignored"), 0);
        assert!(future.tuned().is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn kernel_kind_names_roundtrip() {
        for k in KernelKind::ALL {
            assert_eq!(KernelKind::parse(k.name()), Some(k));
        }
        assert_eq!(KernelKind::parse("auto"), None);
    }

    #[test]
    fn net_kernel_kind_names_roundtrip() {
        for k in NetKernelKind::ALL {
            assert_eq!(NetKernelKind::parse(k.name()), Some(k));
        }
        assert_eq!(NetKernelKind::parse("auto"), None);
    }

    #[test]
    fn lp_pruning_skips_hopeless_probes_but_not_the_heuristic() {
        let tuner = Autotuner::new(65536.0);
        // the patch matrix makes im2col's analytic traffic hopeless here:
        // > 4x the compulsory floor, and the heuristic picks tiled
        let s = ConvShape::new(2, 16, 16, 16, 16, 5, 5, 1, 1);
        let naive = tuner.analytic_kernel_traffic(
            ConvPass::Forward,
            KernelKind::Naive,
            &s,
        );
        let im2col = tuner.analytic_kernel_traffic(
            ConvPass::Forward,
            KernelKind::Im2col,
            &s,
        );
        assert_ne!(Autotuner::heuristic(&s), KernelKind::Im2col);
        assert!(
            im2col > PRUNE_TRAFFIC_RATIO * naive,
            "{im2col} vs floor {naive}"
        );
        let k = tuner.select(&s);
        assert_ne!(k, KernelKind::Im2col, "pruned candidates cannot win");
        assert!(tuner.pruned_probes() >= 1, "the im2col probe was pruned");
        // pruning disabled: every candidate is timed, nothing is counted
        let mut full = Autotuner::new(65536.0);
        full.prune_probes = false;
        let _ = full.select(&s);
        assert_eq!(full.pruned_probes(), 0);
    }

    #[test]
    fn lp_pruning_never_changes_builtin_selection() {
        use crate::runtime::manifest::NetworkSpec;
        // Pruning preserves selection iff the unpruned winner survives the
        // analytic cut: timing is noisy across runs, so the test asserts
        // winner-survival (deterministic given the winner) rather than
        // equality of two independently timed selections.
        let mut full = Autotuner::new(65536.0);
        full.prune_probes = false;
        let catalog: Vec<NetworkStage> = NetworkSpec::tiny_resnet(2)
            .stages
            .into_iter()
            .chain(NetworkSpec::deep_mixnet(2).stages)
            .collect();
        for st in &catalog {
            for pass in [ConvPass::Forward, ConvPass::DFilter, ConvPass::DInput]
            {
                let winner = full.select_pass(pass, &st.shape);
                let floor = Autotuner::pass_kernels(pass)
                    .iter()
                    .map(|&k| full.analytic_kernel_traffic(pass, k, &st.shape))
                    .fold(f64::INFINITY, f64::min);
                let w = full.analytic_kernel_traffic(pass, winner, &st.shape);
                assert!(
                    winner == Autotuner::heuristic_pass(pass, &st.shape)
                        || w <= PRUNE_TRAFFIC_RATIO * floor,
                    "{} {:?}: winner {:?} would be pruned",
                    pass.name(),
                    st.shape,
                    winner
                );
            }
        }
        // network-mode probes: same invariant on the acceptance network
        let net = NetworkSpec::tiny_resnet(2);
        for pass in NetPass::ALL {
            let winner =
                full.select_network_pass(pass, "tiny_resnet", &net.stages);
            let words = |kind| {
                full.network_pass_plan(pass, &net.stages, kind, true, false)
                    .expected_network_traffic()
                    .iter()
                    .map(|t| t.total())
                    .sum::<u64>() as f64
            };
            let floor = Autotuner::net_pass_modes(pass)
                .iter()
                .map(|&kind| words(kind))
                .fold(f64::INFINITY, f64::min);
            assert!(
                winner == full.heuristic_network_pass(pass, &net.stages)
                    || words(winner) <= PRUNE_TRAFFIC_RATIO * floor,
                "{}: network winner {:?} would be pruned",
                pass.name(),
                winner
            );
        }
        assert_eq!(full.pruned_probes(), 0, "pruning was off the whole time");
    }

    #[test]
    fn network_pass_choices_roundtrip_under_their_own_key() {
        let tuner = Autotuner::new(65536.0);
        let net = crate::runtime::manifest::NetworkSpec::tiny_resnet(2);
        let kf =
            tuner.select_network_pass(NetPass::Forward, "tiny_resnet", &net.stages);
        let kb = tuner.select_network_pass(
            NetPass::Backward,
            "tiny_resnet",
            &net.stages,
        );
        let ks =
            tuner.select_network_pass(NetPass::Step, "tiny_resnet", &net.stages);
        assert_eq!(tuner.tuned_networks().len(), 3);
        // gradient sweeps never offer the packed/reference switch
        assert_ne!(kb, NetKernelKind::FusedReference);
        assert_ne!(ks, NetKernelKind::FusedReference);

        let dir = std::env::temp_dir();
        let path = dir.join(format!(
            "convbound_autotune_netpass_{}_{:?}.json",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_file(&path);
        tuner.save(&path).expect("save sidecar");
        let text = std::fs::read_to_string(&path).unwrap();
        // forward stays in the pass-less v1 `networks` list; the gradient
        // records carry a pass field under `pass_networks`
        let doc = Json::parse(&text).unwrap();
        assert_eq!(doc.get("networks").as_arr().unwrap().len(), 1, "{text}");
        assert_eq!(doc.get("pass_networks").as_arr().unwrap().len(), 2);
        assert!(text.contains("\"pass\":\"bwd\""), "{text}");
        assert!(text.contains("\"pass\":\"step\""), "{text}");

        let warm = Autotuner::new(65536.0);
        assert_eq!(warm.warm_start(&path).expect("warm start"), 3);
        assert_eq!(warm.tuned_networks(), tuner.tuned_networks());
        assert_eq!(
            warm.select_network_pass(NetPass::Forward, "tiny_resnet", &net.stages),
            kf
        );
        assert_eq!(
            warm.select_network_pass(NetPass::Backward, "tiny_resnet", &net.stages),
            kb
        );
        assert_eq!(
            warm.select_network_pass(NetPass::Step, "tiny_resnet", &net.stages),
            ks
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn network_selection_caches_runs_and_roundtrips() {
        let tuner = Autotuner::new(65536.0);
        let net = crate::runtime::manifest::NetworkSpec::tiny_resnet(2);
        let k1 = tuner.select_network("tiny_resnet", &net.stages);
        assert_eq!(tuner.select_network("tiny_resnet", &net.stages), k1);
        assert_eq!(tuner.tuned_networks().len(), 1);
        // execution under the tuned mode agrees with the staged oracle
        let image = Tensor4::randn(net.input_dims(), 31);
        let filters: Vec<Tensor4> = net
            .stages
            .iter()
            .enumerate()
            .map(|(i, st)| Tensor4::randn(st.shape.filter_dims(), 32 + i as u64))
            .collect();
        let frefs: Vec<&Tensor4> = filters.iter().collect();
        let got = tuner.run_network(&image, &frefs, "tiny_resnet", &net.stages);
        let want = super::super::fuse::naive_network(&image, &frefs, &net.stages);
        assert!(got.rel_l2(&want) < 1e-4, "rel {}", got.rel_l2(&want));

        // sidecar roundtrip keyed to (network, M, precision)
        let dir = std::env::temp_dir();
        let path = dir.join(format!(
            "convbound_autotune_net_{}_{:?}.json",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_file(&path);
        tuner.save(&path).expect("save sidecar");
        let warm = Autotuner::new(65536.0);
        assert_eq!(warm.warm_start(&path).expect("warm start"), 1);
        assert_eq!(warm.tuned_networks(), tuner.tuned_networks());
        assert_eq!(warm.select_network("tiny_resnet", &net.stages), k1);
        // a different memory budget answers a different planning question
        let other = Autotuner::new(4096.0);
        assert_eq!(other.warm_start(&path).expect("stale ok"), 0);
        assert!(other.tuned_networks().is_empty());
        // an unknown network mode is a record from a newer binary: the
        // entry is skipped (forward compat), while a missing stage
        // fingerprint on a known mode is still structural corruption
        std::fs::write(
            &path,
            r#"{"mem_words":65536,"precision":[1,1,1],"entries":[],
               "networks":[{"name":"x","batch":2,"stages":"0f",
                            "kernel":"winograd"}]}"#,
        )
        .unwrap();
        assert_eq!(warm.warm_start(&path).expect("unknown mode skipped"), 0);
        std::fs::write(
            &path,
            r#"{"mem_words":65536,"precision":[1,1,1],"entries":[],
               "networks":[{"name":"x","batch":2,"kernel":"materialized"}]}"#,
        )
        .unwrap();
        assert!(warm.warm_start(&path).is_err());
        let _ = std::fs::remove_file(&path);

        // same name and batch but a *different* chain must re-probe, not
        // reuse the cached mode — the stage-fingerprint staleness guard
        let mut altered = net.stages.clone();
        altered[0].shape.c_i += 1;
        let _ = tuner.select_network("tiny_resnet", &altered);
        assert_eq!(tuner.tuned_networks().len(), 2);
    }
}
