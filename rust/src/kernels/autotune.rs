//! Kernel selection: a heuristic pre-filter plus a measure-once autotuner
//! choosing between the naive loop nest, im2col+GEMM and the LP-tiled
//! engine per [`ConvShape`].
//!
//! Policy (see DESIGN.md §6):
//!
//! * **heuristic** — tiny problems stay on the naive nest (tile/pack setup
//!   cannot amortize); thin reductions (`cI·wF·hF` small) favor im2col
//!   (the patch matrix is cheap and the GEMM is wide); everything else
//!   goes tiled.
//! * **measured** — `select` times each kernel once on a batch-clamped
//!   probe of the shape and caches the winner. Probes above a MAC budget
//!   skip measurement and trust the heuristic, so selection never costs
//!   more than a couple of probe convolutions.
//! * **persistence** — [`Autotuner::save`] writes the cached choices (and
//!   the tiled-engine word traffic of each shape, which the counters
//!   measure exactly equal to [`super::exec::expected_traffic`]) to a JSON
//!   sidecar; [`Autotuner::warm_start`] reloads them on the next process
//!   start so servers skip the probe convolutions entirely. A sidecar
//!   written under a different memory budget or precision is ignored —
//!   its choices answered a different planning question.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::conv::{conv7nl_naive, ConvShape, NetworkStage, Precision, Tensor4};
use crate::err;
use crate::util::error::{Context, Result};
use crate::util::json::Json;

use super::exec::{
    conv_network_fused_counted, conv_tiled, expected_traffic, NetTrafficCounters,
};
use super::fuse::{FusePlan, FusedExec};
use super::im2col::conv_im2col;
use super::plan::{TilePlan, TilePlanCache};

/// The three executable kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelKind {
    Naive,
    Im2col,
    Tiled,
}

impl KernelKind {
    pub const ALL: [KernelKind; 3] =
        [KernelKind::Naive, KernelKind::Im2col, KernelKind::Tiled];

    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Naive => "naive",
            KernelKind::Im2col => "im2col",
            KernelKind::Tiled => "tiled",
        }
    }

    pub fn parse(s: &str) -> Option<KernelKind> {
        match s {
            "naive" => Some(KernelKind::Naive),
            "im2col" => Some(KernelKind::Im2col),
            "tiled" => Some(KernelKind::Tiled),
            _ => None,
        }
    }
}

/// The three ways to execute a whole network pipeline — the candidate
/// fusion groupings the tuner probes the way it probes kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NetKernelKind {
    /// fused groups through the packed LP microkernel (the default)
    FusedPacked,
    /// fused groups through the patch-local naive reference nest
    FusedReference,
    /// every stage materialized through the LP-tiled engine
    Materialized,
}

impl NetKernelKind {
    pub const ALL: [NetKernelKind; 3] = [
        NetKernelKind::FusedPacked,
        NetKernelKind::FusedReference,
        NetKernelKind::Materialized,
    ];

    pub fn name(self) -> &'static str {
        match self {
            NetKernelKind::FusedPacked => "fused_packed",
            NetKernelKind::FusedReference => "fused_reference",
            NetKernelKind::Materialized => "materialized",
        }
    }

    pub fn parse(s: &str) -> Option<NetKernelKind> {
        match s {
            "fused_packed" => Some(NetKernelKind::FusedPacked),
            "fused_reference" => Some(NetKernelKind::FusedReference),
            "materialized" => Some(NetKernelKind::Materialized),
            _ => None,
        }
    }
}

/// Probes above this many MACs trust the heuristic instead of measuring.
const MEASURE_BUDGET_MACS: u64 = 200_000_000;

/// One cached selection: the winning kernel plus the word traffic the
/// tiled engine charges for the full shape (its counters match the
/// analytic model exactly, so this *is* the measured tiled traffic).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Tuned {
    kernel: KernelKind,
    traffic_words: u64,
}

/// Per-shape kernel chooser (and per-network mode chooser) with a shared
/// plan cache.
pub struct Autotuner {
    pub mem_words: f64,
    /// word model the tile plans are solved under (f32 uniform by default;
    /// probing and execution always use the same plan either way)
    pub precision: Precision,
    plans: TilePlanCache,
    choices: Mutex<HashMap<ConvShape, Tuned>>,
    /// per-network execution-mode choices, keyed on (name, batch, stage
    /// fingerprint) — the fingerprint guards against a renamed-in-place
    /// chain reusing a stale choice, the way `choices` keys on the full
    /// [`ConvShape`]; the sidecar persists them next to the kernel
    /// choices, under the same (M, precision) staleness rule
    net_choices: Mutex<HashMap<(String, u64, u64), NetKernelKind>>,
}

/// Deterministic fingerprint of a stage chain (shapes and precision bit
/// patterns, FNV-folded — stable across processes and toolchains): the
/// staleness guard that keeps a cached or persisted network choice from
/// answering for a *different* chain that shares its name and batch.
fn stages_fingerprint(stages: &[NetworkStage]) -> u64 {
    let mut f: u64 = 0xcbf29ce484222325;
    let mut mix = |v: u64| {
        f ^= v;
        f = f.wrapping_mul(0x100000001b3);
    };
    mix(stages.len() as u64);
    for st in stages {
        let s = &st.shape;
        for d in [s.n, s.c_i, s.c_o, s.w_o, s.h_o, s.w_f, s.h_f, s.s_w, s.s_h] {
            mix(d);
        }
        mix(st.precision.p_i.to_bits());
        mix(st.precision.p_f.to_bits());
        mix(st.precision.p_o.to_bits());
    }
    drop(mix);
    f
}

impl Autotuner {
    pub fn new(mem_words: f64) -> Autotuner {
        Autotuner::with_precision(mem_words, Precision::uniform())
    }

    pub fn with_precision(mem_words: f64, precision: Precision) -> Autotuner {
        Autotuner {
            mem_words,
            precision,
            plans: TilePlanCache::new(),
            choices: Mutex::new(HashMap::new()),
            net_choices: Mutex::new(HashMap::new()),
        }
    }

    /// The (cached) tile plan this tuner would execute `s` with.
    pub fn plan(&self, s: &ConvShape) -> Arc<TilePlan> {
        self.plans.plan(s, self.precision, self.mem_words)
    }

    /// Zero-cost selection from shape structure alone.
    pub fn heuristic(s: &ConvShape) -> KernelKind {
        if s.updates() < (1 << 16) {
            return KernelKind::Naive;
        }
        if s.c_i * s.w_f * s.h_f < 16 {
            return KernelKind::Im2col;
        }
        KernelKind::Tiled
    }

    /// Measure-once selection: time all three kernels on a batch-clamped
    /// probe of `s`, cache and return the fastest. Falls back to
    /// [`Autotuner::heuristic`] when even the probe would be too large.
    pub fn select(&self, s: &ConvShape) -> KernelKind {
        if let Some(t) = self.choices.lock().expect("choices poisoned").get(s) {
            return t.kernel;
        }
        let probe = s.with_batch(s.n.min(2));
        let kernel = if probe.updates() > MEASURE_BUDGET_MACS {
            Autotuner::heuristic(s)
        } else {
            self.measure(&probe)
        };
        // tiled traffic is only meaningful (and its plan only needed) when
        // the tiled engine won — the heuristic early-out stays LP-free
        let traffic_words = if kernel == KernelKind::Tiled {
            expected_traffic(&self.plan(s)).total()
        } else {
            0
        };
        self.choices
            .lock()
            .expect("choices poisoned")
            .insert(*s, Tuned { kernel, traffic_words });
        kernel
    }

    /// The fusion plan this tuner would execute `stages` with under a
    /// given network mode (tile plans come from the shared cache). The
    /// halo flag feeds the *planner* too — fusion decisions and tile
    /// fitting must use the model the run will execute under, or the
    /// `fused ≤ unfused` rule silently evaluates the wrong traffic.
    /// Ignored by `Materialized` (nothing fuses, nothing carries).
    pub fn network_plan(
        &self,
        stages: &[NetworkStage],
        kind: NetKernelKind,
        halo_cache: bool,
    ) -> FusePlan {
        match kind {
            NetKernelKind::FusedPacked => FusePlan::with_options(
                stages,
                self.mem_words,
                &self.plans,
                FusedExec::Packed,
                halo_cache,
            ),
            NetKernelKind::FusedReference => FusePlan::with_options(
                stages,
                self.mem_words,
                &self.plans,
                FusedExec::Reference,
                halo_cache,
            ),
            NetKernelKind::Materialized => {
                FusePlan::materialized(stages, self.mem_words, &self.plans)
            }
        }
    }

    /// Zero-cost network selection from plan structure alone: fuse
    /// (packed) when the planner fuses any boundary at this tuner's
    /// budget, else materialize.
    pub fn heuristic_network(&self, stages: &[NetworkStage]) -> NetKernelKind {
        let plan = FusePlan::new(stages, self.mem_words, &self.plans);
        if plan.fused_boundaries() > 0 {
            NetKernelKind::FusedPacked
        } else {
            NetKernelKind::Materialized
        }
    }

    /// Measure-once network-mode selection: time the three execution modes
    /// (fused-packed, fused-naive, materialized) on a batch-clamped probe
    /// of the chain, cache and return the fastest, keyed on
    /// `(name, batch, stage fingerprint)`. Falls back to
    /// [`Autotuner::heuristic_network`] when even the probe would exceed
    /// the MAC budget.
    pub fn select_network(&self, name: &str, stages: &[NetworkStage]) -> NetKernelKind {
        assert!(!stages.is_empty(), "empty network");
        let key = (name.to_string(), stages[0].shape.n, stages_fingerprint(stages));
        if let Some(k) = self
            .net_choices
            .lock()
            .expect("net choices poisoned")
            .get(&key)
        {
            return *k;
        }
        let probe: Vec<NetworkStage> = stages
            .iter()
            .map(|st| NetworkStage {
                shape: st.shape.with_batch(st.shape.n.min(2)),
                precision: st.precision,
            })
            .collect();
        let macs: u64 = probe.iter().map(|st| st.shape.updates()).sum();
        let kind = if macs > MEASURE_BUDGET_MACS {
            self.heuristic_network(stages)
        } else {
            self.measure_network(&probe)
        };
        self.net_choices
            .lock()
            .expect("net choices poisoned")
            .insert(key, kind);
        kind
    }

    fn measure_network(&self, stages: &[NetworkStage]) -> NetKernelKind {
        let head = &stages[0].shape;
        let image = Tensor4::randn(
            [
                head.n as usize,
                head.c_i as usize,
                head.in_w() as usize,
                head.in_h() as usize,
            ],
            1,
        );
        let filters: Vec<Tensor4> = stages
            .iter()
            .enumerate()
            .map(|(i, st)| Tensor4::randn(st.shape.filter_dims(), 2 + i as u64))
            .collect();
        let frefs: Vec<&Tensor4> = filters.iter().collect();
        let mut best = (NetKernelKind::FusedPacked, f64::INFINITY);
        for kind in NetKernelKind::ALL {
            let plan = self.network_plan(stages, kind, true);
            let counters = NetTrafficCounters::new(stages.len());
            let t0 = Instant::now();
            std::hint::black_box(conv_network_fused_counted(
                &image, &frefs, &plan, &counters,
            ));
            let secs = t0.elapsed().as_secs_f64();
            if secs < best.1 {
                best = (kind, secs);
            }
        }
        best.0
    }

    /// Execute a whole network (serially) under the autotuned mode.
    pub fn run_network(
        &self,
        image: &Tensor4,
        filters: &[&Tensor4],
        name: &str,
        stages: &[NetworkStage],
    ) -> Tensor4 {
        let kind = self.select_network(name, stages);
        let plan = self.network_plan(stages, kind, true);
        let counters = NetTrafficCounters::new(stages.len());
        conv_network_fused_counted(image, filters, &plan, &counters)
    }

    /// Every cached network choice with its full key, sorted for stable
    /// sidecar files.
    fn tuned_networks_raw(&self) -> Vec<((String, u64, u64), NetKernelKind)> {
        let mut out: Vec<((String, u64, u64), NetKernelKind)> = self
            .net_choices
            .lock()
            .expect("net choices poisoned")
            .iter()
            .map(|(key, k)| (key.clone(), *k))
            .collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// Every cached `(network, batch, mode)` triple, in a deterministic
    /// order (for reports and tests).
    pub fn tuned_networks(&self) -> Vec<(String, u64, NetKernelKind)> {
        self.tuned_networks_raw()
            .into_iter()
            .map(|((n, b, _), k)| (n, b, k))
            .collect()
    }

    /// Every cached `(shape, kernel, tiled traffic words)` triple, in a
    /// deterministic order (for stable sidecar files and reports).
    pub fn tuned(&self) -> Vec<(ConvShape, KernelKind, u64)> {
        let mut out: Vec<(ConvShape, KernelKind, u64)> = self
            .choices
            .lock()
            .expect("choices poisoned")
            .iter()
            .map(|(s, t)| (*s, t.kernel, t.traffic_words))
            .collect();
        out.sort_by_key(|(s, _, _)| {
            [s.n, s.c_i, s.c_o, s.w_o, s.h_o, s.w_f, s.h_f, s.s_w, s.s_h]
        });
        out
    }

    /// Persist the cached kernel choices (and their tiled traffic) to a
    /// JSON sidecar, together with the `(M, precision)` configuration they
    /// were selected under.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut doc = std::collections::BTreeMap::new();
        doc.insert("mem_words".to_string(), Json::Num(self.mem_words));
        doc.insert(
            "precision".to_string(),
            Json::Arr(vec![
                Json::Num(self.precision.p_i),
                Json::Num(self.precision.p_f),
                Json::Num(self.precision.p_o),
            ]),
        );
        let entries: Vec<Json> = self
            .tuned()
            .into_iter()
            .map(|(s, k, words)| {
                let mut e = std::collections::BTreeMap::new();
                e.insert(
                    "shape".to_string(),
                    Json::Arr(
                        [s.n, s.c_i, s.c_o, s.w_o, s.h_o, s.w_f, s.h_f, s.s_w, s.s_h]
                            .iter()
                            .map(|&d| Json::Num(d as f64))
                            .collect(),
                    ),
                );
                e.insert("kernel".to_string(), Json::Str(k.name().to_string()));
                e.insert("traffic_words".to_string(), Json::Num(words as f64));
                Json::Obj(e)
            })
            .collect();
        doc.insert("entries".to_string(), Json::Arr(entries));
        let networks: Vec<Json> = self
            .tuned_networks_raw()
            .into_iter()
            .map(|((name, batch, fp), k)| {
                let mut e = std::collections::BTreeMap::new();
                e.insert("name".to_string(), Json::Str(name));
                e.insert("batch".to_string(), Json::Num(batch as f64));
                e.insert("stages".to_string(), Json::Str(format!("{fp:016x}")));
                e.insert("kernel".to_string(), Json::Str(k.name().to_string()));
                Json::Obj(e)
            })
            .collect();
        doc.insert("networks".to_string(), Json::Arr(networks));
        let path = path.as_ref();
        std::fs::write(path, format!("{}\n", Json::Obj(doc)))
            .with_context(|| format!("writing autotune sidecar {}", path.display()))
    }

    /// Warm-start the choice cache from a sidecar written by a previous
    /// process. Returns the number of choices loaded: `0` when the file
    /// does not exist or was written under a different `(M, precision)`
    /// configuration (stale sidecars are ignored, not trusted). Malformed
    /// files are an error.
    pub fn warm_start(&self, path: impl AsRef<Path>) -> Result<usize> {
        let path = path.as_ref();
        if !path.exists() {
            return Ok(0);
        }
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading autotune sidecar {}", path.display()))?;
        let v = Json::parse(&text)
            .map_err(|e| err!("autotune sidecar {}: {e}", path.display()))?;
        if v.get("mem_words").as_f64() != Some(self.mem_words) {
            return Ok(0);
        }
        let p = v.get("precision").as_arr().unwrap_or(&[]);
        if p.len() != 3
            || p[0].as_f64() != Some(self.precision.p_i)
            || p[1].as_f64() != Some(self.precision.p_f)
            || p[2].as_f64() != Some(self.precision.p_o)
        {
            return Ok(0);
        }
        // parse everything before touching the live cache: a malformed
        // sidecar must be rejected whole, not half-applied
        let mut entries = Vec::new();
        for e in v.get("entries").as_arr().unwrap_or(&[]) {
            let dims = e
                .get("shape")
                .as_arr()
                .ok_or_else(|| err!("sidecar entry missing 'shape'"))?;
            if dims.len() != 9 {
                return Err(err!("sidecar shape wants 9 dims, got {}", dims.len()));
            }
            let d: Vec<u64> = dims
                .iter()
                .map(|x| {
                    x.as_u64_strict().ok_or_else(|| {
                        err!("sidecar shape dim '{x}' is not an integer")
                    })
                })
                .collect::<Result<_>>()?;
            let shape = ConvShape::new(
                d[0], d[1], d[2], d[3], d[4], d[5], d[6], d[7], d[8],
            );
            let kernel = e
                .get("kernel")
                .as_str()
                .and_then(KernelKind::parse)
                .ok_or_else(|| err!("sidecar entry has an unknown kernel"))?;
            let traffic_words =
                e.get("traffic_words").as_u64_strict().ok_or_else(|| {
                    err!("sidecar entry has a malformed 'traffic_words'")
                })?;
            entries.push((shape, Tuned { kernel, traffic_words }));
        }
        let mut networks = Vec::new();
        for e in v.get("networks").as_arr().unwrap_or(&[]) {
            let name = e
                .get("name")
                .as_str()
                .ok_or_else(|| err!("sidecar network entry missing 'name'"))?
                .to_string();
            let batch = e.get("batch").as_u64_strict().ok_or_else(|| {
                err!("sidecar network entry has a malformed 'batch'")
            })?;
            let fp = e
                .get("stages")
                .as_str()
                .and_then(|s| u64::from_str_radix(s, 16).ok())
                .ok_or_else(|| {
                    err!(
                        "sidecar network entry has a malformed 'stages' \
                         fingerprint"
                    )
                })?;
            let kernel = e
                .get("kernel")
                .as_str()
                .and_then(NetKernelKind::parse)
                .ok_or_else(|| {
                    err!("sidecar network entry has an unknown kernel")
                })?;
            networks.push(((name, batch, fp), kernel));
        }
        let loaded = entries.len() + networks.len();
        {
            let mut choices = self.choices.lock().expect("choices poisoned");
            for (shape, tuned) in entries {
                choices.insert(shape, tuned);
            }
        }
        {
            let mut nets = self.net_choices.lock().expect("net choices poisoned");
            for (key, kind) in networks {
                nets.insert(key, kind);
            }
        }
        Ok(loaded)
    }

    fn measure(&self, s: &ConvShape) -> KernelKind {
        let (x, w) = crate::conv::paper_operands(s, 1);
        // solve (and cache) the blocking LP outside the timed region: the
        // probe compares steady-state kernels, and the plan is a one-time
        // per-shape cost every later tiled run reuses
        let _ = self.plan(s);
        let mut best = (KernelKind::Naive, f64::INFINITY);
        for k in KernelKind::ALL {
            let t0 = Instant::now();
            std::hint::black_box(self.run_kernel(k, &x, &w, s));
            let secs = t0.elapsed().as_secs_f64();
            if secs < best.1 {
                best = (k, secs);
            }
        }
        best.0
    }

    /// Execute `s` with an explicit kernel.
    pub fn run_kernel(
        &self,
        k: KernelKind,
        x: &Tensor4,
        w: &Tensor4,
        s: &ConvShape,
    ) -> Tensor4 {
        match k {
            KernelKind::Naive => conv7nl_naive(x, w, s),
            KernelKind::Im2col => conv_im2col(x, w, s),
            KernelKind::Tiled => conv_tiled(x, w, &self.plan(s)),
        }
    }

    /// Execute `s` with the autotuned kernel.
    pub fn run(&self, x: &Tensor4, w: &Tensor4, s: &ConvShape) -> Tensor4 {
        let k = self.select(s);
        self.run_kernel(k, x, w, s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heuristic_tiers() {
        // tiny -> naive
        let tiny = ConvShape::new(1, 2, 2, 4, 4, 3, 3, 1, 1);
        assert_eq!(Autotuner::heuristic(&tiny), KernelKind::Naive);
        // big but thin reduction (1x1 filter, few channels) -> im2col
        let thin = ConvShape::new(64, 4, 64, 32, 32, 1, 1, 1, 1);
        assert!(thin.updates() >= (1 << 16));
        assert_eq!(Autotuner::heuristic(&thin), KernelKind::Im2col);
        // big with fat reduction -> tiled
        let fat = ConvShape::new(4, 64, 64, 14, 14, 3, 3, 1, 1);
        assert_eq!(Autotuner::heuristic(&fat), KernelKind::Tiled);
    }

    #[test]
    fn select_caches_and_run_matches_naive() {
        let tuner = Autotuner::new(4096.0);
        let s = ConvShape::new(2, 3, 4, 6, 6, 3, 3, 1, 1);
        let k1 = tuner.select(&s);
        let k2 = tuner.select(&s);
        assert_eq!(k1, k2);
        let (x, w) = crate::conv::paper_operands(&s, 5);
        let got = tuner.run(&x, &w, &s);
        let want = conv7nl_naive(&x, &w, &s);
        assert!(got.rel_l2(&want) < 1e-4, "rel {}", got.rel_l2(&want));
    }

    #[test]
    fn sidecar_roundtrips_and_rejects_stale_configs() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!(
            "convbound_autotune_{}_{:?}.json",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_file(&path);

        let tuner = Autotuner::new(4096.0);
        let a = ConvShape::new(2, 3, 4, 6, 6, 3, 3, 1, 1);
        let b = ConvShape::new(1, 2, 3, 4, 4, 3, 3, 2, 2);
        let ka = tuner.select(&a);
        let kb = tuner.select(&b);
        assert_eq!(tuner.tuned().len(), 2);
        for (_, k, words) in tuner.tuned() {
            if k == KernelKind::Tiled {
                assert!(words > 0, "tiled choices record their traffic");
            } else {
                assert_eq!(words, 0, "non-tiled choices carry no tiled traffic");
            }
        }
        tuner.save(&path).expect("save sidecar");

        // same config: choices come back without re-probing
        let warm = Autotuner::new(4096.0);
        assert_eq!(warm.warm_start(&path).expect("warm start"), 2);
        assert_eq!(warm.select(&a), ka);
        assert_eq!(warm.select(&b), kb);
        assert_eq!(warm.tuned(), tuner.tuned());

        // different memory budget: the sidecar answers a different
        // planning question and must be ignored
        let other = Autotuner::new(8192.0);
        assert_eq!(other.warm_start(&path).expect("stale ok"), 0);
        assert!(other.tuned().is_empty());

        // different precision: ignored too
        let mixed = Autotuner::with_precision(4096.0, Precision::paper_mixed());
        assert_eq!(mixed.warm_start(&path).expect("stale ok"), 0);

        // missing file is not an error; garbage is
        let _ = std::fs::remove_file(&path);
        assert_eq!(tuner.warm_start(&path).expect("missing ok"), 0);
        std::fs::write(&path, "not json").unwrap();
        assert!(tuner.warm_start(&path).is_err());
        // structurally valid JSON with a non-integer shape dim is rejected,
        // not coerced into a phantom shape
        std::fs::write(
            &path,
            r#"{"mem_words":4096,"precision":[1,1,1],"entries":
               [{"shape":[2.5,3,4,6,6,3,3,1,1],"kernel":"tiled","traffic_words":1}]}"#,
        )
        .unwrap();
        assert!(tuner.warm_start(&path).is_err());
        // a rejected sidecar must not have half-applied: cache unchanged
        assert_eq!(tuner.tuned().len(), 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn kernel_kind_names_roundtrip() {
        for k in KernelKind::ALL {
            assert_eq!(KernelKind::parse(k.name()), Some(k));
        }
        assert_eq!(KernelKind::parse("auto"), None);
    }

    #[test]
    fn net_kernel_kind_names_roundtrip() {
        for k in NetKernelKind::ALL {
            assert_eq!(NetKernelKind::parse(k.name()), Some(k));
        }
        assert_eq!(NetKernelKind::parse("auto"), None);
    }

    #[test]
    fn network_selection_caches_runs_and_roundtrips() {
        let tuner = Autotuner::new(65536.0);
        let net = crate::runtime::manifest::NetworkSpec::tiny_resnet(2);
        let k1 = tuner.select_network("tiny_resnet", &net.stages);
        assert_eq!(tuner.select_network("tiny_resnet", &net.stages), k1);
        assert_eq!(tuner.tuned_networks().len(), 1);
        // execution under the tuned mode agrees with the staged oracle
        let image = Tensor4::randn(net.input_dims(), 31);
        let filters: Vec<Tensor4> = net
            .stages
            .iter()
            .enumerate()
            .map(|(i, st)| Tensor4::randn(st.shape.filter_dims(), 32 + i as u64))
            .collect();
        let frefs: Vec<&Tensor4> = filters.iter().collect();
        let got = tuner.run_network(&image, &frefs, "tiny_resnet", &net.stages);
        let want = super::super::fuse::naive_network(&image, &frefs, &net.stages);
        assert!(got.rel_l2(&want) < 1e-4, "rel {}", got.rel_l2(&want));

        // sidecar roundtrip keyed to (network, M, precision)
        let dir = std::env::temp_dir();
        let path = dir.join(format!(
            "convbound_autotune_net_{}_{:?}.json",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_file(&path);
        tuner.save(&path).expect("save sidecar");
        let warm = Autotuner::new(65536.0);
        assert_eq!(warm.warm_start(&path).expect("warm start"), 1);
        assert_eq!(warm.tuned_networks(), tuner.tuned_networks());
        assert_eq!(warm.select_network("tiny_resnet", &net.stages), k1);
        // a different memory budget answers a different planning question
        let other = Autotuner::new(4096.0);
        assert_eq!(other.warm_start(&path).expect("stale ok"), 0);
        assert!(other.tuned_networks().is_empty());
        // an unknown network mode (or a missing stage fingerprint) is
        // rejected, not coerced
        std::fs::write(
            &path,
            r#"{"mem_words":65536,"precision":[1,1,1],"entries":[],
               "networks":[{"name":"x","batch":2,"kernel":"winograd"}]}"#,
        )
        .unwrap();
        assert!(warm.warm_start(&path).is_err());
        let _ = std::fs::remove_file(&path);

        // same name and batch but a *different* chain must re-probe, not
        // reuse the cached mode — the stage-fingerprint staleness guard
        let mut altered = net.stages.clone();
        altered[0].shape.c_i += 1;
        let _ = tuner.select_network("tiny_resnet", &altered);
        assert_eq!(tuner.tuned_networks().len(), 2);
    }
}
