//! Kernel selection: a heuristic pre-filter plus a measure-once autotuner
//! choosing between the naive loop nest, im2col+GEMM and the LP-tiled
//! engine per [`ConvShape`].
//!
//! Policy (see DESIGN.md §6):
//!
//! * **heuristic** — tiny problems stay on the naive nest (tile/pack setup
//!   cannot amortize); thin reductions (`cI·wF·hF` small) favor im2col
//!   (the patch matrix is cheap and the GEMM is wide); everything else
//!   goes tiled.
//! * **measured** — `select` times each kernel once on a batch-clamped
//!   probe of the shape and caches the winner. Probes above a MAC budget
//!   skip measurement and trust the heuristic, so selection never costs
//!   more than a couple of probe convolutions.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::conv::{conv7nl_naive, ConvShape, Precision, Tensor4};

use super::exec::conv_tiled;
use super::im2col::conv_im2col;
use super::plan::{TilePlan, TilePlanCache};

/// The three executable kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelKind {
    Naive,
    Im2col,
    Tiled,
}

impl KernelKind {
    pub const ALL: [KernelKind; 3] =
        [KernelKind::Naive, KernelKind::Im2col, KernelKind::Tiled];

    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Naive => "naive",
            KernelKind::Im2col => "im2col",
            KernelKind::Tiled => "tiled",
        }
    }

    pub fn parse(s: &str) -> Option<KernelKind> {
        match s {
            "naive" => Some(KernelKind::Naive),
            "im2col" => Some(KernelKind::Im2col),
            "tiled" => Some(KernelKind::Tiled),
            _ => None,
        }
    }
}

/// Probes above this many MACs trust the heuristic instead of measuring.
const MEASURE_BUDGET_MACS: u64 = 200_000_000;

/// Per-shape kernel chooser with a shared plan cache.
pub struct Autotuner {
    pub mem_words: f64,
    /// word model the tile plans are solved under (f32 uniform by default;
    /// probing and execution always use the same plan either way)
    pub precision: Precision,
    plans: TilePlanCache,
    choices: Mutex<HashMap<ConvShape, KernelKind>>,
}

impl Autotuner {
    pub fn new(mem_words: f64) -> Autotuner {
        Autotuner::with_precision(mem_words, Precision::uniform())
    }

    pub fn with_precision(mem_words: f64, precision: Precision) -> Autotuner {
        Autotuner {
            mem_words,
            precision,
            plans: TilePlanCache::new(),
            choices: Mutex::new(HashMap::new()),
        }
    }

    /// The (cached) tile plan this tuner would execute `s` with.
    pub fn plan(&self, s: &ConvShape) -> Arc<TilePlan> {
        self.plans.plan(s, self.precision, self.mem_words)
    }

    /// Zero-cost selection from shape structure alone.
    pub fn heuristic(s: &ConvShape) -> KernelKind {
        if s.updates() < (1 << 16) {
            return KernelKind::Naive;
        }
        if s.c_i * s.w_f * s.h_f < 16 {
            return KernelKind::Im2col;
        }
        KernelKind::Tiled
    }

    /// Measure-once selection: time all three kernels on a batch-clamped
    /// probe of `s`, cache and return the fastest. Falls back to
    /// [`Autotuner::heuristic`] when even the probe would be too large.
    pub fn select(&self, s: &ConvShape) -> KernelKind {
        if let Some(k) = self.choices.lock().expect("choices poisoned").get(s) {
            return *k;
        }
        let probe = s.with_batch(s.n.min(2));
        let choice = if probe.updates() > MEASURE_BUDGET_MACS {
            Autotuner::heuristic(s)
        } else {
            self.measure(&probe)
        };
        self.choices
            .lock()
            .expect("choices poisoned")
            .insert(*s, choice);
        choice
    }

    fn measure(&self, s: &ConvShape) -> KernelKind {
        let (x, w) = crate::conv::paper_operands(s, 1);
        // solve (and cache) the blocking LP outside the timed region: the
        // probe compares steady-state kernels, and the plan is a one-time
        // per-shape cost every later tiled run reuses
        let _ = self.plan(s);
        let mut best = (KernelKind::Naive, f64::INFINITY);
        for k in KernelKind::ALL {
            let t0 = Instant::now();
            std::hint::black_box(self.run_kernel(k, &x, &w, s));
            let secs = t0.elapsed().as_secs_f64();
            if secs < best.1 {
                best = (k, secs);
            }
        }
        best.0
    }

    /// Execute `s` with an explicit kernel.
    pub fn run_kernel(
        &self,
        k: KernelKind,
        x: &Tensor4,
        w: &Tensor4,
        s: &ConvShape,
    ) -> Tensor4 {
        match k {
            KernelKind::Naive => conv7nl_naive(x, w, s),
            KernelKind::Im2col => conv_im2col(x, w, s),
            KernelKind::Tiled => conv_tiled(x, w, &self.plan(s)),
        }
    }

    /// Execute `s` with the autotuned kernel.
    pub fn run(&self, x: &Tensor4, w: &Tensor4, s: &ConvShape) -> Tensor4 {
        let k = self.select(s);
        self.run_kernel(k, x, w, s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heuristic_tiers() {
        // tiny -> naive
        let tiny = ConvShape::new(1, 2, 2, 4, 4, 3, 3, 1, 1);
        assert_eq!(Autotuner::heuristic(&tiny), KernelKind::Naive);
        // big but thin reduction (1x1 filter, few channels) -> im2col
        let thin = ConvShape::new(64, 4, 64, 32, 32, 1, 1, 1, 1);
        assert!(thin.updates() >= (1 << 16));
        assert_eq!(Autotuner::heuristic(&thin), KernelKind::Im2col);
        // big with fat reduction -> tiled
        let fat = ConvShape::new(4, 64, 64, 14, 14, 3, 3, 1, 1);
        assert_eq!(Autotuner::heuristic(&fat), KernelKind::Tiled);
    }

    #[test]
    fn select_caches_and_run_matches_naive() {
        let tuner = Autotuner::new(4096.0);
        let s = ConvShape::new(2, 3, 4, 6, 6, 3, 3, 1, 1);
        let k1 = tuner.select(&s);
        let k2 = tuner.select(&s);
        assert_eq!(k1, k2);
        let (x, w) = crate::conv::paper_operands(&s, 5);
        let got = tuner.run(&x, &w, &s);
        let want = conv7nl_naive(&x, &w, &s);
        assert!(got.rel_l2(&want) < 1e-4, "rel {}", got.rel_l2(&want));
    }

    #[test]
    fn kernel_kind_names_roundtrip() {
        for k in KernelKind::ALL {
            assert_eq!(KernelKind::parse(k.name()), Some(k));
        }
        assert_eq!(KernelKind::parse("auto"), None);
    }
}
