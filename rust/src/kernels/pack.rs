//! Operand packing: copy one tile's input/filter working set into dense
//! buffers whose sizes are exactly the per-operand footprints the §3.2 LP
//! budgets for (the word-traffic the engine's counters charge).
//!
//! Layouts (innermost last, contiguous):
//!
//! * input  `[bn][bcI][brw][brh][ew][eh]` with `ew = bwO + bq6 − 1` and
//!   `eh = bhO + bq7 − 1`: for each residue `(r6, r7)` a decimated patch of
//!   the image — entry `(aw, ah)` holds `x[σw·(a0+aw)+r6, σh·(b0+ah)+r7]`,
//!   so the microkernel reads `(i4+q6, i5+q7)` with unit stride in `i5`.
//! * filter `[bcI][bq6][bq7][brw][brh][bcO]`: cO innermost so the inner
//!   update is a contiguous axpy. Split coordinates with
//!   `σw·q6 + r6 ≥ wF` (the over-approximation of the small-filter split)
//!   are zero-filled and skipped by the microkernel.

use crate::conv::{ConvShape, Tensor4};

use super::plan::filter_split_ranges;
use super::tiles::{Blk, OutTile, RedTile};

/// Pack the input working set of `(ot, rt)` into `buf` (cleared and
/// resized — callers reuse one buffer across the reduction loop to avoid
/// per-tile allocation). Returns the extended patch dims `(ew, eh)`.
///
/// The innermost `ah` sweep walks image rows at stride `σh` within one
/// column, so it is widened: at unit stride the whole extended column is
/// one contiguous `copy_from_slice` (h is the contiguous axis), and at
/// larger strides an 8-lane unrolled gather from the column's row slice —
/// the auto-vectorizer sees independent lanes instead of a carried
/// bounds check. Out-of-image tails are bulk `fill(0.0)`, so the packed
/// words are bitwise identical to the scalar nest (the test oracle).
pub(crate) fn pack_input(
    x: &Tensor4,
    sw: usize,
    sh: usize,
    ot: &OutTile,
    rt: &RedTile,
    buf: &mut Vec<f32>,
) -> (usize, usize) {
    let bn = ot.n.len as usize;
    let bci = rt.ci.len as usize;
    let brw = rt.rw.len as usize;
    let brh = rt.rh.len as usize;
    let ew = ot.wo.len as usize + rt.qw.len as usize - 1;
    let eh = ot.ho.len as usize + rt.qh.len as usize - 1;
    let (wi, hi) = (x.dims[2], x.dims[3]);
    let a0 = ot.wo.start as usize + rt.qw.start as usize;
    let b0 = ot.ho.start as usize + rt.qh.start as usize;
    // no zero-fill: the loop below writes every element (out-of-image
    // corners explicitly get 0.0), so stale data from a reused buffer
    // never survives — only the length needs fixing up at ragged edges
    let len = bn * bci * brw * brh * ew * eh;
    if buf.len() != len {
        buf.clear();
        buf.resize(len, 0.0);
    }
    let mut k = 0;
    for n in 0..bn {
        let na = ot.n.start as usize + n;
        for ci in 0..bci {
            let ca = rt.ci.start as usize + ci;
            for r6 in 0..brw {
                let r6a = rt.rw.start as usize + r6;
                for r7 in 0..brh {
                    let r7a = rt.rh.start as usize + r7;
                    let row0 = sh * b0 + r7a;
                    for aw in 0..ew {
                        let col = sw * (a0 + aw) + r6a;
                        let dst = &mut buf[k..k + eh];
                        k += eh;
                        // corners of the (aw, ah) rectangle can exceed
                        // the image when they correspond only to
                        // invalid split coordinates; the microkernel
                        // never reads those zeros
                        if col >= wi || row0 >= hi {
                            dst.fill(0.0);
                            continue;
                        }
                        // rows in range: row0 + σh·ah < hi
                        let valid = ((hi - 1 - row0) / sh + 1).min(eh);
                        if sh == 1 {
                            let src = x.idx(na, ca, col, row0);
                            dst[..valid].copy_from_slice(
                                &x.data[src..src + valid],
                            );
                        } else {
                            let src = x.idx(na, ca, col, 0);
                            let rows = &x.data[src..src + hi];
                            let mut ah = 0;
                            while ah + 8 <= valid {
                                let r = row0 + sh * ah;
                                dst[ah] = rows[r];
                                dst[ah + 1] = rows[r + sh];
                                dst[ah + 2] = rows[r + 2 * sh];
                                dst[ah + 3] = rows[r + 3 * sh];
                                dst[ah + 4] = rows[r + 4 * sh];
                                dst[ah + 5] = rows[r + 5 * sh];
                                dst[ah + 6] = rows[r + 6 * sh];
                                dst[ah + 7] = rows[r + 7 * sh];
                                ah += 8;
                            }
                            while ah < valid {
                                dst[ah] = rows[row0 + sh * ah];
                                ah += 1;
                            }
                        }
                        dst[valid..].fill(0.0);
                    }
                }
            }
        }
    }
    (ew, eh)
}

/// Pack the filter working set of `(ot, rt)` into `buf` (cleared and
/// resized). Returns the number of words actually read from the filter
/// tensor (invalid split coordinates are zero-filled, not read).
///
/// The innermost `co` sweep gathers one tap across the tile's cO block —
/// a fixed-stride walk (`wF·hF` words between channels), widened into an
/// 8-lane unrolled gather so the packed axpy panels assemble without a
/// per-element index recomputation. Bitwise identical to the scalar nest
/// (the test oracle); invalid split coordinates stay zero-filled from the
/// `resize` and are never read.
pub(crate) fn pack_filter(
    w: &Tensor4,
    sw: usize,
    sh: usize,
    wf: usize,
    hf: usize,
    ot: &OutTile,
    rt: &RedTile,
    buf: &mut Vec<f32>,
) -> u64 {
    let bci = rt.ci.len as usize;
    let bco = ot.co.len as usize;
    let bqw = rt.qw.len as usize;
    let bqh = rt.qh.len as usize;
    let brw = rt.rw.len as usize;
    let brh = rt.rh.len as usize;
    buf.clear();
    buf.resize(bci * bqw * bqh * brw * brh * bco, 0.0);
    let co0 = ot.co.start as usize;
    // stride between adjacent cO channels at a fixed tap, from the real
    // tensor dims (the spec admits minimal tensors)
    let cstep = w.dims[2] * w.dims[3];
    let mut words = 0u64;
    let mut k = 0;
    for ci in 0..bci {
        let ca = rt.ci.start as usize + ci;
        for q6 in 0..bqw {
            let i6b = sw * (rt.qw.start as usize + q6);
            for q7 in 0..bqh {
                let i7b = sh * (rt.qh.start as usize + q7);
                for r6 in 0..brw {
                    let i6 = i6b + rt.rw.start as usize + r6;
                    for r7 in 0..brh {
                        let i7 = i7b + rt.rh.start as usize + r7;
                        if i6 < wf && i7 < hf {
                            words += bco as u64;
                            let base = w.idx(ca, co0, i6, i7);
                            let src = &w.data[base..];
                            let dst = &mut buf[k..k + bco];
                            let mut co = 0;
                            while co + 8 <= bco {
                                let s0 = co * cstep;
                                dst[co] = src[s0];
                                dst[co + 1] = src[s0 + cstep];
                                dst[co + 2] = src[s0 + 2 * cstep];
                                dst[co + 3] = src[s0 + 3 * cstep];
                                dst[co + 4] = src[s0 + 4 * cstep];
                                dst[co + 5] = src[s0 + 5 * cstep];
                                dst[co + 6] = src[s0 + 6 * cstep];
                                dst[co + 7] = src[s0 + 7 * cstep];
                                co += 8;
                            }
                            while co < bco {
                                dst[co] = src[co * cstep];
                                co += 1;
                            }
                        }
                        k += bco;
                    }
                }
            }
        }
    }
    words
}

/// Pack one fused stage's panels from a patch-local scratch activation:
/// all of `cI` and the complete split-filter ranges as **one** reduction
/// tile, with the output restricted to rows `[h0, h0 + rows)` — the
/// sliding-window fresh region of the fused sweep. Packing the whole
/// reduction at once is what makes the microkernel's per-element
/// accumulation order equal the naive nest's ascending `(cI, i6, i7)`
/// order (the fused accumulation-order contract, DESIGN.md §7).
///
/// `x` is the stage's scratch input patch (`[bn][cI][iw][ih]`, origin at
/// the patch's first row) and `s` the patch-local sub-shape whose
/// `n/w_o/h_o` are the tile extents. Returns the extended patch dims
/// `(ew, eh)` the microkernel indexes with.
pub(crate) fn pack_fused_stage(
    x: &Tensor4,
    w: &Tensor4,
    s: &ConvShape,
    h0: usize,
    rows: usize,
    xin: &mut Vec<f32>,
    fil: &mut Vec<f32>,
) -> (usize, usize) {
    let (qw, qh, rw, rh) = filter_split_ranges(s);
    let ot = OutTile {
        n: Blk { start: 0, len: s.n },
        co: Blk { start: 0, len: s.c_o },
        wo: Blk { start: 0, len: s.w_o },
        ho: Blk { start: h0 as u64, len: rows as u64 },
    };
    let rt = RedTile {
        ci: Blk { start: 0, len: s.c_i },
        qw: Blk { start: 0, len: qw },
        qh: Blk { start: 0, len: qh },
        rw: Blk { start: 0, len: rw },
        rh: Blk { start: 0, len: rh },
    };
    let dims = pack_input(x, s.s_w as usize, s.s_h as usize, &ot, &rt, xin);
    let _ = pack_filter(
        w,
        s.s_w as usize,
        s.s_h as usize,
        s.w_f as usize,
        s.h_f as usize,
        &ot,
        &rt,
        fil,
    );
    dims
}

// ---------------- backward-pass packing ----------------
//
// The gradient passes pack per (output tile × reduction step) exactly like
// the forward engine, with layouts sized to the pass's LP footprints. Both
// sweep the pass's "filter" loops in full per step (see
// `TilePlan::for_pass`), so the only blocked reduction dim is the
// contracted channel — N for dFilter, cO for dInput. The span helpers
// below are shared by the pack loops and `exec::expected_pass_traffic`,
// which is what keeps measured == analytic traffic exact per pass.

/// Dense image-column span one dFilter tile reads: gradient columns
/// `[i6₀, i6₀ + e)` correlated against every output column touch image
/// columns `[i6₀, i6₀ + e + σ·(out − 1))`.
pub(crate) fn dfilter_span(e: u64, stride: u64, out: u64) -> u64 {
    e + stride * (out.max(1) - 1)
}

/// Half-open output-coordinate span `(lo, len)` feeding dInput columns
/// `[x0, x0 + ex)`: the `wo` with `σ·wo + i6 ∈ [x0, x0 + ex)` for some
/// tap `i6 ∈ [0, filt)`. Empty for the trailing paper-convention padding
/// rows no gradient reaches.
pub(crate) fn dinput_span(x0: u64, ex: u64, stride: u64, filt: u64, out: u64) -> (u64, u64) {
    if out == 0 || ex == 0 {
        return (0, 0);
    }
    let lo = if x0 + 1 > filt {
        crate::util::ceil_div(x0 + 1 - filt, stride)
    } else {
        0
    };
    let hi = ((x0 + ex - 1) / stride).min(out - 1);
    if lo > hi {
        (0, 0)
    } else {
        (lo, hi - lo + 1)
    }
}

/// Per input coordinate of the span `[x0, x0 + ex)`, the valid
/// `(tap, output − g0)` pairs of one dInput axis: the taps `i6 ∈ [0, filt)`
/// whose output position `(x − i6)/σ` exists, paired with that position
/// relative to the gradient patch origin `g0`. Taps ascend within each
/// list — per element the dInput nests accumulate in the oracle's
/// `(i6, i7)` tap order, which is what keeps the tiled and fused backward
/// sweeps bitwise identical to `dinput_naive`. Shared by
/// `exec::run_dinput_tile` (patch origin = the packed span's `lo`) and the
/// fused backward chain's patch-local nest.
pub(crate) fn dinput_pairs(
    x0: u64,
    ex: u64,
    stride: u64,
    filt: u64,
    out: u64,
    g0: u64,
) -> Vec<Vec<(usize, usize)>> {
    (0..ex)
        .map(|dx| {
            let xcol = x0 + dx;
            (0..filt)
                .filter_map(|tap| {
                    let t = xcol.checked_sub(tap)?;
                    if t % stride != 0 || t / stride >= out {
                        return None;
                    }
                    Some((tap as usize, (t / stride - g0) as usize))
                })
                .collect()
        })
        .collect()
}

/// Pack the image working set of one dFilter tile and reduction step:
/// `[bn][bcI][spanW][spanH]` — `bn` the contracted batch block, `bcI` the
/// tile's cI block, spans per [`dfilter_span`]. Rows are copied whole (h
/// is the contiguous axis). Returns `(spanW, spanH)`.
pub(crate) fn pack_dfilter_input(
    x: &Tensor4,
    s: &ConvShape,
    ot: &OutTile,
    rt: &RedTile,
    buf: &mut Vec<f32>,
) -> (usize, usize) {
    let bn = rt.ci.len as usize;
    let n0 = rt.ci.start as usize;
    let bci = ot.n.len as usize;
    let ci0 = ot.n.start as usize;
    let spw = dfilter_span(ot.wo.len, s.s_w, s.w_o) as usize;
    let sph = dfilter_span(ot.ho.len, s.s_h, s.h_o) as usize;
    let (col0, row0) = (ot.wo.start as usize, ot.ho.start as usize);
    let len = bn * bci * spw * sph;
    if buf.len() != len {
        buf.clear();
        buf.resize(len, 0.0);
    }
    let mut k = 0;
    for n in 0..bn {
        for ci in 0..bci {
            for c in 0..spw {
                let src = x.idx(n0 + n, ci0 + ci, col0 + c, row0);
                buf[k..k + sph].copy_from_slice(&x.data[src..src + sph]);
                k += sph;
            }
        }
    }
    (spw, sph)
}

/// Pack the output-gradient working set of one dFilter tile and reduction
/// step: `[bn][bcO][wO][hO]` — the pass's "filter" operand, full spatial
/// extent per step (whole planes are contiguous in `g`).
pub(crate) fn pack_dfilter_gout(
    g: &Tensor4,
    s: &ConvShape,
    ot: &OutTile,
    rt: &RedTile,
    buf: &mut Vec<f32>,
) {
    let bn = rt.ci.len as usize;
    let n0 = rt.ci.start as usize;
    let bco = ot.co.len as usize;
    let co0 = ot.co.start as usize;
    let plane = (s.w_o * s.h_o) as usize;
    let len = bn * bco * plane;
    if buf.len() != len {
        buf.clear();
        buf.resize(len, 0.0);
    }
    let mut k = 0;
    for n in 0..bn {
        for co in 0..bco {
            let src = g.idx(n0 + n, co0 + co, 0, 0);
            buf[k..k + plane].copy_from_slice(&g.data[src..src + plane]);
            k += plane;
        }
    }
}

/// Pack the output-gradient working set of one dInput tile and reduction
/// step: `[bn][bcO][woLen][hoLen]` with spans per [`dinput_span`].
/// Returns `(wo_lo, wo_len, ho_lo, ho_len)`.
pub(crate) fn pack_dinput_gout(
    g: &Tensor4,
    s: &ConvShape,
    ot: &OutTile,
    rt: &RedTile,
    buf: &mut Vec<f32>,
) -> (usize, usize, usize, usize) {
    let (wo_lo, wo_len) = dinput_span(ot.wo.start, ot.wo.len, s.s_w, s.w_f, s.w_o);
    let (ho_lo, ho_len) = dinput_span(ot.ho.start, ot.ho.len, s.s_h, s.h_f, s.h_o);
    let (wo_lo, wo_len) = (wo_lo as usize, wo_len as usize);
    let (ho_lo, ho_len) = (ho_lo as usize, ho_len as usize);
    let bn = ot.n.len as usize;
    let n0 = ot.n.start as usize;
    let bco = rt.ci.len as usize;
    let co0 = rt.ci.start as usize;
    let len = bn * bco * wo_len * ho_len;
    if buf.len() != len {
        buf.clear();
        buf.resize(len, 0.0);
    }
    let mut k = 0;
    if len > 0 {
        for n in 0..bn {
            for co in 0..bco {
                for a in 0..wo_len {
                    let src = g.idx(n0 + n, co0 + co, wo_lo + a, ho_lo);
                    buf[k..k + ho_len].copy_from_slice(&g.data[src..src + ho_len]);
                    k += ho_len;
                }
            }
        }
    }
    (wo_lo, wo_len, ho_lo, ho_len)
}

/// Pack the filter working set of one dInput tile and reduction step:
/// `[bcI][bcO][wF][hF]` — cI from the tile (it owns the output), cO from
/// the reduction step; whole taps are contiguous in `w`.
pub(crate) fn pack_dinput_filter(
    w: &Tensor4,
    s: &ConvShape,
    ot: &OutTile,
    rt: &RedTile,
    buf: &mut Vec<f32>,
) {
    let bci = ot.co.len as usize;
    let ci0 = ot.co.start as usize;
    let bco = rt.ci.len as usize;
    let co0 = rt.ci.start as usize;
    let taps = (s.w_f * s.h_f) as usize;
    let len = bci * bco * taps;
    if buf.len() != len {
        buf.clear();
        buf.resize(len, 0.0);
    }
    let mut k = 0;
    for ci in 0..bci {
        for co in 0..bco {
            let src = w.idx(ci0 + ci, co0 + co, 0, 0);
            buf[k..k + taps].copy_from_slice(&w.data[src..src + taps]);
            k += taps;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::conv7nl_naive;
    use crate::kernels::gemm::{conv_tile_mac, TileDims};

    fn blk(start: u64, len: u64) -> Blk {
        Blk { start, len }
    }

    #[test]
    fn input_pack_matches_direct_indexing() {
        // unit stride: packed (aw, ah) must equal x[a0+aw, b0+ah]
        let x = Tensor4::randn([1, 2, 8, 8], 7);
        let ot = OutTile { n: blk(0, 1), co: blk(0, 1), wo: blk(1, 2), ho: blk(2, 3) };
        let rt = RedTile {
            ci: blk(1, 1),
            qw: blk(0, 2),
            qh: blk(0, 2),
            rw: blk(0, 1),
            rh: blk(0, 1),
        };
        let mut buf = Vec::new();
        let (ew, eh) = pack_input(&x, 1, 1, &ot, &rt, &mut buf);
        assert_eq!((ew, eh), (3, 4));
        assert_eq!(buf.len(), 12); // bn·bcI·brw·brh·ew·eh = 1·1·1·1·3·4
        for aw in 0..ew {
            for ah in 0..eh {
                assert_eq!(buf[aw * eh + ah], x.at(0, 1, 1 + aw, 2 + ah));
            }
        }
    }

    /// Packing a whole strided stage as ONE reduction tile and driving it
    /// through the axpy microkernel reproduces the naive 7NL nest bitwise
    /// — the fused accumulation-order contract (DESIGN.md §7).
    #[test]
    fn fused_stage_pack_plus_mac_is_bitwise_naive() {
        let s = ConvShape::new(2, 3, 5, 4, 3, 3, 4, 2, 2);
        let iw = (s.s_w * (s.w_o - 1) + s.w_f) as usize;
        let ih = (s.s_h * (s.h_o - 1) + s.h_f) as usize;
        let x = Tensor4::randn([2, 3, iw, ih], 11);
        let w = Tensor4::randn([3, 5, 3, 4], 12);
        let (mut xin, mut fil) = (Vec::new(), Vec::new());
        let (qw, qh, rw, rh) = filter_split_ranges(&s);
        let (bn, bco) = (s.n as usize, s.c_o as usize);
        let (bwo, bho) = (s.w_o as usize, s.h_o as usize);
        let want = conv7nl_naive(&x, &w, &s);

        let (ew, eh) =
            pack_fused_stage(&x, &w, &s, 0, bho, &mut xin, &mut fil);
        let mut out = vec![0.0f32; bn * bwo * bho * bco];
        let d = TileDims {
            bn,
            bci: s.c_i as usize,
            bco,
            bwo,
            bho,
            bqw: qw as usize,
            bqh: qh as usize,
            brw: rw as usize,
            brh: rh as usize,
            ew,
            eh,
            q6_0: 0,
            q7_0: 0,
            r6_0: 0,
            r7_0: 0,
            sw: s.s_w as usize,
            sh: s.s_h as usize,
            wf: s.w_f as usize,
            hf: s.h_f as usize,
        };
        conv_tile_mac(&mut out, &xin, &fil, &d);
        let mut k = 0;
        for n in 0..bn {
            for a in 0..bwo {
                for h in 0..bho {
                    for c in 0..bco {
                        assert_eq!(
                            out[k].to_bits(),
                            want.at(n, c, a, h).to_bits(),
                            "({n},{c},{a},{h})"
                        );
                        k += 1;
                    }
                }
            }
        }

        // row-restricted packing (the sliding-window fresh region of a
        // fused sweep) agrees bitwise on the packed rows
        let (ew2, eh2) = pack_fused_stage(&x, &w, &s, 1, 2, &mut xin, &mut fil);
        let mut out2 = vec![0.0f32; bn * bwo * 2 * bco];
        let d2 = TileDims {
            bn,
            bci: s.c_i as usize,
            bco,
            bwo,
            bho: 2,
            bqw: qw as usize,
            bqh: qh as usize,
            brw: rw as usize,
            brh: rh as usize,
            ew: ew2,
            eh: eh2,
            q6_0: 0,
            q7_0: 0,
            r6_0: 0,
            r7_0: 0,
            sw: s.s_w as usize,
            sh: s.s_h as usize,
            wf: s.w_f as usize,
            hf: s.h_f as usize,
        };
        conv_tile_mac(&mut out2, &xin, &fil, &d2);
        let mut k = 0;
        for n in 0..bn {
            for a in 0..bwo {
                for h in 0..2 {
                    for c in 0..bco {
                        assert_eq!(
                            out2[k].to_bits(),
                            want.at(n, c, a, 1 + h).to_bits(),
                            "restricted ({n},{c},{a},{h})"
                        );
                        k += 1;
                    }
                }
            }
        }
    }

    #[test]
    fn dinput_span_hand_cases() {
        // unit stride, 3-tap filter, 5 outputs: column x is fed by
        // wo in [x-2, x] clamped to [0, 4]
        assert_eq!(dinput_span(0, 1, 1, 3, 5), (0, 1));
        assert_eq!(dinput_span(3, 1, 1, 3, 5), (1, 3));
        assert_eq!(dinput_span(0, 8, 1, 3, 5), (0, 5));
        // trailing paper-convention padding rows get no gradient
        assert_eq!(dinput_span(7, 1, 1, 3, 5), (0, 0));
        // stride 2, 2-tap filter: x = 2·wo + i6
        assert_eq!(dinput_span(4, 1, 2, 2, 4), (2, 1));
        assert_eq!(dinput_span(3, 2, 2, 2, 4), (1, 2));
        // degenerate
        assert_eq!(dinput_span(0, 0, 1, 3, 5), (0, 0));
        assert_eq!(dinput_span(0, 1, 1, 3, 0), (0, 0));
    }

    #[test]
    fn dfilter_packs_match_direct_indexing() {
        let s = ConvShape::new(3, 2, 2, 4, 3, 3, 2, 2, 1);
        let x = Tensor4::randn(
            [3, 2, s.in_w() as usize, s.in_h() as usize],
            3,
        );
        let g = Tensor4::randn([3, 2, 4, 3], 4);
        // tile: ci block {1}, co block {0,1}, i6 block {1,2}, i7 block {0};
        // reduction step: n block {1,2}
        let ot = OutTile { n: blk(1, 1), co: blk(0, 2), wo: blk(1, 2), ho: blk(0, 2) };
        let rt = RedTile {
            ci: blk(1, 2),
            qw: blk(0, 4),
            qh: blk(0, 3),
            rw: blk(0, 1),
            rh: blk(0, 1),
        };
        let mut xb = Vec::new();
        let (spw, sph) = pack_dfilter_input(&x, &s, &ot, &rt, &mut xb);
        assert_eq!(spw as u64, dfilter_span(2, 2, 4)); // 2 + 2·3 = 8
        assert_eq!(sph as u64, dfilter_span(2, 1, 3)); // 2 + 2 = 4
        assert_eq!(xb.len(), 2 * 1 * spw * sph);
        // entry (n=0, ci=0, c, r) = x[1+0, 1+0, 1+c, 0+r]
        for c in 0..spw {
            for r in 0..sph {
                assert_eq!(xb[c * sph + r], x.at(1, 1, 1 + c, r));
            }
        }
        let mut gb = Vec::new();
        pack_dfilter_gout(&g, &s, &ot, &rt, &mut gb);
        assert_eq!(gb.len(), 2 * 2 * 4 * 3);
        assert_eq!(gb[0], g.at(1, 0, 0, 0));
        assert_eq!(gb[4 * 3], g.at(1, 1, 0, 0));
    }

    #[test]
    fn dinput_packs_match_direct_indexing() {
        let s = ConvShape::new(2, 3, 4, 5, 5, 3, 3, 1, 1);
        let g = Tensor4::randn([2, 4, 5, 5], 5);
        let w = Tensor4::randn([3, 4, 3, 3], 6);
        // dIn tile columns [3, 6) x rows [0, 2); co reduction block {1, 2}
        let ot = OutTile { n: blk(0, 2), co: blk(1, 2), wo: blk(3, 3), ho: blk(0, 2) };
        let rt = RedTile {
            ci: blk(1, 2),
            qw: blk(0, 3),
            qh: blk(0, 3),
            rw: blk(0, 1),
            rh: blk(0, 1),
        };
        let mut gb = Vec::new();
        let (wo_lo, wo_len, ho_lo, ho_len) =
            pack_dinput_gout(&g, &s, &ot, &rt, &mut gb);
        assert_eq!((wo_lo, wo_len), (1, 4)); // wo in [1, 4]
        assert_eq!((ho_lo, ho_len), (0, 2)); // ho in [0, 1]
        assert_eq!(gb.len(), 2 * 2 * 4 * 2);
        // entry (n=0, co=0, a=0, b=1) = g[0, 1+0, 1+0, 0+1]
        assert_eq!(gb[1], g.at(0, 1, 1, 1));
        let mut fb = Vec::new();
        pack_dinput_filter(&w, &s, &ot, &rt, &mut fb);
        // layout [bci=2][bco=2][3][3], ci from the tile's dim-2 block
        assert_eq!(fb.len(), 2 * 2 * 9);
        assert_eq!(fb[0], w.at(1, 1, 0, 0));
        assert_eq!(fb[9], w.at(1, 2, 0, 0));
        assert_eq!(fb[2 * 2 * 9 - 1], w.at(2, 2, 2, 2));
    }

    #[test]
    fn filter_pack_zero_fills_invalid_split_coords() {
        // 3x3 filter, stride 2: q range = ceil(3/2) = 2, r range = 2;
        // (q=1, r=1) -> i6 = 3 >= wf is invalid
        let w = Tensor4::randn([1, 2, 3, 3], 9);
        let ot = OutTile { n: blk(0, 1), co: blk(0, 2), wo: blk(0, 1), ho: blk(0, 1) };
        let rt = RedTile {
            ci: blk(0, 1),
            qw: blk(0, 2),
            qh: blk(0, 1),
            rw: blk(0, 2),
            rh: blk(0, 1),
        };
        // stale garbage in the reused buffer must not leak into zero-filled
        // (invalid) slots
        let mut buf = vec![777.0; 64];
        let words = pack_filter(&w, 2, 2, 3, 3, &ot, &rt, &mut buf);
        // layout [ci=1][q6=2][q7=1][r6=2][r7=1][co=2]
        assert_eq!(buf.len(), 2 * 2 * 2);
        // q6=0, r6=0 -> i6 = 0; q6=0, r6=1 -> i6 = 1; q6=1, r6=0 -> i6 = 2
        assert_eq!(buf[0], w.at(0, 0, 0, 0));
        assert_eq!(buf[2], w.at(0, 0, 1, 0));
        assert_eq!(buf[4], w.at(0, 0, 2, 0));
        // q6=1, r6=1 -> i6 = 3: invalid, zero-filled
        assert_eq!(buf[6], 0.0);
        assert_eq!(buf[7], 0.0);
        // three valid coords x bco=2 words read
        assert_eq!(words, 6);
    }

    /// The pre-widening scalar input-pack nest, kept verbatim as the
    /// bitwise oracle for the widened copy/gather paths.
    fn pack_input_scalar(
        x: &Tensor4,
        sw: usize,
        sh: usize,
        ot: &OutTile,
        rt: &RedTile,
        buf: &mut Vec<f32>,
    ) -> (usize, usize) {
        let bn = ot.n.len as usize;
        let bci = rt.ci.len as usize;
        let brw = rt.rw.len as usize;
        let brh = rt.rh.len as usize;
        let ew = ot.wo.len as usize + rt.qw.len as usize - 1;
        let eh = ot.ho.len as usize + rt.qh.len as usize - 1;
        let (wi, hi) = (x.dims[2], x.dims[3]);
        let a0 = ot.wo.start as usize + rt.qw.start as usize;
        let b0 = ot.ho.start as usize + rt.qh.start as usize;
        buf.clear();
        buf.resize(bn * bci * brw * brh * ew * eh, 0.0);
        let mut k = 0;
        for n in 0..bn {
            let na = ot.n.start as usize + n;
            for ci in 0..bci {
                let ca = rt.ci.start as usize + ci;
                for r6 in 0..brw {
                    let r6a = rt.rw.start as usize + r6;
                    for r7 in 0..brh {
                        let r7a = rt.rh.start as usize + r7;
                        for aw in 0..ew {
                            let col = sw * (a0 + aw) + r6a;
                            for ah in 0..eh {
                                let row = sh * (b0 + ah) + r7a;
                                buf[k] = if col < wi && row < hi {
                                    x.at(na, ca, col, row)
                                } else {
                                    0.0
                                };
                                k += 1;
                            }
                        }
                    }
                }
            }
        }
        (ew, eh)
    }

    /// The pre-widening scalar filter-pack nest, kept verbatim as the
    /// bitwise oracle for the widened cO gather.
    fn pack_filter_scalar(
        w: &Tensor4,
        sw: usize,
        sh: usize,
        wf: usize,
        hf: usize,
        ot: &OutTile,
        rt: &RedTile,
        buf: &mut Vec<f32>,
    ) -> u64 {
        let bci = rt.ci.len as usize;
        let bco = ot.co.len as usize;
        let bqw = rt.qw.len as usize;
        let bqh = rt.qh.len as usize;
        let brw = rt.rw.len as usize;
        let brh = rt.rh.len as usize;
        buf.clear();
        buf.resize(bci * bqw * bqh * brw * brh * bco, 0.0);
        let mut words = 0u64;
        let mut k = 0;
        for ci in 0..bci {
            let ca = rt.ci.start as usize + ci;
            for q6 in 0..bqw {
                let i6b = sw * (rt.qw.start as usize + q6);
                for q7 in 0..bqh {
                    let i7b = sh * (rt.qh.start as usize + q7);
                    for r6 in 0..brw {
                        let i6 = i6b + rt.rw.start as usize + r6;
                        for r7 in 0..brh {
                            let i7 = i7b + rt.rh.start as usize + r7;
                            if i6 < wf && i7 < hf {
                                words += bco as u64;
                                for co in 0..bco {
                                    buf[k + co] = w.at(
                                        ca,
                                        ot.co.start as usize + co,
                                        i6,
                                        i7,
                                    );
                                }
                            }
                            k += bco;
                        }
                    }
                }
            }
        }
        words
    }

    /// The widened input pack is bitwise identical to the scalar nest on
    /// unit-stride contiguous copies, strided 8-lane gathers, ragged
    /// out-of-image row tails, and fully out-of-image columns.
    #[test]
    fn widened_input_pack_matches_scalar_oracle_bitwise() {
        let x = Tensor4::randn([2, 3, 9, 11], 42);
        let cases: Vec<(usize, usize, OutTile, RedTile)> = vec![
            // unit stride, all in range: pure contiguous copies
            (
                1,
                1,
                OutTile { n: blk(0, 2), co: blk(0, 1), wo: blk(1, 3), ho: blk(2, 4) },
                RedTile { ci: blk(0, 3), qw: blk(0, 3), qh: blk(0, 3), rw: blk(0, 1), rh: blk(0, 1) },
            ),
            // unit stride with ragged row tail (eh = 10 runs past hi at
            // the bottom rows) and trailing out-of-image columns
            (
                1,
                1,
                OutTile { n: blk(0, 1), co: blk(0, 1), wo: blk(5, 3), ho: blk(3, 8) },
                RedTile { ci: blk(1, 2), qw: blk(0, 3), qh: blk(0, 3), rw: blk(0, 1), rh: blk(0, 1) },
            ),
            // stride 2 with split residues: the 8-lane gather path,
            // valid prefix shorter than eh
            (
                2,
                2,
                OutTile { n: blk(0, 2), co: blk(0, 1), wo: blk(0, 3), ho: blk(0, 4) },
                RedTile { ci: blk(0, 2), qw: blk(0, 2), qh: blk(0, 2), rw: blk(0, 2), rh: blk(0, 2) },
            ),
            // stride 3: gather remainder loop only (valid < 8)
            (
                3,
                3,
                OutTile { n: blk(1, 1), co: blk(0, 1), wo: blk(0, 2), ho: blk(0, 3) },
                RedTile { ci: blk(0, 1), qw: blk(0, 1), qh: blk(0, 1), rw: blk(0, 3), rh: blk(0, 3) },
            ),
        ];
        for (i, (sw, sh, ot, rt)) in cases.into_iter().enumerate() {
            let (mut wide, mut scalar) = (Vec::new(), Vec::new());
            let dw = pack_input(&x, sw, sh, &ot, &rt, &mut wide);
            let ds = pack_input_scalar(&x, sw, sh, &ot, &rt, &mut scalar);
            assert_eq!(dw, ds, "case {i}: dims");
            assert_eq!(wide.len(), scalar.len(), "case {i}: len");
            for (j, (a, b)) in wide.iter().zip(&scalar).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "case {i}: word {j} diverged"
                );
            }
        }

        // tall image so a stride-2 column holds >= 8 in-range rows: the
        // full 8-lane gather body runs, not just the remainder loop
        let tall = Tensor4::randn([1, 2, 7, 20], 44);
        let ot = OutTile { n: blk(0, 1), co: blk(0, 1), wo: blk(0, 3), ho: blk(0, 8) };
        let rt = RedTile {
            ci: blk(0, 2),
            qw: blk(0, 2),
            qh: blk(0, 2),
            rw: blk(0, 2),
            rh: blk(0, 2),
        };
        let (mut wide, mut scalar) = (Vec::new(), Vec::new());
        let dw = pack_input(&tall, 2, 2, &ot, &rt, &mut wide);
        let ds = pack_input_scalar(&tall, 2, 2, &ot, &rt, &mut scalar);
        assert_eq!(dw, ds, "tall: dims");
        assert!(dw.1 >= 8, "tall case must exercise the 8-lane body");
        assert_eq!(wide.len(), scalar.len(), "tall: len");
        for (j, (a, b)) in wide.iter().zip(&scalar).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "tall: word {j} diverged");
        }
    }

    /// The widened filter pack (8-lane strided cO gather) is bitwise
    /// identical to the scalar nest, including zero-filled invalid split
    /// coordinates and the sub-8-lane remainder.
    #[test]
    fn widened_filter_pack_matches_scalar_oracle_bitwise() {
        // cO = 11: one full 8-lane pass plus a 3-lane remainder
        let w = Tensor4::randn([2, 11, 3, 3], 43);
        let cases: Vec<(usize, usize, OutTile, RedTile)> = vec![
            // unit stride, full 3x3 split, whole cO block
            (
                1,
                1,
                OutTile { n: blk(0, 1), co: blk(0, 11), wo: blk(0, 1), ho: blk(0, 1) },
                RedTile { ci: blk(0, 2), qw: blk(0, 3), qh: blk(0, 3), rw: blk(0, 1), rh: blk(0, 1) },
            ),
            // stride 2: invalid split coords interleave with valid ones
            (
                2,
                2,
                OutTile { n: blk(0, 1), co: blk(2, 9), wo: blk(0, 1), ho: blk(0, 1) },
                RedTile { ci: blk(1, 1), qw: blk(0, 2), qh: blk(0, 2), rw: blk(0, 2), rh: blk(0, 2) },
            ),
            // small cO block: remainder loop only
            (
                1,
                1,
                OutTile { n: blk(0, 1), co: blk(4, 3), wo: blk(0, 1), ho: blk(0, 1) },
                RedTile { ci: blk(0, 2), qw: blk(0, 3), qh: blk(0, 3), rw: blk(0, 1), rh: blk(0, 1) },
            ),
        ];
        for (i, (sw, sh, ot, rt)) in cases.into_iter().enumerate() {
            let (mut wide, mut scalar) = (Vec::new(), Vec::new());
            let ww = pack_filter(&w, sw, sh, 3, 3, &ot, &rt, &mut wide);
            let ws = pack_filter_scalar(&w, sw, sh, 3, 3, &ot, &rt, &mut scalar);
            assert_eq!(ww, ws, "case {i}: words read");
            assert_eq!(wide.len(), scalar.len(), "case {i}: len");
            for (j, (a, b)) in wide.iter().zip(&scalar).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "case {i}: word {j} diverged"
                );
            }
        }
    }
}
