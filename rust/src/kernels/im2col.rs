//! im2col reference convolution: materialize the `(N·wO·hO) × (cI·wF·hF)`
//! patch matrix, reshape the filter to `(cI·wF·hF) × cO`, multiply, and
//! scatter back to `(N, cO, wO, hO)`.
//!
//! A deliberately different accumulation order from
//! [`crate::conv::conv7nl_naive`], so agreement between the two is a
//! meaningful numerics check — and the baseline the tiled engine is
//! benchmarked against (the paper's §3.2 claim is precisely that the LP
//! blocking beats im2col's patch-matrix traffic).

use crate::conv::{assert_conv_operands, ConvShape, Tensor4};

/// Explicit im2col + GEMM convolution.
pub fn conv_im2col(x: &Tensor4, w: &Tensor4, s: &ConvShape) -> Tensor4 {
    assert_conv_operands(x, w, s);
    let (n, ci, co) = (s.n as usize, s.c_i as usize, s.c_o as usize);
    let (wo, ho) = (s.w_o as usize, s.h_o as usize);
    let (wf, hf) = (s.w_f as usize, s.h_f as usize);
    let (sw, sh) = (s.s_w as usize, s.s_h as usize);

    let k = ci * wf * hf;
    let rows = n * wo * ho;

    // A: patch matrix, row r = (i1, i4, i5), column c = (i2, i6, i7)
    let mut a = vec![0.0f32; rows * k];
    for i1 in 0..n {
        for i4 in 0..wo {
            for i5 in 0..ho {
                let r = (i1 * wo + i4) * ho + i5;
                for i2 in 0..ci {
                    for i6 in 0..wf {
                        for i7 in 0..hf {
                            let c = (i2 * wf + i6) * hf + i7;
                            a[r * k + c] = x.at(i1, i2, sw * i4 + i6, sh * i5 + i7);
                        }
                    }
                }
            }
        }
    }

    // B: reshaped filter, row c = (i2, i6, i7), column i3
    let mut b = vec![0.0f32; k * co];
    for i2 in 0..ci {
        for i3 in 0..co {
            for i6 in 0..wf {
                for i7 in 0..hf {
                    let c = (i2 * wf + i6) * hf + i7;
                    b[c * co + i3] = w.at(i2, i3, i6, i7);
                }
            }
        }
    }

    // C = A·B, scattered to NCWH
    let mut out = Tensor4::zeros([n, co, wo, ho]);
    for r in 0..rows {
        let i1 = r / (wo * ho);
        let rem = r % (wo * ho);
        let (i4, i5) = (rem / ho, rem % ho);
        for i3 in 0..co {
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += a[r * k + kk] * b[kk * co + i3];
            }
            *out.at_mut(i1, i3, i4, i5) = acc;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::conv7nl_naive;

    #[test]
    fn im2col_matches_naive_unit_stride() {
        let s = ConvShape::new(2, 3, 4, 5, 5, 3, 3, 1, 1);
        let x = Tensor4::randn([2, 3, 8, 8], 1);
        let w = Tensor4::randn([3, 4, 3, 3], 2);
        let a = conv7nl_naive(&x, &w, &s);
        let b = conv_im2col(&x, &w, &s);
        assert!(a.rel_l2(&b) < 1e-5, "rel {}", a.rel_l2(&b));
    }

    #[test]
    fn im2col_matches_naive_strided() {
        let s = ConvShape::new(1, 2, 3, 4, 4, 3, 3, 2, 2);
        let x = Tensor4::randn([1, 2, 11, 11], 3);
        let w = Tensor4::randn([2, 3, 3, 3], 4);
        let a = conv7nl_naive(&x, &w, &s);
        let b = conv_im2col(&x, &w, &s);
        assert!(a.rel_l2(&b) < 1e-5, "rel {}", a.rel_l2(&b));
    }
}
