//! The multi-layer fusion planner: walks adjacent stages of a
//! [`NetworkSpec`] chain and decides, per boundary, whether the two stages
//! execute inside one tile sweep (fused — the inter-layer activation stays
//! resident in scratch buffers and never touches main memory) or
//! materialize the full activation tensor between them.
//!
//! **Halo math.** Sweeping output tiles of the *last* stage of a fused
//! group, an output block of extent `e` needs an input span of
//! `σ·(e − 1) + f` rows from the stage above ([`halo_extent`]); applied
//! recursively up the group, each upstream stage's required activation
//! tile grows by one halo per layer. [`group_spans`] performs exactly this
//! walk for one concrete tile and is shared by the fused executor and the
//! analytic traffic model, so measured and expected traffic agree word for
//! word.
//!
//! **Sliding-window halo reuse.** Adjacent h-tiles of a fused sweep need
//! overlapping input rows at every level — a constant
//! [`input_overlap_rows`] per stage, independent of the tile. With the
//! halo cache on, the executor carries each level's trailing overlap rows
//! from one h-tile to the next, so the group head re-reads only the fresh
//! rows from main memory and interior stages recompute only the fresh
//! rows. The carry buffers' footprint is folded into the fuse budget
//! ([`group_footprint`]) and the saved head re-reads into the analytic
//! traffic model ([`charge_fused_group`]).
//!
//! **Fuse-vs-materialize rule** (DESIGN.md §7). A boundary fuses when
//! (a) a tile of the candidate group exists whose peak working set under
//! the packed execution model — scratch input patch + packed input panel +
//! output patch + packed filter panel of the widest stage, plus the
//! sliding-window carries — fits in the memory budget `M`
//! ([`fit_group_tile`]), and (b) the analytic fused traffic of the
//! extended group does not exceed the traffic of leaving the boundary
//! materialized (the current group plus the next stage run layer-by-layer
//! through the LP-tiled engine). Rule (b) guards against fusing past the
//! point where halo recompute and per-tile filter re-reads outweigh the
//! saved activation round-trip, and makes `fused ≤ unfused` hold by
//! construction.
//!
//! **Pass-generic planning** (DESIGN.md §9). The same planner now covers
//! the whole training step through [`NetPass`]:
//!
//! * [`NetPass::Backward`] chains dInput through the network the way
//!   forward activations chain — mirrored for the transposed stencil. The
//!   sweep iterates tiles of the group *head's* input-gradient grid; each
//!   stage's required output-gradient span follows from [`dout_span`] (the
//!   half-open set of output rows whose stencil touches the tile), growing
//!   up the group toward the tail the way forward halos grow toward the
//!   head. Interior gradient boundaries move zero words; a tail-side
//!   sliding-window cache carries the previous h-tile's gradient patch so
//!   only fresh rows are read from main memory.
//! * [`NetPass::Step`] fuses each stage's forward recompute with its own
//!   dFilter (they share the resident activation patch) and with the
//!   dInput chain — one sweep per batch block covering the full spatial
//!   extent. Spatial tiling is forbidden here by the backward bitwise
//!   contract (dFilter adds one scalar accumulator per `(element, n)` over
//!   the *full* ascending `(wO, hO)` sweep), so [`fit_step_group_tile`]
//!   shrinks the batch block only.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::conv::{
    conv7nl_naive, dfilter_naive, dinput_naive, ConvPass, ConvShape,
    NetworkStage, Tensor4,
};
use crate::obs::{self, jf, js, ju};
use crate::util::json::Json;

use super::exec::{expected_pass_traffic, expected_traffic, Traffic};
use super::pack::dinput_span;
use super::plan::{filter_split_ranges, TilePlan, TilePlanCache};
use super::tiles::{split, Blk};

/// Input span one output block of `len` elements needs upstream:
/// `σ·(len − 1) + f`.
pub fn halo_extent(len: u64, stride: u64, filter: u64) -> u64 {
    stride * (len.max(1) - 1) + filter
}

/// Which compute path fused stages run through. Both paths follow the
/// same accumulation-order contract (ascending `(cI, i6, i7)` per output
/// element — see `gemm.rs` and DESIGN.md §7), so they are bitwise
/// interchangeable; `Packed` is the production path, `Reference` the
/// oracle it is pinned to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FusedExec {
    /// The packed LP microkernel: each stage packs its scratch activation
    /// patch and filter into the `pack.rs` panels (one full reduction
    /// tile) and drives them through the `gemm.rs` axpy MAC.
    Packed,
    /// The patch-local naive 7NL nest — the bitwise oracle.
    Reference,
}

impl FusedExec {
    pub fn name(self) -> &'static str {
        match self {
            FusedExec::Packed => "packed",
            FusedExec::Reference => "reference",
        }
    }

    pub fn parse(s: &str) -> Option<FusedExec> {
        match s {
            "packed" => Some(FusedExec::Packed),
            "reference" => Some(FusedExec::Reference),
            _ => None,
        }
    }
}

/// Which network-level sweep a [`FusePlan`] drives — the pass-generic
/// fusion axis. `Forward` is the activation pipeline (PR 3/4), `Backward`
/// the dInput gradient chain mirrored through the transposed stencil, and
/// `Step` the whole training step (forward recompute + dFilter + dInput
/// fused per batch block, loss boundary as the only materialization).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum NetPass {
    Forward,
    Backward,
    Step,
}

impl NetPass {
    pub const ALL: [NetPass; 3] = [NetPass::Forward, NetPass::Backward, NetPass::Step];

    pub fn name(self) -> &'static str {
        match self {
            NetPass::Forward => "fwd",
            NetPass::Backward => "bwd",
            NetPass::Step => "step",
        }
    }

    pub fn parse(s: &str) -> Option<NetPass> {
        match s {
            "fwd" | "forward" => Some(NetPass::Forward),
            "bwd" | "backward" => Some(NetPass::Backward),
            "step" | "training" => Some(NetPass::Step),
            _ => None,
        }
    }
}

/// One contiguous run of stages executed per tile sweep. `start..=end`
/// index into the network's stage list; `b_n`/`b_wo`/`b_ho` are the
/// output-tile blocks of the *last* stage the fused sweep iterates
/// (meaningful when `is_fused()`; single-stage groups execute through the
/// stage's own LP [`TilePlan`] instead).
///
/// Pass-generic reinterpretation: in a [`NetPass::Backward`] plan the
/// sweep iterates the group *head's* input-gradient grid, so `b_wo`/`b_ho`
/// block `in_w(start)`/`in_h(start)`; in a [`NetPass::Step`] plan only the
/// batch is tiled and `b_wo`/`b_ho` hold the full head input extents.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FuseGroup {
    pub start: usize,
    pub end: usize,
    pub b_n: u64,
    pub b_wo: u64,
    pub b_ho: u64,
}

impl FuseGroup {
    pub fn len(&self) -> usize {
        self.end - self.start + 1
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    /// More than one stage per tile sweep?
    pub fn is_fused(&self) -> bool {
        self.len() > 1
    }
}

/// The execution plan for one network pipeline: per-stage LP tile plans
/// (used by materialized stages) plus the fused grouping, the compute path
/// fused stages run ([`FusedExec`]) and the halo-cache switch.
#[derive(Debug, Clone)]
pub struct FusePlan {
    /// which network-level sweep this plan drives; shapes the grouping
    /// rule, the traffic model and the executor dispatch
    pub pass: NetPass,
    pub stages: Vec<NetworkStage>,
    /// fast-memory budget (words) the grouping was decided under
    pub mem_words: f64,
    /// per-stage *forward* LP tile plans (materialized forward stages and
    /// the step plan's phase-1 forward)
    pub stage_plans: Vec<Arc<TilePlan>>,
    /// per-stage dInput LP tile plans — materialized stages of backward
    /// and step plans; empty for forward plans
    pub dinput_plans: Vec<Arc<TilePlan>>,
    /// per-stage dFilter LP tile plans — materialized stages of step
    /// plans; empty otherwise
    pub dfilter_plans: Vec<Arc<TilePlan>>,
    pub groups: Vec<FuseGroup>,
    /// compute path fused stages run (bitwise-identical numerics and
    /// identical traffic either way; the backward/step sweeps always run
    /// the contract-preserving reference nests)
    pub exec: FusedExec,
    /// sliding-window halo cache on/off — shapes both the footprint rule
    /// and the analytic traffic model (forward: per-level input carries;
    /// backward: the tail gradient patch; step: unused)
    pub halo_cache: bool,
    /// w-axis halo carry on/off: additionally reuse the overlap *columns*
    /// adjacent w-tile-columns of a forward fused sweep share at the group
    /// head. Requires `halo_cache` (normalized off otherwise); meaningful
    /// only for [`NetPass::Forward`] plans.
    pub halo_w: bool,
}

impl FusePlan {
    /// Plan a network with the production defaults: packed fused stages
    /// and the sliding-window halo cache on.
    pub fn new(stages: &[NetworkStage], mem_words: f64, cache: &TilePlanCache) -> FusePlan {
        FusePlan::with_options(stages, mem_words, cache, FusedExec::Packed, true)
    }

    /// Plan a network: solve every stage's blocking LP (through the shared
    /// cache) and greedily fuse boundaries under the rule above.
    pub fn with_options(
        stages: &[NetworkStage],
        mem_words: f64,
        cache: &TilePlanCache,
        exec: FusedExec,
        halo_cache: bool,
    ) -> FusePlan {
        FusePlan::for_pass_with_options(
            NetPass::Forward,
            stages,
            mem_words,
            cache,
            exec,
            halo_cache,
            false,
        )
    }

    /// Plan `pass` over the network with the production defaults.
    pub fn for_pass(
        pass: NetPass,
        stages: &[NetworkStage],
        mem_words: f64,
        cache: &TilePlanCache,
    ) -> FusePlan {
        FusePlan::for_pass_with_options(
            pass,
            stages,
            mem_words,
            cache,
            FusedExec::Packed,
            true,
            false,
        )
    }

    /// Pass-generic planner: solve the pass's per-stage LPs (through the
    /// shared cache) and greedily fuse boundaries under the pass's fit and
    /// traffic rules — the same greedy walk for all three sweeps, with
    /// [`fit_pass_group_tile`] / [`pass_group_traffic`] supplying the
    /// pass-specific geometry.
    pub fn for_pass_with_options(
        pass: NetPass,
        stages: &[NetworkStage],
        mem_words: f64,
        cache: &TilePlanCache,
        exec: FusedExec,
        halo_cache: bool,
        halo_w: bool,
    ) -> FusePlan {
        assert!(!stages.is_empty(), "network must have at least one stage");
        // the w-carry rides on the sliding-window machinery and only the
        // forward sweep's tile columns chain along w
        let halo_w = halo_w && halo_cache && pass == NetPass::Forward;
        let stage_plans = solve_stage_plans(stages, mem_words, cache);
        let (dinput_plans, dfilter_plans) =
            solve_grad_plans(pass, stages, mem_words, cache);
        let singles = pass_singles(pass, &stage_plans, &dinput_plans, &dfilter_plans);
        let single_group = |i: usize| {
            let (b_n, b_wo, b_ho) =
                fit_pass_group_tile(pass, stages, i, i, mem_words, halo_cache, halo_w)
                    .unwrap_or((1, 1, 1));
            FuseGroup { start: i, end: i, b_n, b_wo, b_ho }
        };
        let mut groups = Vec::new();
        let mut cur = single_group(0);
        let mut cur_cost = singles[0];
        for i in 1..stages.len() {
            let mut extended = None;
            if let Some((b_n, b_wo, b_ho)) = fit_pass_group_tile(
                pass, stages, cur.start, i, mem_words, halo_cache, halo_w,
            ) {
                let cand = FuseGroup { start: cur.start, end: i, b_n, b_wo, b_ho };
                let cost =
                    pass_group_traffic(pass, stages, &cand, halo_cache, halo_w)
                        .total();
                if cost <= cur_cost + singles[i] {
                    extended = Some((cand, cost));
                }
            }
            match extended {
                Some((cand, cost)) => {
                    cur = cand;
                    cur_cost = cost;
                }
                None => {
                    groups.push(cur);
                    cur = single_group(i);
                    cur_cost = singles[i];
                }
            }
        }
        groups.push(cur);
        let plan = FusePlan {
            pass,
            stages: stages.to_vec(),
            mem_words,
            stage_plans,
            dinput_plans,
            dfilter_plans,
            groups,
            exec,
            halo_cache,
            halo_w,
        };
        plan.trace_plan();
        plan
    }

    /// A plan with every boundary materialized: each stage is a singleton
    /// group running the LP-tiled engine — the layer-by-layer execution
    /// mode the autotuner probes against the fused ones.
    pub fn materialized(
        stages: &[NetworkStage],
        mem_words: f64,
        cache: &TilePlanCache,
    ) -> FusePlan {
        FusePlan::materialized_pass(NetPass::Forward, stages, mem_words, cache)
    }

    /// A fully materialized plan for `pass` — the layer-by-layer
    /// backward / training-step baseline.
    pub fn materialized_pass(
        pass: NetPass,
        stages: &[NetworkStage],
        mem_words: f64,
        cache: &TilePlanCache,
    ) -> FusePlan {
        assert!(!stages.is_empty(), "network must have at least one stage");
        let stage_plans = solve_stage_plans(stages, mem_words, cache);
        let (dinput_plans, dfilter_plans) =
            solve_grad_plans(pass, stages, mem_words, cache);
        let groups = (0..stages.len())
            .map(|i| {
                let (b_n, b_wo, b_ho) =
                    fit_pass_group_tile(pass, stages, i, i, mem_words, false, false)
                        .unwrap_or((1, 1, 1));
                FuseGroup { start: i, end: i, b_n, b_wo, b_ho }
            })
            .collect();
        let plan = FusePlan {
            pass,
            stages: stages.to_vec(),
            mem_words,
            stage_plans,
            dinput_plans,
            dfilter_plans,
            groups,
            exec: FusedExec::Packed,
            halo_cache: false,
            halo_w: false,
        };
        plan.trace_plan();
        plan
    }

    /// Emit a `fuse_plan` trace event recording every fuse-vs-materialize
    /// decision this plan encodes (one entry per group, with the sweep's
    /// tile blocks). One branch when tracing is off.
    fn trace_plan(&self) {
        if !obs::enabled() {
            return;
        }
        let groups = Json::Arr(
            self.groups
                .iter()
                .map(|g| {
                    let mut o = BTreeMap::new();
                    o.insert("start".into(), ju(g.start as u64));
                    o.insert("end".into(), ju(g.end as u64));
                    o.insert("fused".into(), Json::Bool(g.is_fused()));
                    o.insert("b_n".into(), ju(g.b_n));
                    o.insert("b_wo".into(), ju(g.b_wo));
                    o.insert("b_ho".into(), ju(g.b_ho));
                    Json::Obj(o)
                })
                .collect(),
        );
        obs::event(
            obs::kind::FUSE_PLAN,
            &[
                ("pass", js(self.pass.name())),
                ("stages", ju(self.stages.len() as u64)),
                ("mem_words", jf(self.mem_words)),
                ("exec", js(self.exec.name())),
                ("halo_cache", Json::Bool(self.halo_cache)),
                ("halo_w", Json::Bool(self.halo_w)),
                ("fused_boundaries", ju(self.fused_boundaries() as u64)),
                ("groups", groups),
            ],
        );
    }

    /// Number of fused boundaries (adjacent stage pairs whose activation
    /// never materializes).
    pub fn fused_boundaries(&self) -> usize {
        self.groups.iter().map(|g| g.len() - 1).sum()
    }

    /// Words a per-stage traffic vector moves across this plan's *fused*
    /// boundaries. Zero for traffic measured by the fused executor — the
    /// engine's core claim, asserted by the CLI `--check`, the property
    /// tests and the bench JSON through this one definition. Which
    /// counters are boundary counters depends on the pass:
    ///
    /// * `Forward` — reads by any non-head fused stage plus writes by any
    ///   non-tail fused stage (the inter-layer activations).
    /// * `Backward` — the mirror: gradient reads (`input_words`) by any
    ///   non-*tail* stage plus gradient writes by any non-*head* stage;
    ///   legal traffic is the loss gradient in at the tail and the image
    ///   gradient out at the head.
    /// * `Step` — strict interior both ways: the head's activation read /
    ///   dInput write and the tail's loss-gradient read / boundary-act
    ///   write are the sweep's legal materializations (dFilter spills
    ///   live in `filter_words`), everything strictly between is fused.
    pub fn boundary_words(&self, stages: &[Traffic]) -> u64 {
        let mut words = 0;
        for g in &self.groups {
            match self.pass {
                NetPass::Forward => {
                    for k in g.start + 1..=g.end {
                        words += stages[k].input_words;
                    }
                    for k in g.start..g.end {
                        words += stages[k].output_words;
                    }
                }
                NetPass::Backward => {
                    for k in g.start..g.end {
                        words += stages[k].input_words;
                    }
                    for k in g.start + 1..=g.end {
                        words += stages[k].output_words;
                    }
                }
                NetPass::Step => {
                    for k in g.start + 1..g.end {
                        words += stages[k].input_words;
                        words += stages[k].output_words;
                    }
                }
            }
        }
        words
    }

    /// Whether this training-step plan is bitwise identical to the
    /// layer-by-layer SGD oracle: true iff every group that must
    /// materialize a boundary activation for downstream groups (all but
    /// the last) is fused. The fused phase-1 recompute and the backward
    /// nests follow the oracle accumulation order exactly; a materialized
    /// stage's forward runs the LP-tiled engine, whose reduction blocking
    /// reassociates sums.
    pub fn step_bitwise(&self) -> bool {
        self.pass == NetPass::Step
            && self.groups[..self.groups.len() - 1]
                .iter()
                .all(|g| g.is_fused())
    }

    /// The analytic per-stage traffic this plan executes. Fused forward
    /// groups charge the image patch (with halo; only the fresh rows once
    /// the sliding-window cache holds the overlap) at the group head, the
    /// full filter per stage per tile, and the output tile at the group
    /// tail; fused backward groups mirror that through the transposed
    /// stencil ([`charge_bwd_group`]); fused step groups charge per batch
    /// block ([`charge_step_group`]). Materialized stages charge their
    /// pass's LP tile plans. The executors' counters match these totals
    /// exactly.
    pub fn expected_network_traffic(&self) -> Vec<Traffic> {
        let mut t = vec![Traffic::default(); self.stages.len()];
        let last = self.groups.len() - 1;
        for (gi, g) in self.groups.iter().enumerate() {
            match self.pass {
                NetPass::Forward => {
                    if g.is_fused() {
                        charge_fused_group(
                            &self.stages,
                            g,
                            self.halo_cache,
                            self.halo_w,
                            &mut t,
                        );
                    } else {
                        t[g.start] = expected_traffic(&self.stage_plans[g.start]);
                    }
                }
                NetPass::Backward => {
                    if g.is_fused() {
                        charge_bwd_group(&self.stages, g, self.halo_cache, &mut t);
                    } else {
                        t[g.start] = expected_pass_traffic(&self.dinput_plans[g.start]);
                    }
                }
                NetPass::Step => {
                    if g.is_fused() {
                        charge_step_group(&self.stages, g, gi == last, &mut t);
                    } else {
                        let k = g.start;
                        let mut sum = Traffic::default();
                        if gi != last {
                            // phase 1 materializes this stage's output for
                            // the groups downstream; the last group's
                            // forward output is never needed
                            sum = expected_traffic(&self.stage_plans[k]);
                        }
                        for p in [
                            expected_pass_traffic(&self.dfilter_plans[k]),
                            expected_pass_traffic(&self.dinput_plans[k]),
                        ] {
                            sum.input_words += p.input_words;
                            sum.filter_words += p.filter_words;
                            sum.output_words += p.output_words;
                        }
                        t[k] = sum;
                    }
                }
            }
        }
        t
    }

    /// Words each stage's patches are expected to receive from the
    /// sliding-window halo cache instead of main memory, per stage. In a
    /// forward plan these are input rows served at group heads and rows
    /// spared from recompute at interior fused stages — plus, with the
    /// w-carry on, the head-level overlap *columns* served from the
    /// previous w-tile-column's carried patch (the carried corner where
    /// both overlaps meet is counted once). In a backward plan they are
    /// tail gradient rows served from the previous h-tile's carried
    /// patch. All zero when the cache is off, for step plans (batch
    /// blocks never overlap), or when every fused sweep has a single
    /// h-tile (and, for the w part, a single w-column). The executors'
    /// halo counters match these exactly.
    pub fn expected_halo_words(&self) -> Vec<u64> {
        let mut words = vec![0u64; self.stages.len()];
        if !self.halo_cache || self.pass == NetPass::Step {
            return words;
        }
        for g in &self.groups {
            if !g.is_fused() {
                continue;
            }
            if self.pass == NetPass::Backward {
                let tail = &self.stages[g.end].shape;
                for (tn, tw, hs) in bwd_group_tile_columns(&self.stages, g) {
                    let mut prev: Option<Span> = None;
                    for th in hs {
                        let spans =
                            bwd_group_spans(&self.stages, g.start, g.end, tw, th);
                        let gsp = spans[g.end - g.start];
                        if let Some(p) = prev {
                            let fresh_h0 = p.h1.clamp(gsp.h0, gsp.h1);
                            words[g.end] +=
                                tn.len * tail.c_o * gsp.w_len() * (fresh_h0 - gsp.h0);
                        }
                        prev = Some(gsp);
                    }
                }
                continue;
            }
            let overlaps = input_overlap_rows(&self.stages, g.start, g.end);
            let ovw0 = if self.halo_w {
                input_overlap_cols(&self.stages, g.start, g.end)[0]
            } else {
                0
            };
            // the w-carry chains a batch block's columns left to right, so
            // every column after a block's first has carried head columns
            let mut prev_tn: Option<u64> = None;
            for (tn, tw, hs) in group_tile_columns(&self.stages, g) {
                let first_col = prev_tn != Some(tn.start);
                prev_tn = Some(tn.start);
                for (i, th) in hs.iter().enumerate() {
                    let spans =
                        group_spans(&self.stages, g.start, g.end, tw, *th);
                    for k in g.start..=g.end {
                        let ch = if i > 0 { overlaps[k - g.start] } else { 0 };
                        let cw = if k == g.start && !first_col { ovw0 } else { 0 };
                        if ch == 0 && cw == 0 {
                            continue;
                        }
                        let s = &self.stages[k].shape;
                        let (iw, ih) = if k == g.start {
                            let sp = input_span(s, &spans[0]);
                            (sp.w_len(), sp.h_len())
                        } else {
                            let sp = &spans[k - g.start - 1];
                            (sp.w_len(), sp.h_len())
                        };
                        // carried L-shape: `ch` full-width rows plus `cw`
                        // full-height columns, minus the corner they share
                        words[k] +=
                            tn.len * s.c_i * (iw * ch + cw * ih - cw * ch);
                    }
                }
            }
        }
        words
    }
}

/// Solve (through the shared cache) every stage's LP tile plan.
fn solve_stage_plans(
    stages: &[NetworkStage],
    mem_words: f64,
    cache: &TilePlanCache,
) -> Vec<Arc<TilePlan>> {
    stages
        .iter()
        .map(|st| cache.plan(&st.shape, st.precision, mem_words))
        .collect()
}

/// Solve the gradient LP tile plans a pass's materialized stages run:
/// dInput for backward and step plans, dFilter additionally for step
/// plans. Forward plans carry neither.
fn solve_grad_plans(
    pass: NetPass,
    stages: &[NetworkStage],
    mem_words: f64,
    cache: &TilePlanCache,
) -> (Vec<Arc<TilePlan>>, Vec<Arc<TilePlan>>) {
    let dinput = if pass == NetPass::Forward {
        Vec::new()
    } else {
        stages
            .iter()
            .map(|st| {
                cache.plan_pass(ConvPass::DInput, &st.shape, st.precision, mem_words)
            })
            .collect()
    };
    let dfilter = if pass == NetPass::Step {
        stages
            .iter()
            .map(|st| {
                cache.plan_pass(ConvPass::DFilter, &st.shape, st.precision, mem_words)
            })
            .collect()
    } else {
        Vec::new()
    };
    (dinput, dfilter)
}

/// Per-stage analytic traffic of running stage `k` alone through the
/// pass's LP-tiled engine — the greedy planner's materialization
/// baseline. A step stage runs forward + dFilter + dInput.
fn pass_singles(
    pass: NetPass,
    stage_plans: &[Arc<TilePlan>],
    dinput_plans: &[Arc<TilePlan>],
    dfilter_plans: &[Arc<TilePlan>],
) -> Vec<u64> {
    match pass {
        NetPass::Forward => stage_plans
            .iter()
            .map(|p| expected_traffic(p).total())
            .collect(),
        NetPass::Backward => dinput_plans
            .iter()
            .map(|p| expected_pass_traffic(p).total())
            .collect(),
        NetPass::Step => (0..stage_plans.len())
            .map(|k| {
                expected_traffic(&stage_plans[k]).total()
                    + expected_pass_traffic(&dfilter_plans[k]).total()
                    + expected_pass_traffic(&dinput_plans[k]).total()
            })
            .collect(),
    }
}

/// Pass dispatch for the fit rule: find sweep tile blocks for
/// `stages[a..=b]` whose working set fits in `mem` words, or `None` when
/// the boundary must materialize. Forward tiles the tail's output grid,
/// backward the head's input-gradient grid, step the batch only.
pub(crate) fn fit_pass_group_tile(
    pass: NetPass,
    stages: &[NetworkStage],
    a: usize,
    b: usize,
    mem: f64,
    halo: bool,
    halo_w: bool,
) -> Option<(u64, u64, u64)> {
    match pass {
        NetPass::Forward => fit_group_tile(stages, a, b, mem, halo, halo_w),
        NetPass::Backward => fit_bwd_group_tile(stages, a, b, mem, halo),
        NetPass::Step => fit_step_group_tile(stages, a, b, mem),
    }
}

/// Pass dispatch for the greedy cost rule: total analytic traffic of one
/// fused group in isolation. Step groups are costed with their phase-1
/// forward included (conservative — the network's last group skips it).
pub(crate) fn pass_group_traffic(
    pass: NetPass,
    stages: &[NetworkStage],
    g: &FuseGroup,
    halo: bool,
    halo_w: bool,
) -> Traffic {
    match pass {
        NetPass::Forward => fused_group_traffic(stages, g, halo, halo_w),
        NetPass::Backward => bwd_group_traffic(stages, g, halo),
        NetPass::Step => step_group_traffic(stages, g),
    }
}

/// Absolute half-open output spans `[w0, w1) × [h0, h1)` of one stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct Span {
    pub w0: u64,
    pub w1: u64,
    pub h0: u64,
    pub h1: u64,
}

impl Span {
    pub(crate) fn w_len(&self) -> u64 {
        self.w1 - self.w0
    }

    pub(crate) fn h_len(&self) -> u64 {
        self.h1 - self.h0
    }
}

/// The input span `s` reads to produce output span `o`: starts at `σ·o0`,
/// ends one halo past the last output row. Never exceeds the stage's
/// paper-convention input extent, so no clamping is required anywhere.
pub(crate) fn input_span(s: &ConvShape, o: &Span) -> Span {
    Span {
        w0: s.s_w * o.w0,
        w1: s.s_w * (o.w1 - 1) + s.w_f,
        h0: s.s_h * o.h0,
        h1: s.s_h * (o.h1 - 1) + s.h_f,
    }
}

/// Output spans each stage of `stages[a..=b]` computes for one tile
/// `(tw, th)` of the last stage, in stage order (index 0 ↔ stage `a`).
/// Element `k−1` is both stage `k−1`'s output span and stage `k`'s input
/// span — the fused boundary where no main-memory traffic is charged.
pub(crate) fn group_spans(
    stages: &[NetworkStage],
    a: usize,
    b: usize,
    tw: Blk,
    th: Blk,
) -> Vec<Span> {
    let mut spans = vec![
        Span { w0: 0, w1: 0, h0: 0, h1: 0 };
        b - a + 1
    ];
    let mut cur = Span {
        w0: tw.start,
        w1: tw.start + tw.len,
        h0: th.start,
        h1: th.start + th.len,
    };
    for k in (a..=b).rev() {
        spans[k - a] = cur;
        cur = input_span(&stages[k].shape, &cur);
    }
    spans
}

/// Sliding-window overlap per stage: the number of h-rows of stage `k`'s
/// *input* that adjacent h-tiles of the group tail share. With
/// `S = Π σh` (stage `k` down to the tail) and `F` the accumulated halo
/// extent of one tail row, consecutive tail tiles `[t0, t1)` / `[t1, t2)`
/// need stage-k input rows `[S·t0, S·(t1−1) + F)` / `[S·t1, …)`: the
/// overlap `F − S` is tile-independent, and `σ ≤ f` (validated per stage)
/// keeps it ≥ 0. Index 0 ↔ stage `a` (the group head's image patch).
pub(crate) fn input_overlap_rows(stages: &[NetworkStage], a: usize, b: usize) -> Vec<u64> {
    let mut out = vec![0u64; b - a + 1];
    let (mut s, mut f) = (1u64, 1u64);
    for k in (a..=b).rev() {
        let sh = stages[k].shape.s_h;
        f = sh * (f - 1) + stages[k].shape.h_f;
        s *= sh;
        out[k - a] = f - s;
    }
    out
}

/// The w-axis mirror of [`input_overlap_rows`]: the number of w-columns of
/// stage `k`'s *input* that adjacent w-tile-columns of the group tail
/// share. The executor's w-carry uses only the head entry (index 0) —
/// interior boundaries are already traffic-free, so carrying their columns
/// would spend per-h-position buffers for no main-memory savings.
pub(crate) fn input_overlap_cols(stages: &[NetworkStage], a: usize, b: usize) -> Vec<u64> {
    let mut out = vec![0u64; b - a + 1];
    let (mut s, mut f) = (1u64, 1u64);
    for k in (a..=b).rev() {
        let sw = stages[k].shape.s_w;
        f = sw * (f - 1) + stages[k].shape.w_f;
        s *= sw;
        out[k - a] = f - s;
    }
    out
}

/// The (batch, wO) tile columns of a fused group's last stage, each with
/// the ordered h-blocks its sliding-window sweep iterates (h innermost).
/// The executor and the analytic traffic model walk these identically,
/// which is what keeps measured == expected exact with the halo cache on.
pub(crate) fn group_tile_columns(
    stages: &[NetworkStage],
    g: &FuseGroup,
) -> Vec<(Blk, Blk, Vec<Blk>)> {
    let last = &stages[g.end].shape;
    let ns = split(last.n, g.b_n);
    let ws = split(last.w_o, g.b_wo);
    let hs = split(last.h_o, g.b_ho);
    let mut cols = Vec::with_capacity(ns.len() * ws.len());
    for &tn in &ns {
        for &tw in &ws {
            cols.push((tn, tw, hs.clone()));
        }
    }
    cols
}

/// Peak fast-memory working set (words, under each stage's precision) of
/// one fused tile with last-stage output blocks `(bn, bwo, bho)` under the
/// packed execution model: at every stage the scratch input patch, its
/// packed panel, the output patch and the packed filter panel are live
/// simultaneously; patches of other stages are recycled. With `halo` the
/// per-stage sliding-window carry buffers — which persist across the
/// whole h-sweep — are added on top of the peak. With `halo_w` the
/// head-level w-carry buffers are added too: one per h-block position of
/// the column sweep (they all persist while a batch block's columns run),
/// each holding the head overlap columns at a full tile's patch height —
/// a sound overestimate for the sweep's ragged edge tiles, which is all a
/// fit rule needs.
pub(crate) fn group_footprint(
    stages: &[NetworkStage],
    a: usize,
    b: usize,
    bn: u64,
    bwo: u64,
    bho: u64,
    halo: bool,
    halo_w: bool,
) -> f64 {
    let overlaps = input_overlap_rows(stages, a, b);
    let mut peak: f64 = 0.0;
    let mut carry: f64 = 0.0;
    let (mut ow, mut oh) = (bwo, bho);
    for k in (a..=b).rev() {
        let st = &stages[k];
        let s = &st.shape;
        let iw = halo_extent(ow, s.s_w, s.w_f);
        let ih = halo_extent(oh, s.s_h, s.h_f);
        let (qw, qh, rw, rh) = filter_split_ranges(s);
        let (ew, eh) = (ow + qw - 1, oh + qh - 1);
        let words = st.precision.p_i
            * (bn * s.c_i * (iw * ih + rw * rh * ew * eh)) as f64
            + st.precision.p_o * (bn * s.c_o * ow * oh) as f64
            + st.precision.p_f * (s.c_i * qw * qh * rw * rh * s.c_o) as f64;
        peak = peak.max(words);
        if halo {
            carry += st.precision.p_i
                * (bn * s.c_i * iw * overlaps[k - a].min(ih)) as f64;
        }
        if halo_w && k == a {
            let ovw0 = input_overlap_cols(stages, a, b)[0];
            let h_o = stages[b].shape.h_o.max(1);
            let n_th = (h_o + bho - 1) / bho;
            carry += st.precision.p_i
                * (bn * s.c_i * ovw0.min(iw) * ih * n_th) as f64;
        }
        ow = iw;
        oh = ih;
    }
    peak + carry
}

/// Find last-stage output tile blocks whose fused working set fits in
/// `mem` words, shrinking the batch block first (halving N costs no halo
/// recompute) and then the larger spatial block. `None` when even a
/// 1×1×1 tile does not fit — the boundary must materialize.
pub(crate) fn fit_group_tile(
    stages: &[NetworkStage],
    a: usize,
    b: usize,
    mem: f64,
    halo: bool,
    halo_w: bool,
) -> Option<(u64, u64, u64)> {
    let last = &stages[b].shape;
    let (mut bn, mut bwo, mut bho) =
        (last.n.max(1), last.w_o.max(1), last.h_o.max(1));
    loop {
        if group_footprint(stages, a, b, bn, bwo, bho, halo, halo_w) <= mem {
            return Some((bn, bwo, bho));
        }
        if bn > 1 {
            bn = (bn + 1) / 2;
        } else if bwo >= bho && bwo > 1 {
            bwo = (bwo + 1) / 2;
        } else if bho > 1 {
            bho = (bho + 1) / 2;
        } else {
            return None;
        }
    }
}

/// Add one fused group's analytic per-stage traffic into `t` (indexed by
/// absolute stage number). Charges: head stage reads its halo'd image
/// patch per tile — only the fresh rows for non-first tiles of a column
/// when the sliding-window cache is on, and with the w-carry additionally
/// only the fresh columns for every column after a batch block's first
/// (the fresh region is the rectangle both carries leave uncovered);
/// every stage reads its full filter per tile; the tail stage writes its
/// output tile. Interior boundaries charge nothing — the invariant the
/// property tests pin down.
pub(crate) fn charge_fused_group(
    stages: &[NetworkStage],
    g: &FuseGroup,
    halo: bool,
    halo_w: bool,
    t: &mut [Traffic],
) {
    let head = &stages[g.start].shape;
    let tail = &stages[g.end].shape;
    // (batch-block start, head in-w1) of the previous tile column — the
    // w-carry only chains columns of the same batch block
    let mut prev_col: Option<(u64, u64)> = None;
    for (tn, tw, hs) in group_tile_columns(stages, g) {
        let prev_in_w1 = match prev_col {
            Some((n0, w1)) if halo_w && n0 == tn.start => Some(w1),
            _ => None,
        };
        let mut prev_in_h1: Option<u64> = None;
        let mut col_in_w1: Option<u64> = None;
        for th in hs {
            let spans = group_spans(stages, g.start, g.end, tw, th);
            let in_sp = input_span(head, &spans[0]);
            let fresh_h0 = prev_in_h1.map_or(in_sp.h0, |p| p.max(in_sp.h0));
            let fresh_w0 = prev_in_w1.map_or(in_sp.w0, |p| p.max(in_sp.w0));
            t[g.start].input_words += tn.len
                * head.c_i
                * (in_sp.w1 - fresh_w0)
                * (in_sp.h1 - fresh_h0);
            for k in g.start..=g.end {
                t[k].filter_words += stages[k].shape.filter_size();
            }
            t[g.end].output_words += tn.len * tail.c_o * tw.len * th.len;
            if halo {
                prev_in_h1 = Some(in_sp.h1);
            }
            col_in_w1 = Some(in_sp.w1);
        }
        if let Some(w1) = col_in_w1 {
            prev_col = Some((tn.start, w1));
        }
    }
}

/// Total analytic traffic of one fused group in isolation.
pub(crate) fn fused_group_traffic(
    stages: &[NetworkStage],
    g: &FuseGroup,
    halo: bool,
    halo_w: bool,
) -> Traffic {
    let mut t = vec![Traffic::default(); stages.len()];
    charge_fused_group(stages, g, halo, halo_w, &mut t);
    Traffic::sum(&t)
}

// ---------------------------------------------------------------------------
// Backward (dInput-chain) sweep geometry — NetPass::Backward
// ---------------------------------------------------------------------------

/// The output-gradient span stage `s` must consume to produce the
/// input-gradient span `o` — the transposed-stencil mirror of
/// [`input_span`]. Per axis this is `pack::dinput_span`: the output
/// positions whose forward stencil touches the input span. Input rows no
/// forward tap reads (the trailing `σ` paper-convention padding) collapse
/// the span to the canonical empty `0..0 × 0..0` — their gradient is
/// identically zero.
pub(crate) fn dout_span(s: &ConvShape, o: &Span) -> Span {
    let (w0, wl) = dinput_span(o.w0, o.w1 - o.w0, s.s_w, s.w_f, s.w_o);
    let (h0, hl) = dinput_span(o.h0, o.h1 - o.h0, s.s_h, s.h_f, s.h_o);
    if wl == 0 || hl == 0 {
        return Span { w0: 0, w1: 0, h0: 0, h1: 0 };
    }
    Span { w0, w1: w0 + wl, h0, h1: h0 + hl }
}

/// Output-gradient spans each stage of `stages[a..=b]` consumes for one
/// tile `(tw, th)` of the group *head's* input-gradient grid, in stage
/// order (index `k−a` ↔ stage `k`'s output-gradient span). Element
/// `b−a` is the span of `g_b` read from main memory; every earlier
/// element is produced in scratch by the next stage's dInput — the fused
/// gradient boundary where no traffic is charged. The walk runs head →
/// tail because gradient halos grow toward the tail, mirroring how
/// forward halos ([`group_spans`]) grow toward the head.
pub(crate) fn bwd_group_spans(
    stages: &[NetworkStage],
    a: usize,
    b: usize,
    tw: Blk,
    th: Blk,
) -> Vec<Span> {
    let mut spans = vec![
        Span { w0: 0, w1: 0, h0: 0, h1: 0 };
        b - a + 1
    ];
    let mut cur = Span {
        w0: tw.start,
        w1: tw.start + tw.len,
        h0: th.start,
        h1: th.start + th.len,
    };
    for k in a..=b {
        cur = dout_span(&stages[k].shape, &cur);
        spans[k - a] = cur;
    }
    spans
}

/// The (batch, w) tile columns of a backward sweep over the group head's
/// input-gradient grid, each with the ordered h-blocks its sliding-window
/// sweep iterates (h innermost). Walked identically by the fused backward
/// executor and the analytic model — measured == expected exact.
pub(crate) fn bwd_group_tile_columns(
    stages: &[NetworkStage],
    g: &FuseGroup,
) -> Vec<(Blk, Blk, Vec<Blk>)> {
    let head = &stages[g.start].shape;
    let ns = split(head.n, g.b_n);
    let ws = split(head.in_w(), g.b_wo);
    let hs = split(head.in_h(), g.b_ho);
    let mut cols = Vec::with_capacity(ns.len() * ws.len());
    for &tn in &ns {
        for &tw in &ws {
            cols.push((tn, tw, hs.clone()));
        }
    }
    cols
}

/// Upper bound on the output-gradient span length one input span of
/// extent `e` can require: `⌊(e + f − 2)/σ⌋ + 1`, clamped to the output
/// extent — the transposed-stencil analogue of [`halo_extent`], used by
/// the footprint rule (the exact span depends on boundary clamping, the
/// bound does not).
pub(crate) fn bwd_span_len_bound(e: u64, stride: u64, filter: u64, out: u64) -> u64 {
    if e == 0 || out == 0 {
        return 0;
    }
    (((e + filter - 2) / stride) + 1).min(out)
}

/// Peak fast-memory working set (words) of one backward tile with
/// head-input blocks `(bn, bwi, bhi)`: at each stage the output-gradient
/// patch, the input-gradient patch being produced and the stage's filter
/// are live simultaneously; patches ping-pong between stages. With `halo`
/// the carried copy of the previous tile's tail gradient patch persists
/// across the h-sweep and is added on top of the peak.
pub(crate) fn bwd_group_footprint(
    stages: &[NetworkStage],
    a: usize,
    b: usize,
    bn: u64,
    bwi: u64,
    bhi: u64,
    halo: bool,
) -> f64 {
    let mut peak: f64 = 0.0;
    let mut tail_patch: f64 = 0.0;
    let (mut ow, mut oh) = (bwi, bhi);
    for k in a..=b {
        let st = &stages[k];
        let s = &st.shape;
        let gw = bwd_span_len_bound(ow, s.s_w, s.w_f, s.w_o);
        let gh = bwd_span_len_bound(oh, s.s_h, s.h_f, s.h_o);
        let words = st.precision.p_o * (bn * s.c_o * gw * gh) as f64
            + st.precision.p_i * (bn * s.c_i * ow * oh) as f64
            + st.precision.p_f * s.filter_size() as f64;
        peak = peak.max(words);
        if k == b {
            tail_patch = st.precision.p_o * (bn * s.c_o * gw * gh) as f64;
        }
        ow = gw;
        oh = gh;
    }
    peak + if halo { tail_patch } else { 0.0 }
}

/// Find head input-gradient tile blocks whose backward working set fits
/// in `mem` words, shrinking the batch first and then the larger spatial
/// block — the mirror of [`fit_group_tile`]. `None` when even a 1×1×1
/// tile does not fit.
pub(crate) fn fit_bwd_group_tile(
    stages: &[NetworkStage],
    a: usize,
    b: usize,
    mem: f64,
    halo: bool,
) -> Option<(u64, u64, u64)> {
    let head = &stages[a].shape;
    let (mut bn, mut bwi, mut bhi) =
        (head.n.max(1), head.in_w().max(1), head.in_h().max(1));
    loop {
        if bwd_group_footprint(stages, a, b, bn, bwi, bhi, halo) <= mem {
            return Some((bn, bwi, bhi));
        }
        if bn > 1 {
            bn = (bn + 1) / 2;
        } else if bwi >= bhi && bwi > 1 {
            bwi = (bwi + 1) / 2;
        } else if bhi > 1 {
            bhi = (bhi + 1) / 2;
        } else {
            return None;
        }
    }
}

/// Add one fused backward group's analytic per-stage traffic into `t`.
/// Charges: the tail stage reads its loss-gradient span per tile — only
/// the fresh rows for non-first tiles of a column when the sliding-window
/// cache carries the previous patch; every stage reads its full filter
/// per tile; the head stage writes its full input-gradient tile (zeros
/// where no stencil tap lands). Interior gradient boundaries charge
/// nothing.
pub(crate) fn charge_bwd_group(
    stages: &[NetworkStage],
    g: &FuseGroup,
    halo: bool,
    t: &mut [Traffic],
) {
    let head = &stages[g.start].shape;
    let tail = &stages[g.end].shape;
    for (tn, tw, hs) in bwd_group_tile_columns(stages, g) {
        let mut prev: Option<Span> = None;
        for th in hs {
            let spans = bwd_group_spans(stages, g.start, g.end, tw, th);
            let gsp = spans[g.end - g.start];
            let fresh_h0 = prev.map_or(gsp.h0, |p| p.h1.clamp(gsp.h0, gsp.h1));
            t[g.end].input_words +=
                tn.len * tail.c_o * gsp.w_len() * (gsp.h1 - fresh_h0);
            for k in g.start..=g.end {
                t[k].filter_words += stages[k].shape.filter_size();
            }
            t[g.start].output_words += tn.len * head.c_i * tw.len * th.len;
            if halo {
                prev = Some(gsp);
            }
        }
    }
}

/// Total analytic traffic of one fused backward group in isolation.
pub(crate) fn bwd_group_traffic(
    stages: &[NetworkStage],
    g: &FuseGroup,
    halo: bool,
) -> Traffic {
    let mut t = vec![Traffic::default(); stages.len()];
    charge_bwd_group(stages, g, halo, &mut t);
    Traffic::sum(&t)
}

// ---------------------------------------------------------------------------
// Training-step sweep geometry — NetPass::Step
// ---------------------------------------------------------------------------

/// Fast-memory working set (words) of one step-sweep batch block: every
/// stage's activation patch stays resident across the forward recompute
/// and the backward walk (they are re-read by dFilter), the gradient
/// ping-pongs between two buffers sized by the largest per-stage
/// output-gradient block, the filter-gradient accumulators of the whole
/// group are resident (they receive direct `+=` in oracle order), and one
/// stage's filter is live at a time.
pub(crate) fn step_group_footprint(
    stages: &[NetworkStage],
    a: usize,
    b: usize,
    bn: u64,
) -> f64 {
    let mut acts = 0.0;
    let mut g_max: f64 = 0.0;
    let mut dfilters = 0.0;
    let mut filter_max: f64 = 0.0;
    for st in &stages[a..=b] {
        let s = &st.shape;
        acts += st.precision.p_i * (bn * s.c_i * s.in_w() * s.in_h()) as f64;
        g_max = g_max.max(st.precision.p_o * (bn * s.c_o * s.w_o * s.h_o) as f64);
        let fil = st.precision.p_f * s.filter_size() as f64;
        dfilters += fil;
        filter_max = filter_max.max(fil);
    }
    acts + 2.0 * g_max + dfilters + filter_max
}

/// Find a step-sweep batch block whose working set fits in `mem` words.
/// Only the batch shrinks: spatial tiling would split the dFilter
/// reduction over `(wO, hO)` and partial-batch blocks inside one
/// accumulator step would split it over `n`, both of which break the
/// backward bitwise contract (one scalar accumulator per `(element, n)`
/// over the full ascending `(wO, hO)` sweep, accumulators added in
/// ascending `n`). `b_wo`/`b_ho` carry the full head input extents.
pub(crate) fn fit_step_group_tile(
    stages: &[NetworkStage],
    a: usize,
    b: usize,
    mem: f64,
) -> Option<(u64, u64, u64)> {
    let head = &stages[a].shape;
    let mut bn = head.n.max(1);
    loop {
        if step_group_footprint(stages, a, b, bn) <= mem {
            return Some((bn, head.in_w().max(1), head.in_h().max(1)));
        }
        if bn > 1 {
            bn = (bn + 1) / 2;
        } else {
            return None;
        }
    }
}

/// Add one fused step group's analytic per-stage traffic into `t`. Per
/// batch block: unless this is the network's last group, a phase-1
/// forward pass materializes the group's output activation for the
/// groups downstream (head read + per-stage filters + tail write); the
/// phase-2 training sweep then re-reads the head activation block,
/// recomputes the interior activations (filters of every stage but the
/// tail — the tail's forward output is never needed), reads the tail
/// loss-gradient block, walks the dInput chain (every stage's filter once
/// more, a single live filter slot at a time), and writes the head
/// input-gradient block. The filter gradients spill exactly once per
/// group at the end of the sweep, charged to `filter_words`.
pub(crate) fn charge_step_group(
    stages: &[NetworkStage],
    g: &FuseGroup,
    last_group: bool,
    t: &mut [Traffic],
) {
    let head = &stages[g.start].shape;
    let tail = &stages[g.end].shape;
    let head_words = head.c_i * head.in_w() * head.in_h();
    let tail_words = tail.c_o * tail.w_o * tail.h_o;
    for tn in split(head.n, g.b_n) {
        if !last_group {
            t[g.start].input_words += tn.len * head_words;
            for k in g.start..=g.end {
                t[k].filter_words += stages[k].shape.filter_size();
            }
            t[g.end].output_words += tn.len * tail_words;
        }
        t[g.start].input_words += tn.len * head_words;
        for k in g.start..g.end {
            t[k].filter_words += stages[k].shape.filter_size();
        }
        t[g.end].input_words += tn.len * tail_words;
        for k in g.start..=g.end {
            t[k].filter_words += stages[k].shape.filter_size();
        }
        t[g.start].output_words += tn.len * head_words;
    }
    for k in g.start..=g.end {
        t[k].filter_words += stages[k].shape.filter_size();
    }
}

/// Total analytic traffic of one fused step group in isolation, costed
/// with its phase-1 forward included (the greedy rule's conservative
/// estimate — the network's last group skips phase 1 at execution).
pub(crate) fn step_group_traffic(stages: &[NetworkStage], g: &FuseGroup) -> Traffic {
    let mut t = vec![Traffic::default(); stages.len()];
    charge_step_group(stages, g, false, &mut t);
    Traffic::sum(&t)
}

/// The stage-by-stage oracle: run the chain through [`conv7nl_naive`] on
/// full tensors, materializing every activation. Fused groups of the
/// network executor perform this exact per-element accumulation order, so
/// a plan fused end to end reproduces this output bitwise.
pub fn naive_network(image: &Tensor4, filters: &[&Tensor4], stages: &[NetworkStage]) -> Tensor4 {
    assert_eq!(filters.len(), stages.len(), "one filter per stage");
    let mut act = image.clone();
    for (k, st) in stages.iter().enumerate() {
        act = conv7nl_naive(&act, filters[k], &st.shape);
    }
    act
}

/// The layer-by-layer backward oracle: chain [`dinput_naive`] from the
/// loss gradient at the tail down to the image gradient, materializing
/// every intermediate gradient. The fused backward executor performs this
/// exact per-element accumulation order, so every backward plan — fused,
/// mixed or materialized — reproduces it bitwise.
pub fn naive_network_bwd(
    gout: &Tensor4,
    filters: &[&Tensor4],
    stages: &[NetworkStage],
) -> Tensor4 {
    assert_eq!(filters.len(), stages.len(), "one filter per stage");
    let mut g = gout.clone();
    for (k, st) in stages.iter().enumerate().rev() {
        let s = &st.shape;
        g = dinput_naive(&g, filters[k], s, s.in_w() as usize, s.in_h() as usize);
    }
    g
}

/// The layer-by-layer SGD training-step oracle: forward through
/// [`conv7nl_naive`] materializing every activation, then walk the stages
/// in reverse chaining [`dfilter_naive`] / [`dinput_naive`]. Returns the
/// per-stage filter gradients and the image gradient. A step plan whose
/// non-last groups are all fused ([`FusePlan::step_bitwise`]) reproduces
/// both bitwise.
pub fn naive_network_step(
    image: &Tensor4,
    filters: &[&Tensor4],
    gout: &Tensor4,
    stages: &[NetworkStage],
) -> (Vec<Tensor4>, Tensor4) {
    assert_eq!(filters.len(), stages.len(), "one filter per stage");
    let mut acts = Vec::with_capacity(stages.len());
    acts.push(image.clone());
    for (k, st) in stages.iter().enumerate().take(stages.len() - 1) {
        let next = conv7nl_naive(&acts[k], filters[k], &st.shape);
        acts.push(next);
    }
    let mut dfilters: Vec<Tensor4> = Vec::with_capacity(stages.len());
    let mut g = gout.clone();
    for (k, st) in stages.iter().enumerate().rev() {
        let s = &st.shape;
        dfilters.push(dfilter_naive(&acts[k], &g, s));
        g = dinput_naive(&g, filters[k], s, s.in_w() as usize, s.in_h() as usize);
    }
    dfilters.reverse();
    (dfilters, g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::Precision;
    use crate::runtime::manifest::NetworkSpec;

    fn tiny(batch: u64) -> Vec<NetworkStage> {
        NetworkSpec::tiny_resnet(batch).stages
    }

    #[test]
    fn halo_extent_matches_hand_cases() {
        assert_eq!(halo_extent(4, 1, 3), 6); // unit stride 3x3: len + 2
        assert_eq!(halo_extent(4, 2, 2), 8); // stride-2 2x2: 2·3 + 2
        assert_eq!(halo_extent(1, 3, 5), 5); // single row: just the filter
    }

    #[test]
    fn spans_chain_through_the_group() {
        let stages = tiny(2);
        let tw = Blk { start: 1, len: 2 };
        let th = Blk { start: 0, len: 4 };
        let spans = group_spans(&stages, 0, 2, tw, th);
        assert_eq!(spans.len(), 3);
        // last stage's span is the tile itself
        assert_eq!(spans[2], Span { w0: 1, w1: 3, h0: 0, h1: 4 });
        // stage 1 output span = stage 2 input span (stride 2, 2x2 filter)
        assert_eq!(spans[1], Span { w0: 2, w1: 6, h0: 0, h1: 8 });
        // stage 0 output span = stage 1 input span (unit stride, 3x3)
        assert_eq!(spans[0], Span { w0: 2, w1: 8, h0: 0, h1: 10 });
        // the image patch adds one more halo
        let img = input_span(&stages[0].shape, &spans[0]);
        assert_eq!(img, Span { w0: 2, w1: 10, h0: 0, h1: 12 });
    }

    #[test]
    fn overlap_rows_match_hand_cases() {
        let stages = tiny(2);
        // walking up from the tail: stage 2 (2x2 stride 2) -> F=2, S=2:
        // adjacent tiles share nothing; stage 1 (3x3 unit) -> F=4, S=2:
        // overlap 2; stage 0 image patch -> F=6, S=2: overlap 4
        assert_eq!(input_overlap_rows(&stages, 0, 2), vec![4, 2, 0]);
        // single unit-stride 3x3 stage: classic f − σ = 2
        assert_eq!(input_overlap_rows(&stages, 0, 0), vec![2]);
        // consistency with the span walk: consecutive tiles of stage 2
        let a = group_spans(&stages, 0, 2, Blk { start: 0, len: 4 }, Blk { start: 0, len: 2 });
        let b = group_spans(&stages, 0, 2, Blk { start: 0, len: 4 }, Blk { start: 2, len: 2 });
        let ia = input_span(&stages[0].shape, &a[0]);
        let ib = input_span(&stages[0].shape, &b[0]);
        assert_eq!(ia.h1 - ib.h0, 4, "head overlap");
        assert_eq!(a[0].h1 - b[0].h0, 2, "stage-1 input overlap");
    }

    #[test]
    fn tiny_resnet_fuses_end_to_end_at_default_memory() {
        let cache = TilePlanCache::new();
        let plan = FusePlan::new(&tiny(4), super::super::plan::DEFAULT_TILE_MEM_WORDS, &cache);
        assert_eq!(plan.groups.len(), 1, "groups {:?}", plan.groups);
        assert!(plan.groups[0].is_fused());
        assert_eq!(plan.fused_boundaries(), 2);
        // fused traffic strictly below the layer-by-layer sum
        let fused: u64 = Traffic::sum(&plan.expected_network_traffic()).total();
        let layered: u64 = plan
            .stage_plans
            .iter()
            .map(|p| expected_traffic(p).total())
            .sum();
        assert!(fused < layered, "fused {fused} vs layered {layered}");
    }

    #[test]
    fn deep_mixnet_plan_mixes_fused_and_materialized_groups() {
        // the builtin deep pipeline: the 5x5 stage's filter panel alone
        // exceeds the default budget, so it must land in a materialized
        // singleton while the shallow head fuses — the mixed path CI
        // exercises by default
        let net = NetworkSpec::deep_mixnet(4);
        let cache = TilePlanCache::new();
        let plan = FusePlan::new(
            &net.stages,
            super::super::plan::DEFAULT_TILE_MEM_WORDS,
            &cache,
        );
        assert!(
            plan.groups.iter().any(|g| g.is_fused()),
            "groups {:?}",
            plan.groups
        );
        assert!(
            plan.groups.iter().any(|g| !g.is_fused()),
            "groups {:?}",
            plan.groups
        );
        assert!(
            plan.groups.iter().any(|g| g.start == 3 && g.end == 3),
            "the 5x5 stage must materialize: {:?}",
            plan.groups
        );
    }

    #[test]
    fn materialized_plan_has_no_fused_groups() {
        let cache = TilePlanCache::new();
        let stages = tiny(4);
        let plan = FusePlan::materialized(
            &stages,
            super::super::plan::DEFAULT_TILE_MEM_WORDS,
            &cache,
        );
        assert_eq!(plan.groups.len(), stages.len());
        assert_eq!(plan.fused_boundaries(), 0);
        assert!(plan.expected_halo_words().iter().all(|&w| w == 0));
    }

    #[test]
    fn tight_memory_forces_materialization() {
        // a budget below any two-stage working set must split every
        // boundary; every group then runs the plain LP-tiled path
        let stages = tiny(4);
        let two_stage_floor = group_footprint(&stages, 0, 1, 1, 1, 1, true, false)
            .min(group_footprint(&stages, 1, 2, 1, 1, 1, true, false));
        let cache = TilePlanCache::new();
        let plan = FusePlan::new(&stages, two_stage_floor - 1.0, &cache);
        assert_eq!(plan.groups.len(), 3, "groups {:?}", plan.groups);
        assert_eq!(plan.fused_boundaries(), 0);
    }

    #[test]
    fn footprint_grows_with_tile_and_group() {
        let stages = tiny(2);
        let small = group_footprint(&stages, 1, 1, 1, 2, 2, true, false);
        let wider = group_footprint(&stages, 1, 1, 1, 4, 4, true, false);
        assert!(wider > small);
        let deeper = group_footprint(&stages, 0, 2, 1, 2, 2, true, false);
        let tail_only = group_footprint(&stages, 2, 2, 1, 2, 2, true, false);
        assert!(deeper >= tail_only);
        // the halo carries only add footprint, the w-carry on top of that
        assert!(
            group_footprint(&stages, 0, 2, 1, 2, 2, true, false)
                >= group_footprint(&stages, 0, 2, 1, 2, 2, false, false)
        );
        assert!(
            group_footprint(&stages, 0, 2, 1, 2, 2, true, true)
                > group_footprint(&stages, 0, 2, 1, 2, 2, true, false)
        );
    }

    #[test]
    fn fit_group_tile_respects_budget() {
        let stages = tiny(4);
        let (bn, bwo, bho) = fit_group_tile(&stages, 0, 2, 4096.0, true, false)
            .expect("some tile fits");
        assert!(
            group_footprint(&stages, 0, 2, bn, bwo, bho, true, false) <= 4096.0
        );
        let last = &stages[2].shape;
        assert!(bn <= last.n && bwo <= last.w_o && bho <= last.h_o);
        // absurdly small budgets cannot host even a unit tile
        assert!(fit_group_tile(&stages, 0, 2, 8.0, true, false).is_none());
        // the w-carry buffers tighten the fit but never past the budget
        if let Some((bn, bwo, bho)) =
            fit_group_tile(&stages, 0, 2, 4096.0, true, true)
        {
            assert!(
                group_footprint(&stages, 0, 2, bn, bwo, bho, true, true)
                    <= 4096.0
            );
        }
    }

    #[test]
    fn group_tile_columns_cover_last_stage_output() {
        let stages = tiny(3);
        let g = FuseGroup { start: 0, end: 2, b_n: 2, b_wo: 3, b_ho: 2 };
        let last = &stages[2].shape;
        let mut seen = vec![false; (last.n * last.w_o * last.h_o) as usize];
        for (tn, tw, hs) in group_tile_columns(&stages, &g) {
            for th in hs {
                for n in tn.start..tn.start + tn.len {
                    for w in tw.start..tw.start + tw.len {
                        for h in th.start..th.start + th.len {
                            let i = ((n * last.w_o + w) * last.h_o + h) as usize;
                            assert!(!seen[i], "overlap");
                            seen[i] = true;
                        }
                    }
                }
            }
        }
        assert!(seen.into_iter().all(|v| v), "not covered");
    }

    #[test]
    fn halo_model_discounts_head_re_reads_only() {
        // with several h-tiles the cached model must charge strictly less
        // head input traffic, identical filter/output traffic
        let stages = tiny(4);
        let g = FuseGroup { start: 0, end: 2, b_n: 4, b_wo: 4, b_ho: 1 };
        let with = fused_group_traffic(&stages, &g, true, false);
        let without = fused_group_traffic(&stages, &g, false, false);
        assert!(with.input_words < without.input_words);
        assert_eq!(with.filter_words, without.filter_words);
        assert_eq!(with.output_words, without.output_words);
    }

    #[test]
    fn overlap_cols_mirror_rows_on_square_stencils() {
        // tiny_resnet is square in filters and strides, so the w overlap
        // chain must equal the h one
        let stages = tiny(2);
        assert_eq!(
            input_overlap_cols(&stages, 0, 2),
            input_overlap_rows(&stages, 0, 2)
        );
        assert_eq!(input_overlap_cols(&stages, 0, 0), vec![2]);
    }

    #[test]
    fn w_carry_discounts_head_columns_and_serves_the_rest() {
        // narrow w-columns and h-tiles together: the w-carry must charge
        // strictly less head input than the h-carry alone, touch nothing
        // else, and the L-shaped serve accounting must complement the
        // charge exactly (charged fresh + served carry == uncached charge
        // at the head, tile by tile)
        let stages = tiny(4);
        let g = FuseGroup { start: 0, end: 2, b_n: 4, b_wo: 1, b_ho: 1 };
        let h_only = fused_group_traffic(&stages, &g, true, false);
        let both = fused_group_traffic(&stages, &g, true, true);
        assert!(both.input_words < h_only.input_words);
        assert_eq!(both.filter_words, h_only.filter_words);
        assert_eq!(both.output_words, h_only.output_words);
        let mk = |halo_w| FusePlan {
            pass: NetPass::Forward,
            stages: stages.clone(),
            mem_words: 0.0,
            stage_plans: Vec::new(),
            dinput_plans: Vec::new(),
            dfilter_plans: Vec::new(),
            groups: vec![g],
            exec: FusedExec::Reference,
            halo_cache: true,
            halo_w,
        };
        let mut none = vec![Traffic::default(); stages.len()];
        charge_fused_group(&stages, &g, false, false, &mut none);
        for halo_w in [false, true] {
            let mut t = vec![Traffic::default(); stages.len()];
            charge_fused_group(&stages, &g, true, halo_w, &mut t);
            let serve = mk(halo_w).expected_halo_words();
            assert_eq!(
                t[0].input_words + serve[0],
                none[0].input_words,
                "head charge + serve must be carry-invariant (halo_w {halo_w})"
            );
            assert!(serve[0] > 0);
        }
    }

    #[test]
    fn per_stage_precision_shapes_the_footprint() {
        let shape = ConvShape::new(2, 4, 4, 6, 6, 3, 3, 1, 1);
        let cheap = [NetworkStage { shape, precision: Precision::gemmini() }];
        let wide = [NetworkStage { shape, precision: Precision::paper_mixed() }];
        assert!(
            group_footprint(&cheap, 0, 0, 2, 6, 6, true, false)
                < group_footprint(&wide, 0, 0, 2, 6, 6, true, false)
        );
    }

    #[test]
    fn net_pass_names_round_trip() {
        for pass in NetPass::ALL {
            assert_eq!(NetPass::parse(pass.name()), Some(pass));
        }
        assert_eq!(NetPass::parse("forward"), Some(NetPass::Forward));
        assert_eq!(NetPass::parse("backward"), Some(NetPass::Backward));
        assert_eq!(NetPass::parse("training"), Some(NetPass::Step));
        assert_eq!(NetPass::parse("sideways"), None);
    }

    #[test]
    fn dout_spans_chain_through_the_group() {
        let stages = tiny(2);
        let tw = Blk { start: 0, len: 4 };
        let th = Blk { start: 2, len: 3 };
        let spans = bwd_group_spans(&stages, 0, 2, tw, th);
        assert_eq!(spans.len(), 3);
        // stage 0 (unit stride 3x3, 13x13 out): input rows [2,5) are
        // touched by output rows [0,5); cols [0,4) by outputs [0,4)
        assert_eq!(spans[0], Span { w0: 0, w1: 4, h0: 0, h1: 5 });
        // stage 1 consumes stage 0's output grid directly
        assert_eq!(spans[1], Span { w0: 0, w1: 4, h0: 0, h1: 5 });
        // stage 2 (2x2 stride 2, 4x4 out): rows [0,5) -> outputs [0,3)
        assert_eq!(spans[2], Span { w0: 0, w1: 2, h0: 0, h1: 3 });
    }

    #[test]
    fn dout_span_collapses_on_padding_rows() {
        // the paper convention pads σ trailing rows no forward tap reads:
        // their gradient span is empty and stays empty up the chain
        let stages = tiny(2);
        let pad = Span { w0: 0, w1: 1, h0: 15, h1: 16 };
        let sp = dout_span(&stages[0].shape, &pad);
        assert_eq!(sp, Span { w0: 0, w1: 0, h0: 0, h1: 0 });
        let spans = bwd_group_spans(
            &stages,
            0,
            2,
            Blk { start: 0, len: 1 },
            Blk { start: 15, len: 1 },
        );
        assert!(spans.iter().all(|s| s.w_len() == 0 && s.h_len() == 0));
    }

    #[test]
    fn bwd_span_len_bound_dominates_actual_spans() {
        let stages = tiny(2);
        let s = &stages[2].shape;
        for start in 0..s.in_h() {
            for len in 1..=(s.in_h() - start) {
                let (_, hl) = super::super::pack::dinput_span(
                    start, len, s.s_h, s.h_f, s.h_o,
                );
                assert!(hl <= bwd_span_len_bound(len, s.s_h, s.h_f, s.h_o));
            }
        }
        assert_eq!(bwd_span_len_bound(0, 2, 2, 4), 0);
        assert_eq!(bwd_span_len_bound(5, 1, 1, 2), 2); // clamped to out
    }

    #[test]
    fn bwd_tile_columns_cover_head_input_grid() {
        let stages = tiny(3);
        let g = FuseGroup { start: 0, end: 2, b_n: 2, b_wo: 5, b_ho: 7 };
        let head = &stages[0].shape;
        let (iw, ih) = (head.in_w(), head.in_h());
        let mut seen = vec![false; (head.n * iw * ih) as usize];
        for (tn, tw, hs) in bwd_group_tile_columns(&stages, &g) {
            for th in hs {
                for n in tn.start..tn.start + tn.len {
                    for w in tw.start..tw.start + tw.len {
                        for h in th.start..th.start + th.len {
                            let i = ((n * iw + w) * ih + h) as usize;
                            assert!(!seen[i], "overlap");
                            seen[i] = true;
                        }
                    }
                }
            }
        }
        assert!(seen.into_iter().all(|v| v), "not covered");
    }

    #[test]
    fn backward_plan_fuses_tiny_resnet_below_layered_traffic() {
        let cache = TilePlanCache::new();
        let plan = FusePlan::for_pass(
            NetPass::Backward,
            &tiny(4),
            super::super::plan::DEFAULT_TILE_MEM_WORDS,
            &cache,
        );
        assert_eq!(plan.pass, NetPass::Backward);
        assert_eq!(plan.groups.len(), 1, "groups {:?}", plan.groups);
        assert!(plan.groups[0].is_fused());
        assert_eq!(plan.dinput_plans.len(), 3);
        assert!(plan.dfilter_plans.is_empty());
        let fused = Traffic::sum(&plan.expected_network_traffic()).total();
        let layered: u64 = plan
            .dinput_plans
            .iter()
            .map(|p| expected_pass_traffic(p).total())
            .sum();
        assert!(fused < layered, "fused {fused} vs layered {layered}");
    }

    #[test]
    fn bwd_halo_model_discounts_tail_re_reads_only() {
        let stages = tiny(4);
        let g = FuseGroup { start: 0, end: 2, b_n: 4, b_wo: 16, b_ho: 2 };
        let with = bwd_group_traffic(&stages, &g, true);
        let without = bwd_group_traffic(&stages, &g, false);
        assert!(with.input_words < without.input_words);
        assert_eq!(with.filter_words, without.filter_words);
        assert_eq!(with.output_words, without.output_words);
        // the full-tile dIn writes and per-tile filter reads are exact:
        // one (n, w) column of 8 h-tiles covers the whole head input grid
        let head = &stages[0].shape;
        assert_eq!(
            without.output_words,
            head.n * head.c_i * head.in_w() * head.in_h()
        );
        let per_tile_filters: u64 =
            (0..3).map(|k| stages[k].shape.filter_size()).sum();
        assert_eq!(without.filter_words, 8 * per_tile_filters);
    }

    #[test]
    fn step_plan_tiles_batch_only_and_is_bitwise() {
        let cache = TilePlanCache::new();
        let stages = tiny(4);
        let plan = FusePlan::for_pass(
            NetPass::Step,
            &stages,
            super::super::plan::DEFAULT_TILE_MEM_WORDS,
            &cache,
        );
        assert_eq!(plan.groups.len(), 1, "groups {:?}", plan.groups);
        let g = plan.groups[0];
        assert!(g.is_fused());
        let head = &stages[0].shape;
        assert_eq!((g.b_wo, g.b_ho), (head.in_w(), head.in_h()));
        assert!(plan.step_bitwise());
        assert_eq!(plan.dinput_plans.len(), 3);
        assert_eq!(plan.dfilter_plans.len(), 3);
        // step plans have no halo discount by construction
        assert!(plan.expected_halo_words().iter().all(|&w| w == 0));
        // a materialized step plan is never bitwise on a multi-stage net
        let mat = FusePlan::materialized_pass(
            NetPass::Step,
            &stages,
            super::super::plan::DEFAULT_TILE_MEM_WORDS,
            &cache,
        );
        assert!(!mat.step_bitwise());
    }

    #[test]
    fn step_footprint_forces_batch_halving_under_tight_memory() {
        let stages = tiny(4);
        let full = step_group_footprint(&stages, 0, 2, 4);
        let (bn, bwi, bhi) =
            fit_step_group_tile(&stages, 0, 2, full - 1.0).expect("halved fits");
        assert!(bn < 4);
        let head = &stages[0].shape;
        assert_eq!((bwi, bhi), (head.in_w(), head.in_h()));
        assert!(step_group_footprint(&stages, 0, 2, bn) <= full - 1.0);
        assert!(fit_step_group_tile(&stages, 0, 2, 8.0).is_none());
    }

    #[test]
    fn boundary_words_are_pass_aware() {
        let mk = |pass| FusePlan {
            pass,
            stages: tiny(2),
            mem_words: 0.0,
            stage_plans: Vec::new(),
            dinput_plans: Vec::new(),
            dfilter_plans: Vec::new(),
            groups: vec![FuseGroup { start: 0, end: 2, b_n: 1, b_wo: 1, b_ho: 1 }],
            exec: FusedExec::Reference,
            halo_cache: false,
            halo_w: false,
        };
        let t = [
            Traffic { input_words: 1, filter_words: 100, output_words: 10 },
            Traffic { input_words: 2, filter_words: 100, output_words: 20 },
            Traffic { input_words: 4, filter_words: 100, output_words: 40 },
        ];
        // forward: interior reads are stages 1..=2, interior writes 0..2
        assert_eq!(mk(NetPass::Forward).boundary_words(&t), 2 + 4 + 10 + 20);
        // backward mirror: reads 0..2, writes 1..=2
        assert_eq!(mk(NetPass::Backward).boundary_words(&t), 1 + 2 + 20 + 40);
        // step: strict interior both ways (stage 1 only); dF spills are
        // filter_words and never boundary traffic
        assert_eq!(mk(NetPass::Step).boundary_words(&t), 2 + 20);
    }

    #[test]
    fn step_charge_skips_phase_one_for_the_last_group() {
        let stages = tiny(4);
        let g = FuseGroup { start: 0, end: 2, b_n: 4, b_wo: 16, b_ho: 16 };
        let mut interior = vec![Traffic::default(); 3];
        charge_step_group(&stages, &g, false, &mut interior);
        let mut last = vec![Traffic::default(); 3];
        charge_step_group(&stages, &g, true, &mut last);
        let (ti, tl) = (Traffic::sum(&interior), Traffic::sum(&last));
        assert!(ti.total() > tl.total());
        // the last group writes only the head input gradient
        let head = &stages[0].shape;
        assert_eq!(tl.output_words, head.n * head.c_i * head.in_w() * head.in_h());
        assert_eq!(last[1].input_words, 0);
        assert_eq!(last[1].output_words, 0);
    }

    #[test]
    fn training_oracles_are_shape_consistent() {
        use crate::conv::pass_operands;
        let stages = tiny(2);
        let head = &stages[0].shape;
        let tail = &stages[2].shape;
        let (image, _) = crate::conv::paper_operands(head, 7);
        let filters: Vec<Tensor4> = stages
            .iter()
            .enumerate()
            .map(|(k, st)| crate::conv::paper_operands(&st.shape, 11 + k as u64).1)
            .collect();
        let refs: Vec<&Tensor4> = filters.iter().collect();
        let (gout, _) = pass_operands(ConvPass::DInput, tail, 23);
        let din = naive_network_bwd(&gout, &refs, &stages);
        assert_eq!(
            din.dims,
            [
                head.n as usize,
                head.c_i as usize,
                head.in_w() as usize,
                head.in_h() as usize
            ]
        );
        let (dfs, din2) = naive_network_step(&image, &refs, &gout, &stages);
        assert_eq!(dfs.len(), 3);
        for (k, df) in dfs.iter().enumerate() {
            let s = &stages[k].shape;
            assert_eq!(
                df.dims,
                [
                    s.c_i as usize,
                    s.c_o as usize,
                    s.w_f as usize,
                    s.h_f as usize
                ]
            );
        }
        // the step oracle's dInput chain is the backward oracle verbatim
        assert_eq!(din2.data, din.data);
        // trailing padding rows of the image carry zero gradient
        for n in 0..head.n as usize {
            for c in 0..head.c_i as usize {
                for w in 0..head.in_w() as usize {
                    for h in (head.in_h() as usize - head.s_h as usize)
                        ..head.in_h() as usize
                    {
                        assert_eq!(din.at(n, c, w, h), 0.0);
                    }
                }
            }
        }
    }
}
