//! The multi-layer fusion planner: walks adjacent stages of a
//! [`NetworkSpec`] chain and decides, per boundary, whether the two stages
//! execute inside one tile sweep (fused — the inter-layer activation stays
//! resident in scratch buffers and never touches main memory) or
//! materialize the full activation tensor between them.
//!
//! **Halo math.** Sweeping output tiles of the *last* stage of a fused
//! group, an output block of extent `e` needs an input span of
//! `σ·(e − 1) + f` rows from the stage above ([`halo_extent`]); applied
//! recursively up the group, each upstream stage's required activation
//! tile grows by one halo per layer. [`group_spans`] performs exactly this
//! walk for one concrete tile and is shared by the fused executor and the
//! analytic traffic model, so measured and expected traffic agree word for
//! word.
//!
//! **Fuse-vs-materialize rule** (DESIGN.md §7). A boundary fuses when
//! (a) a tile of the candidate group exists whose peak ping-pong working
//! set — input patch + output patch + filter of the widest stage — fits in
//! the memory budget `M` ([`fit_group_tile`]), and (b) the analytic fused
//! traffic of the extended group does not exceed the traffic of leaving
//! the boundary materialized (the current group plus the next stage run
//! layer-by-layer through the LP-tiled engine). Rule (b) guards against
//! fusing past the point where halo recompute and per-tile filter re-reads
//! outweigh the saved activation round-trip, and makes `fused ≤ unfused`
//! hold by construction.

use std::sync::Arc;

use crate::conv::{conv7nl_naive, ConvShape, NetworkStage, Tensor4};

use super::exec::{expected_traffic, Traffic};
use super::plan::{TilePlan, TilePlanCache};
use super::tiles::{split, Blk};

/// Input span one output block of `len` elements needs upstream:
/// `σ·(len − 1) + f`.
pub fn halo_extent(len: u64, stride: u64, filter: u64) -> u64 {
    stride * (len.max(1) - 1) + filter
}

/// One contiguous run of stages executed per tile sweep. `start..=end`
/// index into the network's stage list; `b_n`/`b_wo`/`b_ho` are the
/// output-tile blocks of the *last* stage the fused sweep iterates
/// (meaningful when `is_fused()`; single-stage groups execute through the
/// stage's own LP [`TilePlan`] instead).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FuseGroup {
    pub start: usize,
    pub end: usize,
    pub b_n: u64,
    pub b_wo: u64,
    pub b_ho: u64,
}

impl FuseGroup {
    pub fn len(&self) -> usize {
        self.end - self.start + 1
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    /// More than one stage per tile sweep?
    pub fn is_fused(&self) -> bool {
        self.len() > 1
    }
}

/// The execution plan for one network pipeline: per-stage LP tile plans
/// (used by materialized stages) plus the fused grouping.
#[derive(Debug, Clone)]
pub struct FusePlan {
    pub stages: Vec<NetworkStage>,
    /// fast-memory budget (words) the grouping was decided under
    pub mem_words: f64,
    pub stage_plans: Vec<Arc<TilePlan>>,
    pub groups: Vec<FuseGroup>,
}

impl FusePlan {
    /// Plan a network: solve every stage's blocking LP (through the shared
    /// cache) and greedily fuse boundaries under the rule above.
    pub fn new(stages: &[NetworkStage], mem_words: f64, cache: &TilePlanCache) -> FusePlan {
        assert!(!stages.is_empty(), "network must have at least one stage");
        let stage_plans: Vec<Arc<TilePlan>> = stages
            .iter()
            .map(|st| cache.plan(&st.shape, st.precision, mem_words))
            .collect();
        let singles: Vec<u64> = stage_plans
            .iter()
            .map(|p| expected_traffic(p).total())
            .collect();
        let single_group = |i: usize| {
            let (b_n, b_wo, b_ho) =
                fit_group_tile(stages, i, i, mem_words).unwrap_or((1, 1, 1));
            FuseGroup { start: i, end: i, b_n, b_wo, b_ho }
        };
        let mut groups = Vec::new();
        let mut cur = single_group(0);
        let mut cur_cost = singles[0];
        for i in 1..stages.len() {
            let mut extended = None;
            if let Some((b_n, b_wo, b_ho)) =
                fit_group_tile(stages, cur.start, i, mem_words)
            {
                let cand = FuseGroup { start: cur.start, end: i, b_n, b_wo, b_ho };
                let cost = fused_group_traffic(stages, &cand).total();
                if cost <= cur_cost + singles[i] {
                    extended = Some((cand, cost));
                }
            }
            match extended {
                Some((cand, cost)) => {
                    cur = cand;
                    cur_cost = cost;
                }
                None => {
                    groups.push(cur);
                    cur = single_group(i);
                    cur_cost = singles[i];
                }
            }
        }
        groups.push(cur);
        FusePlan {
            stages: stages.to_vec(),
            mem_words,
            stage_plans,
            groups,
        }
    }

    /// Number of fused boundaries (adjacent stage pairs whose activation
    /// never materializes).
    pub fn fused_boundaries(&self) -> usize {
        self.groups.iter().map(|g| g.len() - 1).sum()
    }

    /// Words a per-stage traffic vector moves across this plan's *fused*
    /// boundaries: reads by any non-head fused stage plus writes by any
    /// non-tail fused stage. Zero for traffic measured by the fused
    /// executor — the engine's core claim, asserted by the CLI `--check`,
    /// the property tests and `BENCH_network.json` through this one
    /// definition.
    pub fn boundary_words(&self, stages: &[Traffic]) -> u64 {
        let mut words = 0;
        for g in &self.groups {
            for k in g.start + 1..=g.end {
                words += stages[k].input_words;
            }
            for k in g.start..g.end {
                words += stages[k].output_words;
            }
        }
        words
    }

    /// The analytic per-stage traffic this plan executes — fused groups
    /// charge the image patch (with halo) at the group head, the full
    /// filter per stage per tile, and the output tile at the group tail;
    /// materialized stages charge their LP tile plan's
    /// [`expected_traffic`]. The fused executor's counters match these
    /// totals exactly.
    pub fn expected_network_traffic(&self) -> Vec<Traffic> {
        let mut t = vec![Traffic::default(); self.stages.len()];
        for g in &self.groups {
            if g.is_fused() {
                charge_fused_group(&self.stages, g, &mut t);
            } else {
                t[g.start] = expected_traffic(&self.stage_plans[g.start]);
            }
        }
        t
    }
}

/// Absolute half-open output spans `[w0, w1) × [h0, h1)` of one stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct Span {
    pub w0: u64,
    pub w1: u64,
    pub h0: u64,
    pub h1: u64,
}

impl Span {
    pub(crate) fn w_len(&self) -> u64 {
        self.w1 - self.w0
    }

    pub(crate) fn h_len(&self) -> u64 {
        self.h1 - self.h0
    }
}

/// The input span `s` reads to produce output span `o`: starts at `σ·o0`,
/// ends one halo past the last output row. Never exceeds the stage's
/// paper-convention input extent, so no clamping is required anywhere.
pub(crate) fn input_span(s: &ConvShape, o: &Span) -> Span {
    Span {
        w0: s.s_w * o.w0,
        w1: s.s_w * (o.w1 - 1) + s.w_f,
        h0: s.s_h * o.h0,
        h1: s.s_h * (o.h1 - 1) + s.h_f,
    }
}

/// Output spans each stage of `stages[a..=b]` computes for one tile
/// `(tw, th)` of the last stage, in stage order (index 0 ↔ stage `a`).
/// Element `k−1` is both stage `k−1`'s output span and stage `k`'s input
/// span — the fused boundary where no main-memory traffic is charged.
pub(crate) fn group_spans(
    stages: &[NetworkStage],
    a: usize,
    b: usize,
    tw: Blk,
    th: Blk,
) -> Vec<Span> {
    let mut spans = vec![
        Span { w0: 0, w1: 0, h0: 0, h1: 0 };
        b - a + 1
    ];
    let mut cur = Span {
        w0: tw.start,
        w1: tw.start + tw.len,
        h0: th.start,
        h1: th.start + th.len,
    };
    for k in (a..=b).rev() {
        spans[k - a] = cur;
        cur = input_span(&stages[k].shape, &cur);
    }
    spans
}

/// Every (batch, wO, hO) tile of a fused group's last stage.
pub(crate) fn group_tiles(stages: &[NetworkStage], g: &FuseGroup) -> Vec<(Blk, Blk, Blk)> {
    let last = &stages[g.end].shape;
    let ns = split(last.n, g.b_n);
    let ws = split(last.w_o, g.b_wo);
    let hs = split(last.h_o, g.b_ho);
    let mut tiles = Vec::with_capacity(ns.len() * ws.len() * hs.len());
    for &tn in &ns {
        for &tw in &ws {
            for &th in &hs {
                tiles.push((tn, tw, th));
            }
        }
    }
    tiles
}

/// Peak ping-pong working set (words, under each stage's precision) of one
/// fused tile with last-stage output blocks `(bn, bwo, bho)`: at every
/// stage the input patch, the output patch and the full filter are live
/// simultaneously; patches of other stages are recycled.
pub(crate) fn group_footprint(
    stages: &[NetworkStage],
    a: usize,
    b: usize,
    bn: u64,
    bwo: u64,
    bho: u64,
) -> f64 {
    let mut peak: f64 = 0.0;
    let (mut ow, mut oh) = (bwo, bho);
    for k in (a..=b).rev() {
        let st = &stages[k];
        let s = &st.shape;
        let iw = halo_extent(ow, s.s_w, s.w_f);
        let ih = halo_extent(oh, s.s_h, s.h_f);
        let words = st.precision.p_i * (bn * s.c_i * iw * ih) as f64
            + st.precision.p_o * (bn * s.c_o * ow * oh) as f64
            + st.precision.p_f * s.filter_size() as f64;
        peak = peak.max(words);
        ow = iw;
        oh = ih;
    }
    peak
}

/// Find last-stage output tile blocks whose fused working set fits in
/// `mem` words, shrinking the batch block first (halving N costs no halo
/// recompute) and then the larger spatial block. `None` when even a
/// 1×1×1 tile does not fit — the boundary must materialize.
pub(crate) fn fit_group_tile(
    stages: &[NetworkStage],
    a: usize,
    b: usize,
    mem: f64,
) -> Option<(u64, u64, u64)> {
    let last = &stages[b].shape;
    let (mut bn, mut bwo, mut bho) =
        (last.n.max(1), last.w_o.max(1), last.h_o.max(1));
    loop {
        if group_footprint(stages, a, b, bn, bwo, bho) <= mem {
            return Some((bn, bwo, bho));
        }
        if bn > 1 {
            bn = (bn + 1) / 2;
        } else if bwo >= bho && bwo > 1 {
            bwo = (bwo + 1) / 2;
        } else if bho > 1 {
            bho = (bho + 1) / 2;
        } else {
            return None;
        }
    }
}

/// Add one fused group's analytic per-stage traffic into `t` (indexed by
/// absolute stage number). Charges: head stage reads its halo'd image
/// patch per tile; every stage reads its full filter per tile; the tail
/// stage writes its output tile. Interior boundaries charge nothing —
/// the invariant the property tests pin down.
pub(crate) fn charge_fused_group(
    stages: &[NetworkStage],
    g: &FuseGroup,
    t: &mut [Traffic],
) {
    let head = &stages[g.start].shape;
    let tail = &stages[g.end].shape;
    for (tn, tw, th) in group_tiles(stages, g) {
        let spans = group_spans(stages, g.start, g.end, tw, th);
        let in_sp = input_span(head, &spans[0]);
        t[g.start].input_words +=
            tn.len * head.c_i * in_sp.w_len() * in_sp.h_len();
        for k in g.start..=g.end {
            t[k].filter_words += stages[k].shape.filter_size();
        }
        t[g.end].output_words += tn.len * tail.c_o * tw.len * th.len;
    }
}

/// Total analytic traffic of one fused group in isolation.
pub(crate) fn fused_group_traffic(stages: &[NetworkStage], g: &FuseGroup) -> Traffic {
    let mut t = vec![Traffic::default(); stages.len()];
    charge_fused_group(stages, g, &mut t);
    Traffic::sum(&t)
}

/// The stage-by-stage oracle: run the chain through [`conv7nl_naive`] on
/// full tensors, materializing every activation. Fused groups of the
/// network executor perform this exact per-element accumulation order, so
/// a plan fused end to end reproduces this output bitwise.
pub fn naive_network(image: &Tensor4, filters: &[&Tensor4], stages: &[NetworkStage]) -> Tensor4 {
    assert_eq!(filters.len(), stages.len(), "one filter per stage");
    let mut act = image.clone();
    for (k, st) in stages.iter().enumerate() {
        act = conv7nl_naive(&act, filters[k], &st.shape);
    }
    act
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::Precision;
    use crate::runtime::manifest::NetworkSpec;

    fn tiny(batch: u64) -> Vec<NetworkStage> {
        NetworkSpec::tiny_resnet(batch).stages
    }

    #[test]
    fn halo_extent_matches_hand_cases() {
        assert_eq!(halo_extent(4, 1, 3), 6); // unit stride 3x3: len + 2
        assert_eq!(halo_extent(4, 2, 2), 8); // stride-2 2x2: 2·3 + 2
        assert_eq!(halo_extent(1, 3, 5), 5); // single row: just the filter
    }

    #[test]
    fn spans_chain_through_the_group() {
        let stages = tiny(2);
        let tw = Blk { start: 1, len: 2 };
        let th = Blk { start: 0, len: 4 };
        let spans = group_spans(&stages, 0, 2, tw, th);
        assert_eq!(spans.len(), 3);
        // last stage's span is the tile itself
        assert_eq!(spans[2], Span { w0: 1, w1: 3, h0: 0, h1: 4 });
        // stage 1 output span = stage 2 input span (stride 2, 2x2 filter)
        assert_eq!(spans[1], Span { w0: 2, w1: 6, h0: 0, h1: 8 });
        // stage 0 output span = stage 1 input span (unit stride, 3x3)
        assert_eq!(spans[0], Span { w0: 2, w1: 8, h0: 0, h1: 10 });
        // the image patch adds one more halo
        let img = input_span(&stages[0].shape, &spans[0]);
        assert_eq!(img, Span { w0: 2, w1: 10, h0: 0, h1: 12 });
    }

    #[test]
    fn tiny_resnet_fuses_end_to_end_at_default_memory() {
        let cache = TilePlanCache::new();
        let plan = FusePlan::new(&tiny(4), super::super::plan::DEFAULT_TILE_MEM_WORDS, &cache);
        assert_eq!(plan.groups.len(), 1, "groups {:?}", plan.groups);
        assert!(plan.groups[0].is_fused());
        assert_eq!(plan.fused_boundaries(), 2);
        // fused traffic strictly below the layer-by-layer sum
        let fused: u64 = Traffic::sum(&plan.expected_network_traffic()).total();
        let layered: u64 = plan
            .stage_plans
            .iter()
            .map(|p| expected_traffic(p).total())
            .sum();
        assert!(fused < layered, "fused {fused} vs layered {layered}");
    }

    #[test]
    fn tight_memory_forces_materialization() {
        // a budget below any two-stage working set must split every
        // boundary; every group then runs the plain LP-tiled path
        let stages = tiny(4);
        let two_stage_floor = group_footprint(&stages, 0, 1, 1, 1, 1)
            .min(group_footprint(&stages, 1, 2, 1, 1, 1));
        let cache = TilePlanCache::new();
        let plan = FusePlan::new(&stages, two_stage_floor - 1.0, &cache);
        assert_eq!(plan.groups.len(), 3, "groups {:?}", plan.groups);
        assert_eq!(plan.fused_boundaries(), 0);
    }

    #[test]
    fn footprint_grows_with_tile_and_group() {
        let stages = tiny(2);
        let small = group_footprint(&stages, 1, 1, 1, 2, 2);
        let wider = group_footprint(&stages, 1, 1, 1, 4, 4);
        assert!(wider > small);
        let deeper = group_footprint(&stages, 0, 2, 1, 2, 2);
        let tail_only = group_footprint(&stages, 2, 2, 1, 2, 2);
        assert!(deeper >= tail_only);
    }

    #[test]
    fn fit_group_tile_respects_budget() {
        let stages = tiny(4);
        let (bn, bwo, bho) =
            fit_group_tile(&stages, 0, 2, 4096.0).expect("some tile fits");
        assert!(group_footprint(&stages, 0, 2, bn, bwo, bho) <= 4096.0);
        let last = &stages[2].shape;
        assert!(bn <= last.n && bwo <= last.w_o && bho <= last.h_o);
        // absurdly small budgets cannot host even a unit tile
        assert!(fit_group_tile(&stages, 0, 2, 8.0).is_none());
    }

    #[test]
    fn group_tiles_cover_last_stage_output() {
        let stages = tiny(3);
        let g = FuseGroup { start: 0, end: 2, b_n: 2, b_wo: 3, b_ho: 2 };
        let tiles = group_tiles(&stages, &g);
        let last = &stages[2].shape;
        let mut seen = vec![false; (last.n * last.w_o * last.h_o) as usize];
        for (tn, tw, th) in tiles {
            for n in tn.start..tn.start + tn.len {
                for w in tw.start..tw.start + tw.len {
                    for h in th.start..th.start + th.len {
                        let i = ((n * last.w_o + w) * last.h_o + h) as usize;
                        assert!(!seen[i], "overlap");
                        seen[i] = true;
                    }
                }
            }
        }
        assert!(seen.into_iter().all(|v| v), "not covered");
    }

    #[test]
    fn per_stage_precision_shapes_the_footprint() {
        let shape = ConvShape::new(2, 4, 4, 6, 6, 3, 3, 1, 1);
        let cheap = [NetworkStage { shape, precision: Precision::gemmini() }];
        let wide = [NetworkStage { shape, precision: Precision::paper_mixed() }];
        assert!(
            group_footprint(&cheap, 0, 0, 2, 6, 6)
                < group_footprint(&wide, 0, 0, 2, 6, 6)
        );
    }
}
