//! The multi-layer fusion planner: walks adjacent stages of a
//! [`NetworkSpec`] chain and decides, per boundary, whether the two stages
//! execute inside one tile sweep (fused — the inter-layer activation stays
//! resident in scratch buffers and never touches main memory) or
//! materialize the full activation tensor between them.
//!
//! **Halo math.** Sweeping output tiles of the *last* stage of a fused
//! group, an output block of extent `e` needs an input span of
//! `σ·(e − 1) + f` rows from the stage above ([`halo_extent`]); applied
//! recursively up the group, each upstream stage's required activation
//! tile grows by one halo per layer. [`group_spans`] performs exactly this
//! walk for one concrete tile and is shared by the fused executor and the
//! analytic traffic model, so measured and expected traffic agree word for
//! word.
//!
//! **Sliding-window halo reuse.** Adjacent h-tiles of a fused sweep need
//! overlapping input rows at every level — a constant
//! [`input_overlap_rows`] per stage, independent of the tile. With the
//! halo cache on, the executor carries each level's trailing overlap rows
//! from one h-tile to the next, so the group head re-reads only the fresh
//! rows from main memory and interior stages recompute only the fresh
//! rows. The carry buffers' footprint is folded into the fuse budget
//! ([`group_footprint`]) and the saved head re-reads into the analytic
//! traffic model ([`charge_fused_group`]).
//!
//! **Fuse-vs-materialize rule** (DESIGN.md §7). A boundary fuses when
//! (a) a tile of the candidate group exists whose peak working set under
//! the packed execution model — scratch input patch + packed input panel +
//! output patch + packed filter panel of the widest stage, plus the
//! sliding-window carries — fits in the memory budget `M`
//! ([`fit_group_tile`]), and (b) the analytic fused traffic of the
//! extended group does not exceed the traffic of leaving the boundary
//! materialized (the current group plus the next stage run layer-by-layer
//! through the LP-tiled engine). Rule (b) guards against fusing past the
//! point where halo recompute and per-tile filter re-reads outweigh the
//! saved activation round-trip, and makes `fused ≤ unfused` hold by
//! construction.

use std::sync::Arc;

use crate::conv::{conv7nl_naive, ConvShape, NetworkStage, Tensor4};

use super::exec::{expected_traffic, Traffic};
use super::plan::{filter_split_ranges, TilePlan, TilePlanCache};
use super::tiles::{split, Blk};

/// Input span one output block of `len` elements needs upstream:
/// `σ·(len − 1) + f`.
pub fn halo_extent(len: u64, stride: u64, filter: u64) -> u64 {
    stride * (len.max(1) - 1) + filter
}

/// Which compute path fused stages run through. Both paths follow the
/// same accumulation-order contract (ascending `(cI, i6, i7)` per output
/// element — see `gemm.rs` and DESIGN.md §7), so they are bitwise
/// interchangeable; `Packed` is the production path, `Reference` the
/// oracle it is pinned to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FusedExec {
    /// The packed LP microkernel: each stage packs its scratch activation
    /// patch and filter into the `pack.rs` panels (one full reduction
    /// tile) and drives them through the `gemm.rs` axpy MAC.
    Packed,
    /// The patch-local naive 7NL nest — the bitwise oracle.
    Reference,
}

impl FusedExec {
    pub fn name(self) -> &'static str {
        match self {
            FusedExec::Packed => "packed",
            FusedExec::Reference => "reference",
        }
    }

    pub fn parse(s: &str) -> Option<FusedExec> {
        match s {
            "packed" => Some(FusedExec::Packed),
            "reference" => Some(FusedExec::Reference),
            _ => None,
        }
    }
}

/// One contiguous run of stages executed per tile sweep. `start..=end`
/// index into the network's stage list; `b_n`/`b_wo`/`b_ho` are the
/// output-tile blocks of the *last* stage the fused sweep iterates
/// (meaningful when `is_fused()`; single-stage groups execute through the
/// stage's own LP [`TilePlan`] instead).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FuseGroup {
    pub start: usize,
    pub end: usize,
    pub b_n: u64,
    pub b_wo: u64,
    pub b_ho: u64,
}

impl FuseGroup {
    pub fn len(&self) -> usize {
        self.end - self.start + 1
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    /// More than one stage per tile sweep?
    pub fn is_fused(&self) -> bool {
        self.len() > 1
    }
}

/// The execution plan for one network pipeline: per-stage LP tile plans
/// (used by materialized stages) plus the fused grouping, the compute path
/// fused stages run ([`FusedExec`]) and the halo-cache switch.
#[derive(Debug, Clone)]
pub struct FusePlan {
    pub stages: Vec<NetworkStage>,
    /// fast-memory budget (words) the grouping was decided under
    pub mem_words: f64,
    pub stage_plans: Vec<Arc<TilePlan>>,
    pub groups: Vec<FuseGroup>,
    /// compute path fused stages run (bitwise-identical numerics and
    /// identical traffic either way)
    pub exec: FusedExec,
    /// sliding-window halo cache on/off — shapes both the footprint rule
    /// and the analytic traffic model
    pub halo_cache: bool,
}

impl FusePlan {
    /// Plan a network with the production defaults: packed fused stages
    /// and the sliding-window halo cache on.
    pub fn new(stages: &[NetworkStage], mem_words: f64, cache: &TilePlanCache) -> FusePlan {
        FusePlan::with_options(stages, mem_words, cache, FusedExec::Packed, true)
    }

    /// Plan a network: solve every stage's blocking LP (through the shared
    /// cache) and greedily fuse boundaries under the rule above.
    pub fn with_options(
        stages: &[NetworkStage],
        mem_words: f64,
        cache: &TilePlanCache,
        exec: FusedExec,
        halo_cache: bool,
    ) -> FusePlan {
        assert!(!stages.is_empty(), "network must have at least one stage");
        let stage_plans = solve_stage_plans(stages, mem_words, cache);
        let singles: Vec<u64> = stage_plans
            .iter()
            .map(|p| expected_traffic(p).total())
            .collect();
        let single_group = |i: usize| {
            let (b_n, b_wo, b_ho) =
                fit_group_tile(stages, i, i, mem_words, halo_cache)
                    .unwrap_or((1, 1, 1));
            FuseGroup { start: i, end: i, b_n, b_wo, b_ho }
        };
        let mut groups = Vec::new();
        let mut cur = single_group(0);
        let mut cur_cost = singles[0];
        for i in 1..stages.len() {
            let mut extended = None;
            if let Some((b_n, b_wo, b_ho)) =
                fit_group_tile(stages, cur.start, i, mem_words, halo_cache)
            {
                let cand = FuseGroup { start: cur.start, end: i, b_n, b_wo, b_ho };
                let cost = fused_group_traffic(stages, &cand, halo_cache).total();
                if cost <= cur_cost + singles[i] {
                    extended = Some((cand, cost));
                }
            }
            match extended {
                Some((cand, cost)) => {
                    cur = cand;
                    cur_cost = cost;
                }
                None => {
                    groups.push(cur);
                    cur = single_group(i);
                    cur_cost = singles[i];
                }
            }
        }
        groups.push(cur);
        FusePlan {
            stages: stages.to_vec(),
            mem_words,
            stage_plans,
            groups,
            exec,
            halo_cache,
        }
    }

    /// A plan with every boundary materialized: each stage is a singleton
    /// group running the LP-tiled engine — the layer-by-layer execution
    /// mode the autotuner probes against the fused ones.
    pub fn materialized(
        stages: &[NetworkStage],
        mem_words: f64,
        cache: &TilePlanCache,
    ) -> FusePlan {
        assert!(!stages.is_empty(), "network must have at least one stage");
        let stage_plans = solve_stage_plans(stages, mem_words, cache);
        let groups = (0..stages.len())
            .map(|i| {
                let (b_n, b_wo, b_ho) =
                    fit_group_tile(stages, i, i, mem_words, false)
                        .unwrap_or((1, 1, 1));
                FuseGroup { start: i, end: i, b_n, b_wo, b_ho }
            })
            .collect();
        FusePlan {
            stages: stages.to_vec(),
            mem_words,
            stage_plans,
            groups,
            exec: FusedExec::Packed,
            halo_cache: false,
        }
    }

    /// Number of fused boundaries (adjacent stage pairs whose activation
    /// never materializes).
    pub fn fused_boundaries(&self) -> usize {
        self.groups.iter().map(|g| g.len() - 1).sum()
    }

    /// Words a per-stage traffic vector moves across this plan's *fused*
    /// boundaries: reads by any non-head fused stage plus writes by any
    /// non-tail fused stage. Zero for traffic measured by the fused
    /// executor — the engine's core claim, asserted by the CLI `--check`,
    /// the property tests and `BENCH_network.json` through this one
    /// definition.
    pub fn boundary_words(&self, stages: &[Traffic]) -> u64 {
        let mut words = 0;
        for g in &self.groups {
            for k in g.start + 1..=g.end {
                words += stages[k].input_words;
            }
            for k in g.start..g.end {
                words += stages[k].output_words;
            }
        }
        words
    }

    /// The analytic per-stage traffic this plan executes — fused groups
    /// charge the image patch (with halo; only the fresh rows once the
    /// sliding-window cache holds the overlap) at the group head, the full
    /// filter per stage per tile, and the output tile at the group tail;
    /// materialized stages charge their LP tile plan's
    /// [`expected_traffic`]. The fused executor's counters match these
    /// totals exactly.
    pub fn expected_network_traffic(&self) -> Vec<Traffic> {
        let mut t = vec![Traffic::default(); self.stages.len()];
        for g in &self.groups {
            if g.is_fused() {
                charge_fused_group(&self.stages, g, self.halo_cache, &mut t);
            } else {
                t[g.start] = expected_traffic(&self.stage_plans[g.start]);
            }
        }
        t
    }

    /// Words each stage's input patch is expected to receive from the
    /// sliding-window halo cache instead of main memory (group heads) or
    /// upstream recompute (interior fused stages), per stage. All zero
    /// when the cache is off or every fused sweep has a single h-tile.
    /// The fused executor's halo counters match these exactly.
    pub fn expected_halo_words(&self) -> Vec<u64> {
        let mut words = vec![0u64; self.stages.len()];
        if !self.halo_cache {
            return words;
        }
        for g in &self.groups {
            if !g.is_fused() {
                continue;
            }
            let overlaps = input_overlap_rows(&self.stages, g.start, g.end);
            for (tn, tw, hs) in group_tile_columns(&self.stages, g) {
                for (i, th) in hs.iter().enumerate() {
                    if i == 0 {
                        continue;
                    }
                    let spans =
                        group_spans(&self.stages, g.start, g.end, tw, *th);
                    for k in g.start..=g.end {
                        let ov = overlaps[k - g.start];
                        if ov == 0 {
                            continue;
                        }
                        let s = &self.stages[k].shape;
                        let iw = if k == g.start {
                            input_span(s, &spans[0]).w_len()
                        } else {
                            spans[k - g.start - 1].w_len()
                        };
                        words[k] += tn.len * s.c_i * iw * ov;
                    }
                }
            }
        }
        words
    }
}

/// Solve (through the shared cache) every stage's LP tile plan.
fn solve_stage_plans(
    stages: &[NetworkStage],
    mem_words: f64,
    cache: &TilePlanCache,
) -> Vec<Arc<TilePlan>> {
    stages
        .iter()
        .map(|st| cache.plan(&st.shape, st.precision, mem_words))
        .collect()
}

/// Absolute half-open output spans `[w0, w1) × [h0, h1)` of one stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct Span {
    pub w0: u64,
    pub w1: u64,
    pub h0: u64,
    pub h1: u64,
}

impl Span {
    pub(crate) fn w_len(&self) -> u64 {
        self.w1 - self.w0
    }

    pub(crate) fn h_len(&self) -> u64 {
        self.h1 - self.h0
    }
}

/// The input span `s` reads to produce output span `o`: starts at `σ·o0`,
/// ends one halo past the last output row. Never exceeds the stage's
/// paper-convention input extent, so no clamping is required anywhere.
pub(crate) fn input_span(s: &ConvShape, o: &Span) -> Span {
    Span {
        w0: s.s_w * o.w0,
        w1: s.s_w * (o.w1 - 1) + s.w_f,
        h0: s.s_h * o.h0,
        h1: s.s_h * (o.h1 - 1) + s.h_f,
    }
}

/// Output spans each stage of `stages[a..=b]` computes for one tile
/// `(tw, th)` of the last stage, in stage order (index 0 ↔ stage `a`).
/// Element `k−1` is both stage `k−1`'s output span and stage `k`'s input
/// span — the fused boundary where no main-memory traffic is charged.
pub(crate) fn group_spans(
    stages: &[NetworkStage],
    a: usize,
    b: usize,
    tw: Blk,
    th: Blk,
) -> Vec<Span> {
    let mut spans = vec![
        Span { w0: 0, w1: 0, h0: 0, h1: 0 };
        b - a + 1
    ];
    let mut cur = Span {
        w0: tw.start,
        w1: tw.start + tw.len,
        h0: th.start,
        h1: th.start + th.len,
    };
    for k in (a..=b).rev() {
        spans[k - a] = cur;
        cur = input_span(&stages[k].shape, &cur);
    }
    spans
}

/// Sliding-window overlap per stage: the number of h-rows of stage `k`'s
/// *input* that adjacent h-tiles of the group tail share. With
/// `S = Π σh` (stage `k` down to the tail) and `F` the accumulated halo
/// extent of one tail row, consecutive tail tiles `[t0, t1)` / `[t1, t2)`
/// need stage-k input rows `[S·t0, S·(t1−1) + F)` / `[S·t1, …)`: the
/// overlap `F − S` is tile-independent, and `σ ≤ f` (validated per stage)
/// keeps it ≥ 0. Index 0 ↔ stage `a` (the group head's image patch).
pub(crate) fn input_overlap_rows(stages: &[NetworkStage], a: usize, b: usize) -> Vec<u64> {
    let mut out = vec![0u64; b - a + 1];
    let (mut s, mut f) = (1u64, 1u64);
    for k in (a..=b).rev() {
        let sh = stages[k].shape.s_h;
        f = sh * (f - 1) + stages[k].shape.h_f;
        s *= sh;
        out[k - a] = f - s;
    }
    out
}

/// The (batch, wO) tile columns of a fused group's last stage, each with
/// the ordered h-blocks its sliding-window sweep iterates (h innermost).
/// The executor and the analytic traffic model walk these identically,
/// which is what keeps measured == expected exact with the halo cache on.
pub(crate) fn group_tile_columns(
    stages: &[NetworkStage],
    g: &FuseGroup,
) -> Vec<(Blk, Blk, Vec<Blk>)> {
    let last = &stages[g.end].shape;
    let ns = split(last.n, g.b_n);
    let ws = split(last.w_o, g.b_wo);
    let hs = split(last.h_o, g.b_ho);
    let mut cols = Vec::with_capacity(ns.len() * ws.len());
    for &tn in &ns {
        for &tw in &ws {
            cols.push((tn, tw, hs.clone()));
        }
    }
    cols
}

/// Peak fast-memory working set (words, under each stage's precision) of
/// one fused tile with last-stage output blocks `(bn, bwo, bho)` under the
/// packed execution model: at every stage the scratch input patch, its
/// packed panel, the output patch and the packed filter panel are live
/// simultaneously; patches of other stages are recycled. With `halo` the
/// per-stage sliding-window carry buffers — which persist across the
/// whole h-sweep — are added on top of the peak.
pub(crate) fn group_footprint(
    stages: &[NetworkStage],
    a: usize,
    b: usize,
    bn: u64,
    bwo: u64,
    bho: u64,
    halo: bool,
) -> f64 {
    let overlaps = input_overlap_rows(stages, a, b);
    let mut peak: f64 = 0.0;
    let mut carry: f64 = 0.0;
    let (mut ow, mut oh) = (bwo, bho);
    for k in (a..=b).rev() {
        let st = &stages[k];
        let s = &st.shape;
        let iw = halo_extent(ow, s.s_w, s.w_f);
        let ih = halo_extent(oh, s.s_h, s.h_f);
        let (qw, qh, rw, rh) = filter_split_ranges(s);
        let (ew, eh) = (ow + qw - 1, oh + qh - 1);
        let words = st.precision.p_i
            * (bn * s.c_i * (iw * ih + rw * rh * ew * eh)) as f64
            + st.precision.p_o * (bn * s.c_o * ow * oh) as f64
            + st.precision.p_f * (s.c_i * qw * qh * rw * rh * s.c_o) as f64;
        peak = peak.max(words);
        if halo {
            carry += st.precision.p_i
                * (bn * s.c_i * iw * overlaps[k - a].min(ih)) as f64;
        }
        ow = iw;
        oh = ih;
    }
    peak + carry
}

/// Find last-stage output tile blocks whose fused working set fits in
/// `mem` words, shrinking the batch block first (halving N costs no halo
/// recompute) and then the larger spatial block. `None` when even a
/// 1×1×1 tile does not fit — the boundary must materialize.
pub(crate) fn fit_group_tile(
    stages: &[NetworkStage],
    a: usize,
    b: usize,
    mem: f64,
    halo: bool,
) -> Option<(u64, u64, u64)> {
    let last = &stages[b].shape;
    let (mut bn, mut bwo, mut bho) =
        (last.n.max(1), last.w_o.max(1), last.h_o.max(1));
    loop {
        if group_footprint(stages, a, b, bn, bwo, bho, halo) <= mem {
            return Some((bn, bwo, bho));
        }
        if bn > 1 {
            bn = (bn + 1) / 2;
        } else if bwo >= bho && bwo > 1 {
            bwo = (bwo + 1) / 2;
        } else if bho > 1 {
            bho = (bho + 1) / 2;
        } else {
            return None;
        }
    }
}

/// Add one fused group's analytic per-stage traffic into `t` (indexed by
/// absolute stage number). Charges: head stage reads its halo'd image
/// patch per tile — only the fresh rows for non-first tiles of a column
/// when the sliding-window cache is on; every stage reads its full filter
/// per tile; the tail stage writes its output tile. Interior boundaries
/// charge nothing — the invariant the property tests pin down.
pub(crate) fn charge_fused_group(
    stages: &[NetworkStage],
    g: &FuseGroup,
    halo: bool,
    t: &mut [Traffic],
) {
    let head = &stages[g.start].shape;
    let tail = &stages[g.end].shape;
    for (tn, tw, hs) in group_tile_columns(stages, g) {
        let mut prev_in_h1: Option<u64> = None;
        for th in hs {
            let spans = group_spans(stages, g.start, g.end, tw, th);
            let in_sp = input_span(head, &spans[0]);
            let fresh_h0 = prev_in_h1.map_or(in_sp.h0, |p| p.max(in_sp.h0));
            t[g.start].input_words +=
                tn.len * head.c_i * in_sp.w_len() * (in_sp.h1 - fresh_h0);
            for k in g.start..=g.end {
                t[k].filter_words += stages[k].shape.filter_size();
            }
            t[g.end].output_words += tn.len * tail.c_o * tw.len * th.len;
            if halo {
                prev_in_h1 = Some(in_sp.h1);
            }
        }
    }
}

/// Total analytic traffic of one fused group in isolation.
pub(crate) fn fused_group_traffic(
    stages: &[NetworkStage],
    g: &FuseGroup,
    halo: bool,
) -> Traffic {
    let mut t = vec![Traffic::default(); stages.len()];
    charge_fused_group(stages, g, halo, &mut t);
    Traffic::sum(&t)
}

/// The stage-by-stage oracle: run the chain through [`conv7nl_naive`] on
/// full tensors, materializing every activation. Fused groups of the
/// network executor perform this exact per-element accumulation order, so
/// a plan fused end to end reproduces this output bitwise.
pub fn naive_network(image: &Tensor4, filters: &[&Tensor4], stages: &[NetworkStage]) -> Tensor4 {
    assert_eq!(filters.len(), stages.len(), "one filter per stage");
    let mut act = image.clone();
    for (k, st) in stages.iter().enumerate() {
        act = conv7nl_naive(&act, filters[k], &st.shape);
    }
    act
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::Precision;
    use crate::runtime::manifest::NetworkSpec;

    fn tiny(batch: u64) -> Vec<NetworkStage> {
        NetworkSpec::tiny_resnet(batch).stages
    }

    #[test]
    fn halo_extent_matches_hand_cases() {
        assert_eq!(halo_extent(4, 1, 3), 6); // unit stride 3x3: len + 2
        assert_eq!(halo_extent(4, 2, 2), 8); // stride-2 2x2: 2·3 + 2
        assert_eq!(halo_extent(1, 3, 5), 5); // single row: just the filter
    }

    #[test]
    fn spans_chain_through_the_group() {
        let stages = tiny(2);
        let tw = Blk { start: 1, len: 2 };
        let th = Blk { start: 0, len: 4 };
        let spans = group_spans(&stages, 0, 2, tw, th);
        assert_eq!(spans.len(), 3);
        // last stage's span is the tile itself
        assert_eq!(spans[2], Span { w0: 1, w1: 3, h0: 0, h1: 4 });
        // stage 1 output span = stage 2 input span (stride 2, 2x2 filter)
        assert_eq!(spans[1], Span { w0: 2, w1: 6, h0: 0, h1: 8 });
        // stage 0 output span = stage 1 input span (unit stride, 3x3)
        assert_eq!(spans[0], Span { w0: 2, w1: 8, h0: 0, h1: 10 });
        // the image patch adds one more halo
        let img = input_span(&stages[0].shape, &spans[0]);
        assert_eq!(img, Span { w0: 2, w1: 10, h0: 0, h1: 12 });
    }

    #[test]
    fn overlap_rows_match_hand_cases() {
        let stages = tiny(2);
        // walking up from the tail: stage 2 (2x2 stride 2) -> F=2, S=2:
        // adjacent tiles share nothing; stage 1 (3x3 unit) -> F=4, S=2:
        // overlap 2; stage 0 image patch -> F=6, S=2: overlap 4
        assert_eq!(input_overlap_rows(&stages, 0, 2), vec![4, 2, 0]);
        // single unit-stride 3x3 stage: classic f − σ = 2
        assert_eq!(input_overlap_rows(&stages, 0, 0), vec![2]);
        // consistency with the span walk: consecutive tiles of stage 2
        let a = group_spans(&stages, 0, 2, Blk { start: 0, len: 4 }, Blk { start: 0, len: 2 });
        let b = group_spans(&stages, 0, 2, Blk { start: 0, len: 4 }, Blk { start: 2, len: 2 });
        let ia = input_span(&stages[0].shape, &a[0]);
        let ib = input_span(&stages[0].shape, &b[0]);
        assert_eq!(ia.h1 - ib.h0, 4, "head overlap");
        assert_eq!(a[0].h1 - b[0].h0, 2, "stage-1 input overlap");
    }

    #[test]
    fn tiny_resnet_fuses_end_to_end_at_default_memory() {
        let cache = TilePlanCache::new();
        let plan = FusePlan::new(&tiny(4), super::super::plan::DEFAULT_TILE_MEM_WORDS, &cache);
        assert_eq!(plan.groups.len(), 1, "groups {:?}", plan.groups);
        assert!(plan.groups[0].is_fused());
        assert_eq!(plan.fused_boundaries(), 2);
        // fused traffic strictly below the layer-by-layer sum
        let fused: u64 = Traffic::sum(&plan.expected_network_traffic()).total();
        let layered: u64 = plan
            .stage_plans
            .iter()
            .map(|p| expected_traffic(p).total())
            .sum();
        assert!(fused < layered, "fused {fused} vs layered {layered}");
    }

    #[test]
    fn deep_mixnet_plan_mixes_fused_and_materialized_groups() {
        // the builtin deep pipeline: the 5x5 stage's filter panel alone
        // exceeds the default budget, so it must land in a materialized
        // singleton while the shallow head fuses — the mixed path CI
        // exercises by default
        let net = NetworkSpec::deep_mixnet(4);
        let cache = TilePlanCache::new();
        let plan = FusePlan::new(
            &net.stages,
            super::super::plan::DEFAULT_TILE_MEM_WORDS,
            &cache,
        );
        assert!(
            plan.groups.iter().any(|g| g.is_fused()),
            "groups {:?}",
            plan.groups
        );
        assert!(
            plan.groups.iter().any(|g| !g.is_fused()),
            "groups {:?}",
            plan.groups
        );
        assert!(
            plan.groups.iter().any(|g| g.start == 3 && g.end == 3),
            "the 5x5 stage must materialize: {:?}",
            plan.groups
        );
    }

    #[test]
    fn materialized_plan_has_no_fused_groups() {
        let cache = TilePlanCache::new();
        let stages = tiny(4);
        let plan = FusePlan::materialized(
            &stages,
            super::super::plan::DEFAULT_TILE_MEM_WORDS,
            &cache,
        );
        assert_eq!(plan.groups.len(), stages.len());
        assert_eq!(plan.fused_boundaries(), 0);
        assert!(plan.expected_halo_words().iter().all(|&w| w == 0));
    }

    #[test]
    fn tight_memory_forces_materialization() {
        // a budget below any two-stage working set must split every
        // boundary; every group then runs the plain LP-tiled path
        let stages = tiny(4);
        let two_stage_floor = group_footprint(&stages, 0, 1, 1, 1, 1, true)
            .min(group_footprint(&stages, 1, 2, 1, 1, 1, true));
        let cache = TilePlanCache::new();
        let plan = FusePlan::new(&stages, two_stage_floor - 1.0, &cache);
        assert_eq!(plan.groups.len(), 3, "groups {:?}", plan.groups);
        assert_eq!(plan.fused_boundaries(), 0);
    }

    #[test]
    fn footprint_grows_with_tile_and_group() {
        let stages = tiny(2);
        let small = group_footprint(&stages, 1, 1, 1, 2, 2, true);
        let wider = group_footprint(&stages, 1, 1, 1, 4, 4, true);
        assert!(wider > small);
        let deeper = group_footprint(&stages, 0, 2, 1, 2, 2, true);
        let tail_only = group_footprint(&stages, 2, 2, 1, 2, 2, true);
        assert!(deeper >= tail_only);
        // the halo carries only add footprint
        assert!(
            group_footprint(&stages, 0, 2, 1, 2, 2, true)
                >= group_footprint(&stages, 0, 2, 1, 2, 2, false)
        );
    }

    #[test]
    fn fit_group_tile_respects_budget() {
        let stages = tiny(4);
        let (bn, bwo, bho) =
            fit_group_tile(&stages, 0, 2, 4096.0, true).expect("some tile fits");
        assert!(group_footprint(&stages, 0, 2, bn, bwo, bho, true) <= 4096.0);
        let last = &stages[2].shape;
        assert!(bn <= last.n && bwo <= last.w_o && bho <= last.h_o);
        // absurdly small budgets cannot host even a unit tile
        assert!(fit_group_tile(&stages, 0, 2, 8.0, true).is_none());
    }

    #[test]
    fn group_tile_columns_cover_last_stage_output() {
        let stages = tiny(3);
        let g = FuseGroup { start: 0, end: 2, b_n: 2, b_wo: 3, b_ho: 2 };
        let last = &stages[2].shape;
        let mut seen = vec![false; (last.n * last.w_o * last.h_o) as usize];
        for (tn, tw, hs) in group_tile_columns(&stages, &g) {
            for th in hs {
                for n in tn.start..tn.start + tn.len {
                    for w in tw.start..tw.start + tw.len {
                        for h in th.start..th.start + th.len {
                            let i = ((n * last.w_o + w) * last.h_o + h) as usize;
                            assert!(!seen[i], "overlap");
                            seen[i] = true;
                        }
                    }
                }
            }
        }
        assert!(seen.into_iter().all(|v| v), "not covered");
    }

    #[test]
    fn halo_model_discounts_head_re_reads_only() {
        // with several h-tiles the cached model must charge strictly less
        // head input traffic, identical filter/output traffic
        let stages = tiny(4);
        let g = FuseGroup { start: 0, end: 2, b_n: 4, b_wo: 4, b_ho: 1 };
        let with = fused_group_traffic(&stages, &g, true);
        let without = fused_group_traffic(&stages, &g, false);
        assert!(with.input_words < without.input_words);
        assert_eq!(with.filter_words, without.filter_words);
        assert_eq!(with.output_words, without.output_words);
    }

    #[test]
    fn per_stage_precision_shapes_the_footprint() {
        let shape = ConvShape::new(2, 4, 4, 6, 6, 3, 3, 1, 1);
        let cheap = [NetworkStage { shape, precision: Precision::gemmini() }];
        let wide = [NetworkStage { shape, precision: Precision::paper_mixed() }];
        assert!(
            group_footprint(&cheap, 0, 0, 2, 6, 6, true)
                < group_footprint(&wide, 0, 0, 2, 6, 6, true)
        );
    }
}
