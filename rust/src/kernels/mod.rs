//! The tiled CPU execution engine: the subsystem that actually *runs* the
//! §3.2 LP blockings the rest of the crate only reasons about.
//!
//! * [`plan`] — [`TilePlan`]: LP blocking → balanced integral loop bounds,
//!   plus the memoizing [`TilePlanCache`].
//! * [`tiles`] — enumeration of output tiles (disjoint output regions, the
//!   unit of parallelism) and reduction tiles (accumulated while an output
//!   tile stays resident), including the split-filter `q/r` loops.
//! * [`exec`] — the engine: pack → microkernel → scatter per tile, serial
//!   or fanned out over `util::threadpool::ThreadPool`, with word-traffic
//!   counters whose totals are checked against the `commvol::seq` blocking
//!   model (within 2×) by the property tests.
//! * [`im2col`] — the explicit patch-matrix + GEMM baseline the engine is
//!   benchmarked against.
//! * [`autotune`] — per-shape kernel selection (naive / im2col / tiled),
//!   heuristic or measure-once.
//!
//! `pack` and `gemm` are crate-private: the packing layouts and the
//! microkernel index arithmetic are implementation details of [`exec`].

pub mod autotune;
pub mod exec;
mod gemm;
pub mod im2col;
mod pack;
pub mod plan;
pub mod tiles;

pub use autotune::{Autotuner, KernelKind};
pub use exec::{
    conv_tiled, conv_tiled_counted, conv_tiled_parallel, default_workers,
    expected_traffic, Traffic, TrafficCounters,
};
pub use im2col::conv_im2col;
pub use plan::{TilePlan, TilePlanCache, DEFAULT_TILE_MEM_WORDS};
pub use tiles::{output_tiles, reduction_tiles, Blk, OutTile, RedTile};
