//! The tiled CPU execution engine: the subsystem that actually *runs* the
//! §3.2 LP blockings the rest of the crate only reasons about — for all
//! three convolution passes of a training step ([`ConvPass`]: forward,
//! dFilter, dInput), each mapped onto the nine blocked LP dims.
//!
//! * [`plan`] — [`TilePlan`]: LP blocking → balanced integral loop bounds
//!   per pass ([`TilePlan::for_pass`]), plus the memoizing
//!   [`TilePlanCache`] keyed by pass.
//! * [`tiles`] — enumeration of output tiles (disjoint output regions, the
//!   unit of parallelism) and reduction tiles (accumulated while an output
//!   tile stays resident), including the split-filter `q/r` loops.
//! * [`exec`] — the engine: pack → microkernel → scatter per tile, serial
//!   or fanned out over `util::threadpool::ThreadPool`, with word-traffic
//!   counters whose totals are checked against the `commvol::seq` blocking
//!   model (within 2×) by the property tests; plus the fused network
//!   executor, which sweeps the last fused stage's output tiles, runs
//!   every fused stage through the same packed panels + axpy microkernel
//!   (bitwise-pinned to the naive reference by the accumulation-order
//!   contract), and carries sliding-window halo rows between adjacent
//!   h-tiles so fused boundaries never touch main memory and overlap rows
//!   are neither re-read nor recomputed.
//! * [`fuse`] — the multi-layer fusion planner: halo math per boundary,
//!   the fuse-vs-materialize rule (packed tile footprints + halo carries
//!   vs. `M`), the [`FusedExec`] packed/reference switch, and the analytic
//!   per-stage traffic + halo-savings models the executor's counters match
//!   exactly.
//! * [`im2col`] — the explicit patch-matrix + GEMM baseline the engine is
//!   benchmarked against.
//! * [`winograd`] — the tiled Winograd F(2,3) transform-domain kernel:
//!   polyphase/chunk normalization to unit-stride ≤3-tap sub-convs, a
//!   pre-transformed filter cache, budget-sized tile blocks, and its own
//!   exact analytic traffic model ([`expected_winograd_traffic`]);
//!   validated against the naive oracle via a documented ULP-scaled
//!   tolerance ([`winograd_tolerance`]) since transforms reassociate.
//! * [`shard`] — sharded parallel execution across in-process virtual
//!   workers (batch / channel / spatial partitions, plus analytic `auto`):
//!   per-shard tiled engines on clamped sub-plans, explicit halo/reduce
//!   exchange buffers counted by [`ShardTrafficCounters`], and the
//!   measured-vs-analytic parallel-volume gate against `commvol::par`.
//! * [`autotune`] — per-shape kernel selection (naive / im2col / tiled /
//!   winograd)
//!   and per-network mode selection (fused-packed / fused-reference /
//!   materialized), heuristic or measure-once, with a JSON sidecar for
//!   warm-starting selection across process restarts; network probes and
//!   shard-strategy probes are LP-pruned by their exact analytic traffic.
//!
//! `pack` is crate-private: the packing layouts are implementation details
//! of [`exec`]. `gemm` is private too, but its axpy microkernels are
//! re-exported so the property tests can pin the unrolled form to the
//! scalar reference bitwise.

pub mod autotune;
pub mod exec;
pub mod fuse;
mod gemm;
pub mod im2col;
mod pack;
pub mod plan;
pub mod shard;
pub mod tiles;
pub mod winograd;

pub use crate::conv::ConvPass;
pub use autotune::{Autotuner, KernelKind, NetKernelKind};
pub use exec::{
    conv_network_bwd, conv_network_bwd_counted, conv_network_fused,
    conv_network_fused_counted, conv_network_staged, conv_network_step_counted,
    conv_pass_tiled, conv_pass_tiled_counted, conv_pass_tiled_parallel,
    conv_tiled, conv_tiled_counted, conv_tiled_parallel, default_workers,
    expected_pass_traffic, expected_traffic, NetTrafficCounters, Traffic,
    TrafficCounters,
};
pub use fuse::{
    halo_extent, naive_network, naive_network_bwd, naive_network_step,
    FuseGroup, FusePlan, FusedExec, NetPass,
};
pub use gemm::{axpy, axpy_scalar};
pub use im2col::conv_im2col;
pub use plan::{TilePlan, TilePlanCache, DEFAULT_TILE_MEM_WORDS};
pub use shard::{
    exec_sharded, staged_reference, verify_exchange, ShardPlan, ShardStrategy,
    ShardTraffic, ShardTrafficCounters,
};
pub use tiles::{output_tiles, reduction_tiles, Blk, OutTile, RedTile};
pub use winograd::{
    conv_winograd, conv_winograd_counted, conv_winograd_parallel,
    expected_winograd_traffic, winograd_tolerance, WinoPlan,
};
