//! The tiled conv execution engine: iterate the LP-blocked tile grid, pack
//! each tile's working set, run the microkernel, and count every word that
//! crosses the (modelled) fast-memory boundary.
//!
//! Execution structure — the loop nest the §3.2 LP optimizes:
//!
//! ```text
//! for each output tile (blocks of n, cO, wO, hO):          // parallel
//!     out_buf = 0                                          // resident
//!     for each reduction tile (blocks of cI, q6, q7, r6, r7):
//!         pack input patch      -> count input words
//!         pack filter block     -> count filter words
//!         microkernel MAC into out_buf
//!     scatter out_buf to the output tensor -> count output words
//! ```
//!
//! Keeping the output tile resident across the whole reduction loop is why
//! measured traffic lands *below* the `commvol::seq` blocking model (which
//! charges the full three-operand footprint per tile step) while staying
//! within its 2× envelope — the property the acceptance tests pin down.
//!
//! Parallelism: output tiles write disjoint output regions, so tile
//! execution fans out over [`ThreadPool`] workers with no synchronization
//! beyond the traffic counters (relaxed atomics). Each output tile is
//! computed serially by one worker in a fixed reduction order, so the
//! parallel result is bitwise identical to the serial one.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::conv::{conv7nl_naive, ConvShape, NetworkStage, Tensor4};
use crate::util::threadpool::ThreadPool;

use super::fuse::{group_spans, group_tiles, input_span, FuseGroup, FusePlan};
use super::gemm::{self, TileDims};
use super::pack;
use super::plan::TilePlan;
use super::tiles::{self, Blk, OutTile, RedTile};

/// Worker count for tile-execution pools: cores minus one (the spare runs
/// the batcher/executor threads), capped at 8 — packed-tile MACs saturate
/// memory bandwidth before they scale further. One policy shared by the
/// native backend and the benches, so `BENCH_kernels.json` measures the
/// pool shape production uses.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .saturating_sub(1)
        .clamp(1, 8)
}

/// A word-traffic snapshot, in f32 words per operand.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Traffic {
    pub input_words: u64,
    pub filter_words: u64,
    pub output_words: u64,
}

impl Traffic {
    pub fn total(&self) -> u64 {
        self.input_words + self.filter_words + self.output_words
    }

    /// Element-wise sum over a slice of per-stage snapshots.
    pub fn sum(stages: &[Traffic]) -> Traffic {
        let mut t = Traffic::default();
        for s in stages {
            t.input_words += s.input_words;
            t.filter_words += s.filter_words;
            t.output_words += s.output_words;
        }
        t
    }
}

/// Thread-safe word-traffic counters the engine charges while executing.
#[derive(Debug, Default)]
pub struct TrafficCounters {
    input: AtomicU64,
    filter: AtomicU64,
    output: AtomicU64,
}

impl TrafficCounters {
    pub fn new() -> TrafficCounters {
        TrafficCounters::default()
    }

    fn add_input(&self, words: u64) {
        self.input.fetch_add(words, Ordering::Relaxed);
    }

    fn add_filter(&self, words: u64) {
        self.filter.fetch_add(words, Ordering::Relaxed);
    }

    fn add_output(&self, words: u64) {
        self.output.fetch_add(words, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> Traffic {
        Traffic {
            input_words: self.input.load(Ordering::Relaxed),
            filter_words: self.filter.load(Ordering::Relaxed),
            output_words: self.output.load(Ordering::Relaxed),
        }
    }

    pub fn reset(&self) {
        self.input.store(0, Ordering::Relaxed);
        self.filter.store(0, Ordering::Relaxed);
        self.output.store(0, Ordering::Relaxed);
    }
}

fn out_dims(s: &ConvShape) -> [usize; 4] {
    [s.n as usize, s.c_o as usize, s.w_o as usize, s.h_o as usize]
}

/// Execute every reduction tile against one resident output tile; returns
/// the accumulated `[bn][bwo][bho][bco]` buffer.
fn run_out_tile(
    x: &Tensor4,
    w: &Tensor4,
    plan: &TilePlan,
    ot: OutTile,
    red: &[RedTile],
    counters: &TrafficCounters,
) -> Vec<f32> {
    let s = &plan.shape;
    let (sw, sh) = (s.s_w as usize, s.s_h as usize);
    let (wf, hf) = (s.w_f as usize, s.h_f as usize);
    let bn = ot.n.len as usize;
    let bco = ot.co.len as usize;
    let bwo = ot.wo.len as usize;
    let bho = ot.ho.len as usize;
    let mut out = vec![0.0f32; bn * bwo * bho * bco];
    // pack buffers live across the whole reduction loop (and grow to the
    // interior-block size once): no per-tile allocation on the hot path
    let mut xin: Vec<f32> = Vec::new();
    let mut fil: Vec<f32> = Vec::new();
    for rt in red {
        let (ew, eh) = pack::pack_input(x, sw, sh, &ot, rt, &mut xin);
        let fil_words = pack::pack_filter(w, sw, sh, wf, hf, &ot, rt, &mut fil);
        counters.add_input(xin.len() as u64);
        counters.add_filter(fil_words);
        let d = TileDims {
            bn,
            bci: rt.ci.len as usize,
            bco,
            bwo,
            bho,
            bqw: rt.qw.len as usize,
            bqh: rt.qh.len as usize,
            brw: rt.rw.len as usize,
            brh: rt.rh.len as usize,
            ew,
            eh,
            q6_0: rt.qw.start as usize,
            q7_0: rt.qh.start as usize,
            r6_0: rt.rw.start as usize,
            r7_0: rt.rh.start as usize,
            sw,
            sh,
            wf,
            hf,
        };
        gemm::conv_tile_mac(&mut out, &xin, &fil, &d);
    }
    counters.add_output(out.len() as u64);
    out
}

/// Write one finished output-tile buffer into the output tensor.
fn scatter(out: &mut Tensor4, ot: &OutTile, buf: &[f32]) {
    let bn = ot.n.len as usize;
    let bco = ot.co.len as usize;
    let bwo = ot.wo.len as usize;
    let bho = ot.ho.len as usize;
    let (n0, co0) = (ot.n.start as usize, ot.co.start as usize);
    let (wo0, ho0) = (ot.wo.start as usize, ot.ho.start as usize);
    let mut k = 0;
    for n in 0..bn {
        for i4 in 0..bwo {
            for i5 in 0..bho {
                for co in 0..bco {
                    *out.at_mut(n0 + n, co0 + co, wo0 + i4, ho0 + i5) = buf[k];
                    k += 1;
                }
            }
        }
    }
}

/// Serial tiled convolution with traffic accounting.
pub fn conv_tiled_counted(
    x: &Tensor4,
    w: &Tensor4,
    plan: &TilePlan,
    counters: &TrafficCounters,
) -> Tensor4 {
    let s = &plan.shape;
    crate::conv::assert_conv_operands(x, w, s);
    if s.updates() == 0 {
        // degenerate shape (some extent is zero): nothing to compute, and
        // the tile grid must not fabricate a tile over an empty dim
        return Tensor4::zeros(out_dims(s));
    }
    let outs = tiles::output_tiles(plan);
    let red = tiles::reduction_tiles(plan);
    let mut out = Tensor4::zeros(out_dims(s));
    for ot in &outs {
        let buf = run_out_tile(x, w, plan, *ot, &red, counters);
        scatter(&mut out, ot, &buf);
    }
    out
}

/// Serial tiled convolution (counters discarded).
pub fn conv_tiled(x: &Tensor4, w: &Tensor4, plan: &TilePlan) -> Tensor4 {
    conv_tiled_counted(x, w, plan, &TrafficCounters::new())
}

/// Tiled convolution with output tiles fanned out over a [`ThreadPool`].
///
/// Operands arrive as `Arc`s because pool jobs must be `'static`; callers
/// on the hot path should hold their tensors in `Arc`s to begin with (the
/// native backend's tiled executable clones once per request — see the
/// ROADMAP open item on scoped zero-copy dispatch). Bitwise identical to
/// [`conv_tiled`]: each output tile runs serially on one worker in the
/// same reduction order.
pub fn conv_tiled_parallel(
    x: &Arc<Tensor4>,
    w: &Arc<Tensor4>,
    plan: &Arc<TilePlan>,
    pool: &ThreadPool,
    counters: &Arc<TrafficCounters>,
) -> Tensor4 {
    let s = plan.shape;
    crate::conv::assert_conv_operands(x, w, &s);
    if s.updates() == 0 {
        return Tensor4::zeros(out_dims(&s));
    }
    let outs = tiles::output_tiles(plan);
    let red = Arc::new(tiles::reduction_tiles(plan));
    let (x2, w2, p2) = (Arc::clone(x), Arc::clone(w), Arc::clone(plan));
    let (r2, c2) = (Arc::clone(&red), Arc::clone(counters));
    let bufs = pool.map(outs.clone(), move |ot| {
        run_out_tile(&x2, &w2, &p2, ot, &r2, &c2)
    });
    let mut out = Tensor4::zeros(out_dims(&s));
    for (ot, buf) in outs.iter().zip(&bufs) {
        scatter(&mut out, ot, buf);
    }
    out
}

/// The traffic the engine *will* charge for `plan`, computed analytically
/// from the tile grid (no execution). Serial and parallel runs both match
/// this exactly — the invariant the property tests assert — and it is the
/// number to hold against the `commvol::seq` blocking model.
pub fn expected_traffic(plan: &TilePlan) -> Traffic {
    let s = &plan.shape;
    if s.updates() == 0 {
        // mirror the execution paths' degenerate early-return, so the
        // measured == analytic invariant holds for zero-extent shapes too
        return Traffic::default();
    }
    let (sw, sh) = (s.s_w, s.s_h);
    let (wf, hf) = (s.w_f, s.h_f);
    let outs = tiles::output_tiles(plan);
    let red = tiles::reduction_tiles(plan);
    // valid split coordinates (σ·q + r < filter extent) depend only on the
    // reduction tile: precompute cI·v6·v7 per RedTile once
    let red_filter: Vec<u64> = red
        .iter()
        .map(|rt| {
            let v6: u64 = (rt.qw.start..rt.qw.start + rt.qw.len)
                .map(|q| {
                    (rt.rw.start..rt.rw.start + rt.rw.len)
                        .filter(|&r| sw * q + r < wf)
                        .count() as u64
                })
                .sum();
            let v7: u64 = (rt.qh.start..rt.qh.start + rt.qh.len)
                .map(|q| {
                    (rt.rh.start..rt.rh.start + rt.rh.len)
                        .filter(|&r| sh * q + r < hf)
                        .count() as u64
                })
                .sum();
            rt.ci.len * v6 * v7
        })
        .collect();
    let mut t = Traffic::default();
    for ot in &outs {
        for (rt, &fil) in red.iter().zip(&red_filter) {
            let ew = ot.wo.len + rt.qw.len - 1;
            let eh = ot.ho.len + rt.qh.len - 1;
            t.input_words += ot.n.len * rt.ci.len * rt.rw.len * rt.rh.len * ew * eh;
            t.filter_words += ot.co.len * fil;
        }
        t.output_words += ot.n.len * ot.co.len * ot.wo.len * ot.ho.len;
    }
    t
}

// ---------------- network pipelines ----------------

/// Per-stage traffic counters for a network pipeline. Each stage owns one
/// [`TrafficCounters`] behind an `Arc` so materialized stages can hand it
/// straight to [`conv_tiled_parallel`] while fused sweeps charge it from
/// worker threads.
#[derive(Debug, Clone)]
pub struct NetTrafficCounters {
    stages: Vec<Arc<TrafficCounters>>,
}

impl NetTrafficCounters {
    pub fn new(stages: usize) -> NetTrafficCounters {
        NetTrafficCounters {
            stages: (0..stages).map(|_| Arc::new(TrafficCounters::new())).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.stages.len()
    }

    pub fn is_empty(&self) -> bool {
        self.stages.is_empty()
    }

    /// Stage `k`'s counters.
    pub fn stage(&self, k: usize) -> &Arc<TrafficCounters> {
        &self.stages[k]
    }

    /// Per-stage snapshots, in stage order.
    pub fn snapshot(&self) -> Vec<Traffic> {
        self.stages.iter().map(|c| c.snapshot()).collect()
    }

    /// Sum of all stages.
    pub fn total(&self) -> Traffic {
        Traffic::sum(&self.snapshot())
    }

    pub fn reset(&self) {
        for c in &self.stages {
            c.reset();
        }
    }
}

/// Validate the (image, per-stage filters) operands of a network chain.
fn assert_network_operands(image: &Tensor4, filters: &[&Tensor4], stages: &[NetworkStage]) {
    assert!(!stages.is_empty(), "empty network");
    assert_eq!(filters.len(), stages.len(), "one filter per stage");
    crate::conv::assert_conv_operands(image, filters[0], &stages[0].shape);
    for (k, st) in stages.iter().enumerate().skip(1) {
        assert_eq!(
            filters[k].dims,
            st.shape.filter_dims(),
            "stage {k} filter shape mismatch"
        );
    }
}

/// Execute one fused tile: copy the halo'd image patch out of `input`
/// (the only input-side main-memory traffic the group charges), then run
/// each stage as a patch-local [`conv7nl_naive`] — identical per-element
/// accumulation order, so the fused result is bitwise identical to the
/// stage-by-stage oracle — holding every inter-stage activation in the
/// scratch tensor that ping-pongs between stages.
fn run_fused_tile(
    input: &Tensor4,
    filters: &[&Tensor4],
    stages: &[NetworkStage],
    g: &FuseGroup,
    tn: Blk,
    tw: Blk,
    th: Blk,
    counters: &NetTrafficCounters,
) -> Tensor4 {
    let spans = group_spans(stages, g.start, g.end, tw, th);
    let head = &stages[g.start].shape;
    let in_sp = input_span(head, &spans[0]);
    let bn = tn.len as usize;
    let ci0 = head.c_i as usize;
    let (iw, ih) = (in_sp.w_len() as usize, in_sp.h_len() as usize);
    let mut cur = Tensor4::zeros([bn, ci0, iw, ih]);
    // the h-axis is contiguous in both the source tensor and the patch:
    // copy whole rows, no per-element bounds checks on the hot path
    let mut k = 0;
    for n in 0..bn {
        let na = tn.start as usize + n;
        for c in 0..ci0 {
            for a in 0..iw {
                let wa = in_sp.w0 as usize + a;
                let src = input.idx(na, c, wa, in_sp.h0 as usize);
                cur.data[k..k + ih].copy_from_slice(&input.data[src..src + ih]);
                k += ih;
            }
        }
    }
    counters.stage(g.start).add_input(cur.len() as u64);
    for (ki, stage) in (g.start..=g.end).enumerate() {
        let st = &stages[stage];
        let sp = &spans[ki];
        let sub = ConvShape {
            n: tn.len,
            w_o: sp.w_len(),
            h_o: sp.h_len(),
            ..st.shape
        };
        cur = conv7nl_naive(&cur, filters[stage], &sub);
        counters.stage(stage).add_filter(st.shape.filter_size());
    }
    counters.stage(g.end).add_output(cur.len() as u64);
    cur
}

/// Write one finished fused tile into the network output tensor
/// (contiguous h-rows on both sides, so whole-row copies).
fn scatter_network(out: &mut Tensor4, tn: Blk, tw: Blk, th: Blk, tile: &Tensor4) {
    let bh = tile.dims[3];
    let mut k = 0;
    for n in 0..tile.dims[0] {
        for c in 0..tile.dims[1] {
            for a in 0..tile.dims[2] {
                let dst = out.idx(
                    tn.start as usize + n,
                    c,
                    tw.start as usize + a,
                    th.start as usize,
                );
                out.data[dst..dst + bh].copy_from_slice(&tile.data[k..k + bh]);
                k += bh;
            }
        }
    }
}

fn network_out_dims(stages: &[NetworkStage], g: &FuseGroup) -> [usize; 4] {
    let s = &stages[g.end].shape;
    [s.n as usize, s.c_o as usize, s.w_o as usize, s.h_o as usize]
}

/// Serial fused network execution with per-stage traffic accounting.
/// Fused groups sweep the last stage's output tiles, recomputing upstream
/// halo regions in scratch; materialized (single-stage) groups run the
/// stage's LP-tiled engine. Within fused groups the per-element operation
/// order equals the oracle's, so a plan that fuses end to end is bitwise
/// identical to [`super::fuse::naive_network`] (materialized stages use
/// the tiled engine's accumulation order and agree to float tolerance).
pub fn conv_network_fused_counted(
    image: &Tensor4,
    filters: &[&Tensor4],
    plan: &FusePlan,
    counters: &NetTrafficCounters,
) -> Tensor4 {
    assert_network_operands(image, filters, &plan.stages);
    assert_eq!(counters.len(), plan.stages.len(), "counter arity");
    let mut act: Option<Tensor4> = None;
    for g in &plan.groups {
        let input: &Tensor4 = act.as_ref().unwrap_or(image);
        let next = if g.is_fused() {
            let mut out = Tensor4::zeros(network_out_dims(&plan.stages, g));
            for (tn, tw, th) in group_tiles(&plan.stages, g) {
                let tile =
                    run_fused_tile(input, filters, &plan.stages, g, tn, tw, th, counters);
                scatter_network(&mut out, tn, tw, th, &tile);
            }
            out
        } else {
            let k = g.start;
            conv_tiled_counted(
                input,
                filters[k],
                &plan.stage_plans[k],
                counters.stage(k),
            )
        };
        act = Some(next);
    }
    act.expect("network has at least one stage")
}

/// Fused network execution with tiles of each fused group fanned out over
/// a [`ThreadPool`] (materialized stages fan out through
/// [`conv_tiled_parallel`]). Bitwise identical to the serial path: every
/// tile is computed by one worker in the same per-element order.
pub fn conv_network_fused(
    image: &Arc<Tensor4>,
    filters: &[Arc<Tensor4>],
    plan: &Arc<FusePlan>,
    pool: &ThreadPool,
    counters: &NetTrafficCounters,
) -> Tensor4 {
    {
        let frefs: Vec<&Tensor4> = filters.iter().map(|f| f.as_ref()).collect();
        assert_network_operands(image, &frefs, &plan.stages);
    }
    assert_eq!(counters.len(), plan.stages.len(), "counter arity");
    let mut act: Arc<Tensor4> = Arc::clone(image);
    for (gi, g) in plan.groups.iter().enumerate() {
        let next = if g.is_fused() {
            let tiles = group_tiles(&plan.stages, g);
            let mut out = Tensor4::zeros(network_out_dims(&plan.stages, g));
            let (x2, p2) = (Arc::clone(&act), Arc::clone(plan));
            let f2: Vec<Arc<Tensor4>> = filters.to_vec();
            let c2 = counters.clone();
            let bufs = pool.map(tiles.clone(), move |(tn, tw, th)| {
                let g = p2.groups[gi];
                let frefs: Vec<&Tensor4> = f2.iter().map(|f| f.as_ref()).collect();
                run_fused_tile(&x2, &frefs, &p2.stages, &g, tn, tw, th, &c2)
            });
            for ((tn, tw, th), tile) in tiles.iter().zip(&bufs) {
                scatter_network(&mut out, *tn, *tw, *th, tile);
            }
            out
        } else {
            let k = g.start;
            conv_tiled_parallel(
                &act,
                &filters[k],
                &plan.stage_plans[k],
                pool,
                counters.stage(k),
            )
        };
        act = Arc::new(next);
    }
    Arc::try_unwrap(act).unwrap_or_else(|a| (*a).clone())
}

/// Layer-by-layer baseline: every stage runs the LP-tiled engine and every
/// activation round-trips through a materialized tensor — the traffic the
/// fusion planner's `fused ≤ unfused` claim is measured against.
pub fn conv_network_staged(
    image: &Arc<Tensor4>,
    filters: &[Arc<Tensor4>],
    plan: &FusePlan,
    pool: &ThreadPool,
    counters: &NetTrafficCounters,
) -> Tensor4 {
    {
        let frefs: Vec<&Tensor4> = filters.iter().map(|f| f.as_ref()).collect();
        assert_network_operands(image, &frefs, &plan.stages);
    }
    assert_eq!(counters.len(), plan.stages.len(), "counter arity");
    let mut act: Arc<Tensor4> = Arc::clone(image);
    for k in 0..plan.stages.len() {
        act = Arc::new(conv_tiled_parallel(
            &act,
            &filters[k],
            &plan.stage_plans[k],
            pool,
            counters.stage(k),
        ));
    }
    Arc::try_unwrap(act).unwrap_or_else(|a| (*a).clone())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::{conv7nl_naive, Precision};

    fn run_pair(s: &ConvShape, m: f64, seed: u64) -> (Tensor4, Tensor4, Traffic) {
        let (x, w) = crate::conv::paper_operands(s, seed);
        let plan = TilePlan::new(s, Precision::uniform(), m);
        let ctr = TrafficCounters::new();
        let got = conv_tiled_counted(&x, &w, &plan, &ctr);
        let want = conv7nl_naive(&x, &w, s);
        (got, want, ctr.snapshot())
    }

    #[test]
    fn matches_naive_unit_stride() {
        let s = ConvShape::new(2, 3, 4, 5, 5, 3, 3, 1, 1);
        let (got, want, t) = run_pair(&s, 1024.0, 1);
        assert!(got.rel_l2(&want) < 1e-5, "rel {}", got.rel_l2(&want));
        assert_eq!(t.output_words, s.output_size());
        assert!(t.input_words > 0 && t.filter_words > 0);
    }

    #[test]
    fn matches_naive_strided_nonsquare() {
        // stride 2x3, non-square 5x4 filter, ragged everything
        let s = ConvShape::new(2, 3, 5, 7, 5, 5, 4, 2, 3);
        let (got, want, _) = run_pair(&s, 512.0, 3);
        assert!(got.rel_l2(&want) < 1e-4, "rel {}", got.rel_l2(&want));
    }

    #[test]
    fn matches_naive_tiny_memory_many_tiles() {
        // memory barely above the planner floor forces deep tiling
        let s = ConvShape::new(3, 4, 6, 9, 11, 3, 2, 1, 1);
        let (got, want, t) = run_pair(&s, 64.0, 5);
        assert!(got.rel_l2(&want) < 1e-4, "rel {}", got.rel_l2(&want));
        // deep tiling re-reads the input many times
        assert!(t.input_words > s.input_size());
    }

    #[test]
    fn measured_traffic_matches_expected_exactly() {
        for (s, m) in [
            (ConvShape::new(2, 3, 4, 6, 6, 3, 3, 1, 1), 256.0),
            (ConvShape::new(1, 2, 3, 4, 4, 3, 3, 2, 2), 128.0),
            (ConvShape::new(2, 5, 7, 7, 5, 4, 5, 3, 2), 512.0),
        ] {
            let plan = TilePlan::new(&s, Precision::uniform(), m);
            let (x, w) = crate::conv::paper_operands(&s, 11);
            let ctr = TrafficCounters::new();
            conv_tiled_counted(&x, &w, &plan, &ctr);
            assert_eq!(ctr.snapshot(), expected_traffic(&plan), "{s}");
        }
    }

    #[test]
    fn parallel_is_bitwise_identical_to_serial() {
        let s = ConvShape::new(3, 4, 8, 10, 9, 3, 3, 1, 1);
        let plan = Arc::new(TilePlan::new(&s, Precision::uniform(), 512.0));
        let (x, w) = crate::conv::paper_operands(&s, 21);
        let (x, w) = (Arc::new(x), Arc::new(w));
        let serial = conv_tiled(&x, &w, &plan);
        let pool = ThreadPool::new(4);
        let ctr = Arc::new(TrafficCounters::new());
        let par = conv_tiled_parallel(&x, &w, &plan, &pool, &ctr);
        assert_eq!(par.max_abs_diff(&serial), 0.0);
        // counters see the same totals regardless of interleaving
        assert_eq!(ctr.snapshot(), expected_traffic(&plan));
    }

    #[test]
    fn degenerate_shapes_return_empty_or_zero_output() {
        // zero batch: empty output, no tile fabricated over the empty dim
        let s = ConvShape::new(0, 3, 4, 5, 5, 3, 3, 1, 1);
        let plan = TilePlan::new(&s, Precision::uniform(), 1024.0);
        let x = Tensor4::zeros([0, 3, 8, 8]);
        let w = Tensor4::zeros([3, 4, 3, 3]);
        let out = conv_tiled(&x, &w, &plan);
        assert_eq!(out.dims, [0, 4, 5, 5]);
        assert!(out.is_empty());

        // zero input channels: full-size all-zero output, like the oracle
        let s2 = ConvShape::new(2, 0, 4, 5, 5, 3, 3, 1, 1);
        let plan2 = TilePlan::new(&s2, Precision::uniform(), 1024.0);
        let x2 = Tensor4::zeros([2, 0, 8, 8]);
        let w2 = Tensor4::zeros([0, 4, 3, 3]);
        let out2 = conv_tiled(&x2, &w2, &plan2);
        assert_eq!(out2.dims, [2, 4, 5, 5]);
        assert!(out2.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn counters_reset() {
        let c = TrafficCounters::new();
        c.add_input(5);
        c.add_filter(3);
        c.add_output(2);
        assert_eq!(c.snapshot().total(), 10);
        c.reset();
        assert_eq!(c.snapshot(), Traffic::default());
    }
}
