//! The tiled conv execution engine: iterate the LP-blocked tile grid, pack
//! each tile's working set, run the microkernel, and count every word that
//! crosses the (modelled) fast-memory boundary.
//!
//! Execution structure — the loop nest the §3.2 LP optimizes:
//!
//! ```text
//! for each output tile (blocks of n, cO, wO, hO):          // parallel
//!     out_buf = 0                                          // resident
//!     for each reduction tile (blocks of cI, q6, q7, r6, r7):
//!         pack input patch      -> count input words
//!         pack filter block     -> count filter words
//!         microkernel MAC into out_buf
//!     scatter out_buf to the output tensor -> count output words
//! ```
//!
//! Keeping the output tile resident across the whole reduction loop is why
//! measured traffic lands *below* the `commvol::seq` blocking model (which
//! charges the full three-operand footprint per tile step) while staying
//! within its 2× envelope — the property the acceptance tests pin down.
//!
//! Parallelism: output tiles write disjoint output regions, so tile
//! execution fans out over [`ThreadPool`] workers with no synchronization
//! beyond the traffic counters (relaxed atomics). Each output tile is
//! computed serially by one worker in a fixed reduction order, so the
//! parallel result is bitwise identical to the serial one.
//!
//! Network pipelines (the fused executor at the bottom of this file) sweep
//! the last fused stage's output tiles; every fused stage runs through the
//! same packed panels and axpy microkernel as one full reduction tile —
//! which pins its per-element accumulation order to the naive nest's
//! ascending `(cI, i6, i7)` (the contract `gemm.rs` documents), keeping
//! fused output bitwise identical to the stage-by-stage oracle — while a
//! sliding-window halo cache carries each level's overlap rows between
//! adjacent h-tiles so the head re-reads and the upstream recompute only
//! cover fresh rows.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::conv::{
    assert_pass_operands, conv7nl_naive, dinput_naive, ConvPass, ConvShape,
    NetworkStage, Tensor4,
};
use crate::obs::{self, jf, js, ju};
use crate::util::threadpool::ThreadPool;

use super::fuse::{
    bwd_group_spans, bwd_group_tile_columns, group_spans, group_tile_columns,
    input_overlap_cols, input_overlap_rows, input_span, FuseGroup, FusePlan,
    FusedExec, NetPass, Span,
};
use super::gemm::{self, TileDims};
use super::pack;
use super::plan::{filter_split_ranges, TilePlan};
use super::tiles::{self, Blk, OutTile, RedTile};

/// Worker count for tile-execution pools: cores minus one (the spare runs
/// the batcher/executor threads), capped at 8 — packed-tile MACs saturate
/// memory bandwidth before they scale further. One policy shared by the
/// native backend and the benches, so `BENCH_kernels.json` measures the
/// pool shape production uses.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .saturating_sub(1)
        .clamp(1, 8)
}

/// A word-traffic snapshot, in f32 words per operand.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Traffic {
    pub input_words: u64,
    pub filter_words: u64,
    pub output_words: u64,
}

impl Traffic {
    pub fn total(&self) -> u64 {
        self.input_words + self.filter_words + self.output_words
    }

    /// Element-wise sum over a slice of per-stage snapshots.
    pub fn sum(stages: &[Traffic]) -> Traffic {
        let mut t = Traffic::default();
        for s in stages {
            t.input_words += s.input_words;
            t.filter_words += s.filter_words;
            t.output_words += s.output_words;
        }
        t
    }
}

/// Thread-safe word-traffic counters the engine charges while executing.
#[derive(Debug, Default)]
pub struct TrafficCounters {
    input: AtomicU64,
    filter: AtomicU64,
    output: AtomicU64,
}

impl TrafficCounters {
    pub fn new() -> TrafficCounters {
        TrafficCounters::default()
    }

    pub(crate) fn add_input(&self, words: u64) {
        self.input.fetch_add(words, Ordering::Relaxed);
    }

    pub(crate) fn add_filter(&self, words: u64) {
        self.filter.fetch_add(words, Ordering::Relaxed);
    }

    pub(crate) fn add_output(&self, words: u64) {
        self.output.fetch_add(words, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> Traffic {
        Traffic {
            input_words: self.input.load(Ordering::Relaxed),
            filter_words: self.filter.load(Ordering::Relaxed),
            output_words: self.output.load(Ordering::Relaxed),
        }
    }

    pub fn reset(&self) {
        self.input.store(0, Ordering::Relaxed);
        self.filter.store(0, Ordering::Relaxed);
        self.output.store(0, Ordering::Relaxed);
    }
}

pub(crate) fn out_dims(s: &ConvShape) -> [usize; 4] {
    [s.n as usize, s.c_o as usize, s.w_o as usize, s.h_o as usize]
}

// ---------------- trace guards ----------------
//
// Every traced traffic event pairs the measured counter delta with the
// analytic expectation computed from the same plan, so `trace summarize`
// can flag any divergence offline — the measured == expected invariant
// the property tests assert, re-checked on every traced run.

thread_local! {
    /// Depth of enclosing traced network sweeps on this thread. The
    /// network sweeps charge their materialized stages through the
    /// single-layer entry points below; suppressing the single-layer
    /// `traffic` events inside a sweep keeps the sweep's `stage_traffic`
    /// events the only charge for those words (summarize totals would
    /// otherwise double-count).
    static NET_SWEEP_DEPTH: Cell<u32> = const { Cell::new(0) };
}

fn traffic_delta(after: &Traffic, before: &Traffic) -> Traffic {
    Traffic {
        input_words: after.input_words - before.input_words,
        filter_words: after.filter_words - before.filter_words,
        output_words: after.output_words - before.output_words,
    }
}

/// Emits one `traffic` event for a single-layer tiled run: the measured
/// counter delta next to [`expected_pass_traffic`]'s analytic words.
/// Inert (no snapshot, one branch) when tracing is off or a network
/// sweep above is already charging these words.
struct PassTraceGuard {
    before: Option<(Traffic, Instant)>,
}

impl PassTraceGuard {
    fn start(counters: &TrafficCounters) -> PassTraceGuard {
        if !obs::enabled() || NET_SWEEP_DEPTH.with(|d| d.get()) > 0 {
            return PassTraceGuard { before: None };
        }
        PassTraceGuard { before: Some((counters.snapshot(), Instant::now())) }
    }

    fn finish(self, plan: &TilePlan, counters: &TrafficCounters) {
        let Some((before, t0)) = self.before else { return };
        let m = traffic_delta(&counters.snapshot(), &before);
        let e = expected_pass_traffic(plan);
        obs::event(
            obs::kind::TRAFFIC,
            &[
                ("pass", js(plan.pass.name())),
                ("shape", js(&plan.shape.to_string())),
                ("secs", jf(t0.elapsed().as_secs_f64())),
                ("measured_input", ju(m.input_words)),
                ("measured_filter", ju(m.filter_words)),
                ("measured_output", ju(m.output_words)),
                ("expected_input", ju(e.input_words)),
                ("expected_filter", ju(e.filter_words)),
                ("expected_output", ju(e.output_words)),
            ],
        );
    }
}

/// Emits one `net_exec` event plus one `stage_traffic` event per stage
/// for a network sweep: per-stage measured deltas (word traffic and
/// halo-cache words) next to the plan's analytic expectations. While
/// live, single-layer guards on this thread are suppressed.
struct NetTraceGuard {
    before: Option<(Vec<Traffic>, Vec<u64>, Instant)>,
}

impl NetTraceGuard {
    fn start(counters: &NetTrafficCounters) -> NetTraceGuard {
        if !obs::enabled() {
            return NetTraceGuard { before: None };
        }
        NET_SWEEP_DEPTH.with(|d| d.set(d.get() + 1));
        NetTraceGuard {
            before: Some((
                counters.snapshot(),
                counters.halo_snapshot(),
                Instant::now(),
            )),
        }
    }

    fn finish(
        mut self,
        plan: &FusePlan,
        expected: &[Traffic],
        expected_halo: &[u64],
        counters: &NetTrafficCounters,
    ) {
        let Some((before, halo_before, t0)) = self.before.take() else { return };
        NET_SWEEP_DEPTH.with(|d| d.set(d.get() - 1));
        let after = counters.snapshot();
        let halo_after = counters.halo_snapshot();
        obs::event(
            obs::kind::NET_EXEC,
            &[
                ("pass", js(plan.pass.name())),
                ("stages", ju(plan.stages.len() as u64)),
                ("groups", ju(plan.groups.len() as u64)),
                ("fused_boundaries", ju(plan.fused_boundaries() as u64)),
                ("secs", jf(t0.elapsed().as_secs_f64())),
            ],
        );
        for k in 0..plan.stages.len() {
            let m = traffic_delta(&after[k], &before[k]);
            let e = expected[k];
            obs::event(
                obs::kind::STAGE_TRAFFIC,
                &[
                    ("pass", js(plan.pass.name())),
                    ("stage", ju(k as u64)),
                    ("measured_input", ju(m.input_words)),
                    ("measured_filter", ju(m.filter_words)),
                    ("measured_output", ju(m.output_words)),
                    ("expected_input", ju(e.input_words)),
                    ("expected_filter", ju(e.filter_words)),
                    ("expected_output", ju(e.output_words)),
                    ("halo_words", ju(halo_after[k] - halo_before[k])),
                    ("expected_halo_words", ju(expected_halo[k])),
                ],
            );
        }
    }
}

impl Drop for NetTraceGuard {
    fn drop(&mut self) {
        // A sweep that unwinds (e.g. an injected tile panic propagating
        // through `ThreadPool::map`) must still restore the suppression
        // depth, or every later single-layer run on this thread would go
        // untraced. `finish` takes `before`, so this never double-counts.
        if self.before.take().is_some() {
            NET_SWEEP_DEPTH.with(|d| d.set(d.get() - 1));
        }
    }
}

/// Execute every reduction tile against one resident output tile; returns
/// the accumulated `[bn][bwo][bho][bco]` buffer. When `seed` is given the
/// buffer starts from that tensor's values instead of zero — the
/// association-preserving continuation used by the channel-sharded
/// traveling accumulator.
fn run_out_tile(
    x: &Tensor4,
    w: &Tensor4,
    plan: &TilePlan,
    ot: OutTile,
    red: &[RedTile],
    counters: &TrafficCounters,
    seed: Option<&Tensor4>,
) -> Vec<f32> {
    crate::testkit::faults::exec_point();
    let s = &plan.shape;
    let (sw, sh) = (s.s_w as usize, s.s_h as usize);
    let (wf, hf) = (s.w_f as usize, s.h_f as usize);
    let bn = ot.n.len as usize;
    let bco = ot.co.len as usize;
    let bwo = ot.wo.len as usize;
    let bho = ot.ho.len as usize;
    let mut out = vec![0.0f32; bn * bwo * bho * bco];
    if let Some(acc) = seed {
        gather_seed(acc, &ot, &mut out);
    }
    // pack buffers live across the whole reduction loop (and grow to the
    // interior-block size once): no per-tile allocation on the hot path
    let mut xin: Vec<f32> = Vec::new();
    let mut fil: Vec<f32> = Vec::new();
    for rt in red {
        let (ew, eh) = pack::pack_input(x, sw, sh, &ot, rt, &mut xin);
        let fil_words = pack::pack_filter(w, sw, sh, wf, hf, &ot, rt, &mut fil);
        counters.add_input(xin.len() as u64);
        counters.add_filter(fil_words);
        let d = TileDims {
            bn,
            bci: rt.ci.len as usize,
            bco,
            bwo,
            bho,
            bqw: rt.qw.len as usize,
            bqh: rt.qh.len as usize,
            brw: rt.rw.len as usize,
            brh: rt.rh.len as usize,
            ew,
            eh,
            q6_0: rt.qw.start as usize,
            q7_0: rt.qh.start as usize,
            r6_0: rt.rw.start as usize,
            r7_0: rt.rh.start as usize,
            sw,
            sh,
            wf,
            hf,
        };
        gemm::conv_tile_mac(&mut out, &xin, &fil, &d);
    }
    counters.add_output(out.len() as u64);
    out
}

/// Read one output-tile region of `acc` into a buffer laid out exactly as
/// [`scatter`] expects (`[bn][bwo][bho][bco]`).
fn gather_seed(acc: &Tensor4, ot: &OutTile, buf: &mut [f32]) {
    let bn = ot.n.len as usize;
    let bco = ot.co.len as usize;
    let bwo = ot.wo.len as usize;
    let bho = ot.ho.len as usize;
    let (n0, co0) = (ot.n.start as usize, ot.co.start as usize);
    let (wo0, ho0) = (ot.wo.start as usize, ot.ho.start as usize);
    let mut k = 0;
    for n in 0..bn {
        for i4 in 0..bwo {
            for i5 in 0..bho {
                for co in 0..bco {
                    buf[k] = acc.at(n0 + n, co0 + co, wo0 + i4, ho0 + i5);
                    k += 1;
                }
            }
        }
    }
}

/// Write one finished output-tile buffer into the output tensor.
fn scatter(out: &mut Tensor4, ot: &OutTile, buf: &[f32]) {
    let bn = ot.n.len as usize;
    let bco = ot.co.len as usize;
    let bwo = ot.wo.len as usize;
    let bho = ot.ho.len as usize;
    let (n0, co0) = (ot.n.start as usize, ot.co.start as usize);
    let (wo0, ho0) = (ot.wo.start as usize, ot.ho.start as usize);
    let mut k = 0;
    for n in 0..bn {
        for i4 in 0..bwo {
            for i5 in 0..bho {
                for co in 0..bco {
                    *out.at_mut(n0 + n, co0 + co, wo0 + i4, ho0 + i5) = buf[k];
                    k += 1;
                }
            }
        }
    }
}

/// Serial tiled convolution with traffic accounting.
pub fn conv_tiled_counted(
    x: &Tensor4,
    w: &Tensor4,
    plan: &TilePlan,
    counters: &TrafficCounters,
) -> Tensor4 {
    let s = &plan.shape;
    crate::conv::assert_conv_operands(x, w, s);
    if s.updates() == 0 {
        // degenerate shape (some extent is zero): nothing to compute, and
        // the tile grid must not fabricate a tile over an empty dim
        return Tensor4::zeros(out_dims(s));
    }
    let tg = PassTraceGuard::start(counters);
    let outs = tiles::output_tiles(plan);
    let red = tiles::reduction_tiles(plan);
    let mut out = Tensor4::zeros(out_dims(s));
    for ot in &outs {
        let buf = run_out_tile(x, w, plan, *ot, &red, counters, None);
        scatter(&mut out, ot, &buf);
    }
    tg.finish(plan, counters);
    out
}

/// Serial tiled convolution (counters discarded).
pub fn conv_tiled(x: &Tensor4, w: &Tensor4, plan: &TilePlan) -> Tensor4 {
    conv_tiled_counted(x, w, plan, &TrafficCounters::new())
}

/// Tiled convolution that *adds onto* `acc` instead of writing a fresh
/// output: every output-tile buffer is seeded from `acc`, the reduction
/// tiles run in the standard ci-outermost order, and the result is
/// scattered back in place.
///
/// Seeding-then-adding appends this plan's MAC contributions to the
/// accumulator in exactly the f32 operation order the single-node engine
/// would have used had it continued past the seed's ci blocks — so a chain
/// of these calls over an ascending input-channel partition is bitwise
/// identical to one unsharded [`conv_tiled_counted`] run (the channel-shard
/// accumulation-order contract, DESIGN.md §13).
pub fn conv_tiled_accumulate_counted(
    x: &Tensor4,
    w: &Tensor4,
    plan: &TilePlan,
    acc: &mut Tensor4,
    counters: &TrafficCounters,
) {
    let s = &plan.shape;
    crate::conv::assert_conv_operands(x, w, s);
    assert_eq!(acc.dims, out_dims(s), "accumulator shape mismatch");
    if s.updates() == 0 {
        return;
    }
    let tg = PassTraceGuard::start(counters);
    let outs = tiles::output_tiles(plan);
    let red = tiles::reduction_tiles(plan);
    for ot in &outs {
        let buf = run_out_tile(x, w, plan, *ot, &red, counters, Some(acc));
        scatter(acc, ot, &buf);
    }
    tg.finish(plan, counters);
}

/// Tiled convolution with output tiles fanned out over a [`ThreadPool`].
///
/// Operands arrive as `Arc`s because pool jobs must be `'static`; callers
/// on the hot path should hold their tensors in `Arc`s to begin with (the
/// native backend's tiled executable clones once per request — see the
/// ROADMAP open item on scoped zero-copy dispatch). Bitwise identical to
/// [`conv_tiled`]: each output tile runs serially on one worker in the
/// same reduction order.
pub fn conv_tiled_parallel(
    x: &Arc<Tensor4>,
    w: &Arc<Tensor4>,
    plan: &Arc<TilePlan>,
    pool: &ThreadPool,
    counters: &Arc<TrafficCounters>,
) -> Tensor4 {
    let s = plan.shape;
    crate::conv::assert_conv_operands(x, w, &s);
    if s.updates() == 0 {
        return Tensor4::zeros(out_dims(&s));
    }
    let tg = PassTraceGuard::start(counters);
    let outs = tiles::output_tiles(plan);
    let red = Arc::new(tiles::reduction_tiles(plan));
    let (x2, w2, p2) = (Arc::clone(x), Arc::clone(w), Arc::clone(plan));
    let (r2, c2) = (Arc::clone(&red), Arc::clone(counters));
    let bufs = pool.map(outs.clone(), move |ot| {
        run_out_tile(&x2, &w2, &p2, ot, &r2, &c2, None)
    });
    let mut out = Tensor4::zeros(out_dims(&s));
    for (ot, buf) in outs.iter().zip(&bufs) {
        scatter(&mut out, ot, buf);
    }
    tg.finish(plan, counters);
    out
}

/// The traffic the engine *will* charge for `plan`, computed analytically
/// from the tile grid (no execution). Serial and parallel runs both match
/// this exactly — the invariant the property tests assert — and it is the
/// number to hold against the `commvol::seq` blocking model.
pub fn expected_traffic(plan: &TilePlan) -> Traffic {
    let s = &plan.shape;
    if s.updates() == 0 {
        // mirror the execution paths' degenerate early-return, so the
        // measured == analytic invariant holds for zero-extent shapes too
        return Traffic::default();
    }
    let (sw, sh) = (s.s_w, s.s_h);
    let (wf, hf) = (s.w_f, s.h_f);
    let outs = tiles::output_tiles(plan);
    let red = tiles::reduction_tiles(plan);
    // valid split coordinates (σ·q + r < filter extent) depend only on the
    // reduction tile: precompute cI·v6·v7 per RedTile once
    let red_filter: Vec<u64> = red
        .iter()
        .map(|rt| {
            let v6: u64 = (rt.qw.start..rt.qw.start + rt.qw.len)
                .map(|q| {
                    (rt.rw.start..rt.rw.start + rt.rw.len)
                        .filter(|&r| sw * q + r < wf)
                        .count() as u64
                })
                .sum();
            let v7: u64 = (rt.qh.start..rt.qh.start + rt.qh.len)
                .map(|q| {
                    (rt.rh.start..rt.rh.start + rt.rh.len)
                        .filter(|&r| sh * q + r < hf)
                        .count() as u64
                })
                .sum();
            rt.ci.len * v6 * v7
        })
        .collect();
    let mut t = Traffic::default();
    for ot in &outs {
        for (rt, &fil) in red.iter().zip(&red_filter) {
            let ew = ot.wo.len + rt.qw.len - 1;
            let eh = ot.ho.len + rt.qh.len - 1;
            t.input_words += ot.n.len * rt.ci.len * rt.rw.len * rt.rh.len * ew * eh;
            t.filter_words += ot.co.len * fil;
        }
        t.output_words += ot.n.len * ot.co.len * ot.wo.len * ot.ho.len;
    }
    t
}

// ---------------- backward passes (dFilter / dInput) ----------------
//
// The gradient convolutions run the same machinery — LP-derived
// [`TilePlan`], `tiles.rs` enumeration, packed per-step working sets,
// resident output tiles, exact traffic counters — instantiated for the
// pass's permuted dim roles (`TilePlan::for_pass`).
//
// **Backward accumulation-order contract.** Tiled gradients are *bitwise*
// identical to the `conv/training.rs` naive oracles, for every plan:
//
// * the only blocked reduction dim is the contracted channel (N for
//   dFilter, cO for dInput), and its blocks are swept in ascending order
//   — so per output element the reduction visits the contracted channel
//   exactly as the oracle's flat nest does, regardless of the block size;
// * within one reduction step the pass's remaining reduction loops run in
//   full, in the oracle's own order — dFilter forms one scalar
//   accumulator per (element, n) over ascending (wO, hO) and adds it once
//   (the oracle's `acc` structure), dInput adds directly per ascending
//   (i6, i7) tap with the oracle's zero-tap skip;
// * every term is the same single mul-add on the same operand values.
//
// Blocking the swept loops would interleave their term order across tiles
// and break bitwise equality — which is why `TilePlan::for_pass` pins
// those blocks to the full range (the backward analogue of the fused
// forward contract in `gemm.rs`).

/// Execute every reduction step of one resident dFilter output tile;
/// returns the accumulated `[bcI][bcO][e6][e7]` buffer.
fn run_dfilter_tile(
    x: &Tensor4,
    g: &Tensor4,
    plan: &TilePlan,
    ot: &OutTile,
    red: &[RedTile],
    counters: &TrafficCounters,
) -> Vec<f32> {
    let s = &plan.shape;
    let (sw, sh) = (s.s_w as usize, s.s_h as usize);
    let (w_o, h_o) = (s.w_o as usize, s.h_o as usize);
    let bci = ot.n.len as usize;
    let bco = ot.co.len as usize;
    let e6 = ot.wo.len as usize;
    let e7 = ot.ho.len as usize;
    let mut out = vec![0.0f32; bci * bco * e6 * e7];
    let mut xin: Vec<f32> = Vec::new();
    let mut gbuf: Vec<f32> = Vec::new();
    for rt in red {
        let (spw, sph) = pack::pack_dfilter_input(x, s, ot, rt, &mut xin);
        pack::pack_dfilter_gout(g, s, ot, rt, &mut gbuf);
        counters.add_input(xin.len() as u64);
        counters.add_filter(gbuf.len() as u64);
        let bn = rt.ci.len as usize;
        let mut k = 0;
        for ci in 0..bci {
            for co in 0..bco {
                for a in 0..e6 {
                    for b in 0..e7 {
                        let mut elem = out[k];
                        for n in 0..bn {
                            let xpl = (n * bci + ci) * spw;
                            let gpl = (n * bco + co) * w_o;
                            // one scalar accumulator per (element, n),
                            // added once — dfilter_naive's structure
                            let mut acc = 0.0f32;
                            for wo in 0..w_o {
                                let xrow = (xpl + a + sw * wo) * sph + b;
                                let grow = (gpl + wo) * h_o;
                                for ho in 0..h_o {
                                    acc += xin[xrow + sh * ho] * gbuf[grow + ho];
                                }
                            }
                            elem += acc;
                        }
                        out[k] = elem;
                        k += 1;
                    }
                }
            }
        }
    }
    counters.add_output(out.len() as u64);
    out
}

/// Execute every reduction step of one resident dInput output tile;
/// returns the accumulated `[bn][bcI][ex][ey]` buffer.
fn run_dinput_tile(
    g: &Tensor4,
    w: &Tensor4,
    plan: &TilePlan,
    ot: &OutTile,
    red: &[RedTile],
    counters: &TrafficCounters,
) -> Vec<f32> {
    let s = &plan.shape;
    let (w_f, h_f) = (s.w_f as usize, s.h_f as usize);
    let bn = ot.n.len as usize;
    let bci = ot.co.len as usize;
    let ex = ot.wo.len as usize;
    let ey = ot.ho.len as usize;
    let mut out = vec![0.0f32; bn * bci * ex * ey];
    let mut gbuf: Vec<f32> = Vec::new();
    let mut fbuf: Vec<f32> = Vec::new();
    // valid (tap, output coordinate) pairs per tile column/row — identical
    // across reduction steps, computed once; taps ascend in each list, so
    // the per-element accumulation runs in the oracle's (i6, i7) order
    let wpairs = pack::dinput_pairs(ot.wo.start, ot.wo.len, s.s_w, s.w_f, s.w_o, 0);
    let hpairs = pack::dinput_pairs(ot.ho.start, ot.ho.len, s.s_h, s.h_f, s.h_o, 0);
    for rt in red {
        let (wo_lo, wo_len, ho_lo, ho_len) =
            pack::pack_dinput_gout(g, s, ot, rt, &mut gbuf);
        pack::pack_dinput_filter(w, s, ot, rt, &mut fbuf);
        counters.add_input(gbuf.len() as u64);
        counters.add_filter(fbuf.len() as u64);
        let bco = rt.ci.len as usize;
        let mut k = 0;
        for n in 0..bn {
            for ci in 0..bci {
                for dx in 0..ex {
                    let wp = &wpairs[dx];
                    for dy in 0..ey {
                        let hp = &hpairs[dy];
                        let mut elem = out[k];
                        for co in 0..bco {
                            let fpl = (ci * bco + co) * w_f;
                            let gpl = (n * bco + co) * wo_len;
                            for &(i6, wo) in wp {
                                let frow = (fpl + i6) * h_f;
                                let grow = (gpl + (wo - wo_lo)) * ho_len;
                                for &(i7, ho) in hp {
                                    let f = fbuf[frow + i7];
                                    if f == 0.0 {
                                        // the oracle's zero-tap skip
                                        continue;
                                    }
                                    elem += gbuf[grow + (ho - ho_lo)] * f;
                                }
                            }
                        }
                        out[k] = elem;
                        k += 1;
                    }
                }
            }
        }
    }
    counters.add_output(out.len() as u64);
    out
}

/// Dispatch one output tile of a backward pass.
fn run_pass_out_tile(
    pass: ConvPass,
    a: &Tensor4,
    b: &Tensor4,
    plan: &TilePlan,
    ot: &OutTile,
    red: &[RedTile],
    counters: &TrafficCounters,
) -> Vec<f32> {
    crate::testkit::faults::exec_point();
    match pass {
        ConvPass::DFilter => run_dfilter_tile(a, b, plan, ot, red, counters),
        ConvPass::DInput => run_dinput_tile(a, b, plan, ot, red, counters),
        ConvPass::Forward => unreachable!("forward runs run_out_tile"),
    }
}

/// Write one finished backward output tile (natural `[d0][d1][d2][d3]`
/// layout) into the pass's output tensor.
fn scatter_pass(out: &mut Tensor4, ot: &OutTile, buf: &[f32]) {
    let b0 = ot.n.len as usize;
    let b1 = ot.co.len as usize;
    let b2 = ot.wo.len as usize;
    let b3 = ot.ho.len as usize;
    let mut k = 0;
    for i0 in 0..b0 {
        for i1 in 0..b1 {
            for i2 in 0..b2 {
                let dst = out.idx(
                    ot.n.start as usize + i0,
                    ot.co.start as usize + i1,
                    ot.wo.start as usize + i2,
                    ot.ho.start as usize,
                );
                out.data[dst..dst + b3].copy_from_slice(&buf[k..k + b3]);
                k += b3;
            }
        }
    }
}

/// Serial pass-generic tiled convolution with traffic accounting: the
/// forward pass runs [`conv_tiled_counted`] unchanged, the gradient passes
/// run the LP-blocked backward sweeps above — bitwise identical to
/// [`crate::conv::dfilter_naive`] / [`crate::conv::dinput_naive`] (the
/// backward accumulation-order contract), with measured traffic equal to
/// [`expected_pass_traffic`] exactly.
pub fn conv_pass_tiled_counted(
    pass: ConvPass,
    a: &Tensor4,
    b: &Tensor4,
    plan: &TilePlan,
    counters: &TrafficCounters,
) -> Tensor4 {
    assert_eq!(plan.pass, pass, "plan solved for a different pass");
    if pass == ConvPass::Forward {
        return conv_tiled_counted(a, b, plan, counters);
    }
    let s = &plan.shape;
    assert_pass_operands(pass, a, b, s);
    if s.updates() == 0 {
        return Tensor4::zeros(pass.out_dims(s));
    }
    let tg = PassTraceGuard::start(counters);
    let outs = tiles::output_tiles(plan);
    let red = tiles::reduction_tiles(plan);
    let mut out = Tensor4::zeros(pass.out_dims(s));
    for ot in &outs {
        let buf = run_pass_out_tile(pass, a, b, plan, ot, &red, counters);
        scatter_pass(&mut out, ot, &buf);
    }
    tg.finish(plan, counters);
    out
}

/// Serial pass-generic tiled convolution (counters discarded).
pub fn conv_pass_tiled(pass: ConvPass, a: &Tensor4, b: &Tensor4, plan: &TilePlan) -> Tensor4 {
    conv_pass_tiled_counted(pass, a, b, plan, &TrafficCounters::new())
}

/// Pass-generic tiled convolution with output tiles fanned out over a
/// [`ThreadPool`]. Distinct output tiles of every pass write disjoint
/// output regions, and each tile reduces serially in the fixed order, so
/// the parallel result is bitwise identical to the serial one.
pub fn conv_pass_tiled_parallel(
    pass: ConvPass,
    a: &Arc<Tensor4>,
    b: &Arc<Tensor4>,
    plan: &Arc<TilePlan>,
    pool: &ThreadPool,
    counters: &Arc<TrafficCounters>,
) -> Tensor4 {
    assert_eq!(plan.pass, pass, "plan solved for a different pass");
    if pass == ConvPass::Forward {
        return conv_tiled_parallel(a, b, plan, pool, counters);
    }
    let s = plan.shape;
    assert_pass_operands(pass, a, b, &s);
    if s.updates() == 0 {
        return Tensor4::zeros(pass.out_dims(&s));
    }
    let tg = PassTraceGuard::start(counters);
    let outs = tiles::output_tiles(plan);
    let red = Arc::new(tiles::reduction_tiles(plan));
    let (a2, b2, p2) = (Arc::clone(a), Arc::clone(b), Arc::clone(plan));
    let (r2, c2) = (Arc::clone(&red), Arc::clone(counters));
    let bufs = pool.map(outs.clone(), move |ot| {
        run_pass_out_tile(pass, &a2, &b2, &p2, &ot, &r2, &c2)
    });
    let mut out = Tensor4::zeros(pass.out_dims(&s));
    for (ot, buf) in outs.iter().zip(&bufs) {
        scatter_pass(&mut out, ot, buf);
    }
    tg.finish(plan, counters);
    out
}

/// The traffic [`conv_pass_tiled_counted`] *will* charge for `plan`,
/// computed analytically from the pass's tile grid — the per-pass
/// extension of [`expected_traffic`] (to which the forward case
/// delegates). Shares the span helpers with the pack loops, so measured
/// and analytic totals agree word for word.
pub fn expected_pass_traffic(plan: &TilePlan) -> Traffic {
    let s = &plan.shape;
    match plan.pass {
        ConvPass::Forward => expected_traffic(plan),
        ConvPass::DFilter => {
            if s.updates() == 0 {
                return Traffic::default();
            }
            let mut t = Traffic::default();
            let outs = tiles::output_tiles(plan);
            let red = tiles::reduction_tiles(plan);
            for ot in &outs {
                let spw = pack::dfilter_span(ot.wo.len, s.s_w, s.w_o);
                let sph = pack::dfilter_span(ot.ho.len, s.s_h, s.h_o);
                for rt in &red {
                    t.input_words += rt.ci.len * ot.n.len * spw * sph;
                    t.filter_words += rt.ci.len * ot.co.len * s.w_o * s.h_o;
                }
                t.output_words += ot.n.len * ot.co.len * ot.wo.len * ot.ho.len;
            }
            t
        }
        ConvPass::DInput => {
            if s.updates() == 0 {
                return Traffic::default();
            }
            let mut t = Traffic::default();
            let outs = tiles::output_tiles(plan);
            let red = tiles::reduction_tiles(plan);
            for ot in &outs {
                let (_, wo_len) =
                    pack::dinput_span(ot.wo.start, ot.wo.len, s.s_w, s.w_f, s.w_o);
                let (_, ho_len) =
                    pack::dinput_span(ot.ho.start, ot.ho.len, s.s_h, s.h_f, s.h_o);
                for rt in &red {
                    t.input_words += ot.n.len * rt.ci.len * wo_len * ho_len;
                    t.filter_words += ot.co.len * rt.ci.len * s.w_f * s.h_f;
                }
                t.output_words += ot.n.len * ot.co.len * ot.wo.len * ot.ho.len;
            }
            t
        }
    }
}

// ---------------- network pipelines ----------------

/// Per-stage traffic counters for a network pipeline. Each stage owns one
/// [`TrafficCounters`] behind an `Arc` so materialized stages can hand it
/// straight to [`conv_tiled_parallel`] while fused sweeps charge it from
/// worker threads. A parallel per-stage halo counter records the words the
/// fused executor served from its sliding-window cache.
#[derive(Debug, Clone)]
pub struct NetTrafficCounters {
    stages: Vec<Arc<TrafficCounters>>,
    /// per-stage words of input patch served from the sliding-window halo
    /// cache: group heads avoid main-memory re-reads, interior fused
    /// stages avoid upstream recompute
    halo: Vec<Arc<AtomicU64>>,
}

impl NetTrafficCounters {
    pub fn new(stages: usize) -> NetTrafficCounters {
        NetTrafficCounters {
            stages: (0..stages).map(|_| Arc::new(TrafficCounters::new())).collect(),
            halo: (0..stages).map(|_| Arc::new(AtomicU64::new(0))).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.stages.len()
    }

    pub fn is_empty(&self) -> bool {
        self.stages.is_empty()
    }

    /// Stage `k`'s counters.
    pub fn stage(&self, k: usize) -> &Arc<TrafficCounters> {
        &self.stages[k]
    }

    fn add_halo(&self, k: usize, words: u64) {
        self.halo[k].fetch_add(words, Ordering::Relaxed);
    }

    /// Per-stage snapshots, in stage order.
    pub fn snapshot(&self) -> Vec<Traffic> {
        self.stages.iter().map(|c| c.snapshot()).collect()
    }

    /// Per-stage words served from the halo cache, in stage order. Matches
    /// [`FusePlan::expected_halo_words`] exactly.
    pub fn halo_snapshot(&self) -> Vec<u64> {
        self.halo.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    /// Sum of all stages.
    pub fn total(&self) -> Traffic {
        Traffic::sum(&self.snapshot())
    }

    pub fn reset(&self) {
        for c in &self.stages {
            c.reset();
        }
        for h in &self.halo {
            h.store(0, Ordering::Relaxed);
        }
    }
}

/// Validate the (image, per-stage filters) operands of a network chain.
pub(crate) fn assert_network_operands(image: &Tensor4, filters: &[&Tensor4], stages: &[NetworkStage]) {
    assert!(!stages.is_empty(), "empty network");
    assert_eq!(filters.len(), stages.len(), "one filter per stage");
    crate::conv::assert_conv_operands(image, filters[0], &stages[0].shape);
    for (k, st) in stages.iter().enumerate().skip(1) {
        assert_eq!(
            filters[k].dims,
            st.shape.filter_dims(),
            "stage {k} filter shape mismatch"
        );
    }
}

/// Repoint a reusable tensor at new dims WITHOUT zeroing the retained
/// payload — every caller either overwrites all elements (carry prefix +
/// fresh copies / microkernel scatter) or zeroes exactly the rows it
/// accumulates into ([`zero_rows_from`]). `resize` keeps the allocation,
/// so reuse across tiles costs no allocator calls after warmup.
fn reset_tensor(t: &mut Tensor4, dims: [usize; 4]) {
    let len = dims.iter().product();
    t.dims = dims;
    t.data.resize(len, 0.0);
}

/// Zero rows `[h0, dims[3])` of every (n, c, w) line — the fresh region
/// the reference nest accumulates into.
fn zero_rows_from(t: &mut Tensor4, h0: usize) {
    let h = t.dims[3];
    let lines = t.dims[0] * t.dims[1] * t.dims[2];
    let mut d = h0;
    for _ in 0..lines {
        t.data[d..d + (h - h0)].fill(0.0);
        d += h;
    }
}

/// Copy the carry's rows into the leading h-rows of every (n, c, w) line
/// of `dst` (h is the contiguous axis on both sides).
fn copy_carry_prefix(dst: &mut Tensor4, src: &Tensor4, rows: usize) {
    debug_assert_eq!(src.dims[3], rows);
    debug_assert_eq!(src.dims[..3], dst.dims[..3]);
    let dh = dst.dims[3];
    let lines = dst.dims[0] * dst.dims[1] * dst.dims[2];
    let mut s = 0;
    let mut d = 0;
    for _ in 0..lines {
        dst.data[d..d + rows].copy_from_slice(&src.data[s..s + rows]);
        s += rows;
        d += dh;
    }
}

/// Save the trailing `rows` h-rows of every (n, c, w) line of `src` into
/// `dst` (resized to match) — the sliding-window carry the next h-tile
/// starts from.
fn save_carry_tail(dst: &mut Tensor4, src: &Tensor4, rows: usize) {
    let sh = src.dims[3];
    debug_assert!(rows <= sh);
    reset_tensor(dst, [src.dims[0], src.dims[1], src.dims[2], rows]);
    let lines = src.dims[0] * src.dims[1] * src.dims[2];
    let mut s = sh - rows;
    let mut d = 0;
    for _ in 0..lines {
        dst.data[d..d + rows].copy_from_slice(&src.data[s..s + rows]);
        s += sh;
        d += rows;
    }
}

/// Copy rows `[h0, h)` of the first `cols` w-columns of `src` (a saved
/// w-carry, exactly `cols` columns wide) into the same positions of
/// `dst` — the left edge of a patch whose top `h0` rows the h-carry
/// already filled.
fn copy_carry_cols(dst: &mut Tensor4, src: &Tensor4, cols: usize, h0: usize) {
    debug_assert_eq!(src.dims[2], cols);
    debug_assert_eq!(src.dims[3], dst.dims[3]);
    debug_assert_eq!(src.dims[..2], dst.dims[..2]);
    let h = dst.dims[3];
    for n in 0..dst.dims[0] {
        for c in 0..dst.dims[1] {
            for a in 0..cols {
                let s = src.idx(n, c, a, h0);
                let d = dst.idx(n, c, a, h0);
                dst.data[d..d + (h - h0)]
                    .copy_from_slice(&src.data[s..s + (h - h0)]);
            }
        }
    }
}

/// Save the trailing `cols` w-columns (full height) of every (n, c) plane
/// of `src` into `dst` (resized to match) — the w-axis carry the same
/// h-position of the next w-tile-column starts from.
fn save_carry_wtail(dst: &mut Tensor4, src: &Tensor4, cols: usize) {
    let sw = src.dims[2];
    let h = src.dims[3];
    debug_assert!(cols <= sw);
    reset_tensor(dst, [src.dims[0], src.dims[1], cols, h]);
    for n in 0..src.dims[0] {
        for c in 0..src.dims[1] {
            for a in 0..cols {
                let s = src.idx(n, c, sw - cols + a, 0);
                let d = dst.idx(n, c, a, 0);
                dst.data[d..d + h].copy_from_slice(&src.data[s..s + h]);
            }
        }
    }
}

/// Reusable per-worker scratch for a fused group's tile sweeps: the
/// ping-pong activation patches, the packed panels, the microkernel output
/// buffer and the per-level sliding-window carries. Hoisted out of the
/// tile and stage loops so the hot path performs no allocator calls after
/// warmup (every buffer keeps its capacity across reuse).
struct FusedScratch {
    /// current stage's input patch (level j)
    cur: Tensor4,
    /// current stage's output patch (level j + 1); swapped into `cur`
    next: Tensor4,
    /// packed input panel, reused across stages and tiles
    xin: Vec<f32>,
    /// packed filter panel, reused across stages and tiles
    fil: Vec<f32>,
    /// microkernel output buffer for the fresh rows
    mac_out: Vec<f32>,
    /// per-level carries: `carry[j]` holds the trailing overlap rows of
    /// level j's input (level 0 = the head image patch) from the previous
    /// h-tile of the column
    carry: Vec<Tensor4>,
    carry_valid: Vec<bool>,
    /// constant per-level overlap row counts ([`input_overlap_rows`]);
    /// all zero with the halo cache off
    overlap: Vec<u64>,
    /// head-level w-axis carries, one per h-block position of the column
    /// sweep: the trailing overlap columns (full patch height) of the
    /// previous w-tile-column's image patch at the same h position. They
    /// persist across a batch block's columns; empty with the w-carry off
    carry_w: Vec<Tensor4>,
    carry_w_valid: Vec<bool>,
    /// head-level column overlap ([`input_overlap_cols`]); 0 with the
    /// w-carry off
    overlap_w0: u64,
}

impl FusedScratch {
    fn for_group(
        stages: &[NetworkStage],
        g: &FuseGroup,
        halo: bool,
        halo_w: bool,
    ) -> FusedScratch {
        let levels = g.len();
        let h_o = stages[g.end].shape.h_o;
        let n_th = if halo_w {
            ((h_o + g.b_ho - 1) / g.b_ho) as usize
        } else {
            0
        };
        FusedScratch {
            cur: Tensor4::zeros([0, 0, 0, 0]),
            next: Tensor4::zeros([0, 0, 0, 0]),
            xin: Vec::new(),
            fil: Vec::new(),
            mac_out: Vec::new(),
            carry: (0..levels).map(|_| Tensor4::zeros([0, 0, 0, 0])).collect(),
            carry_valid: vec![false; levels],
            overlap: if halo {
                input_overlap_rows(stages, g.start, g.end)
            } else {
                vec![0; levels]
            },
            carry_w: (0..n_th).map(|_| Tensor4::zeros([0, 0, 0, 0])).collect(),
            carry_w_valid: vec![false; n_th],
            overlap_w0: if halo_w {
                input_overlap_cols(stages, g.start, g.end)[0]
            } else {
                0
            },
        }
    }

    /// Start a fresh (batch, wO) column: the previous column's carries are
    /// stale.
    fn reset_column(&mut self) {
        for v in self.carry_valid.iter_mut() {
            *v = false;
        }
    }

    /// Start a fresh batch block: the previous block's w-carries are
    /// stale.
    fn reset_batch(&mut self) {
        for v in self.carry_w_valid.iter_mut() {
            *v = false;
        }
    }
}

/// The patch-local naive 7NL nest restricted to output rows
/// `[h0, s.h_o)`, accumulating into `out` (`[n][cO][wO][hO]`, the target
/// rows pre-zeroed). Loop order and the zero-tap skip match
/// [`conv7nl_naive`] exactly, so row-restricted execution stays bitwise
/// identical to the full nest.
fn conv7nl_naive_rows(
    x: &Tensor4,
    w: &Tensor4,
    s: &ConvShape,
    h0: usize,
    out: &mut Tensor4,
) {
    let (n, c_i, c_o) = (s.n as usize, s.c_i as usize, s.c_o as usize);
    let (w_o, h_o) = (s.w_o as usize, s.h_o as usize);
    let (w_f, h_f) = (s.w_f as usize, s.h_f as usize);
    let (sw, sh) = (s.s_w as usize, s.s_h as usize);
    for i1 in 0..n {
        for i3 in 0..c_o {
            for i2 in 0..c_i {
                for i6 in 0..w_f {
                    for i7 in 0..h_f {
                        let f = w.at(i2, i3, i6, i7);
                        if f == 0.0 {
                            continue;
                        }
                        for i4 in 0..w_o {
                            for i5 in h0..h_o {
                                *out.at_mut(i1, i3, i4, i5) +=
                                    x.at(i1, i2, sw * i4 + i6, sh * i5 + i7) * f;
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Execute one fused tile and return (a reference to) its finished tail
/// activation, held in the scratch ping-pong buffers.
///
/// **Accumulation-order contract** (DESIGN.md §7). Every stage computes
/// each output element by accumulating over `(cI, i6, i7)` in ascending
/// order — the 7NL naive nest's order. The [`FusedExec::Packed`] path
/// realizes it as one full reduction tile through the `pack.rs` panels and
/// the `gemm.rs` axpy MAC; [`FusedExec::Reference`] is the patch-local
/// naive nest itself. Both are therefore bitwise identical to the
/// stage-by-stage [`super::fuse::naive_network`] oracle, halo cache on or
/// off: a cached row is bitwise equal to what recompute would produce,
/// because an activation element's value depends only on its absolute
/// position, never on which tile computed it.
#[allow(clippy::too_many_arguments)]
fn run_fused_tile<'a>(
    input: &Tensor4,
    filters: &[&Tensor4],
    stages: &[NetworkStage],
    g: &FuseGroup,
    tn: Blk,
    tw: Blk,
    th: Blk,
    ti: usize,
    exec: FusedExec,
    halo: bool,
    halo_w: bool,
    scratch: &'a mut FusedScratch,
    counters: &NetTrafficCounters,
) -> &'a Tensor4 {
    crate::testkit::faults::exec_point();
    let spans = group_spans(stages, g.start, g.end, tw, th);
    let head = &stages[g.start].shape;
    let in_sp = input_span(head, &spans[0]);
    let bn = tn.len as usize;
    let ci0 = head.c_i as usize;
    let (iw, ih) = (in_sp.w_len() as usize, in_sp.h_len() as usize);
    // a column's h-blocks cover [0, h_o) of the group tail, so the tile
    // ending at h_o is the column's last: nothing follows to consume a
    // carry, and saving one would be wasted copies
    let more_tiles = th.start + th.len < stages[g.end].shape.h_o;
    // likewise, the column ending at w_o is the batch block's last: no
    // column to its right will consume a w-carry
    let more_cols = tw.start + tw.len < stages[g.end].shape.w_o;

    // ---- level 0: the halo'd image patch. Carried rows come from the
    // previous h-tile, carried columns (w-carry on) from the previous
    // w-tile-column at the same h position; only the fresh rectangle is
    // read from main memory (the only input-side traffic the group
    // charges). ----
    let ov0 = scratch.overlap[0] as usize;
    let carried = if halo && scratch.carry_valid[0] && ov0 > 0 { ov0 } else { 0 };
    let ovw0 = scratch.overlap_w0 as usize;
    let carried_w = if halo_w && scratch.carry_w_valid[ti] && ovw0 > 0 {
        ovw0
    } else {
        0
    };
    reset_tensor(&mut scratch.cur, [bn, ci0, iw, ih]);
    if carried > 0 {
        let FusedScratch { cur, carry, .. } = &mut *scratch;
        copy_carry_prefix(cur, &carry[0], carried);
        counters.add_halo(g.start, (bn * ci0 * iw * carried) as u64);
    }
    if carried_w > 0 {
        // the h-carry prefix already filled the top `carried` rows across
        // the full width (corner included), so the w-carry serves only
        // the rows below — the L-shape's corner is counted once
        let FusedScratch { cur, carry_w, .. } = &mut *scratch;
        copy_carry_cols(cur, &carry_w[ti], carried_w, carried);
        counters.add_halo(g.start, (bn * ci0 * carried_w * (ih - carried)) as u64);
    }
    {
        let cur = &mut scratch.cur;
        let fresh = ih - carried;
        for n in 0..bn {
            let na = tn.start as usize + n;
            for c in 0..ci0 {
                for a in carried_w..iw {
                    let wa = in_sp.w0 as usize + a;
                    let src = input.idx(na, c, wa, in_sp.h0 as usize + carried);
                    let dst = cur.idx(n, c, a, carried);
                    cur.data[dst..dst + fresh]
                        .copy_from_slice(&input.data[src..src + fresh]);
                }
            }
        }
        counters
            .stage(g.start)
            .add_input((bn * ci0 * (iw - carried_w) * fresh) as u64);
    }
    if halo && more_tiles && ov0 > 0 {
        let FusedScratch { cur, carry, carry_valid, .. } = &mut *scratch;
        save_carry_tail(&mut carry[0], cur, ov0);
        carry_valid[0] = true;
    }
    if halo_w && more_cols && ovw0 > 0 {
        let FusedScratch { cur, carry_w, carry_w_valid, .. } = &mut *scratch;
        save_carry_wtail(&mut carry_w[ti], cur, ovw0);
        carry_w_valid[ti] = true;
    }

    // ---- the stage chain: level j input -> level j+1 output ----
    for (j, stage) in (g.start..=g.end).enumerate() {
        let st = &stages[stage];
        let sp = &spans[j];
        let (ow, oh) = (sp.w_len() as usize, sp.h_len() as usize);
        let co = st.shape.c_o as usize;
        // this stage's output is stage `stage + 1`'s input: its carry is
        // the next level's (the group tail's tiles never overlap)
        let next_level = j + 1 < g.len();
        let ov_next = if next_level { scratch.overlap[j + 1] as usize } else { 0 };
        let carried_out =
            if halo && next_level && scratch.carry_valid[j + 1] && ov_next > 0 {
                ov_next
            } else {
                0
            };
        reset_tensor(&mut scratch.next, [bn, co, ow, oh]);
        if carried_out > 0 {
            let FusedScratch { next, carry, .. } = &mut *scratch;
            copy_carry_prefix(next, &carry[j + 1], carried_out);
            counters.add_halo(stage + 1, (bn * co * ow * carried_out) as u64);
        }
        let sub = ConvShape {
            n: tn.len,
            w_o: sp.w_len(),
            h_o: sp.h_len(),
            ..st.shape
        };
        let fresh = oh - carried_out;
        match exec {
            FusedExec::Packed => {
                let FusedScratch { cur, next, xin, fil, mac_out, .. } =
                    &mut *scratch;
                let (ew, eh) = pack::pack_fused_stage(
                    cur,
                    filters[stage],
                    &sub,
                    carried_out,
                    fresh,
                    xin,
                    fil,
                );
                mac_out.clear();
                mac_out.resize(bn * ow * fresh * co, 0.0);
                let (qw, qh, rw, rh) = filter_split_ranges(&sub);
                let d = TileDims {
                    bn,
                    bci: sub.c_i as usize,
                    bco: co,
                    bwo: ow,
                    bho: fresh,
                    bqw: qw as usize,
                    bqh: qh as usize,
                    brw: rw as usize,
                    brh: rh as usize,
                    ew,
                    eh,
                    q6_0: 0,
                    q7_0: 0,
                    r6_0: 0,
                    r7_0: 0,
                    sw: sub.s_w as usize,
                    sh: sub.s_h as usize,
                    wf: sub.w_f as usize,
                    hf: sub.h_f as usize,
                };
                gemm::conv_tile_mac(mac_out, xin, fil, &d);
                // scatter the fresh rows into the output patch
                // ([bn][ow][fresh][co] -> [bn][co][ow][oh] at row offset)
                let mut k = 0;
                for n in 0..bn {
                    for a in 0..ow {
                        for h in 0..fresh {
                            for c in 0..co {
                                *next.at_mut(n, c, a, carried_out + h) =
                                    mac_out[k];
                                k += 1;
                            }
                        }
                    }
                }
            }
            FusedExec::Reference => {
                let FusedScratch { cur, next, .. } = &mut *scratch;
                // the nest accumulates: its fresh rows must start at zero
                // (the carry prefix was copied, nothing else is read)
                zero_rows_from(next, carried_out);
                conv7nl_naive_rows(cur, filters[stage], &sub, carried_out, next);
            }
        }
        counters.stage(stage).add_filter(st.shape.filter_size());
        // rotate the ping-pong and save this level's sliding-window carry
        std::mem::swap(&mut scratch.cur, &mut scratch.next);
        if halo && more_tiles && next_level && ov_next > 0 {
            let FusedScratch { cur, carry, carry_valid, .. } = &mut *scratch;
            save_carry_tail(&mut carry[j + 1], cur, ov_next);
            carry_valid[j + 1] = true;
        }
    }
    counters.stage(g.end).add_output(scratch.cur.len() as u64);
    &scratch.cur
}

/// Write one finished fused tile into the network output tensor
/// (contiguous h-rows on both sides, so whole-row copies).
fn scatter_network(out: &mut Tensor4, tn: Blk, tw: Blk, th: Blk, tile: &Tensor4) {
    let bh = tile.dims[3];
    let mut k = 0;
    for n in 0..tile.dims[0] {
        for c in 0..tile.dims[1] {
            for a in 0..tile.dims[2] {
                let dst = out.idx(
                    tn.start as usize + n,
                    c,
                    tw.start as usize + a,
                    th.start as usize,
                );
                out.data[dst..dst + bh].copy_from_slice(&tile.data[k..k + bh]);
                k += bh;
            }
        }
    }
}

fn network_out_dims(stages: &[NetworkStage], g: &FuseGroup) -> [usize; 4] {
    let s = &stages[g.end].shape;
    [s.n as usize, s.c_o as usize, s.w_o as usize, s.h_o as usize]
}

/// Serial fused network execution with per-stage traffic accounting.
/// Fused groups sweep the last stage's output tiles through the plan's
/// [`FusedExec`] path (packed panels + axpy MAC by default), holding every
/// inter-stage activation in ping-pong scratch and carrying sliding-window
/// halo rows between adjacent h-tiles when the plan's cache is on;
/// materialized (single-stage) groups run the stage's LP-tiled engine.
/// Within fused groups the per-element operation order equals the
/// oracle's, so a plan that fuses end to end is bitwise identical to
/// [`super::fuse::naive_network`] (materialized stages use the tiled
/// engine's accumulation order and agree to float tolerance).
pub fn conv_network_fused_counted(
    image: &Tensor4,
    filters: &[&Tensor4],
    plan: &FusePlan,
    counters: &NetTrafficCounters,
) -> Tensor4 {
    assert_network_operands(image, filters, &plan.stages);
    assert_eq!(counters.len(), plan.stages.len(), "counter arity");
    let tg = NetTraceGuard::start(counters);
    let mut act: Option<Tensor4> = None;
    for g in &plan.groups {
        let input: &Tensor4 = act.as_ref().unwrap_or(image);
        let next = if g.is_fused() {
            let mut out = Tensor4::zeros(network_out_dims(&plan.stages, g));
            let mut scratch = FusedScratch::for_group(
                &plan.stages,
                g,
                plan.halo_cache,
                plan.halo_w,
            );
            let mut prev_tn: Option<u64> = None;
            for (tn, tw, hs) in group_tile_columns(&plan.stages, g) {
                if prev_tn != Some(tn.start) {
                    scratch.reset_batch();
                    prev_tn = Some(tn.start);
                }
                scratch.reset_column();
                for (ti, th) in hs.into_iter().enumerate() {
                    let tile = run_fused_tile(
                        input,
                        filters,
                        &plan.stages,
                        g,
                        tn,
                        tw,
                        th,
                        ti,
                        plan.exec,
                        plan.halo_cache,
                        plan.halo_w,
                        &mut scratch,
                        counters,
                    );
                    scatter_network(&mut out, tn, tw, th, tile);
                }
            }
            out
        } else {
            let k = g.start;
            conv_tiled_counted(
                input,
                filters[k],
                &plan.stage_plans[k],
                counters.stage(k),
            )
        };
        act = Some(next);
    }
    let out = act.expect("network has at least one stage");
    tg.finish(
        plan,
        &plan.expected_network_traffic(),
        &plan.expected_halo_words(),
        counters,
    );
    out
}

/// Fused network execution fanned out over a [`ThreadPool`]. The unit of
/// parallelism is one (batch, wO) tile *column*: the sliding-window carry
/// chains a column's h-tiles serially on one worker, and distinct columns
/// write disjoint output regions. With the w-carry on the unit widens to
/// one *batch block* (the carry chains a block's columns left to right).
/// Bitwise identical to the serial path: every tile is computed in the
/// same per-element order. Materialized stages fan out through
/// [`conv_tiled_parallel`].
pub fn conv_network_fused(
    image: &Arc<Tensor4>,
    filters: &[Arc<Tensor4>],
    plan: &Arc<FusePlan>,
    pool: &ThreadPool,
    counters: &NetTrafficCounters,
) -> Tensor4 {
    {
        let frefs: Vec<&Tensor4> = filters.iter().map(|f| f.as_ref()).collect();
        assert_network_operands(image, &frefs, &plan.stages);
    }
    assert_eq!(counters.len(), plan.stages.len(), "counter arity");
    let tg = NetTraceGuard::start(counters);
    let mut act: Arc<Tensor4> = Arc::clone(image);
    for (gi, g) in plan.groups.iter().enumerate() {
        let next = if g.is_fused() {
            let cols = group_tile_columns(&plan.stages, g);
            // one work unit per column, or per batch block with the
            // w-carry on (carries chain across a block's columns)
            let units: Vec<Vec<(Blk, Blk, Vec<Blk>)>> = if plan.halo_w {
                let mut units: Vec<Vec<(Blk, Blk, Vec<Blk>)>> = Vec::new();
                for col in cols {
                    match units.last_mut() {
                        Some(u) if u[0].0.start == col.0.start => u.push(col),
                        _ => units.push(vec![col]),
                    }
                }
                units
            } else {
                cols.into_iter().map(|c| vec![c]).collect()
            };
            let mut out = Tensor4::zeros(network_out_dims(&plan.stages, g));
            let (x2, p2) = (Arc::clone(&act), Arc::clone(plan));
            let f2: Vec<Arc<Tensor4>> = filters.to_vec();
            let c2 = counters.clone();
            let bufs = pool.map(units.clone(), move |unit| {
                let g = p2.groups[gi];
                let frefs: Vec<&Tensor4> =
                    f2.iter().map(|f| f.as_ref()).collect();
                let mut scratch = FusedScratch::for_group(
                    &p2.stages,
                    &g,
                    p2.halo_cache,
                    p2.halo_w,
                );
                let mut tiles = Vec::new();
                for (tn, tw, hs) in unit {
                    scratch.reset_column();
                    for (ti, th) in hs.into_iter().enumerate() {
                        let tile = run_fused_tile(
                            &x2,
                            &frefs,
                            &p2.stages,
                            &g,
                            tn,
                            tw,
                            th,
                            ti,
                            p2.exec,
                            p2.halo_cache,
                            p2.halo_w,
                            &mut scratch,
                            &c2,
                        );
                        tiles.push(tile.clone());
                    }
                }
                tiles
            });
            for (unit, tiles) in units.iter().zip(&bufs) {
                let mut it = tiles.iter();
                for (tn, tw, hs) in unit {
                    for th in hs {
                        let tile = it.next().expect("one tile per (column, h)");
                        scatter_network(&mut out, *tn, *tw, *th, tile);
                    }
                }
            }
            out
        } else {
            let k = g.start;
            conv_tiled_parallel(
                &act,
                &filters[k],
                &plan.stage_plans[k],
                pool,
                counters.stage(k),
            )
        };
        act = Arc::new(next);
    }
    let out = Arc::try_unwrap(act).unwrap_or_else(|a| (*a).clone());
    tg.finish(
        plan,
        &plan.expected_network_traffic(),
        &plan.expected_halo_words(),
        counters,
    );
    out
}

/// Layer-by-layer baseline: every stage runs the LP-tiled engine and every
/// activation round-trips through a materialized tensor — the traffic the
/// fusion planner's `fused ≤ unfused` claim is measured against.
pub fn conv_network_staged(
    image: &Arc<Tensor4>,
    filters: &[Arc<Tensor4>],
    plan: &FusePlan,
    pool: &ThreadPool,
    counters: &NetTrafficCounters,
) -> Tensor4 {
    {
        let frefs: Vec<&Tensor4> = filters.iter().map(|f| f.as_ref()).collect();
        assert_network_operands(image, &frefs, &plan.stages);
    }
    assert_eq!(counters.len(), plan.stages.len(), "counter arity");
    let tg = NetTraceGuard::start(counters);
    let mut act: Arc<Tensor4> = Arc::clone(image);
    for k in 0..plan.stages.len() {
        act = Arc::new(conv_tiled_parallel(
            &act,
            &filters[k],
            &plan.stage_plans[k],
            pool,
            counters.stage(k),
        ));
    }
    let out = Arc::try_unwrap(act).unwrap_or_else(|a| (*a).clone());
    // the staged baseline ignores the plan's grouping: each stage charges
    // its own LP plan's analytic traffic, with no halo cache anywhere
    let expected: Vec<Traffic> =
        plan.stage_plans.iter().map(|p| expected_traffic(p)).collect();
    tg.finish(plan, &expected, &vec![0; plan.stages.len()], counters);
    out
}

// ---------------- fused training sweeps (NetPass::Backward / Step) ----------------
//
// The backward sweep chains dInput through a fused group the way the
// forward sweep chains activations: tiles cover the group *head's*
// input-gradient grid, each tile pulls its loss-gradient span at the tail
// and walks the transposed stencil head-ward, with every interior gradient
// held in ping-pong scratch (zero boundary words). The step sweep runs the
// whole training step per batch block: recompute the group's activations,
// then walk dFilter + dInput back down, with the filter gradients resident
// across blocks. Both sweeps obey the backward accumulation-order contract
// above, so fused gradients are bitwise identical to the
// `conv/training.rs` oracles.

/// Validate the (loss gradient, per-stage filters) operands of a backward
/// network sweep: `gout` must carry the tail stage's output dims.
fn assert_bwd_network_operands(
    gout: &Tensor4,
    filters: &[&Tensor4],
    stages: &[NetworkStage],
) {
    assert!(!stages.is_empty(), "empty network");
    assert_eq!(filters.len(), stages.len(), "one filter per stage");
    let tail = &stages[stages.len() - 1].shape;
    assert_eq!(gout.dims, out_dims(tail), "loss gradient shape mismatch");
    for (k, st) in stages.iter().enumerate() {
        assert_eq!(
            filters[k].dims,
            st.shape.filter_dims(),
            "stage {k} filter shape mismatch"
        );
    }
}

/// The patch-local transposed-stencil nest: produce the input-gradient
/// span `osp` of stage `s` from the output-gradient patch `gpatch`
/// (absolute span `gsp = dout_span(s, osp)`), overwriting `out`
/// (`[bn][cI][osp.w][osp.h]`). Per element the accumulation runs over
/// ascending `(cO, i6, i7)` with the oracle's zero-tap skip — exactly
/// [`dinput_naive`]'s per-element term order, so span-restricted execution
/// stays bitwise identical to the full nest. Elements no stencil tap
/// reaches (the trailing σ padding rows) come out exactly zero.
fn dinput_patch(
    gpatch: &Tensor4,
    gsp: Span,
    filter: &Tensor4,
    s: &ConvShape,
    osp: Span,
    out: &mut Tensor4,
) {
    let bn = out.dims[0];
    let c_i = s.c_i as usize;
    let c_o = s.c_o as usize;
    let (ow, oh) = (osp.w_len() as usize, osp.h_len() as usize);
    // valid (tap, patch-relative output coordinate) pairs per input
    // column/row; taps ascend, giving the oracle's (i6, i7) order
    let wpairs =
        pack::dinput_pairs(osp.w0, osp.w_len(), s.s_w, s.w_f, s.w_o, gsp.w0);
    let hpairs =
        pack::dinput_pairs(osp.h0, osp.h_len(), s.s_h, s.h_f, s.h_o, gsp.h0);
    for n in 0..bn {
        for ci in 0..c_i {
            for dx in 0..ow {
                let wp = &wpairs[dx];
                for dy in 0..oh {
                    let hp = &hpairs[dy];
                    let mut elem = 0.0f32;
                    for co in 0..c_o {
                        for &(i6, wo) in wp {
                            for &(i7, ho) in hp {
                                let f = filter.at(ci, co, i6, i7);
                                if f == 0.0 {
                                    // the oracle's zero-tap skip
                                    continue;
                                }
                                elem += gpatch.at(n, co, wo, ho) * f;
                            }
                        }
                    }
                    *out.at_mut(n, ci, dx, dy) = elem;
                }
            }
        }
    }
}

/// Reusable per-worker scratch for a backward sweep: the gradient
/// ping-pong patches and the previous h-tile's full tail loss-gradient
/// patch (the sliding-window carry — the carried span is remembered
/// because boundary clamping makes the overlap non-constant, unlike the
/// forward sweep's fixed per-level row counts).
struct BwdScratch {
    cur: Tensor4,
    next: Tensor4,
    carry: Tensor4,
    carry_span: Option<Span>,
}

impl BwdScratch {
    fn new() -> BwdScratch {
        BwdScratch {
            cur: Tensor4::zeros([0, 0, 0, 0]),
            next: Tensor4::zeros([0, 0, 0, 0]),
            carry: Tensor4::zeros([0, 0, 0, 0]),
            carry_span: None,
        }
    }
}

/// Execute one backward tile of a fused group and return (a reference to)
/// the head's finished input-gradient tile, held in scratch.
///
/// The tail loss-gradient patch is assembled from the previous h-tile's
/// carried patch (rows already in fast memory — counted as halo words)
/// plus fresh rows read from `grad`; the dInput chain then walks tail →
/// head through [`dinput_patch`], charging each stage's filter once per
/// tile and the head's full tile write. A gradient element's value
/// depends only on its absolute position, so cached rows are bitwise
/// equal to re-read ones and the sweep stays bitwise identical to the
/// layer-by-layer [`dinput_naive`] chain.
#[allow(clippy::too_many_arguments)]
fn run_bwd_tile<'a>(
    grad: &Tensor4,
    filters: &[&Tensor4],
    stages: &[NetworkStage],
    g: &FuseGroup,
    tn: Blk,
    tw: Blk,
    th: Blk,
    halo: bool,
    scratch: &'a mut BwdScratch,
    counters: &NetTrafficCounters,
) -> &'a Tensor4 {
    crate::testkit::faults::exec_point();
    let spans = bwd_group_spans(stages, g.start, g.end, tw, th);
    let head = &stages[g.start].shape;
    let tail = &stages[g.end].shape;
    let bn = tn.len as usize;
    let co_b = tail.c_o as usize;
    let gsp = spans[g.end - g.start];
    let (gw, gh) = (gsp.w_len() as usize, gsp.h_len() as usize);
    let more_tiles = th.start + th.len < head.in_h();

    // ---- assemble the tail loss-gradient patch ----
    let fresh_h0 = match (halo, scratch.carry_span) {
        (true, Some(p)) => p.h1.clamp(gsp.h0, gsp.h1),
        _ => gsp.h0,
    };
    let carried = (fresh_h0 - gsp.h0) as usize;
    reset_tensor(&mut scratch.cur, [bn, co_b, gw, gh]);
    if carried > 0 {
        let off = (gsp.h0 - scratch.carry_span.unwrap().h0) as usize;
        let BwdScratch { cur, carry, .. } = &mut *scratch;
        for n in 0..bn {
            for c in 0..co_b {
                for a in 0..gw {
                    let src = carry.idx(n, c, a, off);
                    let dst = cur.idx(n, c, a, 0);
                    cur.data[dst..dst + carried]
                        .copy_from_slice(&carry.data[src..src + carried]);
                }
            }
        }
        counters.add_halo(g.end, (bn * co_b * gw * carried) as u64);
    }
    {
        let cur = &mut scratch.cur;
        let fresh = gh - carried;
        for n in 0..bn {
            let na = tn.start as usize + n;
            for c in 0..co_b {
                for a in 0..gw {
                    let wa = gsp.w0 as usize + a;
                    let src = grad.idx(na, c, wa, fresh_h0 as usize);
                    let dst = cur.idx(n, c, a, carried);
                    cur.data[dst..dst + fresh]
                        .copy_from_slice(&grad.data[src..src + fresh]);
                }
            }
        }
        counters
            .stage(g.end)
            .add_input((bn * co_b * gw * fresh) as u64);
    }
    if halo && more_tiles {
        let BwdScratch { cur, carry, .. } = &mut *scratch;
        reset_tensor(carry, cur.dims);
        carry.data.copy_from_slice(&cur.data);
        scratch.carry_span = Some(gsp);
    }

    // ---- the dInput chain: stage k's output gradient -> its input
    // gradient (= stage k−1's output gradient), tail to head ----
    for k in (g.start..=g.end).rev() {
        let st = &stages[k].shape;
        let osp = if k > g.start {
            spans[k - 1 - g.start]
        } else {
            Span {
                w0: tw.start,
                w1: tw.start + tw.len,
                h0: th.start,
                h1: th.start + th.len,
            }
        };
        let gsp_k = spans[k - g.start];
        reset_tensor(
            &mut scratch.next,
            [bn, st.c_i as usize, osp.w_len() as usize, osp.h_len() as usize],
        );
        {
            let BwdScratch { cur, next, .. } = &mut *scratch;
            dinput_patch(cur, gsp_k, filters[k], st, osp, next);
        }
        counters.stage(k).add_filter(st.filter_size());
        std::mem::swap(&mut scratch.cur, &mut scratch.next);
    }
    counters.stage(g.start).add_output(scratch.cur.len() as u64);
    &scratch.cur
}

/// Serial fused backward (dInput-chain) execution with per-stage traffic
/// accounting: groups run tail to head, fused groups sweep the group
/// head's input-gradient tiles through [`run_bwd_tile`], materialized
/// groups run the stage's LP-tiled dInput engine. Every path obeys the
/// backward accumulation-order contract, so the result is bitwise
/// identical to [`super::fuse::naive_network_bwd`] for *every* plan, and
/// measured traffic equals [`FusePlan::expected_network_traffic`] exactly.
pub fn conv_network_bwd_counted(
    gout: &Tensor4,
    filters: &[&Tensor4],
    plan: &FusePlan,
    counters: &NetTrafficCounters,
) -> Tensor4 {
    assert_eq!(plan.pass, NetPass::Backward, "plan solved for a different pass");
    assert_bwd_network_operands(gout, filters, &plan.stages);
    assert_eq!(counters.len(), plan.stages.len(), "counter arity");
    let tg = NetTraceGuard::start(counters);
    let mut grad: Option<Tensor4> = None;
    for g in plan.groups.iter().rev() {
        let gin: &Tensor4 = grad.as_ref().unwrap_or(gout);
        let next = if g.is_fused() {
            let head = &plan.stages[g.start].shape;
            let mut out = Tensor4::zeros([
                head.n as usize,
                head.c_i as usize,
                head.in_w() as usize,
                head.in_h() as usize,
            ]);
            let mut scratch = BwdScratch::new();
            for (tn, tw, hs) in bwd_group_tile_columns(&plan.stages, g) {
                scratch.carry_span = None;
                for th in hs {
                    let tile = run_bwd_tile(
                        gin,
                        filters,
                        &plan.stages,
                        g,
                        tn,
                        tw,
                        th,
                        plan.halo_cache,
                        &mut scratch,
                        counters,
                    );
                    scatter_network(&mut out, tn, tw, th, tile);
                }
            }
            out
        } else {
            let k = g.start;
            conv_pass_tiled_counted(
                ConvPass::DInput,
                gin,
                filters[k],
                &plan.dinput_plans[k],
                counters.stage(k),
            )
        };
        grad = Some(next);
    }
    let out = grad.expect("network has at least one stage");
    tg.finish(
        plan,
        &plan.expected_network_traffic(),
        &plan.expected_halo_words(),
        counters,
    );
    out
}

/// Fused backward execution fanned out over a [`ThreadPool`]. As in the
/// forward sweep, the unit of parallelism is one (batch, w) tile column of
/// the group head's input-gradient grid: a column's h-tiles chain through
/// the sliding-window carry on one worker, and distinct columns write
/// disjoint gradient regions. Bitwise identical to
/// [`conv_network_bwd_counted`].
pub fn conv_network_bwd(
    gout: &Arc<Tensor4>,
    filters: &[Arc<Tensor4>],
    plan: &Arc<FusePlan>,
    pool: &ThreadPool,
    counters: &NetTrafficCounters,
) -> Tensor4 {
    assert_eq!(plan.pass, NetPass::Backward, "plan solved for a different pass");
    {
        let frefs: Vec<&Tensor4> = filters.iter().map(|f| f.as_ref()).collect();
        assert_bwd_network_operands(gout, &frefs, &plan.stages);
    }
    assert_eq!(counters.len(), plan.stages.len(), "counter arity");
    let tg = NetTraceGuard::start(counters);
    let mut grad: Arc<Tensor4> = Arc::clone(gout);
    for gi in (0..plan.groups.len()).rev() {
        let g = &plan.groups[gi];
        let next = if g.is_fused() {
            let cols = bwd_group_tile_columns(&plan.stages, g);
            let head = &plan.stages[g.start].shape;
            let mut out = Tensor4::zeros([
                head.n as usize,
                head.c_i as usize,
                head.in_w() as usize,
                head.in_h() as usize,
            ]);
            let (g2, p2) = (Arc::clone(&grad), Arc::clone(plan));
            let f2: Vec<Arc<Tensor4>> = filters.to_vec();
            let c2 = counters.clone();
            let bufs = pool.map(cols.clone(), move |(tn, tw, hs)| {
                let g = p2.groups[gi];
                let frefs: Vec<&Tensor4> =
                    f2.iter().map(|f| f.as_ref()).collect();
                let mut scratch = BwdScratch::new();
                let mut tiles = Vec::with_capacity(hs.len());
                for th in hs {
                    let tile = run_bwd_tile(
                        &g2,
                        &frefs,
                        &p2.stages,
                        &g,
                        tn,
                        tw,
                        th,
                        p2.halo_cache,
                        &mut scratch,
                        &c2,
                    );
                    tiles.push(tile.clone());
                }
                tiles
            });
            for ((tn, tw, hs), tiles) in cols.iter().zip(&bufs) {
                for (th, tile) in hs.iter().zip(tiles) {
                    scatter_network(&mut out, *tn, *tw, *th, tile);
                }
            }
            out
        } else {
            let k = g.start;
            conv_pass_tiled_parallel(
                ConvPass::DInput,
                &grad,
                &filters[k],
                &plan.dinput_plans[k],
                pool,
                counters.stage(k),
            )
        };
        grad = Arc::new(next);
    }
    let out = Arc::try_unwrap(grad).unwrap_or_else(|a| (*a).clone());
    tg.finish(
        plan,
        &plan.expected_network_traffic(),
        &plan.expected_halo_words(),
        counters,
    );
    out
}

/// Extract batch rows `tn` of `t` as an owned tensor (the batch axis is
/// outermost, so a block is one contiguous slice).
pub(crate) fn batch_block(t: &Tensor4, tn: Blk) -> Tensor4 {
    let stride = t.dims[1] * t.dims[2] * t.dims[3];
    let s0 = tn.start as usize * stride;
    let s1 = s0 + tn.len as usize * stride;
    Tensor4 {
        dims: [tn.len as usize, t.dims[1], t.dims[2], t.dims[3]],
        data: t.data[s0..s1].to_vec(),
    }
}

/// Write a batch block back at rows `tn` of `out`.
pub(crate) fn scatter_batch_block(out: &mut Tensor4, tn: Blk, blk: &Tensor4) {
    let stride = out.dims[1] * out.dims[2] * out.dims[3];
    let s0 = tn.start as usize * stride;
    out.data[s0..s0 + blk.data.len()].copy_from_slice(&blk.data);
}

/// [`crate::conv::dfilter_naive`]'s exact nest, accumulating into the
/// resident filter-gradient tensor instead of a fresh one. The step sweep
/// feeds batch blocks in ascending order and this nest adds one scalar
/// accumulator per (element, n) over ascending `(wO, hO)` — so across
/// blocks every dFilter element receives its per-sample terms exactly as
/// the oracle's flat `i1` loop does, keeping the blocked sweep bitwise.
fn dfilter_accumulate(x: &Tensor4, g: &Tensor4, s: &ConvShape, out: &mut Tensor4) {
    let (n, c_i, c_o) = (s.n as usize, s.c_i as usize, s.c_o as usize);
    let (w_o, h_o) = (s.w_o as usize, s.h_o as usize);
    let (w_f, h_f) = (s.w_f as usize, s.h_f as usize);
    let (sw, sh) = (s.s_w as usize, s.s_h as usize);
    for i1 in 0..n {
        for i2 in 0..c_i {
            for i3 in 0..c_o {
                for i6 in 0..w_f {
                    for i7 in 0..h_f {
                        let mut acc = 0.0f32;
                        for i4 in 0..w_o {
                            for i5 in 0..h_o {
                                acc += x.at(i1, i2, sw * i4 + i6, sh * i5 + i7)
                                    * g.at(i1, i3, i4, i5);
                            }
                        }
                        *out.at_mut(i2, i3, i6, i7) += acc;
                    }
                }
            }
        }
    }
}

/// One fused training step: forward to the loss boundary, then every
/// filter and the image gradient, with fused groups materializing nothing
/// between their stages. Returns `(per-stage dFilter, dInput of stage 0)`.
///
/// Phase 1 runs the forward network, materializing only the boundary
/// activations between groups (the last group's forward output feeds
/// nothing — the loss gradient arrives from outside — so it is skipped).
/// Phase 2 walks the groups tail to head; a fused group processes one
/// batch block at a time in ascending order: re-read the head activation
/// block, recompute the interior activations, read the loss-gradient
/// block at the tail, then walk dFilter + dInput back down with the
/// group's filter gradients resident across blocks (spilled once per
/// group). Batch blocking is the only blocking — dFilter's accumulation
/// contract forbids spatial tiles — so when every non-last group is fused
/// ([`FusePlan::step_bitwise`]) the whole step is bitwise identical to
/// [`super::fuse::naive_network_step`]; materialized groups run the
/// LP-tiled engine (gradients bitwise, forward to float tolerance).
/// Measured per-stage traffic equals
/// [`FusePlan::expected_network_traffic`] exactly.
pub fn conv_network_step_counted(
    image: &Tensor4,
    filters: &[&Tensor4],
    gout: &Tensor4,
    plan: &FusePlan,
    counters: &NetTrafficCounters,
) -> (Vec<Tensor4>, Tensor4) {
    assert_eq!(plan.pass, NetPass::Step, "plan solved for a different pass");
    assert_network_operands(image, filters, &plan.stages);
    {
        let tail = &plan.stages[plan.stages.len() - 1].shape;
        assert_eq!(gout.dims, out_dims(tail), "loss gradient shape mismatch");
    }
    assert_eq!(counters.len(), plan.stages.len(), "counter arity");
    let tg = NetTraceGuard::start(counters);
    let groups = &plan.groups;
    let last = groups.len() - 1;

    // ---- phase 1: forward, materializing only group-boundary activations ----
    let mut boundary: Vec<Option<Tensor4>> = vec![None; groups.len()];
    for (gi, g) in groups[..last].iter().enumerate() {
        let input: &Tensor4 = if gi == 0 {
            image
        } else {
            boundary[gi - 1].as_ref().unwrap()
        };
        let out = if g.is_fused() {
            let head = &plan.stages[g.start].shape;
            let mut out = Tensor4::zeros(network_out_dims(&plan.stages, g));
            for tn in tiles::split(head.n, g.b_n) {
                let mut act = batch_block(input, tn);
                counters.stage(g.start).add_input(act.len() as u64);
                for k in g.start..=g.end {
                    let st = &plan.stages[k].shape;
                    let sub = ConvShape { n: tn.len, ..*st };
                    act = conv7nl_naive(&act, filters[k], &sub);
                    counters.stage(k).add_filter(st.filter_size());
                }
                counters.stage(g.end).add_output(act.len() as u64);
                scatter_batch_block(&mut out, tn, &act);
            }
            out
        } else {
            let k = g.start;
            conv_tiled_counted(
                input,
                filters[k],
                &plan.stage_plans[k],
                counters.stage(k),
            )
        };
        boundary[gi] = Some(out);
    }

    // ---- phase 2: the training sweep, tail group to head group ----
    let mut dfilters: Vec<Tensor4> = plan
        .stages
        .iter()
        .map(|st| Tensor4::zeros(st.shape.filter_dims()))
        .collect();
    let mut grad = gout.clone();
    for gi in (0..groups.len()).rev() {
        let g = &groups[gi];
        let input: &Tensor4 = if gi == 0 {
            image
        } else {
            boundary[gi - 1].as_ref().unwrap()
        };
        if g.is_fused() {
            let head = &plan.stages[g.start].shape;
            let mut din = Tensor4::zeros([
                head.n as usize,
                head.c_i as usize,
                head.in_w() as usize,
                head.in_h() as usize,
            ]);
            for tn in tiles::split(head.n, g.b_n) {
                // head activation block + interior recompute (the tail
                // stage's forward output is never needed)
                let act0 = batch_block(input, tn);
                counters.stage(g.start).add_input(act0.len() as u64);
                let mut acts: Vec<Tensor4> = Vec::with_capacity(g.len());
                acts.push(act0);
                for k in g.start..g.end {
                    let st = &plan.stages[k].shape;
                    let sub = ConvShape { n: tn.len, ..*st };
                    let next = conv7nl_naive(acts.last().unwrap(), filters[k], &sub);
                    counters.stage(k).add_filter(st.filter_size());
                    acts.push(next);
                }
                // loss-gradient block at the tail
                let mut gblk = batch_block(&grad, tn);
                counters.stage(g.end).add_input(gblk.len() as u64);
                // backward walk: dFilter accumulates into the resident
                // group gradients, dInput chains the block head-ward
                for k in (g.start..=g.end).rev() {
                    let st = &plan.stages[k].shape;
                    let sub = ConvShape { n: tn.len, ..*st };
                    dfilter_accumulate(
                        &acts[k - g.start],
                        &gblk,
                        &sub,
                        &mut dfilters[k],
                    );
                    counters.stage(k).add_filter(st.filter_size());
                    gblk = dinput_naive(
                        &gblk,
                        filters[k],
                        &sub,
                        sub.in_w() as usize,
                        sub.in_h() as usize,
                    );
                }
                counters.stage(g.start).add_output(gblk.len() as u64);
                scatter_batch_block(&mut din, tn, &gblk);
            }
            // the group's filter gradients spill once
            for k in g.start..=g.end {
                counters.stage(k).add_filter(plan.stages[k].shape.filter_size());
            }
            grad = din;
        } else {
            let k = g.start;
            dfilters[k] = conv_pass_tiled_counted(
                ConvPass::DFilter,
                input,
                &grad,
                &plan.dfilter_plans[k],
                counters.stage(k),
            );
            grad = conv_pass_tiled_counted(
                ConvPass::DInput,
                &grad,
                filters[k],
                &plan.dinput_plans[k],
                counters.stage(k),
            );
        }
    }
    tg.finish(
        plan,
        &plan.expected_network_traffic(),
        &plan.expected_halo_words(),
        counters,
    );
    (dfilters, grad)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::{conv7nl_naive, Precision};
    use crate::kernels::TilePlanCache;
    use crate::runtime::manifest::NetworkSpec;

    fn run_pair(s: &ConvShape, m: f64, seed: u64) -> (Tensor4, Tensor4, Traffic) {
        let (x, w) = crate::conv::paper_operands(s, seed);
        let plan = TilePlan::new(s, Precision::uniform(), m);
        let ctr = TrafficCounters::new();
        let got = conv_tiled_counted(&x, &w, &plan, &ctr);
        let want = conv7nl_naive(&x, &w, s);
        (got, want, ctr.snapshot())
    }

    #[test]
    fn matches_naive_unit_stride() {
        let s = ConvShape::new(2, 3, 4, 5, 5, 3, 3, 1, 1);
        let (got, want, t) = run_pair(&s, 1024.0, 1);
        assert!(got.rel_l2(&want) < 1e-5, "rel {}", got.rel_l2(&want));
        assert_eq!(t.output_words, s.output_size());
        assert!(t.input_words > 0 && t.filter_words > 0);
    }

    #[test]
    fn matches_naive_strided_nonsquare() {
        // stride 2x3, non-square 5x4 filter, ragged everything
        let s = ConvShape::new(2, 3, 5, 7, 5, 5, 4, 2, 3);
        let (got, want, _) = run_pair(&s, 512.0, 3);
        assert!(got.rel_l2(&want) < 1e-4, "rel {}", got.rel_l2(&want));
    }

    #[test]
    fn matches_naive_tiny_memory_many_tiles() {
        // memory barely above the planner floor forces deep tiling
        let s = ConvShape::new(3, 4, 6, 9, 11, 3, 2, 1, 1);
        let (got, want, t) = run_pair(&s, 64.0, 5);
        assert!(got.rel_l2(&want) < 1e-4, "rel {}", got.rel_l2(&want));
        // deep tiling re-reads the input many times
        assert!(t.input_words > s.input_size());
    }

    #[test]
    fn measured_traffic_matches_expected_exactly() {
        for (s, m) in [
            (ConvShape::new(2, 3, 4, 6, 6, 3, 3, 1, 1), 256.0),
            (ConvShape::new(1, 2, 3, 4, 4, 3, 3, 2, 2), 128.0),
            (ConvShape::new(2, 5, 7, 7, 5, 4, 5, 3, 2), 512.0),
        ] {
            let plan = TilePlan::new(&s, Precision::uniform(), m);
            let (x, w) = crate::conv::paper_operands(&s, 11);
            let ctr = TrafficCounters::new();
            conv_tiled_counted(&x, &w, &plan, &ctr);
            assert_eq!(ctr.snapshot(), expected_traffic(&plan), "{s}");
        }
    }

    #[test]
    fn parallel_is_bitwise_identical_to_serial() {
        let s = ConvShape::new(3, 4, 8, 10, 9, 3, 3, 1, 1);
        let plan = Arc::new(TilePlan::new(&s, Precision::uniform(), 512.0));
        let (x, w) = crate::conv::paper_operands(&s, 21);
        let (x, w) = (Arc::new(x), Arc::new(w));
        let serial = conv_tiled(&x, &w, &plan);
        let pool = ThreadPool::new(4);
        let ctr = Arc::new(TrafficCounters::new());
        let par = conv_tiled_parallel(&x, &w, &plan, &pool, &ctr);
        assert_eq!(par.max_abs_diff(&serial), 0.0);
        // counters see the same totals regardless of interleaving
        assert_eq!(ctr.snapshot(), expected_traffic(&plan));
    }

    #[test]
    fn degenerate_shapes_return_empty_or_zero_output() {
        // zero batch: empty output, no tile fabricated over the empty dim
        let s = ConvShape::new(0, 3, 4, 5, 5, 3, 3, 1, 1);
        let plan = TilePlan::new(&s, Precision::uniform(), 1024.0);
        let x = Tensor4::zeros([0, 3, 8, 8]);
        let w = Tensor4::zeros([3, 4, 3, 3]);
        let out = conv_tiled(&x, &w, &plan);
        assert_eq!(out.dims, [0, 4, 5, 5]);
        assert!(out.is_empty());

        // zero input channels: full-size all-zero output, like the oracle
        let s2 = ConvShape::new(2, 0, 4, 5, 5, 3, 3, 1, 1);
        let plan2 = TilePlan::new(&s2, Precision::uniform(), 1024.0);
        let x2 = Tensor4::zeros([2, 0, 8, 8]);
        let w2 = Tensor4::zeros([0, 4, 3, 3]);
        let out2 = conv_tiled(&x2, &w2, &plan2);
        assert_eq!(out2.dims, [2, 4, 5, 5]);
        assert!(out2.data.iter().all(|&v| v == 0.0));
    }

    /// Tiled gradients are bitwise identical to the naive oracles — the
    /// backward accumulation-order contract — with exact traffic, on
    /// strided, non-square, ragged shapes.
    #[test]
    fn backward_passes_bitwise_match_oracles() {
        for (s, m) in [
            (ConvShape::new(2, 3, 4, 5, 5, 3, 3, 1, 1), 1024.0),
            (ConvShape::new(2, 3, 5, 7, 5, 5, 4, 2, 3), 512.0),
            (ConvShape::new(3, 4, 6, 9, 11, 3, 2, 1, 1), 96.0),
            (ConvShape::new(1, 2, 3, 4, 4, 3, 3, 2, 2), 128.0),
        ] {
            for pass in [ConvPass::DFilter, ConvPass::DInput] {
                let plan = TilePlan::for_pass(pass, &s, Precision::uniform(), m);
                let (a, b) = crate::conv::pass_operands(pass, &s, 7);
                let ctr = TrafficCounters::new();
                let got = conv_pass_tiled_counted(pass, &a, &b, &plan, &ctr);
                let want = pass.naive_oracle(&a, &b, &s);
                assert_eq!(got.dims, want.dims, "{s} {}", pass.name());
                assert_eq!(
                    got.max_abs_diff(&want),
                    0.0,
                    "{s} {}: tiled diverged from the oracle",
                    pass.name()
                );
                assert_eq!(
                    ctr.snapshot(),
                    expected_pass_traffic(&plan),
                    "{s} {}: traffic",
                    pass.name()
                );
            }
        }
    }

    #[test]
    fn backward_parallel_is_bitwise_identical_to_serial() {
        let s = ConvShape::new(3, 4, 8, 10, 9, 3, 3, 1, 1);
        let pool = ThreadPool::new(4);
        for pass in [ConvPass::DFilter, ConvPass::DInput] {
            let plan =
                Arc::new(TilePlan::for_pass(pass, &s, Precision::uniform(), 512.0));
            let (a, b) = crate::conv::pass_operands(pass, &s, 23);
            let (a, b) = (Arc::new(a), Arc::new(b));
            let serial = conv_pass_tiled(pass, &a, &b, &plan);
            let ctr = Arc::new(TrafficCounters::new());
            let par = conv_pass_tiled_parallel(pass, &a, &b, &plan, &pool, &ctr);
            assert_eq!(par.max_abs_diff(&serial), 0.0, "{}", pass.name());
            assert_eq!(ctr.snapshot(), expected_pass_traffic(&plan), "{}", pass.name());
        }
    }

    #[test]
    fn degenerate_backward_shapes_return_empty_or_zero_gradients() {
        // zero batch: dFilter is the full-size zero gradient, dInput empty
        let s = ConvShape::new(0, 3, 4, 5, 5, 3, 3, 1, 1);
        let (a, b) = crate::conv::pass_operands(ConvPass::DFilter, &s, 1);
        let plan = TilePlan::for_pass(ConvPass::DFilter, &s, Precision::uniform(), 1024.0);
        let out = conv_pass_tiled(ConvPass::DFilter, &a, &b, &plan);
        assert_eq!(out.dims, [3, 4, 3, 3]);
        assert!(out.data.iter().all(|&v| v == 0.0));
        assert_eq!(expected_pass_traffic(&plan), Traffic::default());

        // zero output channels: dInput is the full-size zero gradient
        let s2 = ConvShape::new(2, 3, 0, 5, 5, 3, 3, 1, 1);
        let (a2, b2) = crate::conv::pass_operands(ConvPass::DInput, &s2, 2);
        let plan2 = TilePlan::for_pass(ConvPass::DInput, &s2, Precision::uniform(), 1024.0);
        let out2 = conv_pass_tiled(ConvPass::DInput, &a2, &b2, &plan2);
        assert_eq!(out2.dims, [2, 3, 8, 8]);
        assert!(out2.data.iter().all(|&v| v == 0.0));
    }

    /// The forward pass through the pass-generic entry point is the
    /// existing engine, bit for bit.
    #[test]
    fn forward_pass_entry_is_the_existing_engine() {
        let s = ConvShape::new(2, 3, 4, 6, 6, 3, 3, 1, 1);
        let (x, w) = crate::conv::paper_operands(&s, 9);
        let plan = TilePlan::for_pass(ConvPass::Forward, &s, Precision::uniform(), 256.0);
        let via_pass = conv_pass_tiled(ConvPass::Forward, &x, &w, &plan);
        let direct = conv_tiled(&x, &w, &plan);
        assert_eq!(via_pass.max_abs_diff(&direct), 0.0);
        assert_eq!(expected_pass_traffic(&plan), expected_traffic(&plan));
    }

    #[test]
    fn counters_reset() {
        let c = TrafficCounters::new();
        c.add_input(5);
        c.add_filter(3);
        c.add_output(2);
        assert_eq!(c.snapshot().total(), 10);
        c.reset();
        assert_eq!(c.snapshot(), Traffic::default());
    }

    /// Packed and reference fused execution, halo cache on and off, must
    /// all be bitwise identical to the staged naive oracle, with measured
    /// traffic and halo words matching the plan's analytic models exactly.
    #[test]
    fn fused_packed_reference_and_halo_agree_bitwise() {
        let net = NetworkSpec::tiny_resnet(2);
        let cache = TilePlanCache::new();
        let mut base = FusePlan::new(&net.stages, 65536.0, &cache);
        // force one fused group swept in single-row h-tiles so the
        // sliding-window cache engages on every boundary
        base.groups = vec![FuseGroup {
            start: 0,
            end: 2,
            b_n: 2,
            b_wo: 4,
            b_ho: 1,
        }];
        let image = Tensor4::randn(net.input_dims(), 9);
        let filters: Vec<Tensor4> = net
            .stages
            .iter()
            .enumerate()
            .map(|(i, st)| Tensor4::randn(st.shape.filter_dims(), 10 + i as u64))
            .collect();
        let frefs: Vec<&Tensor4> = filters.iter().collect();
        let want = super::super::fuse::naive_network(&image, &frefs, &net.stages);
        let mut cached_halo_words = 0u64;
        for (exec, halo) in [
            (FusedExec::Packed, true),
            (FusedExec::Packed, false),
            (FusedExec::Reference, true),
            (FusedExec::Reference, false),
        ] {
            let mut plan = base.clone();
            plan.exec = exec;
            plan.halo_cache = halo;
            let counters = NetTrafficCounters::new(net.stages.len());
            let got = conv_network_fused_counted(&image, &frefs, &plan, &counters);
            assert_eq!(
                got.max_abs_diff(&want),
                0.0,
                "{exec:?} halo={halo} diverged from the oracle"
            );
            assert_eq!(
                counters.snapshot(),
                plan.expected_network_traffic(),
                "{exec:?} halo={halo} traffic"
            );
            assert_eq!(
                counters.halo_snapshot(),
                plan.expected_halo_words(),
                "{exec:?} halo={halo} halo words"
            );
            if halo {
                cached_halo_words = counters.halo_snapshot().iter().sum();
            } else {
                assert!(counters.halo_snapshot().iter().all(|&w| w == 0));
            }
        }
        assert!(
            cached_halo_words > 0,
            "single-row sweep must serve words from the halo cache"
        );
    }

    /// The w-axis halo carry changes no output bit (on or off, serial or
    /// parallel), keeps measured traffic and halo words exactly on the
    /// analytic models, and with single-column w-tiles serves strictly
    /// more words (and reads strictly fewer) than the h-carry alone.
    #[test]
    fn w_carry_is_bitwise_with_exact_traffic() {
        let net = NetworkSpec::tiny_resnet(2);
        let cache = TilePlanCache::new();
        let mut base = FusePlan::new(&net.stages, 65536.0, &cache);
        // single-column, single-row tiles: both carries engage on every
        // interior tile of every batch block
        base.groups = vec![FuseGroup {
            start: 0,
            end: 2,
            b_n: 1,
            b_wo: 1,
            b_ho: 1,
        }];
        let image = Tensor4::randn(net.input_dims(), 21);
        let filters: Vec<Tensor4> = net
            .stages
            .iter()
            .enumerate()
            .map(|(i, st)| Tensor4::randn(st.shape.filter_dims(), 22 + i as u64))
            .collect();
        let frefs: Vec<&Tensor4> = filters.iter().collect();
        let want = super::super::fuse::naive_network(&image, &frefs, &net.stages);
        let image_arc = Arc::new(image.clone());
        let farcs: Vec<Arc<Tensor4>> =
            filters.iter().cloned().map(Arc::new).collect();
        let pool = ThreadPool::new(3);
        let mut served = [0u64; 2];
        let mut head_reads = [0u64; 2];
        for (i, halo_w) in [false, true].into_iter().enumerate() {
            let mut plan = base.clone();
            plan.halo_cache = true;
            plan.halo_w = halo_w;
            let counters = NetTrafficCounters::new(net.stages.len());
            let got =
                conv_network_fused_counted(&image, &frefs, &plan, &counters);
            assert_eq!(
                got.max_abs_diff(&want),
                0.0,
                "halo_w={halo_w} diverged from the oracle"
            );
            assert_eq!(
                counters.snapshot(),
                plan.expected_network_traffic(),
                "halo_w={halo_w} traffic"
            );
            assert_eq!(
                counters.halo_snapshot(),
                plan.expected_halo_words(),
                "halo_w={halo_w} halo words"
            );
            served[i] = counters.halo_snapshot().iter().sum();
            head_reads[i] = counters.snapshot()[0].input_words;
            // the widened parallel work unit stays bitwise and exact
            let plan = Arc::new(plan);
            let par_ctr = NetTrafficCounters::new(net.stages.len());
            let par =
                conv_network_fused(&image_arc, &farcs, &plan, &pool, &par_ctr);
            assert_eq!(par.max_abs_diff(&got), 0.0, "halo_w={halo_w} parallel");
            assert_eq!(
                par_ctr.snapshot(),
                plan.expected_network_traffic(),
                "halo_w={halo_w} parallel traffic"
            );
            assert_eq!(
                par_ctr.halo_snapshot(),
                plan.expected_halo_words(),
                "halo_w={halo_w} parallel halo words"
            );
        }
        assert!(
            served[1] > served[0],
            "w-carry must serve extra words ({:?})",
            served
        );
        assert!(
            head_reads[1] < head_reads[0],
            "w-carry must cut head input reads ({:?})",
            head_reads
        );
    }

    fn training_operands(
        net: &NetworkSpec,
        seed: u64,
    ) -> (Tensor4, Vec<Tensor4>, Tensor4) {
        let image = Tensor4::randn(net.input_dims(), seed);
        let filters: Vec<Tensor4> = net
            .stages
            .iter()
            .enumerate()
            .map(|(i, st)| {
                Tensor4::randn(st.shape.filter_dims(), seed + 1 + i as u64)
            })
            .collect();
        let tail = &net.stages[net.stages.len() - 1].shape;
        let gout = Tensor4::randn(out_dims(tail), seed + 100);
        (image, filters, gout)
    }

    /// The fused backward sweep is bitwise identical to the dInput-chain
    /// oracle, halo cache on or off, with measured traffic and halo words
    /// matching the plan's analytic models exactly and zero words across
    /// fused gradient boundaries.
    #[test]
    fn fused_backward_matches_oracle_bitwise_with_exact_traffic() {
        let net = NetworkSpec::tiny_resnet(2);
        let cache = TilePlanCache::new();
        let mut base =
            FusePlan::for_pass(NetPass::Backward, &net.stages, 65536.0, &cache);
        // force one fused group swept in short h-tiles so consecutive
        // tail gradient spans overlap and the carry engages
        base.groups = vec![FuseGroup {
            start: 0,
            end: 2,
            b_n: 2,
            b_wo: 8,
            b_ho: 2,
        }];
        let (_, filters, gout) = training_operands(&net, 31);
        let frefs: Vec<&Tensor4> = filters.iter().collect();
        let want = super::super::fuse::naive_network_bwd(&gout, &frefs, &net.stages);
        let mut cached_halo_words = 0u64;
        for halo in [true, false] {
            let mut plan = base.clone();
            plan.halo_cache = halo;
            let counters = NetTrafficCounters::new(net.stages.len());
            let got = conv_network_bwd_counted(&gout, &frefs, &plan, &counters);
            assert_eq!(
                got.max_abs_diff(&want),
                0.0,
                "halo={halo} diverged from the oracle"
            );
            let snap = counters.snapshot();
            assert_eq!(snap, plan.expected_network_traffic(), "halo={halo} traffic");
            assert_eq!(
                counters.halo_snapshot(),
                plan.expected_halo_words(),
                "halo={halo} halo words"
            );
            assert_eq!(plan.boundary_words(&snap), 0, "halo={halo} boundary");
            if halo {
                cached_halo_words = counters.halo_snapshot().iter().sum();
            }
        }
        assert!(
            cached_halo_words > 0,
            "short h-tiles must serve gradient rows from the carry"
        );
    }

    #[test]
    fn backward_network_parallel_is_bitwise_identical_to_serial() {
        let net = NetworkSpec::tiny_resnet(2);
        let cache = TilePlanCache::new();
        let plan = Arc::new(FusePlan::for_pass(
            NetPass::Backward,
            &net.stages,
            65536.0,
            &cache,
        ));
        let (_, filters, gout) = training_operands(&net, 47);
        let frefs: Vec<&Tensor4> = filters.iter().collect();
        let serial_ctr = NetTrafficCounters::new(net.stages.len());
        let serial = conv_network_bwd_counted(&gout, &frefs, &plan, &serial_ctr);
        let gout = Arc::new(gout);
        let farcs: Vec<Arc<Tensor4>> =
            filters.into_iter().map(Arc::new).collect();
        let pool = ThreadPool::new(4);
        let ctr = NetTrafficCounters::new(net.stages.len());
        let par = conv_network_bwd(&gout, &farcs, &plan, &pool, &ctr);
        assert_eq!(par.max_abs_diff(&serial), 0.0);
        assert_eq!(ctr.snapshot(), serial_ctr.snapshot());
        assert_eq!(ctr.snapshot(), plan.expected_network_traffic());
    }

    /// A step plan whose groups are all fused runs the whole training
    /// step bitwise identical to the layer-by-layer SGD oracle — every
    /// filter gradient and the image gradient — with exact traffic and
    /// zero boundary words, including when batch blocking splits the
    /// sweep.
    #[test]
    fn fused_step_matches_sgd_oracle_bitwise() {
        let net = NetworkSpec::tiny_resnet(2);
        let cache = TilePlanCache::new();
        let base =
            FusePlan::for_pass(NetPass::Step, &net.stages, 65536.0, &cache);
        assert!(base.step_bitwise(), "tiny_resnet step must fuse end to end");
        let (image, filters, gout) = training_operands(&net, 59);
        let frefs: Vec<&Tensor4> = filters.iter().collect();
        let (want_df, want_din) = super::super::fuse::naive_network_step(
            &image,
            &frefs,
            &gout,
            &net.stages,
        );
        for b_n in [2, 1] {
            let mut plan = base.clone();
            plan.groups[0].b_n = b_n;
            let counters = NetTrafficCounters::new(net.stages.len());
            let (df, din) =
                conv_network_step_counted(&image, &frefs, &gout, &plan, &counters);
            for (k, (got, want)) in df.iter().zip(&want_df).enumerate() {
                assert_eq!(
                    got.max_abs_diff(want),
                    0.0,
                    "b_n={b_n} dFilter[{k}] diverged from the oracle"
                );
            }
            assert_eq!(
                din.max_abs_diff(&want_din),
                0.0,
                "b_n={b_n} image gradient diverged from the oracle"
            );
            let snap = counters.snapshot();
            assert_eq!(snap, plan.expected_network_traffic(), "b_n={b_n} traffic");
            assert_eq!(plan.boundary_words(&snap), 0, "b_n={b_n} boundary");
            assert!(counters.halo_snapshot().iter().all(|&w| w == 0));
        }
    }

    /// A fully materialized step plan keeps its gradients bitwise at the
    /// last stage (tiled backward passes honor the contract) but its
    /// layered forward reassociates sums — so the step agrees to float
    /// tolerance, is not `step_bitwise`, and still measures its traffic
    /// exactly.
    #[test]
    fn materialized_step_stays_close_with_exact_traffic() {
        let net = NetworkSpec::tiny_resnet(2);
        let cache = TilePlanCache::new();
        let plan = FusePlan::materialized_pass(
            NetPass::Step,
            &net.stages,
            65536.0,
            &cache,
        );
        assert!(!plan.step_bitwise());
        let (image, filters, gout) = training_operands(&net, 73);
        let frefs: Vec<&Tensor4> = filters.iter().collect();
        let (want_df, want_din) = super::super::fuse::naive_network_step(
            &image,
            &frefs,
            &gout,
            &net.stages,
        );
        let counters = NetTrafficCounters::new(net.stages.len());
        let (df, din) =
            conv_network_step_counted(&image, &frefs, &gout, &plan, &counters);
        for (got, want) in df.iter().zip(&want_df) {
            assert!(got.rel_l2(want) < 1e-4, "dFilter rel {}", got.rel_l2(want));
        }
        assert!(din.rel_l2(&want_din) < 1e-4, "dIn rel {}", din.rel_l2(&want_din));
        assert_eq!(counters.snapshot(), plan.expected_network_traffic());
    }

    /// Backward plans stay bitwise for *every* grouping — materialized
    /// singles use the tiled dInput engine, which honors the contract.
    #[test]
    fn materialized_backward_is_bitwise_too() {
        let net = NetworkSpec::tiny_resnet(2);
        let cache = TilePlanCache::new();
        let plan = FusePlan::materialized_pass(
            NetPass::Backward,
            &net.stages,
            65536.0,
            &cache,
        );
        let (_, filters, gout) = training_operands(&net, 83);
        let frefs: Vec<&Tensor4> = filters.iter().collect();
        let want = super::super::fuse::naive_network_bwd(&gout, &frefs, &net.stages);
        let counters = NetTrafficCounters::new(net.stages.len());
        let got = conv_network_bwd_counted(&gout, &frefs, &plan, &counters);
        assert_eq!(got.max_abs_diff(&want), 0.0);
        assert_eq!(counters.snapshot(), plan.expected_network_traffic());
    }
}
