//! The inner tile microkernel: a register-friendly MAC loop over packed
//! buffers.
//!
//! For one (output tile × reduction tile) pair the update is
//!
//! ```text
//! out[n][i4][i5][co] += in[n][ci][r6][r7][i4+q6][i5+q7] · f[ci][q6][q7][r6][r7][co]
//! ```
//!
//! organised so the innermost loop is a contiguous axpy over the cO block:
//! one input scalar broadcast against one cached filter row, accumulating
//! into one contiguous output row — the shape LLVM auto-vectorizes. The
//! filter row (`bcO` floats) stays hot across the whole `n × i4 × i5`
//! sweep.

/// All block extents and absolute split offsets one tile-pair MAC needs.
pub(crate) struct TileDims {
    pub bn: usize,
    pub bci: usize,
    pub bco: usize,
    pub bwo: usize,
    pub bho: usize,
    pub bqw: usize,
    pub bqh: usize,
    pub brw: usize,
    pub brh: usize,
    /// extended input patch dims: `ew = bwo + bqw − 1`, `eh = bho + bqh − 1`
    pub ew: usize,
    pub eh: usize,
    /// absolute starts of the split-filter blocks
    pub q6_0: usize,
    pub q7_0: usize,
    pub r6_0: usize,
    pub r7_0: usize,
    /// strides and true filter extents, for split-coordinate validity
    pub sw: usize,
    pub sh: usize,
    pub wf: usize,
    pub hf: usize,
}

/// Scalar reference axpy: `out[co] += x · f[co]` over one contiguous cO
/// row. Kept as the semantics oracle the unrolled form is pinned to
/// bitwise (each lane performs the identical single mul-add per element).
#[inline]
pub fn axpy_scalar(out: &mut [f32], f_row: &[f32], x: f32) {
    for (o, f) in out.iter_mut().zip(f_row.iter()) {
        *o += x * *f;
    }
}

/// `out[co] += x · f[co]` over one contiguous cO row, unrolled into eight
/// independent accumulator lanes. The bounds are hoisted out of the body
/// via `split_at`, so the eight updates carry no per-element bounds checks
/// or cross-lane dependencies — the shape LLVM reliably turns into packed
/// mul-add vectors. Lane `i` still computes exactly `out[i] += x · f[i]`
/// once, so the result is bitwise identical to [`axpy_scalar`].
#[inline]
pub fn axpy(out: &mut [f32], f_row: &[f32], x: f32) {
    let n = out.len().min(f_row.len());
    let main = n - n % 8;
    let (o_main, o_tail) = out[..n].split_at_mut(main);
    let (f_main, f_tail) = f_row[..n].split_at(main);
    for (o8, f8) in o_main.chunks_exact_mut(8).zip(f_main.chunks_exact(8)) {
        o8[0] += x * f8[0];
        o8[1] += x * f8[1];
        o8[2] += x * f8[2];
        o8[3] += x * f8[3];
        o8[4] += x * f8[4];
        o8[5] += x * f8[5];
        o8[6] += x * f8[6];
        o8[7] += x * f8[7];
    }
    for (o, f) in o_tail.iter_mut().zip(f_tail.iter()) {
        *o += x * *f;
    }
}

/// Accumulate one reduction tile into one resident output tile.
///
/// `out`: `[bn][bwo][bho][bco]`, `xin`: `[bn][bci][brw][brh][ew][eh]`,
/// `fil`: `[bci][bqw][bqh][brw][brh][bco]` (layouts from `pack.rs`).
///
/// **Accumulation-order contract** (DESIGN.md §7). Per output element the
/// reduction terms are added in loop order `ci → (q6, r6) → (q7, r7)`;
/// since `i6 = σw·q6 + r6` with `r6 < σw`, lexicographic `(q6, r6)`
/// enumerates `i6` ascending (likewise `i7`). A tile covering the *whole*
/// reduction — full `cI` and complete split ranges, as the fused executor
/// packs it — therefore accumulates in ascending `(cI, i6, i7)` order,
/// exactly the naive 7NL nest's order, and each update is the same single
/// mul-add: the fused packed path is bitwise identical to the naive
/// reference. (The nest skips exact-zero filter taps where this path adds
/// `x·0`; that changes no bits for the finite, nonzero operands the stack
/// computes on.)
pub(crate) fn conv_tile_mac(out: &mut [f32], xin: &[f32], fil: &[f32], d: &TileDims) {
    debug_assert_eq!(out.len(), d.bn * d.bwo * d.bho * d.bco);
    debug_assert_eq!(xin.len(), d.bn * d.bci * d.brw * d.brh * d.ew * d.eh);
    debug_assert_eq!(fil.len(), d.bci * d.bqw * d.bqh * d.brw * d.brh * d.bco);
    for ci in 0..d.bci {
        for q6 in 0..d.bqw {
            let i6_base = d.sw * (d.q6_0 + q6);
            for r6 in 0..d.brw {
                if i6_base + d.r6_0 + r6 >= d.wf {
                    continue; // split coordinate beyond the true filter
                }
                for q7 in 0..d.bqh {
                    let i7_base = d.sh * (d.q7_0 + q7);
                    for r7 in 0..d.brh {
                        if i7_base + d.r7_0 + r7 >= d.hf {
                            continue;
                        }
                        let f_off = ((((ci * d.bqw + q6) * d.bqh + q7) * d.brw
                            + r6)
                            * d.brh
                            + r7)
                            * d.bco;
                        let f_row = &fil[f_off..f_off + d.bco];
                        for n in 0..d.bn {
                            let x_plane =
                                ((n * d.bci + ci) * d.brw + r6) * d.brh + r7;
                            for i4 in 0..d.bwo {
                                let x_row =
                                    (x_plane * d.ew + (i4 + q6)) * d.eh + q7;
                                let o_row = (n * d.bwo + i4) * d.bho * d.bco;
                                for i5 in 0..d.bho {
                                    let xv = xin[x_row + i5];
                                    let o = &mut out[o_row + i5 * d.bco
                                        ..o_row + (i5 + 1) * d.bco];
                                    axpy(o, f_row, xv);
                                }
                            }
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One 2x2 output tile, 1x1 filter, single channel: the MAC reduces to
    /// an elementwise scale of the packed input.
    #[test]
    fn one_by_one_filter_scales_input() {
        let d = TileDims {
            bn: 1,
            bci: 1,
            bco: 1,
            bwo: 2,
            bho: 2,
            bqw: 1,
            bqh: 1,
            brw: 1,
            brh: 1,
            ew: 2,
            eh: 2,
            q6_0: 0,
            q7_0: 0,
            r6_0: 0,
            r7_0: 0,
            sw: 1,
            sh: 1,
            wf: 1,
            hf: 1,
        };
        let xin = vec![1.0, 2.0, 3.0, 4.0];
        let fil = vec![0.5];
        let mut out = vec![0.0; 4];
        conv_tile_mac(&mut out, &xin, &fil, &d);
        assert_eq!(out, vec![0.5, 1.0, 1.5, 2.0]);
        // accumulation: a second pass doubles
        conv_tile_mac(&mut out, &xin, &fil, &d);
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0]);
    }

    /// The unrolled axpy must agree with the scalar reference bit for bit
    /// across main-block and tail lengths.
    #[test]
    fn unrolled_axpy_bitwise_matches_scalar() {
        for len in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 24, 31] {
            let f_row: Vec<f32> =
                (0..len).map(|i| (i as f32 - 3.5) * 0.37).collect();
            let base: Vec<f32> =
                (0..len).map(|i| (i as f32) * 1.25 - 2.0).collect();
            let x = 0.731f32;
            let mut a = base.clone();
            let mut b = base.clone();
            axpy(&mut a, &f_row, x);
            axpy_scalar(&mut b, &f_row, x);
            for (va, vb) in a.iter().zip(&b) {
                assert_eq!(va.to_bits(), vb.to_bits(), "len {len}");
            }
        }
    }

    /// Invalid split coordinates must contribute nothing even when the
    /// filter buffer holds garbage there.
    #[test]
    fn invalid_split_coords_skipped() {
        // wf = 1, stride 2: q range = 1, r range = 2; (q=0, r=1) invalid
        let d = TileDims {
            bn: 1,
            bci: 1,
            bco: 1,
            bwo: 1,
            bho: 1,
            bqw: 1,
            bqh: 1,
            brw: 2,
            brh: 1,
            ew: 1,
            eh: 1,
            q6_0: 0,
            q7_0: 0,
            r6_0: 0,
            r7_0: 0,
            sw: 2,
            sh: 1,
            wf: 1,
            hf: 1,
        };
        // xin layout [n][ci][r6][r7][ew][eh]: r6=0 -> 3.0, r6=1 -> 100.0
        let xin = vec![3.0, 100.0];
        // fil layout [ci][q6][q7][r6][r7][co]: r6=1 slot holds garbage
        let fil = vec![2.0, 999.0];
        let mut out = vec![0.0];
        conv_tile_mac(&mut out, &xin, &fil, &d);
        assert_eq!(out, vec![6.0]);
    }
}
