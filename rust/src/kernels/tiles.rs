//! Tile enumeration over the nine blocked loops.
//!
//! A tile is addressed by one [`Blk`] (half-open index range) per blocked
//! dim. Tiles split into the output-owning coordinates ([`OutTile`]: blocks
//! of n, cO, wO, hO — disjoint output regions, the unit of parallelism) and
//! the reduction coordinates ([`RedTile`]: blocks of cI and the split
//! filter loops q6, q7, r6, r7 — accumulated serially while an output tile
//! stays resident).

use crate::util::ceil_div;

use super::plan::{TilePlan, OUT_DIMS, RED_DIMS};

/// Half-open range `[start, start + len)` of one blocked loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Blk {
    pub start: u64,
    pub len: u64,
}

/// Split `range` into blocks of `block` (the last one ragged).
pub fn split(range: u64, block: u64) -> Vec<Blk> {
    let range = range.max(1);
    let block = block.clamp(1, range);
    let mut out = Vec::with_capacity(ceil_div(range, block) as usize);
    let mut start = 0;
    while start < range {
        let len = block.min(range - start);
        out.push(Blk { start, len });
        start += len;
    }
    out
}

/// One output tile: blocks of (n, cO, wO, hO).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OutTile {
    pub n: Blk,
    pub co: Blk,
    pub wo: Blk,
    pub ho: Blk,
}

/// One reduction tile: blocks of (cI, q6, q7, r6, r7).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RedTile {
    pub ci: Blk,
    pub qw: Blk,
    pub qh: Blk,
    pub rw: Blk,
    pub rh: Blk,
}

/// Every output tile of `plan`, in a fixed row-major order (n outermost).
pub fn output_tiles(plan: &TilePlan) -> Vec<OutTile> {
    let [n, co, wo, ho] =
        OUT_DIMS.map(|i| split(plan.ranges[i], plan.blocks[i]));
    let mut tiles = Vec::with_capacity(n.len() * co.len() * wo.len() * ho.len());
    for &bn in &n {
        for &bco in &co {
            for &bwo in &wo {
                for &bho in &ho {
                    tiles.push(OutTile { n: bn, co: bco, wo: bwo, ho: bho });
                }
            }
        }
    }
    tiles
}

/// Every reduction tile of `plan` (cI outermost, r7 innermost).
pub fn reduction_tiles(plan: &TilePlan) -> Vec<RedTile> {
    let [ci, qw, qh, rw, rh] =
        RED_DIMS.map(|i| split(plan.ranges[i], plan.blocks[i]));
    let mut tiles =
        Vec::with_capacity(ci.len() * qw.len() * qh.len() * rw.len() * rh.len());
    for &bci in &ci {
        for &bqw in &qw {
            for &bqh in &qh {
                for &brw in &rw {
                    for &brh in &rh {
                        tiles.push(RedTile {
                            ci: bci,
                            qw: bqw,
                            qh: bqh,
                            rw: brw,
                            rh: brh,
                        });
                    }
                }
            }
        }
    }
    tiles
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::{ConvShape, Precision};

    #[test]
    fn split_covers_range_exactly() {
        for (range, block) in [(10, 3), (7, 7), (5, 4), (1, 1), (9, 2)] {
            let blks = split(range, block);
            let total: u64 = blks.iter().map(|b| b.len).sum();
            assert_eq!(total, range, "range {range} block {block}");
            assert_eq!(blks[0].start, 0);
            for w in blks.windows(2) {
                assert_eq!(w[0].start + w[0].len, w[1].start);
            }
            assert!(blks.iter().all(|b| b.len >= 1 && b.len <= block));
        }
    }

    #[test]
    fn tile_lists_match_plan_counts() {
        let s = ConvShape::new(3, 5, 7, 11, 13, 3, 2, 1, 1);
        let plan = TilePlan::new(&s, Precision::uniform(), 2048.0);
        assert_eq!(output_tiles(&plan).len() as u64, plan.output_tiles());
        assert_eq!(reduction_tiles(&plan).len() as u64, plan.reduction_tiles());
    }

    #[test]
    fn output_tiles_are_disjoint_and_cover() {
        let s = ConvShape::new(2, 3, 5, 6, 7, 3, 3, 1, 1);
        let plan = TilePlan::new(&s, Precision::uniform(), 1024.0);
        let tiles = output_tiles(&plan);
        let mut seen =
            vec![false; (s.n * s.c_o * s.w_o * s.h_o) as usize];
        for t in &tiles {
            for n in t.n.start..t.n.start + t.n.len {
                for co in t.co.start..t.co.start + t.co.len {
                    for wo in t.wo.start..t.wo.start + t.wo.len {
                        for ho in t.ho.start..t.ho.start + t.ho.len {
                            let idx = (((n * s.c_o + co) * s.w_o + wo) * s.h_o
                                + ho) as usize;
                            assert!(!seen[idx], "overlapping output tiles");
                            seen[idx] = true;
                        }
                    }
                }
            }
        }
        assert!(seen.into_iter().all(|v| v), "output not covered");
    }
}
