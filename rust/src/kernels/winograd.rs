//! Tiled Winograd F(2,3) execution path: the transform-domain algorithm
//! `commvol::seq::winograd_volume` models analytically, actually running.
//!
//! The kernel computes each 2×2 output tile from a 4×4 input tile through
//! the classic F(2,3) transforms (nested per axis for F(2×2, 3×3)):
//!
//! ```text
//! U = G g Gᵀ      (filter transform, 3×3 -> 4×4, done once per filter)
//! V = Bᵀ d B      (input transform, one 4×4 gather per tile per channel)
//! Y = Aᵀ (U∘V) A  (elementwise transform-domain MAC, then 4×4 -> 2×2)
//! ```
//!
//! Arbitrary (stride, filter) layers are normalized to unit-stride ≤3-tap
//! sub-convolutions in two steps, mirroring the analytic model's polyphase
//! decomposition (`commvol/seq.rs`):
//!
//! 1. **Polyphase**: split `i6 = σw·u + rw` (likewise `i7`), so the layer
//!    is a sum over σw·σh residues of *unit-stride* convolutions of the
//!    decimated image `x_r[a][b] = x[σw·a + rw][σh·b + rh]` with the
//!    decimated filter `g_r[u][v] = g[rw + σw·u][rh + σh·v]`. Residues
//!    with no real taps are skipped outright (the analytic model's
//!    `.max(1)` floor is a model convention, not an execution path).
//! 2. **Chunking**: each decimated filter axis is cut into ≤3-tap chunks
//!    at offsets `q0 ∈ {0, 3, …}`; a chunk is a unit-stride 3×3 conv of
//!    the image shifted by `q0`, its missing taps zero-padded.
//!
//! Every real filter tap lands in exactly one (residue, chunk), so the
//! filter transform reads `|F|` words exactly. Out-of-range 4×4 gather
//! positions are zero-filled and **not charged**: in exact arithmetic they
//! multiply only zero taps or feed the ragged 2×2 outputs the scatter
//! discards, so zero-fill is exact (floating-point rounding still differs
//! from the naive nest — hence the tolerance oracle below, not `==`).
//!
//! **Traffic model** ([`expected_winograd_traffic`]): the counters mirror
//! the executor loop for loop, so measured == expected *exactly* like the
//! tiled engine — `filter = |F|` (U cache built once), `output = |O|`
//! (each 2×2 accumulator stays resident across all sub-convolutions and
//! scatters its valid elements once), `input = N·cI·Σ_sub Σ_tile
//! in-range(4×4 gather)` (overlapping gathers are charged honestly; the
//! transform-domain working set is what buys the ~(4·9)/16 input reuse).
//! The model is blocking-independent: the tile-block size only shapes
//! locality, never words.
//!
//! **Tolerance oracle** ([`winograd_tolerance`]): transforms reassociate
//! the reduction, so validation vs [`conv7nl_naive`] uses a ULP-scaled
//! per-element bound. The 1-D transform rows have absolute sums ≤ 2 (Bᵀ),
//! ≤ 1.5 (G) and ≤ 3 (Aᵀ); nesting squares them, so one tile's
//! transform-domain magnitudes grow by at most 4 · 2.25 · 9 = 81 over the
//! plain products. With `R = cI·wF·hF` accumulated products per output
//! (plus a fixed 32-term slack for the 16-point transform sums), the
//! per-element error is bounded by `81 · (R + 32) · ε · max|x| · max|g|`
//! — see DESIGN.md §11.
//!
//! Parallel sweeps fan tile *blocks* out over the shared [`ThreadPool`];
//! a tile's value never depends on any other tile, and blocks scatter to
//! disjoint output regions, so parallel output is bitwise identical to
//! serial.

use std::sync::Arc;
use std::time::Instant;

use crate::conv::{assert_conv_operands, ConvShape, Precision, Tensor4};
use crate::obs::{self, jf, js, ju};
use crate::util::ceil_div;
use crate::util::threadpool::ThreadPool;

use super::exec::{Traffic, TrafficCounters};
use super::gemm::axpy;

/// Bᵀ of F(2,3): 4×4 input transform.
const BT: [[f32; 4]; 4] = [
    [1.0, 0.0, -1.0, 0.0],
    [0.0, 1.0, 1.0, 0.0],
    [0.0, -1.0, 1.0, 0.0],
    [0.0, 1.0, 0.0, -1.0],
];

/// G of F(2,3): 4×3 filter transform.
const G: [[f32; 3]; 4] = [
    [1.0, 0.0, 0.0],
    [0.5, 0.5, 0.5],
    [0.5, -0.5, 0.5],
    [0.0, 0.0, 1.0],
];

/// Aᵀ of F(2,3): 2×4 output transform.
const AT: [[f32; 4]; 2] = [
    [1.0, 1.0, 1.0, 0.0],
    [0.0, 1.0, -1.0, -1.0],
];

/// One unit-stride ≤3-tap sub-convolution: polyphase residue `(rw, rh)`
/// plus chunk offset `(qw, qh)` into the decimated filter, with `cw × ch`
/// real taps (1..=3 each; the rest of the 3×3 tap block is zero-padded).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct SubConv {
    pub rw: u64,
    pub rh: u64,
    pub qw: u64,
    pub qh: u64,
    pub cw: u64,
    pub ch: u64,
}

/// The Winograd execution plan for one layer: the normalized sub-conv
/// list plus an LP-style tile-block size fit to the memory budget (like
/// [`super::plan::TilePlan`], the budget shapes residency, never words).
#[derive(Debug, Clone)]
pub struct WinoPlan {
    pub shape: ConvShape,
    pub precision: Precision,
    pub mem_words: f64,
    pub(crate) subs: Vec<SubConv>,
    /// Tiles processed per resident block (≥ 1).
    pub tile_block: usize,
}

impl WinoPlan {
    pub fn new(shape: &ConvShape, precision: Precision, mem_words: f64) -> WinoPlan {
        let subs = enumerate_subs(shape);
        let tile_block = fit_tile_block(shape, subs.len(), precision, mem_words);
        WinoPlan { shape: *shape, precision, mem_words, subs, tile_block }
    }

    /// 2-wide output tiles along wO.
    pub fn tiles_w(&self) -> u64 {
        ceil_div(self.shape.w_o, 2)
    }

    /// 2-tall output tiles along hO.
    pub fn tiles_h(&self) -> u64 {
        ceil_div(self.shape.h_o, 2)
    }

    /// Total 2×2 tiles across the batch.
    pub fn total_tiles(&self) -> u64 {
        self.shape.n * self.tiles_w() * self.tiles_h()
    }

    /// Number of unit-stride sub-convolutions the layer normalizes to.
    pub fn sub_convs(&self) -> usize {
        self.subs.len()
    }
}

/// Enumerate the (residue, chunk) sub-convolutions in a fixed
/// deterministic order: `rw`, `rh` ascending, then `qw`, `qh` by 3s.
fn enumerate_subs(s: &ConvShape) -> Vec<SubConv> {
    let mut subs = Vec::new();
    for rw in 0..s.s_w.max(1) {
        let fw = ceil_div(s.w_f.saturating_sub(rw), s.s_w.max(1));
        if fw == 0 {
            continue; // residue has no real taps along w
        }
        for rh in 0..s.s_h.max(1) {
            let fh = ceil_div(s.h_f.saturating_sub(rh), s.s_h.max(1));
            if fh == 0 {
                continue;
            }
            let mut qw = 0;
            while qw < fw {
                let cw = (fw - qw).min(3);
                let mut qh = 0;
                while qh < fh {
                    let ch = (fh - qh).min(3);
                    subs.push(SubConv { rw, rh, qw, qh, cw, ch });
                    qh += 3;
                }
                qw += 3;
            }
        }
    }
    subs
}

/// Fit the tile-block size to the memory budget: the pre-transformed
/// filter cache stays resident for the whole sweep; each tile in a block
/// then holds its 2×2 accumulator, its 16-point transform-domain panel
/// row, and the V/d transform scratch.
fn fit_tile_block(s: &ConvShape, n_subs: usize, p: Precision, m: f64) -> usize {
    let co = s.c_o as f64;
    // per-tile resident words: Yacc (4·cO) + M panel (16·cO) at output
    // precision, V + d transform scratch (16 + 16) at input precision
    let per_tile = p.p_o * 20.0 * co + p.p_i * 32.0;
    let ucache = p.p_f * 16.0 * n_subs as f64 * s.c_i as f64 * co;
    let avail = (m - ucache).max(per_tile);
    let bt = (avail / per_tile).floor() as u64;
    let cap = s.n * ceil_div(s.w_o, 2) * ceil_div(s.h_o, 2);
    bt.max(1).min(cap.max(1)) as usize
}

/// `U = G g Gᵀ` for one 3×3 tap block, row-major `[i][j] -> 4i + j`.
fn filter_transform(g: &[[f32; 3]; 3]) -> [f32; 16] {
    // tmp = G g (4×3)
    let mut tmp = [[0.0f32; 3]; 4];
    for (i, gi) in G.iter().enumerate() {
        for j in 0..3 {
            tmp[i][j] = gi[0] * g[0][j] + gi[1] * g[1][j] + gi[2] * g[2][j];
        }
    }
    // U = tmp Gᵀ: U[i][j] = Σ_k tmp[i][k] G[j][k]
    let mut u = [0.0f32; 16];
    for i in 0..4 {
        for (j, gj) in G.iter().enumerate() {
            u[4 * i + j] =
                tmp[i][0] * gj[0] + tmp[i][1] * gj[1] + tmp[i][2] * gj[2];
        }
    }
    u
}

/// `V = Bᵀ d B` for one 4×4 input tile, row-major.
fn input_transform(d: &[f32; 16]) -> [f32; 16] {
    // tmp = Bᵀ d (4×4)
    let mut tmp = [0.0f32; 16];
    for (i, bi) in BT.iter().enumerate() {
        for j in 0..4 {
            let mut acc = 0.0;
            for (a, c) in bi.iter().enumerate() {
                acc += c * d[4 * a + j];
            }
            tmp[4 * i + j] = acc;
        }
    }
    // V = tmp B: V[i][j] = Σ_b tmp[i][b] B[b][j] = Σ_b tmp[i][b] Bᵀ[j][b]
    let mut v = [0.0f32; 16];
    for i in 0..4 {
        for (j, bj) in BT.iter().enumerate() {
            let mut acc = 0.0;
            for (b, c) in bj.iter().enumerate() {
                acc += tmp[4 * i + b] * c;
            }
            v[4 * i + j] = acc;
        }
    }
    v
}

/// `Y = Aᵀ m A` for one 4×4 transform-domain tile, row-major 2×2 out.
fn output_transform(m: &[f32; 16]) -> [f32; 4] {
    // tmp = Aᵀ m (2×4)
    let mut tmp = [0.0f32; 8];
    for (i, ai) in AT.iter().enumerate() {
        for j in 0..4 {
            let mut acc = 0.0;
            for (k, c) in ai.iter().enumerate() {
                acc += c * m[4 * k + j];
            }
            tmp[4 * i + j] = acc;
        }
    }
    let mut y = [0.0f32; 4];
    for i in 0..2 {
        for (j, aj) in AT.iter().enumerate() {
            let mut acc = 0.0;
            for (l, c) in aj.iter().enumerate() {
                acc += tmp[4 * i + l] * c;
            }
            y[2 * i + j] = acc;
        }
    }
    y
}

/// In-range element count of one tile's 4×4 gather — the analytic side of
/// the input charge. Separable, and shared with the executor's gather so
/// measured input words equal the model by construction.
fn gather_in_range(s: &ConvShape, sc: &SubConv, tx: u64, ty: u64) -> u64 {
    let (iw, ih) = (s.in_w(), s.in_h());
    let cols = (0..4u64)
        .filter(|a| s.s_w * (2 * tx + sc.qw + a) + sc.rw < iw)
        .count() as u64;
    let rows = (0..4u64)
        .filter(|b| s.s_h * (2 * ty + sc.qh + b) + sc.rh < ih)
        .count() as u64;
    cols * rows
}

/// Gather one 4×4 decimated+shifted input tile for `(n, ci)`, zero-filling
/// out-of-range positions, returning the in-range word count (the charge).
#[inline]
fn gather_tile(
    x: &Tensor4,
    n: usize,
    ci: usize,
    s: &ConvShape,
    sc: &SubConv,
    tx: u64,
    ty: u64,
    d: &mut [f32; 16],
) -> u64 {
    let (iw, ih) = (s.in_w(), s.in_h());
    // charge by the model's paper-convention bounds; the actual read is
    // additionally guarded by the tensor dims (`assert_conv_operands`
    // admits minimally-sized inputs narrower than `in_w()` — positions
    // past the minimal bound only feed discarded outputs, so zero is
    // exact there)
    let (xw, xh) = (x.dims[2] as u64, x.dims[3] as u64);
    let mut inr = 0u64;
    for a in 0..4u64 {
        let col = s.s_w * (2 * tx + sc.qw + a) + sc.rw;
        for b in 0..4u64 {
            let row = s.s_h * (2 * ty + sc.qh + b) + sc.rh;
            let charge = col < iw && row < ih;
            inr += charge as u64;
            d[(4 * a + b) as usize] = if charge && col < xw && row < xh {
                x.at(n, ci, col as usize, row as usize)
            } else {
                0.0
            };
        }
    }
    inr
}

/// The analytic Winograd traffic model the counters match exactly: it
/// walks the same (sub-conv × tile) grid the executor walks and charges
/// the same words, independent of the tile-block size.
pub fn expected_winograd_traffic(plan: &WinoPlan) -> Traffic {
    let s = &plan.shape;
    let (tw, th) = (plan.tiles_w(), plan.tiles_h());
    let mut gathered = 0u64;
    for sc in &plan.subs {
        for tx in 0..tw {
            for ty in 0..th {
                gathered += gather_in_range(s, sc, tx, ty);
            }
        }
    }
    Traffic {
        input_words: s.n * s.c_i * gathered,
        filter_words: s.filter_size(),
        output_words: s.output_size(),
    }
}

/// Documented ULP-scaled per-element tolerance for winograd-vs-naive
/// validation (see the module docs and DESIGN.md §11 for the derivation
/// of the 81× transform growth and the 32-term transform slack).
pub fn winograd_tolerance(x: &Tensor4, w: &Tensor4, s: &ConvShape) -> f32 {
    let terms = (s.c_i * s.w_f * s.h_f) as f32 + 32.0;
    let amax = x.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let gmax = w.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    81.0 * terms * f32::EPSILON * amax * gmax
}

/// Build the pre-transformed filter cache: `U[sub][ci][k][co]` with the
/// cO axis contiguous so the transform-domain MAC is one [`axpy`] per
/// (ci, k, tile). Reads each real filter tap exactly once -> charges |F|.
fn build_ucache(
    w: &Tensor4,
    plan: &WinoPlan,
    counters: &TrafficCounters,
) -> Vec<f32> {
    let s = &plan.shape;
    let (ci_n, co_n) = (s.c_i as usize, s.c_o as usize);
    let mut cache = vec![0.0f32; plan.subs.len() * ci_n * 16 * co_n];
    for (si, sc) in plan.subs.iter().enumerate() {
        for ci in 0..ci_n {
            for co in 0..co_n {
                let mut g3 = [[0.0f32; 3]; 3];
                for u in 0..sc.cw {
                    let i6 = sc.rw + s.s_w * (sc.qw + u);
                    for v in 0..sc.ch {
                        let i7 = sc.rh + s.s_h * (sc.qh + v);
                        g3[u as usize][v as usize] =
                            w.at(ci, co, i6 as usize, i7 as usize);
                    }
                }
                counters.add_filter(sc.cw * sc.ch);
                let ut = filter_transform(&g3);
                for (k, val) in ut.iter().enumerate() {
                    cache[((si * ci_n + ci) * 16 + k) * co_n + co] = *val;
                }
            }
        }
    }
    cache
}

/// Decode a flat tile index into `(n, tx, ty)`.
#[inline]
fn decode_tile(plan: &WinoPlan, t: u64) -> (usize, u64, u64) {
    let (tw, th) = (plan.tiles_w(), plan.tiles_h());
    let per_n = tw * th;
    let n = t / per_n;
    let rem = t % per_n;
    (n as usize, rem / th, rem % th)
}

/// Compute the 2×2 accumulators for tiles `[t0, t1)` into `yacc`
/// (layout `[tile][co][4]`), charging input words as it gathers.
/// `stage_secs`, when present, accumulates (input+MAC, output) transform
/// wall time for the obs stage events.
fn run_tile_block(
    x: &Tensor4,
    ucache: &[f32],
    plan: &WinoPlan,
    t0: u64,
    t1: u64,
    yacc: &mut [f32],
    mbuf: &mut Vec<f32>,
    counters: &TrafficCounters,
    stage_secs: Option<&mut [f64; 2]>,
) {
    let s = &plan.shape;
    let (ci_n, co_n) = (s.c_i as usize, s.c_o as usize);
    let bt = (t1 - t0) as usize;
    debug_assert_eq!(yacc.len(), bt * co_n * 4);
    yacc.fill(0.0);
    mbuf.clear();
    mbuf.resize(16 * bt * co_n, 0.0);
    let mut d = [0.0f32; 16];
    let mut m4 = [0.0f32; 16];
    let (mut in_secs, mut out_secs) = (0.0f64, 0.0f64);
    let timing = stage_secs.is_some();
    for (si, sc) in plan.subs.iter().enumerate() {
        mbuf.fill(0.0);
        let clock = if timing { Some(Instant::now()) } else { None };
        for ci in 0..ci_n {
            for ti in 0..bt {
                let (n, tx, ty) = decode_tile(plan, t0 + ti as u64);
                let inr = gather_tile(x, n, ci, s, sc, tx, ty, &mut d);
                counters.add_input(inr);
                let v = input_transform(&d);
                for (k, vk) in v.iter().enumerate() {
                    let uo = ((si * ci_n + ci) * 16 + k) * co_n;
                    let mo = (k * bt + ti) * co_n;
                    axpy(
                        &mut mbuf[mo..mo + co_n],
                        &ucache[uo..uo + co_n],
                        *vk,
                    );
                }
            }
        }
        if let Some(c) = clock {
            in_secs += c.elapsed().as_secs_f64();
        }
        let clock = if timing { Some(Instant::now()) } else { None };
        for ti in 0..bt {
            for co in 0..co_n {
                for (k, mk) in m4.iter_mut().enumerate() {
                    *mk = mbuf[(k * bt + ti) * co_n + co];
                }
                let y = output_transform(&m4);
                let yo = (ti * co_n + co) * 4;
                for (j, yj) in y.iter().enumerate() {
                    yacc[yo + j] += *yj;
                }
            }
        }
        if let Some(c) = clock {
            out_secs += c.elapsed().as_secs_f64();
        }
    }
    if let Some(secs) = stage_secs {
        secs[0] += in_secs;
        secs[1] += out_secs;
    }
}

/// Scatter a finished block's valid 2×2 elements into the output tensor,
/// charging exactly the valid (ragged-clipped) words.
fn scatter_block(
    out: &mut Tensor4,
    plan: &WinoPlan,
    t0: u64,
    t1: u64,
    yacc: &[f32],
    counters: &TrafficCounters,
) {
    let s = &plan.shape;
    let co_n = s.c_o as usize;
    for ti in 0..(t1 - t0) as usize {
        let (n, tx, ty) = decode_tile(plan, t0 + ti as u64);
        let vw = (s.w_o - 2 * tx).min(2) as usize;
        let vh = (s.h_o - 2 * ty).min(2) as usize;
        for co in 0..co_n {
            let yo = (ti * co_n + co) * 4;
            for dw in 0..vw {
                for dh in 0..vh {
                    *out.at_mut(
                        n,
                        co,
                        2 * tx as usize + dw,
                        2 * ty as usize + dh,
                    ) = yacc[yo + 2 * dw + dh];
                }
            }
        }
        counters.add_output((vw * vh * co_n) as u64);
    }
}

/// Serial counted Winograd execution with obs span + per-stage events
/// (filter/input/output transform) when tracing is on.
pub fn conv_winograd_counted(
    x: &Tensor4,
    w: &Tensor4,
    plan: &WinoPlan,
    counters: &TrafficCounters,
) -> Tensor4 {
    let s = &plan.shape;
    assert_conv_operands(x, w, s);
    let tracing = obs::enabled();
    let before = if tracing { Some(counters.snapshot()) } else { None };
    let span = if tracing {
        Some(obs::scope(
            obs::kind::WINOGRAD,
            &[
                ("shape", js(&s.to_string())),
                ("sub_convs", ju(plan.subs.len() as u64)),
                ("tile_block", ju(plan.tile_block as u64)),
            ],
        ))
    } else {
        None
    };
    let mut out = Tensor4::zeros([
        s.n as usize,
        s.c_o as usize,
        s.w_o as usize,
        s.h_o as usize,
    ]);
    let clock = if tracing { Some(Instant::now()) } else { None };
    let ucache = build_ucache(w, plan, counters);
    let filter_secs = clock.map(|c| c.elapsed().as_secs_f64()).unwrap_or(0.0);

    let total = plan.total_tiles();
    let bt = plan.tile_block as u64;
    let mut yacc = Vec::new();
    let mut mbuf = Vec::new();
    let mut secs = [0.0f64; 2];
    let mut t0 = 0;
    while t0 < total {
        crate::testkit::faults::exec_point();
        let t1 = (t0 + bt).min(total);
        let need = (t1 - t0) as usize * s.c_o as usize * 4;
        yacc.clear();
        yacc.resize(need, 0.0);
        run_tile_block(
            x,
            &ucache,
            plan,
            t0,
            t1,
            &mut yacc,
            &mut mbuf,
            counters,
            if tracing { Some(&mut secs) } else { None },
        );
        scatter_block(&mut out, plan, t0, t1, &yacc, counters);
        t0 = t1;
    }
    if tracing {
        let m = counters.snapshot();
        let b = before.unwrap();
        for (stage, sec, words) in [
            ("filter_transform", filter_secs, m.filter_words - b.filter_words),
            ("input_transform", secs[0], m.input_words - b.input_words),
            ("output_transform", secs[1], m.output_words - b.output_words),
        ] {
            obs::event(
                obs::kind::WINOGRAD_STAGE,
                &[
                    ("stage", js(stage)),
                    ("secs", jf(sec)),
                    ("words", ju(words)),
                ],
            );
        }
    }
    drop(span);
    out
}

/// Serial Winograd execution without counter plumbing.
pub fn conv_winograd(x: &Tensor4, w: &Tensor4, plan: &WinoPlan) -> Tensor4 {
    conv_winograd_counted(x, w, plan, &TrafficCounters::new())
}

/// Winograd execution fanned out over a [`ThreadPool`]: the filter cache
/// is built once, tile blocks are computed on workers, and finished
/// blocks scatter to disjoint output regions — bitwise identical to
/// [`conv_winograd_counted`].
pub fn conv_winograd_parallel(
    x: &Arc<Tensor4>,
    w: &Arc<Tensor4>,
    plan: &Arc<WinoPlan>,
    pool: &ThreadPool,
    counters: &Arc<TrafficCounters>,
) -> Tensor4 {
    let s = plan.shape;
    assert_conv_operands(x, w, &s);
    let mut out = Tensor4::zeros([
        s.n as usize,
        s.c_o as usize,
        s.w_o as usize,
        s.h_o as usize,
    ]);
    let ucache = Arc::new(build_ucache(w, plan, counters));
    let total = plan.total_tiles();
    let bt = plan.tile_block as u64;
    let mut blocks = Vec::new();
    let mut t0 = 0;
    while t0 < total {
        blocks.push((t0, (t0 + bt).min(total)));
        t0 = (t0 + bt).min(total);
    }
    let (x2, u2, p2, c2) =
        (Arc::clone(x), Arc::clone(&ucache), Arc::clone(plan), Arc::clone(counters));
    let bufs = pool.map(blocks.clone(), move |(b0, b1)| {
        crate::testkit::faults::exec_point();
        let mut yacc = vec![0.0f32; (b1 - b0) as usize * p2.shape.c_o as usize * 4];
        let mut mbuf = Vec::new();
        run_tile_block(&x2, &u2, &p2, b0, b1, &mut yacc, &mut mbuf, &c2, None);
        yacc
    });
    for ((b0, b1), yacc) in blocks.iter().zip(&bufs) {
        scatter_block(&mut out, plan, *b0, *b1, yacc, counters);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::{conv7nl_naive, paper_operands};

    /// The nested F(2×2, 3×3) transform identity: Y equals the direct 2×2
    /// correlation for arbitrary tiles and taps, to float tolerance.
    #[test]
    fn transform_identity_matches_direct_convolution() {
        let d_t = Tensor4::randn([1, 1, 4, 4], 3);
        let g_t = Tensor4::randn([1, 1, 3, 3], 4);
        let mut d = [0.0f32; 16];
        let mut g = [[0.0f32; 3]; 3];
        for i in 0..4 {
            for j in 0..4 {
                d[4 * i + j] = d_t.at(0, 0, i, j);
            }
        }
        for (u, gu) in g.iter_mut().enumerate() {
            for (v, gv) in gu.iter_mut().enumerate() {
                *gv = g_t.at(0, 0, u, v);
            }
        }
        let u = filter_transform(&g);
        let v = input_transform(&d);
        let mut m = [0.0f32; 16];
        for k in 0..16 {
            m[k] = u[k] * v[k];
        }
        let y = output_transform(&m);
        for i in 0..2 {
            for j in 0..2 {
                let mut want = 0.0f32;
                for (u_, gu) in g.iter().enumerate() {
                    for (v_, gv) in gu.iter().enumerate() {
                        want += d[4 * (i + u_) + (j + v_)] * gv;
                    }
                }
                let got = y[2 * i + j];
                assert!(
                    (got - want).abs() < 1e-4,
                    "y[{i}][{j}] = {got} want {want}"
                );
            }
        }
    }

    #[test]
    fn sub_conv_taps_partition_the_filter() {
        // every real tap lands in exactly one (residue, chunk), so the
        // charged filter words are |F| for any (stride, filter) combo
        for (wf, hf, sw, sh) in
            [(3, 3, 1, 1), (5, 5, 1, 1), (5, 4, 2, 3), (7, 7, 2, 2), (1, 1, 1, 1)]
        {
            let s = ConvShape::new(1, 1, 1, 8, 8, wf, hf, sw, sh);
            let subs = enumerate_subs(&s);
            let taps: u64 = subs.iter().map(|sc| sc.cw * sc.ch).sum();
            assert_eq!(taps, wf * hf, "{wf}x{hf}/{sw}x{sh}");
        }
    }

    #[test]
    fn matches_naive_within_tolerance_3x3() {
        let s = ConvShape::new(2, 3, 4, 6, 5, 3, 3, 1, 1);
        let (x, w) = paper_operands(&s, 7);
        let plan = WinoPlan::new(&s, Precision::uniform(), 65536.0);
        assert_eq!(plan.sub_convs(), 1);
        let got = conv_winograd(&x, &w, &plan);
        let want = conv7nl_naive(&x, &w, &s);
        let tol = winograd_tolerance(&x, &w, &s);
        let diff = got.max_abs_diff(&want);
        assert!(diff <= tol, "diff {diff} > tol {tol}");
        assert!(got.rel_l2(&want) < 1e-4);
    }

    #[test]
    fn matches_naive_polyphase_strided_5x5() {
        // stride 2: 4 residues, each ≤3-tap after decimation
        let s = ConvShape::new(2, 3, 4, 5, 6, 5, 5, 2, 2);
        let (x, w) = paper_operands(&s, 11);
        let plan = WinoPlan::new(&s, Precision::uniform(), 65536.0);
        assert!(plan.sub_convs() >= 4);
        let got = conv_winograd(&x, &w, &plan);
        let want = conv7nl_naive(&x, &w, &s);
        let diff = got.max_abs_diff(&want);
        let tol = winograd_tolerance(&x, &w, &s);
        assert!(diff <= tol, "diff {diff} > tol {tol}");
        assert!(got.rel_l2(&want) < 1e-4);
    }

    #[test]
    fn chunked_large_filter_unit_stride() {
        // 5×4 unit-stride filter chunks into 2×2 sub-convs per axis combo
        let s = ConvShape::new(1, 2, 3, 7, 6, 5, 4, 1, 1);
        let (x, w) = paper_operands(&s, 13);
        let plan = WinoPlan::new(&s, Precision::uniform(), 65536.0);
        assert_eq!(plan.sub_convs(), 4); // qw ∈ {0,3}, qh ∈ {0,3}
        let got = conv_winograd(&x, &w, &plan);
        let want = conv7nl_naive(&x, &w, &s);
        let diff = got.max_abs_diff(&want);
        let tol = winograd_tolerance(&x, &w, &s);
        assert!(diff <= tol, "diff {diff} > tol {tol}");
    }

    #[test]
    fn measured_traffic_matches_model_exactly() {
        for (s, m) in [
            (ConvShape::new(2, 3, 4, 6, 6, 3, 3, 1, 1), 4096.0),
            (ConvShape::new(1, 2, 3, 5, 7, 3, 3, 1, 1), 64.0), // bt = 1
            (ConvShape::new(2, 2, 3, 5, 6, 5, 5, 2, 2), 1024.0),
            (ConvShape::new(1, 2, 3, 4, 4, 3, 3, 2, 2), 512.0),
        ] {
            let plan = WinoPlan::new(&s, Precision::uniform(), m);
            let (x, w) = paper_operands(&s, 5);
            let ctr = TrafficCounters::new();
            conv_winograd_counted(&x, &w, &plan, &ctr);
            let e = expected_winograd_traffic(&plan);
            assert_eq!(ctr.snapshot(), e, "{s}");
            assert_eq!(e.filter_words, s.filter_size(), "{s}");
            assert_eq!(e.output_words, s.output_size(), "{s}");
        }
    }

    #[test]
    fn traffic_model_is_blocking_independent() {
        let s = ConvShape::new(2, 3, 4, 9, 7, 3, 3, 1, 1);
        let small = WinoPlan::new(&s, Precision::uniform(), 64.0);
        let large = WinoPlan::new(&s, Precision::uniform(), 1.0e7);
        assert!(small.tile_block < large.tile_block);
        assert_eq!(
            expected_winograd_traffic(&small),
            expected_winograd_traffic(&large)
        );
    }

    #[test]
    fn parallel_is_bitwise_identical_to_serial() {
        let s = ConvShape::new(3, 4, 5, 10, 9, 3, 3, 1, 1);
        let plan = Arc::new(WinoPlan::new(&s, Precision::uniform(), 2048.0));
        let (x, w) = paper_operands(&s, 21);
        let (x, w) = (Arc::new(x), Arc::new(w));
        let serial = conv_winograd(&x, &w, &plan);
        let pool = ThreadPool::new(4);
        let ctr = Arc::new(TrafficCounters::new());
        let par = conv_winograd_parallel(&x, &w, &plan, &pool, &ctr);
        assert_eq!(par.max_abs_diff(&serial), 0.0);
        assert_eq!(ctr.snapshot(), expected_winograd_traffic(&plan));
    }

    #[test]
    fn degenerate_shapes_return_empty_or_zero_output() {
        // zero batch: empty output, nothing charged
        let s = ConvShape::new(0, 3, 4, 5, 5, 3, 3, 1, 1);
        let plan = WinoPlan::new(&s, Precision::uniform(), 1024.0);
        let x = Tensor4::zeros([0, 3, 8, 8]);
        let w = Tensor4::zeros([3, 4, 3, 3]);
        let out = conv_winograd(&x, &w, &plan);
        assert_eq!(out.dims, [0, 4, 5, 5]);
        assert!(out.is_empty());

        // zero input channels: full-size all-zero output, like the oracle
        let s2 = ConvShape::new(2, 0, 4, 5, 5, 3, 3, 1, 1);
        let plan2 = WinoPlan::new(&s2, Precision::uniform(), 1024.0);
        let x2 = Tensor4::zeros([2, 0, 8, 8]);
        let w2 = Tensor4::zeros([0, 4, 3, 3]);
        let ctr = TrafficCounters::new();
        let out2 = conv_winograd_counted(&x2, &w2, &plan2, &ctr);
        assert_eq!(out2.dims, [2, 4, 5, 5]);
        assert!(out2.data.iter().all(|&v| v == 0.0));
        assert_eq!(ctr.snapshot(), expected_winograd_traffic(&plan2));
        assert_eq!(ctr.snapshot().input_words, 0);
    }

    #[test]
    fn tolerance_scales_with_operands_and_reduction_depth() {
        let s = ConvShape::new(1, 8, 2, 4, 4, 3, 3, 1, 1);
        let (x, w) = paper_operands(&s, 2);
        let t = winograd_tolerance(&x, &w, &s);
        assert!(t > 0.0 && t < 1.0, "tolerance {t}");
        // doubling cI roughly doubles the bound's term count
        let s2 = ConvShape { c_i: 16, ..s };
        let (x2, w2) = paper_operands(&s2, 2);
        let t2 = winograd_tolerance(&x2, &w2, &s2);
        assert!(t2 > t, "{t2} vs {t}");
    }
}
