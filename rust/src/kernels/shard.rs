//! Sharded parallel execution across in-process virtual workers — the
//! executing half of the paper's *parallel* communication story
//! (Theorems 2.2/2.3).
//!
//! A [`ShardPlan`] partitions one conv layer or a whole stage chain across
//! `P` virtual nodes under one of three strategies:
//!
//! * **Batch** — each shard owns a contiguous batch slice; activations
//!   never cross shards, only the filter broadcast does.
//! * **Channel** — each shard owns an input-channel slice of the input
//!   and the matching filter rows, and contributes *partial sums* over
//!   the full output. Partials are combined by a traveling accumulator
//!   that visits shards in ascending order, so the f32 additions land in
//!   exactly the order the single-node engine would have issued them
//!   (the accumulation-order contract) — bitwise, not just close.
//! * **Spatial** — each shard owns a contiguous band of output rows plus
//!   the input rows they map onto; before each stage it receives the
//!   `h_f`-row halo (and, when the band layout shifts between stages,
//!   any redistributed rows) from its peers.
//!
//! Every shard runs the existing LP-blocked tiled engine on its sub-shape
//! (a clamped clone of the full-shape [`TilePlan`], so per-element
//! reduction order is untouched), and every word crossing a shard
//! boundary moves through an explicit exchange buffer tallied by
//! [`ShardTrafficCounters`]. The gate: measured exchange words must equal
//! [`ShardPlan::expected_per_shard`] *exactly* — the same
//! measured-vs-analytic contract `TrafficCounters` enforces for memory
//! traffic — while the assembled output stays bitwise identical to the
//! single-node staged engine. Exchange phases rendezvous on
//! [`ShardBarrier`] (no spin-waits), and a panicking shard breaks the
//! barrier so peers fail fast with a typed error instead of hanging.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::conv::{ConvPass, ConvShape, NetworkStage, Tensor4};
use crate::obs::{self, jb, js, ju};
use crate::util::ceil_div;
use crate::util::error::{Error, ErrorKind, Result};
use crate::util::threadpool::{panic_message, ShardBarrier, ThreadPool};

use super::exec::{
    self, conv_tiled_accumulate_counted, conv_tiled_counted, TrafficCounters,
};
use super::plan::{TilePlan, TilePlanCache};
use super::tiles::{self, Blk};

// ---------------- strategies ----------------

/// How a layer/network is partitioned across virtual workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardStrategy {
    Batch,
    Channel,
    Spatial,
}

impl ShardStrategy {
    /// Tie-break order for `auto`: batch first (cheapest to reason
    /// about), then spatial, then channel.
    pub const ALL: [ShardStrategy; 3] =
        [ShardStrategy::Batch, ShardStrategy::Spatial, ShardStrategy::Channel];

    pub fn name(self) -> &'static str {
        match self {
            ShardStrategy::Batch => "batch",
            ShardStrategy::Channel => "channel",
            ShardStrategy::Spatial => "spatial",
        }
    }

    pub fn parse(s: &str) -> Option<ShardStrategy> {
        match s {
            "batch" => Some(ShardStrategy::Batch),
            "channel" => Some(ShardStrategy::Channel),
            "spatial" => Some(ShardStrategy::Spatial),
            _ => None,
        }
    }
}

// ---------------- exchange accounting ----------------

/// Words one shard *received* from peers, by exchange class.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ShardTraffic {
    /// Spatial overlap/redistribution rows of activations.
    pub halo_words: u64,
    /// Broadcast/redistribution of operands a shard doesn't own (the
    /// filter under batch/spatial sharding; next-stage channel slices
    /// under channel sharding).
    pub gather_words: u64,
    /// The traveling partial-sum accumulator under channel sharding.
    pub reduce_words: u64,
}

impl ShardTraffic {
    pub fn total(&self) -> u64 {
        self.halo_words + self.gather_words + self.reduce_words
    }
}

#[derive(Default)]
struct ShardCell {
    halo: AtomicU64,
    gather: AtomicU64,
    reduce: AtomicU64,
}

/// Per-shard atomic tallies of inter-shard exchange words, charged at the
/// copy site by the *receiving* shard (the paper's convention: a
/// processor pays for the words it must fetch).
pub struct ShardTrafficCounters {
    cells: Vec<ShardCell>,
}

impl ShardTrafficCounters {
    pub fn new(workers: usize) -> ShardTrafficCounters {
        ShardTrafficCounters {
            cells: (0..workers.max(1)).map(|_| ShardCell::default()).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    pub fn add_halo(&self, shard: usize, words: u64) {
        self.cells[shard].halo.fetch_add(words, Ordering::Relaxed);
    }

    pub fn add_gather(&self, shard: usize, words: u64) {
        self.cells[shard].gather.fetch_add(words, Ordering::Relaxed);
    }

    pub fn add_reduce(&self, shard: usize, words: u64) {
        self.cells[shard].reduce.fetch_add(words, Ordering::Relaxed);
    }

    pub fn shard(&self, k: usize) -> ShardTraffic {
        let c = &self.cells[k];
        ShardTraffic {
            halo_words: c.halo.load(Ordering::Relaxed),
            gather_words: c.gather.load(Ordering::Relaxed),
            reduce_words: c.reduce.load(Ordering::Relaxed),
        }
    }

    pub fn total(&self) -> ShardTraffic {
        let mut t = ShardTraffic::default();
        for k in 0..self.cells.len() {
            let s = self.shard(k);
            t.halo_words += s.halo_words;
            t.gather_words += s.gather_words;
            t.reduce_words += s.reduce_words;
        }
        t
    }

    pub fn reset(&self) {
        for c in &self.cells {
            c.halo.store(0, Ordering::Relaxed);
            c.gather.store(0, Ordering::Relaxed);
            c.reduce.store(0, Ordering::Relaxed);
        }
    }
}

// ---------------- the plan ----------------

/// A partition of a stage chain across `shards` virtual workers: the
/// full-shape tile plan per stage (the engine every shard's sub-plan is
/// clamped from) plus the per-stage chunk table along the sharded
/// dimension. A single layer is a one-stage chain.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    pub stages: Vec<NetworkStage>,
    pub strategy: ShardStrategy,
    /// Requested worker count `P`; fewer may be active when the sharded
    /// dimension is smaller (idle shards neither send nor receive).
    pub shards: u64,
    /// Full-shape forward plans per stage — shared with the single-node
    /// engine so sharded sub-plans inherit identical blocking.
    pub stage_plans: Vec<Arc<TilePlan>>,
    /// Per stage: the active shards' extents along the sharded dimension
    /// (batch rows, input channels, or output-height rows).
    pub chunks: Vec<Vec<Blk>>,
}

fn even_chunks(dim: u64, shards: u64) -> Vec<Blk> {
    tiles::split(dim.max(1), ceil_div(dim.max(1), shards.max(1)))
}

fn stage_chunks(
    s: &ConvShape,
    plan: &TilePlan,
    strategy: ShardStrategy,
    shards: u64,
) -> Vec<Blk> {
    match strategy {
        ShardStrategy::Batch => even_chunks(s.n, shards),
        ShardStrategy::Spatial => even_chunks(s.h_o, shards),
        ShardStrategy::Channel => {
            // a channel chunk must be a union of consecutive full-plan ci
            // blocks, so the traveling accumulator replays the reduction
            // tiles in exactly the single-node order
            let blocks = tiles::split(plan.ranges[1], plan.blocks[1]);
            even_chunks(blocks.len() as u64, shards)
                .iter()
                .map(|g| {
                    let lo = g.start as usize;
                    let hi = (g.start + g.len) as usize;
                    Blk {
                        start: blocks[lo].start,
                        len: blocks[lo..hi].iter().map(|b| b.len).sum(),
                    }
                })
                .collect()
        }
    }
}

impl ShardPlan {
    pub fn new(
        stages: &[NetworkStage],
        strategy: ShardStrategy,
        shards: u64,
        mem_words: f64,
        cache: &TilePlanCache,
    ) -> ShardPlan {
        assert!(!stages.is_empty(), "empty stage chain");
        assert!(shards >= 1, "need at least one shard");
        let stage_plans: Vec<Arc<TilePlan>> = stages
            .iter()
            .map(|st| {
                cache.plan_pass(ConvPass::Forward, &st.shape, st.precision, mem_words)
            })
            .collect();
        let chunks = stages
            .iter()
            .zip(&stage_plans)
            .map(|(st, sp)| stage_chunks(&st.shape, sp, strategy, shards))
            .collect();
        ShardPlan { stages: stages.to_vec(), strategy, shards, stage_plans, chunks }
    }

    /// Pick the strategy with minimum total analytic exchange volume
    /// (ties resolved in [`ShardStrategy::ALL`] order).
    pub fn auto(
        stages: &[NetworkStage],
        shards: u64,
        mem_words: f64,
        cache: &TilePlanCache,
    ) -> ShardPlan {
        let mut best: Option<(u64, ShardPlan)> = None;
        for strat in ShardStrategy::ALL {
            let p = ShardPlan::new(stages, strat, shards, mem_words, cache);
            let words = p.expected_exchange().total();
            if best.as_ref().map_or(true, |(w, _)| words < *w) {
                best = Some((words, p));
            }
        }
        best.unwrap().1
    }

    /// Active shards at stage `j` (≤ `shards`; the rest idle there).
    pub fn active(&self, j: usize) -> usize {
        self.chunks[j].len()
    }

    /// Virtual workers the executor spawns: the max active count over the
    /// chain (a stage's band layout can need more shards than an earlier
    /// stage's — all of them run every barrier phase).
    pub fn workers(&self) -> usize {
        self.chunks.iter().map(Vec::len).max().unwrap_or(1)
    }

    /// The analytic per-shard exchange triple this plan's execution must
    /// match exactly. Computed purely from the chunk tables by interval
    /// arithmetic — an independent code path from the executor's
    /// copy-site counting, so the measured==expected gate is non-vacuous.
    pub fn expected_per_shard(&self) -> Vec<ShardTraffic> {
        let mut out = vec![ShardTraffic::default(); self.workers()];
        match self.strategy {
            ShardStrategy::Batch => {
                for (j, st) in self.stages.iter().enumerate() {
                    for k in 1..self.chunks[j].len() {
                        out[k].gather_words += st.shape.filter_size();
                    }
                }
            }
            ShardStrategy::Spatial => {
                for j in 0..self.stages.len() {
                    let s = &self.stages[j].shape;
                    let row = s.n * s.c_i * s.in_w();
                    let a = self.chunks[j].len();
                    for (k, c) in self.chunks[j].iter().enumerate() {
                        let need = (s.s_h * c.start, s.s_h * (c.start + c.len) + s.h_f);
                        let have = if j == 0 {
                            // initial placement: the input rows this
                            // shard's band maps onto; the last active
                            // shard also owns the h_f-row tail
                            let tail = if k == a - 1 { s.h_f } else { 0 };
                            Some((s.s_h * c.start, s.s_h * (c.start + c.len) + tail))
                        } else {
                            self.chunks[j - 1].get(k).map(|p| (p.start, p.start + p.len))
                        };
                        let covered = have.map_or(0, |(h0, h1)| {
                            h1.min(need.1).saturating_sub(h0.max(need.0))
                        });
                        out[k].halo_words += row * (need.1 - need.0 - covered);
                    }
                    for k in 1..a {
                        out[k].gather_words += s.filter_size();
                    }
                }
            }
            ShardStrategy::Channel => {
                for j in 0..self.stages.len() {
                    let s = &self.stages[j].shape;
                    let a = self.chunks[j].len();
                    for k in 1..a {
                        out[k].reduce_words += s.output_size();
                    }
                    if j + 1 < self.stages.len() {
                        // the full stage output lives on the ring tail;
                        // everyone else receives its next-stage ci slice
                        let tail = a - 1;
                        let nxt = &self.stages[j + 1].shape;
                        let plane = nxt.n * nxt.in_w() * nxt.in_h();
                        for (k, c) in self.chunks[j + 1].iter().enumerate() {
                            if k != tail {
                                out[k].gather_words += plane * c.len;
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Total analytic exchange volume across all shards and stages.
    pub fn expected_exchange(&self) -> ShardTraffic {
        let mut t = ShardTraffic::default();
        for s in self.expected_per_shard() {
            t.halo_words += s.halo_words;
            t.gather_words += s.gather_words;
            t.reduce_words += s.reduce_words;
        }
        t
    }
}

/// A shard's sub-plan: the full-shape plan with the sharded dimension
/// clamped to one chunk. Only the partitioned dim's range/blocking (and
/// the matching shape field) change, so tile enumeration order and the
/// per-element reduction order are identical to the single-node engine.
fn sub_plan(full: &TilePlan, strategy: ShardStrategy, chunk: Blk) -> TilePlan {
    let mut p = full.clone();
    let d = match strategy {
        ShardStrategy::Batch => {
            p.shape.n = chunk.len;
            0
        }
        ShardStrategy::Channel => {
            p.shape.c_i = chunk.len;
            1
        }
        ShardStrategy::Spatial => {
            p.shape.h_o = chunk.len;
            4
        }
    };
    p.ranges[d] = chunk.len;
    p.blocks[d] = p.blocks[d].min(chunk.len).max(1);
    p
}

// ---------------- tensor slicing ----------------

/// Copy `len` height rows (dim 3) from `src` starting at `src_h0` into
/// `dst` at `dst_h0`; dims 0–2 must match.
fn copy_rows(dst: &mut Tensor4, dst_h0: usize, src: &Tensor4, src_h0: usize, len: usize) {
    debug_assert_eq!(dst.dims[..3], src.dims[..3]);
    let (hd, hs) = (dst.dims[3], src.dims[3]);
    let outer = dst.dims[0] * dst.dims[1] * dst.dims[2];
    for i in 0..outer {
        dst.data[i * hd + dst_h0..i * hd + dst_h0 + len]
            .copy_from_slice(&src.data[i * hs + src_h0..i * hs + src_h0 + len]);
    }
}

/// Extract height rows `[h0, h0+len)` (dim 3) as an owned tensor.
fn height_block(t: &Tensor4, h0: usize, len: usize) -> Tensor4 {
    let mut out = Tensor4::zeros([t.dims[0], t.dims[1], t.dims[2], len]);
    copy_rows(&mut out, 0, t, h0, len);
    out
}

/// Extract channel rows `c` (dim 1) as an owned tensor.
fn channel_block(t: &Tensor4, c: Blk) -> Tensor4 {
    let [d0, d1, d2, d3] = t.dims;
    let (c0, cl) = (c.start as usize, c.len as usize);
    let mut out = Tensor4::zeros([d0, cl, d2, d3]);
    let plane = d2 * d3;
    for a in 0..d0 {
        for b in 0..cl {
            let s0 = (a * d1 + c0 + b) * plane;
            let o0 = (a * cl + b) * plane;
            out.data[o0..o0 + plane].copy_from_slice(&t.data[s0..s0 + plane]);
        }
    }
    out
}

// ---------------- execution ----------------

type RowSlot = Mutex<Option<(u64, Arc<Tensor4>)>>;

/// Run the sharded plan and assemble the full output tensor.
///
/// Healthy runs return a tensor bitwise identical to the single-node
/// staged engine ([`staged_reference`]) with every inter-shard word
/// tallied in `counters` (callers reset them first to gate a single run).
/// A panicking shard — including injected `exec:panic` faults inside a
/// worker's tile loop — breaks the exchange barrier, releases its peers,
/// and surfaces here as one typed [`ErrorKind::WorkerPanicked`] error so
/// callers can degrade to a verified fallback.
pub fn exec_sharded(
    image: &Arc<Tensor4>,
    filters: &[Arc<Tensor4>],
    plan: &Arc<ShardPlan>,
    counters: &Arc<ShardTrafficCounters>,
) -> Result<Tensor4> {
    {
        let frefs: Vec<&Tensor4> = filters.iter().map(|f| f.as_ref()).collect();
        exec::assert_network_operands(image, &frefs, &plan.stages);
    }
    assert!(
        counters.len() >= plan.workers(),
        "counters sized for {} shards, plan needs {}",
        counters.len(),
        plan.workers()
    );
    let t0 = Instant::now();
    let scope = obs::scope(
        obs::kind::SHARD,
        &[
            ("strategy", js(plan.strategy.name())),
            ("shards", ju(plan.shards)),
            ("active", ju(plan.workers() as u64)),
            ("stages", ju(plan.stages.len() as u64)),
        ],
    );
    let out = match plan.strategy {
        ShardStrategy::Channel => run_channel(image, filters, plan, counters),
        ShardStrategy::Batch | ShardStrategy::Spatial => {
            run_workers(image, filters, plan, counters)
        }
    };
    if out.is_ok() && obs::enabled() {
        let exp = plan.expected_per_shard();
        for k in 0..plan.workers() {
            let m = counters.shard(k);
            obs::event(
                obs::kind::SHARD_TRAFFIC,
                &[
                    ("shard", ju(k as u64)),
                    ("strategy", js(plan.strategy.name())),
                    ("halo_words", ju(m.halo_words)),
                    ("gather_words", ju(m.gather_words)),
                    ("reduce_words", ju(m.reduce_words)),
                    ("exp_halo_words", ju(exp[k].halo_words)),
                    ("exp_gather_words", ju(exp[k].gather_words)),
                    ("exp_reduce_words", ju(exp[k].reduce_words)),
                    ("exchange_ok", jb(m == exp[k])),
                ],
            );
        }
        obs::event(obs::kind::LOG, &[
            ("level", js("debug")),
            ("msg", js(&format!(
                "shard exec {} x{} done in {:.3}s",
                plan.strategy.name(), plan.workers(), t0.elapsed().as_secs_f64()
            ))),
        ]);
    }
    drop(scope);
    out
}

/// The exchange gate: every shard's measured words must equal the
/// analytic triple exactly, and shards beyond the active set must have
/// moved nothing.
pub fn verify_exchange(plan: &ShardPlan, counters: &ShardTrafficCounters) -> Result<()> {
    let exp = plan.expected_per_shard();
    for k in 0..counters.len() {
        let m = counters.shard(k);
        let e = exp.get(k).copied().unwrap_or_default();
        if m != e {
            return Err(Error::msg(format!(
                "shard {k} ({} over {} workers): measured exchange {m:?} != analytic {e:?}",
                plan.strategy.name(),
                plan.workers(),
            )));
        }
    }
    Ok(())
}

/// The single-node comparator: the same per-stage full-shape plans run
/// serially — bitwise identical to both the parallel staged engine and
/// (the contract under test) any healthy sharded run.
pub fn staged_reference(image: &Tensor4, filters: &[&Tensor4], plan: &ShardPlan) -> Tensor4 {
    let mem = TrafficCounters::new();
    let mut x = image.clone();
    for (j, sp) in plan.stage_plans.iter().enumerate() {
        x = conv_tiled_counted(&x, filters[j], sp, &mem);
    }
    x
}

/// Batch/spatial execution: `workers()` virtual nodes on a dedicated
/// pool, one BSP super-step per stage (publish → barrier → assemble →
/// barrier → compute).
fn run_workers(
    image: &Arc<Tensor4>,
    filters: &[Arc<Tensor4>],
    plan: &Arc<ShardPlan>,
    counters: &Arc<ShardTrafficCounters>,
) -> Result<Tensor4> {
    let w = plan.workers();
    // a dedicated pool: barrier-blocked shards park on a condvar, and a
    // shared pool's free workers are never consumed by a blocked phase
    let pool = ThreadPool::new(w);
    let barrier = Arc::new(ShardBarrier::new(w));
    let slots: Arc<Vec<RowSlot>> = Arc::new((0..w).map(|_| Mutex::new(None)).collect());
    let mem = Arc::new(TrafficCounters::new());
    let (img, pl, ct) = (Arc::clone(image), Arc::clone(plan), Arc::clone(counters));
    let fls: Vec<Arc<Tensor4>> = filters.to_vec();
    let results = pool.run_batch((0..w).collect::<Vec<usize>>(), move |k| {
        let guard = barrier.guard();
        let r = match pl.strategy {
            ShardStrategy::Batch => worker_batch(k, &img, &fls, &pl, &ct, &mem),
            ShardStrategy::Spatial => {
                worker_spatial(k, &img, &fls, &pl, &ct, &barrier, &slots, &mem)
            }
            ShardStrategy::Channel => unreachable!("channel runs on the ring path"),
        };
        if r.is_ok() {
            guard.complete();
        }
        r
    });
    let last = &plan.stages[plan.stages.len() - 1].shape;
    let mut out = Tensor4::zeros(exec::out_dims(last));
    for r in results {
        match r {
            Ok(Ok(Some((chunk, piece)))) => match plan.strategy {
                ShardStrategy::Batch => exec::scatter_batch_block(&mut out, chunk, &piece),
                ShardStrategy::Spatial => {
                    copy_rows(&mut out, chunk.start as usize, &piece, 0, chunk.len as usize)
                }
                ShardStrategy::Channel => unreachable!(),
            },
            Ok(Ok(None)) => {} // idle shard
            Ok(Err(e)) | Err(e) => return Err(e),
        }
    }
    Ok(out)
}

/// Batch shard: compute every stage on the owned batch slice; the only
/// exchange is the per-stage filter broadcast (shard 0 owns the filters).
fn worker_batch(
    k: usize,
    image: &Arc<Tensor4>,
    filters: &[Arc<Tensor4>],
    plan: &ShardPlan,
    counters: &ShardTrafficCounters,
    mem: &TrafficCounters,
) -> Result<Option<(Blk, Tensor4)>> {
    if k >= plan.chunks[0].len() {
        return Ok(None);
    }
    let chunk = plan.chunks[0][k];
    let mut x = exec::batch_block(image, chunk);
    for (j, st) in plan.stages.iter().enumerate() {
        debug_assert_eq!(plan.chunks[j].len(), plan.chunks[0].len());
        if k >= 1 {
            counters.add_gather(k, st.shape.filter_size());
        }
        let sub = sub_plan(&plan.stage_plans[j], ShardStrategy::Batch, chunk);
        x = conv_tiled_counted(&x, &filters[j], &sub, mem);
    }
    Ok(Some((chunk, x)))
}

/// Spatial shard: per stage, publish the rows this worker holds, gather
/// the band it needs (halo + any redistribution counted at the copy
/// site), then run the tiled engine on the band.
#[allow(clippy::too_many_arguments)]
fn worker_spatial(
    k: usize,
    image: &Arc<Tensor4>,
    filters: &[Arc<Tensor4>],
    plan: &ShardPlan,
    counters: &ShardTrafficCounters,
    barrier: &Arc<ShardBarrier>,
    slots: &[RowSlot],
    mem: &TrafficCounters,
) -> Result<Option<(Blk, Tensor4)>> {
    // rows this worker holds, in the current stage's input-row coordinates
    let mut have: Option<(u64, Tensor4)> = {
        let s = &plan.stages[0].shape;
        let a0 = plan.chunks[0].len();
        plan.chunks[0].get(k).map(|c| {
            let h0 = s.s_h * c.start;
            let tail = if k == a0 - 1 { s.h_f } else { 0 };
            let len = s.s_h * c.len + tail;
            (h0, height_block(image, h0 as usize, len as usize))
        })
    };
    for j in 0..plan.stages.len() {
        let s = plan.stages[j].shape;
        let a = plan.chunks[j].len();
        *slots[k].lock().unwrap() = have.take().map(|(h0, t)| (h0, Arc::new(t)));
        barrier.wait()?;
        let mine = match plan.chunks[j].get(k) {
            Some(c) => {
                let need0 = s.s_h * c.start;
                let need_len = s.s_h * c.len + s.h_f;
                Some(assemble_rows(k, need0, need_len, slots, counters)?)
            }
            None => None,
        };
        if k >= 1 && k < a {
            counters.add_gather(k, s.filter_size());
        }
        barrier.wait()?;
        have = match mine {
            Some(x) => {
                let c = plan.chunks[j][k];
                let sub = sub_plan(&plan.stage_plans[j], ShardStrategy::Spatial, c);
                Some((c.start, conv_tiled_counted(&x, &filters[j], &sub, mem)))
            }
            None => None,
        };
    }
    Ok(have.map(|(h0, t)| (Blk { start: h0, len: t.dims[3] as u64 }, t)))
}

/// Build the row band `[need0, need0+need_len)` from the published slots,
/// charging `halo` words for every row that did not come from this
/// worker's own slot.
fn assemble_rows(
    k: usize,
    need0: u64,
    need_len: u64,
    slots: &[RowSlot],
    counters: &ShardTrafficCounters,
) -> Result<Tensor4> {
    let own: Option<(u64, Arc<Tensor4>)> = slots[k].lock().unwrap().clone();
    let own_iv = own.as_ref().map(|(h0, t)| (*h0, h0 + t.dims[3] as u64));
    // all publishers share the leading dims
    let proto = own.as_ref().map(|(_, t)| Arc::clone(t)).or_else(|| {
        slots.iter().find_map(|s| s.lock().unwrap().as_ref().map(|(_, t)| Arc::clone(t)))
    });
    let Some(proto) = proto else {
        return Err(Error::msg("no shard published any rows"));
    };
    let [d0, d1, d2, _] = proto.dims;
    let row_words = (d0 * d1 * d2) as u64;
    let mut out = Tensor4::zeros([d0, d1, d2, need_len as usize]);
    let end = need0 + need_len;
    let mut r = need0;
    while r < end {
        let use_own = own_iv.map_or(false, |(h0, h1)| r >= h0 && r < h1);
        let (src_h0, src) = if use_own {
            let (h0, t) = own.as_ref().unwrap();
            (*h0, Arc::clone(t))
        } else {
            let found = slots.iter().find_map(|s| {
                let g = s.lock().unwrap();
                g.as_ref().and_then(|(h0, t)| {
                    (r >= *h0 && r < h0 + t.dims[3] as u64)
                        .then(|| (*h0, Arc::clone(t)))
                })
            });
            found.ok_or_else(|| {
                Error::msg(format!("row {r} not published by any shard"))
            })?
        };
        let mut run_end = end.min(src_h0 + src.dims[3] as u64);
        if use_own {
            run_end = run_end.min(own_iv.unwrap().1);
        } else if let Some((h0, _)) = own_iv {
            if h0 > r {
                // stop at our own rows so they aren't charged as received
                run_end = run_end.min(h0);
            }
        }
        let len = (run_end - r) as usize;
        copy_rows(&mut out, (r - need0) as usize, &src, (r - src_h0) as usize, len);
        if !use_own {
            counters.add_halo(k, len as u64 * row_words);
        }
        r = run_end;
    }
    Ok(out)
}

/// Channel execution: a sequential traveling-accumulator ring. Shard 0
/// computes its partial into a fresh accumulator; each later shard
/// receives it (counted as `reduce` words) and adds its own input-channel
/// group's contributions *in the single-node reduction order* via
/// [`conv_tiled_accumulate_counted`] — association-preserving, so the
/// final output is bitwise. Between stages the full activation lives on
/// the ring tail and every other shard receives its next channel slice.
fn run_channel(
    image: &Arc<Tensor4>,
    filters: &[Arc<Tensor4>],
    plan: &Arc<ShardPlan>,
    counters: &Arc<ShardTrafficCounters>,
) -> Result<Tensor4> {
    let r = catch_unwind(AssertUnwindSafe(|| -> Tensor4 {
        let mem = TrafficCounters::new();
        let mut x_slices: Vec<Tensor4> =
            plan.chunks[0].iter().map(|c| channel_block(image, *c)).collect();
        let mut out = Tensor4::zeros([0; 4]);
        for j in 0..plan.stages.len() {
            let s = &plan.stages[j].shape;
            let a = plan.chunks[j].len();
            let mut acc: Option<Tensor4> = None;
            for k in 0..a {
                let c = plan.chunks[j][k];
                // the filter's ci rows are dim 0 — the batch slicer fits
                let f = exec::batch_block(&filters[j], c);
                let sub = sub_plan(&plan.stage_plans[j], ShardStrategy::Channel, c);
                match acc.take() {
                    None => acc = Some(conv_tiled_counted(&x_slices[k], &f, &sub, &mem)),
                    Some(mut partial) => {
                        counters.add_reduce(k, s.output_size());
                        conv_tiled_accumulate_counted(
                            &x_slices[k], &f, &sub, &mut partial, &mem,
                        );
                        acc = Some(partial);
                    }
                }
            }
            let stage_out = acc.expect("at least one active shard");
            if j + 1 < plan.stages.len() {
                let tail = a - 1;
                let plane =
                    (stage_out.dims[0] * stage_out.dims[2] * stage_out.dims[3]) as u64;
                x_slices = plan.chunks[j + 1]
                    .iter()
                    .enumerate()
                    .map(|(k, c)| {
                        if k != tail {
                            counters.add_gather(k, plane * c.len);
                        }
                        channel_block(&stage_out, *c)
                    })
                    .collect();
            } else {
                out = stage_out;
            }
        }
        out
    }));
    r.map_err(|p| {
        Error::typed(
            ErrorKind::WorkerPanicked,
            format!("worker panicked: {}", panic_message(p.as_ref())),
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::commvol::par as cpar;
    use crate::conv::Precision;
    use crate::kernels::plan::DEFAULT_TILE_MEM_WORDS;

    fn layer(shape: ConvShape) -> Vec<NetworkStage> {
        vec![NetworkStage { shape, precision: Precision::uniform() }]
    }

    /// A 2-stage chain with valid shape chaining (stage 1 input dims ==
    /// stage 0 output dims: [2, 3, 6, 6]).
    fn tiny_net() -> Vec<NetworkStage> {
        let s0 = ConvShape::new(2, 2, 3, 6, 6, 3, 3, 1, 1);
        let s1 = ConvShape::new(2, 3, 2, 3, 3, 3, 3, 1, 1);
        assert_eq!([s0.c_o, s0.w_o, s0.h_o], [s1.c_i, s1.in_w(), s1.in_h()]);
        vec![
            NetworkStage { shape: s0, precision: Precision::uniform() },
            NetworkStage { shape: s1, precision: Precision::uniform() },
        ]
    }

    fn operands(stages: &[NetworkStage]) -> (Arc<Tensor4>, Vec<Arc<Tensor4>>) {
        let s0 = &stages[0].shape;
        let image = Arc::new(Tensor4::randn(
            [
                s0.n as usize,
                s0.c_i as usize,
                s0.in_w() as usize,
                s0.in_h() as usize,
            ],
            1,
        ));
        let filters = stages
            .iter()
            .enumerate()
            .map(|(i, st)| {
                Arc::new(Tensor4::randn(st.shape.filter_dims(), 2 + i as u64))
            })
            .collect();
        (image, filters)
    }

    fn check_strategy(stages: &[NetworkStage], strategy: ShardStrategy, shards: u64) {
        let cache = TilePlanCache::new();
        let plan = Arc::new(ShardPlan::new(
            stages, strategy, shards, DEFAULT_TILE_MEM_WORDS, &cache,
        ));
        let (image, filters) = operands(stages);
        let counters = Arc::new(ShardTrafficCounters::new(plan.workers()));
        let got = exec_sharded(&image, &filters, &plan, &counters).unwrap();
        let frefs: Vec<&Tensor4> = filters.iter().map(|f| f.as_ref()).collect();
        let want = staged_reference(&image, &frefs, &plan);
        assert_eq!(
            got.max_abs_diff(&want),
            0.0,
            "{} P={shards}: sharded output not bitwise",
            strategy.name()
        );
        verify_exchange(&plan, &counters).unwrap();
    }

    #[test]
    fn all_strategies_bitwise_and_exact_on_a_layer() {
        let s = ConvShape::new(4, 3, 2, 5, 5, 3, 3, 1, 1);
        for strat in ShardStrategy::ALL {
            for shards in [1u64, 2, 4, 8] {
                check_strategy(&layer(s), strat, shards);
            }
        }
    }

    #[test]
    fn all_strategies_bitwise_and_exact_on_a_network() {
        for strat in ShardStrategy::ALL {
            for shards in [1u64, 2, 3, 4] {
                check_strategy(&tiny_net(), strat, shards);
            }
        }
    }

    #[test]
    fn strided_spatial_shards_bitwise() {
        let s = ConvShape::new(2, 2, 2, 4, 6, 3, 3, 2, 2);
        for shards in [2u64, 3, 4] {
            check_strategy(&layer(s), ShardStrategy::Spatial, shards);
        }
    }

    #[test]
    fn single_shard_is_the_unsharded_engine_with_zero_exchange() {
        let s = ConvShape::new(3, 2, 2, 4, 4, 3, 3, 1, 1);
        for strat in ShardStrategy::ALL {
            let cache = TilePlanCache::new();
            let plan = Arc::new(ShardPlan::new(
                &layer(s), strat, 1, DEFAULT_TILE_MEM_WORDS, &cache,
            ));
            assert_eq!(plan.workers(), 1);
            let (image, filters) = operands(&layer(s));
            let counters = Arc::new(ShardTrafficCounters::new(1));
            let got = exec_sharded(&image, &filters, &plan, &counters).unwrap();
            let full = conv_tiled_counted(
                &image,
                &filters[0],
                &plan.stage_plans[0],
                &TrafficCounters::new(),
            );
            assert_eq!(got.max_abs_diff(&full), 0.0);
            assert_eq!(counters.total(), ShardTraffic::default());
        }
    }

    #[test]
    fn more_shards_than_batch_leaves_idle_shards_silent() {
        // P=8 over n=3: only 3 shards active, 5 idle with zero exchange
        let s = ConvShape::new(3, 2, 2, 4, 4, 3, 3, 1, 1);
        let cache = TilePlanCache::new();
        let plan = Arc::new(ShardPlan::new(
            &layer(s), ShardStrategy::Batch, 8, DEFAULT_TILE_MEM_WORDS, &cache,
        ));
        assert_eq!(plan.workers(), 3);
        check_strategy(&layer(s), ShardStrategy::Batch, 8);
    }

    #[test]
    fn ragged_chunks_cover_the_dim_exactly() {
        // 5 output rows over 2 shards -> 3 + 2 (ragged tail)
        let s = ConvShape::new(2, 2, 2, 5, 5, 3, 3, 1, 1);
        let cache = TilePlanCache::new();
        let plan = ShardPlan::new(
            &layer(s), ShardStrategy::Spatial, 2, DEFAULT_TILE_MEM_WORDS, &cache,
        );
        let lens: Vec<u64> = plan.chunks[0].iter().map(|c| c.len).collect();
        assert_eq!(lens.iter().sum::<u64>(), 5);
        assert_eq!(lens, vec![3, 2]);
        check_strategy(&layer(s), ShardStrategy::Spatial, 2);
        check_strategy(&layer(s), ShardStrategy::Channel, 2);
        check_strategy(&layer(s), ShardStrategy::Batch, 2);
    }

    #[test]
    fn single_layer_expected_matches_commvol_formulas() {
        let s = ConvShape::new(4, 3, 2, 5, 5, 3, 3, 1, 1);
        let cache = TilePlanCache::new();
        for shards in [1u64, 2, 4, 8] {
            for strat in ShardStrategy::ALL {
                let plan = ShardPlan::new(
                    &layer(s), strat, shards, DEFAULT_TILE_MEM_WORDS, &cache,
                );
                let active = plan.active(0) as u64;
                let total = plan.expected_exchange();
                match strat {
                    ShardStrategy::Batch => {
                        assert_eq!(total.total(), cpar::batch_shard_words(&s, active))
                    }
                    ShardStrategy::Channel => {
                        assert_eq!(total.total(), cpar::channel_shard_words(&s, active))
                    }
                    ShardStrategy::Spatial => {
                        assert_eq!(total.halo_words, cpar::spatial_halo_words(&s, active));
                        assert_eq!(total.total(), cpar::spatial_shard_words(&s, active));
                    }
                }
            }
        }
    }

    #[test]
    fn auto_picks_the_minimum_volume_strategy() {
        let s = ConvShape::new(4, 3, 2, 5, 5, 3, 3, 1, 1);
        let cache = TilePlanCache::new();
        let auto = ShardPlan::auto(&layer(s), 4, DEFAULT_TILE_MEM_WORDS, &cache);
        let best = ShardStrategy::ALL
            .iter()
            .map(|&st| {
                ShardPlan::new(&layer(s), st, 4, DEFAULT_TILE_MEM_WORDS, &cache)
                    .expected_exchange()
                    .total()
            })
            .min()
            .unwrap();
        assert_eq!(auto.expected_exchange().total(), best);
    }

    // NOTE: fault-injected shard panics are covered by the serialized
    // integration tests in `tests/faults_e2e.rs` (arming faults is
    // process-global and would perturb concurrent in-lib tests).
}
