//! Figure/table emitters: aligned-text tables for the CLI and the
//! benches, matching the rows/series of the paper's figures.

pub mod figures;

pub use figures::{
    default_mem_sweep, default_proc_sweep, fig2_series, fig3_series, fig4_rows,
    fig4_table, ratio_table, Fig4Row,
};

use std::fmt::Write as _;

/// A simple aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |out: &mut String, cells: &[String]| {
            for i in 0..ncol {
                let pad = widths[i];
                if i + 1 == ncol {
                    let _ = writeln!(out, "{:<pad$}", cells[i]);
                } else {
                    let _ = write!(out, "{:<pad$}  ", cells[i]);
                }
            }
        };
        fmt_row(&mut out, &self.header);
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            fmt_row(&mut out, row);
        }
        out
    }
}

/// Format a float compactly for table cells (3 significant-ish digits).
pub fn fmt_f(v: f64) -> String {
    if !v.is_finite() {
        return format!("{v}");
    }
    let a = v.abs();
    if a == 0.0 {
        "0".to_string()
    } else if a >= 1e6 || a < 1e-3 {
        format!("{v:.2e}")
    } else if a >= 100.0 {
        format!("{v:.0}")
    } else {
        format!("{v:.2}")
    }
}

/// Format a ratio as `1.73x`.
pub fn fmt_x(v: f64) -> String {
    format!("{v:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "2.5".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[2].starts_with("a"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn float_formats() {
        assert_eq!(fmt_f(0.0), "0");
        assert_eq!(fmt_f(3.14159), "3.14");
        assert_eq!(fmt_f(12345.0), "12345");
        assert_eq!(fmt_f(1.23e8), "1.23e8");
        assert_eq!(fmt_x(1.732), "1.73x");
    }
}
