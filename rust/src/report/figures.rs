//! The paper's figures as data: each function returns the exact series the
//! corresponding figure plots, consumed by the CLI, the benches and
//! EXPERIMENTS.md generation.

use crate::commvol::{parallel_volumes, sequential_volumes};
use crate::conv::{resnet50_layers, ConvShape, Precision};
use crate::gemmini::{simulate_layer, GemminiConfig, SimResult};
use crate::tiling::{optimize_gemmini_tiling, vendor_tiling, GemminiTile, OptOptions};

use super::{fmt_f, fmt_x, Table};

/// Figure 2: sequential comm volumes relative to the bound vs memory size,
/// for one layer. Returns (M, [ratios per algorithm]) rows.
pub fn fig2_series(
    shape: &ConvShape,
    p: Precision,
    mem_sizes: &[f64],
) -> Vec<(f64, [(&'static str, f64); 5])> {
    mem_sizes
        .iter()
        .map(|&m| (m, sequential_volumes(shape, p, m).ratios()))
        .collect()
}

/// Figure 3: parallel comm volumes relative to the bound vs processors.
pub fn fig3_series(
    shape: &ConvShape,
    p: Precision,
    procs: &[u64],
    m: f64,
) -> Vec<(u64, [(&'static str, f64); 5])> {
    procs
        .iter()
        .map(|&pp| (pp, parallel_volumes(shape, p, pp, m).ratios()))
        .collect()
}

/// One Figure-4 row: a layer simulated under our tiling and the vendor's.
#[derive(Debug, Clone)]
pub struct Fig4Row {
    pub name: String,
    pub ours_tile: GemminiTile,
    pub vendor_tile: GemminiTile,
    pub ours: SimResult,
    pub vendor: SimResult,
}

impl Fig4Row {
    pub fn comm_ratio(&self) -> f64 {
        self.ours.comm_rows as f64 / self.vendor.comm_rows as f64
    }

    pub fn cycle_ratio(&self) -> f64 {
        self.ours.cycles as f64 / self.vendor.cycles as f64
    }
}

/// Figure 4: all five ResNet-50 layers at batch `n` on the GEMMINI
/// simulator, ours vs vendor. `conv5_fix` applies the §5 extra constraint
/// (don't tile the 7×7 image) to layers whose image is that small.
pub fn fig4_rows(n: u64, cfg: &GemminiConfig, conv5_fix: bool) -> Vec<Fig4Row> {
    resnet50_layers(n)
        .into_iter()
        .map(|l| {
            let opts = if conv5_fix {
                OptOptions { no_spatial_tiling_upto: Some(7), ..Default::default() }
            } else {
                OptOptions::default()
            };
            let ours_tile = optimize_gemmini_tiling(&l.shape, cfg, opts);
            let vendor_tile = vendor_tiling(&l.shape, cfg);
            Fig4Row {
                name: l.name.to_string(),
                ours_tile,
                vendor_tile,
                ours: simulate_layer(&l.shape, cfg, &ours_tile),
                vendor: simulate_layer(&l.shape, cfg, &vendor_tile),
            }
        })
        .collect()
}

/// Render Figure 4 as a table.
pub fn fig4_table(rows: &[Fig4Row]) -> Table {
    let mut t = Table::new(&[
        "layer", "ours cycles", "vendor cycles", "cycle ratio",
        "ours comm(rows)", "vendor comm(rows)", "comm ratio", "vendor spad util",
    ]);
    for r in rows {
        t.row(vec![
            r.name.clone(),
            fmt_f(r.ours.cycles as f64),
            fmt_f(r.vendor.cycles as f64),
            fmt_x(r.cycle_ratio()),
            fmt_f(r.ours.comm_rows as f64),
            fmt_f(r.vendor.comm_rows as f64),
            fmt_x(r.comm_ratio()),
            format!("{:.0}%", r.vendor.spad_utilization * 100.0),
        ]);
    }
    t
}

/// Render a Figure-2/3 series as a table.
///
/// Column headers carry the provenance of each algorithm's numbers:
/// `*` marks algorithms that also *execute* in-tree with measured ==
/// analytic traffic asserted (blocking via the `kernels/` tiled engine,
/// winograd via the F(2,3) path; naive and im2col execute but charge
/// compulsory traffic only), so a starred column's analytic curve is
/// counter-validated, while `fft` remains model-only.
pub fn ratio_table<X: std::fmt::Display>(
    xlabel: &str,
    rows: &[(X, [(&'static str, f64); 5])],
) -> Table {
    let mut t = Table::new(&[
        xlabel,
        "naive",
        "im2col",
        "blocking*",
        "winograd*",
        "fft (model)",
    ]);
    for (x, ratios) in rows {
        let mut cells = vec![format!("{x}")];
        cells.extend(ratios.iter().map(|(_, r)| fmt_x(*r)));
        t.row(cells);
    }
    t
}

/// Default Figure-2 memory sweep (words): 2^10 … 2^24.
pub fn default_mem_sweep() -> Vec<f64> {
    (10..=24).map(|e| (1u64 << e) as f64).collect()
}

/// Default Figure-3 processor sweep: 2^1 … 2^14.
pub fn default_proc_sweep() -> Vec<u64> {
    (1..=14).map(|e| 1u64 << e).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_series_shape() {
        let l = resnet50_layers(100)[1];
        let rows = fig2_series(&l.shape, Precision::paper_mixed(), &[4096.0, 65536.0]);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].0, 4096.0);
        for (_, ratios) in &rows {
            assert_eq!(ratios.len(), 5);
        }
    }

    #[test]
    fn fig4_rows_cover_five_layers() {
        let cfg = GemminiConfig::default();
        let rows = fig4_rows(8, &cfg, false);
        assert_eq!(rows.len(), 5);
        let names: Vec<&str> = rows.iter().map(|r| r.name.as_str()).collect();
        assert_eq!(names, ["conv1", "conv2_x", "conv3_x", "conv4_x", "conv5_x"]);
        // the paper objective wins on average (individual layers may regress
        // — that is the paper's own conv5 observation)
        let geo = crate::util::stats::geomean(
            &rows.iter().map(|r| r.comm_ratio()).collect::<Vec<_>>(),
        );
        assert!(geo < 1.0, "geomean comm ratio {geo}");
    }

    #[test]
    fn tables_render() {
        let cfg = GemminiConfig::default();
        let rows = fig4_rows(4, &cfg, true);
        let s = fig4_table(&rows).render();
        assert!(s.contains("conv1"));
        let l = resnet50_layers(10)[1];
        let f2 = fig2_series(&l.shape, Precision::uniform(), &[65536.0]);
        let s2 = ratio_table("M", &f2).render();
        assert!(s2.contains("blocking"));
    }
}
