//! Sequential (single-processor) communication volumes — Figure 2.
//!
//! Per-algorithm models (all include the compulsory p_O|O| output write):
//!
//! * **naive** — output-stationary scalar loop with no cache reuse beyond
//!   one register: every MAC loads its input and filter word:
//!   `(p_I + p_F)·G + p_O·|O|`.
//! * **im2col** — read the input once, materialize the patch matrix
//!   (`G/cO` elements, written then re-read at input precision), then a
//!   communication-optimal matmul `(N·wO·hO) × (cI·wF·hF) × cO` [12].
//! * **blocking** — the paper's LP tiling (§3.2): `G/U` tile steps, each
//!   loading one input+filter+output block (the blocks' true footprint).
//! * **Winograd** — F(2×2, r) tiles (strided layers are first polyphase-
//!   decomposed into σw·σh unit-stride sub-convolutions): input/output
//!   transforms touch their arrays a constant number of times, and the
//!   `t²` per-point channel matmuls are charged the [12] volume.
//! * **FFT** — full-image transforms: `N·cI` forward FFTs, `cI·cO` filter
//!   FFTs, per-frequency channel matmuls, `N·cO` inverse FFTs, with the
//!   [7] FFT volume and complex-word doubling.

use crate::bounds::sequential_bound;
use crate::conv::{ConvShape, Precision};
use crate::tiling::sequential_blocking;
use crate::util::ceil_div;

use super::{fft_seq, matmul_seq, pbar};

/// All Figure-2 series at one memory size.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SeqVolumes {
    pub m: f64,
    pub bound: f64,
    pub naive: f64,
    pub im2col: f64,
    pub blocking: f64,
    pub winograd: f64,
    pub fft: f64,
}

impl SeqVolumes {
    /// Ratios to the lower bound, in the figure's plotting order.
    pub fn ratios(&self) -> [(&'static str, f64); 5] {
        [
            ("naive", self.naive / self.bound),
            ("im2col", self.im2col / self.bound),
            ("blocking", self.blocking / self.bound),
            ("winograd", self.winograd / self.bound),
            ("fft", self.fft / self.bound),
        ]
    }
}

pub fn naive_volume(s: &ConvShape, p: Precision) -> f64 {
    (p.p_i + p.p_f) * s.updates() as f64 + p.p_o * s.output_size() as f64
}

pub fn im2col_volume(s: &ConvShape, p: Precision, m: f64) -> f64 {
    let g = s.updates() as f64;
    let patch = g / s.c_o as f64; // (N·wO·hO) × (cI·wF·hF)
    let mm = matmul_seq(
        (s.n * s.w_o * s.h_o) as f64,
        (s.c_i * s.w_f * s.h_f) as f64,
        s.c_o as f64,
        pbar(p),
        m,
    );
    p.p_i * (s.input_size() as f64 + 2.0 * patch) + mm
        + p.p_o * s.output_size() as f64
}

pub fn blocking_volume(s: &ConvShape, p: Precision, m: f64) -> f64 {
    let b = sequential_blocking(s, p, m);
    let tiles = s.updates() as f64 / b.updates_per_tile();
    tiles * b.footprint_words(p) + p.p_o * s.output_size() as f64
}

/// Winograd F(2×2, r×r) with polyphase decomposition for strided layers.
pub fn winograd_volume(s: &ConvShape, p: Precision, m: f64) -> f64 {
    let mut total = 0.0;
    // polyphase: σw·σh sub-convolutions with decimated images and filters
    for rw in 0..s.s_w {
        for rh in 0..s.s_h {
            let wf = ceil_div(s.w_f.saturating_sub(rw), s.s_w).max(1);
            let hf = ceil_div(s.h_f.saturating_sub(rh), s.s_h).max(1);
            let sub = ConvShape {
                w_f: wf,
                h_f: hf,
                s_w: 1,
                s_h: 1,
                ..*s
            };
            total += winograd_unit_stride(&sub, p, m);
        }
    }
    total
}

fn winograd_unit_stride(s: &ConvShape, p: Precision, m: f64) -> f64 {
    let mw = 2.0_f64; // F(2×2, r): output tile side
    let tw = mw + s.w_f as f64 - 1.0; // input tile side
    let th = mw + s.h_f as f64 - 1.0;
    let tiles = (s.w_o as f64 / mw).ceil() * (s.h_o as f64 / mw).ceil();
    let n = s.n as f64;
    let (ci, co) = (s.c_i as f64, s.c_o as f64);
    let points = tw * th;

    // input transform: read input, write U (points per tile per channel)
    let u_size = n * tiles * points * ci;
    let v_size = n * tiles * points * co;
    let f_size = points * ci * co;
    let mut vol = p.p_i * (s.input_size() as f64 + u_size)
        + p.p_f * (s.filter_size() as f64 + f_size);
    // per-point channel matmuls (N·tiles × cI × cO), batched over points
    vol += points * matmul_seq(n * tiles, ci, co, pbar(p), m);
    // inverse transform: read V, write output
    vol += p.p_o * (v_size + s.output_size() as f64);
    vol
}

pub fn fft_volume(s: &ConvShape, p: Precision, m: f64) -> f64 {
    let img = (s.in_w() * s.in_h()) as f64;
    let n = s.n as f64;
    let (ci, co) = (s.c_i as f64, s.c_o as f64);
    // complex words double the footprint of every transform-domain array
    let cx = 2.0;
    let mut vol = 0.0;
    // forward FFTs of every input channel plane
    vol += p.p_i * cx * n * ci * fft_seq(img, m);
    // filter FFTs (padded to image size)
    vol += p.p_f * cx * ci * co * fft_seq(img, m);
    // per-frequency channel contraction: img point-matmuls N × cI × cO
    vol += cx * img * matmul_seq(n, ci, co, pbar(p), m) / 1.0;
    // inverse FFTs of every output plane + final write
    vol += p.p_o * (cx * n * co * fft_seq(img, m) + s.output_size() as f64);
    vol
}

/// Evaluate every model at memory size `m`.
pub fn sequential_volumes(s: &ConvShape, p: Precision, m: f64) -> SeqVolumes {
    SeqVolumes {
        m,
        bound: sequential_bound(s, p, m).max(1.0),
        naive: naive_volume(s, p),
        im2col: im2col_volume(s, p, m),
        blocking: blocking_volume(s, p, m),
        winograd: winograd_volume(s, p, m),
        fft: fft_volume(s, p, m),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::resnet50_layers;

    fn conv2x(batch: u64) -> ConvShape {
        resnet50_layers(batch)[1].shape
    }

    #[test]
    fn all_volumes_at_least_bound_scale() {
        // every algorithm's volume must be ≥ a constant fraction of the
        // bound (sanity: no model undercuts the lower bound by >2×)
        let s = conv2x(100);
        let p = Precision::paper_mixed();
        for m in [4096.0, 65536.0, 1048576.0] {
            let v = sequential_volumes(&s, p, m);
            for (name, r) in v.ratios() {
                assert!(r > 0.5, "{name} ratio {r} at M={m}");
            }
        }
    }

    #[test]
    fn naive_is_worst_at_realistic_memory() {
        let s = conv2x(100);
        let v = sequential_volumes(&s, Precision::uniform(), 65536.0);
        assert!(v.naive > v.im2col);
        assert!(v.naive > v.blocking);
    }

    #[test]
    fn blocking_and_im2col_scale_better_than_fft_winograd_in_m() {
        // §3.2: "Blocking and im2col scale better than FFT and Winograd in
        // the memory size" — compare improvement factors from small to
        // large M
        let s = conv2x(100);
        let p = Precision::uniform();
        let small = sequential_volumes(&s, p, 1024.0);
        let large = sequential_volumes(&s, p, 1048576.0);
        let impr = |a: f64, b: f64| a / b;
        assert!(
            impr(small.blocking, large.blocking) > impr(small.fft, large.fft)
        );
        assert!(
            impr(small.im2col, large.im2col) > impr(small.winograd, large.winograd)
        );
    }

    #[test]
    fn blocking_beats_im2col_for_unit_stride_large_m() {
        // Figure 2: "for conv2_x, the strides of 1 are more favorable to
        // the blocking, and blocking beats im2col for sufficiently large
        // memory sizes"
        let s = conv2x(1000);
        let p = Precision::paper_mixed();
        let v = sequential_volumes(&s, p, 4.0 * 1048576.0);
        assert!(
            v.blocking < v.im2col,
            "blocking {} vs im2col {}",
            v.blocking, v.im2col
        );
    }

    #[test]
    fn volumes_positive_and_finite_for_all_layers() {
        let p = Precision::paper_mixed();
        for l in resnet50_layers(1000) {
            for m in [4096.0, 262144.0] {
                let v = sequential_volumes(&l.shape, p, m);
                for x in [v.bound, v.naive, v.im2col, v.blocking, v.winograd, v.fft] {
                    assert!(x.is_finite() && x > 0.0, "{}: {v:?}", l.name);
                }
            }
        }
    }

    #[test]
    fn winograd_polyphase_reduces_to_unit_stride() {
        // for σ=1 the polyphase loop has exactly one term
        let s = conv2x(10);
        let p = Precision::uniform();
        let a = winograd_volume(&s, p, 65536.0);
        let b = winograd_unit_stride(&s, p, 65536.0);
        assert_eq!(a, b);
    }
}
