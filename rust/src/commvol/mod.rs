//! Symbolic communication-volume models (paper §3.2 and §4.2).
//!
//! For Figures 2 and 3 the paper "symbolically calculate[s] the amount of
//! communication each [algorithm] requires" — naive, im2col, LP blocking,
//! Winograd and FFT — and plots it relative to the lower bound. This module
//! is that calculator. Model assumptions are documented per function; the
//! goal is the paper's *shape*: who wins, by what factor, where crossovers
//! fall, not testbed-exact constants.
//!
//! Conventions:
//! * volumes are in words (32 bits), mixed precision via [`Precision`];
//! * matmul sub-steps are charged the Kwasniewski et al. [12] optimal
//!   volume `2·mnk·√(p̄/M)` (sequential) and its parallel / 2.5D variants,
//!   with `p̄` the geometric-mean precision of the three operands;
//! * FFT sub-steps are charged the Elango [7] volume `n·log₂n / log₂M`.

pub mod par;
pub mod seq;

pub use par::{parallel_volumes, ParVolumes};
pub use seq::{sequential_volumes, SeqVolumes};

use crate::conv::Precision;

/// Geometric-mean precision of the three arrays.
pub(crate) fn pbar(p: Precision) -> f64 {
    (p.p_i * p.p_f * p.p_o).cbrt()
}

/// Sequential blocked-matmul volume (Kwasniewski [12]): `2·mnk·√(p̄/M)`,
/// floored at the compulsory traffic of the three matrices.
pub(crate) fn matmul_seq(m: f64, k: f64, n: f64, pb: f64, mem: f64) -> f64 {
    let hbl = 2.0 * m * k * n * (pb / mem).sqrt();
    let compulsory = pb * (m * k + k * n + m * n);
    hbl.max(compulsory)
}

/// Per-processor parallel matmul volume: the max of the memory-dependent
/// `2mnk/(P√(M/p̄))` and the memory-independent 2.5D term `(mnk/P)^{2/3}·p̄`.
pub(crate) fn matmul_par(m: f64, k: f64, n: f64, pb: f64, procs: f64, mem: f64) -> f64 {
    let dep = 2.0 * m * k * n * (pb / mem).sqrt() / procs;
    let indep = pb * (m * k * n / procs).powf(2.0 / 3.0);
    dep.max(indep)
}

/// Sequential FFT volume (Elango [7]): `n·log₂n / log₂M` per n-point
/// transform, floored at 2n (read + write).
pub(crate) fn fft_seq(n: f64, mem: f64) -> f64 {
    let v = n * n.log2() / mem.log2().max(1.0);
    v.max(2.0 * n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_seq_matches_kwasniewski_uniform() {
        // 2mnk/√M for unit precision, when above the compulsory floor
        let v = matmul_seq(1e3, 1e3, 1e3, 1.0, 1e4);
        assert!((v - 2.0 * 1e9 / 1e2).abs() < 1e-6);
    }

    #[test]
    fn matmul_seq_floors_at_compulsory() {
        // huge memory: the √M term vanishes below the array sizes
        let v = matmul_seq(100.0, 100.0, 100.0, 1.0, 1e12);
        assert_eq!(v, 3.0 * 100.0 * 100.0);
    }

    #[test]
    fn matmul_par_regimes() {
        // small memory: dependent term dominates; huge memory: 2.5D term
        let dep = matmul_par(1e3, 1e3, 1e3, 1.0, 8.0, 1e2);
        assert!((dep - 2.0 * 1e9 / (8.0 * 10.0)).abs() < 1.0);
        let indep = matmul_par(1e3, 1e3, 1e3, 1.0, 8.0, 1e12);
        assert!((indep - (1e9 / 8.0f64).powf(2.0 / 3.0)).abs() < 1.0);
    }

    #[test]
    fn fft_seq_scaling() {
        let small_m = fft_seq(1048576.0, 64.0);
        let big_m = fft_seq(1048576.0, 1048576.0);
        assert!(small_m > big_m, "FFT volume must shrink with log M");
        // floor: at least read+write
        assert!(fft_seq(1024.0, 1e30) >= 2048.0);
    }

    #[test]
    fn pbar_uniform_is_one() {
        assert!((pbar(Precision::uniform()) - 1.0).abs() < 1e-12);
        let mixed = pbar(Precision::paper_mixed());
        assert!((mixed - 2.0f64.cbrt()).abs() < 1e-12);
    }
}
