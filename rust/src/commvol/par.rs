//! Parallel per-processor communication volumes — Figure 3.
//!
//! The paper's models assume the data starts *inside* the distributed
//! memory, load-balanced (the Theorem 2.3 setting); converting a model that
//! assumes external data "simply add[s] or subtract[s] the total size of
//! the problem" (§4.2). We charge each algorithm the words a processor must
//! *receive*: what it touches minus the load-balanced share it already
//! holds, plus any transform-domain intermediates it materializes.

use crate::bounds::parallel_bound;
use crate::conv::{ConvShape, Precision};
use crate::tiling::parallel_blocking;
use crate::util::ceil_div;

use super::{matmul_par, pbar};

/// All Figure-3 series at one processor count.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ParVolumes {
    pub procs: f64,
    pub bound: f64,
    pub naive: f64,
    pub im2col: f64,
    pub blocking: f64,
    pub winograd: f64,
    pub fft: f64,
}

impl ParVolumes {
    pub fn ratios(&self) -> [(&'static str, f64); 5] {
        [
            ("naive", self.naive / self.bound),
            ("im2col", self.im2col / self.bound),
            ("blocking", self.blocking / self.bound),
            ("winograd", self.winograd / self.bound),
            ("fft", self.fft / self.bound),
        ]
    }
}

/// Naive parallel: output split over P, every MAC fetches its operands
/// remotely except the locally-resident share.
pub fn naive_volume_par(s: &ConvShape, p: Precision, procs: f64) -> f64 {
    let g = s.updates() as f64;
    let resident = s.footprint_words(p) / procs;
    ((p.p_i + p.p_f) * g / procs + p.p_o * s.output_size() as f64 / procs
        - resident)
        .max(0.0)
}

/// im2col parallel: the patch matrix is materialized *locally* (its rows
/// are distributed with the output rows, so building it is local memory
/// traffic, not network words — unlike the sequential model where every
/// slow↔fast transfer counts). The network pays the input-halo fetch plus
/// a communication-optimal parallel matmul [12].
pub fn im2col_volume_par(s: &ConvShape, p: Precision, procs: f64, m: f64) -> f64 {
    // building a patch row touches a remote input halo: charge one full
    // fetch of the processor's input slice (the resident share covers the
    // interior, the halo costs about as much for im2col's row mapping)
    let in_fetch = p.p_i * s.input_size() as f64 / procs;
    let mm = matmul_par(
        (s.n * s.w_o * s.h_o) as f64,
        (s.c_i * s.w_f * s.h_f) as f64,
        s.c_o as f64,
        pbar(p),
        procs,
        m,
    );
    in_fetch + mm
}

/// The paper's LP blocking over the processor grid (§4.2).
pub fn blocking_volume_par(s: &ConvShape, p: Precision, procs: u64, m: f64) -> f64 {
    parallel_blocking(s, p, procs, m).comm_per_proc(s, p)
}

/// Winograd parallel: transforms are tile-local (distributed with the
/// output tiles); the per-point channel matmuls pay the parallel matmul
/// volume. Strided layers are polyphase-decomposed as in the sequential
/// model.
pub fn winograd_volume_par(s: &ConvShape, p: Precision, procs: f64, m: f64) -> f64 {
    let mut total = 0.0;
    for rw in 0..s.s_w {
        for rh in 0..s.s_h {
            let wf = ceil_div(s.w_f.saturating_sub(rw), s.s_w).max(1);
            let hf = ceil_div(s.h_f.saturating_sub(rh), s.s_h).max(1);
            let sub = ConvShape { w_f: wf, h_f: hf, s_w: 1, s_h: 1, ..*s };
            total += winograd_unit_par(&sub, p, procs, m);
        }
    }
    total
}

fn winograd_unit_par(s: &ConvShape, p: Precision, procs: f64, m: f64) -> f64 {
    let mw = 2.0_f64;
    let tw = mw + s.w_f as f64 - 1.0;
    let th = mw + s.h_f as f64 - 1.0;
    let tiles = (s.w_o as f64 / mw).ceil() * (s.h_o as f64 / mw).ceil();
    let n = s.n as f64;
    let (ci, co) = (s.c_i as f64, s.c_o as f64);
    let points = tw * th;
    // transform-domain arrays, distributed: local writes, but the filter
    // transform must be replicated across the processor rows that use it
    let u_local = p.p_i * n * tiles * points * ci / procs;
    let f_repl = p.p_f * points * ci * co * (1.0 - 1.0 / procs);
    let v_local = p.p_o * n * tiles * points * co / procs;
    let mm: f64 = points * matmul_par(n * tiles, ci, co, pbar(p), procs, m);
    u_local + f_repl + v_local + mm
}

/// FFT parallel: distributed FFTs pay `n·log n/(P·log M)` each ([7]),
/// plus the layout redistribution between the transform phase (data
/// sharded by image plane) and the contraction phase (data sharded by
/// frequency) — an all-to-all of the full transform-domain volume — plus
/// the per-frequency channel matmuls and filter-transform replication.
pub fn fft_volume_par(s: &ConvShape, p: Precision, procs: f64, m: f64) -> f64 {
    let img = (s.in_w() * s.in_h()) as f64;
    let n = s.n as f64;
    let (ci, co) = (s.c_i as f64, s.c_o as f64);
    let cx = 2.0;
    let fft_one = img * img.log2() / (procs * m.log2().max(1.0));
    let mut vol = 0.0;
    // forward/filter/inverse transforms
    vol += p.p_i * cx * n * ci * fft_one;
    vol += p.p_f * cx * ci * co * fft_one
        + p.p_f * cx * ci * co * img * (1.0 - 1.0 / procs) / procs;
    vol += p.p_o * cx * n * co * fft_one;
    // plane-sharded → frequency-sharded all-to-all (U, Ŵ) and back (V̂)
    vol += cx * (p.p_i * n * ci + p.p_f * ci * co + p.p_o * n * co) * img / procs;
    // per-frequency channel contraction
    vol += cx * img * matmul_par(n, ci, co, pbar(p), procs, m);
    vol
}

/// Evaluate every model at processor count `procs` (memory `m` words each).
pub fn parallel_volumes(s: &ConvShape, p: Precision, procs: u64, m: f64) -> ParVolumes {
    let pf = procs as f64;
    ParVolumes {
        procs: pf,
        bound: parallel_bound(s, p, pf, m).max(1.0),
        naive: naive_volume_par(s, p, pf),
        im2col: im2col_volume_par(s, p, pf, m),
        blocking: blocking_volume_par(s, p, procs, m),
        winograd: winograd_volume_par(s, p, pf, m),
        fft: fft_volume_par(s, p, pf, m),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::resnet50_layers;

    fn conv2x(batch: u64) -> ConvShape {
        resnet50_layers(batch)[1].shape
    }

    #[test]
    fn blocking_outperforms_im2col() {
        // Figure 3: "blocking outperforms im2col considerably, especially
        // for layer 2"
        let s = conv2x(1000);
        let p = Precision::paper_mixed();
        for procs in [16u64, 64, 256] {
            let v = parallel_volumes(&s, p, procs, 1e6);
            assert!(
                v.blocking < v.im2col,
                "P={procs}: blocking {} im2col {}",
                v.blocking, v.im2col
            );
        }
    }

    #[test]
    fn im2col_orders_of_magnitude_better_than_fft_winograd() {
        // §4.2: "Winograd and FFT remain quite far from the communication
        // bound … while im2col performs orders of magnitude better"
        let s = conv2x(1000);
        let p = Precision::paper_mixed();
        let v = parallel_volumes(&s, p, 64, 1e6);
        assert!(v.im2col * 5.0 < v.winograd, "{v:?}");
        assert!(v.im2col * 5.0 < v.fft, "{v:?}");
    }

    #[test]
    fn all_finite_nonnegative() {
        let p = Precision::paper_mixed();
        for l in resnet50_layers(1000) {
            for procs in [2u64, 32, 1024] {
                let v = parallel_volumes(&l.shape, p, procs, 1e6);
                for x in [v.bound, v.naive, v.im2col, v.blocking, v.winograd, v.fft] {
                    assert!(x.is_finite() && x >= 0.0, "{}: {v:?}", l.name);
                }
            }
        }
    }

    #[test]
    fn naive_touch_volume_decreases_with_p() {
        let s = conv2x(100);
        let p = Precision::uniform();
        let few = naive_volume_par(&s, p, 4.0);
        let many = naive_volume_par(&s, p, 256.0);
        assert!(many < few);
    }
}
