//! Parallel per-processor communication volumes — Figure 3.
//!
//! The paper's models assume the data starts *inside* the distributed
//! memory, load-balanced (the Theorem 2.3 setting); converting a model that
//! assumes external data "simply add[s] or subtract[s] the total size of
//! the problem" (§4.2). We charge each algorithm the words a processor must
//! *receive*: what it touches minus the load-balanced share it already
//! holds, plus any transform-domain intermediates it materializes.

use crate::bounds::parallel_bound;
use crate::conv::{ConvShape, Precision};
use crate::tiling::parallel_blocking;
use crate::util::ceil_div;

use super::{matmul_par, pbar};

/// All Figure-3 series at one processor count.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ParVolumes {
    pub procs: f64,
    pub bound: f64,
    pub naive: f64,
    pub im2col: f64,
    pub blocking: f64,
    pub winograd: f64,
    pub fft: f64,
}

impl ParVolumes {
    pub fn ratios(&self) -> [(&'static str, f64); 5] {
        [
            ("naive", self.naive / self.bound),
            ("im2col", self.im2col / self.bound),
            ("blocking", self.blocking / self.bound),
            ("winograd", self.winograd / self.bound),
            ("fft", self.fft / self.bound),
        ]
    }
}

/// Naive parallel: output split over P, every MAC fetches its operands
/// remotely except the locally-resident share.
pub fn naive_volume_par(s: &ConvShape, p: Precision, procs: f64) -> f64 {
    let g = s.updates() as f64;
    let resident = s.footprint_words(p) / procs;
    ((p.p_i + p.p_f) * g / procs + p.p_o * s.output_size() as f64 / procs
        - resident)
        .max(0.0)
}

/// im2col parallel: the patch matrix is materialized *locally* (its rows
/// are distributed with the output rows, so building it is local memory
/// traffic, not network words — unlike the sequential model where every
/// slow↔fast transfer counts). The network pays the input-halo fetch plus
/// a communication-optimal parallel matmul [12].
pub fn im2col_volume_par(s: &ConvShape, p: Precision, procs: f64, m: f64) -> f64 {
    // building a patch row touches a remote input halo: charge one full
    // fetch of the processor's input slice (the resident share covers the
    // interior, the halo costs about as much for im2col's row mapping)
    let in_fetch = p.p_i * s.input_size() as f64 / procs;
    let mm = matmul_par(
        (s.n * s.w_o * s.h_o) as f64,
        (s.c_i * s.w_f * s.h_f) as f64,
        s.c_o as f64,
        pbar(p),
        procs,
        m,
    );
    in_fetch + mm
}

/// The paper's LP blocking over the processor grid (§4.2).
pub fn blocking_volume_par(s: &ConvShape, p: Precision, procs: u64, m: f64) -> f64 {
    parallel_blocking(s, p, procs, m).comm_per_proc(s, p)
}

/// Winograd parallel: transforms are tile-local (distributed with the
/// output tiles); the per-point channel matmuls pay the parallel matmul
/// volume. Strided layers are polyphase-decomposed as in the sequential
/// model.
pub fn winograd_volume_par(s: &ConvShape, p: Precision, procs: f64, m: f64) -> f64 {
    let mut total = 0.0;
    for rw in 0..s.s_w {
        for rh in 0..s.s_h {
            let wf = ceil_div(s.w_f.saturating_sub(rw), s.s_w).max(1);
            let hf = ceil_div(s.h_f.saturating_sub(rh), s.s_h).max(1);
            let sub = ConvShape { w_f: wf, h_f: hf, s_w: 1, s_h: 1, ..*s };
            total += winograd_unit_par(&sub, p, procs, m);
        }
    }
    total
}

fn winograd_unit_par(s: &ConvShape, p: Precision, procs: f64, m: f64) -> f64 {
    let mw = 2.0_f64;
    let tw = mw + s.w_f as f64 - 1.0;
    let th = mw + s.h_f as f64 - 1.0;
    let tiles = (s.w_o as f64 / mw).ceil() * (s.h_o as f64 / mw).ceil();
    let n = s.n as f64;
    let (ci, co) = (s.c_i as f64, s.c_o as f64);
    let points = tw * th;
    // transform-domain arrays, distributed: local writes, but the filter
    // transform must be replicated across the processor rows that use it
    let u_local = p.p_i * n * tiles * points * ci / procs;
    let f_repl = p.p_f * points * ci * co * (1.0 - 1.0 / procs);
    let v_local = p.p_o * n * tiles * points * co / procs;
    let mm: f64 = points * matmul_par(n * tiles, ci, co, pbar(p), procs, m);
    u_local + f_repl + v_local + mm
}

/// FFT parallel: distributed FFTs pay `n·log n/(P·log M)` each ([7]),
/// plus the layout redistribution between the transform phase (data
/// sharded by image plane) and the contraction phase (data sharded by
/// frequency) — an all-to-all of the full transform-domain volume — plus
/// the per-frequency channel matmuls and filter-transform replication.
pub fn fft_volume_par(s: &ConvShape, p: Precision, procs: f64, m: f64) -> f64 {
    let img = (s.in_w() * s.in_h()) as f64;
    let n = s.n as f64;
    let (ci, co) = (s.c_i as f64, s.c_o as f64);
    let cx = 2.0;
    let fft_one = img * img.log2() / (procs * m.log2().max(1.0));
    let mut vol = 0.0;
    // forward/filter/inverse transforms
    vol += p.p_i * cx * n * ci * fft_one;
    vol += p.p_f * cx * ci * co * fft_one
        + p.p_f * cx * ci * co * img * (1.0 - 1.0 / procs) / procs;
    vol += p.p_o * cx * n * co * fft_one;
    // plane-sharded → frequency-sharded all-to-all (U, Ŵ) and back (V̂)
    vol += cx * (p.p_i * n * ci + p.p_f * ci * co + p.p_o * n * co) * img / procs;
    // per-frequency channel contraction
    vol += cx * img * matmul_par(n, ci, co, pbar(p), procs, m);
    vol
}

// ---------------------------------------------------------------------------
// Per-strategy exact shard-exchange volumes (words, integral).
//
// These are the *executable* counterparts of the models above: the sharded
// engine in `kernels/shard.rs` partitions one conv layer across `active`
// in-process workers and its measured inter-shard words must equal these
// formulas EXACTLY (same contract as `expected_traffic` for memory words).
// Ownership follows the Theorem 2.3 setting — every operand starts inside
// the distributed memory, load-balanced along the sharded dimension — so a
// shard is charged only the words it must *receive* from a peer.

/// Number of shards that actually hold work when a dimension of extent
/// `dim` is split `shards` ways: `min(shards, dim)`, at least 1. Extra
/// shards idle (degenerate P > N case) and neither send nor receive.
pub fn shard_active(dim: u64, shards: u64) -> u64 {
    shards.min(dim).max(1)
}

/// Batch sharding: each shard owns its batch slice of the input and writes
/// its batch slice of the output locally; the only exchange is the filter
/// broadcast to the `active - 1` shards that don't hold the (unsharded)
/// filter tensor.
pub fn batch_shard_words(s: &ConvShape, active: u64) -> u64 {
    s.filter_size() * active.saturating_sub(1)
}

/// Input-channel sharding: each shard owns a `c_i` slice of the input and
/// the matching filter rows, and produces a *partial sum* over the full
/// output. The partials are combined by a traveling accumulator visiting
/// shards in ascending order (preserving the accumulation-order contract),
/// so `active - 1` shards each receive the full |O|-word accumulator.
pub fn channel_shard_words(s: &ConvShape, active: u64) -> u64 {
    s.output_size() * active.saturating_sub(1)
}

/// Spatial (output-height) sharding, halo exchange only: shard k owns the
/// input rows its output rows map onto (`σ_h` rows per output row; the last
/// active shard also owns the `h_f`-row tail), and must receive the `h_f`
/// overlap rows past its core from its successor — `active - 1` halos of
/// `n · c_i · in_w · h_f` words each.
pub fn spatial_halo_words(s: &ConvShape, active: u64) -> u64 {
    active.saturating_sub(1) * s.n * s.c_i * s.in_w() * s.h_f
}

/// Spatial sharding, total exchange: the halo rows plus the same filter
/// broadcast batch sharding pays (every shard convolves with the full
/// filter).
pub fn spatial_shard_words(s: &ConvShape, active: u64) -> u64 {
    spatial_halo_words(s, active) + s.filter_size() * active.saturating_sub(1)
}

/// Evaluate every model at processor count `procs` (memory `m` words each).
pub fn parallel_volumes(s: &ConvShape, p: Precision, procs: u64, m: f64) -> ParVolumes {
    let pf = procs as f64;
    ParVolumes {
        procs: pf,
        bound: parallel_bound(s, p, pf, m).max(1.0),
        naive: naive_volume_par(s, p, pf),
        im2col: im2col_volume_par(s, p, pf, m),
        blocking: blocking_volume_par(s, p, procs, m),
        winograd: winograd_volume_par(s, p, pf, m),
        fft: fft_volume_par(s, p, pf, m),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::resnet50_layers;

    fn conv2x(batch: u64) -> ConvShape {
        resnet50_layers(batch)[1].shape
    }

    #[test]
    fn blocking_outperforms_im2col() {
        // Figure 3: "blocking outperforms im2col considerably, especially
        // for layer 2"
        let s = conv2x(1000);
        let p = Precision::paper_mixed();
        for procs in [16u64, 64, 256] {
            let v = parallel_volumes(&s, p, procs, 1e6);
            assert!(
                v.blocking < v.im2col,
                "P={procs}: blocking {} im2col {}",
                v.blocking, v.im2col
            );
        }
    }

    #[test]
    fn im2col_orders_of_magnitude_better_than_fft_winograd() {
        // §4.2: "Winograd and FFT remain quite far from the communication
        // bound … while im2col performs orders of magnitude better"
        let s = conv2x(1000);
        let p = Precision::paper_mixed();
        let v = parallel_volumes(&s, p, 64, 1e6);
        assert!(v.im2col * 5.0 < v.winograd, "{v:?}");
        assert!(v.im2col * 5.0 < v.fft, "{v:?}");
    }

    #[test]
    fn all_finite_nonnegative() {
        let p = Precision::paper_mixed();
        for l in resnet50_layers(1000) {
            for procs in [2u64, 32, 1024] {
                let v = parallel_volumes(&l.shape, p, procs, 1e6);
                for x in [v.bound, v.naive, v.im2col, v.blocking, v.winograd, v.fft] {
                    assert!(x.is_finite() && x >= 0.0, "{}: {v:?}", l.name);
                }
            }
        }
    }

    #[test]
    fn shard_active_clamps() {
        assert_eq!(shard_active(8, 4), 4);
        assert_eq!(shard_active(3, 8), 3); // P > N: only N shards work
        assert_eq!(shard_active(5, 1), 1);
        assert_eq!(shard_active(0, 4), 1); // degenerate dim still has 1 shard
    }

    #[test]
    fn batch_shard_words_hand_computed() {
        // n=4, cI=2, cO=3, wO=5, hO=5, f=3x3, stride 1:
        // |F| = 2*3*3*3 = 54; 4 active shards -> 3 receive the filter.
        let s = ConvShape::new(4, 2, 3, 5, 5, 3, 3, 1, 1);
        assert_eq!(s.filter_size(), 54);
        assert_eq!(batch_shard_words(&s, 4), 3 * 54);
        assert_eq!(batch_shard_words(&s, 1), 0); // single shard: no exchange
    }

    #[test]
    fn channel_shard_words_hand_computed() {
        // |O| = 4*3*5*5 = 300; the accumulator visits 2 of 3 shards.
        let s = ConvShape::new(4, 2, 3, 5, 5, 3, 3, 1, 1);
        assert_eq!(s.output_size(), 300);
        assert_eq!(channel_shard_words(&s, 3), 2 * 300);
        assert_eq!(channel_shard_words(&s, 1), 0);
    }

    #[test]
    fn spatial_shard_words_hand_computed() {
        // in_w = 1*5+3 = 8; one halo = n*cI*in_w*hF = 4*2*8*3 = 192 words;
        // 2 active shards -> 1 halo + 1 filter copy.
        let s = ConvShape::new(4, 2, 3, 5, 5, 3, 3, 1, 1);
        assert_eq!(s.in_w(), 8);
        assert_eq!(spatial_halo_words(&s, 2), 192);
        assert_eq!(spatial_shard_words(&s, 2), 192 + 54);
        assert_eq!(spatial_shard_words(&s, 1), 0);
    }

    #[test]
    fn strided_spatial_halo_uses_filter_rows_not_stride() {
        // stride 2, f=3: the overlap past a shard's owned core is still
        // h_f rows regardless of stride. n=1, cI=1, wO=4, in_w=2*4+3=11.
        let s = ConvShape::new(1, 1, 1, 4, 4, 3, 3, 2, 2);
        assert_eq!(spatial_halo_words(&s, 4), 3 * 11 * 3);
    }

    #[test]
    fn naive_touch_volume_decreases_with_p() {
        let s = conv2x(100);
        let p = Precision::uniform();
        let few = naive_volume_par(&s, p, 4.0);
        let many = naive_volume_par(&s, p, 256.0);
        assert!(many < few);
    }
}
