//! Minimal benchmark harness (criterion is not vendored offline).
//!
//! `benches/*.rs` are `harness = false` binaries built on this module:
//! warmup, timed iterations, and a summary line per benchmark, plus CSV
//! emission for the figure-regeneration harnesses.

use std::time::Instant;

use crate::obs;
use crate::util::stats::Summary;

/// One timed benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
    pub iters: usize,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let s = &self.summary;
        format!(
            "{:<44} {:>10.3} ms/iter (p50 {:.3}, p95 {:.3}, n={})",
            self.name,
            s.mean * 1e3,
            s.p50 * 1e3,
            s.p95 * 1e3,
            self.iters
        )
    }
}

/// Time `f` with automatic iteration-count calibration: roughly
/// `target_secs` of measurement after one warmup call.
pub fn bench<F: FnMut()>(name: &str, target_secs: f64, mut f: F) -> BenchResult {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_secs / once).ceil() as usize).clamp(3, 10_000);
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    let r = BenchResult {
        name: name.to_string(),
        summary: Summary::of(&samples),
        iters,
    };
    // through the trace sink's log channel: traced bench runs record every
    // summary line as a `log` event, and `--quiet`-style verbosity control
    // comes for free (Info prints at the default level)
    obs::log(obs::Level::Info, &r.report());
    r
}

/// Write a CSV file of figure series (first column x, one column per series).
pub fn write_csv(
    path: &str,
    header: &[&str],
    rows: &[Vec<f64>],
) -> std::io::Result<()> {
    use std::io::Write;
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        let cells: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
        writeln!(f, "{}", cells.join(","))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let r = bench("noop", 0.01, || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.iters >= 3);
        assert!(r.summary.mean >= 0.0);
        assert!(r.report().contains("noop"));
    }

    #[test]
    fn csv_roundtrip() {
        let path = std::env::temp_dir().join("convbound_csv_test.csv");
        let path = path.to_str().unwrap();
        write_csv(path, &["x", "y"], &[vec![1.0, 2.0], vec![3.0, 4.5]]).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        assert!(text.starts_with("x,y\n1,2\n3,4.5"));
        std::fs::remove_file(path).ok();
    }
}
