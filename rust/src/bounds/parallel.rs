//! Theorems 2.2 and 2.3 — parallel distributed-memory lower bounds.
//!
//! Theorem 2.2 (memory-dependent, per-processor):
//! ```text
//! X ≥ max{ C_p·G/(P·M) − M,  2(p_Ip_Fp_O)^{1/2}(σwσh)^{1/2}G/(P(wFhFM)^{1/2}) − 2M }
//! ```
//!
//! Theorem 2.3 (memory-independent, needs initial load balance;
//! A_P = max array size in words):
//! ```text
//! X ≥ (p_Ip_Fp_O)^{1/3}·max{ (G/P)^{1/2}, (Gσwσh)^{2/3}/(P·wFhF)^{2/3} } − A_P/P
//! ```

use crate::conv::{ConvShape, Precision};

/// All four parallel bound terms.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ParallelBoundTerms {
    /// `C_p·G/(PM) − M` (Theorem 2.2, first term)
    pub hbl: f64,
    /// small-filter memory-dependent term (Theorem 2.2, second term)
    pub small_filter: f64,
    /// `(p_Ip_Fp_O)^{1/3}(G/P)^{1/2} − A_P/P` (Theorem 2.3, first term)
    pub mem_indep: f64,
    /// `(p_Ip_Fp_O)^{1/3}(Gσwσh)^{2/3}/(PwFhF)^{2/3} − A_P/P` (Thm 2.3, 2nd)
    pub mem_indep_small_filter: f64,
}

impl ParallelBoundTerms {
    /// Max of the memory-dependent pair (Theorem 2.2 alone).
    pub fn thm22(&self) -> f64 {
        self.hbl.max(self.small_filter).max(0.0)
    }

    /// Max of the memory-independent pair (Theorem 2.3 alone).
    pub fn thm23(&self) -> f64 {
        self.mem_indep.max(self.mem_indep_small_filter).max(0.0)
    }

    /// Overall lower bound (all four terms).
    pub fn max(&self) -> f64 {
        self.thm22().max(self.thm23())
    }
}

/// Evaluate all parallel bound terms for `p_procs` processors with `m`
/// words of local memory each.
pub fn parallel_bound_terms(
    s: &ConvShape,
    p: Precision,
    p_procs: f64,
    m: f64,
) -> ParallelBoundTerms {
    assert!(p_procs >= 1.0 && m > 0.0);
    let g = s.updates() as f64;
    let sigma = (s.s_w * s.s_h) as f64;
    let filt = (s.w_f * s.h_f) as f64;
    let prod3 = (p.p_i * p.p_f * p.p_o).cbrt();
    let prod2 = (p.p_i * p.p_f * p.p_o).sqrt();
    let a_p = s.max_array_words(p);

    ParallelBoundTerms {
        hbl: p.c_p() * g / (p_procs * m) - m,
        small_filter: 2.0 * prod2 * sigma.sqrt() * g
            / (p_procs * (filt * m).sqrt())
            - 2.0 * m,
        mem_indep: prod3 * (g / p_procs).sqrt() - a_p / p_procs,
        mem_indep_small_filter: prod3
            * ((g * sigma) / (p_procs * filt)).powf(2.0 / 3.0)
            - a_p / p_procs,
    }
}

/// Combined Theorem 2.2 + 2.3 lower bound.
pub fn parallel_bound(s: &ConvShape, p: Precision, p_procs: f64, m: f64) -> f64 {
    parallel_bound_terms(s, p, p_procs, m).max()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::resnet50_layers;

    fn shape() -> ConvShape {
        ConvShape::new(100, 64, 64, 56, 56, 3, 3, 1, 1)
    }

    #[test]
    fn standard_precision_thm22_matches_formula() {
        let s = shape();
        let p = Precision::uniform();
        let (pp, m) = (16.0, 8192.0);
        let t = parallel_bound_terms(&s, p, pp, m);
        let g = s.updates() as f64;
        assert!((t.hbl - (2.25 * g / (pp * m) - m)).abs() < 1e-6);
        let sf = 2.0 * g / (pp * (9.0 * m).sqrt()) - 2.0 * m;
        assert!((t.small_filter - sf).abs() * 1e-9 < 1.0);
    }

    #[test]
    fn mem_indep_matches_formula() {
        let s = shape();
        let p = Precision::uniform();
        let pp = 64.0;
        let t = parallel_bound_terms(&s, p, pp, 1.0);
        let g = s.updates() as f64;
        let a_p = s.max_array_words(p);
        assert!((t.mem_indep - ((g / pp).sqrt() - a_p / pp)).abs() < 1e-6);
        let want = (g / (pp * 9.0)).powf(2.0 / 3.0) - a_p / pp;
        assert!((t.mem_indep_small_filter - want).abs() * 1e-9 < 1.0);
    }

    #[test]
    fn thm22_decays_with_processors() {
        let s = shape();
        let p = Precision::paper_mixed();
        let m = 4096.0;
        let mut last = f64::INFINITY;
        for pp in [1.0, 4.0, 16.0, 64.0] {
            let b = parallel_bound_terms(&s, p, pp, m).thm22();
            assert!(b <= last);
            last = b;
        }
    }

    #[test]
    fn thm23_kicks_in_when_thm22_trivial() {
        // Huge memory per processor: Thm 2.2 goes negative. Thm 2.3 becomes
        // nontrivial once P is large enough that A_P/P < (G/P)^{1/2}, i.e.
        // P > A_P²/G (≈ 680 for conv2_x at batch 1000 — the "many
        // processors or much memory" regime the paper targets).
        let s = resnet50_layers(1000)[1].shape; // conv2_x, batch 1000
        let p = Precision::uniform();
        let m = 1e10;
        let t = parallel_bound_terms(&s, p, 1048576.0, m);
        assert!(t.thm22() == 0.0, "{t:?}");
        assert!(t.thm23() > 0.0, "{t:?}");
    }

    #[test]
    fn small_filter_mem_indep_dominates_for_small_filters() {
        // σ=1, 3x3 filter, big G: the (Gσσ/PwFhF)^{2/3} term beats (G/P)^{1/2}
        // when G is large relative to P·(wFhF)²
        let s = resnet50_layers(1000)[1].shape;
        let p = Precision::uniform();
        let t = parallel_bound_terms(&s, p, 4.0, 1.0);
        assert!(t.mem_indep_small_filter > t.mem_indep, "{t:?}");
    }

    #[test]
    fn overall_bound_nonnegative() {
        let s = shape();
        for pp in [1.0, 16.0, 1024.0] {
            for m in [64.0, 1e6, 1e12] {
                assert!(parallel_bound(&s, Precision::gemmini(), pp, m) >= 0.0);
            }
        }
    }
}
