//! Theorem 2.1 — single-processor (two-level memory) lower bound.
//!
//! ```text
//! X ≥ max{ p_I|I| + p_F|F| + p_O|O|,
//!          C_p·G/M − M,
//!          2(p_I p_F p_O)^{1/2}(σw σh)^{1/2}·G/(wF hF M)^{1/2} − 2M }
//! ```
//!
//! with `C_p = p_T²/4` under the triangle condition, else `p_j(p_k+p_l)`.
//! In the standard precision case this is the familiar
//! `max{|I|+|F|+|O|, 9G/4M − M, 2G(σwσh)^{1/2}/(wFhFM)^{1/2} − 2M}`.

use crate::conv::{ConvShape, Precision};

/// The three terms of Theorem 2.1, individually (for figure annotations and
/// crossover analysis).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SeqBoundTerms {
    /// memory-independent compulsory traffic (Lemma 3.1)
    pub compulsory: f64,
    /// `C_p·G/M − M` (Lemmas 3.2/3.3)
    pub hbl: f64,
    /// `2(p_Ip_Fp_O)^{1/2}(σwσh)^{1/2}G/(wFhFM)^{1/2} − 2M` (Lemma 3.4)
    pub small_filter: f64,
}

impl SeqBoundTerms {
    pub fn max(&self) -> f64 {
        self.compulsory.max(self.hbl).max(self.small_filter).max(0.0)
    }

    /// Which term dominates: "compulsory" | "hbl" | "small_filter".
    pub fn dominant(&self) -> &'static str {
        let m = self.max();
        if m == self.compulsory {
            "compulsory"
        } else if m == self.hbl {
            "hbl"
        } else {
            "small_filter"
        }
    }
}

/// Evaluate the three terms at memory size `m` words.
pub fn sequential_bound_terms(s: &ConvShape, p: Precision, m: f64) -> SeqBoundTerms {
    assert!(m > 0.0, "memory size must be positive");
    let g = s.updates() as f64;
    let compulsory = s.footprint_words(p);
    let hbl = p.c_p() * g / m - m;
    let sigma = (s.s_w * s.s_h) as f64;
    let filt = (s.w_f * s.h_f) as f64;
    let small_filter =
        2.0 * (p.p_i * p.p_f * p.p_o).sqrt() * sigma.sqrt() * g / (filt * m).sqrt()
            - 2.0 * m;
    SeqBoundTerms { compulsory, hbl, small_filter }
}

/// Theorem 2.1: the max of the three terms (≥ 0).
pub fn sequential_bound(s: &ConvShape, p: Precision, m: f64) -> f64 {
    sequential_bound_terms(s, p, m).max()
}

/// The memory size below which the small-filter term eclipses the HBL term
/// in the standard-precision case: `wF·hF < 64·M·σw·σh / 81` (§3.1), i.e.
/// `M > 81·wF·hF / (64·σw·σh)` makes the small-filter bound dominate.
pub fn small_filter_crossover_m(s: &ConvShape) -> f64 {
    81.0 * (s.w_f * s.h_f) as f64 / (64.0 * (s.s_w * s.s_h) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::resnet50_layers;

    fn shape() -> ConvShape {
        // conv2_x-like at small batch
        ConvShape::new(10, 64, 64, 56, 56, 3, 3, 1, 1)
    }

    #[test]
    fn standard_precision_formula_match() {
        let s = shape();
        let p = Precision::uniform();
        let m = 65536.0;
        let t = sequential_bound_terms(&s, p, m);
        let g = s.updates() as f64;
        assert!((t.hbl - (2.25 * g / m - m)).abs() < 1e-6);
        let expect_sf = 2.0 * g / (9.0 * m).sqrt() - 2.0 * m;
        assert!((t.small_filter - expect_sf).abs() * 1e-9 < 1.0);
        assert_eq!(
            t.compulsory,
            (s.input_size() + s.filter_size() + s.output_size()) as f64
        );
    }

    #[test]
    fn bound_is_nonnegative_even_for_huge_memory() {
        let s = shape();
        let b = sequential_bound(&s, Precision::uniform(), 1e12);
        assert!(b >= 0.0);
        // with huge M the compulsory term dominates
        let t = sequential_bound_terms(&s, Precision::uniform(), 1e12);
        assert_eq!(t.dominant(), "compulsory");
    }

    #[test]
    fn hbl_dominates_for_tiny_memory_large_filter() {
        // large filter relative to M: 7x7 filter, tiny cache
        let s = ConvShape::new(100, 64, 64, 56, 56, 7, 7, 1, 1);
        let m = 16.0;
        let t = sequential_bound_terms(&s, Precision::uniform(), m);
        assert!(t.hbl > t.small_filter, "{t:?}");
    }

    #[test]
    fn small_filter_dominates_above_crossover() {
        let s = shape(); // 3x3 filter, stride 1 -> crossover at M = 81*9/64
        let mx = small_filter_crossover_m(&s);
        assert!((mx - 81.0 * 9.0 / 64.0).abs() < 1e-9);
        // well above crossover but small enough that compulsory doesn't win
        let m = mx * 100.0;
        let t = sequential_bound_terms(&s, Precision::uniform(), m);
        assert!(t.small_filter > t.hbl, "{t:?}");
    }

    #[test]
    fn bound_decreases_with_memory() {
        let s = shape();
        let p = Precision::paper_mixed();
        let mut last = f64::INFINITY;
        for m in [1024.0, 4096.0, 16384.0, 65536.0] {
            let b = sequential_bound(&s, p, m);
            assert!(b <= last, "bound must be non-increasing in M");
            last = b;
        }
    }

    #[test]
    fn mixed_precision_scales_hbl_term() {
        let s = shape();
        let m = 4096.0;
        let t1 = sequential_bound_terms(&s, Precision::uniform(), m);
        let t2 = sequential_bound_terms(&s, Precision::paper_mixed(), m);
        // C_p: 9/4 -> 4, so hbl term grows by 16/9 (up to the −M shift)
        let g = s.updates() as f64;
        assert!((t2.hbl - (4.0 * g / m - m)).abs() < 1e-6);
        assert!(t2.hbl > t1.hbl);
    }

    #[test]
    fn resnet_layers_have_positive_bounds() {
        for l in resnet50_layers(1000) {
            let b = sequential_bound(&l.shape, Precision::paper_mixed(), 65536.0);
            assert!(b > 0.0, "{}", l.name);
        }
    }
}
