//! Multi-level memory hierarchies — the paper's §6 "future directions"
//! item "extend our results to … single processors with more levels of
//! cache", implemented as an extension.
//!
//! The standard reduction: in a hierarchy `L1 ⊂ L2 ⊂ … ⊂ DRAM`, the traffic
//! crossing the boundary above level *i* is the traffic of a two-level
//! machine whose fast memory is everything at level ≤ i (size `M_i`), so
//! Theorem 2.1 applies independently at every boundary. A weighted total
//! (per-level cost-per-word, e.g. inverse bandwidths or energy) gives a
//! single machine-level lower bound.

use crate::conv::{ConvShape, Precision};

use super::sequential::{sequential_bound, sequential_bound_terms, SeqBoundTerms};

/// One cache level: capacity in words + cost per word moved across the
/// boundary *above* it (to the next, larger level).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CacheLevel {
    pub capacity_words: f64,
    pub cost_per_word: f64,
}

/// A memory hierarchy, ordered smallest (fastest) first. DRAM is implicit.
#[derive(Debug, Clone, PartialEq)]
pub struct Hierarchy {
    pub levels: Vec<CacheLevel>,
}

impl Hierarchy {
    /// A typical 3-level CPU: 32 KiB L1, 256 KiB L2, 8 MiB L3 (words are
    /// 4 B), with per-word costs 1 : 4 : 16 (relative inverse bandwidths).
    pub fn typical_cpu() -> Hierarchy {
        Hierarchy {
            levels: vec![
                CacheLevel { capacity_words: 8.0 * 1024.0, cost_per_word: 1.0 },
                CacheLevel { capacity_words: 64.0 * 1024.0, cost_per_word: 4.0 },
                CacheLevel { capacity_words: 2048.0 * 1024.0, cost_per_word: 16.0 },
            ],
        }
    }

    pub fn validate(&self) {
        assert!(!self.levels.is_empty());
        for w in self.levels.windows(2) {
            assert!(
                w[0].capacity_words < w[1].capacity_words,
                "levels must grow: {w:?}"
            );
        }
        assert!(self.levels.iter().all(|l| l.cost_per_word > 0.0));
    }
}

/// Per-boundary Theorem-2.1 lower bounds: `bounds[i]` is the minimum number
/// of words crossing the boundary between level i and level i+1 (or DRAM).
pub fn per_level_bounds(s: &ConvShape, p: Precision, h: &Hierarchy) -> Vec<SeqBoundTerms> {
    h.validate();
    h.levels
        .iter()
        .map(|l| sequential_bound_terms(s, p, l.capacity_words))
        .collect()
}

/// Weighted total communication cost lower bound:
/// `Σ_i cost_i · X_i(M_i)`.
pub fn hierarchy_cost_bound(s: &ConvShape, p: Precision, h: &Hierarchy) -> f64 {
    h.validate();
    h.levels
        .iter()
        .map(|l| l.cost_per_word * sequential_bound(s, p, l.capacity_words))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::resnet50_layers;

    fn layer() -> ConvShape {
        resnet50_layers(100)[1].shape
    }

    #[test]
    fn typical_cpu_is_valid_and_monotone() {
        let h = Hierarchy::typical_cpu();
        h.validate();
        let bounds = per_level_bounds(&layer(), Precision::uniform(), &h);
        assert_eq!(bounds.len(), 3);
        // smaller caches bound more traffic
        assert!(bounds[0].max() >= bounds[1].max());
        assert!(bounds[1].max() >= bounds[2].max());
    }

    #[test]
    fn cost_bound_at_least_most_expensive_level() {
        let h = Hierarchy::typical_cpu();
        let s = layer();
        let p = Precision::paper_mixed();
        let total = hierarchy_cost_bound(&s, p, &h);
        for l in &h.levels {
            let single = l.cost_per_word * sequential_bound(&s, p, l.capacity_words);
            assert!(total >= single - 1e-6);
        }
    }

    #[test]
    #[should_panic(expected = "levels must grow")]
    fn shrinking_levels_rejected() {
        let h = Hierarchy {
            levels: vec![
                CacheLevel { capacity_words: 1024.0, cost_per_word: 1.0 },
                CacheLevel { capacity_words: 512.0, cost_per_word: 2.0 },
            ],
        };
        per_level_bounds(&layer(), Precision::uniform(), &h);
    }
}
