//! Communication lower bounds: Theorems 2.1, 2.2 and 2.3.
//!
//! All bounds are in *words* (32 bits) and accept mixed-precision arrays.
//! Negative intermediate values (the `−M` style correction terms can exceed
//! the main term for huge M) are clamped at the trivial floor of zero; the
//! sequential bound additionally includes the compulsory-traffic term
//! `p_I|I| + p_F|F| + p_O|O|` which keeps it positive in practice.

pub mod hierarchy;
pub mod parallel;
pub mod sequential;

pub use parallel::{parallel_bound, parallel_bound_terms, ParallelBoundTerms};
pub use sequential::{sequential_bound, sequential_bound_terms, SeqBoundTerms};
