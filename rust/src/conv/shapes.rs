//! The 7NL CNN problem shape (paper §2.1) and the mixed-precision model.
//!
//! ```text
//! for {i1..i7} = 0 : {N, cI, cO, wO, hO, wF, hF} - 1
//!   Output(i1,i3,i4,i5) += Input(i1,i2, σw·i4+i6, σh·i5+i7) · Filter(i2,i3,i6,i7)
//! ```
//!
//! Sizes follow the paper exactly: `|I| = N·cI·(σw·wO + wF)(σh·hO + hF)`,
//! `|O| = N·cO·wO·hO`, `|F| = cI·cO·wF·hF`, `G = N·cI·cO·wO·hO·wF·hF`.

use std::fmt;

/// Precisions of the three arrays, in words (32 bits). GEMMINI's 8-bit
/// inputs are `0.25` words; its 32-bit accumulator outputs are `1.0`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Precision {
    pub p_i: f64,
    pub p_f: f64,
    pub p_o: f64,
}

impl Precision {
    pub const fn new(p_i: f64, p_f: f64, p_o: f64) -> Precision {
        Precision { p_i, p_f, p_o }
    }

    /// All-single-precision (the "standard case", C_p = 9/4).
    pub const fn uniform() -> Precision {
        Precision::new(1.0, 1.0, 1.0)
    }

    /// Figure 2/3 setting: p_I = p_F = 1, p_O = 2.
    pub const fn paper_mixed() -> Precision {
        Precision::new(1.0, 1.0, 2.0)
    }

    /// GEMMINI setting: 8-bit input/filter, 32-bit accumulator output.
    pub const fn gemmini() -> Precision {
        Precision::new(0.25, 0.25, 1.0)
    }

    /// p_T = p_I + p_F + p_O.
    pub fn total(&self) -> f64 {
        self.p_i + self.p_f + self.p_o
    }

    /// Does the triangle condition `p_j <= p_k + p_l` hold for all j?
    pub fn triangle(&self) -> bool {
        self.p_i <= self.p_f + self.p_o
            && self.p_f <= self.p_i + self.p_o
            && self.p_o <= self.p_i + self.p_f
    }

    /// The constant C_p of Theorem 2.1:
    /// `p_T²/4` under the triangle condition, else `p_j(p_k + p_l)` for the
    /// violating j.
    pub fn c_p(&self) -> f64 {
        if self.triangle() {
            return self.total().powi(2) / 4.0;
        }
        let (pi, pf, po) = (self.p_i, self.p_f, self.p_o);
        if pi > pf + po {
            pi * (pf + po)
        } else if pf > pi + po {
            pf * (pi + po)
        } else {
            po * (pi + pf)
        }
    }
}

/// One 7NL CNN layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ConvShape {
    /// Batch size N (i1).
    pub n: u64,
    /// Input channels c_I (i2).
    pub c_i: u64,
    /// Output channels c_O (i3).
    pub c_o: u64,
    /// Output width w_O (i4).
    pub w_o: u64,
    /// Output height h_O (i5).
    pub h_o: u64,
    /// Filter width w_F (i6).
    pub w_f: u64,
    /// Filter height h_F (i7).
    pub h_f: u64,
    /// Horizontal stride σ_w.
    pub s_w: u64,
    /// Vertical stride σ_h.
    pub s_h: u64,
}

impl ConvShape {
    #[allow(clippy::too_many_arguments)]
    pub const fn new(n: u64, c_i: u64, c_o: u64, w_o: u64, h_o: u64,
                     w_f: u64, h_f: u64, s_w: u64, s_h: u64) -> ConvShape {
        ConvShape { n, c_i, c_o, w_o, h_o, w_f, h_f, s_w, s_h }
    }

    /// Paper model-assumption check: `σ ≤ f ≤ σ·out` in both axes.
    pub fn paper_assumptions_hold(&self) -> bool {
        self.s_w <= self.w_f
            && self.s_h <= self.h_f
            && self.w_f <= self.s_w * self.w_o
            && self.h_f <= self.s_h * self.h_o
    }

    /// Input width `σw·wO + wF` (paper convention).
    pub fn in_w(&self) -> u64 {
        self.s_w * self.w_o + self.w_f
    }

    /// Input height `σh·hO + hF`.
    pub fn in_h(&self) -> u64 {
        self.s_h * self.h_o + self.h_f
    }

    /// |I| in elements.
    pub fn input_size(&self) -> u64 {
        self.n * self.c_i * self.in_w() * self.in_h()
    }

    /// |F| in elements.
    pub fn filter_size(&self) -> u64 {
        self.c_i * self.c_o * self.w_f * self.h_f
    }

    /// |O| in elements.
    pub fn output_size(&self) -> u64 {
        self.n * self.c_o * self.w_o * self.h_o
    }

    /// G = total number of multiply-accumulate updates.
    pub fn updates(&self) -> u64 {
        self.n * self.c_i * self.c_o * self.w_o * self.h_o * self.w_f * self.h_f
    }

    /// Total array footprint in *words* under precisions `p`:
    /// `p_I|I| + p_F|F| + p_O|O|` (the compulsory-traffic bound).
    pub fn footprint_words(&self, p: Precision) -> f64 {
        p.p_i * self.input_size() as f64
            + p.p_f * self.filter_size() as f64
            + p.p_o * self.output_size() as f64
    }

    /// Largest single array in words: `A_P` of Theorem 2.3.
    pub fn max_array_words(&self, p: Precision) -> f64 {
        let i = p.p_i * self.input_size() as f64;
        let f = p.p_f * self.filter_size() as f64;
        let o = p.p_o * self.output_size() as f64;
        i.max(f).max(o)
    }

    /// Scale the batch dimension.
    pub fn with_batch(mut self, n: u64) -> ConvShape {
        self.n = n;
        self
    }

    /// Filter tensor dims `(cI, cO, wF, hF)` as tensor-shape usizes — the
    /// one place the filter layout is spelled out for tensor construction
    /// and validation.
    pub fn filter_dims(&self) -> [usize; 4] {
        [
            self.c_i as usize,
            self.c_o as usize,
            self.w_f as usize,
            self.h_f as usize,
        ]
    }
}

/// One stage of a served network pipeline: a conv layer plus the
/// word-precision model its tile plan is solved under. Defined here, next
/// to [`ConvShape`] and [`Precision`], so the execution engine
/// (`kernels/fuse`, `kernels/exec`) can consume stage chains without
/// depending on the manifest layer; `runtime::manifest` re-exports it and
/// owns the chain-validation logic (`NetworkSpec`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkStage {
    pub shape: ConvShape,
    pub precision: Precision,
}

impl fmt::Display for ConvShape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "N={} cI={} cO={} out={}x{} filt={}x{} stride={}x{}",
            self.n, self.c_i, self.c_o, self.w_o, self.h_o, self.w_f,
            self.h_f, self.s_w, self.s_h
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> ConvShape {
        ConvShape::new(2, 3, 4, 5, 6, 3, 3, 1, 1)
    }

    #[test]
    fn sizes_match_formulas() {
        let s = small();
        assert_eq!(s.in_w(), 5 + 3);
        assert_eq!(s.in_h(), 6 + 3);
        assert_eq!(s.input_size(), 2 * 3 * 8 * 9);
        assert_eq!(s.filter_size(), 3 * 4 * 3 * 3);
        assert_eq!(s.output_size(), 2 * 4 * 5 * 6);
        assert_eq!(s.updates(), 2 * 3 * 4 * 5 * 6 * 3 * 3);
    }

    #[test]
    fn strided_input_size() {
        let s = ConvShape::new(1, 1, 1, 10, 10, 4, 4, 2, 2);
        assert_eq!(s.in_w(), 24);
        assert_eq!(s.input_size(), 24 * 24);
    }

    #[test]
    fn paper_assumptions() {
        assert!(small().paper_assumptions_hold());
        // stride bigger than filter violates σ ≤ f
        let bad = ConvShape::new(1, 1, 1, 10, 10, 2, 2, 3, 3);
        assert!(!bad.paper_assumptions_hold());
        // filter bigger than σ·out violates f ≤ σ·out
        let bad2 = ConvShape::new(1, 1, 1, 2, 2, 5, 5, 1, 1);
        assert!(!bad2.paper_assumptions_hold());
    }

    #[test]
    fn uniform_precision_cp_is_nine_fourths() {
        assert!((Precision::uniform().c_p() - 2.25).abs() < 1e-12);
    }

    #[test]
    fn mixed_precision_cp() {
        // p = (1,1,2): triangle holds with equality; C_p = 16/4 = 4
        let p = Precision::paper_mixed();
        assert!(p.triangle());
        assert!((p.c_p() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn triangle_violated_cp() {
        // p_O = 5 > 1 + 1: C_p = 5·(1+1) = 10
        let p = Precision::new(1.0, 1.0, 5.0);
        assert!(!p.triangle());
        assert!((p.c_p() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn triangle_violation_is_unique() {
        // if p_j > p_k + p_l then the other two conditions hold
        let p = Precision::new(8.0, 2.0, 1.0);
        assert!(p.p_i > p.p_f + p.p_o);
        assert!(p.p_f <= p.p_i + p.p_o);
        assert!(p.p_o <= p.p_i + p.p_f);
        assert!((p.c_p() - 8.0 * 3.0).abs() < 1e-12);
    }

    #[test]
    fn gemmini_precision() {
        let p = Precision::gemmini();
        assert!(!p.triangle()); // 1.0 > 0.25 + 0.25
        assert!((p.c_p() - 1.0 * 0.5).abs() < 1e-12);
    }

    #[test]
    fn footprint_words_mixed() {
        let s = small();
        let p = Precision::paper_mixed();
        let expect = s.input_size() as f64
            + s.filter_size() as f64
            + 2.0 * s.output_size() as f64;
        assert_eq!(s.footprint_words(p), expect);
    }
}
