//! Layer catalogs used in the paper's evaluation.
//!
//! The paper evaluates the "five standard ResNet convolution sizes" (He et
//! al. [9]) at batch size 1000 (Figures 2–4) and mentions AlexNet parameters
//! for the Section 3.2 comparison. conv2_x…conv5_x are the 3×3 convolutions
//! of the residual blocks; the paper notes conv3_x–conv5_x "resemble
//! conv2_x", and Figure 4 uses one representative size per stage.

use super::shapes::ConvShape;

/// A named layer.
#[derive(Debug, Clone, Copy)]
pub struct NamedLayer {
    pub name: &'static str,
    pub shape: ConvShape,
}

/// ResNet-50 representative convolution sizes at batch size `n`.
///
/// * conv1: 7×7/2, 3→64, 112×112 out
/// * conv2_x: 3×3/1, 64→64, 56×56 out
/// * conv3_x: 3×3/1, 128→128, 28×28 out
/// * conv4_x: 3×3/1, 256→256, 14×14 out
/// * conv5_x: 3×3/1, 512→512, 7×7 out
pub fn resnet50_layers(n: u64) -> Vec<NamedLayer> {
    vec![
        NamedLayer {
            name: "conv1",
            shape: ConvShape::new(n, 3, 64, 112, 112, 7, 7, 2, 2),
        },
        NamedLayer {
            name: "conv2_x",
            shape: ConvShape::new(n, 64, 64, 56, 56, 3, 3, 1, 1),
        },
        NamedLayer {
            name: "conv3_x",
            shape: ConvShape::new(n, 128, 128, 28, 28, 3, 3, 1, 1),
        },
        NamedLayer {
            name: "conv4_x",
            shape: ConvShape::new(n, 256, 256, 14, 14, 3, 3, 1, 1),
        },
        NamedLayer {
            name: "conv5_x",
            shape: ConvShape::new(n, 512, 512, 7, 7, 3, 3, 1, 1),
        },
    ]
}

/// AlexNet convolution sizes (Krizhevsky et al., as used in §3.2).
pub fn alexnet_layers(n: u64) -> Vec<NamedLayer> {
    vec![
        NamedLayer {
            name: "alex1",
            shape: ConvShape::new(n, 3, 96, 55, 55, 11, 11, 4, 4),
        },
        NamedLayer {
            name: "alex2",
            shape: ConvShape::new(n, 96, 256, 27, 27, 5, 5, 1, 1),
        },
        NamedLayer {
            name: "alex3",
            shape: ConvShape::new(n, 256, 384, 13, 13, 3, 3, 1, 1),
        },
        NamedLayer {
            name: "alex4",
            shape: ConvShape::new(n, 384, 384, 13, 13, 3, 3, 1, 1),
        },
        NamedLayer {
            name: "alex5",
            shape: ConvShape::new(n, 384, 256, 13, 13, 3, 3, 1, 1),
        },
    ]
}

/// Look up a layer by name across both catalogs.
pub fn find_layer(name: &str, n: u64) -> Option<NamedLayer> {
    resnet50_layers(n)
        .into_iter()
        .chain(alexnet_layers(n))
        .find(|l| l.name == name)
}

/// Uniformly scale a shape's channel/spatial dims down by `k` (keeping
/// filters and strides) — used to make runnable-size variants of the real
/// layer shapes for the e2e driver.
pub fn scaled(shape: ConvShape, k: u64) -> ConvShape {
    ConvShape {
        n: shape.n,
        c_i: (shape.c_i / k).max(1),
        c_o: (shape.c_o / k).max(1),
        w_o: (shape.w_o / k).max(shape.w_f),
        h_o: (shape.h_o / k).max(shape.h_f),
        ..shape
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet_has_five_layers() {
        let layers = resnet50_layers(1000);
        assert_eq!(layers.len(), 5);
        assert_eq!(layers[0].name, "conv1");
        assert_eq!(layers[0].shape.w_f, 7);
        assert_eq!(layers[0].shape.s_w, 2);
        assert_eq!(layers[4].shape.c_o, 512);
    }

    #[test]
    fn paper_assumptions_hold_for_all_catalog_layers() {
        for l in resnet50_layers(1000).into_iter().chain(alexnet_layers(1000)) {
            assert!(
                l.shape.paper_assumptions_hold(),
                "{} violates paper assumptions",
                l.name
            );
        }
    }

    #[test]
    fn conv1_sizes() {
        // |O| for conv1 at batch 1: 64·112·112
        let s = resnet50_layers(1).remove(0).shape;
        assert_eq!(s.output_size(), 64 * 112 * 112);
        assert_eq!(s.filter_size(), 3 * 64 * 7 * 7);
        // G = N cI cO wO hO wF hF
        assert_eq!(s.updates(), 3 * 64 * 112 * 112 * 49);
    }

    #[test]
    fn find_layer_works() {
        assert!(find_layer("conv3_x", 10).is_some());
        assert!(find_layer("alex2", 10).is_some());
        assert!(find_layer("nope", 10).is_none());
    }

    #[test]
    fn scaled_keeps_validity() {
        let s = resnet50_layers(4).remove(1).shape;
        let t = scaled(s, 8);
        assert_eq!(t.c_i, 8);
        assert_eq!(t.c_o, 8);
        assert_eq!(t.w_o, 7);
        assert!(t.paper_assumptions_hold());
    }
}
