//! The naive in-Rust 7NL CNN execution — the crate's own oracle.
//!
//! The PJRT runtime's outputs (Pallas kernel, im2col kernel, full network)
//! are validated against this implementation; it is also the "naive"
//! algorithm whose communication volume Figure 2 charts.

use super::shapes::ConvShape;
use super::tensor::Tensor4;

/// Validate the (image, filter) operand shapes against `s` under the
/// paper's input convention `WI ≥ σw(wO−1)+wF` — the one shape contract
/// every in-tree conv kernel (naive, im2col, tiled) enforces identically.
pub fn assert_conv_operands(x: &Tensor4, w: &Tensor4, s: &ConvShape) {
    let (n, c_i, c_o) = (s.n as usize, s.c_i as usize, s.c_o as usize);
    let (w_o, h_o) = (s.w_o as usize, s.h_o as usize);
    let (w_f, h_f) = (s.w_f as usize, s.h_f as usize);
    let (sw, sh) = (s.s_w as usize, s.s_h as usize);
    assert_eq!(x.dims[0], n, "batch mismatch");
    assert_eq!(x.dims[1], c_i, "input channel mismatch");
    // max(1) so zero-extent outputs (degenerate shapes) don't underflow
    assert!(x.dims[2] >= sw * (w_o.max(1) - 1) + w_f, "input width too small");
    assert!(x.dims[3] >= sh * (h_o.max(1) - 1) + h_f, "input height too small");
    assert_eq!(w.dims, [c_i, c_o, w_f, h_f], "filter shape mismatch");
}

/// Execute the seven-loop nest exactly as written in the paper (eq. 1).
///
/// `x`: (N, cI, WI, HI) with WI ≥ σw(wO−1)+wF, `w`: (cI, cO, wF, hF).
/// Returns (N, cO, wO, hO).
pub fn conv7nl_naive(x: &Tensor4, w: &Tensor4, s: &ConvShape) -> Tensor4 {
    assert_conv_operands(x, w, s);
    let (n, c_i, c_o) = (s.n as usize, s.c_i as usize, s.c_o as usize);
    let (w_o, h_o) = (s.w_o as usize, s.h_o as usize);
    let (w_f, h_f) = (s.w_f as usize, s.h_f as usize);
    let (sw, sh) = (s.s_w as usize, s.s_h as usize);

    let mut out = Tensor4::zeros([n, c_o, w_o, h_o]);
    // Loop order chosen for locality of the inner accumulation; any order
    // computes the same result (the paper's reorderability premise).
    for i1 in 0..n {
        for i3 in 0..c_o {
            for i2 in 0..c_i {
                for i6 in 0..w_f {
                    for i7 in 0..h_f {
                        let f = w.at(i2, i3, i6, i7);
                        if f == 0.0 {
                            continue;
                        }
                        for i4 in 0..w_o {
                            for i5 in 0..h_o {
                                *out.at_mut(i1, i3, i4, i5) +=
                                    x.at(i1, i2, sw * i4 + i6, sh * i5 + i7) * f;
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 1×1 filter, unit stride: conv reduces to a per-pixel channel matmul.
    #[test]
    fn one_by_one_filter_is_channel_matmul() {
        let s = ConvShape::new(1, 2, 3, 2, 2, 1, 1, 1, 1);
        let mut x = Tensor4::zeros([1, 2, 3, 3]);
        let mut w = Tensor4::zeros([2, 3, 1, 1]);
        // x[c=0] = 1 everywhere, x[c=1] = 2 everywhere
        for i in 0..3 {
            for j in 0..3 {
                *x.at_mut(0, 0, i, j) = 1.0;
                *x.at_mut(0, 1, i, j) = 2.0;
            }
        }
        // w[ci, co] = ci + co
        for ci in 0..2 {
            for co in 0..3 {
                *w.at_mut(ci, co, 0, 0) = (ci + co) as f32;
            }
        }
        let out = conv7nl_naive(&x, &w, &s);
        // out[co] = 1·(0+co) + 2·(1+co) = 3co + 2
        for co in 0..3 {
            for i in 0..2 {
                for j in 0..2 {
                    assert_eq!(out.at(0, co, i, j), (3 * co + 2) as f32);
                }
            }
        }
    }

    /// Identity filter (delta at tap 0,0) passes the input through.
    #[test]
    fn delta_filter_is_identity() {
        let s = ConvShape::new(1, 1, 1, 4, 4, 2, 2, 1, 1);
        let x = Tensor4::randn([1, 1, 6, 6], 5);
        let mut w = Tensor4::zeros([1, 1, 2, 2]);
        *w.at_mut(0, 0, 0, 0) = 1.0;
        let out = conv7nl_naive(&x, &w, &s);
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(out.at(0, 0, i, j), x.at(0, 0, i, j));
            }
        }
    }

    /// Box filter of ones computes window sums; check one window by hand.
    #[test]
    fn box_filter_window_sum() {
        let s = ConvShape::new(1, 1, 1, 2, 2, 2, 2, 2, 2);
        let mut x = Tensor4::zeros([1, 1, 6, 6]);
        for i in 0..6 {
            for j in 0..6 {
                *x.at_mut(0, 0, i, j) = (i * 6 + j) as f32;
            }
        }
        let mut w = Tensor4::zeros([1, 1, 2, 2]);
        for a in 0..2 {
            for b in 0..2 {
                *w.at_mut(0, 0, a, b) = 1.0;
            }
        }
        let out = conv7nl_naive(&x, &w, &s);
        // window at output (1,1): input rows 2..3, cols 2..3
        let expect = (2 * 6 + 2) + (2 * 6 + 3) + (3 * 6 + 2) + (3 * 6 + 3);
        assert_eq!(out.at(0, 0, 1, 1), expect as f32);
    }

    /// Linearity: conv(x, a·w1 + b·w2) = a·conv(x,w1) + b·conv(x,w2).
    #[test]
    fn linear_in_filter() {
        let s = ConvShape::new(2, 3, 2, 3, 3, 3, 3, 1, 1);
        let x = Tensor4::randn([2, 3, 6, 6], 1);
        let w1 = Tensor4::randn([3, 2, 3, 3], 2);
        let w2 = Tensor4::randn([3, 2, 3, 3], 3);
        let mut wc = w1.clone();
        for (c, (a, b)) in wc.data.iter_mut().zip(w1.data.iter().zip(&w2.data)) {
            *c = 2.0 * a - 0.5 * b;
        }
        let o1 = conv7nl_naive(&x, &w1, &s);
        let o2 = conv7nl_naive(&x, &w2, &s);
        let oc = conv7nl_naive(&x, &wc, &s);
        let mut expect = o1.clone();
        for (e, (a, b)) in expect.data.iter_mut().zip(o1.data.iter().zip(&o2.data)) {
            *e = 2.0 * a - 0.5 * b;
        }
        assert!(oc.max_abs_diff(&expect) < 1e-4);
    }
}
