//! Training-time convolutions: the backward passes as 7NL CNN instances.
//!
//! The paper analyzes the forward 7NL loop nest; a training step runs two
//! more computations of exactly the same algebraic shape (three arrays, one
//! contraction per tap), so Theorems 2.1–2.3 and every tiling in this crate
//! apply to them with the roles permuted:
//!
//! * **dFilter** — `dF(ci,co,i6,i7) += In(..)·dOut(..)`: the "output" array
//!   is the filter, the contraction runs over (N, wO, hO).
//! * **dInput** — `dIn(..) += dOut(..)·F(..)`: the "output" array is the
//!   input image, the contraction runs over (cO, i6, i7).
//!
//! [`backward_shapes`] produces the permuted [`ConvShape`]s, and the naive
//! oracles here validate the AOT gradient artifacts end to end.

use super::shapes::{ConvShape, Precision};
use super::tensor::Tensor4;

/// The three convolution passes of one training step, as instantiations of
/// the same 7NL machinery. The tiled engine (`kernels/`) is generic over
/// this enum: each pass maps its seven loops onto the nine blocked LP dims
/// (`ConvPass::lp_shape` / [`ConvPass::lp_precision`] feed the §3.2
/// blocking LP the pass's permuted operand sizes), and the per-pass
/// kernels realize the blocking with the accumulation order of the naive
/// oracles below, so tiled backward execution is bitwise identical to
/// [`dfilter_naive`] / [`dinput_naive`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum ConvPass {
    Forward,
    DFilter,
    DInput,
}

impl ConvPass {
    pub const ALL: [ConvPass; 3] =
        [ConvPass::Forward, ConvPass::DFilter, ConvPass::DInput];

    pub fn name(self) -> &'static str {
        match self {
            ConvPass::Forward => "fwd",
            ConvPass::DFilter => "dfilter",
            ConvPass::DInput => "dinput",
        }
    }

    pub fn parse(s: &str) -> Option<ConvPass> {
        match s {
            "fwd" | "forward" => Some(ConvPass::Forward),
            "dfilter" => Some(ConvPass::DFilter),
            "dinput" => Some(ConvPass::DInput),
            _ => None,
        }
    }

    /// The permuted 7NL shape whose nine loop ranges the §3.2 blocking LP
    /// solves for this pass: the forward shape itself, the
    /// [`backward_shapes`] dFilter permutation (output = the filter
    /// gradient, batch contracted), or the channel-swapped forward shape
    /// for dInput (cO contracted, cI owned by the output).
    pub fn lp_shape(self, s: &ConvShape) -> ConvShape {
        match self {
            ConvPass::Forward => *s,
            ConvPass::DFilter => backward_shapes(*s).dfilter,
            ConvPass::DInput => ConvShape { c_i: s.c_o, c_o: s.c_i, ..*s },
        }
    }

    /// Precision triple under this pass's (input, filter, output) role
    /// map: (In, F, Out), (In, dOut, dF), (dOut, F, dIn).
    pub fn lp_precision(self, p: Precision) -> Precision {
        match self {
            ConvPass::Forward => p,
            ConvPass::DFilter => dfilter_precision(p),
            ConvPass::DInput => dinput_precision(p),
        }
    }

    /// Output tensor dims of this pass on layer `s`.
    pub fn out_dims(self, s: &ConvShape) -> [usize; 4] {
        match self {
            ConvPass::Forward => [
                s.n as usize,
                s.c_o as usize,
                s.w_o as usize,
                s.h_o as usize,
            ],
            ConvPass::DFilter => s.filter_dims(),
            ConvPass::DInput => [
                s.n as usize,
                s.c_i as usize,
                s.in_w() as usize,
                s.in_h() as usize,
            ],
        }
    }

    /// Run this pass's naive oracle on its `(a, b)` operands: the 7NL
    /// nest for forward, [`dfilter_naive`] / [`dinput_naive`] (at the
    /// paper-convention input extent) for the gradients. The one dispatch
    /// every check path — CLI `--check`, benches, property and unit tests
    /// — validates the tiled engine against.
    pub fn naive_oracle(self, a: &Tensor4, b: &Tensor4, s: &ConvShape) -> Tensor4 {
        match self {
            ConvPass::Forward => super::naive::conv7nl_naive(a, b, s),
            ConvPass::DFilter => dfilter_naive(a, b, s),
            ConvPass::DInput => {
                dinput_naive(a, b, s, s.in_w() as usize, s.in_h() as usize)
            }
        }
    }

    /// Operand tensor dims `(a, b)` in call order: (image, filter) for
    /// forward, (image, dOut) for dFilter, (dOut, filter) for dInput.
    pub fn operand_dims(self, s: &ConvShape) -> ([usize; 4], [usize; 4]) {
        let image = [
            s.n as usize,
            s.c_i as usize,
            s.in_w() as usize,
            s.in_h() as usize,
        ];
        let gout = [
            s.n as usize,
            s.c_o as usize,
            s.w_o as usize,
            s.h_o as usize,
        ];
        match self {
            ConvPass::Forward => (image, s.filter_dims()),
            ConvPass::DFilter => (image, gout),
            ConvPass::DInput => (gout, s.filter_dims()),
        }
    }
}

/// Validate the `(a, b)` operand shapes of `pass` on layer `s` — the
/// pass-generic extension of [`super::naive::assert_conv_operands`] (whose
/// relaxed image bound forward keeps).
pub fn assert_pass_operands(pass: ConvPass, a: &Tensor4, b: &Tensor4, s: &ConvShape) {
    match pass {
        ConvPass::Forward => super::naive::assert_conv_operands(a, b, s),
        ConvPass::DFilter => {
            // image under the same relaxed WI >= σw(wO−1)+wF bound the
            // forward kernels accept (max(1) guards degenerate outputs)
            assert_eq!(a.dims[0], s.n as usize, "batch mismatch");
            assert_eq!(a.dims[1], s.c_i as usize, "input channel mismatch");
            assert!(
                a.dims[2] as u64 >= s.s_w * (s.w_o.max(1) - 1) + s.w_f,
                "input width too small"
            );
            assert!(
                a.dims[3] as u64 >= s.s_h * (s.h_o.max(1) - 1) + s.h_f,
                "input height too small"
            );
            assert_eq!(
                b.dims,
                [s.n as usize, s.c_o as usize, s.w_o as usize, s.h_o as usize],
                "output-gradient shape mismatch"
            );
        }
        ConvPass::DInput => {
            assert_eq!(
                a.dims,
                [s.n as usize, s.c_o as usize, s.w_o as usize, s.h_o as usize],
                "output-gradient shape mismatch"
            );
            assert_eq!(b.dims, s.filter_dims(), "filter shape mismatch");
        }
    }
}

/// The three communication problems of one training step. `G` is identical
/// for all three (every MAC has a mirror in each pass).
#[derive(Debug, Clone, Copy)]
pub struct TrainingShapes {
    pub forward: ConvShape,
    /// dFilter as a 7NL instance: loop roles (N↔cI-contraction) permuted.
    /// Stored as the same ConvShape — sizes/G are what the bounds consume.
    pub dfilter: ConvShape,
    /// dInput as a 7NL instance.
    pub dinput: ConvShape,
}

/// Permute a forward shape into the two backward-problem shapes.
///
/// The 7NL bounds only see array sizes |I|, |F|, |O| and G; for dFilter the
/// "(input, filter, output)" triple is (In, dOut, dF) and for dInput it is
/// (dOut, F, dIn). We encode each as a ConvShape whose derived sizes match
/// that triple so `sequential_bound`/`parallel_bound` can be reused as-is.
pub fn backward_shapes(f: ConvShape) -> TrainingShapes {
    // dFilter: output array has |dF| = cI·cO·wF·hF elements; the batch axis
    // is the reduction. Swap N <-> cI? The clean encoding keeps the loop
    // ranges (identical G) but relabels which arrays the bounds weight:
    // treat (n) as the contracted channel. ConvShape cannot express the
    // permutation literally, so we produce the shape whose |I|,|F|,|O|
    // equal the dFilter problem's operand sizes:
    //   "input"  = In   (same as forward)
    //   "filter" = dOut (size N·cO·wO·hO)
    //   "output" = dF   (size cI·cO·wF·hF)
    // This is the transpose-convolution shape with (wF,hF) as the "output
    // image" and (wO,hO) as the "filter":
    let dfilter = ConvShape {
        n: f.c_i,       // i1 <- cI (indexes In and dF)
        c_i: f.n,       // i2 <- N (contracted, indexes In and dOut)
        c_o: f.c_o,     // i3 <- cO (indexes dOut and dF)
        w_o: f.w_f,     // output image = filter extent
        h_o: f.h_f,
        w_f: f.w_o,     // "filter" = output extent
        h_f: f.h_o,
        s_w: f.s_w,
        s_h: f.s_h,
    };
    // dInput: "input" = dOut, "filter" = F, "output" = dIn. Same loop
    // ranges as forward; operand roles swap In <-> Out, which the bounds
    // see through the precision/role assignment rather than the shape, so
    // the forward shape itself carries the right sizes when precisions are
    // permuted accordingly.
    TrainingShapes { forward: f, dfilter, dinput: f }
}

/// Precision triple for the dInput problem given forward precisions:
/// roles (I,F,O) = (dOut, F, dIn) → (p_O, p_F, p_I).
pub fn dinput_precision(p: Precision) -> Precision {
    Precision::new(p.p_o, p.p_f, p.p_i)
}

/// Precision triple for the dFilter problem given forward precisions:
/// roles (I,F,O) = (In, dOut, dF) → (p_I, p_O, p_F).
pub fn dfilter_precision(p: Precision) -> Precision {
    Precision::new(p.p_i, p.p_o, p.p_f)
}

/// Naive filter gradient: `dF(ci,co,i6,i7) += x(n,ci,σw·w+i6,σh·h+i7)·g(n,co,w,h)`.
pub fn dfilter_naive(x: &Tensor4, g: &Tensor4, s: &ConvShape) -> Tensor4 {
    let (n, c_i, c_o) = (s.n as usize, s.c_i as usize, s.c_o as usize);
    let (w_o, h_o) = (s.w_o as usize, s.h_o as usize);
    let (w_f, h_f) = (s.w_f as usize, s.h_f as usize);
    let (sw, sh) = (s.s_w as usize, s.s_h as usize);
    assert_eq!(g.dims, [n, c_o, w_o, h_o]);
    let mut out = Tensor4::zeros([c_i, c_o, w_f, h_f]);
    for i1 in 0..n {
        for i2 in 0..c_i {
            for i3 in 0..c_o {
                for i6 in 0..w_f {
                    for i7 in 0..h_f {
                        let mut acc = 0.0;
                        for i4 in 0..w_o {
                            for i5 in 0..h_o {
                                acc += x.at(i1, i2, sw * i4 + i6, sh * i5 + i7)
                                    * g.at(i1, i3, i4, i5);
                            }
                        }
                        *out.at_mut(i2, i3, i6, i7) += acc;
                    }
                }
            }
        }
    }
    out
}

/// Naive input gradient: `dIn(n,ci,σw·w+i6,σh·h+i7) += g(n,co,w,h)·F(ci,co,i6,i7)`.
pub fn dinput_naive(g: &Tensor4, w: &Tensor4, s: &ConvShape,
                    in_w: usize, in_h: usize) -> Tensor4 {
    let (n, c_i, c_o) = (s.n as usize, s.c_i as usize, s.c_o as usize);
    let (w_o, h_o) = (s.w_o as usize, s.h_o as usize);
    let (w_f, h_f) = (s.w_f as usize, s.h_f as usize);
    let (sw, sh) = (s.s_w as usize, s.s_h as usize);
    assert_eq!(g.dims, [n, c_o, w_o, h_o]);
    assert_eq!(w.dims, [c_i, c_o, w_f, h_f]);
    let mut out = Tensor4::zeros([n, c_i, in_w, in_h]);
    for i1 in 0..n {
        for i2 in 0..c_i {
            for i3 in 0..c_o {
                for i6 in 0..w_f {
                    for i7 in 0..h_f {
                        let f = w.at(i2, i3, i6, i7);
                        if f == 0.0 {
                            continue;
                        }
                        for i4 in 0..w_o {
                            for i5 in 0..h_o {
                                *out.at_mut(i1, i2, sw * i4 + i6, sh * i5 + i7) +=
                                    g.at(i1, i3, i4, i5) * f;
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bounds::sequential_bound;
    use crate::conv::conv7nl_naive;

    fn shape() -> ConvShape {
        ConvShape::new(2, 3, 4, 5, 5, 3, 3, 1, 1)
    }

    #[test]
    fn backward_shapes_preserve_g() {
        let t = backward_shapes(shape());
        assert_eq!(t.forward.updates(), t.dfilter.updates());
        assert_eq!(t.forward.updates(), t.dinput.updates());
    }

    #[test]
    fn dfilter_shape_sizes_are_the_permuted_operands() {
        let f = shape();
        let t = backward_shapes(f);
        // |O| of the dfilter problem = |F| of the forward problem
        assert_eq!(t.dfilter.output_size(), f.filter_size());
        // "filter" operand of dfilter = dOut
        assert_eq!(t.dfilter.filter_size(), f.output_size());
    }

    #[test]
    fn bounds_apply_to_backward_problems() {
        let t = backward_shapes(shape().with_batch(64));
        let p = Precision::uniform();
        for s in [t.forward, t.dfilter, t.dinput] {
            assert!(sequential_bound(&s, p, 4096.0) > 0.0);
        }
    }

    /// <conv(x,w), g> gradients: dfilter/dinput oracles vs a finite
    /// difference of the forward naive conv.
    #[test]
    fn naive_grads_match_finite_difference() {
        let s = ConvShape::new(1, 2, 2, 3, 3, 2, 2, 1, 1);
        let x = Tensor4::randn([1, 2, 5, 5], 1);
        let w = Tensor4::randn([2, 2, 2, 2], 2);
        let g = Tensor4::randn([1, 2, 3, 3], 3);

        let loss = |x: &Tensor4, w: &Tensor4| -> f32 {
            let out = conv7nl_naive(x, w, &s);
            out.data.iter().zip(&g.data).map(|(a, b)| a * b).sum()
        };

        let dw = dfilter_naive(&x, &g, &s);
        let dx = dinput_naive(&g, &w, &s, 5, 5);

        let eps = 1e-2_f32;
        // spot-check a few coordinates of each gradient
        for idx in [0usize, 3, 7] {
            let mut wp = w.clone();
            wp.data[idx] += eps;
            let num = (loss(&x, &wp) - loss(&x, &w)) / eps;
            assert!((num - dw.data[idx]).abs() < 0.05 * dw.data[idx].abs().max(1.0),
                    "dW[{idx}]: fd {num} vs {}", dw.data[idx]);

            let mut xp = x.clone();
            xp.data[idx] += eps;
            let num = (loss(&xp, &w) - loss(&x, &w)) / eps;
            assert!((num - dx.data[idx]).abs() < 0.05 * dx.data[idx].abs().max(1.0),
                    "dX[{idx}]: fd {num} vs {}", dx.data[idx]);
        }
    }

    #[test]
    fn dinput_precision_swaps_roles() {
        let p = Precision::new(0.25, 0.5, 1.0);
        let q = dinput_precision(p);
        assert_eq!((q.p_i, q.p_f, q.p_o), (1.0, 0.5, 0.25));
        let r = dfilter_precision(p);
        assert_eq!((r.p_i, r.p_f, r.p_o), (0.25, 1.0, 0.5));
    }

    #[test]
    fn pass_names_roundtrip() {
        for pass in ConvPass::ALL {
            assert_eq!(ConvPass::parse(pass.name()), Some(pass));
        }
        assert_eq!(ConvPass::parse("forward"), Some(ConvPass::Forward));
        assert_eq!(ConvPass::parse("dweight"), None);
    }

    #[test]
    fn pass_dims_match_oracles() {
        let s = ConvShape::new(2, 3, 4, 5, 6, 3, 2, 1, 1);
        let (xa, xb) = ConvPass::DFilter.operand_dims(&s);
        let x = Tensor4::randn(xa, 1);
        let g = Tensor4::randn(xb, 2);
        assert_eq!(dfilter_naive(&x, &g, &s).dims, ConvPass::DFilter.out_dims(&s));
        assert_pass_operands(ConvPass::DFilter, &x, &g, &s);

        let (ga, gb) = ConvPass::DInput.operand_dims(&s);
        let g2 = Tensor4::randn(ga, 3);
        let w = Tensor4::randn(gb, 4);
        let din = dinput_naive(&g2, &w, &s, s.in_w() as usize, s.in_h() as usize);
        assert_eq!(din.dims, ConvPass::DInput.out_dims(&s));
        assert_pass_operands(ConvPass::DInput, &g2, &w, &s);
    }

    #[test]
    fn lp_shapes_carry_the_permuted_operand_sizes() {
        let s = ConvShape::new(4, 3, 8, 10, 10, 5, 5, 2, 2);
        // dFilter: LP "output" = |dF|, LP "filter" = |dOut|
        let df = ConvPass::DFilter.lp_shape(&s);
        assert_eq!(df.output_size(), s.filter_size());
        assert_eq!(df.filter_size(), s.output_size());
        // dInput: channel swap puts the contracted cO in the cI slot
        let di = ConvPass::DInput.lp_shape(&s);
        assert_eq!(di.c_i, s.c_o);
        assert_eq!(di.c_o, s.c_i);
        assert_eq!(ConvPass::Forward.lp_shape(&s), s);
    }
}
