//! Training-time convolutions: the backward passes as 7NL CNN instances.
//!
//! The paper analyzes the forward 7NL loop nest; a training step runs two
//! more computations of exactly the same algebraic shape (three arrays, one
//! contraction per tap), so Theorems 2.1–2.3 and every tiling in this crate
//! apply to them with the roles permuted:
//!
//! * **dFilter** — `dF(ci,co,i6,i7) += In(..)·dOut(..)`: the "output" array
//!   is the filter, the contraction runs over (N, wO, hO).
//! * **dInput** — `dIn(..) += dOut(..)·F(..)`: the "output" array is the
//!   input image, the contraction runs over (cO, i6, i7).
//!
//! [`backward_shapes`] produces the permuted [`ConvShape`]s, and the naive
//! oracles here validate the AOT gradient artifacts end to end.

use super::shapes::{ConvShape, Precision};
use super::tensor::Tensor4;

/// The three communication problems of one training step. `G` is identical
/// for all three (every MAC has a mirror in each pass).
#[derive(Debug, Clone, Copy)]
pub struct TrainingShapes {
    pub forward: ConvShape,
    /// dFilter as a 7NL instance: loop roles (N↔cI-contraction) permuted.
    /// Stored as the same ConvShape — sizes/G are what the bounds consume.
    pub dfilter: ConvShape,
    /// dInput as a 7NL instance.
    pub dinput: ConvShape,
}

/// Permute a forward shape into the two backward-problem shapes.
///
/// The 7NL bounds only see array sizes |I|, |F|, |O| and G; for dFilter the
/// "(input, filter, output)" triple is (In, dOut, dF) and for dInput it is
/// (dOut, F, dIn). We encode each as a ConvShape whose derived sizes match
/// that triple so `sequential_bound`/`parallel_bound` can be reused as-is.
pub fn backward_shapes(f: ConvShape) -> TrainingShapes {
    // dFilter: output array has |dF| = cI·cO·wF·hF elements; the batch axis
    // is the reduction. Swap N <-> cI? The clean encoding keeps the loop
    // ranges (identical G) but relabels which arrays the bounds weight:
    // treat (n) as the contracted channel. ConvShape cannot express the
    // permutation literally, so we produce the shape whose |I|,|F|,|O|
    // equal the dFilter problem's operand sizes:
    //   "input"  = In   (same as forward)
    //   "filter" = dOut (size N·cO·wO·hO)
    //   "output" = dF   (size cI·cO·wF·hF)
    // This is the transpose-convolution shape with (wF,hF) as the "output
    // image" and (wO,hO) as the "filter":
    let dfilter = ConvShape {
        n: f.c_i,       // i1 <- cI (indexes In and dF)
        c_i: f.n,       // i2 <- N (contracted, indexes In and dOut)
        c_o: f.c_o,     // i3 <- cO (indexes dOut and dF)
        w_o: f.w_f,     // output image = filter extent
        h_o: f.h_f,
        w_f: f.w_o,     // "filter" = output extent
        h_f: f.h_o,
        s_w: f.s_w,
        s_h: f.s_h,
    };
    // dInput: "input" = dOut, "filter" = F, "output" = dIn. Same loop
    // ranges as forward; operand roles swap In <-> Out, which the bounds
    // see through the precision/role assignment rather than the shape, so
    // the forward shape itself carries the right sizes when precisions are
    // permuted accordingly.
    TrainingShapes { forward: f, dfilter, dinput: f }
}

/// Precision triple for the dInput problem given forward precisions:
/// roles (I,F,O) = (dOut, F, dIn) → (p_O, p_F, p_I).
pub fn dinput_precision(p: Precision) -> Precision {
    Precision::new(p.p_o, p.p_f, p.p_i)
}

/// Naive filter gradient: `dF(ci,co,i6,i7) += x(n,ci,σw·w+i6,σh·h+i7)·g(n,co,w,h)`.
pub fn dfilter_naive(x: &Tensor4, g: &Tensor4, s: &ConvShape) -> Tensor4 {
    let (n, c_i, c_o) = (s.n as usize, s.c_i as usize, s.c_o as usize);
    let (w_o, h_o) = (s.w_o as usize, s.h_o as usize);
    let (w_f, h_f) = (s.w_f as usize, s.h_f as usize);
    let (sw, sh) = (s.s_w as usize, s.s_h as usize);
    assert_eq!(g.dims, [n, c_o, w_o, h_o]);
    let mut out = Tensor4::zeros([c_i, c_o, w_f, h_f]);
    for i1 in 0..n {
        for i2 in 0..c_i {
            for i3 in 0..c_o {
                for i6 in 0..w_f {
                    for i7 in 0..h_f {
                        let mut acc = 0.0;
                        for i4 in 0..w_o {
                            for i5 in 0..h_o {
                                acc += x.at(i1, i2, sw * i4 + i6, sh * i5 + i7)
                                    * g.at(i1, i3, i4, i5);
                            }
                        }
                        *out.at_mut(i2, i3, i6, i7) += acc;
                    }
                }
            }
        }
    }
    out
}

/// Naive input gradient: `dIn(n,ci,σw·w+i6,σh·h+i7) += g(n,co,w,h)·F(ci,co,i6,i7)`.
pub fn dinput_naive(g: &Tensor4, w: &Tensor4, s: &ConvShape,
                    in_w: usize, in_h: usize) -> Tensor4 {
    let (n, c_i, c_o) = (s.n as usize, s.c_i as usize, s.c_o as usize);
    let (w_o, h_o) = (s.w_o as usize, s.h_o as usize);
    let (w_f, h_f) = (s.w_f as usize, s.h_f as usize);
    let (sw, sh) = (s.s_w as usize, s.s_h as usize);
    assert_eq!(g.dims, [n, c_o, w_o, h_o]);
    assert_eq!(w.dims, [c_i, c_o, w_f, h_f]);
    let mut out = Tensor4::zeros([n, c_i, in_w, in_h]);
    for i1 in 0..n {
        for i2 in 0..c_i {
            for i3 in 0..c_o {
                for i6 in 0..w_f {
                    for i7 in 0..h_f {
                        let f = w.at(i2, i3, i6, i7);
                        if f == 0.0 {
                            continue;
                        }
                        for i4 in 0..w_o {
                            for i5 in 0..h_o {
                                *out.at_mut(i1, i2, sw * i4 + i6, sh * i5 + i7) +=
                                    g.at(i1, i3, i4, i5) * f;
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bounds::sequential_bound;
    use crate::conv::conv7nl_naive;

    fn shape() -> ConvShape {
        ConvShape::new(2, 3, 4, 5, 5, 3, 3, 1, 1)
    }

    #[test]
    fn backward_shapes_preserve_g() {
        let t = backward_shapes(shape());
        assert_eq!(t.forward.updates(), t.dfilter.updates());
        assert_eq!(t.forward.updates(), t.dinput.updates());
    }

    #[test]
    fn dfilter_shape_sizes_are_the_permuted_operands() {
        let f = shape();
        let t = backward_shapes(f);
        // |O| of the dfilter problem = |F| of the forward problem
        assert_eq!(t.dfilter.output_size(), f.filter_size());
        // "filter" operand of dfilter = dOut
        assert_eq!(t.dfilter.filter_size(), f.output_size());
    }

    #[test]
    fn bounds_apply_to_backward_problems() {
        let t = backward_shapes(shape().with_batch(64));
        let p = Precision::uniform();
        for s in [t.forward, t.dfilter, t.dinput] {
            assert!(sequential_bound(&s, p, 4096.0) > 0.0);
        }
    }

    /// <conv(x,w), g> gradients: dfilter/dinput oracles vs a finite
    /// difference of the forward naive conv.
    #[test]
    fn naive_grads_match_finite_difference() {
        let s = ConvShape::new(1, 2, 2, 3, 3, 2, 2, 1, 1);
        let x = Tensor4::randn([1, 2, 5, 5], 1);
        let w = Tensor4::randn([2, 2, 2, 2], 2);
        let g = Tensor4::randn([1, 2, 3, 3], 3);

        let loss = |x: &Tensor4, w: &Tensor4| -> f32 {
            let out = conv7nl_naive(x, w, &s);
            out.data.iter().zip(&g.data).map(|(a, b)| a * b).sum()
        };

        let dw = dfilter_naive(&x, &g, &s);
        let dx = dinput_naive(&g, &w, &s, 5, 5);

        let eps = 1e-2_f32;
        // spot-check a few coordinates of each gradient
        for idx in [0usize, 3, 7] {
            let mut wp = w.clone();
            wp.data[idx] += eps;
            let num = (loss(&x, &wp) - loss(&x, &w)) / eps;
            assert!((num - dw.data[idx]).abs() < 0.05 * dw.data[idx].abs().max(1.0),
                    "dW[{idx}]: fd {num} vs {}", dw.data[idx]);

            let mut xp = x.clone();
            xp.data[idx] += eps;
            let num = (loss(&xp, &w) - loss(&x, &w)) / eps;
            assert!((num - dx.data[idx]).abs() < 0.05 * dx.data[idx].abs().max(1.0),
                    "dX[{idx}]: fd {num} vs {}", dx.data[idx]);
        }
    }

    #[test]
    fn dinput_precision_swaps_roles() {
        let p = Precision::new(0.25, 0.5, 1.0);
        let q = dinput_precision(p);
        assert_eq!((q.p_i, q.p_f, q.p_o), (1.0, 0.5, 0.25));
    }
}
