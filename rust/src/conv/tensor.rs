//! A minimal dense 4-D f32 tensor (row-major, NCWH index order as in the
//! paper's loop nest). This is the host-side data container the runtime
//! feeds to PJRT and the naive validator computes over.

use crate::util::rng::Rng;

/// Dense 4-D tensor, row-major over (d0, d1, d2, d3).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor4 {
    pub dims: [usize; 4],
    pub data: Vec<f32>,
}

impl Tensor4 {
    pub fn zeros(dims: [usize; 4]) -> Tensor4 {
        Tensor4 { dims, data: vec![0.0; dims.iter().product()] }
    }

    /// Filled with deterministic normal-ish noise from `seed`.
    pub fn randn(dims: [usize; 4], seed: u64) -> Tensor4 {
        let mut rng = Rng::new(seed);
        Tensor4 { dims, data: rng.normal_vec(dims.iter().product()) }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn idx(&self, a: usize, b: usize, c: usize, d: usize) -> usize {
        debug_assert!(a < self.dims[0] && b < self.dims[1]
            && c < self.dims[2] && d < self.dims[3]);
        ((a * self.dims[1] + b) * self.dims[2] + c) * self.dims[3] + d
    }

    #[inline]
    pub fn at(&self, a: usize, b: usize, c: usize, d: usize) -> f32 {
        self.data[self.idx(a, b, c, d)]
    }

    #[inline]
    pub fn at_mut(&mut self, a: usize, b: usize, c: usize, d: usize) -> &mut f32 {
        let i = self.idx(a, b, c, d);
        &mut self.data[i]
    }

    /// Max |a-b| over all elements (shape must match).
    pub fn max_abs_diff(&self, other: &Tensor4) -> f32 {
        assert_eq!(self.dims, other.dims, "shape mismatch");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Relative L2 error ‖a−b‖/‖b‖ (0 when both are zero).
    pub fn rel_l2(&self, other: &Tensor4) -> f32 {
        assert_eq!(self.dims, other.dims, "shape mismatch");
        let num: f32 = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).powi(2))
            .sum();
        let den: f32 = other.data.iter().map(|b| b * b).sum();
        if den == 0.0 {
            num.sqrt()
        } else {
            (num / den).sqrt()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_len() {
        let t = Tensor4::zeros([2, 3, 4, 5]);
        assert_eq!(t.len(), 120);
        assert!(t.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn indexing_row_major() {
        let mut t = Tensor4::zeros([2, 2, 2, 2]);
        *t.at_mut(1, 1, 1, 1) = 5.0;
        assert_eq!(t.data[15], 5.0);
        *t.at_mut(0, 0, 0, 1) = 3.0;
        assert_eq!(t.data[1], 3.0);
        assert_eq!(t.at(1, 1, 1, 1), 5.0);
    }

    #[test]
    fn randn_deterministic() {
        let a = Tensor4::randn([1, 2, 3, 4], 99);
        let b = Tensor4::randn([1, 2, 3, 4], 99);
        assert_eq!(a, b);
        let c = Tensor4::randn([1, 2, 3, 4], 100);
        assert_ne!(a, c);
    }

    #[test]
    fn diff_metrics() {
        let a = Tensor4::zeros([1, 1, 1, 3]);
        let mut b = Tensor4::zeros([1, 1, 1, 3]);
        b.data = vec![0.0, 3.0, 4.0];
        assert_eq!(a.max_abs_diff(&b), 4.0);
        assert!((a.rel_l2(&b) - 1.0).abs() < 1e-6);
        assert_eq!(b.rel_l2(&b), 0.0);
    }
}
