//! Convolution problem definitions: the 7NL CNN shape model (paper §2.1),
//! mixed-precision word model, the ResNet-50 / AlexNet layer catalogs used
//! throughout the evaluation, and a native tensor + naive convolution used
//! to validate the PJRT runtime end to end.

pub mod catalog;
pub mod naive;
pub mod shapes;
pub mod tensor;
pub mod training;

pub use catalog::{alexnet_layers, find_layer, resnet50_layers, scaled};
pub use naive::conv7nl_naive;
pub use shapes::{ConvShape, Precision};
pub use tensor::Tensor4;
pub use training::{backward_shapes, dfilter_naive, dinput_naive, TrainingShapes};
