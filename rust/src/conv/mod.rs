//! Convolution problem definitions: the 7NL CNN shape model (paper §2.1),
//! mixed-precision word model, the ResNet-50 / AlexNet layer catalogs used
//! throughout the evaluation, and a native tensor + naive convolution used
//! to validate the PJRT runtime end to end.

pub mod catalog;
pub mod naive;
pub mod shapes;
pub mod tensor;
pub mod training;

pub use catalog::{alexnet_layers, find_layer, resnet50_layers, scaled};
pub use naive::{assert_conv_operands, conv7nl_naive};
pub use shapes::{ConvShape, NetworkStage, Precision};
pub use tensor::Tensor4;
pub use training::{
    assert_pass_operands, backward_shapes, dfilter_naive, dfilter_precision,
    dinput_naive, dinput_precision, ConvPass, TrainingShapes,
};

/// Random paper-convention operands for `s`: image `(N, cI, WI, HI)` with
/// `WI = σw·wO + wF` seeded from `seed`, filter `(cI, cO, wF, hF)` seeded
/// from `seed + 1`. The one constructor the kernels, benches, examples and
/// tests all share, so the input-sizing convention lives in a single place.
pub fn paper_operands(s: &ConvShape, seed: u64) -> (Tensor4, Tensor4) {
    let x = Tensor4::randn(
        [s.n as usize, s.c_i as usize, s.in_w() as usize, s.in_h() as usize],
        seed,
    );
    let w = Tensor4::randn(s.filter_dims(), seed + 1);
    (x, w)
}

/// Random operands for one pass of `s`, in the pass's `(a, b)` call order
/// ([`ConvPass::operand_dims`]): the pass-generic extension of
/// [`paper_operands`] (which it reproduces exactly for the forward pass).
pub fn pass_operands(pass: ConvPass, s: &ConvShape, seed: u64) -> (Tensor4, Tensor4) {
    let (da, db) = pass.operand_dims(s);
    (Tensor4::randn(da, seed), Tensor4::randn(db, seed + 1))
}
