//! Deterministic fault injection for the serving stack.
//!
//! A [`FaultPlan`] is parsed from a compact spec (CLI `--faults`, env
//! `CONVBOUND_FAULTS`) and installed process-globally; instrumented
//! *fault points* on the kernel and server hot paths consult it with one
//! relaxed atomic load when disarmed, so production runs pay nothing.
//!
//! Spec grammar (rules joined with `;`):
//!
//! ```text
//! spec   := rule (';' rule)*
//! rule   := site ':' action (':' param)*
//! site   := 'exec' | 'queue'
//! action := 'panic' | 'error' | 'stall'
//! param  := 'every=' N     fire on every N-th tick of the site (default 1)
//!         | 'ms=' K        stall duration in milliseconds (default 10)
//!         | 'times=' K     fire at most K times total (default 0 = unlimited)
//! ```
//!
//! Examples: `exec:panic:every=7` panics every 7th kernel tile;
//! `queue:stall:ms=50` turns the server's batch dispatch into a
//! deterministic slow backend; `exec:error:every=1:times=1` fails exactly
//! the first dispatch attempt (exercising the retry path).
//!
//! Determinism: rules tick monotone atomic counters — no clocks, no
//! randomness — so a given spec against a given workload fires at exactly
//! the same points on every run.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, Once, PoisonError};
use std::time::Duration;

use crate::err;
use crate::util::error::{Context, Result};

/// Marker prefix carried by every injected panic payload, so log readers
/// (and the quiet panic hook) can tell injected faults from real bugs.
pub const INJECTED_PANIC: &str = "injected fault";

/// Where a rule fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Site {
    /// Kernel execution hot paths (per-tile panic/stall, per-attempt error).
    Exec,
    /// The server executor's batch dispatch (stall = slow backend).
    Queue,
}

/// What a rule does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    Panic,
    Error,
    Stall,
}

/// One parsed fault rule.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Rule {
    pub site: Site,
    pub action: Action,
    /// Fire on every `every`-th tick of the site (1 = every tick).
    pub every: u64,
    /// Stall duration for [`Action::Stall`].
    pub ms: u64,
    /// Fire at most this many times; 0 = unlimited.
    pub times: u64,
}

/// A parsed, installable set of fault rules.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub rules: Vec<Rule>,
}

impl FaultPlan {
    /// Parse a spec string (see the module docs for the grammar).
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut rules = Vec::new();
        for part in spec.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            rules.push(Rule::parse(part).with_context(|| format!("fault rule '{part}'"))?);
        }
        if rules.is_empty() {
            return Err(err!("empty fault spec"));
        }
        Ok(FaultPlan { rules })
    }
}

impl Rule {
    fn parse(rule: &str) -> Result<Rule> {
        let mut segs = rule.split(':');
        let site = match segs.next().unwrap_or("") {
            "exec" => Site::Exec,
            "queue" => Site::Queue,
            other => return Err(err!("unknown site '{other}' (expected exec|queue)")),
        };
        let action = match segs.next().unwrap_or("") {
            "panic" => Action::Panic,
            "error" => Action::Error,
            "stall" => Action::Stall,
            other => return Err(err!("unknown action '{other}' (expected panic|error|stall)")),
        };
        if site == Site::Queue && action != Action::Stall {
            return Err(err!("site 'queue' only supports the 'stall' action"));
        }
        let mut rule = Rule { site, action, every: 1, ms: 10, times: 0 };
        for param in segs {
            let (key, val) = param
                .split_once('=')
                .ok_or_else(|| err!("parameter '{param}' is not key=value"))?;
            let val: u64 = val
                .parse()
                .map_err(|_| err!("parameter '{key}' value '{val}' is not an integer"))?;
            match key {
                "every" => {
                    if val == 0 {
                        return Err(err!("every=0 would never tick; use 1 for every tick"));
                    }
                    rule.every = val;
                }
                "ms" => rule.ms = val,
                "times" => rule.times = val,
                other => return Err(err!("unknown parameter '{other}' (expected every|ms|times)")),
            }
        }
        Ok(rule)
    }
}

/// An installed plan plus its per-rule tick state.
struct Active {
    plan: FaultPlan,
    /// Per-rule monotone tick counters (same order as `plan.rules`).
    ticks: Vec<AtomicU64>,
    /// Per-rule fire counts (for `times=` caps and test assertions).
    fires: Vec<AtomicU64>,
}

impl Active {
    /// Tick every rule matching (site, actions); returns the first rule
    /// that fires this tick (with its fire ordinal), if any.
    fn tick(&self, site: Site, actions: &[Action]) -> Option<(&Rule, u64)> {
        let mut fired = None;
        for (k, rule) in self.plan.rules.iter().enumerate() {
            if rule.site != site || !actions.contains(&rule.action) {
                continue;
            }
            let n = self.ticks[k].fetch_add(1, Ordering::Relaxed) + 1;
            if n % rule.every != 0 {
                continue;
            }
            let shot = self.fires[k].fetch_add(1, Ordering::Relaxed) + 1;
            if rule.times != 0 && shot > rule.times {
                continue;
            }
            if fired.is_none() {
                fired = Some((rule, shot));
            }
        }
        fired
    }
}

/// One-load fast path: true iff a plan is installed.
static ARMED: AtomicBool = AtomicBool::new(false);
static ACTIVE: Mutex<Option<Arc<Active>>> = Mutex::new(None);

fn active() -> Option<Arc<Active>> {
    ACTIVE
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .clone()
}

/// True iff a fault plan is installed (one relaxed load).
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Install a plan process-globally (replacing any previous one) and
/// silence the default panic-hook noise for injected panics.
pub fn install(plan: FaultPlan) {
    quiet_injected_panics();
    let active = Active {
        ticks: plan.rules.iter().map(|_| AtomicU64::new(0)).collect(),
        fires: plan.rules.iter().map(|_| AtomicU64::new(0)).collect(),
        plan,
    };
    *ACTIVE.lock().unwrap_or_else(PoisonError::into_inner) = Some(Arc::new(active));
    ARMED.store(true, Ordering::SeqCst);
}

/// Parse and install a spec string.
pub fn install_spec(spec: &str) -> Result<()> {
    install(FaultPlan::parse(spec)?);
    Ok(())
}

/// Disarm: remove any installed plan.
pub fn clear() {
    ARMED.store(false, Ordering::SeqCst);
    *ACTIVE.lock().unwrap_or_else(PoisonError::into_inner) = None;
}

/// Install from `CONVBOUND_FAULTS` if set (ignored when unset; a bad
/// spec is an error so CI can't silently run fault-free).
pub fn init_from_env() -> Result<()> {
    match std::env::var("CONVBOUND_FAULTS") {
        Ok(spec) if !spec.trim().is_empty() => {
            install_spec(&spec).context("CONVBOUND_FAULTS")
        }
        _ => Ok(()),
    }
}

/// Total fires across all rules of `site` so far.
pub fn fired(site: Site) -> u64 {
    let Some(a) = active() else { return 0 };
    a.plan
        .rules
        .iter()
        .zip(&a.fires)
        .filter(|(r, _)| r.site == site)
        .map(|(_, f)| f.load(Ordering::Relaxed))
        .sum()
}

/// Per-tile fault point on the kernel hot paths: panics or stalls when an
/// armed `exec:panic` / `exec:stall` rule fires. No-op (one atomic load)
/// when disarmed.
pub fn exec_point() {
    if !armed() {
        return;
    }
    let Some(a) = active() else { return };
    if let Some((rule, shot)) = a.tick(Site::Exec, &[Action::Panic, Action::Stall]) {
        match rule.action {
            Action::Stall => std::thread::sleep(Duration::from_millis(rule.ms)),
            _ => panic!("{INJECTED_PANIC}: exec panic (fire {shot})"),
        }
    }
}

/// Per-attempt fault point at executable dispatch: returns an injected
/// error when an `exec:error` rule fires.
pub fn exec_error_point() -> Result<()> {
    if !armed() {
        return Ok(());
    }
    let Some(a) = active() else { return Ok(()) };
    if let Some((_, shot)) = a.tick(Site::Exec, &[Action::Error]) {
        return Err(err!("{INJECTED_PANIC}: exec error (fire {shot})"));
    }
    Ok(())
}

/// Batch-dispatch fault point in the server executor: sleeps when a
/// `queue:stall` rule fires — a deterministic slow backend for
/// backpressure and deadline tests.
pub fn queue_point() {
    if !armed() {
        return;
    }
    let Some(a) = active() else { return };
    if let Some((rule, _)) = a.tick(Site::Queue, &[Action::Stall]) {
        std::thread::sleep(Duration::from_millis(rule.ms));
    }
}

/// Suppress the default panic-hook backtrace/noise for payloads carrying
/// the [`INJECTED_PANIC`] marker; every other panic still reports through
/// whatever hook was installed before.
fn quiet_injected_panics() {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let injected = info
                .payload()
                .downcast_ref::<String>()
                .map(|s| s.starts_with(INJECTED_PANIC))
                .or_else(|| {
                    info.payload()
                        .downcast_ref::<&str>()
                        .map(|s| s.starts_with(INJECTED_PANIC))
                })
                .unwrap_or(false);
            if !injected {
                prev(info);
            }
        }));
    });
}

/// Fault state is process-global; tests that *arm* faults serialize on
/// this gate so concurrent test threads cannot observe each other's
/// injections. Dropping the guard disarms.
static TEST_GATE: Mutex<()> = Mutex::new(());

/// RAII guard: holds the global test gate with a plan installed; disarms
/// on drop. Use from integration tests only — arming faults perturbs
/// every instrumented path in the process.
pub struct ArmedGuard {
    _gate: MutexGuard<'static, ()>,
}

/// Install `plan` under the global test gate.
pub fn arm_scoped(plan: FaultPlan) -> ArmedGuard {
    let gate = TEST_GATE.lock().unwrap_or_else(PoisonError::into_inner);
    install(plan);
    ArmedGuard { _gate: gate }
}

impl Drop for ArmedGuard {
    fn drop(&mut self) {
        clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: in-lib tests must not arm panic/error rules — kernel tests
    // running concurrently in this process would observe them. Parsing is
    // covered here; the arming behavior is covered by the serialized
    // integration tests in `tests/faults_e2e.rs`.

    #[test]
    fn parses_the_documented_examples() {
        let p = FaultPlan::parse("exec:panic:every=7").unwrap();
        assert_eq!(
            p.rules,
            vec![Rule { site: Site::Exec, action: Action::Panic, every: 7, ms: 10, times: 0 }]
        );

        let p = FaultPlan::parse("queue:stall:ms=50").unwrap();
        assert_eq!(
            p.rules,
            vec![Rule { site: Site::Queue, action: Action::Stall, every: 1, ms: 50, times: 0 }]
        );

        let p = FaultPlan::parse("exec:error:every=1:times=1; queue:stall:ms=5").unwrap();
        assert_eq!(p.rules.len(), 2);
        assert_eq!(p.rules[0].times, 1);
        assert_eq!(p.rules[1].ms, 5);
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "",
            "exec",
            "exec:detonate",
            "disk:panic",
            "exec:panic:every=0",
            "exec:panic:every=x",
            "exec:panic:sometimes",
            "exec:panic:when=later",
            "queue:panic", // queue only stalls
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted: {bad:?}");
        }
    }

    #[test]
    fn disarmed_points_are_no_ops() {
        // no plan installed in this process outside arm_scoped tests
        assert!(!armed() || true); // points must be callable regardless
        exec_point();
        assert!(exec_error_point().is_ok() || armed());
        queue_point();
    }
}
