//! Minimal property-testing framework (proptest is not vendored offline).
//!
//! `forall` drives a generator function with a deterministic RNG and, on
//! failure, retries the failing case with simple halving shrink candidates
//! produced by the caller-supplied `shrink` hook. Keep generators simple:
//! the framework favors clarity over proptest's full strategy algebra.

pub mod faults;

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 256, seed: 0xC0FFEE }
    }
}

/// Run `prop` on `cases` values drawn from `gen`. On failure, tries the
/// shrink candidates from `shrink` (depth-first, up to 200 steps) and
/// panics with the smallest failing case's debug representation.
pub fn forall_shrink<T, G, P, S>(cfg: Config, mut gen: G, prop: P, shrink: S)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> bool,
    S: Fn(&T) -> Vec<T>,
{
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let value = gen(&mut rng);
        if prop(&value) {
            continue;
        }
        // shrink
        let mut smallest = value.clone();
        let mut budget = 200;
        'outer: while budget > 0 {
            for cand in shrink(&smallest) {
                budget -= 1;
                if !prop(&cand) {
                    smallest = cand;
                    continue 'outer;
                }
                if budget == 0 {
                    break;
                }
            }
            break;
        }
        panic!(
            "property failed (case {case}, seed {:#x}):\n  original: {value:?}\n  shrunk:   {smallest:?}",
            cfg.seed
        );
    }
}

/// `forall` without shrinking.
pub fn forall<T, G, P>(cfg: Config, gen: G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> bool,
{
    forall_shrink(cfg, gen, prop, |_| Vec::new());
}

/// Standard shrinker for a vector of u64s: halve each entry toward 1.
pub fn shrink_u64s(v: &[u64]) -> Vec<Vec<u64>> {
    let mut out = Vec::new();
    for i in 0..v.len() {
        if v[i] > 1 {
            let mut c = v.to_vec();
            c[i] /= 2;
            out.push(c);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(Config::default(), |r| r.range(0, 100), |&x| x <= 100);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        forall(Config { cases: 64, seed: 1 }, |r| r.range(0, 100), |&x| x < 50);
    }

    #[test]
    fn shrinking_finds_small_case() {
        // property: all entries < 64. Start from random big vectors; the
        // shrunk failure should have all-but-one entry minimal.
        let result = std::panic::catch_unwind(|| {
            forall_shrink(
                Config { cases: 16, seed: 7 },
                |r| vec![r.range(64, 1024), r.range(64, 1024)],
                |v| v.iter().all(|&x| x < 64),
                |v| shrink_u64s(v),
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("shrunk"), "{msg}");
    }

    #[test]
    fn shrink_u64s_halves() {
        assert_eq!(shrink_u64s(&[4, 1]), vec![vec![2, 1]]);
        assert!(shrink_u64s(&[1, 1]).is_empty());
    }
}
