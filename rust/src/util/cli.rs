//! Tiny argv parser (clap is not vendored offline).
//!
//! Grammar: `convbound <subcommand> [--flag] [--key value] [positional...]`.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub flags: Vec<String>,
    pub options: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of argv entries (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.options.insert(name.to_string(), v);
                } else {
                    args.flags.push(name.to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(a);
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> u64 {
        self.opt(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} must be an integer")))
            .unwrap_or(default)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> f64 {
        self.opt(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} must be a number")))
            .unwrap_or(default)
    }

    pub fn opt_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse("fig2 conv1 conv2");
        assert_eq!(a.subcommand.as_deref(), Some("fig2"));
        assert_eq!(a.positional, vec!["conv1", "conv2"]);
    }

    #[test]
    fn options_with_value() {
        let a = parse("fig4 --batch 1000 --layer=conv1");
        assert_eq!(a.opt_u64("batch", 1), 1000);
        assert_eq!(a.opt("layer"), Some("conv1"));
    }

    #[test]
    fn bare_flags() {
        let a = parse("fig4 --claims --batch 10");
        assert!(a.flag("claims"));
        assert!(!a.flag("nope"));
        assert_eq!(a.opt_u64("batch", 1), 10);
    }

    #[test]
    fn trailing_flag_is_flag() {
        let a = parse("run --verbose");
        assert!(a.flag("verbose"));
    }

    #[test]
    fn defaults() {
        let a = parse("cmd");
        assert_eq!(a.opt_u64("missing", 7), 7);
        assert_eq!(a.opt_str("missing", "x"), "x");
        assert_eq!(a.opt_f64("missing", 1.5), 1.5);
    }
}
