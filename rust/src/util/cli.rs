//! Tiny argv parser (clap is not vendored offline).
//!
//! Grammar: `convbound <subcommand> [--flag] [--key value] [positional...]`.
//!
//! Typed accessors return [`Result`] so malformed values (`--batch ten`)
//! surface as a one-line error instead of a panic backtrace; `main`
//! renders the message and exits nonzero.

use std::collections::BTreeMap;

use crate::err;
use crate::util::error::Result;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub flags: Vec<String>,
    pub options: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of argv entries (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.options.insert(name.to_string(), v);
                } else {
                    args.flags.push(name.to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(a);
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| err!("--{name}: '{v}' is not an integer")),
        }
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| err!("--{name}: '{v}' is not a number")),
        }
    }

    pub fn opt_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse("fig2 conv1 conv2");
        assert_eq!(a.subcommand.as_deref(), Some("fig2"));
        assert_eq!(a.positional, vec!["conv1", "conv2"]);
    }

    #[test]
    fn options_with_value() {
        let a = parse("fig4 --batch 1000 --layer=conv1");
        assert_eq!(a.opt_u64("batch", 1).unwrap(), 1000);
        assert_eq!(a.opt("layer"), Some("conv1"));
    }

    #[test]
    fn bare_flags() {
        let a = parse("fig4 --claims --batch 10");
        assert!(a.flag("claims"));
        assert!(!a.flag("nope"));
        assert_eq!(a.opt_u64("batch", 1).unwrap(), 10);
    }

    #[test]
    fn malformed_values_error_instead_of_panicking() {
        let a = parse("fig4 --batch ten --mem 1e");
        let e = a.opt_u64("batch", 1).unwrap_err().to_string();
        assert!(e.contains("--batch"), "{e}");
        assert!(e.contains("ten"), "{e}");
        assert!(a.opt_f64("mem", 1.0).is_err());
        // scientific notation is a valid f64
        assert_eq!(parse("x --mem 1e6").opt_f64("mem", 0.0).unwrap(), 1e6);
    }

    #[test]
    fn trailing_flag_is_flag() {
        let a = parse("run --verbose");
        assert!(a.flag("verbose"));
    }

    #[test]
    fn defaults() {
        let a = parse("cmd");
        assert_eq!(a.opt_u64("missing", 7).unwrap(), 7);
        assert_eq!(a.opt_str("missing", "x"), "x");
        assert_eq!(a.opt_f64("missing", 1.5).unwrap(), 1.5);
    }
}
