//! Minimal JSON parser + emitter (serde is not available offline).
//!
//! Supports the full JSON grammar except `\u` surrogate pairs beyond the
//! BMP. Used for `artifacts/manifest.json` and for report emission.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    /// Strict integer accessor: `Some` only for a finite, non-negative
    /// number with no fractional part that is exactly representable as an
    /// f64 integer (≤ 2⁵³) — no truncation, no saturation, no defaulting.
    /// Use where coercing a malformed value would silently load different
    /// semantics than its author wrote (manifest shapes, sidecar entries).
    pub fn as_u64_strict(&self) -> Option<u64> {
        const MAX_EXACT: f64 = (1u64 << 53) as f64;
        match self.as_f64() {
            Some(v)
                if v.is_finite()
                    && (0.0..=MAX_EXACT).contains(&v)
                    && v.fract() == 0.0 =>
            {
                Some(v as u64)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]`-style access; returns Null for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full utf-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// Escape + quote a string for JSON output.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write!(f, "{}", escape(s)),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", escape(k), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-17").unwrap(), Json::Num(-17.0));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\n"}], "c": null}"#).unwrap();
        assert_eq!(v.get("c"), &Json::Null);
        let arr = v.get("a").as_arr().unwrap();
        assert_eq!(arr[0].as_u64(), Some(1));
        assert_eq!(arr[2].get("b").as_str(), Some("x\n"));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn unicode_escape_roundtrip() {
        let v = Json::parse(r#""aéb""#).unwrap();
        assert_eq!(v.as_str(), Some("aéb"));
    }

    #[test]
    fn display_roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"b":true,"n":null}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn missing_key_is_null() {
        let v = Json::parse("{}").unwrap();
        assert_eq!(v.get("nope"), &Json::Null);
    }

    #[test]
    fn strict_u64_rejects_coercions_plain_u64_allows() {
        assert_eq!(Json::Num(13.0).as_u64_strict(), Some(13));
        assert_eq!(Json::Num(0.0).as_u64_strict(), Some(0));
        assert_eq!(Json::Num(1.9).as_u64_strict(), None);
        assert_eq!(Json::Num(-1.0).as_u64_strict(), None);
        assert_eq!(Json::Num(f64::NAN).as_u64_strict(), None);
        // integral but beyond exact-f64 range: would saturate, so refused
        assert_eq!(Json::Num(1e19).as_u64_strict(), None);
        assert_eq!(Json::Num((1u64 << 53) as f64).as_u64_strict(), Some(1 << 53));
        assert_eq!(Json::Str("4".into()).as_u64_strict(), None);
        assert_eq!(Json::Null.as_u64_strict(), None);
        // the lenient accessor truncates where the strict one refuses
        assert_eq!(Json::Num(1.9).as_u64(), Some(1));
    }
}
