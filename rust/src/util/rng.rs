//! Deterministic xorshift RNG — no external rand crates offline.
//!
//! Used for test-data generation, the property-testing framework, and the
//! randomized restarts of the GEMMINI tile optimizer. Determinism matters:
//! every experiment in EXPERIMENTS.md is reproducible from its seed.

/// xorshift64* generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // avoid the all-zero fixed point
        Rng { state: seed.wrapping_mul(0x9E3779B97F4A7C15).max(1) }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // modulo bias is irrelevant for our n << 2^64 use cases
        self.next_u64() % n
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard-normal-ish (Irwin–Hall of 12 uniforms) — good enough for
    /// generating conv test tensors.
    pub fn normalish(&mut self) -> f32 {
        let mut s = 0.0;
        for _ in 0..12 {
            s += self.f64();
        }
        (s - 6.0) as f32
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }

    /// A vector of `n` normal-ish f32 values.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normalish()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(9);
        let mut hit_lo = false;
        let mut hit_hi = false;
        for _ in 0..2000 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
            hit_lo |= v == 3;
            hit_hi |= v == 5;
        }
        assert!(hit_lo && hit_hi);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(11);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normalish_mean_zero() {
        let mut r = Rng::new(13);
        let v = r.normal_vec(10_000);
        let mean: f32 = v.iter().sum::<f32>() / v.len() as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
    }
}
