//! In-tree error substrate for the fully offline build: a context-chained
//! [`Error`], the crate-wide [`Result`] alias, the [`Context`] extension
//! trait for wrapping fallible calls, and the [`crate::err!`] constructor
//! macro. External error crates are deliberately not used — the crate's
//! default `[dependencies]` table is empty.
//!
//! Rendering follows the familiar `outer: inner: root` convention, so
//! `Manifest::load` failures read like
//! `loading manifest from artifacts: reading artifacts/manifest.json: No
//! such file or directory (os error 2)`.

use std::fmt;

/// Machine-readable discriminant on an [`Error`]. The serving layer
/// matches on it to pick a recovery: `QueueFull`/`DeadlineExceeded` are
/// load-shedding outcomes a client may retry elsewhere, `WorkerPanicked`
/// marks a caught panic (the dispatch is retried once and may degrade to
/// a fallback path), `Shutdown` is terminal for this server/pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ErrorKind {
    /// Plain error with no recovery semantics (the `err!` default).
    #[default]
    Other,
    /// Bounded admission queue rejected the request under `Shed` overflow.
    QueueFull,
    /// The request's deadline expired before it reached a batch slot.
    DeadlineExceeded,
    /// A worker/job panicked; the panic was caught and converted.
    WorkerPanicked,
    /// The server or pool was already shut down.
    Shutdown,
}

/// A chained error: the root cause plus any context frames wrapped around
/// it, stored outermost-first, and a [`ErrorKind`] discriminant that
/// survives context wrapping.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Error {
    frames: Vec<String>,
    kind: ErrorKind,
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// A new root error from a message.
    pub fn msg(msg: impl Into<String>) -> Error {
        Error { frames: vec![msg.into()], kind: ErrorKind::Other }
    }

    /// A new root error carrying a machine-readable kind.
    pub fn typed(kind: ErrorKind, msg: impl Into<String>) -> Error {
        Error { frames: vec![msg.into()], kind }
    }

    /// Wrap this error with one more (outermost) context frame. The kind
    /// is preserved — context describes where the error surfaced, not
    /// what it is.
    pub fn context(mut self, msg: impl Into<String>) -> Error {
        self.frames.insert(0, msg.into());
        self
    }

    /// The machine-readable discriminant.
    pub fn kind(&self) -> ErrorKind {
        self.kind
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.frames.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, frame) in self.frames.iter().enumerate() {
            if i > 0 {
                write!(f, ": ")?;
            }
            write!(f, "{frame}")?;
        }
        Ok(())
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e.to_string())
    }
}

impl From<crate::util::json::JsonError> for Error {
    fn from(e: crate::util::json::JsonError) -> Error {
        Error::msg(e.to_string())
    }
}

/// Context chaining for any `Result` whose error converts into [`Error`]
/// (the identity conversion included, so an already-chained [`Error`]
/// keeps its frames instead of being flattened).
pub trait Context<T> {
    /// Wrap the error (if any) with a fixed context message.
    fn context(self, msg: impl Into<String>) -> Result<T>;

    /// Wrap the error (if any) with a lazily computed context message.
    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl Into<String>) -> Result<T> {
        self.map_err(|e| e.into().context(msg))
    }

    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string:
/// `err!("artifact '{key}' not found")`.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(crate::err!("root cause {}", 7))
    }

    #[test]
    fn macro_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "root cause 7");
        assert_eq!(e.root_cause(), "root cause 7");
    }

    #[test]
    fn context_chains_outermost_first() {
        let e = fails().context("loading").unwrap_err().context("outer");
        assert_eq!(e.to_string(), "outer: loading: root cause 7");
        assert_eq!(e.root_cause(), "root cause 7");
    }

    #[test]
    fn rewrapping_preserves_the_root_cause() {
        // a Result<_, Error> run through the trait keeps its frame chain
        let wrapped: Result<()> = fails().context("inner");
        let e = wrapped.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner: root cause 7");
        assert_eq!(e.root_cause(), "root cause 7");
    }

    #[test]
    fn with_context_is_lazy_on_ok() {
        let ok: std::result::Result<u32, std::io::Error> = Ok(5);
        let r: Result<u32> = ok.with_context(|| panic!("must not be called"));
        assert_eq!(r.unwrap(), 5);
    }

    #[test]
    fn io_and_json_errors_convert() {
        let io = std::fs::read_to_string("/definitely/not/a/file");
        let e: Error = io.with_context(|| "reading config".to_string()).unwrap_err();
        assert!(e.to_string().starts_with("reading config: "));

        let j = crate::util::json::Json::parse("{oops").unwrap_err();
        let e: Error = j.into();
        assert!(e.to_string().contains("json error"));
    }

    #[test]
    fn typed_errors_expose_their_kind() {
        let e = Error::typed(ErrorKind::QueueFull, "queue full (4 requests)");
        assert_eq!(e.kind(), ErrorKind::QueueFull);
        assert_eq!(e.to_string(), "queue full (4 requests)");
        // the default constructor and the macro stay `Other`
        assert_eq!(fails().unwrap_err().kind(), ErrorKind::Other);
    }

    #[test]
    fn context_preserves_the_kind() {
        let e = Error::typed(ErrorKind::WorkerPanicked, "worker panicked: boom");
        let wrapped: Result<()> = Err(e);
        let e = wrapped.context("dispatching batch 3").unwrap_err().context("serving");
        assert_eq!(e.kind(), ErrorKind::WorkerPanicked);
        assert_eq!(e.to_string(), "serving: dispatching batch 3: worker panicked: boom");
        assert_eq!(e.root_cause(), "worker panicked: boom");
    }

    #[test]
    fn question_mark_interops_with_io() {
        fn read() -> Result<String> {
            let s = std::fs::read_to_string("/nope/nope")?;
            Ok(s)
        }
        assert!(read().is_err());
    }
}
