//! In-tree substrates for the offline build: errors, JSON, CLI parsing,
//! RNG, thread pool, and summary statistics.

pub mod cli;
pub mod error;
pub mod json;
pub mod rng;
pub mod stats;
pub mod threadpool;

/// Integer ceil-division (used everywhere tile counts are computed).
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Round `a` up to the next multiple of `b`.
#[inline]
pub fn round_up(a: u64, b: u64) -> u64 {
    ceil_div(a, b) * b
}

/// All divisors of `n` in ascending order (tile-size candidate sets).
pub fn divisors(n: u64) -> Vec<u64> {
    assert!(n > 0);
    let mut small = Vec::new();
    let mut large = Vec::new();
    let mut d = 1;
    while d * d <= n {
        if n % d == 0 {
            small.push(d);
            if d != n / d {
                large.push(n / d);
            }
        }
        d += 1;
    }
    large.reverse();
    small.extend(large);
    small
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_exact_and_inexact() {
        assert_eq!(ceil_div(10, 5), 2);
        assert_eq!(ceil_div(11, 5), 3);
        assert_eq!(ceil_div(1, 1), 1);
        assert_eq!(ceil_div(0, 7), 0);
    }

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(10, 4), 12);
        assert_eq!(round_up(12, 4), 12);
        assert_eq!(round_up(0, 3), 0);
    }

    #[test]
    fn divisors_of_12() {
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
    }

    #[test]
    fn divisors_of_prime() {
        assert_eq!(divisors(13), vec![1, 13]);
    }

    #[test]
    fn divisors_of_square() {
        assert_eq!(divisors(36), vec![1, 2, 3, 4, 6, 9, 12, 18, 36]);
    }

    #[test]
    fn divisors_are_sorted_and_divide() {
        for n in 1..200u64 {
            let ds = divisors(n);
            assert!(ds.windows(2).all(|w| w[0] < w[1]));
            assert!(ds.iter().all(|d| n % d == 0));
            assert_eq!(ds.first(), Some(&1));
            assert_eq!(ds.last(), Some(&n));
        }
    }
}
