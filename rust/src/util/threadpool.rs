//! A small fixed-size thread pool (tokio is not vendored offline).
//!
//! The coordinator uses this to run per-layer convolution executions and
//! tiling searches in parallel. Jobs are `FnOnce() + Send` closures; results
//! flow back through regular channels owned by the caller.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Fixed-size worker pool. Dropping the pool joins all workers.
pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `size` workers (must be >= 1).
    pub fn new(size: usize) -> ThreadPool {
        assert!(size >= 1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("convbound-worker-{i}"))
                    .spawn(move || loop {
                        let msg = rx.lock().unwrap().recv();
                        match msg {
                            Ok(Msg::Run(job)) => job(),
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx, workers }
    }

    /// Submit a job. Panics if the pool has been shut down.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.send(Msg::Run(Box::new(f))).expect("pool alive");
    }

    /// Convenience: map `f` over `items` in parallel, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel();
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let r = f(item);
                // receiver may be gone if the caller panicked; ignore
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|r| r.expect("worker completed")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                tx.send(()).unwrap();
            });
        }
        drop(tx);
        for _ in rx {}
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<i32>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<i32>>());
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool); // must not hang
    }

    #[test]
    fn single_worker_pool() {
        let pool = ThreadPool::new(1);
        let out = pool.map(vec![1u64, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }
}
