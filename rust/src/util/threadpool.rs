//! A small fixed-size thread pool (tokio is not vendored offline).
//!
//! The coordinator uses this to run per-layer convolution executions and
//! tiling searches in parallel. Jobs are `FnOnce() + Send` closures; results
//! flow back through regular channels owned by the caller.
//!
//! Fault tolerance: a panicking job cannot take its worker (or the
//! process) down — every job runs under `catch_unwind`, and the batched
//! entry point [`ThreadPool::run_batch`] surfaces per-item panics as
//! typed [`ErrorKind::WorkerPanicked`] errors so callers decide whether
//! to fail one item, retry, or degrade to a fallback path.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

use crate::util::error::{Error, ErrorKind, Result};

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Fixed-size worker pool. Dropping the pool joins all workers.
pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    workers: Vec<thread::JoinHandle<()>>,
}

/// Render a caught panic payload (the `&str`/`String` cases cover
/// `panic!` with a message; anything else gets a generic label).
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

impl ThreadPool {
    /// Spawn `size` workers (must be >= 1).
    pub fn new(size: usize) -> ThreadPool {
        assert!(size >= 1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("convbound-worker-{i}"))
                    .spawn(move || loop {
                        let msg = rx.lock().unwrap().recv();
                        match msg {
                            Ok(Msg::Run(job)) => {
                                // a panicking job must not kill its worker:
                                // the failure is reported through whatever
                                // channel the job owns, never by unwinding
                                // a pool thread
                                let _ = catch_unwind(AssertUnwindSafe(job));
                            }
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx, workers }
    }

    /// Submit a job. A job submitted during/after teardown (workers gone,
    /// channel closed) is silently dropped — batched callers observe the
    /// lost slot as a typed error from [`ThreadPool::run_batch`] instead
    /// of the process aborting on a closed channel.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        let _ = self.tx.send(Msg::Run(Box::new(f)));
    }

    /// Run `f` over `items` in parallel, preserving order, isolating
    /// per-item failures: a panicking item yields
    /// `Err(ErrorKind::WorkerPanicked)` carrying the panic message, a
    /// slot lost to pool teardown yields `Err(ErrorKind::Shutdown)`, and
    /// every other item still completes normally.
    pub fn run_batch<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<Result<R>>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel();
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let r = catch_unwind(AssertUnwindSafe(|| f(item))).map_err(|p| {
                    Error::typed(
                        ErrorKind::WorkerPanicked,
                        format!("worker panicked: {}", panic_message(p.as_ref())),
                    )
                });
                // receiver may be gone if the caller panicked; ignore
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut out: Vec<Option<Result<R>>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter()
            .map(|r| {
                r.unwrap_or_else(|| {
                    Err(Error::typed(
                        ErrorKind::Shutdown,
                        "pool shut down before the job ran",
                    ))
                })
            })
            .collect()
    }

    /// Convenience: map `f` over `items` in parallel, preserving order.
    /// Propagates the first failed item by panicking in the *caller* with
    /// the original failure message — the pool and its workers stay alive,
    /// and an enclosing `catch_unwind` (e.g. the runtime's fallback
    /// wrapper) sees the real cause.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        self.run_batch(items, f)
            .into_iter()
            .map(|r| match r {
                Ok(v) => v,
                Err(e) => panic!("{e}"),
            })
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// A reusable rendezvous for the sharded executor's BSP exchange phases
/// (publish → wait → read → wait → compute), replacing a spin-wait.
///
/// Unlike `std::sync::Barrier` it can *break*: when a participant panics
/// mid-phase its [`BarrierGuard`] breaks the barrier on unwind, waking every
/// peer with a typed [`ErrorKind::WorkerPanicked`] error instead of leaving
/// them blocked forever on a rendezvous that can no longer complete.
pub struct ShardBarrier {
    state: Mutex<BarrierState>,
    cv: Condvar,
}

struct BarrierState {
    parties: usize,
    arrived: usize,
    generation: u64,
    broken: bool,
}

impl ShardBarrier {
    /// A barrier over `parties` participants (must be >= 1).
    pub fn new(parties: usize) -> ShardBarrier {
        assert!(parties >= 1);
        ShardBarrier {
            state: Mutex::new(BarrierState {
                parties,
                arrived: 0,
                generation: 0,
                broken: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Block until all parties arrive. Returns `Ok(true)` for exactly one
    /// "leader" per generation, `Ok(false)` for the rest, and a typed
    /// error if the barrier was broken by a panicking peer (in which case
    /// it stays broken — every later wait fails fast).
    pub fn wait(&self) -> Result<bool> {
        let mut st = self.state.lock().unwrap();
        if st.broken {
            return Err(Self::broken_err());
        }
        let gen = st.generation;
        st.arrived += 1;
        if st.arrived == st.parties {
            st.arrived = 0;
            st.generation += 1;
            self.cv.notify_all();
            return Ok(true);
        }
        while st.generation == gen && !st.broken {
            st = self.cv.wait(st).unwrap();
        }
        if st.broken {
            return Err(Self::broken_err());
        }
        Ok(false)
    }

    /// Break the barrier: every current and future `wait` returns an
    /// error. Idempotent.
    pub fn break_barrier(&self) {
        let mut st = self.state.lock().unwrap();
        st.broken = true;
        self.cv.notify_all();
    }

    /// True once any participant broke the barrier.
    pub fn is_broken(&self) -> bool {
        self.state.lock().unwrap().broken
    }

    /// An unwind guard for one participant: if the closure it protects
    /// panics (or errors out early) before [`BarrierGuard::complete`] is
    /// called, dropping the guard breaks the barrier so peers blocked in
    /// `wait` are released instead of hanging.
    pub fn guard(self: &Arc<Self>) -> BarrierGuard {
        BarrierGuard { barrier: Arc::clone(self), armed: true }
    }

    fn broken_err() -> Error {
        Error::typed(
            ErrorKind::WorkerPanicked,
            "shard barrier broken: a peer shard panicked mid-phase",
        )
    }
}

/// RAII companion to [`ShardBarrier::guard`].
pub struct BarrierGuard {
    barrier: Arc<ShardBarrier>,
    armed: bool,
}

impl BarrierGuard {
    /// Disarm: the participant finished cleanly, don't break on drop.
    pub fn complete(mut self) {
        self.armed = false;
    }
}

impl Drop for BarrierGuard {
    fn drop(&mut self) {
        if self.armed {
            self.barrier.break_barrier();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::{Duration, Instant};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                tx.send(()).unwrap();
            });
        }
        drop(tx);
        for _ in rx {}
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<i32>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<i32>>());
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool); // must not hang
    }

    #[test]
    fn single_worker_pool() {
        let pool = ThreadPool::new(1);
        let out = pool.map(vec![1u64, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn run_batch_isolates_panicking_items() {
        let pool = ThreadPool::new(2);
        let out = pool.run_batch(vec![0u32, 1, 2, 3], |x| {
            if x % 2 == 1 {
                panic!("boom on {x}");
            }
            x * 10
        });
        assert_eq!(out.len(), 4);
        assert_eq!(*out[0].as_ref().unwrap(), 0);
        assert_eq!(*out[2].as_ref().unwrap(), 20);
        for i in [1usize, 3] {
            let e = out[i].as_ref().unwrap_err();
            assert_eq!(e.kind(), ErrorKind::WorkerPanicked);
            assert!(e.to_string().contains("boom on"), "got: {e}");
        }
        // the pool survives the panics and still serves work
        assert_eq!(pool.map(vec![5i32, 6], |x| x + 1), vec![6, 7]);
    }

    #[test]
    fn map_propagates_worker_panic_to_caller() {
        let pool = ThreadPool::new(2);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool.map(vec![1u32, 2, 3], |x| {
                if x == 2 {
                    panic!("injected");
                }
                x
            })
        }));
        let msg = panic_message(caught.unwrap_err().as_ref());
        assert!(msg.contains("worker panicked: injected"), "got: {msg}");
        // caller-side panic, pool still alive
        assert_eq!(pool.map(vec![7u32], |x| x), vec![7]);
    }

    #[test]
    fn barrier_synchronizes_phases() {
        let pool = ThreadPool::new(4);
        let barrier = Arc::new(ShardBarrier::new(4));
        let phase1 = Arc::new(AtomicUsize::new(0));
        let results = {
            let p1 = Arc::clone(&phase1);
            let b = Arc::clone(&barrier);
            pool.run_batch((0..4usize).collect(), move |_k| {
                p1.fetch_add(1, Ordering::SeqCst);
                b.wait().unwrap();
                // after the rendezvous every peer's phase-1 write is visible
                p1.load(Ordering::SeqCst)
            })
        };
        for r in results {
            assert_eq!(r.unwrap(), 4);
        }
    }

    #[test]
    fn barrier_elects_one_leader_per_generation() {
        let pool = ThreadPool::new(3);
        let barrier = Arc::new(ShardBarrier::new(3));
        for _generation in 0..5 {
            let b = Arc::clone(&barrier);
            let leaders: usize = pool
                .run_batch((0..3usize).collect(), move |_| b.wait().unwrap())
                .into_iter()
                .filter(|r| *r.as_ref().unwrap())
                .count();
            assert_eq!(leaders, 1);
        }
    }

    #[test]
    fn panicking_shard_releases_waiting_peers() {
        // Regression: without break-on-unwind, the two surviving shards
        // would block forever on a 3-party barrier whose third member
        // died — this test would hang instead of failing.
        let pool = ThreadPool::new(3);
        let barrier = Arc::new(ShardBarrier::new(3));
        let b = Arc::clone(&barrier);
        let out = pool.run_batch(vec![0usize, 1, 2], move |k| {
            let guard = b.guard();
            if k == 2 {
                panic!("shard 2 dies before the rendezvous");
            }
            let r = b.wait();
            guard.complete();
            r
        });
        assert_eq!(
            out[2].as_ref().unwrap_err().kind(),
            ErrorKind::WorkerPanicked
        );
        for k in [0usize, 1] {
            // the survivors return (not hang), observing a typed break
            let r = out[k].as_ref().unwrap();
            let e = r.as_ref().unwrap_err();
            assert_eq!(e.kind(), ErrorKind::WorkerPanicked);
            assert!(e.to_string().contains("barrier broken"), "got: {e}");
        }
        assert!(barrier.is_broken());
        // and the break is sticky: later waits fail fast
        assert!(barrier.wait().is_err());
    }

    #[test]
    fn completed_guard_leaves_barrier_intact() {
        let barrier = Arc::new(ShardBarrier::new(1));
        let g = barrier.guard();
        assert!(barrier.wait().unwrap());
        g.complete();
        assert!(!barrier.is_broken());
        assert!(barrier.wait().unwrap()); // still usable next generation
    }

    #[test]
    fn execute_survives_teardown_race() {
        // Reproduce the drop-order race that used to abort the process:
        // all workers exit (dropping the shared receiver) while a caller
        // still holds the pool and submits work.
        let pool = ThreadPool::new(2);
        for _ in 0..2 {
            pool.tx.send(Msg::Shutdown).unwrap();
        }
        // wait for the workers to exit and drop the receiver; extra
        // Shutdown probes are never received, so a send error means the
        // channel is really closed
        let deadline = Instant::now() + Duration::from_secs(10);
        while pool.tx.send(Msg::Shutdown).is_ok() {
            assert!(Instant::now() < deadline, "workers never exited");
            thread::sleep(Duration::from_millis(1));
        }
        pool.execute(|| {}); // must not panic (used to `expect("pool alive")`)
        let out = pool.run_batch(vec![1u32, 2], |x| x);
        for r in out {
            assert_eq!(r.unwrap_err().kind(), ErrorKind::Shutdown);
        }
        drop(pool); // and drop still joins cleanly
    }
}
