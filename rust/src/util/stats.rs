//! Summary statistics for benchmark timing (criterion is unavailable
//! offline; benches/ uses [`crate::bench`] which builds on this).

/// Summary of a sample of f64 observations (times in seconds, cycle counts…).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / n.max(2).saturating_sub(1) as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile(&sorted, 0.50),
            p95: percentile(&sorted, 0.95),
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean (used for figure-level "average ratio vs bound" rows).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    assert!(xs.iter().all(|x| *x > 0.0));
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::of(&[2.0, 2.0, 2.0, 2.0]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 2.0);
        assert_eq!(s.p50, 2.0);
    }

    #[test]
    fn summary_simple() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert_eq!(percentile(&sorted, 0.5), 5.0);
        assert_eq!(percentile(&sorted, 0.0), 0.0);
        assert_eq!(percentile(&sorted, 1.0), 10.0);
    }

    #[test]
    fn geomean_powers_of_two() {
        let g = geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
    }
}
