//! Attainability: the paper's blocking algorithms (§3.2, §4.2, §5).
//!
//! * [`seq_lp`] — the single-processor LP blocking with the small-filter
//!   trick (paper eq. (6) and the 6×9 constraint matrix).
//! * [`par_lp`] — the parallel processor-grid LP (§4.2). The paper's A
//!   matrix is garbled in the published text; DESIGN.md documents the
//!   reconstruction (minimize the maximum per-processor array slice subject
//!   to the processor-count and memory constraints).
//! * [`gemmini_opt`] — the §5 integral tile optimizer for the GEMMINI
//!   scratchpad/accumulator geometry (replaces Mathematica's NMaximize).
//! * [`vendor`] — a reimplementation of the vendor-supplied GEMMINI conv
//!   tiling heuristic, the Figure 4 baseline.

pub mod gemmini_opt;
pub mod hierarchical;
pub mod par_lp;
pub mod seq_lp;
pub mod vendor;

pub use hierarchical::{hierarchical_blocking, HierarchicalBlocking};
pub use gemmini_opt::{optimize_gemmini_tiling, GemminiTile, OptObjective, OptOptions};
pub use par_lp::{parallel_blocking, ParBlocking};
pub use seq_lp::{sequential_blocking, SeqBlocking};
pub use vendor::vendor_tiling;
