//! Parallel processor-grid blocking (paper §4.2).
//!
//! Each of the seven loop ranges is cut into `slices_ℓ` contiguous segments
//! and the processor grid is the product of the slice counts, so
//! `Π slices_ℓ = P` and each processor performs `G/P` updates (perfect load
//! balance by construction — the paper's assumption for Theorem 2.3).
//!
//! The published A matrix for this LP is unreadable in the paper's text, so
//! we reconstruct the optimization it describes (DESIGN.md §Substitutions):
//! in log_P space over `y_ℓ = log_P slices_ℓ ≥ 0` we *minimize the largest
//! per-processor array slice* — the dominant term of the per-processor
//! communication `p_I·I_p + p_F·F_p + p_O·O_p − footprint/P` — subject to
//!
//! ```text
//! Σ_ℓ y_ℓ = 1                        (use exactly P processors)
//! y_ℓ ≤ log_P range_ℓ               (cannot slice finer than the loop)
//! log_P(p_a|A_a|) − Σ_{ℓ∈idx(a)} y_ℓ ≤ log_P(p_a·M·share)   (fits in memory)
//! ```
//!
//! Array index sets: I ← {N, cI, wO, hO}, F ← {cI, cO, wF, hF},
//! O ← {N, cO, wO, hO} (slicing a loop that an array is not indexed by does
//! not shrink that array's per-processor slice).

use crate::conv::{ConvShape, Precision};
use crate::lp::{self, Constraint, Objective, Rel};

/// Slice counts per loop (their product ≈ P) plus per-processor volumes.
#[derive(Debug, Clone, PartialEq)]
pub struct ParBlocking {
    /// slices of (N, cI, cO, wO, hO, wF, hF)
    pub slices: [u64; 7],
    /// processors actually used (product of slices)
    pub procs_used: u64,
    /// continuous LP solution y (log_P of slice counts)
    pub lp_y: Vec<f64>,
}

/// Which loops index which array (order: N, cI, cO, wO, hO, wF, hF).
const IDX_I: [usize; 4] = [0, 1, 3, 4];
const IDX_F: [usize; 4] = [1, 2, 5, 6];
const IDX_O: [usize; 4] = [0, 2, 3, 4];

impl ParBlocking {
    /// Per-processor slice of each array, in words:
    /// (input, filter, output).
    pub fn per_proc_words(&self, s: &ConvShape, p: Precision) -> (f64, f64, f64) {
        let div = |idx: &[usize]| -> f64 {
            idx.iter().map(|&i| self.slices[i] as f64).product()
        };
        (
            p.p_i * s.input_size() as f64 / div(&IDX_I),
            p.p_f * s.filter_size() as f64 / div(&IDX_F),
            p.p_o * s.output_size() as f64 / div(&IDX_O),
        )
    }

    /// Estimated per-processor communication under the paper's model: every
    /// word a processor touches must arrive over the network except its
    /// initially-resident share. Each array starts load balanced (the
    /// Theorem 2.3 assumption), so a processor already holds `A_a/P` of
    /// *each* array and must receive the rest of its slice.
    pub fn comm_per_proc(&self, s: &ConvShape, p: Precision) -> f64 {
        let (i, f, o) = self.per_proc_words(s, p);
        let pp = self.procs_used as f64;
        let res_i = p.p_i * s.input_size() as f64 / pp;
        let res_f = p.p_f * s.filter_size() as f64 / pp;
        let res_o = p.p_o * s.output_size() as f64 / pp;
        (i - res_i).max(0.0) + (f - res_f).max(0.0) + (o - res_o).max(0.0)
    }

    /// Do the per-processor slices fit in `m` words of local memory?
    pub fn fits(&self, s: &ConvShape, p: Precision, m: f64) -> bool {
        let (i, f, o) = self.per_proc_words(s, p);
        i + f + o <= m
    }
}

/// Solve the processor-grid LP for `p_procs` processors, each with `m`
/// words, and round to an integral grid.
pub fn parallel_blocking(
    s: &ConvShape,
    p: Precision,
    p_procs: u64,
    m: f64,
) -> ParBlocking {
    assert!(p_procs >= 1);
    let ranges = [s.n, s.c_i, s.c_o, s.w_o, s.h_o, s.w_f, s.h_f];
    if p_procs == 1 {
        return ParBlocking { slices: [1; 7], procs_used: 1, lp_y: vec![0.0; 7] };
    }
    let ln_p = (p_procs as f64).ln();
    let lg = |v: f64| v.ln() / ln_p;

    // vars: y_0..y_6, t (the max per-proc array slice, log_P)
    let nv = 8;
    let mut cons: Vec<Constraint<f64>> = Vec::new();
    // Σ y = 1
    let mut coeffs = vec![1.0; 7];
    coeffs.push(0.0);
    cons.push(Constraint { coeffs, rel: Rel::Eq, rhs: 1.0 });
    // y_ℓ ≤ log_P range_ℓ
    for (i, &ri) in ranges.iter().enumerate() {
        let mut c = vec![0.0; nv];
        c[i] = 1.0;
        cons.push(Constraint { coeffs: c, rel: Rel::Le, rhs: lg(ri.max(1) as f64) });
    }
    // per-array: log_P(p_a |A|) − Σ_{ℓ∈idx} y_ℓ ≤ t  (t = max slice)
    // and ≤ log_P(p_a·M/p_T·3) memory share (loose share: full M)
    let arrays: [(&[usize], f64); 3] = [
        (&IDX_I, p.p_i * s.input_size() as f64),
        (&IDX_F, p.p_f * s.filter_size() as f64),
        (&IDX_O, p.p_o * s.output_size() as f64),
    ];
    for (idx, words) in arrays {
        // -Σ y - t ≤ -log_P(words)  ⇔  log_P(words) - Σ y ≤ t
        let mut c = vec![0.0; nv];
        for &i in idx {
            c[i] = -1.0;
        }
        c[7] = -1.0;
        cons.push(Constraint { coeffs: c, rel: Rel::Le, rhs: -lg(words) });
        // memory: log_P(words) - Σ y ≤ log_P(M)
        let mut c2 = vec![0.0; nv];
        for &i in idx {
            c2[i] = -1.0;
        }
        cons.push(Constraint { coeffs: c2, rel: Rel::Le, rhs: lg(m) - lg(words) });
    }
    // minimize t
    let mut obj = vec![0.0; nv];
    obj[7] = 1.0;
    let sol = lp::solve(Objective::Minimize, &obj, &cons);
    let y = match sol.optimal() {
        Some((_, x)) => x[..7].to_vec(),
        // memory-infeasible: fall back to slicing everything maximally
        None => ranges.iter().map(|&r| lg(r.max(1) as f64).min(1.0)).collect(),
    };

    // Integral grid: greedy ascent from the unit grid on the true
    // objective. Each step either doubles a slice count or clamps it to
    // its full range, choosing the feasible move that minimizes
    // per-processor communication (touched − resident per array); the LP
    // solution `y` is kept for reporting/diagnostics.
    let as_blocking = |sl: &[u64], y: &[f64]| ParBlocking {
        slices: [sl[0], sl[1], sl[2], sl[3], sl[4], sl[5], sl[6]],
        procs_used: sl.iter().product(),
        lp_y: y.to_vec(),
    };
    let product = |s: &[u64]| s.iter().product::<u64>();
    let mut slices: Vec<u64> = vec![1; 7];
    loop {
        let mut best: Option<(Vec<u64>, f64)> = None;
        for i in 0..7 {
            let range = ranges[i].max(1);
            for next in [slices[i] * 2, range] {
                if next <= slices[i] || next > range {
                    continue;
                }
                if product(&slices) / slices[i] * next > p_procs {
                    continue;
                }
                let mut cand = slices.clone();
                cand[i] = next;
                let comm = as_blocking(&cand, &y).comm_per_proc(s, p);
                if best.as_ref().map(|(_, bc)| comm < *bc).unwrap_or(true) {
                    best = Some((cand, comm));
                }
            }
        }
        match best {
            Some((cand, _)) => slices = cand,
            None => break,
        }
    }
    as_blocking(&slices, &y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::resnet50_layers;

    #[test]
    fn uses_at_most_p_processors() {
        let p = Precision::paper_mixed();
        for l in resnet50_layers(1000) {
            for pp in [2u64, 8, 64, 512, 4096] {
                let b = parallel_blocking(&l.shape, p, pp, 1e9);
                assert!(b.procs_used <= pp, "{} P={pp}: {b:?}", l.name);
                assert!(b.procs_used >= 1);
                for (i, &sl) in b.slices.iter().enumerate() {
                    let ranges =
                        [l.shape.n, l.shape.c_i, l.shape.c_o, l.shape.w_o,
                         l.shape.h_o, l.shape.w_f, l.shape.h_f];
                    assert!(sl <= ranges[i].max(1));
                }
            }
        }
    }

    #[test]
    fn single_processor_trivial() {
        let s = resnet50_layers(10)[1].shape;
        let b = parallel_blocking(&s, Precision::uniform(), 1, 1e9);
        assert_eq!(b.slices, [1; 7]);
        assert_eq!(b.procs_used, 1);
    }

    #[test]
    fn touched_volume_decreases_and_comm_bounded_by_filter_replication() {
        // Per-processor *touched* volume must shrink as P grows; the
        // residual communication converges to the filter-replication cost
        // (≈ p_F·|F|), which no grid can avoid once N carries the slicing
        // (the paper's Figure 3 ratios grow for the same reason: the lower
        // bound decays faster than replication cost).
        let s = resnet50_layers(1000)[1].shape;
        let p = Precision::uniform();
        let mut last_touched = f64::INFINITY;
        for pp in [8u64, 64, 1024] {
            let b = parallel_blocking(&s, p, pp, 1e12);
            let (i, f, o) = b.per_proc_words(&s, p);
            let touched = i + f + o;
            assert!(touched < last_touched, "P={pp}: {touched} vs {last_touched}");
            last_touched = touched;
            let comm = b.comm_per_proc(&s, p);
            assert!(comm <= touched);
            assert!(
                comm <= p.p_f * s.filter_size() as f64
                    + p.p_i * s.input_size() as f64 / b.procs_used as f64
                    + p.p_o * s.output_size() as f64 / b.procs_used as f64
                    + 1.0,
                "P={pp}: comm {comm} unexpectedly high"
            );
        }
    }

    #[test]
    fn respects_memory_when_feasible() {
        let s = resnet50_layers(100)[1].shape;
        let p = Precision::uniform();
        // generous memory: must fit
        let b = parallel_blocking(&s, p, 256, 1e10);
        assert!(b.fits(&s, p, 1e10));
    }

    #[test]
    fn load_balance_near_perfect_for_power_of_two() {
        let s = resnet50_layers(1024)[1].shape; // all dims powers of 2-ish
        let b = parallel_blocking(&s, Precision::uniform(), 256, 1e12);
        // should use a large fraction of the processor budget
        assert!(b.procs_used >= 128, "{b:?}");
    }
}
