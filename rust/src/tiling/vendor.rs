//! The vendor-supplied GEMMINI convolution tiling (Figure 4 baseline).
//!
//! Re-implementation of the decision procedure of the conv tiler in the
//! upstream GEMMINI software library: one image at a time (the batch loop
//! stays outside the accelerator call), a DIM-channel im2col seed over the
//! full output image, spatial halving only until the tile first *fits*,
//! then channel-dimension doubling (input channels before output channels)
//! until the next doubling would overflow a buffer.
//!
//! The procedure is communication-oblivious: it never asks how often a
//! tile will be reloaded, only whether it fits, stops at the first
//! feasible channel growth, and never revisits batch or spatial choices.
//! That is why the paper observes "the vendor tiling was unable to take
//! full advantage of the buffer" (low per-tile scratchpad utilization) on
//! conv1-conv3, where small channel counts leave the halving trajectory
//! stranded far below scratchpad capacity.

use crate::conv::ConvShape;
use crate::gemmini::config::GemminiConfig;

use super::gemmini_opt::GemminiTile;

/// Compute the vendor tile for a layer.
pub fn vendor_tiling(s: &ConvShape, c: &GemminiConfig) -> GemminiTile {
    let dim = c.dim as u64;
    // seed: one image, DIM-channel blocks, full spatial extent
    let mut t = GemminiTile {
        b_n: 1,
        b_ci: s.c_i.min(dim),
        b_co: s.c_o.min(dim),
        b_wo: s.w_o,
        b_ho: s.h_o,
    };
    // halve the larger spatial dim until the seed fits
    while !t.fits(s, c) && (t.b_wo > 1 || t.b_ho > 1) {
        if t.b_wo >= t.b_ho {
            t.b_wo = t.b_wo.div_ceil(2);
        } else {
            t.b_ho = t.b_ho.div_ceil(2);
        }
    }
    assert!(t.fits(s, c), "vendor seed tile does not fit: {t:?}");
    // channel-first growth: double kchs, then ochs, until a doubling no
    // longer fits; spatial dims and batch are never grown back
    let caps = [s.c_i, s.c_o];
    let mut done = [false; 2];
    while !done.iter().all(|&d| d) {
        for k in 0..2 {
            if done[k] {
                continue;
            }
            let mut next = t;
            let (cur, cap) = match k {
                0 => (&mut next.b_ci, caps[0]),
                _ => (&mut next.b_co, caps[1]),
            };
            if *cur >= cap {
                done[k] = true;
                continue;
            }
            *cur = (*cur * 2).min(cap);
            if next.fits(s, c) {
                t = next;
            } else {
                done[k] = true;
            }
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::resnet50_layers;
    use crate::tiling::gemmini_opt::{optimize_gemmini_tiling, OptOptions};

    #[test]
    fn vendor_tile_fits_all_layers() {
        let c = GemminiConfig::default();
        for l in resnet50_layers(1000) {
            let t = vendor_tiling(&l.shape, &c);
            assert!(t.fits(&l.shape, &c), "{}: {t:?}", l.name);
        }
    }

    #[test]
    fn vendor_is_first_fit_not_optimal() {
        // doubling any dimension of the vendor tile must overflow a buffer
        // *at the step the algorithm stopped*, i.e. the tile just fits —
        // but the optimizer may still communicate less with a different
        // shape. Sanity: vendor utilizes less than 100% of the scratchpad.
        let c = GemminiConfig::default();
        for l in resnet50_layers(1000) {
            let t = vendor_tiling(&l.shape, &c);
            assert!(t.spad_utilization(&l.shape, &c) <= 1.0);
        }
    }

    #[test]
    fn vendor_underuses_scratchpad_on_early_layers() {
        // §5: poor per-tile scratchpad utilization for convs 1–2 (small
        // channel counts + accumulator-bound halving trajectory)
        let c = GemminiConfig::default();
        let layers = resnet50_layers(1000);
        for l in &layers[..2] {
            let u = vendor_tiling(&l.shape, &c).spad_utilization(&l.shape, &c);
            assert!(u < 0.5, "{}: utilization {u}", l.name);
        }
    }

    #[test]
    fn min_comm_objective_never_communicates_more_than_vendor() {
        // with the MinCommRows ablation objective the optimizer provably
        // dominates any feasible tile, including the vendor's
        use crate::tiling::gemmini_opt::OptObjective;
        let c = GemminiConfig::default();
        let opts = OptOptions {
            objective: OptObjective::MinCommRows,
            ..Default::default()
        };
        for l in resnet50_layers(1000) {
            let ours = optimize_gemmini_tiling(&l.shape, &c, opts);
            let vend = vendor_tiling(&l.shape, &c);
            assert!(
                ours.comm_rows(&l.shape, &c) <= vend.comm_rows(&l.shape, &c),
                "{}: ours {:?} vendor {:?}",
                l.name, ours, vend
            );
        }
    }

    #[test]
    fn paper_objective_beats_vendor_comm_on_average() {
        // the paper's §5 objective (max updates/tile) wins on most layers;
        // geometric-mean communication ratio must be < 1 (paper: 45%–85%)
        let c = GemminiConfig::default();
        let ratios: Vec<f64> = resnet50_layers(1000)
            .iter()
            .map(|l| {
                let ours = optimize_gemmini_tiling(&l.shape, &c, OptOptions::default());
                let vend = vendor_tiling(&l.shape, &c);
                ours.comm_rows(&l.shape, &c) as f64
                    / vend.comm_rows(&l.shape, &c) as f64
            })
            .collect();
        let geo = crate::util::stats::geomean(&ratios);
        assert!(geo < 1.0, "geomean comm ratio {geo} ({ratios:?})");
    }

    #[test]
    fn optimizer_strictly_beats_vendor_on_an_early_layer() {
        // the paper's headline: significant communication reduction on the
        // low-utilization layers
        let c = GemminiConfig::default();
        let layers = resnet50_layers(1000);
        let improved = layers.iter().take(3).any(|l| {
            let ours = optimize_gemmini_tiling(&l.shape, &c, OptOptions::default());
            let vend = vendor_tiling(&l.shape, &c);
            ours.comm_rows(&l.shape, &c) < vend.comm_rows(&l.shape, &c)
        });
        assert!(improved, "expected a strict communication win on convs 1-3");
    }
}
