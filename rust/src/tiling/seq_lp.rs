//! Sequential LP blocking (paper §3.2).
//!
//! Blocking vector `B = (b_N, b_cI, b_cO, b_wO, b_hO, b_wF', b_hF', b_wF'',
//! b_hF'')` using the small-filter trick of [6]: the filter loop `i6` is
//! split as `i6 = σw·q6 + r6` with `q6 ∈ [0, wF/σw)`, `r6 ∈ [0, σw)` (and
//! likewise `i7`), so `b_wF'` blocks `q6` and `b_wF''` blocks `r6`.
//!
//! In log-space `x = log_M B` we maximize `Σ x` (updates per tile) subject
//! to the three memory constraints (6), with the input constraint's
//! `(b_wO + b_wF')(b_hO + b_hF')` product expanded into four terms each
//! bounded by `M/(4·p_T)`:
//!
//! ```text
//! output:  b_N b_cO b_wO b_hO                         ≤ M/p_T
//! filter:  b_cI b_cO b_wF' b_hF' b_wF'' b_hF''        ≤ M/p_T
//! input:   b_N b_cI {b_wO,b_wF'}×{b_hO,b_hF'} b_wF'' b_hF''  ≤ M/(4p_T) each
//! ```
//!
//! (The published matrix rows 3 and 5 contain two transposed entries — the
//! expansion terms must each carry `b_wF''·b_hF''`, which the constraint
//! derivation in the paper's own eq. (6) confirms; we use the corrected
//! rows and note this in DESIGN.md.)

use crate::conv::{ConvShape, Precision};
use crate::lp::{self, Constraint, Objective, Rel};

/// The nine block sizes (integral, post-rounding), plus diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct SeqBlocking {
    pub b_n: u64,
    pub b_ci: u64,
    pub b_co: u64,
    pub b_wo: u64,
    pub b_ho: u64,
    /// block of q6 ∈ [0, ceil(wF/σw))
    pub b_wf_q: u64,
    /// block of q7
    pub b_hf_q: u64,
    /// block of r6 ∈ [0, σw)
    pub b_wf_r: u64,
    /// block of r7
    pub b_hf_r: u64,
    /// raw (continuous) LP solution in log_M space
    pub lp_x: Vec<f64>,
}

impl SeqBlocking {
    /// Updates per tile: the product of all nine block sizes.
    pub fn updates_per_tile(&self) -> f64 {
        (self.b_n * self.b_ci * self.b_co * self.b_wo * self.b_ho
            * self.b_wf_q * self.b_hf_q * self.b_wf_r * self.b_hf_r) as f64
    }

    /// Words of fast memory the three blocks occupy simultaneously
    /// (un-expanded input term, i.e. the true constraint (6) lhs).
    pub fn footprint_words(&self, p: Precision) -> f64 {
        p.p_o * (self.b_n * self.b_co * self.b_wo * self.b_ho) as f64
            + p.p_f
                * (self.b_ci * self.b_co * self.b_wf_q * self.b_hf_q
                    * self.b_wf_r * self.b_hf_r) as f64
            + p.p_i
                * (self.b_n * self.b_ci) as f64
                * ((self.b_wo + self.b_wf_q) * (self.b_ho + self.b_hf_q)
                    * self.b_wf_r * self.b_hf_r) as f64
    }

    /// Does the blocking fit in `m` words of fast memory?
    pub fn fits(&self, p: Precision, m: f64) -> bool {
        self.footprint_words(p) <= m
    }
}

/// Upper bounds (ranges) of the nine blocked loops for a shape.
fn ranges(s: &ConvShape) -> [u64; 9] {
    let qw = (s.w_f + s.s_w - 1) / s.s_w; // ceil(wF/σw)
    let qh = (s.h_f + s.s_h - 1) / s.s_h;
    [s.n, s.c_i, s.c_o, s.w_o, s.h_o, qw, qh, s.s_w, s.s_h]
}

/// Solve the §3.2 LP and round to a feasible integral blocking.
pub fn sequential_blocking(s: &ConvShape, p: Precision, m: f64) -> SeqBlocking {
    assert!(m >= p.total() * 4.0, "memory too small for any tile");
    let r = ranges(s);
    let ln_m = m.ln();
    // log_M helpers
    let lg = |v: f64| v.ln() / ln_m;

    // constraint rows over x = log_M B (9 vars)
    let rows_a: [[f64; 9]; 6] = [
        [1., 0., 1., 1., 1., 0., 0., 0., 0.], // output
        [0., 1., 1., 0., 0., 1., 1., 1., 1.], // filter
        [1., 1., 0., 1., 1., 0., 0., 1., 1.], // input: bwO·bhO term
        [1., 1., 0., 1., 0., 0., 1., 1., 1.], // input: bwO·bhF' term
        [1., 1., 0., 0., 1., 1., 0., 1., 1.], // input: bwF'·bhO term
        [1., 1., 0., 0., 0., 1., 1., 1., 1.], // input: bwF'·bhF' term
    ];
    let p_t = p.total();
    let b_rhs = [
        1.0 - lg(p_t),
        1.0 - lg(p_t),
        1.0 - lg(4.0 * p_t),
        1.0 - lg(4.0 * p_t),
        1.0 - lg(4.0 * p_t),
        1.0 - lg(4.0 * p_t),
    ];

    let mut cons: Vec<Constraint<f64>> = rows_a
        .iter()
        .zip(b_rhs)
        .map(|(row, rhs)| Constraint { coeffs: row.to_vec(), rel: Rel::Le, rhs })
        .collect();
    // per-variable upper bounds x_i <= log_M(range_i)
    for (i, &ri) in r.iter().enumerate() {
        let mut coeffs = vec![0.0; 9];
        coeffs[i] = 1.0;
        cons.push(Constraint { coeffs, rel: Rel::Le, rhs: lg(ri.max(1) as f64) });
    }

    let c = vec![1.0; 9];
    let sol = lp::solve(Objective::Maximize, &c, &cons)
        .optimal()
        .expect("sequential blocking LP must be feasible");
    let x = sol.1;

    // exponentiate + round down, clamp to [1, range]
    let mut b: Vec<u64> = x
        .iter()
        .zip(r.iter())
        .map(|(&xi, &ri)| (m.powf(xi).floor() as u64).clamp(1, ri.max(1)))
        .collect();

    // feasibility repair on the true (un-expanded) constraint: shrink the
    // largest block until the three tiles fit in M
    let mk = |b: &[u64], x: &[f64]| SeqBlocking {
        b_n: b[0], b_ci: b[1], b_co: b[2], b_wo: b[3], b_ho: b[4],
        b_wf_q: b[5], b_hf_q: b[6], b_wf_r: b[7], b_hf_r: b[8],
        lp_x: x.to_vec(),
    };
    let mut guard = 0;
    while !mk(&b, &x).fits(p, m) {
        let (imax, _) = b
            .iter()
            .enumerate()
            .max_by_key(|(_, &v)| v)
            .expect("nonempty");
        assert!(b[imax] > 1, "cannot shrink blocking to fit M={m}");
        b[imax] = (b[imax] as f64 * 0.8).floor().max(1.0) as u64;
        guard += 1;
        assert!(guard < 512, "repair loop diverged");
    }
    mk(&b, &x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::resnet50_layers;

    #[test]
    fn blocking_fits_memory_for_resnet_layers() {
        let p = Precision::paper_mixed();
        for l in resnet50_layers(1000) {
            for m in [4096.0, 65536.0, 1048576.0] {
                let b = sequential_blocking(&l.shape, p, m);
                assert!(b.fits(p, m), "{} M={m}: {b:?}", l.name);
                assert!(b.updates_per_tile() >= 1.0);
            }
        }
    }

    #[test]
    fn blocks_respect_ranges() {
        let s = resnet50_layers(100)[1].shape; // conv2_x
        let b = sequential_blocking(&s, Precision::uniform(), 65536.0);
        assert!(b.b_n <= s.n);
        assert!(b.b_ci <= s.c_i && b.b_co <= s.c_o);
        assert!(b.b_wo <= s.w_o && b.b_ho <= s.h_o);
        assert!(b.b_wf_r <= s.s_w && b.b_hf_r <= s.s_h);
        // stride 1: the r-blocks are exactly 1
        assert_eq!(b.b_wf_r, 1);
        assert_eq!(b.b_hf_r, 1);
    }

    #[test]
    fn more_memory_more_updates_per_tile() {
        let s = resnet50_layers(1000)[1].shape;
        let p = Precision::uniform();
        let small = sequential_blocking(&s, p, 4096.0).updates_per_tile();
        let big = sequential_blocking(&s, p, 262144.0).updates_per_tile();
        assert!(big > small * 4.0, "small={small} big={big}");
    }

    #[test]
    fn strided_layer_uses_small_filter_split() {
        // conv1: 7x7 stride 2 -> q-range = ceil(7/2) = 4, r-range = 2
        let s = resnet50_layers(1000)[0].shape;
        let r = super::ranges(&s);
        assert_eq!(r[5], 4);
        assert_eq!(r[7], 2);
        let b = sequential_blocking(&s, Precision::uniform(), 65536.0);
        assert!(b.b_wf_q <= 4 && b.b_wf_r <= 2);
    }

    #[test]
    fn updates_per_tile_close_to_lp_ideal() {
        // rounding loses at most a constant factor vs the continuous LP
        let s = resnet50_layers(1000)[3].shape; // conv4_x: all dims composite
        let p = Precision::uniform();
        let m = 65536.0;
        let b = sequential_blocking(&s, p, m);
        let ideal: f64 = m.powf(b.lp_x.iter().sum::<f64>());
        let got = b.updates_per_tile();
        assert!(got > ideal / 64.0, "got={got} ideal={ideal}");
    }
}
