//! Hierarchical (nested) blocking for multi-level caches — the attainability
//! side of [`crate::bounds::hierarchy`]: solve the §3.2 LP at the outermost
//! level, then re-block the resulting tile's sub-problem for the next level
//! down, recursively.

use crate::bounds::hierarchy::Hierarchy;
use crate::conv::{ConvShape, Precision};

use super::seq_lp::{sequential_blocking, SeqBlocking};

/// One blocking per cache level, innermost (smallest cache) first.
#[derive(Debug, Clone)]
pub struct HierarchicalBlocking {
    pub levels: Vec<SeqBlocking>,
    /// estimated words crossing each boundary (innermost first)
    pub traffic: Vec<f64>,
}

/// The sub-problem a tile poses to the next cache level down: the tile's
/// extents become the loop ranges (the small-filter split collapses back
/// into plain filter extents).
fn tile_subproblem(s: &ConvShape, b: &SeqBlocking) -> ConvShape {
    ConvShape {
        n: b.b_n.max(1),
        c_i: b.b_ci.max(1),
        c_o: b.b_co.max(1),
        w_o: b.b_wo.max(1),
        h_o: b.b_ho.max(1),
        w_f: (b.b_wf_q * b.b_wf_r).clamp(1, s.w_f),
        h_f: (b.b_hf_q * b.b_hf_r).clamp(1, s.h_f),
        // strides collapse inside a tile whose r-blocks are 1
        s_w: s.s_w.min(b.b_wf_r.max(1) * s.s_w).max(1),
        s_h: s.s_h.min(b.b_hf_r.max(1) * s.s_h).max(1),
    }
}

/// Block a layer for every level of the hierarchy, outermost level first
/// internally, reported innermost first.
pub fn hierarchical_blocking(
    s: &ConvShape,
    p: Precision,
    h: &Hierarchy,
) -> HierarchicalBlocking {
    h.validate();
    let mut levels_out: Vec<SeqBlocking> = Vec::new();
    let mut traffic = Vec::new();
    let mut problem = *s;
    // whole-execution scaling: a level's boundary traffic is its
    // per-sub-problem traffic times the number of enclosing outer tiles
    let mut enclosing_tiles = 1.0;
    // outermost (largest cache) first
    for level in h.levels.iter().rev() {
        let b = sequential_blocking(&problem, p, level.capacity_words);
        let tiles = problem.updates() as f64 / b.updates_per_tile();
        traffic.push(enclosing_tiles
            * (tiles * b.footprint_words(p)
                + p.p_o * problem.output_size() as f64));
        let sub = tile_subproblem(&problem, &b);
        levels_out.push(b);
        enclosing_tiles *= tiles.max(1.0);
        problem = sub;
    }
    levels_out.reverse();
    traffic.reverse();
    HierarchicalBlocking { levels: levels_out, traffic }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bounds::hierarchy::per_level_bounds;
    use crate::conv::resnet50_layers;

    #[test]
    fn every_level_fits_its_cache() {
        let s = resnet50_layers(100)[1].shape;
        let p = Precision::uniform();
        let h = Hierarchy::typical_cpu();
        let hb = hierarchical_blocking(&s, p, &h);
        assert_eq!(hb.levels.len(), h.levels.len());
        for (b, level) in hb.levels.iter().zip(&h.levels) {
            assert!(
                b.fits(p, level.capacity_words),
                "blocking {b:?} does not fit {level:?}"
            );
        }
    }

    #[test]
    fn inner_traffic_exceeds_outer_traffic() {
        // words crossing the L1 boundary >= words crossing the L3 boundary
        let s = resnet50_layers(100)[1].shape;
        let p = Precision::uniform();
        let hb = hierarchical_blocking(&s, p, &Hierarchy::typical_cpu());
        assert!(hb.traffic[0] >= hb.traffic[2] * 0.99, "{:?}", hb.traffic);
    }

    #[test]
    fn traffic_respects_per_level_bounds_up_to_model_slack() {
        // attainability sanity: the nested blocking's boundary traffic is
        // within a constant factor of the per-level lower bound (outer
        // levels see a sub-problem, so compare only the outermost level
        // where problem == full layer)
        let s = resnet50_layers(100)[3].shape;
        let p = Precision::uniform();
        let h = Hierarchy::typical_cpu();
        let hb = hierarchical_blocking(&s, p, &h);
        let bounds = per_level_bounds(&s, p, &h);
        let outer = h.levels.len() - 1;
        let ratio = hb.traffic[outer] / bounds[outer].max().max(1.0);
        assert!(ratio >= 0.9, "traffic below bound?! ratio {ratio}");
        assert!(ratio < 100.0, "blocking far from bound: ratio {ratio}");
    }

    #[test]
    fn subproblem_shrinks() {
        let s = resnet50_layers(64)[1].shape;
        let b = sequential_blocking(&s, Precision::uniform(), 65536.0);
        let sub = tile_subproblem(&s, &b);
        assert!(sub.updates() <= s.updates());
        assert!(sub.n <= s.n && sub.c_i <= s.c_i && sub.c_o <= s.c_o);
    }
}
