//! The §5 integral tile optimizer for GEMMINI.
//!
//! The paper modifies the blocking LP (6) to account for (a) input and
//! filter *sharing* the scratchpad, (b) integral tile sizes, and (c) the
//! row-allocation granularity of the memory controller, and solves the
//! resulting nonlinear integer program with Mathematica's `NMaximize`
//! (~5 s). We solve the same program exactly by exhaustive search over a
//! pruned candidate grid (divisors + clamped powers of two per dimension),
//! which takes milliseconds and is deterministic.
//!
//! Loop nest (fixed by GEMMINI's accumulator semantics): output tiles
//! (n, wo, ho, co) outermost, the cI reduction innermost; the partial sums
//! stay in the accumulator until fully reduced, so output traffic is paid
//! once, while input and filter are reloaded from DRAM at every tile step.

use crate::conv::ConvShape;
use crate::gemmini::config::GemminiConfig;
use crate::util::{ceil_div, divisors};

/// An integral GEMMINI tile over the five blocked loops (filter loops are
/// never tiled: taps stream through the weight-stationary array).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemminiTile {
    pub b_n: u64,
    pub b_ci: u64,
    pub b_co: u64,
    pub b_wo: u64,
    pub b_ho: u64,
}

/// Search objective.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OptObjective {
    /// The paper's §5 objective: maximize updates per tile (the integral
    /// analogue of the blocking LP (6)). Communication-optimal asymptotically
    /// but blind to clipping waste and burst efficiency — which is exactly
    /// how the paper's conv5 regression arises.
    #[default]
    MaxTileUpdates,
    /// Extension (ablation): minimize the estimated communication rows
    /// directly, accounting for edge-tile clipping via ceil tile counts.
    MinCommRows,
}

/// Optimizer options (the paper's "additional constraints may be added"
/// hook, e.g. forbidding the 7×7 conv5 image from being tiled).
#[derive(Debug, Clone, Copy, Default)]
pub struct OptOptions {
    pub objective: OptObjective,
    /// If set, spatial dims whose full range is ≤ this value must not be
    /// tiled (the conv5 fix in §5: image rows fit in a scratchpad line).
    pub no_spatial_tiling_upto: Option<u64>,
}

impl GemminiTile {
    /// Input-patch spatial extent covered by this tile.
    pub fn in_w(&self, s: &ConvShape) -> u64 {
        s.s_w * (self.b_wo - 1) + s.w_f
    }

    pub fn in_h(&self, s: &ConvShape) -> u64 {
        s.s_h * (self.b_ho - 1) + s.h_f
    }

    /// Scratchpad rows for the input block (row granularity: `dim` 8-bit
    /// words of the channel dimension per pixel).
    pub fn input_rows(&self, s: &ConvShape, c: &GemminiConfig) -> u64 {
        self.b_n * self.in_w(s) * self.in_h(s)
            * ceil_div(self.b_ci, c.dim as u64)
    }

    /// Scratchpad rows for the filter block (dim×dim weight sub-blocks, one
    /// per (tap, ci-block, co-block), each occupying `dim` rows).
    pub fn filter_rows(&self, s: &ConvShape, c: &GemminiConfig) -> u64 {
        s.w_f * s.h_f
            * ceil_div(self.b_ci, c.dim as u64)
            * ceil_div(self.b_co, c.dim as u64)
            * c.dim as u64
    }

    /// Accumulator rows for the output block.
    pub fn output_rows(&self, _s: &ConvShape, c: &GemminiConfig) -> u64 {
        self.b_n * self.b_wo * self.b_ho * ceil_div(self.b_co, c.dim as u64)
    }

    /// Does the tile fit (shared scratchpad + accumulator)?
    pub fn fits(&self, s: &ConvShape, c: &GemminiConfig) -> bool {
        self.input_rows(s, c) + self.filter_rows(s, c) <= c.spad_rows() as u64
            && self.output_rows(s, c) <= c.acc_rows() as u64
    }

    /// Tile counts over (n, ci, co, wo, ho).
    pub fn tile_counts(&self, s: &ConvShape) -> [u64; 5] {
        [
            ceil_div(s.n, self.b_n),
            ceil_div(s.c_i, self.b_ci),
            ceil_div(s.c_o, self.b_co),
            ceil_div(s.w_o, self.b_wo),
            ceil_div(s.h_o, self.b_ho),
        ]
    }

    /// Estimated communication in memory-controller rows (the paper's
    /// metric): input+filter rows reloaded at every tile step, output rows
    /// paid once per output tile.
    pub fn comm_rows(&self, s: &ConvShape, c: &GemminiConfig) -> u64 {
        let [tn, tci, tco, two, tho] = self.tile_counts(s);
        let out_tiles = tn * tco * two * tho;
        let all_tiles = out_tiles * tci;
        all_tiles * (self.input_rows(s, c) + self.filter_rows(s, c))
            + out_tiles * self.output_rows(s, c)
    }

    /// Same communication in bytes (input/filter rows are 16 B; accumulator
    /// rows leave the chip after rounding to 8-bit, so 16 B as well).
    pub fn comm_bytes(&self, s: &ConvShape, c: &GemminiConfig) -> u64 {
        self.comm_rows(s, c) * c.dim as u64
    }

    /// Scratchpad utilization of one tile (fraction of usable rows).
    pub fn spad_utilization(&self, s: &ConvShape, c: &GemminiConfig) -> f64 {
        (self.input_rows(s, c) + self.filter_rows(s, c)) as f64
            / c.spad_rows() as f64
    }
}

/// Candidate tile sizes for a loop of the given range: all divisors, the
/// clamped powers of two, and the full range.
fn candidates(range: u64, cap: u64) -> Vec<u64> {
    let mut v = divisors(range);
    let mut p = 1;
    while p < range {
        v.push(p.min(range));
        p *= 2;
    }
    v.push(range);
    v.retain(|&x| x <= cap.max(1));
    v.sort_unstable();
    v.dedup();
    v
}

/// Exhaustively minimize estimated communication over the candidate grid.
///
/// Pruning: dimensions are scanned outer→inner with monotone feasibility
/// (a tile that doesn't fit only gets bigger), and candidate lists are a
/// few dozen entries each, so the search visits ≲ 10⁵ feasible points.
pub fn optimize_gemmini_tiling(
    s: &ConvShape,
    c: &GemminiConfig,
    opts: OptOptions,
) -> GemminiTile {
    let spatial_locked = |range: u64| {
        opts.no_spatial_tiling_upto.map(|t| range <= t).unwrap_or(false)
    };
    let cand_n = candidates(s.n, s.n);
    let cand_ci = candidates(s.c_i, s.c_i);
    let cand_co = candidates(s.c_o, s.c_o);
    let cand_wo = if spatial_locked(s.w_o) {
        vec![s.w_o]
    } else {
        candidates(s.w_o, s.w_o)
    };
    let cand_ho = if spatial_locked(s.h_o) {
        vec![s.h_o]
    } else {
        candidates(s.h_o, s.h_o)
    };

    // (cost, tie) pair to MINIMIZE lexicographically. Max-updates ties are
    // extremely common (products coincide), so the tie-break matters: among
    // equal-updates tiles prefer the one with less estimated communication
    // (halo-wasting shapes like b_wo = 1 lose the tie).
    let cost_of = |t: &GemminiTile| -> (u64, u64) {
        match opts.objective {
            OptObjective::MinCommRows => {
                let updates = t.b_n * t.b_ci * t.b_co * t.b_wo * t.b_ho;
                (t.comm_rows(s, c), u64::MAX - updates)
            }
            OptObjective::MaxTileUpdates => {
                let updates = t.b_n * t.b_ci * t.b_co * t.b_wo * t.b_ho;
                (u64::MAX - updates, t.comm_rows(s, c))
            }
        }
    };

    let mut best: Option<((u64, u64), GemminiTile)> = None;
    for &b_ci in &cand_ci {
        for &b_co in &cand_co {
            for &b_wo in &cand_wo {
                for &b_ho in &cand_ho {
                    for &b_n in &cand_n {
                        let t = GemminiTile { b_n, b_ci, b_co, b_wo, b_ho };
                        if !t.fits(s, c) {
                            break; // larger b_n only grows the tile
                        }
                        let cost = cost_of(&t);
                        if best.as_ref().map(|(bc, _)| cost < *bc).unwrap_or(true) {
                            best = Some((cost, t));
                        }
                    }
                }
            }
        }
    }
    best.expect("no feasible GEMMINI tile — layer larger than buffers at minimum tile")
        .1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::resnet50_layers;

    #[test]
    fn candidates_contain_divisors_and_range() {
        let c = candidates(12, 12);
        for d in [1, 2, 3, 4, 6, 8, 12] {
            assert!(c.contains(&d), "{c:?} missing {d}");
        }
        assert!(c.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn optimal_tile_fits_for_all_resnet_layers() {
        let cfg = GemminiConfig::default();
        for l in resnet50_layers(1000) {
            let t = optimize_gemmini_tiling(&l.shape, &cfg, OptOptions::default());
            assert!(t.fits(&l.shape, &cfg), "{}: {t:?}", l.name);
            assert!(t.b_n >= 1 && t.b_ci >= 1);
        }
    }

    #[test]
    fn comm_counts_compulsory_output_exactly_once() {
        // a tile covering the whole problem moves each array once
        let s = ConvShape::new(1, 16, 16, 8, 8, 3, 3, 1, 1);
        let cfg = GemminiConfig::default();
        let t = GemminiTile { b_n: 1, b_ci: 16, b_co: 16, b_wo: 8, b_ho: 8 };
        assert!(t.fits(&s, &cfg));
        let rows = t.comm_rows(&s, &cfg);
        assert_eq!(
            rows,
            t.input_rows(&s, &cfg) + t.filter_rows(&s, &cfg)
                + t.output_rows(&s, &cfg)
        );
    }

    #[test]
    fn no_spatial_tiling_option_respected() {
        let s = resnet50_layers(64)[4].shape; // conv5_x: 7x7 image
        let cfg = GemminiConfig::default();
        let t = optimize_gemmini_tiling(
            &s,
            &cfg,
            OptOptions { no_spatial_tiling_upto: Some(7), ..Default::default() },
        );
        assert_eq!(t.b_wo, 7);
        assert_eq!(t.b_ho, 7);
    }

    #[test]
    fn small_channel_layer_exploits_batch_or_spatial_dims() {
        // conv1 has cI=3: channel growth cannot fill the buffers, so a good
        // tile must carry a substantial batch×spatial pixel footprint (the
        // accumulator allows up to 512 pixel-rows per co-block).
        let s = resnet50_layers(1000)[0].shape;
        let cfg = GemminiConfig::default();
        let t = optimize_gemmini_tiling(&s, &cfg, OptOptions::default());
        assert!(
            t.b_n * t.b_wo * t.b_ho >= 128,
            "expected large batch/spatial tile, got {t:?}"
        );
        // and the full input-channel extent (no reason to cut cI=3)
        assert_eq!(t.b_ci, 3, "{t:?}");
    }

    #[test]
    fn optimizer_beats_trivial_tile() {
        let cfg = GemminiConfig::default();
        for l in resnet50_layers(100) {
            let t = optimize_gemmini_tiling(&l.shape, &cfg, OptOptions::default());
            // a minimal tile (all 1s except filter) is always feasible…
            let triv = GemminiTile {
                b_n: 1, b_ci: 1.min(l.shape.c_i), b_co: 1, b_wo: 1, b_ho: 1,
            };
            // …and must never beat the optimizer
            assert!(
                t.comm_rows(&l.shape, &cfg) <= triv.comm_rows(&l.shape, &cfg),
                "{}", l.name
            );
        }
    }
}
