//! # convbound
//!
//! A reproduction of *"Communication Bounds for Convolutional Neural
//! Networks"* (Chen, Demmel, Dinh, Haberle, Holtz — PASC '22) as a
//! production-style three-layer Rust + JAX + Pallas stack.
//!
//! The crate contains:
//!
//! * [`hbl`] — the discrete Hölder–Brascamp–Lieb machinery of §2.3: integer
//!   homomorphisms, subgroup lattices, rank computation and the LP over HBL
//!   exponents that yields the paper's constraint table.
//! * [`bounds`] — Theorems 2.1, 2.2 and 2.3: mixed-precision communication
//!   lower bounds for the sequential and parallel memory models.
//! * [`lp`] — an exact-rational two-phase simplex solver (substrate for
//!   [`hbl`] and [`tiling`]).
//! * [`tiling`] — the attainability side: the paper's LP blocking
//!   (sequential, §3.2, with the small-filter trick), the parallel blocking
//!   LP (§4.2), the GEMMINI integral tile optimizer (§5) and the vendor
//!   baseline heuristic it is compared against.
//! * [`commvol`] — symbolic communication-volume models for naive, im2col,
//!   blocking, Winograd and FFT convolutions (Figures 2 and 3).
//! * [`gemmini`] — a cycle-approximate simulator of the GEMMINI accelerator
//!   (scratchpad / accumulator / double-buffered DMA / 16×16 systolic
//!   array), the substrate for Figure 4.
//! * [`kernels`] — the tiled CPU execution engine: packs per-tile working
//!   sets sized to the LP's operand footprints, runs a small GEMM-style
//!   microkernel over the nine blocked loops (including the split-filter
//!   `q/r` dims), counts word traffic against the `commvol` predictions,
//!   autotunes naive/im2col/tiled per shape (persisting choices to a JSON
//!   sidecar), and executes whole-network pipelines with multi-layer
//!   fusion: adjacent stages share one tile sweep so inter-layer
//!   activations never touch main memory.
//! * [`runtime`] — the execution layer behind a pluggable
//!   [`runtime::ExecBackend`]: the default **native** backend runs conv
//!   specs with in-tree kernels (zero setup, zero dependencies), while the
//!   PJRT/XLA backend — loading `artifacts/*.hlo.txt`, AOT-lowered
//!   JAX+Pallas convolutions — sits behind the `pjrt` cargo feature.
//! * [`coordinator`] — the L3 runner: plans tilings per layer and drives
//!   batched network execution across a thread pool.
//! * [`conv`] — problem shapes, the ResNet-50 / AlexNet layer catalogs and a
//!   native naive convolution used to validate the runtime end to end.
//! * [`obs`] — the observability layer: a process-wide JSONL trace sink
//!   (every traffic event carries its analytic expectation next to the
//!   measured words) plus offline replay (`convbound trace
//!   check|summarize`), switchable via `--trace`/`CONVBOUND_TRACE`.
//! * [`util`], [`testkit`], [`bench`] — in-tree substrates (errors, JSON,
//!   CLI, RNG, thread pool, stats; property testing; timing harness) for
//!   the fully offline build environment.

pub mod bench;
pub mod bounds;
pub mod commvol;
pub mod conv;
pub mod coordinator;
pub mod gemmini;
pub mod hbl;
pub mod kernels;
pub mod lp;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod testkit;
pub mod tiling;
pub mod util;
