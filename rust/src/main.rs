//! convbound CLI — the leader entrypoint.
//!
//! ```text
//! convbound hbl-table                       reproduce the §3.1 constraint table
//! convbound bounds  --layer conv2_x ...     Theorem 2.1/2.2/2.3 values
//! convbound fig2    --layer conv1 ...       sequential comm volumes vs M
//! convbound fig3    --layer conv2_x ...     parallel comm volumes vs P
//! convbound fig4    [--claims]              GEMMINI sim, ours vs vendor
//! convbound plan    --layer conv4_x ...     full layer plan (blocking+tile)
//! convbound serve   --key unit3x3/blocked   batched serving demo (native
//!                                           backend; PJRT with artifacts)
//! ```

use convbound::bounds::{parallel_bound_terms, sequential_bound_terms};
use convbound::conv::{find_layer, Precision, Tensor4};
use convbound::coordinator::{plan_layer, ConvServer};
use convbound::gemmini::GemminiConfig;
use convbound::hbl::{analyze_7nl, analyze_small_filter};
use convbound::report::{
    self, default_mem_sweep, default_proc_sweep, fig2_series, fig3_series,
    fig4_rows, fig4_table, ratio_table, Table,
};
use convbound::tiling::OptOptions;
use convbound::util::cli::Args;

fn precision_of(args: &Args) -> Precision {
    match args.opt_str("precision", "mixed") {
        "uniform" => Precision::uniform(),
        "mixed" => Precision::paper_mixed(),
        "gemmini" => Precision::gemmini(),
        other => panic!("unknown --precision {other} (uniform|mixed|gemmini)"),
    }
}

fn layer_of(args: &Args, default: &str) -> (String, convbound::conv::ConvShape) {
    let name = args.opt_str("layer", default).to_string();
    let batch = args.opt_u64("batch", 1000);
    let l = find_layer(&name, batch)
        .unwrap_or_else(|| panic!("unknown layer '{name}' (conv1..conv5_x, alex1..alex5)"));
    (name, l.shape)
}

fn cmd_hbl_table() {
    let sol = analyze_7nl(1, 1);
    println!("7NL CNN HBL analysis (σw = σh = 1)\n");
    let mut t = Table::new(&["rank H", "rk φI(H)", "rk φF(H)", "rk φO(H)", "constraint"]);
    for c in &sol.constraints {
        t.row(vec![
            c.rank_h.to_string(),
            c.ranks_img[0].to_string(),
            c.ranks_img[1].to_string(),
            c.ranks_img[2].to_string(),
            c.pretty(&["I", "F", "O"]),
        ]);
    }
    print!("{}", t.render());
    println!(
        "\noptimal exponents: Σs = {} (LP vertex {:?}; the symmetric optimum is (2/3, 2/3, 2/3))",
        sol.total,
        sol.s.iter().map(|r| r.to_string()).collect::<Vec<_>>()
    );
    let sf = analyze_small_filter();
    println!(
        "small-filter lift: Σs = {} with s = {:?}",
        sf.total,
        sf.s.iter().map(|r| r.to_string()).collect::<Vec<_>>()
    );
}

fn cmd_bounds(args: &Args) {
    let (name, shape) = layer_of(args, "conv2_x");
    let p = precision_of(args);
    let m = args.opt_f64("mem", 65536.0);
    let procs = args.opt_f64("procs", 64.0);
    println!("layer {name}: {shape}");
    println!("precision: pI={} pF={} pO={} (C_p = {})", p.p_i, p.p_f, p.p_o, p.c_p());
    let t = sequential_bound_terms(&shape, p, m);
    println!("\nTheorem 2.1 (sequential, M = {m} words):");
    println!("  compulsory    = {:.3e}", t.compulsory);
    println!("  HBL           = {:.3e}", t.hbl);
    println!("  small-filter  = {:.3e}", t.small_filter);
    println!("  X ≥ {:.3e}  (dominant: {})", t.max(), t.dominant());
    let pt = parallel_bound_terms(&shape, p, procs, m);
    println!("\nTheorems 2.2 + 2.3 (parallel, P = {procs}, M = {m}):");
    println!("  Thm 2.2 HBL           = {:.3e}", pt.hbl);
    println!("  Thm 2.2 small-filter  = {:.3e}", pt.small_filter);
    println!("  Thm 2.3 mem-indep     = {:.3e}", pt.mem_indep);
    println!("  Thm 2.3 small-filter  = {:.3e}", pt.mem_indep_small_filter);
    println!("  X ≥ {:.3e}", pt.max());
}

fn cmd_fig2(args: &Args) {
    let (name, shape) = layer_of(args, "conv1");
    let p = precision_of(args);
    println!("Figure 2 — sequential communication / bound, layer {name}, batch {}\n", shape.n);
    let rows = fig2_series(&shape, p, &default_mem_sweep());
    print!("{}", ratio_table("M (words)", &rows).render());
}

fn cmd_fig3(args: &Args) {
    let (name, shape) = layer_of(args, "conv2_x");
    let p = precision_of(args);
    let m = args.opt_f64("mem", 1e6);
    println!("Figure 3 — parallel communication / bound, layer {name}, batch {}, M = {m}\n", shape.n);
    let rows = fig3_series(&shape, p, &default_proc_sweep(), m);
    print!("{}", ratio_table("P", &rows).render());
}

fn cmd_fig4(args: &Args) {
    let batch = args.opt_u64("batch", 1000);
    let cfg = GemminiConfig::default();
    let fix = args.flag("conv5-fix");
    println!(
        "Figure 4 — GEMMINI simulation, batch {batch}{}\n",
        if fix { " (with the §5 conv5 no-tile constraint)" } else { "" }
    );
    let rows = fig4_rows(batch, &cfg, fix);
    print!("{}", fig4_table(&rows).render());
    if args.flag("claims") {
        println!("\n§5 claims check:");
        for r in &rows {
            println!(
                "  {}: comm {:.0}% of vendor, cycles {:.2}x vendor",
                r.name,
                r.comm_ratio() * 100.0,
                r.cycle_ratio()
            );
        }
    }
}

fn cmd_plan(args: &Args) {
    let (name, shape) = layer_of(args, "conv4_x");
    let p = precision_of(args);
    let m = args.opt_f64("mem", 65536.0);
    let plan = plan_layer(&name, shape, p, m, &GemminiConfig::default(), OptOptions::default());
    println!("plan for {name} ({shape}) at M = {m} words:");
    println!("  LP blocking: {:?}", plan.blocking);
    println!("  fits: {} (footprint {} words)", plan.blocking.fits(p, m),
             report::fmt_f(plan.blocking.footprint_words(p)));
    println!("  GEMMINI tile (ours):   {:?}", plan.gemmini);
    println!("  GEMMINI tile (vendor): {:?}", plan.gemmini_vendor);
    println!("  bound: X ≥ {} words ({})", report::fmt_f(plan.bound.max()), plan.bound.dominant());
    println!("  blocking/bound ratio: {}", report::fmt_x(plan.blocking_ratio()));
}

fn cmd_serve(args: &Args) {
    let dir = args.opt_str("artifacts", "artifacts").to_string();
    let key = args.opt_str("key", "unit3x3/blocked").to_string();
    let requests = args.opt_u64("requests", 32);
    let have_artifacts = std::path::Path::new(&dir).join("manifest.json").exists();
    let manifest = if have_artifacts {
        convbound::runtime::Manifest::load(
            std::path::Path::new(&dir).join("manifest.json"),
        )
        .expect("manifest")
    } else {
        println!("no {dir}/manifest.json — serving over the built-in native backend");
        convbound::runtime::Manifest::builtin(convbound::runtime::manifest::BUILTIN_BATCH)
    };
    let spec = manifest.find(&key).expect("artifact key").clone();
    let wd = &spec.inputs[1];
    let weights = Tensor4::randn([wd[0], wd[1], wd[2], wd[3]], 1);
    let linger = std::time::Duration::from_millis(2);
    let server = if have_artifacts {
        ConvServer::start(&dir, &key, weights, linger)
    } else {
        ConvServer::start_builtin(&key, weights, linger)
    }
    .expect("server start");
    let xd = &spec.inputs[0];
    let mut pending = Vec::new();
    let t0 = std::time::Instant::now();
    for i in 0..requests {
        let img = Tensor4::randn([1, xd[1], xd[2], xd[3]], 100 + i);
        pending.push(server.submit(img).expect("submit"));
    }
    let mut total_latency = 0.0;
    for rx in pending {
        let resp = rx.recv().expect("response");
        total_latency += resp.latency.as_secs_f64();
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.shutdown().expect("shutdown");
    println!("served {requests} requests in {wall:.3}s ({:.1} req/s)", requests as f64 / wall);
    println!("mean latency {:.2} ms", total_latency / requests as f64 * 1e3);
    println!(
        "batches {} (batch size {}), padded slots {}, exec time {:.3}s",
        stats.batches, spec.inputs[0][0], stats.padded_slots, stats.total_exec_secs
    );
}

fn cmd_hlo_stats(args: &Args) {
    let dir = args.opt_str("artifacts", "artifacts").to_string();
    let manifest = convbound::runtime::Manifest::load(
        std::path::Path::new(&dir).join("manifest.json"),
    )
    .expect("manifest (run `make artifacts`)");
    let mut t = Table::new(&["artifact", "instrs", "dots", "dot MACs", "whiles", "fusions"]);
    for a in &manifest.artifacts {
        let st = convbound::runtime::analyze_file(
            std::path::Path::new(&dir).join(&a.path),
        )
        .expect("analyze");
        t.row(vec![
            a.key(),
            st.total.to_string(),
            st.ops.get("dot").copied().unwrap_or(0).to_string(),
            report::fmt_f(st.dot_macs as f64),
            st.while_loops.to_string(),
            st.fusions.to_string(),
        ]);
    }
    print!("{}", t.render());
}

fn main() {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("hbl-table") => cmd_hbl_table(),
        Some("hlo-stats") => cmd_hlo_stats(&args),
        Some("bounds") => cmd_bounds(&args),
        Some("fig2") => cmd_fig2(&args),
        Some("fig3") => cmd_fig3(&args),
        Some("fig4") => cmd_fig4(&args),
        Some("plan") => cmd_plan(&args),
        Some("serve") => cmd_serve(&args),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand '{o}'\n");
            }
            eprintln!("usage: convbound <hbl-table|bounds|fig2|fig3|fig4|plan|serve> [options]");
            eprintln!("  common: --layer conv2_x --batch 1000 --precision mixed|uniform|gemmini");
            eprintln!("  bounds/fig2/plan: --mem <words>;  fig3/bounds: --procs <P>");
            eprintln!("  fig4: --claims --conv5-fix;  serve: --key unit3x3/blocked --requests 32");
            std::process::exit(2);
        }
    }
}
