//! convbound CLI — the leader entrypoint.
//!
//! ```text
//! convbound hbl-table                       reproduce the §3.1 constraint table
//! convbound bounds  --layer conv2_x ...     Theorem 2.1/2.2/2.3 values
//! convbound fig2    --layer conv1 ...       sequential comm volumes vs M
//! convbound fig3    --layer conv2_x ...     parallel comm volumes vs P
//! convbound fig4    [--claims]              GEMMINI sim, ours vs vendor
//! convbound plan    --layer conv4_x ...     full layer plan (blocking+tile)
//! convbound exec    --layer conv4_x ...     run a layer through the CPU
//!                                           kernels (naive|im2col|tiled|auto)
//!                                           with measured word traffic
//! convbound exec    --pass dfilter --check  run a backward convolution
//!                                           (dfilter|dinput) through the
//!                                           pass-generic tiled engine,
//!                                           bitwise vs the naive training
//!                                           oracle, traffic vs the exact
//!                                           per-pass model
//! convbound exec    --network tiny_resnet   run a whole network through the
//!                                           fused pipeline (--fused-kernel
//!                                           packed|reference|auto,
//!                                           --halo-cache on|off; --check
//!                                           compares bitwise vs the staged
//!                                           oracle and validates the
//!                                           traffic + halo models)
//! convbound exec    --network tiny_resnet   run the fused backward sweep or
//!           --pass bwd|step --check         the whole training step as one
//!                                           fused sweep per group (--check
//!                                           compares bitwise vs the
//!                                           layer-by-layer SGD oracles and
//!                                           requires zero fused-boundary
//!                                           words)
//! convbound exec    --layer conv4_x         shard a forward layer (or, with
//!           --shards 4 --shard-by auto      --network, a whole chain) across
//!                                           P in-process virtual workers
//!                                           (batch|channel|spatial|auto|
//!                                           tuned; --check gates the sharded
//!                                           output bitwise against the
//!                                           single-node engine and every
//!                                           shard's measured exchange words
//!                                           against the analytic parallel
//!                                           volume exactly)
//! convbound serve   --key unit3x3/blocked   batched serving demo (native
//!                                           backend; PJRT with artifacts;
//!                                           network keys serve the fused
//!                                           pipeline; --queue N
//!                                           --policy block|shed bounds
//!                                           admission, --deadline-ms K
//!                                           sheds expired work, --check
//!                                           verifies the accounting
//!                                           identity and, with --trace,
//!                                           that trace replay matches
//!                                           ServerStats exactly)
//! convbound trace   check     t.jsonl       validate a JSONL trace (parse,
//!                                           span balance, required kinds)
//! convbound trace   summarize t.jsonl       latency percentiles, batch
//!                                           histogram, per-stage traffic
//!                                           totals and measured-vs-expected
//!                                           mismatches, from the log alone
//! ```
//!
//! Every subcommand accepts `--trace <path>` (or the `CONVBOUND_TRACE`
//! env var) to stream structured JSONL events — request/batch/dispatch
//! spans, plan decisions, per-stage measured-vs-analytic traffic,
//! autotuner probes — to a file while it runs; see DESIGN.md §10.
//!
//! Every subcommand also accepts `--faults <spec>` (or `CONVBOUND_FAULTS`)
//! to arm the deterministic fault-injection harness — e.g.
//! `exec:panic:every=7` panics every 7th kernel tile, `queue:stall:ms=50`
//! makes the server's batcher slow — proving the degradation and
//! backpressure machinery end to end; see DESIGN.md §12.
//!
//! Bad arguments (unknown layers, malformed numbers) exit with a one-line
//! error, not a panic backtrace: every subcommand returns
//! `util::error::Result` and `main` renders the failure.

use std::sync::Arc;
use std::time::Instant;

use convbound::bounds::{parallel_bound_terms, sequential_bound_terms};
use convbound::commvol;
use convbound::conv::{
    conv7nl_naive, find_layer, paper_operands, pass_operands, scaled,
    ConvPass, ConvShape, NetworkStage, Precision, Tensor4,
};
use convbound::coordinator::{
    plan_layer, ConvServer, Overflow, QueuePolicy, ServerOptions,
};
use convbound::err;
use convbound::gemmini::GemminiConfig;
use convbound::hbl::{analyze_7nl, analyze_small_filter};
use convbound::kernels::{
    conv_network_bwd_counted, conv_network_fused_counted,
    conv_network_step_counted, conv_pass_tiled, conv_pass_tiled_counted,
    conv_tiled_counted, conv_winograd_counted, exec_sharded,
    expected_pass_traffic, expected_traffic, expected_winograd_traffic,
    naive_network, naive_network_bwd, naive_network_step, staged_reference,
    verify_exchange, winograd_tolerance, Autotuner, FusePlan, FusedExec,
    KernelKind, NetPass, NetTrafficCounters, ShardPlan, ShardStrategy,
    ShardTrafficCounters, TilePlanCache, Traffic, TrafficCounters, WinoPlan,
    DEFAULT_TILE_MEM_WORDS,
};
use convbound::obs;
use convbound::runtime::fallback;
use convbound::report::{
    self, default_mem_sweep, default_proc_sweep, fig2_series, fig3_series,
    fig4_rows, fig4_table, ratio_table, Table,
};
use convbound::tiling::OptOptions;
use convbound::testkit::faults;
use convbound::util::cli::Args;
use convbound::util::error::{ErrorKind, Result};

fn precision_of(args: &Args) -> Result<Precision> {
    match args.opt_str("precision", "mixed") {
        "uniform" => Ok(Precision::uniform()),
        "mixed" => Ok(Precision::paper_mixed()),
        "gemmini" => Ok(Precision::gemmini()),
        other => Err(err!(
            "unknown --precision '{other}' (uniform|mixed|gemmini)"
        )),
    }
}

/// Parse `--mem` and validate it can hold at least one tile of any
/// supported precision (the blocking LP asserts `M ≥ 4·p_T`), so bad
/// values exit with a message instead of a solver panic.
fn mem_of(args: &Args, default: f64) -> Result<f64> {
    let m = args.opt_f64("mem", default)?;
    if !m.is_finite() || m < 64.0 {
        return Err(err!(
            "--mem must be a finite word count >= 64, got {m}"
        ));
    }
    Ok(m)
}

fn layer_of(
    args: &Args,
    default: &str,
    default_batch: u64,
) -> Result<(String, convbound::conv::ConvShape)> {
    let name = args.opt_str("layer", default).to_string();
    let batch = args.opt_u64("batch", default_batch)?;
    let l = find_layer(&name, batch).ok_or_else(|| {
        err!("unknown layer '{name}' (conv1..conv5_x, alex1..alex5)")
    })?;
    Ok((name, l.shape))
}

fn cmd_hbl_table() -> Result<()> {
    let sol = analyze_7nl(1, 1)?;
    println!("7NL CNN HBL analysis (σw = σh = 1)\n");
    let mut t = Table::new(&["rank H", "rk φI(H)", "rk φF(H)", "rk φO(H)", "constraint"]);
    for c in &sol.constraints {
        t.row(vec![
            c.rank_h.to_string(),
            c.ranks_img[0].to_string(),
            c.ranks_img[1].to_string(),
            c.ranks_img[2].to_string(),
            c.pretty(&["I", "F", "O"]),
        ]);
    }
    print!("{}", t.render());
    println!(
        "\noptimal exponents: Σs = {} (LP vertex {:?}; the symmetric optimum is (2/3, 2/3, 2/3))",
        sol.total,
        sol.s.iter().map(|r| r.to_string()).collect::<Vec<_>>()
    );
    let sf = analyze_small_filter()?;
    println!(
        "small-filter lift: Σs = {} with s = {:?}",
        sf.total,
        sf.s.iter().map(|r| r.to_string()).collect::<Vec<_>>()
    );
    Ok(())
}

fn cmd_bounds(args: &Args) -> Result<()> {
    let (name, shape) = layer_of(args, "conv2_x", 1000)?;
    let p = precision_of(args)?;
    let m = mem_of(args, 65536.0)?;
    let procs = args.opt_f64("procs", 64.0)?;
    println!("layer {name}: {shape}");
    println!("precision: pI={} pF={} pO={} (C_p = {})", p.p_i, p.p_f, p.p_o, p.c_p());
    let t = sequential_bound_terms(&shape, p, m);
    println!("\nTheorem 2.1 (sequential, M = {m} words):");
    println!("  compulsory    = {:.3e}", t.compulsory);
    println!("  HBL           = {:.3e}", t.hbl);
    println!("  small-filter  = {:.3e}", t.small_filter);
    println!("  X ≥ {:.3e}  (dominant: {})", t.max(), t.dominant());
    let pt = parallel_bound_terms(&shape, p, procs, m);
    println!("\nTheorems 2.2 + 2.3 (parallel, P = {procs}, M = {m}):");
    println!("  Thm 2.2 HBL           = {:.3e}", pt.hbl);
    println!("  Thm 2.2 small-filter  = {:.3e}", pt.small_filter);
    println!("  Thm 2.3 mem-indep     = {:.3e}", pt.mem_indep);
    println!("  Thm 2.3 small-filter  = {:.3e}", pt.mem_indep_small_filter);
    println!("  X ≥ {:.3e}", pt.max());
    Ok(())
}

fn cmd_fig2(args: &Args) -> Result<()> {
    let (name, shape) = layer_of(args, "conv1", 1000)?;
    let p = precision_of(args)?;
    println!("Figure 2 — sequential communication / bound, layer {name}, batch {}\n", shape.n);
    let rows = fig2_series(&shape, p, &default_mem_sweep());
    print!("{}", ratio_table("M (words)", &rows).render());
    Ok(())
}

fn cmd_fig3(args: &Args) -> Result<()> {
    let (name, shape) = layer_of(args, "conv2_x", 1000)?;
    let p = precision_of(args)?;
    let m = mem_of(args, 1e6)?;
    println!("Figure 3 — parallel communication / bound, layer {name}, batch {}, M = {m}\n", shape.n);
    let rows = fig3_series(&shape, p, &default_proc_sweep(), m);
    print!("{}", ratio_table("P", &rows).render());
    Ok(())
}

fn cmd_fig4(args: &Args) -> Result<()> {
    let batch = args.opt_u64("batch", 1000)?;
    let cfg = GemminiConfig::default();
    let fix = args.flag("conv5-fix");
    println!(
        "Figure 4 — GEMMINI simulation, batch {batch}{}\n",
        if fix { " (with the §5 conv5 no-tile constraint)" } else { "" }
    );
    let rows = fig4_rows(batch, &cfg, fix);
    print!("{}", fig4_table(&rows).render());
    if args.flag("claims") {
        println!("\n§5 claims check:");
        for r in &rows {
            println!(
                "  {}: comm {:.0}% of vendor, cycles {:.2}x vendor",
                r.name,
                r.comm_ratio() * 100.0,
                r.cycle_ratio()
            );
        }
    }
    Ok(())
}

fn cmd_plan(args: &Args) -> Result<()> {
    let (name, shape) = layer_of(args, "conv4_x", 1000)?;
    let p = precision_of(args)?;
    let m = mem_of(args, 65536.0)?;
    let plan = plan_layer(&name, shape, p, m, &GemminiConfig::default(), OptOptions::default());
    println!("plan for {name} ({shape}) at M = {m} words:");
    println!("  LP blocking: {:?}", plan.blocking);
    println!("  fits: {} (footprint {} words)", plan.blocking.fits(p, m),
             report::fmt_f(plan.blocking.footprint_words(p)));
    println!("  GEMMINI tile (ours):   {:?}", plan.gemmini);
    println!("  GEMMINI tile (vendor): {:?}", plan.gemmini_vendor);
    println!("  bound: X ≥ {} words ({})", report::fmt_f(plan.bound.max()), plan.bound.dominant());
    println!("  blocking/bound ratio: {}", report::fmt_x(plan.blocking_ratio()));
    Ok(())
}

/// Per-stage measured-vs-model traffic report shared by the three
/// network passes; returns the snapshots so `--check` can gate on them.
fn report_network_traffic(
    plan: &FusePlan,
    counters: &NetTrafficCounters,
    layered_total: u64,
) -> (Vec<Traffic>, Vec<Traffic>) {
    let measured = counters.snapshot();
    let expected = plan.expected_network_traffic();
    for (k, (t, e)) in measured.iter().zip(&expected).enumerate() {
        println!(
            "  stage {k}: input {} + filter {} + output {} = {} words \
             (model {}{})",
            t.input_words,
            t.filter_words,
            t.output_words,
            t.total(),
            e.total(),
            if t == e { ", exact" } else { ", MISMATCH" }
        );
    }
    let fused_total = Traffic::sum(&measured).total();
    println!(
        "  fused total {} words vs layer-by-layer {} words ({:.2}x saved)",
        fused_total,
        layered_total,
        layered_total as f64 / fused_total.max(1) as f64
    );
    (measured, expected)
}

/// The `--check` traffic gates shared by the three network passes:
/// measured == model exactly, zero fused-boundary words, and the
/// halo-cache counters matching the analytic savings model.
fn check_network_traffic(
    plan: &FusePlan,
    counters: &NetTrafficCounters,
    measured: &[Traffic],
    expected: &[Traffic],
) -> Result<()> {
    if measured != expected {
        return Err(err!("measured traffic disagrees with the model"));
    }
    let boundary = plan.boundary_words(measured);
    if boundary != 0 {
        return Err(err!(
            "{boundary} words crossed fused boundaries (must be 0)"
        ));
    }
    println!("  fused boundaries touched 0 main-memory words: OK");
    // halo-cache report: measured carried words per stage vs the plan's
    // analytic savings model (exact, like the traffic model)
    let halo_meas = counters.halo_snapshot();
    let halo_want = plan.expected_halo_words();
    for (k, (got, want)) in halo_meas.iter().zip(&halo_want).enumerate() {
        if *got != 0 || *want != 0 {
            println!(
                "  stage {k}: {got} input words served from the halo \
                 cache (model {want}{})",
                if got == want { ", exact" } else { ", MISMATCH" }
            );
        }
    }
    if halo_meas != halo_want {
        return Err(err!(
            "measured halo-cache words disagree with the model"
        ));
    }
    let served: u64 = halo_meas.iter().sum();
    println!(
        "  halo cache ({}) served {served} words without re-read or \
         recompute",
        if plan.halo_cache { "on" } else { "off" }
    );
    Ok(())
}

/// Run a builtin network through the fused executor for any [`NetPass`]
/// (`--pass fwd|bwd|step`) and report fusion decisions, per-stage traffic,
/// the halo-cache savings, and the layer-by-layer comparison;
/// `--fused-kernel` picks the packed microkernel (default), the naive
/// reference oracle, or the autotuner's measured choice; `--check`
/// cross-validates against the layer-by-layer oracles (bitwise on fully
/// fused plans — and on *every* backward plan) and requires the traffic,
/// boundary and halo models to hold exactly.
fn cmd_exec_network(args: &Args, name: &str) -> Result<()> {
    let pass = NetPass::parse(args.opt_str("pass", "fwd")).ok_or_else(|| {
        err!(
            "unknown --pass '{}' for --network (fwd|bwd|step)",
            args.opt_str("pass", "fwd")
        )
    })?;
    let batch = args.opt_u64("batch", convbound::runtime::manifest::BUILTIN_BATCH)?;
    if batch < 1 {
        return Err(err!("--batch must be >= 1"));
    }
    let m = mem_of(args, DEFAULT_TILE_MEM_WORDS)?;
    let halo = match args.opt_str("halo-cache", "on") {
        "on" => true,
        "off" => false,
        other => return Err(err!("unknown --halo-cache '{other}' (on|off)")),
    };
    let halo_w = match args.opt_str("halo-w", "off") {
        "on" => true,
        "off" => false,
        other => return Err(err!("unknown --halo-w '{other}' (on|off)")),
    };
    if halo_w && !halo {
        return Err(err!("--halo-w on requires --halo-cache on"));
    }
    let manifest = convbound::runtime::Manifest::builtin(batch);
    let net = manifest.network(name).ok_or_else(|| {
        err!(
            "unknown --network '{name}' (builtin networks: {})",
            manifest
                .networks
                .iter()
                .map(|n| n.name.as_str())
                .collect::<Vec<_>>()
                .join(", ")
        )
    })?;
    if args.opt("shards").is_some() {
        if pass != NetPass::Forward {
            return Err(err!(
                "--shards supports only --pass fwd with --network \
                 (the backward sweeps are single-node)"
            ));
        }
        return cmd_exec_network_sharded(args, name, net, m);
    }
    let cache = TilePlanCache::new();
    let plan = match args.opt_str("fused-kernel", "packed") {
        "auto" => {
            // the measured network-mode choice (fused-packed vs
            // fused-naive vs materialized), probed per pass the way the
            // kernel autotuner probes kernels and persisted through the
            // same sidecar as the per-layer choices
            let tuner = Autotuner::new(m);
            if let Some(path) = args.opt("tune-cache") {
                let loaded = tuner.warm_start(path)?;
                if loaded > 0 {
                    obs::log(
                        obs::Level::Debug,
                        &format!(
                            "warm-started {loaded} tuned choice(s) from {path}"
                        ),
                    );
                }
            }
            let kind = tuner.select_network_pass(pass, name, &net.stages);
            obs::log(
                obs::Level::Info,
                &format!("autotuner picked '{}'", kind.name()),
            );
            // the requested halo flag reaches the *planner*, so fusion
            // decisions are made under the model this run executes
            let p =
                tuner.network_pass_plan(pass, &net.stages, kind, halo, halo_w);
            if let Some(path) = args.opt("tune-cache") {
                tuner.save(path)?;
            }
            p
        }
        other => match FusedExec::parse(other) {
            Some(exec) => FusePlan::for_pass_with_options(
                pass,
                &net.stages,
                m,
                &cache,
                exec,
                halo,
                halo_w,
            ),
            None => {
                return Err(err!(
                    "unknown --fused-kernel '{other}' (packed|reference|auto)"
                ))
            }
        },
    };
    println!(
        "exec network {name} pass {} (batch {batch}, {} stages, {} MACs) \
         at M = {m} words",
        pass.name(),
        net.stages.len(),
        net.updates()
    );
    println!(
        "  fused kernel '{}', halo cache {}, w-carry {}",
        plan.exec.name(),
        if plan.halo_cache { "on" } else { "off" },
        if plan.halo_w { "on" } else { "off" }
    );
    for g in &plan.groups {
        if g.is_fused() {
            match pass {
                NetPass::Forward => println!(
                    "  stages {}..={} FUSED (last-stage tile N={} wO={} \
                     hO={}; inter-layer activations stay resident)",
                    g.start, g.end, g.b_n, g.b_wo, g.b_ho
                ),
                NetPass::Backward => println!(
                    "  stages {}..={} FUSED (head input-gradient tile N={} \
                     w={} h={}; inter-layer gradients stay resident)",
                    g.start, g.end, g.b_n, g.b_wo, g.b_ho
                ),
                NetPass::Step => println!(
                    "  stages {}..={} FUSED (batch block N={}; activations \
                     recomputed in-tile, gradients stay resident)",
                    g.start, g.end, g.b_n
                ),
            }
        } else {
            println!("  stage {} materialized (LP-tiled)", g.start);
        }
    }

    let tail = &net.stages[net.stages.len() - 1].shape;
    let image = Tensor4::randn(net.input_dims(), 1);
    let gout = Tensor4::randn(
        [
            tail.n as usize,
            tail.c_o as usize,
            tail.w_o as usize,
            tail.h_o as usize,
        ],
        99,
    );
    let filters: Vec<Tensor4> = net
        .stages
        .iter()
        .enumerate()
        .map(|(i, st)| Tensor4::randn(st.shape.filter_dims(), 2 + i as u64))
        .collect();
    let frefs: Vec<&Tensor4> = filters.iter().collect();
    let counters = NetTrafficCounters::new(net.stages.len());

    match pass {
        NetPass::Forward => {
            let t0 = Instant::now();
            let (out, degraded) = fallback::run_recovering(
                name,
                "fused",
                "layered",
                || conv_network_fused_counted(&image, &frefs, &plan, &counters),
                || {
                    counters.reset();
                    naive_network(&image, &frefs, &net.stages)
                },
            );
            let secs = t0.elapsed().as_secs_f64();
            let pair = if degraded {
                println!(
                    "  DEGRADED: fused pipeline failed; reran the staged \
                     naive oracle (traffic gates skipped)"
                );
                None
            } else {
                let layered: u64 = plan
                    .stage_plans
                    .iter()
                    .map(|p| expected_traffic(p).total())
                    .sum();
                Some(report_network_traffic(&plan, &counters, layered))
            };
            println!(
                "  {secs:.3}s, {:.1} MMAC/s",
                net.updates() as f64 / secs.max(1e-9) / 1e6
            );
            if args.flag("check") {
                let want = naive_network(&image, &frefs, &net.stages);
                // a fully fused plan performs the oracle's exact
                // per-element ops in order -> bitwise; materialized stages
                // run the LP-tiled engine's accumulation order -> tolerance
                if plan.groups.len() == 1 && plan.groups[0].is_fused() {
                    let diff = out.max_abs_diff(&want);
                    println!(
                        "  check vs stage-by-stage naive oracle: \
                         max_abs_diff = {diff}"
                    );
                    if diff != 0.0 {
                        return Err(err!(
                            "fused network diverged from the staged oracle: {diff}"
                        ));
                    }
                } else {
                    let rel = out.rel_l2(&want);
                    println!(
                        "  check vs stage-by-stage naive oracle: rel_l2 = {rel:.2e}"
                    );
                    if rel >= 1e-4 {
                        return Err(err!(
                            "network pipeline diverged from the staged oracle: {rel}"
                        ));
                    }
                }
                if let Some((measured, expected)) = &pair {
                    check_network_traffic(&plan, &counters, measured, expected)?;
                }
            } else {
                std::hint::black_box(&out);
            }
        }
        NetPass::Backward => {
            let t0 = Instant::now();
            let (din, degraded) = fallback::run_recovering(
                name,
                "fused-bwd",
                "layered",
                || conv_network_bwd_counted(&gout, &frefs, &plan, &counters),
                || {
                    counters.reset();
                    naive_network_bwd(&gout, &frefs, &net.stages)
                },
            );
            let secs = t0.elapsed().as_secs_f64();
            let pair = if degraded {
                println!(
                    "  DEGRADED: fused backward sweep failed; reran the \
                     layer-by-layer oracle (traffic gates skipped)"
                );
                None
            } else {
                let layered: u64 = plan
                    .dinput_plans
                    .iter()
                    .map(|p| expected_pass_traffic(p).total())
                    .sum();
                Some(report_network_traffic(&plan, &counters, layered))
            };
            println!(
                "  {secs:.3}s, {:.1} MMAC/s",
                net.updates() as f64 / secs.max(1e-9) / 1e6
            );
            if args.flag("check") {
                // the backward accumulation-order contract makes *every*
                // backward plan bitwise — fused, mixed or materialized
                let want = naive_network_bwd(&gout, &frefs, &net.stages);
                let diff = din.max_abs_diff(&want);
                println!(
                    "  check vs layer-by-layer dInput oracle: \
                     max_abs_diff = {diff}"
                );
                if diff != 0.0 {
                    return Err(err!(
                        "fused backward sweep diverged from the oracle: {diff}"
                    ));
                }
                if let Some((measured, expected)) = &pair {
                    check_network_traffic(&plan, &counters, measured, expected)?;
                }
            } else {
                std::hint::black_box(&din);
            }
        }
        NetPass::Step => {
            let t0 = Instant::now();
            let ((dfilters, din), degraded) = fallback::run_recovering(
                name,
                "fused-step",
                "layered",
                || conv_network_step_counted(&image, &frefs, &gout, &plan, &counters),
                || {
                    counters.reset();
                    naive_network_step(&image, &frefs, &gout, &net.stages)
                },
            );
            let secs = t0.elapsed().as_secs_f64();
            let pair = if degraded {
                println!(
                    "  DEGRADED: fused training step failed; reran the \
                     layer-by-layer SGD oracle (traffic gates skipped)"
                );
                None
            } else {
                let layered: u64 = plan
                    .stage_plans
                    .iter()
                    .map(|p| expected_traffic(p).total())
                    .sum::<u64>()
                    + plan
                        .dfilter_plans
                        .iter()
                        .map(|p| expected_pass_traffic(p).total())
                        .sum::<u64>()
                    + plan
                        .dinput_plans
                        .iter()
                        .map(|p| expected_pass_traffic(p).total())
                        .sum::<u64>();
                Some(report_network_traffic(&plan, &counters, layered))
            };
            println!(
                "  {secs:.3}s, {:.1} MMAC/s (forward recompute + dFilter + \
                 dInput)",
                3.0 * net.updates() as f64 / secs.max(1e-9) / 1e6
            );
            if args.flag("check") {
                let (want_df, want_din) =
                    naive_network_step(&image, &frefs, &gout, &net.stages);
                if plan.step_bitwise() {
                    let mut diff = din.max_abs_diff(&want_din);
                    for (df, want) in dfilters.iter().zip(&want_df) {
                        diff = diff.max(df.max_abs_diff(want));
                    }
                    println!(
                        "  check vs layer-by-layer SGD oracle: \
                         max_abs_diff = {diff}"
                    );
                    if diff != 0.0 {
                        return Err(err!(
                            "fused training step diverged from the SGD \
                             oracle: {diff}"
                        ));
                    }
                } else {
                    // a materialized phase-1 forward runs the LP-tiled
                    // engine's accumulation order -> tolerance check
                    let mut rel = din.rel_l2(&want_din);
                    for (df, want) in dfilters.iter().zip(&want_df) {
                        rel = rel.max(df.rel_l2(want));
                    }
                    println!(
                        "  check vs layer-by-layer SGD oracle: rel_l2 = {rel:.2e}"
                    );
                    if rel >= 1e-4 {
                        return Err(err!(
                            "training step diverged from the SGD oracle: {rel}"
                        ));
                    }
                }
                if let Some((measured, expected)) = &pair {
                    check_network_traffic(&plan, &counters, measured, expected)?;
                }
            } else {
                std::hint::black_box((&dfilters, &din));
            }
        }
    }
    Ok(())
}

/// Run one backward convolution (dFilter or dInput) of a catalog layer
/// through the pass-generic tiled engine (or the naive oracle), reporting
/// throughput and measured vs analytic word traffic; `--check`
/// cross-validates the tiled gradient against the `conv/training.rs`
/// naive oracle *bitwise* (the backward accumulation-order contract) and
/// requires the traffic counters to match the per-pass tile-grid model
/// exactly.
fn cmd_exec_pass(args: &Args, pass: ConvPass) -> Result<()> {
    let (name, full) = layer_of(args, "conv4_x", 2)?;
    let scale = args.opt_u64("scale", 1)?.max(1);
    let shape = scaled(full, scale);
    let m = mem_of(args, DEFAULT_TILE_MEM_WORDS)?;
    let p = precision_of(args)?;
    let tuner = Autotuner::with_precision(m, p);
    if let Some(path) = args.opt("tune-cache") {
        let loaded = tuner.warm_start(path)?;
        if loaded > 0 {
            obs::log(
                obs::Level::Debug,
                &format!("warm-started {loaded} tuned choice(s) from {path}"),
            );
        }
    }
    let (a, b) = pass_operands(pass, &shape, 1);

    let kind = match args.opt_str("kernel", "tiled") {
        "auto" => {
            let k = tuner.select_pass(pass, &shape);
            obs::log(
                obs::Level::Info,
                &format!("autotuner picked '{}'", k.name()),
            );
            k
        }
        other => match KernelKind::parse(other) {
            // no im2col or winograd lowering exists for the gradients
            Some(k)
                if k != KernelKind::Im2col && k != KernelKind::Winograd =>
            {
                k
            }
            _ => {
                return Err(err!(
                    "unknown --kernel '{other}' for --pass {} \
                     (naive|tiled|auto)",
                    pass.name()
                ))
            }
        },
    };

    println!(
        "exec {name}{} ({shape}) pass {} via {} at M = {m} words",
        if scale > 1 { format!(" /{scale}") } else { String::new() },
        pass.name(),
        kind.name()
    );

    let out;
    let secs;
    let mut traffic_pair: Option<(Traffic, Traffic)> = None;
    if kind == KernelKind::Tiled {
        let plan = tuner.plan_pass(pass, &shape);
        let counters = TrafficCounters::new();
        let t0 = Instant::now();
        let from = if pass == ConvPass::DFilter { "dfilter" } else { "dinput" };
        let (o, degraded) = fallback::run_recovering(
            &name,
            from,
            "naive",
            || conv_pass_tiled_counted(pass, &a, &b, &plan, &counters),
            || {
                counters.reset();
                pass.naive_oracle(&a, &b, &shape)
            },
        );
        out = o;
        secs = t0.elapsed().as_secs_f64();
        if degraded {
            // traffic_pair stays None: nothing was counted, so `--check`
            // gates only the (bitwise) gradient below
            println!(
                "  DEGRADED: tiled {} path failed; reran the naive oracle \
                 (traffic report skipped)",
                pass.name()
            );
        } else {
            let t = counters.snapshot();
            let e = expected_pass_traffic(&plan);
            let fmt9 = |v: &[u64; 9]| {
                v.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(" ")
            };
            println!(
                "  blocks: [{}] over ranges [{}] -> {} tiles",
                fmt9(&plan.blocks),
                fmt9(&plan.ranges),
                plan.total_tiles()
            );
            println!(
                "  traffic: input {} + filter {} + output {} = {} words \
                 (model {}{})",
                t.input_words,
                t.filter_words,
                t.output_words,
                t.total(),
                e.total(),
                if t == e { ", exact" } else { ", MISMATCH" }
            );
            traffic_pair = Some((t, e));
        }
    } else {
        let t0 = Instant::now();
        out = tuner.run_pass_kernel(pass, kind, &a, &b, &shape);
        secs = t0.elapsed().as_secs_f64();
    }
    println!(
        "  {secs:.3}s, {:.1} MMAC/s",
        shape.updates() as f64 / secs.max(1e-9) / 1e6
    );

    if args.flag("check") {
        // the naive oracle and the tiled engine cross-validate each other:
        // whichever one just ran is held against the other, bitwise
        let (other, want) = if kind == KernelKind::Tiled {
            ("naive", pass.naive_oracle(&a, &b, &shape))
        } else {
            ("tiled", conv_pass_tiled(pass, &a, &b, &tuner.plan_pass(pass, &shape)))
        };
        let diff = out.max_abs_diff(&want);
        println!("  check vs {other} oracle: max_abs_diff = {diff}");
        if diff != 0.0 {
            return Err(err!(
                "{} pass diverged from the {other} oracle: {diff}",
                pass.name()
            ));
        }
        if let Some((t, e)) = traffic_pair {
            if t != e {
                return Err(err!(
                    "measured {} traffic disagrees with the analytic model",
                    pass.name()
                ));
            }
            println!("  measured traffic matches the analytic model exactly: OK");
        }
    } else {
        std::hint::black_box(&out);
    }
    if let Some(path) = args.opt("tune-cache") {
        tuner.save(path)?;
    }
    Ok(())
}

/// Run one catalog layer through a CPU kernel and report throughput plus
/// (for the tiled engine) measured vs modelled word traffic.
/// Resolve `--shards`/`--shard-by` into a [`ShardPlan`] — the analytic
/// `auto` pick, the measured `tuned` pick, or an explicit strategy —
/// shared by the layer and network sharded paths.
fn shard_plan_of(
    args: &Args,
    name: &str,
    stages: &[NetworkStage],
    m: f64,
) -> Result<Arc<ShardPlan>> {
    let shards = args.opt_u64("shards", 1)?;
    if shards < 1 {
        return Err(err!("--shards must be >= 1"));
    }
    let cache = TilePlanCache::new();
    let plan = match args.opt_str("shard-by", "auto") {
        "auto" => ShardPlan::auto(stages, shards, m, &cache),
        "tuned" => {
            let tuner = Autotuner::new(m);
            let strategy = tuner.select_shard(name, stages, shards);
            obs::log(
                obs::Level::Info,
                &format!("autotuner picked shard strategy '{}'", strategy.name()),
            );
            ShardPlan::new(stages, strategy, shards, m, &cache)
        }
        other => match ShardStrategy::parse(other) {
            Some(s) => ShardPlan::new(stages, s, shards, m, &cache),
            None => {
                return Err(err!(
                    "unknown --shard-by '{other}' \
                     (batch|channel|spatial|auto|tuned)"
                ))
            }
        },
    };
    Ok(Arc::new(plan))
}

/// Run a sharded forward chain (one layer or a whole network) and report
/// per-shard exchange words against the analytic parallel volume. A shard
/// panic degrades to the staged naive oracle on one node (exchange gates
/// skipped — the fallback exchanges nothing); `--check` requires the
/// healthy sharded output to be *bitwise* equal to the single-node staged
/// engine and every shard's measured exchange to equal the model exactly.
fn run_sharded(
    args: &Args,
    name: &str,
    plan: &Arc<ShardPlan>,
    image: Arc<Tensor4>,
    filters: Vec<Arc<Tensor4>>,
    updates: u64,
) -> Result<()> {
    let actives: Vec<usize> =
        (0..plan.stages.len()).map(|j| plan.active(j)).collect();
    println!(
        "  shard plan: strategy '{}', {} requested, {} worker(s), \
         per-stage active {actives:?}",
        plan.strategy.name(),
        plan.shards,
        plan.workers()
    );
    let counters = Arc::new(ShardTrafficCounters::new(plan.workers()));
    let frefs: Vec<&Tensor4> = filters.iter().map(|f| f.as_ref()).collect();
    let t0 = Instant::now();
    let (out, degraded) = match exec_sharded(&image, &filters, plan, &counters)
    {
        Ok(o) => (o, false),
        Err(e) => {
            fallback::note_panic(name, "sharded", &e);
            fallback::note_degrade(name, "sharded", "staged-naive", &e);
            (naive_network(&image, &frefs, &plan.stages), true)
        }
    };
    let secs = t0.elapsed().as_secs_f64();
    if degraded {
        println!(
            "  DEGRADED: sharded execution failed; reran the staged naive \
             oracle on one node (exchange gates skipped)"
        );
    } else {
        let expected = plan.expected_per_shard();
        for k in 0..plan.workers() {
            let got = counters.shard(k);
            let want = expected[k];
            println!(
                "  shard {k}: halo {} + gather {} + reduce {} = {} exchange \
                 words (model {}{})",
                got.halo_words,
                got.gather_words,
                got.reduce_words,
                got.total(),
                want.total(),
                if got == want { ", exact" } else { ", MISMATCH" }
            );
        }
        println!(
            "  exchange total {} words (analytic parallel volume {})",
            counters.total().total(),
            plan.expected_exchange().total()
        );
    }
    println!(
        "  {secs:.3}s, {:.1} MMAC/s",
        updates as f64 / secs.max(1e-9) / 1e6
    );
    if args.flag("check") {
        if degraded {
            // the degraded path *is* the staged naive oracle, so the gate
            // left standing is determinism: rerunning it must be bitwise
            let want = naive_network(&image, &frefs, &plan.stages);
            let diff = out.max_abs_diff(&want);
            println!(
                "  check vs staged naive oracle (degraded): \
                 max_abs_diff = {diff}"
            );
            if diff != 0.0 {
                return Err(err!(
                    "degraded sharded run diverged from the staged oracle: \
                     {diff}"
                ));
            }
        } else {
            let want = staged_reference(&image, &frefs, plan);
            let diff = out.max_abs_diff(&want);
            println!(
                "  check vs single-node staged engine: max_abs_diff = {diff}"
            );
            if diff != 0.0 {
                return Err(err!(
                    "sharded output diverged from the single-node engine: \
                     {diff}"
                ));
            }
            verify_exchange(plan, &counters)?;
            println!(
                "  measured exchange matches the analytic parallel volume \
                 exactly: OK"
            );
        }
    } else {
        std::hint::black_box(&out);
    }
    Ok(())
}

/// `exec --layer L --shards P [--shard-by S]`: one catalog layer across P
/// in-process virtual workers (DESIGN.md §13).
fn cmd_exec_layer_sharded(
    args: &Args,
    name: &str,
    shape: ConvShape,
    m: f64,
    p: Precision,
) -> Result<()> {
    let stages = vec![NetworkStage { shape, precision: p }];
    let plan = shard_plan_of(args, name, &stages, m)?;
    println!(
        "exec {name} ({shape}) sharded x{} by '{}' at M = {m} words",
        plan.shards,
        plan.strategy.name()
    );
    let (x, w) = paper_operands(&shape, 1);
    run_sharded(
        args,
        name,
        &plan,
        Arc::new(x),
        vec![Arc::new(w)],
        shape.updates(),
    )
}

/// `exec --network N --shards P [--shard-by S]`: a builtin network chain
/// across P in-process virtual workers (forward only).
fn cmd_exec_network_sharded(
    args: &Args,
    name: &str,
    net: &convbound::runtime::NetworkSpec,
    m: f64,
) -> Result<()> {
    let plan = shard_plan_of(args, name, &net.stages, m)?;
    println!(
        "exec network {name} sharded x{} by '{}' (batch {}, {} stages, \
         {} MACs) at M = {m} words",
        plan.shards,
        plan.strategy.name(),
        net.stages[0].shape.n,
        net.stages.len(),
        net.updates()
    );
    let image = Arc::new(Tensor4::randn(net.input_dims(), 1));
    let filters: Vec<Arc<Tensor4>> = net
        .stages
        .iter()
        .enumerate()
        .map(|(i, st)| {
            Arc::new(Tensor4::randn(st.shape.filter_dims(), 2 + i as u64))
        })
        .collect();
    run_sharded(args, name, &plan, image, filters, net.updates())
}

fn cmd_exec(args: &Args) -> Result<()> {
    if let Some(net) = args.opt("network") {
        // network runs parse `--pass` themselves (fwd|bwd|step — the
        // network-sweep axis, not the single-layer ConvPass below), so an
        // unknown pass string errors instead of being silently ignored
        let net = net.to_string();
        return cmd_exec_network(args, &net);
    }
    match ConvPass::parse(args.opt_str("pass", "fwd")) {
        Some(ConvPass::Forward) => {}
        Some(pass) => {
            if args.opt("shards").is_some() {
                return Err(err!(
                    "--shards supports only the forward pass (--pass fwd)"
                ));
            }
            return cmd_exec_pass(args, pass);
        }
        None => {
            return Err(err!(
                "unknown --pass '{}' (fwd|dfilter|dinput)",
                args.opt_str("pass", "fwd")
            ))
        }
    }
    let (name, full) = layer_of(args, "conv4_x", 2)?;
    let scale = args.opt_u64("scale", 1)?.max(1);
    let shape = scaled(full, scale);
    let m = mem_of(args, DEFAULT_TILE_MEM_WORDS)?;
    // --precision shapes the plan and the traffic model; execution itself
    // is f32 either way
    let p = precision_of(args)?;
    if args.opt("shards").is_some() {
        return cmd_exec_layer_sharded(args, &name, shape, m, p);
    }
    let kernel_arg = args.opt_str("kernel", "tiled");
    // one tuner = one plan cache: selection probes and the final run use
    // the same (precision, M) tiling, solved once
    let tuner = Autotuner::with_precision(m, p);
    // warm-start measured kernel choices from a previous process, if asked
    if let Some(path) = args.opt("tune-cache") {
        let loaded = tuner.warm_start(path)?;
        if loaded > 0 {
            obs::log(
                obs::Level::Debug,
                &format!("warm-started {loaded} kernel choice(s) from {path}"),
            );
        }
    }

    let (x, w) = paper_operands(&shape, 1);

    let kind = match kernel_arg {
        "auto" => {
            let k = tuner.select(&shape);
            obs::log(
                obs::Level::Info,
                &format!("autotuner picked '{}'", k.name()),
            );
            k
        }
        other => KernelKind::parse(other).ok_or_else(|| {
            err!("unknown --kernel '{other}' (naive|im2col|tiled|winograd|auto)")
        })?,
    };

    println!(
        "exec {name}{} ({shape}) via {} at M = {m} words",
        if scale > 1 { format!(" /{scale}") } else { String::new() },
        kind.name()
    );

    let out;
    let secs;
    // winograd's measured-vs-analytic pair, kept for the `--check` gate
    let mut wino_pair: Option<(Traffic, Traffic)> = None;
    // a fast path that panicked (or tripped an injected fault) reran on
    // the naive oracle; traffic gates are skipped — the fallback is
    // uncounted — but the bitwise `--check` gates below still apply
    let mut degraded = false;
    if kind == KernelKind::Tiled {
        let plan = tuner.plan(&shape);
        let counters = TrafficCounters::new();
        let t0 = Instant::now();
        let (o, deg) = fallback::run_recovering(
            &name,
            "tiled",
            "naive",
            || conv_tiled_counted(&x, &w, &plan, &counters),
            || {
                counters.reset();
                conv7nl_naive(&x, &w, &shape)
            },
        );
        out = o;
        degraded = deg;
        secs = t0.elapsed().as_secs_f64();
        if degraded {
            println!(
                "  DEGRADED: tiled path failed; reran the naive oracle \
                 (traffic report skipped)"
            );
        } else {
            let t = counters.snapshot();
            let predicted = commvol::seq::blocking_volume(&shape, p, m);
            println!(
                "  blocks: n={} cI={} cO={} wO={} hO={} q=({}, {}) r=({}, {}) -> {} tiles",
                plan.blocks[0], plan.blocks[1], plan.blocks[2], plan.blocks[3],
                plan.blocks[4], plan.blocks[5], plan.blocks[6], plan.blocks[7],
                plan.blocks[8], plan.total_tiles()
            );
            println!(
                "  traffic: input {} + filter {} + output {} = {} words \
                 ({:.2}x the commvol blocking model)",
                t.input_words, t.filter_words, t.output_words, t.total(),
                t.total() as f64 / predicted.max(1.0)
            );
        }
    } else if kind == KernelKind::Winograd {
        let plan = WinoPlan::new(&shape, p, m);
        let counters = TrafficCounters::new();
        let t0 = Instant::now();
        let (o, deg) = fallback::run_recovering(
            &name,
            "winograd",
            "naive",
            || conv_winograd_counted(&x, &w, &plan, &counters),
            || {
                counters.reset();
                conv7nl_naive(&x, &w, &shape)
            },
        );
        out = o;
        degraded = deg;
        secs = t0.elapsed().as_secs_f64();
        if degraded {
            println!(
                "  DEGRADED: winograd path failed; reran the naive oracle \
                 (traffic report skipped)"
            );
        } else {
            let t = counters.snapshot();
            let e = expected_winograd_traffic(&plan);
            println!(
                "  F(2,3): {} sub-conv(s) x {} tiles, block {}",
                plan.sub_convs(),
                plan.total_tiles(),
                plan.tile_block
            );
            println!(
                "  traffic: input {} + filter {} + output {} = {} words \
                 (model {}{})",
                t.input_words,
                t.filter_words,
                t.output_words,
                t.total(),
                e.total(),
                if t == e { ", exact" } else { ", MISMATCH" }
            );
            wino_pair = Some((t, e));
        }
    } else {
        let t0 = Instant::now();
        out = tuner.run_kernel(kind, &x, &w, &shape);
        secs = t0.elapsed().as_secs_f64();
    }
    println!(
        "  {secs:.3}s, {:.1} MMAC/s",
        shape.updates() as f64 / secs.max(1e-9) / 1e6
    );

    if args.flag("check") {
        // cross-validate against an *independent* kernel: the naive nest
        // for im2col/tiled, and im2col for the naive nest itself
        let (oracle, want) = if kind == KernelKind::Naive {
            ("im2col", tuner.run_kernel(KernelKind::Im2col, &x, &w, &shape))
        } else {
            ("naive", conv7nl_naive(&x, &w, &shape))
        };
        let rel = out.rel_l2(&want);
        println!("  check vs {oracle} oracle: rel_l2 = {rel:.2e}");
        if rel >= 1e-4 {
            return Err(err!("kernel disagrees with the {oracle} oracle: {rel}"));
        }
        if kind == KernelKind::Winograd {
            // transforms reassociate, so the gate is the documented
            // ULP-scaled tolerance oracle plus exact traffic — see
            // kernels/winograd.rs and DESIGN.md §11
            let tol = winograd_tolerance(&x, &w, &shape);
            let diff = out.max_abs_diff(&want);
            println!(
                "  winograd tolerance oracle: max_abs_diff = {diff:.3e} \
                 (bound {tol:.3e})"
            );
            if diff > tol {
                return Err(err!(
                    "winograd exceeded the tolerance oracle: {diff} > {tol}"
                ));
            }
            // a degraded run never counted winograd traffic, so there is
            // nothing to hold against the model
            if !degraded {
                match wino_pair {
                    Some((t, e)) if t == e => println!(
                        "  measured traffic matches expected_winograd_traffic \
                         exactly: OK"
                    ),
                    _ => {
                        return Err(err!(
                            "measured winograd traffic disagrees with \
                             expected_winograd_traffic"
                        ))
                    }
                }
            }
        }
    } else {
        // keep `out` observable so the kernel call is never optimized away
        std::hint::black_box(&out);
    }
    // persist whatever the tuner learned this run for the next process
    if let Some(path) = args.opt("tune-cache") {
        tuner.save(path)?;
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let dir = args.opt_str("artifacts", "artifacts").to_string();
    let key = args.opt_str("key", "unit3x3/blocked").to_string();
    let requests = args.opt_u64("requests", 32)?;
    // sharded dispatch (DESIGN.md §13): the env pair is how the native
    // backend picks up the config — ServerOptions stays transport-only,
    // and a sharded executor is bitwise-identical to the single-node one
    if args.opt("shards").is_some() {
        let shards = args.opt_u64("shards", 1)?;
        if shards < 1 {
            return Err(err!("--shards must be >= 1"));
        }
        let by = args.opt_str("shard-by", "auto");
        if by != "auto" && ShardStrategy::parse(by).is_none() {
            return Err(err!(
                "unknown --shard-by '{by}' (batch|channel|spatial|auto)"
            ));
        }
        std::env::set_var("CONVBOUND_SHARDS", shards.to_string());
        std::env::set_var("CONVBOUND_SHARD_BY", by);
    }
    // fault-tolerance knobs (DESIGN.md §12): a bounded admission queue
    // with a block|shed overflow policy, and a per-request deadline
    let queue = match args.opt("queue") {
        Some(_) => {
            let cap = args.opt_u64("queue", 0)?;
            if cap == 0 {
                return Err(err!("--queue must be >= 1"));
            }
            let overflow = match args.opt_str("policy", "block") {
                "block" => Overflow::Block,
                "shed" => Overflow::Shed,
                other => {
                    return Err(err!("unknown --policy '{other}' (block|shed)"))
                }
            };
            Some(QueuePolicy { capacity: cap, overflow })
        }
        None => {
            if args.opt("policy").is_some() {
                return Err(err!("--policy requires --queue <capacity>"));
            }
            None
        }
    };
    let deadline = match args.opt("deadline-ms") {
        Some(_) => Some(std::time::Duration::from_millis(
            args.opt_u64("deadline-ms", 0)?,
        )),
        None => None,
    };
    let opts = ServerOptions {
        queue,
        deadline,
        linger: std::time::Duration::from_millis(2),
    };
    let have_artifacts = std::path::Path::new(&dir).join("manifest.json").exists();
    let manifest = if have_artifacts {
        convbound::runtime::Manifest::load(
            std::path::Path::new(&dir).join("manifest.json"),
        )?
    } else {
        println!("no {dir}/manifest.json — serving over the built-in native backend");
        convbound::runtime::Manifest::builtin(convbound::runtime::manifest::BUILTIN_BATCH)
    };
    let spec = manifest
        .find(&key)
        .ok_or_else(|| err!("artifact '{key}' not in manifest"))?
        .clone();
    // one random filter tensor per weight input: single-layer artifacts
    // take one, network pipelines one per stage
    let weights: Vec<Tensor4> = spec.inputs[1..]
        .iter()
        .enumerate()
        .map(|(i, d)| Tensor4::randn([d[0], d[1], d[2], d[3]], 1 + i as u64))
        .collect();
    let server = if have_artifacts {
        ConvServer::start_opts(&dir, &key, weights, opts)
    } else {
        ConvServer::start_builtin_opts(&key, weights, opts)
    }?;
    let xd = &spec.inputs[0];
    let mut pending = Vec::new();
    let mut client_shed: u64 = 0;
    let t0 = Instant::now();
    for i in 0..requests {
        let img = Tensor4::randn([1, xd[1], xd[2], xd[3]], 100 + i);
        match server.submit(img) {
            Ok(rx) => pending.push(rx),
            // a full Shed queue is load shedding working as configured,
            // not a serve failure
            Err(e) if e.kind() == ErrorKind::QueueFull => client_shed += 1,
            Err(e) => return Err(e),
        }
    }
    let mut ok: u64 = 0;
    let mut errs: u64 = 0;
    let mut total_latency = 0.0;
    for rx in pending {
        match rx.recv().map_err(|_| err!("server dropped a response"))? {
            Ok(resp) => {
                ok += 1;
                total_latency += resp.latency.as_secs_f64();
            }
            // typed per-request failure (expired deadline, failed batch)
            Err(_) => errs += 1,
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.shutdown()?;
    println!(
        "served {ok}/{requests} requests in {wall:.3}s ({:.1} req/s)",
        ok as f64 / wall.max(1e-9)
    );
    if ok > 0 {
        println!("mean latency {:.2} ms", total_latency / ok as f64 * 1e3);
    }
    println!(
        "batches {} (batch size {}), padded slots {}, exec time {:.3}s",
        stats.batches, spec.inputs[0][0], stats.padded_slots, stats.total_exec_secs
    );
    println!(
        "latency p50 {:.2} / p95 {:.2} / p99 {:.2} ms, peak queue depth {}",
        stats.latency_p50_ms,
        stats.latency_p95_ms,
        stats.latency_p99_ms,
        stats.peak_queue_depth
    );
    println!(
        "dispositions: ok {} failed {} shed {} expired {}; panicked {} degraded {}",
        stats.requests, stats.failed, stats.shed, stats.expired,
        stats.panicked, stats.degraded
    );
    if args.flag("check") {
        // the client kept its own books; they must agree with the
        // server's, and both with the accounting identity
        if stats.requests != ok {
            return Err(err!(
                "serve --check: server says {} ok, client saw {ok}",
                stats.requests
            ));
        }
        if stats.shed != client_shed {
            return Err(err!(
                "serve --check: server shed {}, client saw {client_shed}",
                stats.shed
            ));
        }
        if stats.failed + stats.expired != errs {
            return Err(err!(
                "serve --check: server failed+expired {}, client saw {errs}",
                stats.failed + stats.expired
            ));
        }
        if let Some(pol) = queue {
            if pol.overflow == Overflow::Shed
                && stats.peak_queue_depth > pol.capacity
            {
                return Err(err!(
                    "serve --check: peak queue depth {} exceeded capacity {}",
                    stats.peak_queue_depth,
                    pol.capacity
                ));
            }
        }
        let submitted = ok + errs + client_shed;
        if stats.requests + stats.failed + stats.expired + stats.shed != submitted {
            return Err(err!(
                "serve --check: accounting identity broken ({submitted} submitted)"
            ));
        }
        if let Some(path) = args.opt("trace") {
            // replay the structured log and require its counters to match
            // ServerStats exactly — the trace is the ground truth the
            // fault gates in ci.sh rely on
            obs::flush();
            let s = obs::replay::summarize_file(path)?;
            let want = [
                ("requests", s.requests, stats.requests),
                ("failed", s.dropped_requests, stats.failed),
                ("shed", s.shed, stats.shed),
                ("expired", s.expired, stats.expired),
                ("panicked", s.panicked, stats.panicked),
                ("degraded", s.degraded, stats.degraded),
                ("batches", s.batches, stats.batches),
            ];
            for (what, replayed, served) in want {
                if replayed != served {
                    return Err(err!(
                        "serve --check: trace replay {what} = {replayed} but \
                         ServerStats says {served}"
                    ));
                }
            }
            println!("serve --check: trace replay matches ServerStats exactly: OK");
        } else {
            println!("serve --check: accounting identity holds: OK");
        }
    }
    Ok(())
}

/// Offline trace replay: `convbound trace check|summarize <file.jsonl>`.
/// `check` validates structure (every line parses, timestamps are
/// monotone, spans balance) and `summarize` reconstructs the run's
/// metrics — latency percentiles, batch histogram, per-stage traffic
/// totals, measured-vs-expected mismatches — from the log alone.
fn cmd_trace(args: &Args) -> Result<()> {
    let usage = "usage: convbound trace <check|summarize> <trace.jsonl>";
    let mode = args
        .positional
        .first()
        .ok_or_else(|| err!("{usage}"))?
        .as_str();
    let path = args
        .positional
        .get(1)
        .ok_or_else(|| err!("{usage}"))?
        .as_str();
    match mode {
        "check" => {
            let report = obs::replay::check_file(path)?;
            println!("{}", report.render());
        }
        "summarize" => {
            let summary = obs::replay::summarize_file(path)?;
            print!("{}", summary.render());
        }
        other => {
            return Err(err!("unknown trace mode '{other}' (check|summarize)"))
        }
    }
    Ok(())
}

fn cmd_hlo_stats(args: &Args) -> Result<()> {
    let dir = args.opt_str("artifacts", "artifacts").to_string();
    let manifest = convbound::runtime::Manifest::load(
        std::path::Path::new(&dir).join("manifest.json"),
    )?;
    let mut t = Table::new(&["artifact", "instrs", "dots", "dot MACs", "whiles", "fusions"]);
    for a in &manifest.artifacts {
        let st = convbound::runtime::analyze_file(
            std::path::Path::new(&dir).join(&a.path),
        )?;
        t.row(vec![
            a.key(),
            st.total.to_string(),
            st.ops.get("dot").copied().unwrap_or(0).to_string(),
            report::fmt_f(st.dot_macs as f64),
            st.while_loops.to_string(),
            st.fusions.to_string(),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn exec_rejects_unknown_pass_for_networks() {
        // regression: the --network branch used to return before --pass
        // parsing, so a bad pass string was silently ignored instead of
        // producing a Result error listing the valid values
        let a = parse("exec --network tiny_resnet --pass nonsense");
        let e = cmd_exec(&a).unwrap_err().to_string();
        assert!(e.contains("--pass"), "{e}");
        assert!(e.contains("nonsense"), "{e}");
        assert!(e.contains("fwd|bwd|step"), "{e}");
    }

    #[test]
    fn trace_rejects_missing_or_unknown_modes() {
        let e = cmd_trace(&parse("trace")).unwrap_err().to_string();
        assert!(e.contains("usage"), "{e}");
        let e = cmd_trace(&parse("trace summarize")).unwrap_err().to_string();
        assert!(e.contains("usage"), "{e}");
        let e = cmd_trace(&parse("trace frobnicate x.jsonl"))
            .unwrap_err()
            .to_string();
        assert!(e.contains("frobnicate"), "{e}");
        assert!(e.contains("check|summarize"), "{e}");
    }

    #[test]
    fn trace_check_and_summarize_roundtrip_a_real_log() {
        use convbound::obs::{self, js, ju};
        let path = std::env::temp_dir().join("convbound_cli_trace_test.jsonl");
        let path = path.to_str().unwrap().to_string();
        let sink = obs::TraceSink::to_file(&path).unwrap();
        obs::install(&sink).unwrap();
        obs::event(
            obs::kind::TRAFFIC,
            &[
                ("pass", js("fwd")),
                ("measured_input", ju(10)),
                ("measured_filter", ju(4)),
                ("measured_output", ju(6)),
                ("expected_input", ju(10)),
                ("expected_filter", ju(4)),
                ("expected_output", ju(6)),
            ],
        );
        obs::uninstall();
        assert!(cmd_trace(&parse(&format!("trace check {path}"))).is_ok());
        assert!(cmd_trace(&parse(&format!("trace summarize {path}"))).is_ok());
        let s = obs::replay::summarize_file(&path).unwrap();
        assert_eq!(s.measured_words, 20);
        assert_eq!(s.mismatches, 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn exec_rejects_bad_shard_flags() {
        let e = cmd_exec(&parse("exec --shards 0")).unwrap_err().to_string();
        assert!(e.contains("--shards"), "{e}");
        assert!(e.contains(">= 1"), "{e}");
        let e = cmd_exec(&parse("exec --shards 2 --shard-by ring"))
            .unwrap_err()
            .to_string();
        assert!(e.contains("ring"), "{e}");
        assert!(e.contains("batch|channel|spatial|auto|tuned"), "{e}");
    }

    #[test]
    fn exec_rejects_shards_on_backward_passes() {
        let e = cmd_exec(&parse("exec --pass dfilter --shards 2"))
            .unwrap_err()
            .to_string();
        assert!(e.contains("forward"), "{e}");
        let e = cmd_exec(&parse(
            "exec --network tiny_resnet --pass bwd --shards 2",
        ))
        .unwrap_err()
        .to_string();
        assert!(e.contains("--pass fwd"), "{e}");
    }

    #[test]
    fn exec_rejects_unknown_pass_for_layers() {
        let a = parse("exec --pass sideways");
        let e = cmd_exec(&a).unwrap_err().to_string();
        assert!(e.contains("sideways"), "{e}");
        assert!(e.contains("fwd|dfilter|dinput"), "{e}");
    }
}

fn main() {
    let args = Args::from_env();
    // --trace wins over the CONVBOUND_TRACE env var; init_from_env also
    // picks up CONVBOUND_VERBOSE either way
    obs::init_from_env();
    if let Some(path) = args.opt("trace") {
        if let Err(e) = obs::install_file(path) {
            eprintln!("error: --trace {path}: {e}");
            std::process::exit(1);
        }
    }
    if args.flag("verbose") {
        obs::set_verbosity(obs::Level::Debug as u8);
    }
    // deterministic fault injection (DESIGN.md §12): --faults wins over
    // the CONVBOUND_FAULTS env var; a malformed spec is a startup error
    if let Err(e) = faults::init_from_env() {
        eprintln!("error: CONVBOUND_FAULTS: {e}");
        std::process::exit(1);
    }
    if let Some(spec) = args.opt("faults") {
        if let Err(e) = faults::install_spec(spec) {
            eprintln!("error: --faults {spec}: {e}");
            std::process::exit(1);
        }
    }
    let result = match args.subcommand.as_deref() {
        Some("hbl-table") => cmd_hbl_table(),
        Some("hlo-stats") => cmd_hlo_stats(&args),
        Some("bounds") => cmd_bounds(&args),
        Some("fig2") => cmd_fig2(&args),
        Some("fig3") => cmd_fig3(&args),
        Some("fig4") => cmd_fig4(&args),
        Some("plan") => cmd_plan(&args),
        Some("exec") => cmd_exec(&args),
        Some("serve") => cmd_serve(&args),
        Some("trace") => cmd_trace(&args),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand '{o}'\n");
            }
            eprintln!("usage: convbound <hbl-table|bounds|fig2|fig3|fig4|plan|exec|serve|trace> [options]");
            eprintln!("  common: --layer conv2_x --batch 1000 --precision mixed|uniform|gemmini");
            eprintln!("  bounds/fig2/plan: --mem <words>;  fig3/bounds: --procs <P>");
            eprintln!("  exec: --kernel naive|im2col|tiled|winograd|auto --scale <k> --check --tune-cache <path>");
            eprintln!("        --pass fwd|dfilter|dinput (backward passes: --kernel naive|tiled|auto)");
            eprintln!("        --network tiny_resnet|deep_mixnet [--batch N] [--mem M] [--check]");
            eprintln!("        --fused-kernel packed|reference|auto --halo-cache on|off --halo-w on|off");
            eprintln!("        --pass fwd|bwd|step (with --network: fused backward / training-step sweeps)");
            eprintln!("        --shards P --shard-by batch|channel|spatial|auto|tuned (sharded forward");
            eprintln!("        execution; --check gates bitwise output + exact exchange words)");
            eprintln!("  fig4: --claims --conv5-fix;  serve: --key unit3x3/blocked --requests 32");
            eprintln!("        --queue <cap> --policy block|shed --deadline-ms <ms> --check");
            eprintln!("        --shards P --shard-by batch|channel|spatial|auto (sharded dispatch)");
            eprintln!("  trace: check|summarize <trace.jsonl> (replay a structured log offline)");
            eprintln!("  any:  --trace <path> (JSONL event log; CONVBOUND_TRACE env works too)");
            eprintln!("        --verbose (debug-level diagnostics on stderr; CONVBOUND_VERBOSE=2)");
            eprintln!("        --faults <spec> (deterministic fault injection, e.g. exec:panic:every=7;");
            eprintln!("        sites exec|queue, actions panic|error|stall; CONVBOUND_FAULTS env works too)");
            std::process::exit(2);
        }
    };
    // close the span-free tail of the log deterministically: flush and
    // drop the sink before the process exits (nothing is written after)
    obs::uninstall();
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
