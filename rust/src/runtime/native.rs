//! The default execution backend: runs single-layer conv artifacts with
//! in-tree kernels — no PJRT, no artifact files, no external crates.
//!
//! An [`crate::runtime::ArtifactSpec`] of kind `"blocked"` executes through
//! [`crate::conv::conv7nl_naive`]; kind `"im2col"` executes through a
//! genuinely different code path ([`conv_im2col`]: patch-matrix + GEMM);
//! kind `"tiled"` routes through the `kernels/` LP-blocked tiled engine
//! (packed per-tile working sets, traffic counters, output tiles fanned
//! out over a shared thread pool); kind `"winograd"` routes through the
//! tiled F(2,3) transform-domain kernel (4 multiplies per 2 outputs on
//! 3×3 stencils, polyphase decomposition otherwise — a *reassociating*
//! path, so agreement tests use the scaled tolerance oracle rather than
//! bitwise equality); kinds `"dfilter"`/`"dinput"` run the
//! backward convolutions of a training step through the same pass-generic
//! tiled engine (bitwise identical to the `conv/training.rs` naive
//! oracles); kind `"network"` executes a whole
//! [`crate::runtime::manifest::NetworkSpec`] pipeline through the
//! `kernels/fuse` fused executor, and kind `"training"` runs the same
//! pipeline's fused *backward* sweep — tail loss gradient in, head image
//! gradient out, dInput chained stage to stage without materializing
//! interior gradients (both resolved via [`ExecBackend::load_network`] —
//! the single-layer `load` entry rejects them). Three independent
//! single-layer accumulation orders, so cross-kind
//! agreement tests exercise real cross-validation even without compiled
//! artifacts.
//!
//! The [`ConvShape`] is recovered and validated by
//! [`ArtifactSpec::layer_shape`] (the one authoritative inversion of the
//! paper's input convention `WI = σw·wO + wF`): a spec that is not a
//! consistent paper-convention conv layer is rejected at load time.
//!
//! Tiled executables share one [`TilePlanCache`] and one lazily spawned
//! [`ThreadPool`] per backend instance (clones share both), so repeated
//! loads of the same shape never re-solve the blocking LP and the worker
//! threads only exist once a tiled artifact is actually loaded.

use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::conv::{conv7nl_naive, ConvPass, ConvShape, Precision, Tensor4};
use crate::err;
use crate::kernels::{
    conv_network_bwd, conv_network_fused, conv_pass_tiled_parallel,
    conv_tiled_parallel, conv_winograd_parallel, exec_sharded, naive_network,
    naive_network_bwd, FusePlan, NetPass, NetTrafficCounters, ShardPlan,
    ShardStrategy, ShardTrafficCounters, TilePlan, TilePlanCache, Traffic,
    TrafficCounters, WinoPlan, DEFAULT_TILE_MEM_WORDS,
};
use crate::util::error::Result;
use crate::util::threadpool::ThreadPool;

pub use crate::kernels::conv_im2col;

use super::backend::{ExecBackend, Executable};
use super::fallback::FallbackExec;
use super::manifest::{ArtifactSpec, NetworkSpec, NetworkStage};

/// The in-tree CPU backend.
#[derive(Clone)]
pub struct NativeBackend {
    plans: Arc<TilePlanCache>,
    pool: Arc<Mutex<Option<Arc<ThreadPool>>>>,
    /// `> 1` routes forward `"network"` pipelines through the sharded
    /// executor (DESIGN.md §13) instead of the fused single-node path.
    shards: u64,
    /// Explicit shard strategy; `None` means the analytic `auto` pick.
    shard_by: Option<ShardStrategy>,
}

impl Default for NativeBackend {
    fn default() -> NativeBackend {
        NativeBackend::new()
    }
}

impl NativeBackend {
    /// Environment-configured backend: `CONVBOUND_SHARDS` (worker count)
    /// and `CONVBOUND_SHARD_BY` (strategy name) select sharded network
    /// dispatch; absent or unparsable values mean single-node `auto`.
    /// The env pair exists so `serve --shards` reaches the executor the
    /// server spawns without widening every construction site.
    pub fn new() -> NativeBackend {
        let shards = std::env::var("CONVBOUND_SHARDS")
            .ok()
            .and_then(|v| v.trim().parse::<u64>().ok())
            .unwrap_or(1);
        let shard_by = std::env::var("CONVBOUND_SHARD_BY")
            .ok()
            .and_then(|v| ShardStrategy::parse(v.trim()));
        NativeBackend::with_shards(shards, shard_by)
    }

    /// Direct constructor for tests and embedders: `shards` virtual
    /// workers for forward network pipelines, `shard_by` an explicit
    /// strategy or `None` for the analytic `auto` pick.
    pub fn with_shards(
        shards: u64,
        shard_by: Option<ShardStrategy>,
    ) -> NativeBackend {
        NativeBackend {
            plans: Arc::new(TilePlanCache::new()),
            pool: Arc::new(Mutex::new(None)),
            shards: shards.max(1),
            shard_by,
        }
    }

    /// The shared tile-execution pool, spawned on first use.
    fn tiled_pool(&self) -> Arc<ThreadPool> {
        let mut slot = self.pool.lock().expect("pool slot poisoned");
        if let Some(pool) = slot.as_ref() {
            return Arc::clone(pool);
        }
        let pool = Arc::new(ThreadPool::new(crate::kernels::default_workers()));
        *slot = Some(Arc::clone(&pool));
        pool
    }
}

impl ExecBackend for NativeBackend {
    fn platform(&self) -> String {
        "native-cpu".to_string()
    }

    fn supports_networks(&self) -> bool {
        true
    }

    fn load(
        &mut self,
        spec: &ArtifactSpec,
        _path: Option<&Path>,
    ) -> Result<Box<dyn Executable>> {
        match spec.kind.as_str() {
            // the naive/im2col paths ARE the simplest verified paths:
            // nothing to degrade to, but panics still become typed errors
            "blocked" => Ok(Box::new(FallbackExec::guard(
                spec.key(),
                "naive",
                Box::new(NaiveExec { shape: spec.layer_shape()? }),
            ))),
            "im2col" => Ok(Box::new(FallbackExec::guard(
                spec.key(),
                "im2col",
                Box::new(Im2colExec { shape: spec.layer_shape()? }),
            ))),
            "tiled" => {
                let shape = spec.layer_shape()?;
                let plan = self.plans.plan(
                    &shape,
                    Precision::uniform(),
                    DEFAULT_TILE_MEM_WORDS,
                );
                let counters = Arc::new(TrafficCounters::new());
                let c = Arc::clone(&counters);
                Ok(Box::new(FallbackExec::new(
                    spec.key(),
                    "tiled",
                    "naive",
                    Box::new(TiledExec {
                        plan,
                        pool: self.tiled_pool(),
                        counters,
                    }),
                    Box::new(NaiveExec { shape }),
                    Some(Box::new(move || c.reset())),
                )))
            }
            "winograd" => {
                let shape = spec.layer_shape()?;
                let plan = Arc::new(WinoPlan::new(
                    &shape,
                    Precision::uniform(),
                    DEFAULT_TILE_MEM_WORDS,
                ));
                let counters = Arc::new(TrafficCounters::new());
                let c = Arc::clone(&counters);
                Ok(Box::new(FallbackExec::new(
                    spec.key(),
                    "winograd",
                    "naive",
                    Box::new(WinogradExec {
                        plan,
                        pool: self.tiled_pool(),
                        counters,
                    }),
                    Box::new(NaiveExec { shape }),
                    Some(Box::new(move || c.reset())),
                )))
            }
            "dfilter" | "dinput" => {
                let pass = ConvPass::parse(&spec.kind)
                    .expect("matched kinds parse as passes");
                let shape = spec.pass_shape(pass)?;
                let plan = self.plans.plan_pass(
                    pass,
                    &shape,
                    Precision::uniform(),
                    DEFAULT_TILE_MEM_WORDS,
                );
                let counters = Arc::new(TrafficCounters::new());
                let c = Arc::clone(&counters);
                Ok(Box::new(FallbackExec::new(
                    spec.key(),
                    if pass == ConvPass::DFilter { "dfilter" } else { "dinput" },
                    "naive",
                    Box::new(PassExec {
                        pass,
                        plan,
                        pool: self.tiled_pool(),
                        counters,
                    }),
                    Box::new(NaivePassExec { pass, shape }),
                    Some(Box::new(move || c.reset())),
                )))
            }
            "network" | "training" => Err(err!(
                "artifact '{}' is a network pipeline but the manifest \
                 carries no matching 'networks' entry to execute it \
                 natively: add one (name '{}', a stage per conv), or build \
                 with --features pjrt to run the compiled HLO over XLA",
                spec.key(),
                spec.name
            )),
            other => Err(err!(
                "native backend cannot execute artifact '{}' of kind '{other}' \
                 (single-layer 'blocked'/'im2col'/'tiled'/'winograd' specs, \
                 training 'dfilter'/'dinput' specs, or 'network'/'training' \
                 pipelines); build with --features pjrt to run it over XLA",
                spec.key()
            )),
        }
    }

    fn load_network(
        &mut self,
        net: &NetworkSpec,
        spec: &ArtifactSpec,
    ) -> Result<Box<dyn Executable>> {
        if spec.inputs.len() != net.stages.len() + 1 {
            return Err(err!(
                "network artifact '{}' wants {} + {} filters, spec has {} \
                 inputs",
                spec.key(),
                if spec.kind == "training" { "loss gradient" } else { "image" },
                net.stages.len(),
                spec.inputs.len()
            ));
        }
        // sharded forward dispatch: bits are pinned to the single-node
        // staged *tiled* chain (kernels::staged_reference) — not the fused
        // path, whose fully fused groups follow the naive accumulation
        // order. A shard panic degrades to the layered naive oracle like
        // every other network path.
        if spec.kind != "training" && self.shards > 1 {
            let plan = Arc::new(match self.shard_by {
                Some(s) => ShardPlan::new(
                    &net.stages,
                    s,
                    self.shards,
                    DEFAULT_TILE_MEM_WORDS,
                    &self.plans,
                ),
                None => ShardPlan::auto(
                    &net.stages,
                    self.shards,
                    DEFAULT_TILE_MEM_WORDS,
                    &self.plans,
                ),
            });
            let counters = Arc::new(ShardTrafficCounters::new(plan.workers()));
            let c = Arc::clone(&counters);
            return Ok(Box::new(FallbackExec::new(
                spec.key(),
                "sharded",
                "layered",
                Box::new(ShardedNetExec { plan, counters }),
                Box::new(NaiveNetExec {
                    stages: net.stages.clone(),
                    pass: NetPass::Forward,
                }),
                Some(Box::new(move || c.reset())),
            )));
        }
        let counters = Arc::new(NetTrafficCounters::new(net.stages.len()));
        let c = Arc::clone(&counters);
        let reset: Box<dyn Fn() + Send + Sync> = Box::new(move || c.reset());
        match spec.kind.as_str() {
            "training" => {
                let plan = Arc::new(FusePlan::for_pass(
                    NetPass::Backward,
                    &net.stages,
                    DEFAULT_TILE_MEM_WORDS,
                    &self.plans,
                ));
                Ok(Box::new(FallbackExec::new(
                    spec.key(),
                    "fused-bwd",
                    "layered",
                    Box::new(TrainingExec {
                        plan,
                        pool: self.tiled_pool(),
                        counters,
                    }),
                    Box::new(NaiveNetExec {
                        stages: net.stages.clone(),
                        pass: NetPass::Backward,
                    }),
                    Some(reset),
                )))
            }
            _ => {
                let plan = Arc::new(FusePlan::new(
                    &net.stages,
                    DEFAULT_TILE_MEM_WORDS,
                    &self.plans,
                ));
                Ok(Box::new(FallbackExec::new(
                    spec.key(),
                    "fused",
                    "layered",
                    Box::new(NetworkExec {
                        plan,
                        pool: self.tiled_pool(),
                        counters,
                    }),
                    Box::new(NaiveNetExec {
                        stages: net.stages.clone(),
                        pass: NetPass::Forward,
                    }),
                    Some(reset),
                )))
            }
        }
    }
}

/// Executes the seven-loop nest directly (the crate's oracle).
struct NaiveExec {
    shape: ConvShape,
}

impl Executable for NaiveExec {
    fn execute(&self, inputs: &[&Tensor4]) -> Result<Tensor4> {
        Ok(conv7nl_naive(inputs[0], inputs[1], &self.shape))
    }
}

/// Executes via explicit im2col + GEMM.
struct Im2colExec {
    shape: ConvShape,
}

impl Executable for Im2colExec {
    fn execute(&self, inputs: &[&Tensor4]) -> Result<Tensor4> {
        Ok(conv_im2col(inputs[0], inputs[1], &self.shape))
    }
}

/// Executes through the `kernels/` tiled engine, output tiles fanned out
/// over the backend's shared pool. The per-call `Arc` wrap copies the
/// operands once (pool jobs must be `'static`); see the ROADMAP open item
/// on scoped zero-copy dispatch.
struct TiledExec {
    plan: Arc<TilePlan>,
    pool: Arc<ThreadPool>,
    counters: Arc<TrafficCounters>,
}

impl Executable for TiledExec {
    fn execute(&self, inputs: &[&Tensor4]) -> Result<Tensor4> {
        let x = Arc::new(inputs[0].clone());
        let w = Arc::new(inputs[1].clone());
        Ok(conv_tiled_parallel(&x, &w, &self.plan, &self.pool, &self.counters))
    }

    fn execute_arc(&self, inputs: &[Arc<Tensor4>]) -> Result<Tensor4> {
        Ok(conv_tiled_parallel(
            &inputs[0],
            &inputs[1],
            &self.plan,
            &self.pool,
            &self.counters,
        ))
    }

    fn traffic(&self) -> Option<Traffic> {
        Some(self.counters.snapshot())
    }
}

/// Executes through the tiled Winograd F(2,3) transform-domain kernel,
/// tile blocks fanned out over the backend's shared pool. Winograd
/// reassociates the inner products (4 multiplies per 2 outputs), so this
/// path agrees with the oracles to the scaled tolerance of
/// [`crate::kernels::winograd_tolerance`], not bitwise.
struct WinogradExec {
    plan: Arc<WinoPlan>,
    pool: Arc<ThreadPool>,
    counters: Arc<TrafficCounters>,
}

impl Executable for WinogradExec {
    fn execute(&self, inputs: &[&Tensor4]) -> Result<Tensor4> {
        let x = Arc::new(inputs[0].clone());
        let w = Arc::new(inputs[1].clone());
        Ok(conv_winograd_parallel(
            &x,
            &w,
            &self.plan,
            &self.pool,
            &self.counters,
        ))
    }

    fn execute_arc(&self, inputs: &[Arc<Tensor4>]) -> Result<Tensor4> {
        Ok(conv_winograd_parallel(
            &inputs[0],
            &inputs[1],
            &self.plan,
            &self.pool,
            &self.counters,
        ))
    }

    fn traffic(&self) -> Option<Traffic> {
        Some(self.counters.snapshot())
    }
}

/// Executes one backward convolution (dFilter or dInput) through the
/// pass-generic `kernels/` tiled engine, output tiles fanned out over the
/// backend's shared pool — bitwise identical to the `conv/training.rs`
/// naive oracles by the backward accumulation-order contract.
struct PassExec {
    pass: ConvPass,
    plan: Arc<TilePlan>,
    pool: Arc<ThreadPool>,
    counters: Arc<TrafficCounters>,
}

impl Executable for PassExec {
    fn execute(&self, inputs: &[&Tensor4]) -> Result<Tensor4> {
        let a = Arc::new(inputs[0].clone());
        let b = Arc::new(inputs[1].clone());
        Ok(conv_pass_tiled_parallel(
            self.pass,
            &a,
            &b,
            &self.plan,
            &self.pool,
            &self.counters,
        ))
    }

    fn execute_arc(&self, inputs: &[Arc<Tensor4>]) -> Result<Tensor4> {
        Ok(conv_pass_tiled_parallel(
            self.pass,
            &inputs[0],
            &inputs[1],
            &self.plan,
            &self.pool,
            &self.counters,
        ))
    }

    fn traffic(&self) -> Option<Traffic> {
        Some(self.counters.snapshot())
    }
}

/// The naive single-pass fallback for gradient kinds: runs the training
/// oracle directly (uncounted, serial) — the exact function the tiled
/// pass engine is bitwise-validated against.
struct NaivePassExec {
    pass: ConvPass,
    shape: ConvShape,
}

impl Executable for NaivePassExec {
    fn execute(&self, inputs: &[&Tensor4]) -> Result<Tensor4> {
        Ok(self.pass.naive_oracle(inputs[0], inputs[1], &self.shape))
    }
}

/// The layered (stage-by-stage naive) fallback for network pipelines:
/// runs [`naive_network`] / [`naive_network_bwd`] — the exact staged
/// oracles the fused executors are bitwise-validated against, so a
/// degraded network answer is still bitwise-correct.
struct NaiveNetExec {
    stages: Vec<NetworkStage>,
    pass: NetPass,
}

impl Executable for NaiveNetExec {
    fn execute(&self, inputs: &[&Tensor4]) -> Result<Tensor4> {
        let head = inputs[0];
        let filters: Vec<&Tensor4> = inputs[1..].to_vec();
        Ok(match self.pass {
            NetPass::Backward => naive_network_bwd(head, &filters, &self.stages),
            _ => naive_network(head, &filters, &self.stages),
        })
    }
}

/// Executes a whole network pipeline through the `kernels/fuse` fused
/// executor: fused groups sweep the last stage's output tiles with
/// inter-layer activations held in scratch, materialized stages run the
/// LP-tiled engine, tiles fanned out over the backend's shared pool.
struct NetworkExec {
    plan: Arc<FusePlan>,
    pool: Arc<ThreadPool>,
    counters: Arc<NetTrafficCounters>,
}

impl Executable for NetworkExec {
    fn execute(&self, inputs: &[&Tensor4]) -> Result<Tensor4> {
        let arcs: Vec<Arc<Tensor4>> =
            inputs.iter().map(|t| Arc::new((*t).clone())).collect();
        self.execute_arc(&arcs)
    }

    fn execute_arc(&self, inputs: &[Arc<Tensor4>]) -> Result<Tensor4> {
        let image = &inputs[0];
        let filters = &inputs[1..];
        Ok(conv_network_fused(
            image,
            filters,
            &self.plan,
            &self.pool,
            &self.counters,
        ))
    }

    fn traffic(&self) -> Option<Traffic> {
        Some(self.counters.total())
    }

    fn stage_traffic(&self) -> Option<Vec<Traffic>> {
        Some(self.counters.snapshot())
    }

    fn halo_words(&self) -> Option<Vec<u64>> {
        Some(self.counters.halo_snapshot())
    }
}

/// Executes a forward network pipeline across the backend's configured
/// in-process virtual shard workers (DESIGN.md §13): bitwise identical to
/// the single-node staged engine, with every inter-shard exchange word
/// counted against the analytic parallel volume.
struct ShardedNetExec {
    plan: Arc<ShardPlan>,
    counters: Arc<ShardTrafficCounters>,
}

impl Executable for ShardedNetExec {
    fn execute(&self, inputs: &[&Tensor4]) -> Result<Tensor4> {
        let arcs: Vec<Arc<Tensor4>> =
            inputs.iter().map(|t| Arc::new((*t).clone())).collect();
        self.execute_arc(&arcs)
    }

    fn execute_arc(&self, inputs: &[Arc<Tensor4>]) -> Result<Tensor4> {
        exec_sharded(&inputs[0], &inputs[1..], &self.plan, &self.counters)
    }

    fn traffic(&self) -> Option<Traffic> {
        // the exchange triple reported through the Traffic lens: halo rows
        // are input words, broadcast filters are filter words, traveling
        // accumulators are output words
        let t = self.counters.total();
        Some(Traffic {
            input_words: t.halo_words,
            filter_words: t.gather_words,
            output_words: t.reduce_words,
        })
    }
}

/// Executes a network pipeline's fused backward sweep (kind `"training"`):
/// the tail loss gradient chains through the transposed stencils back to
/// the head image gradient, fused groups keeping interior stage gradients
/// in scratch. Bitwise identical to chaining the per-stage dInput oracles
/// by the backward accumulation-order contract.
struct TrainingExec {
    plan: Arc<FusePlan>,
    pool: Arc<ThreadPool>,
    counters: Arc<NetTrafficCounters>,
}

impl Executable for TrainingExec {
    fn execute(&self, inputs: &[&Tensor4]) -> Result<Tensor4> {
        let arcs: Vec<Arc<Tensor4>> =
            inputs.iter().map(|t| Arc::new((*t).clone())).collect();
        self.execute_arc(&arcs)
    }

    fn execute_arc(&self, inputs: &[Arc<Tensor4>]) -> Result<Tensor4> {
        let gout = &inputs[0];
        let filters = &inputs[1..];
        Ok(conv_network_bwd(
            gout,
            filters,
            &self.plan,
            &self.pool,
            &self.counters,
        ))
    }

    fn traffic(&self) -> Option<Traffic> {
        Some(self.counters.total())
    }

    fn stage_traffic(&self) -> Option<Vec<Traffic>> {
        Some(self.counters.snapshot())
    }

    fn halo_words(&self) -> Option<Vec<u64>> {
        Some(self.counters.halo_snapshot())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    #[test]
    fn builtin_specs_roundtrip_to_shapes() {
        let m = Manifest::builtin(4);
        assert!(m.artifacts.len() >= 3);
        for spec in &m.artifacts {
            if spec.kind == "network" || spec.kind == "training" {
                // whole-network artifacts resolve through
                // Manifest::network, never the single-layer inversion
                assert!(spec.layer_shape().is_err(), "{}", spec.key());
                continue;
            }
            if let Some(pass) = ConvPass::parse(&spec.kind) {
                // gradient artifacts invert through the pass-aware
                // reconstruction instead of the (image, filter) one
                let s = spec.pass_shape(pass).expect("builtin gradient spec");
                assert_eq!(s.updates(), spec.updates, "{}", spec.key());
                assert!(s.paper_assumptions_hold(), "{}", spec.key());
                continue;
            }
            let s = spec.layer_shape().expect("builtin spec must be derivable");
            assert_eq!(s.n, spec.output[0] as u64, "{}", spec.key());
            assert_eq!(s.in_w() as usize, spec.inputs[0][2], "{}", spec.key());
            assert_eq!(s.in_h() as usize, spec.inputs[0][3], "{}", spec.key());
            assert_eq!(s.updates(), spec.updates, "{}", spec.key());
            assert!(s.paper_assumptions_hold(), "{}", spec.key());
        }
    }

    #[test]
    fn gradient_kinds_load_and_match_oracles_bitwise() {
        let shape = ConvShape::new(2, 3, 4, 6, 6, 3, 3, 2, 2);
        let mut be = NativeBackend::new();
        for pass in [ConvPass::DFilter, ConvPass::DInput] {
            let spec = ArtifactSpec::for_pass("g", pass, &shape);
            let exe = be.load(&spec, None).expect("gradient kind loads");
            let (a, b) = crate::conv::pass_operands(pass, &shape, 41);
            let got = exe.execute(&[&a, &b]).expect("gradient execute");
            let want = pass.naive_oracle(&a, &b, &shape);
            assert_eq!(got.dims.to_vec(), spec.output, "{}", pass.name());
            assert_eq!(
                got.max_abs_diff(&want),
                0.0,
                "{}: native gradient diverged from the oracle",
                pass.name()
            );
            // instrumented like the forward tiled kind
            assert!(exe.traffic().expect("instrumented").total() > 0);
            // a spec whose dims are not a consistent gradient problem is
            // rejected at load
            let mut bad = spec.clone();
            bad.inputs[0][0] += 1;
            assert!(be.load(&bad, None).is_err(), "{}", pass.name());
        }
    }

    #[test]
    fn im2col_matches_naive_unit_stride() {
        let s = ConvShape::new(2, 3, 4, 5, 5, 3, 3, 1, 1);
        let x = Tensor4::randn([2, 3, 8, 8], 1);
        let w = Tensor4::randn([3, 4, 3, 3], 2);
        let a = conv7nl_naive(&x, &w, &s);
        let b = conv_im2col(&x, &w, &s);
        assert!(a.rel_l2(&b) < 1e-5, "rel {}", a.rel_l2(&b));
    }

    #[test]
    fn im2col_matches_naive_strided() {
        let s = ConvShape::new(1, 2, 3, 4, 4, 3, 3, 2, 2);
        let x = Tensor4::randn([1, 2, 11, 11], 3);
        let w = Tensor4::randn([2, 3, 3, 3], 4);
        let a = conv7nl_naive(&x, &w, &s);
        let b = conv_im2col(&x, &w, &s);
        assert!(a.rel_l2(&b) < 1e-5, "rel {}", a.rel_l2(&b));
    }

    #[test]
    fn tiled_kind_loads_and_matches_oracle() {
        let shape = ConvShape::new(2, 3, 4, 6, 6, 3, 3, 1, 1);
        let spec = ArtifactSpec::for_layer("t", "tiled", &shape);
        let mut be = NativeBackend::new();
        let exe = be.load(&spec, None).expect("tiled kind loads");
        let x = Tensor4::randn(
            [2, 3, shape.in_w() as usize, shape.in_h() as usize],
            31,
        );
        let w = Tensor4::randn([3, 4, 3, 3], 32);
        let got = exe.execute(&[&x, &w]).expect("tiled execute");
        let want = conv7nl_naive(&x, &w, &shape);
        assert!(got.rel_l2(&want) < 1e-4, "rel {}", got.rel_l2(&want));
    }

    #[test]
    fn winograd_kind_loads_and_matches_oracle() {
        let shape = ConvShape::new(2, 3, 4, 6, 6, 3, 3, 1, 1);
        let spec = ArtifactSpec::for_layer("w", "winograd", &shape);
        let mut be = NativeBackend::new();
        let exe = be.load(&spec, None).expect("winograd kind loads");
        let x = Tensor4::randn(
            [2, 3, shape.in_w() as usize, shape.in_h() as usize],
            33,
        );
        let w = Tensor4::randn([3, 4, 3, 3], 34);
        let got = exe.execute(&[&x, &w]).expect("winograd execute");
        let want = conv7nl_naive(&x, &w, &shape);
        // Winograd reassociates the reduction: tolerance oracle, not bitwise.
        assert!(got.rel_l2(&want) < 1e-4, "rel {}", got.rel_l2(&want));
        assert!(exe.traffic().expect("instrumented").total() > 0);
    }

    #[test]
    fn backend_clones_share_plan_cache() {
        let shape = ConvShape::new(2, 3, 4, 6, 6, 3, 3, 1, 1);
        let spec = ArtifactSpec::for_layer("t", "tiled", &shape);
        let be = NativeBackend::new();
        let mut a = be.clone();
        let mut b = be.clone();
        a.load(&spec, None).expect("first load");
        b.load(&spec, None).expect("second load");
        assert_eq!(be.plans.len(), 1, "clones must share one plan cache");
    }

    #[test]
    fn network_pipeline_loads_and_matches_staged_oracle() {
        let net = NetworkSpec::tiny_resnet(2);
        let spec = ArtifactSpec::for_network(&net);
        let mut be = NativeBackend::new();
        let exe = be.load_network(&net, &spec).expect("load network");
        let image = Tensor4::randn(net.input_dims(), 5);
        let filters: Vec<Tensor4> = net
            .stages
            .iter()
            .enumerate()
            .map(|(i, st)| Tensor4::randn(st.shape.filter_dims(), 6 + i as u64))
            .collect();
        let mut ins: Vec<&Tensor4> = vec![&image];
        ins.extend(filters.iter());
        let got = exe.execute(&ins).expect("run network");
        let frefs: Vec<&Tensor4> = filters.iter().collect();
        let want = crate::kernels::naive_network(&image, &frefs, &net.stages);
        assert_eq!(got.dims.to_vec(), spec.output);
        assert_eq!(got.max_abs_diff(&want), 0.0, "fused must be bitwise");
        let per_stage = exe.stage_traffic().expect("network is instrumented");
        assert_eq!(per_stage.len(), net.stages.len());
        assert!(exe.traffic().expect("aggregate").total() > 0);
        // arity mismatch between spec and chain is rejected at load
        let mut bad = spec.clone();
        bad.inputs.pop();
        assert!(be.load_network(&net, &bad).is_err());
    }

    #[test]
    fn sharded_backend_matches_staged_engine_bitwise() {
        let net = NetworkSpec::tiny_resnet(2);
        let spec = ArtifactSpec::for_network(&net);
        let image = Tensor4::randn(net.input_dims(), 5);
        let filters: Vec<Tensor4> = net
            .stages
            .iter()
            .enumerate()
            .map(|(i, st)| Tensor4::randn(st.shape.filter_dims(), 6 + i as u64))
            .collect();
        let mut ins: Vec<&Tensor4> = vec![&image];
        ins.extend(filters.iter());
        // the sharded contract pins bits to the single-node staged tiled
        // chain, not the fused path (whose fully fused groups follow the
        // naive accumulation order instead)
        let want = {
            let cache = TilePlanCache::new();
            let p1 = ShardPlan::new(
                &net.stages,
                ShardStrategy::Batch,
                1,
                DEFAULT_TILE_MEM_WORDS,
                &cache,
            );
            let frefs: Vec<&Tensor4> = filters.iter().collect();
            crate::kernels::staged_reference(&image, &frefs, &p1)
        };
        for strategy in [
            None,
            Some(ShardStrategy::Batch),
            Some(ShardStrategy::Spatial),
            Some(ShardStrategy::Channel),
        ] {
            let mut be = NativeBackend::with_shards(3, strategy);
            let exe = be.load_network(&net, &spec).expect("sharded load");
            let got = exe.execute(&ins).expect("run sharded");
            assert_eq!(
                got.max_abs_diff(&want),
                0.0,
                "sharded ({strategy:?}) must be bitwise vs single-node"
            );
            // batch/spatial always broadcast filters to active peers, so
            // their exchange is provably nonzero here; channel's can be
            // legitimately zero when every stage keeps one ci chunk
            if matches!(
                strategy,
                Some(ShardStrategy::Batch) | Some(ShardStrategy::Spatial)
            ) {
                assert!(exe.traffic().expect("instrumented").total() > 0);
            }
            // no degradation happened on the healthy path
            let fs = exe.fault_stats().expect("fallback shell");
            assert_eq!((fs.panicked, fs.degraded), (0, 0));
        }
        // training pipelines ignore the shard config (backward sweeps are
        // single-node) and still load
        let tspec = ArtifactSpec::for_training(&net);
        let mut be = NativeBackend::with_shards(3, None);
        assert!(be.load_network(&net, &tspec).is_ok());
    }

    #[test]
    fn training_pipeline_loads_and_matches_backward_oracle() {
        let net = NetworkSpec::tiny_resnet(2);
        let spec = ArtifactSpec::for_training(&net);
        let mut be = NativeBackend::new();
        let exe = be.load_network(&net, &spec).expect("load training");
        let gd = &spec.inputs[0];
        let gout = Tensor4::randn([gd[0], gd[1], gd[2], gd[3]], 7);
        let filters: Vec<Tensor4> = net
            .stages
            .iter()
            .enumerate()
            .map(|(i, st)| Tensor4::randn(st.shape.filter_dims(), 8 + i as u64))
            .collect();
        let mut ins: Vec<&Tensor4> = vec![&gout];
        ins.extend(filters.iter());
        let got = exe.execute(&ins).expect("run training sweep");
        let frefs: Vec<&Tensor4> = filters.iter().collect();
        let want =
            crate::kernels::naive_network_bwd(&gout, &frefs, &net.stages);
        assert_eq!(got.dims.to_vec(), spec.output);
        assert_eq!(
            got.max_abs_diff(&want),
            0.0,
            "fused backward must be bitwise"
        );
        let per_stage = exe.stage_traffic().expect("training is instrumented");
        assert_eq!(per_stage.len(), net.stages.len());
        assert!(exe.traffic().expect("aggregate").total() > 0);
        assert!(exe.halo_words().is_some());
        // arity mismatch between spec and chain is rejected at load
        let mut bad = spec.clone();
        bad.inputs.pop();
        assert!(be.load_network(&net, &bad).is_err());
        // the single-layer load entry rejects the kind outright
        assert!(be.load(&spec, None).is_err());
    }

    #[test]
    fn rejects_non_layer_specs() {
        let shape = ConvShape::new(1, 1, 1, 2, 2, 1, 1, 1, 1);
        let mut spec = ArtifactSpec::for_layer("x", "network", &shape);
        assert!(NativeBackend::new().load(&spec, None).is_err());

        spec.kind = "blocked".to_string();
        assert!(NativeBackend::new().load(&spec, None).is_ok());

        spec.inputs[0][2] = 1; // breaks WI = σw·wO + wF
        assert!(NativeBackend::new().load(&spec, None).is_err());

        spec.inputs.pop(); // wrong arity
        assert!(NativeBackend::new().load(&spec, None).is_err());
    }
}
