//! The default execution backend: runs single-layer conv artifacts with
//! in-tree kernels — no PJRT, no artifact files, no external crates.
//!
//! An [`crate::runtime::ArtifactSpec`] of kind `"blocked"` executes through
//! [`crate::conv::conv7nl_naive`]; kind `"im2col"` executes through a
//! genuinely different code path ([`conv_im2col`]: patch-matrix + GEMM), so
//! blocked-vs-im2col agreement tests exercise real cross-validation even
//! without compiled artifacts. Other kinds (`"network"`, gradient passes)
//! require the PJRT backend.
//!
//! The [`ConvShape`] is recovered and validated by
//! [`ArtifactSpec::layer_shape`] (the one authoritative inversion of the
//! paper's input convention `WI = σw·wO + wF`): a spec that is not a
//! consistent paper-convention conv layer is rejected at load time.

use std::path::Path;

use crate::conv::{conv7nl_naive, ConvShape, Tensor4};
use crate::err;
use crate::util::error::Result;

use super::backend::{ExecBackend, Executable};
use super::manifest::ArtifactSpec;

/// The in-tree CPU backend.
#[derive(Debug, Clone, Copy, Default)]
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend
    }
}

impl ExecBackend for NativeBackend {
    fn platform(&self) -> String {
        "native-cpu".to_string()
    }

    fn load(
        &mut self,
        spec: &ArtifactSpec,
        _path: Option<&Path>,
    ) -> Result<Box<dyn Executable>> {
        match spec.kind.as_str() {
            "blocked" => Ok(Box::new(NaiveExec { shape: spec.layer_shape()? })),
            "im2col" => Ok(Box::new(Im2colExec { shape: spec.layer_shape()? })),
            other => Err(err!(
                "native backend cannot execute artifact '{}' of kind '{other}' \
                 (only single-layer 'blocked'/'im2col' specs); build with \
                 --features pjrt to run it over XLA",
                spec.key()
            )),
        }
    }
}

/// Executes the seven-loop nest directly (the crate's oracle).
struct NaiveExec {
    shape: ConvShape,
}

impl Executable for NaiveExec {
    fn execute(&self, inputs: &[&Tensor4]) -> Result<Tensor4> {
        Ok(conv7nl_naive(inputs[0], inputs[1], &self.shape))
    }
}

/// Executes via explicit im2col + GEMM.
struct Im2colExec {
    shape: ConvShape,
}

impl Executable for Im2colExec {
    fn execute(&self, inputs: &[&Tensor4]) -> Result<Tensor4> {
        Ok(conv_im2col(inputs[0], inputs[1], &self.shape))
    }
}

/// im2col reference convolution: materialize the `(N·wO·hO) × (cI·wF·hF)`
/// patch matrix, reshape the filter to `(cI·wF·hF) × cO`, multiply, and
/// scatter back to `(N, cO, wO, hO)`.
///
/// A deliberately different accumulation order from [`conv7nl_naive`], so
/// agreement between the two is a meaningful numerics check.
pub fn conv_im2col(x: &Tensor4, w: &Tensor4, s: &ConvShape) -> Tensor4 {
    let (n, ci, co) = (s.n as usize, s.c_i as usize, s.c_o as usize);
    let (wo, ho) = (s.w_o as usize, s.h_o as usize);
    let (wf, hf) = (s.w_f as usize, s.h_f as usize);
    let (sw, sh) = (s.s_w as usize, s.s_h as usize);
    assert_eq!(x.dims[0], n, "batch mismatch");
    assert_eq!(x.dims[1], ci, "input channel mismatch");
    assert_eq!(w.dims, [ci, co, wf, hf], "filter shape mismatch");

    let k = ci * wf * hf;
    let rows = n * wo * ho;

    // A: patch matrix, row r = (i1, i4, i5), column c = (i2, i6, i7)
    let mut a = vec![0.0f32; rows * k];
    for i1 in 0..n {
        for i4 in 0..wo {
            for i5 in 0..ho {
                let r = (i1 * wo + i4) * ho + i5;
                for i2 in 0..ci {
                    for i6 in 0..wf {
                        for i7 in 0..hf {
                            let c = (i2 * wf + i6) * hf + i7;
                            a[r * k + c] = x.at(i1, i2, sw * i4 + i6, sh * i5 + i7);
                        }
                    }
                }
            }
        }
    }

    // B: reshaped filter, row c = (i2, i6, i7), column i3
    let mut b = vec![0.0f32; k * co];
    for i2 in 0..ci {
        for i3 in 0..co {
            for i6 in 0..wf {
                for i7 in 0..hf {
                    let c = (i2 * wf + i6) * hf + i7;
                    b[c * co + i3] = w.at(i2, i3, i6, i7);
                }
            }
        }
    }

    // C = A·B, scattered to NCWH
    let mut out = Tensor4::zeros([n, co, wo, ho]);
    for r in 0..rows {
        let i1 = r / (wo * ho);
        let rem = r % (wo * ho);
        let (i4, i5) = (rem / ho, rem % ho);
        for i3 in 0..co {
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += a[r * k + kk] * b[kk * co + i3];
            }
            *out.at_mut(i1, i3, i4, i5) = acc;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    #[test]
    fn builtin_specs_roundtrip_to_shapes() {
        let m = Manifest::builtin(4);
        assert!(m.artifacts.len() >= 3);
        for spec in &m.artifacts {
            let s = spec.layer_shape().expect("builtin spec must be derivable");
            assert_eq!(s.n, spec.output[0] as u64, "{}", spec.key());
            assert_eq!(s.in_w() as usize, spec.inputs[0][2], "{}", spec.key());
            assert_eq!(s.in_h() as usize, spec.inputs[0][3], "{}", spec.key());
            assert_eq!(s.updates(), spec.updates, "{}", spec.key());
            assert!(s.paper_assumptions_hold(), "{}", spec.key());
        }
    }

    #[test]
    fn im2col_matches_naive_unit_stride() {
        let s = ConvShape::new(2, 3, 4, 5, 5, 3, 3, 1, 1);
        let x = Tensor4::randn([2, 3, 8, 8], 1);
        let w = Tensor4::randn([3, 4, 3, 3], 2);
        let a = conv7nl_naive(&x, &w, &s);
        let b = conv_im2col(&x, &w, &s);
        assert!(a.rel_l2(&b) < 1e-5, "rel {}", a.rel_l2(&b));
    }

    #[test]
    fn im2col_matches_naive_strided() {
        let s = ConvShape::new(1, 2, 3, 4, 4, 3, 3, 2, 2);
        let x = Tensor4::randn([1, 2, 11, 11], 3);
        let w = Tensor4::randn([2, 3, 3, 3], 4);
        let a = conv7nl_naive(&x, &w, &s);
        let b = conv_im2col(&x, &w, &s);
        assert!(a.rel_l2(&b) < 1e-5, "rel {}", a.rel_l2(&b));
    }

    #[test]
    fn rejects_non_layer_specs() {
        let shape = ConvShape::new(1, 1, 1, 2, 2, 1, 1, 1, 1);
        let mut spec = ArtifactSpec::for_layer("x", "network", &shape);
        assert!(NativeBackend::new().load(&spec, None).is_err());

        spec.kind = "blocked".to_string();
        assert!(NativeBackend::new().load(&spec, None).is_ok());

        spec.inputs[0][2] = 1; // breaks WI = σw·wO + wF
        assert!(NativeBackend::new().load(&spec, None).is_err());

        spec.inputs.pop(); // wrong arity
        assert!(NativeBackend::new().load(&spec, None).is_err());
    }
}
